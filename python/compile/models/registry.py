"""Model registry: name -> ModelSpec with everything aot.py and the rust
manifest need (init/apply/loss fns + static shape and batch config).

Batch sizes / hyperparameters default to the paper's (§4.2-4.4) but are
overridable from the aot.py CLI so scaled-down artifact sets can be built
for CI.
"""

import dataclasses
from typing import Any, Callable, Dict, Tuple

from . import cifar, lm, mnist


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    init: Callable[..., Any]
    loss_and_metrics: Callable[..., Tuple[Any, Any]]
    # static data config consumed by rust via manifest.json
    input_shape: Tuple[int, ...]  # per-example feature shape (no batch dim)
    input_dtype: str  # "f32" | "i32"
    num_classes: int
    batch_size: int
    # paper hyperparameters
    lr: float
    weight_decay: float
    extra: Dict[str, Any]


def _mnist_spec(batch_size=32):
    return ModelSpec(
        name="mnist",
        init=mnist.init,
        loss_and_metrics=mnist.loss_and_metrics,
        input_shape=mnist.INPUT_SHAPE,
        input_dtype="f32",
        num_classes=mnist.NUM_CLASSES,
        batch_size=batch_size,
        lr=1e-3,  # paper §4.2
        weight_decay=0.0,
        extra={},
    )


def _cifar_spec(batch_size=32):
    return ModelSpec(
        name="cifar",
        init=cifar.init,
        loss_and_metrics=cifar.loss_and_metrics,
        input_shape=cifar.INPUT_SHAPE,
        input_dtype="f32",
        num_classes=cifar.NUM_CLASSES,
        batch_size=batch_size,
        lr=5e-4,  # paper §4.3
        weight_decay=0.0,
        extra={"paper_batch_size": 128},
    )


def _lm_spec(config_name="lm", batch_size=8):
    cfg = lm.CONFIGS[config_name]
    return ModelSpec(
        name=config_name,
        init=lm.make_init(cfg),
        loss_and_metrics=lm.make_loss(cfg),
        # one training example = seq_len + 1 tokens (input + shifted target)
        input_shape=(cfg.seq_len + 1,),
        input_dtype="i32",
        num_classes=cfg.vocab,
        batch_size=batch_size,
        lr=2e-5,  # paper §4.4 (AdamW)
        weight_decay=0.01,
        extra={
            "vocab": cfg.vocab,
            "seq_len": cfg.seq_len,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
        },
    )


MODELS: Dict[str, Callable[..., ModelSpec]] = {
    "mnist": _mnist_spec,
    "cifar": _cifar_spec,
    "lm": lambda batch_size=8: _lm_spec("lm", batch_size),
    "lm_medium": lambda batch_size=8: _lm_spec("lm_medium", batch_size),
    "lm14m": lambda batch_size=4: _lm_spec("lm14m", batch_size),
}


def get_model(name: str, **kw) -> ModelSpec:
    if name not in MODELS:
        raise KeyError(f"unknown model {name!r}; have {sorted(MODELS)}")
    return MODELS[name](**kw)
