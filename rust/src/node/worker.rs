//! The node thread body: local training + the two serverless federation
//! protocols.
//!
//! **Sync** (§3 "Synchronous serverless federated learning"): after each
//! epoch a node pushes `(round, weights, n_k)` and polls the store until
//! *all* K nodes' round-`r` entries are present, then every node aggregates
//! the same set client-side (so all nodes compute identical weights —
//! checked by `rust/tests/protocol_invariants.rs`).
//!
//! **Async** (Algorithm 1, FedAvgAsync): after each epoch, with probability
//! `C` the node pushes its weights, then compares the store's state hash
//! with the one it saw last; if the store changed, it pulls the latest
//! entry per peer, inserts its own weights as `ω[k]`, and aggregates with
//! its strategy. No global round and no waiting — a straggler never blocks
//! anyone.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{ExperimentConfig, FederationMode};
use crate::data::BatchLoader;
use crate::metrics::timeline::{SpanKind, Timeline};
use crate::metrics::RunLogger;
use crate::runtime::{Engine, Manifest, ModelBundle, TrainState};
use crate::store::{PushRequest, WeightStore};
use crate::strategy::{Contribution, Strategy};

use crate::util::Rng;

use super::{NodeHandle, NodeReport, NodeStatus};

/// Everything a node thread needs (moved into the thread).
pub struct NodeCtx {
    /// This node's id (also its index into per-node config vectors).
    pub node_id: usize,
    /// The experiment configuration (shared, read-only).
    pub cfg: Arc<ExperimentConfig>,
    /// Artifact manifest for loading the model bundle.
    pub manifest: Arc<Manifest>,
    /// The weight store shared by all nodes of the experiment.
    pub store: Arc<dyn WeightStore>,
    /// This node's own aggregation strategy instance (client-side state).
    pub strategy: Box<dyn Strategy>,
    /// Batch loader over this node's data shard.
    pub loader: BatchLoader,
    /// Shared wall-clock origin for timelines.
    pub origin: Instant,
    /// Shared start barrier so all nodes begin epoch 0 together.
    pub start: Arc<std::sync::Barrier>,
    /// Optional shared run logger (CSV metrics + JSONL events).
    pub logger: Option<Arc<RunLogger>>,
}

/// Spawn the node thread.
pub fn spawn_node(ctx: NodeCtx) -> NodeHandle {
    let node_id = ctx.node_id;
    let join = std::thread::Builder::new()
        .name(format!("fed-node-{node_id}"))
        .spawn(move || run_node(ctx))
        .expect("spawn node thread");
    NodeHandle { node_id, join }
}

fn run_node(mut ctx: NodeCtx) -> NodeReport {
    let mut timeline = Timeline::new(ctx.node_id, ctx.origin);
    let mut report = NodeReport {
        node_id: ctx.node_id,
        status: NodeStatus::Completed,
        epochs_done: 0,
        final_params: None,
        n_examples_per_epoch: (ctx.cfg.steps_per_epoch
            * batch_size_of(&ctx.manifest, &ctx.cfg.model)) as u64,
        epoch_losses: vec![],
        epoch_accs: vec![],
        aggregations: 0,
        pushes: 0,
        timeline: Timeline::new(ctx.node_id, ctx.origin),
        train_time: Duration::ZERO,
        wait_time: Duration::ZERO,
    };

    match run_node_inner(&mut ctx, &mut report, &mut timeline) {
        Ok(()) => {}
        Err(e) => {
            if report.status == NodeStatus::Completed {
                report.status = NodeStatus::Failed(format!("{e:#}"));
            }
        }
    }
    report.train_time = timeline.total(SpanKind::Train);
    report.wait_time = timeline.total(SpanKind::Wait);
    report.timeline = timeline;
    report
}

fn batch_size_of(manifest: &Manifest, model: &str) -> usize {
    manifest.model(model).map(|m| m.batch_size).unwrap_or(32)
}

fn run_node_inner(
    ctx: &mut NodeCtx,
    report: &mut NodeReport,
    timeline: &mut Timeline,
) -> anyhow::Result<()> {
    let cfg = Arc::clone(&ctx.cfg);
    let info = ctx.manifest.model(&cfg.model)?.clone();
    let engine = Engine::new()?;
    let bundle = ModelBundle::load(&engine, &info)?;

    // Same seed on every node -> identical w_0 ("initialize w_0",
    // Algorithm 1).
    let params = bundle.init_params(cfg.seed)?;
    let mut state = TrainState::new(params);
    let mut rng = Rng::new(cfg.seed ^ ((ctx.node_id as u64 + 1) << 20));

    let step_delay = cfg
        .node_delays_ms
        .get(ctx.node_id)
        .copied()
        .map(|ms| Duration::from_secs_f64(ms / 1000.0))
        .unwrap_or(Duration::ZERO);

    // async change detection: last store state hash we aggregated against
    let mut last_seen_hash: Option<u64> = None;

    ctx.start.wait();

    for epoch in 0..cfg.epochs {
        if let Some(crash) = &cfg.crash {
            if crash.node == ctx.node_id && crash.at_epoch == epoch {
                report.status = NodeStatus::Crashed { at_epoch: epoch };
                if let Some(lg) = &ctx.logger {
                    let _ = lg.log_event(
                        "node_crash",
                        &[("node", ctx.node_id.to_string()), ("epoch", epoch.to_string())],
                    );
                }
                let t = Instant::now();
                timeline.record(SpanKind::Crashed, t);
                return Ok(());
            }
        }

        // ---- local training -------------------------------------------
        let t_train = Instant::now();
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        bundle.run_steps(&mut state, &mut ctx.loader, cfg.steps_per_epoch, |_i, m| {
            loss_sum += m.loss as f64;
            acc_sum += m.acc_count as f64 / m.n_preds as f64;
            if !step_delay.is_zero() {
                std::thread::sleep(step_delay);
            }
        })?;
        timeline.record(SpanKind::Train, t_train);
        let mean_loss = loss_sum / cfg.steps_per_epoch as f64;
        let mean_acc = acc_sum / cfg.steps_per_epoch as f64;
        report.epoch_losses.push(mean_loss);
        report.epoch_accs.push(mean_acc);
        report.epochs_done = epoch + 1;
        if let Some(lg) = &ctx.logger {
            let _ = lg.log_metrics(&[
                ("node", ctx.node_id as f64),
                ("epoch", epoch as f64),
                ("train_loss", mean_loss),
                ("train_acc", mean_acc),
                ("elapsed_s", ctx.origin.elapsed().as_secs_f64()),
            ]);
        }
        if cfg.verbose {
            eprintln!(
                "[node {} epoch {}] loss={mean_loss:.4} acc={mean_acc:.4}",
                ctx.node_id, epoch
            );
        }

        // ---- federation ------------------------------------------------
        match cfg.mode {
            FederationMode::Local => {} // centralized baseline: no store
            FederationMode::Sync => {
                let round = epoch as u64;
                sync_federate(ctx, report, timeline, &mut state, round)?;
                if matches!(report.status, NodeStatus::Stalled { .. }) {
                    // The node is stuck at the barrier, not dead: its
                    // current weights still exist (and were pushed), so
                    // report them — the driver can evaluate what training
                    // achieved before the stall.
                    report.final_params = Some(state.params.clone());
                    return Ok(());
                }
            }
            FederationMode::Async => {
                // Algorithm 1: sampling gates the WeightUpdate step; a
                // non-sampled client keeps training on its own weights.
                if rng.chance(cfg.sample_prob) {
                    async_federate(ctx, report, timeline, &mut state, epoch, &mut last_seen_hash)?;
                }
            }
        }
    }

    report.final_params = Some(state.params.clone());
    Ok(())
}

/// Synchronous serverless federation: push for `round`, barrier-poll until
/// all peers' entries for `round` exist, aggregate client-side.
fn sync_federate(
    ctx: &mut NodeCtx,
    report: &mut NodeReport,
    timeline: &mut Timeline,
    state: &mut TrainState,
    round: u64,
) -> anyhow::Result<()> {
    let cfg = &ctx.cfg;
    ctx.store.push(PushRequest {
        node_id: ctx.node_id,
        round,
        epoch: round,
        n_examples: report.n_examples_per_epoch,
        params: Arc::new(state.params.clone()),
    })?;
    report.pushes += 1;

    // barrier: wait for all K entries of this round
    let t_wait = Instant::now();
    let entries = loop {
        let entries = ctx.store.entries_for_round(round)?;
        if entries.len() >= cfg.n_nodes {
            break entries;
        }
        if t_wait.elapsed() > cfg.sync_timeout {
            timeline.record(SpanKind::Wait, t_wait);
            report.status = NodeStatus::Stalled { at_round: round };
            if let Some(lg) = &ctx.logger {
                let _ = lg.log_event(
                    "sync_stall",
                    &[("node", ctx.node_id.to_string()), ("round", round.to_string())],
                );
            }
            return Ok(());
        }
        std::thread::sleep(Duration::from_micros(200));
    };
    timeline.record(SpanKind::Wait, t_wait);

    let t_agg = Instant::now();
    let contribs: Vec<Contribution> = entries
        .iter()
        .map(|e| Contribution {
            node_id: e.node_id,
            n_examples: e.n_examples,
            is_self: e.node_id == ctx.node_id,
            seq: e.seq,
            params: Arc::clone(&e.params),
        })
        .collect();
    if let Some(new_params) = ctx.strategy.aggregate(&contribs) {
        state.set_params(new_params);
        report.aggregations += 1;
    }
    timeline.record(SpanKind::Aggregate, t_agg);
    Ok(())
}

/// Asynchronous federation — Algorithm 1's WeightUpdate: push w^k, detect
/// store change by hash, pull ω, set ω[k] = w^k, aggregate client-side.
fn async_federate(
    ctx: &mut NodeCtx,
    report: &mut NodeReport,
    timeline: &mut Timeline,
    state: &mut TrainState,
    epoch: usize,
    last_seen_hash: &mut Option<u64>,
) -> anyhow::Result<()> {
    let t_agg = Instant::now();
    ctx.store.push(PushRequest {
        node_id: ctx.node_id,
        round: epoch as u64,
        epoch: epoch as u64,
        n_examples: report.n_examples_per_epoch,
        params: Arc::new(state.params.clone()),
    })?;
    report.pushes += 1;

    // "performs a check to see if the remote server has changed state"
    let hash = ctx.store.state_hash()?;
    let changed = last_seen_hash.map(|h| h != hash).unwrap_or(true);
    if changed {
        let entries = ctx.store.latest_per_node()?;
        // ω[k] <- w^k : own current weights replace our stored entry
        // (we keep the store-assigned seq so staleness-aware strategies
        // see honest sequence numbers).
        let mut contribs: Vec<Contribution> = entries
            .iter()
            .map(|e| Contribution {
                node_id: e.node_id,
                n_examples: e.n_examples,
                is_self: e.node_id == ctx.node_id,
                seq: e.seq,
                params: if e.node_id == ctx.node_id {
                    Arc::new(state.params.clone())
                } else {
                    Arc::clone(&e.params)
                },
            })
            .collect();
        if !contribs.iter().any(|c| c.is_self) {
            // our push raced a clear() or failed partially; contribute
            // locally anyway
            let max_seq = contribs.iter().map(|c| c.seq).max().unwrap_or(0);
            contribs.push(Contribution {
                node_id: ctx.node_id,
                n_examples: report.n_examples_per_epoch,
                is_self: true,
                seq: max_seq,
                params: Arc::new(state.params.clone()),
            });
        }
        if contribs.len() > 1 {
            if let Some(new_params) = ctx.strategy.aggregate(&contribs) {
                state.set_params(new_params);
                report.aggregations += 1;
            }
        }
        *last_seen_hash = Some(ctx.store.state_hash()?);
    }
    timeline.record(SpanKind::Aggregate, t_agg);
    Ok(())
}
