//! [`RealClock`] — wall-clock time; the behaviour every component had
//! before the clock abstraction existed.

use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::{Clock, Condition};

/// Wall-clock [`Clock`]: `now` is elapsed real time since construction,
/// `sleep` is `std::thread::sleep`, conditions are plain `Condvar`s and
/// participant registration is a no-op (real time advances on its own).
pub struct RealClock {
    origin: Instant,
}

impl RealClock {
    /// A clock whose origin is now.
    pub fn new() -> RealClock {
        RealClock { origin: Instant::now() }
    }

    /// The process-wide shared real clock — the default time source for
    /// stores built without an explicit clock. Its origin is the first
    /// call, which is fine for every user: they only ever take `now()`
    /// differences.
    pub fn shared() -> Arc<RealClock> {
        static SHARED: OnceLock<Arc<RealClock>> = OnceLock::new();
        Arc::clone(SHARED.get_or_init(|| Arc::new(RealClock::new())))
    }
}

impl Default for RealClock {
    fn default() -> Self {
        RealClock::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> Duration {
        self.origin.elapsed()
    }

    fn sleep(&self, d: Duration) {
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }

    fn condition(&self) -> Arc<dyn Condition> {
        Arc::new(RealCondition::default())
    }

    fn enter(&self) {}

    fn exit(&self) {}
}

/// Plain `Condvar`-backed [`Condition`] with an epoch counter.
#[derive(Default)]
struct RealCondition {
    epoch: Mutex<u64>,
    changed: Condvar,
}

impl Condition for RealCondition {
    fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    fn wait_past(&self, seen: u64, timeout: Duration) {
        // A huge timeout may not be representable as a deadline; treat
        // it as "wait forever".
        let deadline = Instant::now().checked_add(timeout);
        let mut e = self.epoch.lock().unwrap();
        loop {
            if *e > seen {
                return;
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if d <= now {
                        return;
                    }
                    let (guard, _) = self.changed.wait_timeout(e, d - now).unwrap();
                    e = guard;
                }
                None => e = self.changed.wait(e).unwrap(),
            }
        }
    }

    fn notify_all(&self) {
        let mut e = self.epoch.lock().unwrap();
        *e += 1;
        self.changed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_tracks_real_time() {
        let c = RealClock::new();
        let t0 = c.now();
        std::thread::sleep(Duration::from_millis(10));
        assert!(c.now() - t0 >= Duration::from_millis(9));
    }

    #[test]
    fn shared_clock_is_one_instance() {
        let a = RealClock::shared();
        let b = RealClock::shared();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn condition_timeout_is_real_time() {
        let c = RealClock::new();
        let cond = c.condition();
        let t0 = Instant::now();
        cond.wait_past(cond.epoch(), Duration::from_millis(30));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }
}
