//! Compression end-to-end: the acceptance scenario for the codec
//! subsystem. A 4-node async federation under `clock = virtual` with a
//! bandwidth-limited simulated-S3 store must move ≥3× fewer wire bytes
//! and finish in strictly less *simulated* wall-clock with `compress =
//! q8` than with `compress = none`, at identical `bytes_per_sec` — and
//! `compress = none` must keep the store contents bit-identical to the
//! pre-codec behaviour.
//!
//! The protocol-level harness below needs no artifacts or PJRT runtime;
//! the `run_experiment` end-to-end test skips itself when the artifacts
//! are not built (same environment contract as
//! `rust/tests/integration.rs`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use fedless::compress::{CodecKind, CodecState};
use fedless::config::{ExperimentConfig, FederationMode};
use fedless::metrics::timeline::Timeline;
use fedless::metrics::TrafficMeter;
use fedless::protocol::ProtocolKind;
use fedless::store::{LatencyConfig, LatencyStore, MemoryStore, WeightStore};
use fedless::strategy::StrategyKind;
use fedless::tensor::codec::raw_wire_bytes;
use fedless::tensor::FlatParams;
use fedless::time::{Clock, ParticipantGuard, VirtualClock};

const N_NODES: usize = 4;
const EPOCHS: usize = 6;
const PARAMS: usize = 4_096;

/// What one simulated node reports back.
struct SimNode {
    finish: Duration,
    traffic: TrafficMeter,
    params: FlatParams,
}

/// Drive a 4-node async federation on a virtual clock over a
/// bandwidth-limited store: each epoch is one `clock.sleep` ("training",
/// distinct per node) followed by the protocol's `after_epoch`, with
/// every push running through `compress`.
fn run_sim(compress: CodecKind, bytes_per_sec: u64) -> (Duration, Vec<SimNode>) {
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let cfg = ExperimentConfig {
        mode: FederationMode::Async,
        n_nodes: N_NODES,
        compress,
        ..Default::default()
    };
    let lat = LatencyConfig {
        base: Duration::from_millis(5),
        jitter: Duration::ZERO,
        bytes_per_sec,
    };
    let store: Arc<dyn WeightStore> = Arc::new(LatencyStore::with_clock(
        MemoryStore::with_clock(Arc::clone(&clock)),
        lat,
        7,
        Arc::clone(&clock),
    ));
    for _ in 0..N_NODES {
        clock.enter();
    }
    let start = Arc::new(std::sync::Barrier::new(N_NODES));
    let nodes = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..N_NODES)
            .map(|node_id| {
                let clock = Arc::clone(&clock);
                let store = Arc::clone(&store);
                let cfg = cfg.clone();
                let start = Arc::clone(&start);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    let mut protocol = ProtocolKind::from(cfg.mode).build(node_id, &cfg);
                    let mut strategy = StrategyKind::FedAvg.build();
                    let mut codec = CodecState::new(cfg.compress);
                    let mut timeline = Timeline::new(node_id);
                    // distinct starting weights so averaging is visible,
                    // in a training-like range
                    let mut params = FlatParams(
                        (0..PARAMS)
                            .map(|i| ((i as f32) * 0.0113).sin() * 0.5 + node_id as f32 * 0.01)
                            .collect(),
                    );
                    start.wait();
                    for epoch in 0..EPOCHS {
                        // distinct per-node train time so no two nodes
                        // share a simulated instant
                        clock.sleep(Duration::from_millis(40 + 7 * node_id as u64));
                        let mut ctx = fedless::protocol::EpochCtx {
                            node_id,
                            n_nodes: N_NODES,
                            round_k: N_NODES,
                            epoch,
                            n_examples: 100,
                            store: store.as_ref(),
                            strategy: strategy.as_mut(),
                            timeline: &mut timeline,
                            sync_timeout: Duration::from_secs(3600),
                            clock: clock.as_ref(),
                            codec: &mut codec,
                            pool: fedless::par::ChunkPool::from_config(cfg.threads),
                            tracer: None,
                        };
                        protocol.after_epoch(&mut ctx, &mut params).unwrap();
                    }
                    SimNode { finish: clock.now(), traffic: timeline.traffic, params }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<SimNode>>()
    });
    let wall = nodes.iter().map(|n| n.finish).max().unwrap();
    (wall, nodes)
}

fn total_traffic(nodes: &[SimNode]) -> TrafficMeter {
    let mut t = TrafficMeter::default();
    for n in nodes {
        t.merge(&n.traffic);
    }
    t
}

/// The acceptance scenario, artifact-free: q8 moves ≥3× fewer wire
/// bytes and finishes strictly sooner in simulated time at identical
/// bandwidth, while staying close to the uncompressed weights.
#[test]
fn q8_cuts_wire_bytes_3x_and_simulated_wall_clock_at_equal_bandwidth() {
    let bytes_per_sec = 1_000_000; // 1 MB/s: transfers dominate
    let t_real = Instant::now();
    let (wall_none, nodes_none) = run_sim(CodecKind::None, bytes_per_sec);
    let (wall_q8, nodes_q8) = run_sim(CodecKind::Q8, bytes_per_sec);
    assert!(
        t_real.elapsed() < Duration::from_secs(30),
        "virtual-clock runs must be CPU-bound, took {:?}",
        t_real.elapsed()
    );

    let t_none = total_traffic(&nodes_none);
    let t_q8 = total_traffic(&nodes_q8);
    // same protocol schedule: identical push counts
    assert_eq!(t_none.pushes, (N_NODES * EPOCHS) as u64);
    assert_eq!(t_q8.pushes, t_none.pushes);
    // uncompressed accounting is exact: every push is one v1 blob
    assert_eq!(t_none.bytes_pushed, t_none.pushes * raw_wire_bytes(PARAMS));

    // >= 3x fewer wire bytes in *each* direction and in total
    assert!(
        t_none.bytes_pushed as f64 >= 3.0 * t_q8.bytes_pushed as f64,
        "push bytes: none {} vs q8 {}",
        t_none.bytes_pushed,
        t_q8.bytes_pushed
    );
    assert!(
        t_none.total_bytes() as f64 >= 3.0 * t_q8.total_bytes() as f64,
        "total bytes: none {} vs q8 {}",
        t_none.total_bytes(),
        t_q8.total_bytes()
    );

    // strictly lower simulated wall-clock at the same bytes_per_sec
    assert!(
        wall_q8 < wall_none,
        "q8 must finish sooner: {wall_q8:?} vs {wall_none:?}"
    );

    // lossy but bounded: final weights stay close to the uncompressed
    // run's (per-push error is (chunk range)/255/2; six epochs of
    // averaging keep the accumulated drift far below this tolerance)
    for (a, b) in nodes_none.iter().zip(&nodes_q8) {
        let drift = a.params.max_abs_diff(&b.params);
        assert!(drift < 0.05, "node drift {drift} too large for q8");
        assert!(b.params.all_finite());
    }
}

/// `compress = none` is the pre-codec system, bit for bit: entries
/// deposited through the codec-threaded push path carry the identical
/// params and the raw v1 wire size.
#[test]
fn compress_none_is_bit_identical_to_the_uncompressed_path() {
    let (_, nodes) = run_sim(CodecKind::None, 0);
    for n in &nodes {
        assert_eq!(
            n.traffic.bytes_pushed,
            EPOCHS as u64 * raw_wire_bytes(PARAMS),
            "every push costs exactly the v1 blob"
        );
    }

    // and directly: a TestNode-shaped push deposits the exact input bits
    let store = MemoryStore::new();
    let cfg = ExperimentConfig {
        mode: FederationMode::Async,
        n_nodes: 2,
        ..Default::default()
    };
    let mut protocol = ProtocolKind::from(cfg.mode).build(0, &cfg);
    let mut strategy = StrategyKind::FedAvg.build();
    let mut codec = CodecState::new(CodecKind::None);
    let mut timeline = Timeline::new(0);
    let mut params = FlatParams(vec![0.123456789, -7.25, 3.0e-20, 1.5e20]);
    let clock = fedless::time::RealClock::shared();
    let mut ctx = fedless::protocol::EpochCtx {
        node_id: 0,
        n_nodes: 2,
        round_k: 2,
        epoch: 0,
        n_examples: 100,
        store: &store,
        strategy: strategy.as_mut(),
        timeline: &mut timeline,
        sync_timeout: Duration::from_secs(1),
        clock: clock.as_ref(),
        codec: &mut codec,
        pool: fedless::par::ChunkPool::sequential(),
        tracer: None,
    };
    let expected = params.clone();
    protocol.after_epoch(&mut ctx, &mut params).unwrap();
    let e = store.latest_for_node(0).unwrap().unwrap();
    for (a, b) in e.params.0.iter().zip(expected.0.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "stored bits must be the input bits");
    }
    assert_eq!(e.wire_bytes, raw_wire_bytes(4));
}

/// Delta-q8 costs exactly one flag byte per push over plain q8 (the
/// tighter-reconstruction half of the trade is unit-tested in
/// `compress/delta.rs`).
#[test]
fn delta_q8_wire_cost_is_q8_plus_flag_byte() {
    let bytes_per_sec = 1_000_000;
    let (_, nodes_q8) = run_sim(CodecKind::Q8, bytes_per_sec);
    let (_, nodes_dq8) = run_sim(CodecKind::DeltaQ8, bytes_per_sec);
    let t_q8 = total_traffic(&nodes_q8);
    let t_dq8 = total_traffic(&nodes_dq8);
    // same pushes; delta adds exactly one flag byte per push
    assert_eq!(t_dq8.pushes, t_q8.pushes);
    assert_eq!(t_dq8.bytes_pushed, t_q8.bytes_pushed + t_q8.pushes);
    for n in &nodes_dq8 {
        assert!(n.params.all_finite());
    }
}

/// TopK sparsification shows up in the accounting with its own ratio.
#[test]
fn topk_wire_bytes_match_the_kept_fraction() {
    let (_, nodes) = run_sim(CodecKind::TopK { frac: 0.1 }, 0);
    let t = total_traffic(&nodes);
    let k = (PARAMS as f64 * 0.1).ceil() as u64;
    // per push: v2 header (72) + count (4) + 8k pair bytes
    let per_push = 72 + 4 + 8 * k;
    assert_eq!(t.bytes_pushed, t.pushes * per_push);
    assert!(
        t.bytes_pushed * 4 < t.pushes * raw_wire_bytes(PARAMS),
        "topk:0.1 must be >4x smaller on the wire"
    );
}

// ---------------------------------------------------------------------------
// end-to-end through run_experiment (skipped without artifacts)

fn have_artifacts() -> bool {
    fedless::runtime::Manifest::discover().is_ok()
}

/// The full acceptance criterion through `run_experiment`: a 4-node
/// async mnist run under `clock = virtual` with a bandwidth-limited
/// store reports ≥3× fewer wire bytes via `TrafficMeter` and strictly
/// lower simulated `wall_clock_s` with `compress = q8` than with
/// `compress = none`, with the final-accuracy delta within the codec's
/// conformance bound's reach.
#[test]
fn e2e_q8_beats_none_on_bytes_and_simulated_wall_clock() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let base = ExperimentConfig {
        model: "mnist".into(),
        n_nodes: 4,
        mode: FederationMode::Async,
        epochs: 3,
        steps_per_epoch: 10,
        train_size: 1_200,
        test_size: 160,
        seed: 11,
        clock: fedless::config::ClockKind::Virtual,
        latency: Some(LatencyConfig {
            base: Duration::from_millis(10),
            jitter: Duration::ZERO,
            bytes_per_sec: 5_000_000,
        }),
        ..Default::default()
    };

    let none = fedless::sim::run_experiment(&base).unwrap();
    let q8 = fedless::sim::run_experiment(&ExperimentConfig {
        compress: CodecKind::Q8,
        ..base.clone()
    })
    .unwrap();

    assert!(none.all_completed && q8.all_completed);
    let t_none = none.total_traffic();
    let t_q8 = q8.total_traffic();
    assert!(t_none.total_bytes() > 0);
    assert!(
        t_none.total_bytes() as f64 >= 3.0 * t_q8.total_bytes() as f64,
        "wire bytes: none {} vs q8 {}",
        t_none.total_bytes(),
        t_q8.total_bytes()
    );
    assert!(
        q8.wall_clock_s < none.wall_clock_s,
        "simulated wall-clock: q8 {} vs none {}",
        q8.wall_clock_s,
        none.wall_clock_s
    );
    let acc_delta = (q8.final_accuracy - none.final_accuracy).abs();
    assert!(
        acc_delta < 0.1,
        "q8 accuracy must track the uncompressed run: delta {acc_delta}"
    );
}
