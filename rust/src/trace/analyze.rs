//! Round-history analytics over the store's round archive.
//!
//! The in-process stores' `EntryLog` retains **every** deposited entry
//! (nothing is evicted), and
//! [`crate::store::WeightStore::entries_for_round`] serves them back per
//! round — a post-hoc round archive with no extra retention machinery.
//! [`compute_divergence`] replays that archive: for each round it
//! re-derives the round aggregate (the same examples-weighted average
//! the clients computed) and measures every client update against it
//! (L2 distance and cosine similarity), then builds a pairwise cosine
//! matrix over the final round's clients and clusters them greedily at a
//! similarity threshold. Every kernel is the deterministic chunked
//! [`crate::tensor::flat`] arithmetic, so all numbers — and therefore
//! the rendered tables and exported JSON — are bit-identical across
//! schedulers and thread counts.

use anyhow::Result;

use crate::par::ChunkPool;
use crate::store::{WeightEntry, WeightStore};
use crate::tensor::flat::{
    cosine_pooled, sq_l2_diff_pooled, weighted_average_pooled, FlatParams,
};

/// Greedy clustering joins a client to a cluster when its cosine to the
/// cluster representative is at least this.
pub const DEFAULT_CLUSTER_THRESHOLD: f64 = 0.9;

/// Pairwise matrix + clustering are gated to fleets of at most this many
/// distinct final-round clients (the matrix is quadratic).
pub const PAIRWISE_MAX_NODES: usize = 64;

/// One client's distance to its round's aggregate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientDivergence {
    /// The client.
    pub node_id: usize,
    /// L2 distance of the client's deposited update to the round
    /// aggregate.
    pub l2: f64,
    /// Cosine similarity of the client's update to the round aggregate
    /// (0.0 for a zero-norm vector — never NaN).
    pub cosine: f64,
}

/// Divergence of every client against one round's aggregate.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundDivergence {
    /// The archived round.
    pub round: u64,
    /// Per-client rows, sorted by node id.
    pub clients: Vec<ClientDivergence>,
    /// Mean of the client L2 distances.
    pub mean_l2: f64,
    /// Mean of the client cosines.
    pub mean_cosine: f64,
}

/// The full round-history analytics record of one run.
#[derive(Clone, Debug, PartialEq)]
pub struct DivergenceReport {
    /// Non-empty archived rounds, in round order.
    pub rounds: Vec<RoundDivergence>,
    /// Node ids indexing [`DivergenceReport::pairwise_cosine`] (the final
    /// archived round's clients), empty when the pairwise pass was
    /// skipped.
    pub pairwise_nodes: Vec<usize>,
    /// Pairwise cosine-similarity matrix over the final round's client
    /// updates; `None` when that round had more than
    /// [`PAIRWISE_MAX_NODES`] clients.
    pub pairwise_cosine: Option<Vec<Vec<f64>>>,
    /// Greedy threshold clusters over the final round (each inner vec is
    /// one cluster's node ids, in id order).
    pub clusters: Vec<Vec<usize>>,
    /// The similarity threshold the clustering used.
    pub cluster_threshold: f64,
}

impl DivergenceReport {
    /// Mean over all archived rounds of the per-round mean client L2 —
    /// the sweep report's `divergence` column.
    pub fn mean_l2(&self) -> Option<f64> {
        if self.rounds.is_empty() {
            return None;
        }
        Some(self.rounds.iter().map(|r| r.mean_l2).sum::<f64>() / self.rounds.len() as f64)
    }

    /// Render the per-round divergence table, each client's drift
    /// trajectory, and the final-round cosine clusters.
    pub fn render(&self) -> String {
        let mut out = String::from(
            "per-round divergence (client update vs round aggregate):\nround | clients | mean L2 | mean cos\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{:>5} | {:>7} | {:>10.6} | {:>8.6}\n",
                r.round,
                r.clients.len(),
                r.mean_l2,
                r.mean_cosine
            ));
        }
        // drift trajectories: one row per client that appears anywhere
        let mut ids: Vec<usize> = Vec::new();
        for r in &self.rounds {
            for c in &r.clients {
                if !ids.contains(&c.node_id) {
                    ids.push(c.node_id);
                }
            }
        }
        ids.sort_unstable();
        if !ids.is_empty() {
            out.push_str("\nclient drift (L2 per round, `-` = not archived):\n");
            for id in ids {
                let cells: Vec<String> = self
                    .rounds
                    .iter()
                    .map(|r| {
                        r.clients
                            .iter()
                            .find(|c| c.node_id == id)
                            .map(|c| format!("{:.6}", c.l2))
                            .unwrap_or_else(|| "-".to_string())
                    })
                    .collect();
                out.push_str(&format!("node {:>3}: {}\n", id, cells.join(" ")));
            }
        }
        if let Some(m) = &self.pairwise_cosine {
            out.push_str(&format!(
                "\npairwise cosine, final round (nodes {:?}):\n",
                self.pairwise_nodes
            ));
            for row in m {
                let cells: Vec<String> = row.iter().map(|v| format!("{v:>7.4}")).collect();
                out.push_str(&format!("  {}\n", cells.join(" ")));
            }
        }
        if !self.clusters.is_empty() {
            out.push_str(&format!(
                "cosine clusters (threshold {}): {:?}\n",
                self.cluster_threshold, self.clusters
            ));
        }
        out
    }
}

/// Latest entry per node in a round's archive, sorted by node id.
fn round_roster(mut entries: Vec<WeightEntry>) -> Vec<WeightEntry> {
    entries.sort_by_key(|e| (e.node_id, e.seq));
    let mut roster: Vec<WeightEntry> = Vec::new();
    for e in entries {
        match roster.last_mut() {
            Some(last) if last.node_id == e.node_id => *last = e,
            _ => roster.push(e),
        }
    }
    roster
}

/// Greedy threshold clustering: walk clients in node-id order; join the
/// first cluster whose *representative* (first member) is at least
/// `threshold`-cosine-similar, else open a new cluster. Deterministic by
/// construction.
fn greedy_clusters(
    nodes: &[usize],
    params: &[&FlatParams],
    threshold: f64,
    pool: ChunkPool,
) -> Vec<Vec<usize>> {
    let mut clusters: Vec<Vec<usize>> = Vec::new();
    let mut reps: Vec<usize> = Vec::new(); // index into `params` per cluster
    for (i, &id) in nodes.iter().enumerate() {
        let mut joined = false;
        for (c, &rep) in reps.iter().enumerate() {
            if cosine_pooled(params[i], params[rep], pool) >= threshold {
                clusters[c].push(id);
                joined = true;
                break;
            }
        }
        if !joined {
            clusters.push(vec![id]);
            reps.push(i);
        }
    }
    clusters
}

/// Replay the store's round archive into a [`DivergenceReport`],
/// scanning rounds `0..rounds`. Returns `None` when no round deposited
/// anything (e.g. `mode = local`). All arithmetic runs on `pool`'s
/// deterministic chunked kernels.
pub fn compute_divergence(
    store: &dyn WeightStore,
    rounds: u64,
    pool: ChunkPool,
) -> Result<Option<DivergenceReport>> {
    let mut report_rounds = Vec::new();
    let mut final_roster: Vec<WeightEntry> = Vec::new();
    for round in 0..rounds {
        let roster = round_roster(store.entries_for_round(round)?);
        if roster.is_empty() {
            continue;
        }
        let dim = roster[0].params.len();
        if roster.iter().any(|e| e.params.len() != dim) {
            continue; // heterogeneous archive (shouldn't happen) — skip
        }
        let total: u64 = roster.iter().map(|e| e.n_examples).sum();
        let weights: Vec<f32> = roster
            .iter()
            .map(|e| {
                if total == 0 {
                    1.0 / roster.len() as f32
                } else {
                    e.n_examples as f32 / total as f32
                }
            })
            .collect();
        let refs: Vec<&FlatParams> = roster.iter().map(|e| e.params.as_ref()).collect();
        let aggregate = weighted_average_pooled(&refs, &weights, pool);
        let clients: Vec<ClientDivergence> = roster
            .iter()
            .map(|e| ClientDivergence {
                node_id: e.node_id,
                l2: sq_l2_diff_pooled(e.params.as_ref(), &aggregate, pool).sqrt(),
                cosine: cosine_pooled(e.params.as_ref(), &aggregate, pool),
            })
            .collect();
        let n = clients.len() as f64;
        report_rounds.push(RoundDivergence {
            round,
            mean_l2: clients.iter().map(|c| c.l2).sum::<f64>() / n,
            mean_cosine: clients.iter().map(|c| c.cosine).sum::<f64>() / n,
            clients,
        });
        final_roster = roster;
    }
    if report_rounds.is_empty() {
        return Ok(None);
    }
    let (pairwise_nodes, pairwise_cosine, clusters) =
        if final_roster.len() <= PAIRWISE_MAX_NODES {
            let nodes: Vec<usize> = final_roster.iter().map(|e| e.node_id).collect();
            let refs: Vec<&FlatParams> =
                final_roster.iter().map(|e| e.params.as_ref()).collect();
            let matrix: Vec<Vec<f64>> = refs
                .iter()
                .map(|a| refs.iter().map(|b| cosine_pooled(a, b, pool)).collect())
                .collect();
            let clusters = greedy_clusters(&nodes, &refs, DEFAULT_CLUSTER_THRESHOLD, pool);
            (nodes, Some(matrix), clusters)
        } else {
            (Vec::new(), None, Vec::new())
        };
    Ok(Some(DivergenceReport {
        rounds: report_rounds,
        pairwise_nodes,
        pairwise_cosine,
        clusters,
        cluster_threshold: DEFAULT_CLUSTER_THRESHOLD,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{MemoryStore, PushRequest};
    use std::sync::Arc;

    fn push(store: &MemoryStore, node_id: usize, round: u64, xs: Vec<f32>, n_examples: u64) {
        store
            .push(PushRequest {
                node_id,
                round,
                epoch: round,
                n_examples,
                wire_bytes: (xs.len() * 4) as u64,
                params: Arc::new(FlatParams(xs)),
            })
            .unwrap();
    }

    /// Hand-checkable archive: clients at [0;4] and [2;4] with equal
    /// weights average to [1;4]; each client is L2 = 2 away; the zero
    /// vector's cosine is defined 0, the other's is exactly 1.
    #[test]
    fn divergence_hand_values() {
        let store = MemoryStore::new();
        push(&store, 0, 0, vec![0.0; 4], 100);
        push(&store, 1, 0, vec![2.0; 4], 100);
        let rep = compute_divergence(&store, 1, ChunkPool::sequential())
            .unwrap()
            .expect("archive is non-empty");
        assert_eq!(rep.rounds.len(), 1);
        let r = &rep.rounds[0];
        assert_eq!(r.round, 0);
        assert_eq!(r.clients.len(), 2);
        assert_eq!(r.clients[0].l2, 2.0);
        assert_eq!(r.clients[1].l2, 2.0);
        assert_eq!(r.clients[0].cosine, 0.0, "zero vector cosine is defined 0");
        assert_eq!(r.clients[1].cosine, 1.0);
        assert_eq!(r.mean_l2, 2.0);
        // pairwise: 2 clients, identical-direction diagonal
        let m = rep.pairwise_cosine.as_ref().unwrap();
        assert_eq!(m[1][1], 1.0);
        assert_eq!(m[0][1], 0.0);
        // zero vector opens its own cluster
        assert_eq!(rep.clusters, vec![vec![0], vec![1]]);
        assert!(rep.render().contains("round | clients"));
        assert!(!rep.render().contains("NaN"));
    }

    #[test]
    fn empty_archive_yields_none() {
        let store = MemoryStore::new();
        assert!(compute_divergence(&store, 4, ChunkPool::sequential()).unwrap().is_none());
    }

    /// A re-pushed round keeps only the node's latest entry, and the
    /// numbers are bit-identical across thread counts.
    #[test]
    fn roster_dedups_and_pool_is_bit_identical() {
        let store = MemoryStore::new();
        push(&store, 0, 0, vec![1.0, 0.0, 3.0, -1.0], 50);
        push(&store, 1, 0, vec![0.5, 2.0, -1.0, 4.0], 150);
        push(&store, 0, 0, vec![2.0, 1.0, 0.0, 1.0], 50); // supersedes
        let seq = compute_divergence(&store, 1, ChunkPool::sequential()).unwrap().unwrap();
        assert_eq!(seq.rounds[0].clients.len(), 2);
        for threads in [2usize, 8] {
            let par = compute_divergence(&store, 1, ChunkPool::new(threads)).unwrap().unwrap();
            assert_eq!(par, seq, "threads={threads}");
        }
    }
}
