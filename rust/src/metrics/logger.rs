//! Run logging: CSV (step metrics) + JSONL (events) under `runs/<name>/`.
//! This is the substitution for the paper's Weights & Biases tracking
//! (DESIGN.md §Substitutions) — every experiment leaves a reproducible
//! on-disk record.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// One typed value in a structured event — controls how the field is
/// rendered in `events.jsonl`, so numeric fields land as JSON numbers
/// (not quoted strings) and downstream tooling can aggregate without
/// re-parsing.
pub enum EventField {
    /// A string field (escaped).
    Str(String),
    /// A float field, emitted with Rust's shortest-round-trip `{}`
    /// formatting; non-finite values degrade to `0` (JSON has no NaN).
    Num(f64),
    /// An integer field, emitted exactly (no f64 precision loss).
    Int(u64),
}

impl EventField {
    fn render(&self) -> String {
        match self {
            EventField::Str(s) => format!("\"{}\"", escape(s)),
            EventField::Num(v) if v.is_finite() => format!("{v}"),
            EventField::Num(_) => "0".to_string(),
            EventField::Int(v) => format!("{v}"),
        }
    }
}

/// Thread-safe append-only logger for one run.
pub struct RunLogger {
    dir: PathBuf,
    csv: Mutex<BufWriter<File>>,
    events: Mutex<BufWriter<File>>,
    csv_header: Mutex<Option<Vec<String>>>,
}

impl RunLogger {
    /// Create `runs/<name>/{metrics.csv,events.jsonl}` (truncating).
    pub fn create<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
        let csv = BufWriter::new(File::create(dir.join("metrics.csv"))?);
        let events = BufWriter::new(File::create(dir.join("events.jsonl"))?);
        Ok(RunLogger {
            dir,
            csv: Mutex::new(csv),
            events: Mutex::new(events),
            csv_header: Mutex::new(None),
        })
    }

    /// The run directory this logger writes into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Log one row of named metric values; the first call fixes the column
    /// set and writes the header.
    pub fn log_metrics(&self, fields: &[(&str, f64)]) -> Result<()> {
        let mut header = self.csv_header.lock().unwrap();
        let mut csv = self.csv.lock().unwrap();
        match header.as_ref() {
            None => {
                let cols: Vec<String> = fields.iter().map(|(k, _)| k.to_string()).collect();
                writeln!(csv, "{}", cols.join(","))?;
                *header = Some(cols);
            }
            Some(cols) => {
                let now: Vec<&str> = fields.iter().map(|(k, _)| *k).collect();
                anyhow::ensure!(
                    cols.iter().map(String::as_str).eq(now.iter().copied()),
                    "metric columns changed mid-run: {:?} vs {:?}",
                    cols,
                    now
                );
            }
        }
        let row: Vec<String> = fields.iter().map(|(_, v)| format!("{v}")).collect();
        writeln!(csv, "{}", row.join(","))?;
        csv.flush()?;
        Ok(())
    }

    /// Log a structured event as one JSON line, every field a string.
    /// Prefer [`RunLogger::log_event_typed`] for numeric fields.
    pub fn log_event(&self, kind: &str, fields: &[(&str, String)]) -> Result<()> {
        let typed: Vec<(&str, EventField)> = fields
            .iter()
            .map(|(k, v)| (*k, EventField::Str(v.clone())))
            .collect();
        self.log_event_typed(kind, &typed)
    }

    /// Log a structured event as one JSON line with typed field values.
    pub fn log_event_typed(&self, kind: &str, fields: &[(&str, EventField)]) -> Result<()> {
        let mut ev = self.events.lock().unwrap();
        let mut line = format!("{{\"event\":\"{}\"", escape(kind));
        for (k, v) in fields {
            line.push_str(&format!(",\"{}\":{}", escape(k), v.render()));
        }
        line.push('}');
        writeln!(ev, "{line}")?;
        ev.flush()?;
        Ok(())
    }
}

/// JSON string-escape: quotes, backslashes, and *every* control
/// character (`\n`, `\r`, `\t`, and the rest as `\u00XX`) — a field
/// value can never break the one-line-per-event invariant or produce an
/// invalid JSON line.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "fedless_logger_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn writes_csv_with_header() {
        let dir = tmpdir("csv");
        let lg = RunLogger::create(&dir).unwrap();
        lg.log_metrics(&[("step", 1.0), ("loss", 2.5)]).unwrap();
        lg.log_metrics(&[("step", 2.0), ("loss", 2.0)]).unwrap();
        let text = fs::read_to_string(dir.join("metrics.csv")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,loss");
        assert_eq!(lines.len(), 3);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_changed_columns() {
        let dir = tmpdir("cols");
        let lg = RunLogger::create(&dir).unwrap();
        lg.log_metrics(&[("a", 1.0)]).unwrap();
        assert!(lg.log_metrics(&[("b", 1.0)]).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn events_are_valid_jsonl() {
        let dir = tmpdir("ev");
        let lg = RunLogger::create(&dir).unwrap();
        lg.log_event("node_crash", &[("node", "3".into()), ("msg", "a\"b".into())])
            .unwrap();
        let text = fs::read_to_string(dir.join("events.jsonl")).unwrap();
        let parsed = crate::util::json::Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.get("event").unwrap().as_str(), Some("node_crash"));
        assert_eq!(parsed.get("msg").unwrap().as_str(), Some("a\"b"));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn typed_events_emit_json_numbers_and_escape_controls() {
        let dir = tmpdir("typed");
        let lg = RunLogger::create(&dir).unwrap();
        lg.log_event_typed(
            "experiment_done",
            &[
                ("node", EventField::Int(u64::MAX)),
                ("idle", EventField::Num(0.25)),
                ("bad", EventField::Num(f64::NAN)),
                ("msg", EventField::Str("a\r\nb\tc\u{1}".into())),
            ],
        )
        .unwrap();
        let text = fs::read_to_string(dir.join("events.jsonl")).unwrap();
        let line = text.lines().next().unwrap();
        // raw JSON text: numbers unquoted, controls escaped in place
        assert!(line.contains("\"node\":18446744073709551615"), "{line}");
        assert!(line.contains("\"idle\":0.25"), "{line}");
        assert!(line.contains("\"bad\":0"), "{line}");
        assert!(line.contains("a\\r\\nb\\tc\\u0001"), "{line}");
        let parsed = crate::util::json::Json::parse(line).unwrap();
        assert_eq!(parsed.get("idle").unwrap().as_f64(), Some(0.25));
        assert_eq!(parsed.get("msg").unwrap().as_str(), Some("a\r\nb\tc\u{1}"));
        fs::remove_dir_all(&dir).unwrap();
    }
}
