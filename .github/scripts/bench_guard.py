#!/usr/bin/env python3
"""Bench-trajectory guard: fail CI when headline kernel throughput
regresses more than MAX_REGRESSION against the committed baseline.

Usage: bench_guard.py <committed BENCH_kernels.json> <fresh BENCH_kernels.json>

The committed file is snapshotted before the bench run overwrites it in
place. While the committed baseline carries an estimated (non-measured)
provenance, the guard prints the fresh numbers and exits 0 — the first
measured run committed back to the repo arms the comparison.
"""

import json
import sys

# (kernel, threads) headline rows, compared at the smallest common size
# (check mode measures only the smallest size).
HEADLINES = [("q8_encode", 1), ("hash_chunked", 1)]
MAX_REGRESSION = 0.30


def rows(doc):
    return {(r["kernel"], r["params"], r["threads"]): r["gbps"] for r in doc["results"]}


def main():
    if len(sys.argv) != 3:
        print(__doc__.strip())
        return 2
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)

    if base.get("provenance") != "measured":
        prov = str(base.get("provenance", "<missing>"))
        print(f"bench-guard: committed baseline is not measured (provenance: {prov[:60]}…)")
        print("bench-guard: skipping comparison; commit a measured run to arm the guard")
        return 0

    b, f = rows(base), rows(fresh)
    common = sorted({p for (_, p, _) in b} & {p for (_, p, _) in f})
    if not common:
        print("bench-guard: no common param size between baseline and fresh run; skipping")
        return 0
    size = common[0]

    failed = False
    for kernel, threads in HEADLINES:
        old = b.get((kernel, size, threads))
        new = f.get((kernel, size, threads))
        if old is None or new is None:
            print(f"bench-guard: {kernel} t={threads} @ {size}: row missing, skipping")
            continue
        ratio = new / old if old > 0 else float("inf")
        ok = ratio >= 1 - MAX_REGRESSION
        verdict = "ok" if ok else "REGRESSION"
        print(
            f"bench-guard: {kernel} t={threads} @ {size} params: "
            f"{old:.3f} -> {new:.3f} GB/s ({ratio:.2f}x) {verdict}"
        )
        failed = failed or not ok

    if failed:
        print(f"bench-guard: headline throughput regressed more than {MAX_REGRESSION:.0%}")
        return 1
    print("bench-guard: headline throughput within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
