//! Content-level adversary injection: a store wrapper that rewrites the
//! *weights* of selected pushes, extending [`super::FaultStore`]'s op
//! failures to the Byzantine-client threat model (any node that can
//! write to the serverless store can poison the global model — the open
//! security problem FedLess flags for serverless FL).
//!
//! The wrapper sits *outside* the wire stack (`run_experiment` stacks it
//! over [`super::LatencyStore`]), which models a malicious client
//! corrupting its update before upload: the rewritten weights travel the
//! real codec/blob/wire path, get charged to traffic accounting like any
//! honest push, and reach every peer's pull. All rewrites are
//! length-preserving, so `wire_bytes` stays truthful.
//!
//! Like the fault wrapper, the subscription path
//! (`version`/`wait_for_change`) and all read paths are forwarded
//! untouched — an adversary corrupts content, it does not desert the
//! barrier notification path (the PR-3 bug class; regression-tested
//! below).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::{PushRequest, WeightEntry, WeightStore};
use crate::tensor::FlatParams;
use crate::util::Rng;

/// Standard deviation of the `byzantine` attack's Gaussian noise —
/// large enough that a single corrupted vector dominates any plain mean.
pub const BYZANTINE_SIGMA: f32 = 1.0e6;

/// Which content attack the adversarial clients mount. Parsed from the
/// `adversary = byzantine:k | scale:<f> | signflip:k | stale:<rounds>`
/// config value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdversaryKind {
    /// `byzantine[:k]` — `k` clients (default 1) replace every pushed
    /// weight with seeded Gaussian noise of [`BYZANTINE_SIGMA`].
    Byzantine {
        /// Number of noise-pushing clients (0 = spec is a no-op).
        k: usize,
    },
    /// `scale[:<f>]` — one client multiplies its update by `f` (default
    /// 10; model-replacement / boosting attack).
    Scale {
        /// The multiplicative boost factor.
        factor: f64,
    },
    /// `signflip[:k]` — `k` clients (default 1) negate their update.
    SignFlip {
        /// Number of sign-flipping clients (0 = spec is a no-op).
        k: usize,
    },
    /// `stale[:<r>]` — one client replays the weights it pushed `r`
    /// rounds earlier (default 1; free-rider / staleness attack).
    Stale {
        /// How many pushes back the replayed weights come from (>= 1).
        rounds: usize,
    },
}

/// A parsed per-experiment adversary configuration. Adversarial roles
/// are assigned to the *highest* node ids (node 0, the conventional
/// reference node, stays honest), deterministically in `(spec, n_nodes)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdversarySpec {
    /// The attack the adversarial clients mount.
    pub kind: AdversaryKind,
}

impl AdversarySpec {
    /// Parse an `adversary` config/CLI value; `None` on anything
    /// malformed (including non-finite scale factors and `stale:0`).
    pub fn parse(s: &str) -> Option<AdversarySpec> {
        let lower = s.to_ascii_lowercase();
        let (name, arg) = match lower.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (lower.as_str(), None),
        };
        let kind = match name {
            "byzantine" => AdversaryKind::Byzantine { k: parse_count(arg, 1)? },
            "signflip" => AdversaryKind::SignFlip { k: parse_count(arg, 1)? },
            "scale" => {
                let factor = match arg {
                    Some(a) => a.parse::<f64>().ok().filter(|f| f.is_finite())?,
                    None => 10.0,
                };
                AdversaryKind::Scale { factor }
            }
            "stale" => {
                let rounds = parse_count(arg, 1)?;
                if rounds == 0 {
                    return None;
                }
                AdversaryKind::Stale { rounds }
            }
            _ => return None,
        };
        Some(AdversarySpec { kind })
    }

    /// Filesystem/label-safe short form: `byz1`, `scale10`, `signflip2`,
    /// `stale3`.
    pub fn label(&self) -> String {
        match self.kind {
            AdversaryKind::Byzantine { k } => format!("byz{k}"),
            AdversaryKind::Scale { factor } => format!("scale{factor}"),
            AdversaryKind::SignFlip { k } => format!("signflip{k}"),
            AdversaryKind::Stale { rounds } => format!("stale{rounds}"),
        }
    }

    /// Number of adversarial clients this spec assigns.
    pub fn n_adversaries(&self) -> usize {
        match self.kind {
            AdversaryKind::Byzantine { k } | AdversaryKind::SignFlip { k } => k,
            AdversaryKind::Scale { .. } | AdversaryKind::Stale { .. } => 1,
        }
    }

    /// True when `node_id` plays an adversarial role in an `n_nodes`
    /// federation (the highest `n_adversaries()` ids).
    pub fn is_adversary(&self, node_id: usize, n_nodes: usize) -> bool {
        node_id < n_nodes && node_id >= n_nodes.saturating_sub(self.n_adversaries())
    }
}

impl std::fmt::Display for AdversarySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

fn parse_count(arg: Option<&str>, default: usize) -> Option<usize> {
    match arg {
        Some(a) => a.parse().ok(),
        None => Some(default),
    }
}

/// Wraps an inner store; pushes from adversarial node ids get their
/// decoded weights rewritten per the [`AdversarySpec`] before they land.
/// Everything else — every read, the subscription path, wire accounting
/// — is forwarded untouched.
pub struct AdversaryStore<S> {
    inner: S,
    spec: AdversarySpec,
    n_nodes: usize,
    seed: u64,
    corrupted: AtomicU64,
    /// Per-node honest push history backing the `stale` replay attack.
    history: Mutex<HashMap<usize, Vec<Arc<FlatParams>>>>,
}

impl<S: WeightStore> AdversaryStore<S> {
    /// Wrap `inner`; `spec` picks the attack, `n_nodes` fixes which node
    /// ids play adversary, `seed` drives the Byzantine noise.
    pub fn new(inner: S, spec: AdversarySpec, n_nodes: usize, seed: u64) -> Self {
        AdversaryStore {
            inner,
            spec,
            n_nodes,
            seed,
            corrupted: Default::default(),
            history: Default::default(),
        }
    }

    /// Number of pushes whose content was rewritten so far.
    pub fn corrupted(&self) -> u64 {
        self.corrupted.load(Ordering::Relaxed)
    }

    /// The rewritten params for an adversarial push, or `None` when this
    /// particular push passes through unchanged (e.g. `stale` before any
    /// history exists).
    fn corrupt(&self, req: &PushRequest) -> Option<Arc<FlatParams>> {
        match self.spec.kind {
            AdversaryKind::Byzantine { .. } => {
                // The noise stream is derived from (seed, node, round)
                // alone — not from a shared generator — so replays are
                // bit-identical regardless of cross-node push ordering.
                let mut rng = Rng::new(
                    self.seed
                        ^ (req.node_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ req.round.wrapping_mul(0xD1B5_4A32_D192_ED03),
                );
                let noise: Vec<f32> =
                    (0..req.params.len()).map(|_| rng.normal_f32() * BYZANTINE_SIGMA).collect();
                Some(Arc::new(FlatParams(noise)))
            }
            AdversaryKind::Scale { factor } => Some(Arc::new(FlatParams(
                req.params.as_slice().iter().map(|x| (*x as f64 * factor) as f32).collect(),
            ))),
            AdversaryKind::SignFlip { .. } => {
                Some(Arc::new(FlatParams(req.params.as_slice().iter().map(|x| -x).collect())))
            }
            AdversaryKind::Stale { rounds } => {
                let mut history = self.history.lock().unwrap();
                let entries = history.entry(req.node_id).or_default();
                let replay = if entries.len() >= rounds {
                    Some(Arc::clone(&entries[entries.len() - rounds]))
                } else {
                    None // nothing old enough yet: the push passes through
                };
                entries.push(Arc::clone(&req.params));
                replay
            }
        }
    }
}

impl<S: WeightStore> WeightStore for AdversaryStore<S> {
    fn push(&self, mut req: PushRequest) -> Result<u64> {
        if self.spec.is_adversary(req.node_id, self.n_nodes) {
            if let Some(rewritten) = self.corrupt(&req) {
                self.corrupted.fetch_add(1, Ordering::Relaxed);
                req.params = rewritten;
            }
        }
        self.inner.push(req)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        self.inner.latest_per_node()
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        self.inner.entries_for_round(round)
    }

    fn state_hash(&self) -> Result<u64> {
        self.inner.state_hash()
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        // Forwarded untouched: corruption happens at push time, so reads
        // already observe whatever the adversary deposited.
        self.inner.latest_for_node(node_id)
    }

    fn version(&self) -> Result<u64> {
        // Never intercepted: `version`/`wait_for_change` are the barrier
        // notification path (see FaultStore — the PR-3 desertion bug
        // class). A content adversary corrupts weights, not wake-ups.
        self.inner.version()
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        self.inner.wait_for_change(since, timeout)
    }

    fn push_count(&self) -> u64 {
        self.inner.push_count()
    }

    fn clear(&self) -> Result<()> {
        self.history.lock().unwrap().clear();
        self.inner.clear()
    }

    fn push_if_version(&self, mut req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // Same content rewrite as a plain push, then forward to the
        // inner store's atomic CAS. A refused CAS still "spent" the
        // corruption (stale history advanced) — matching a real replay
        // adversary, who cannot observe the conditional-put verdict
        // before choosing its payload.
        if self.spec.is_adversary(req.node_id, self.n_nodes) {
            if let Some(rewritten) = self.corrupt(&req) {
                self.corrupted.fetch_add(1, Ordering::Relaxed);
                req.params = rewritten;
            }
        }
        self.inner.push_if_version(req, expected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{store_tests, MemoryStore};
    use crate::tensor::codec::{encode_blob_v2, read_blob, BlobMeta};

    fn spec(s: &str) -> AdversarySpec {
        AdversarySpec::parse(s).unwrap()
    }

    #[test]
    fn parse_and_label_round_trip() {
        assert_eq!(spec("byzantine").kind, AdversaryKind::Byzantine { k: 1 });
        assert_eq!(spec("byzantine:2").kind, AdversaryKind::Byzantine { k: 2 });
        assert_eq!(spec("scale").kind, AdversaryKind::Scale { factor: 10.0 });
        assert_eq!(spec("scale:2.5").kind, AdversaryKind::Scale { factor: 2.5 });
        assert_eq!(spec("signflip:3").kind, AdversaryKind::SignFlip { k: 3 });
        assert_eq!(spec("stale:4").kind, AdversaryKind::Stale { rounds: 4 });
        assert_eq!(spec("byzantine:2").label(), "byz2");
        assert_eq!(spec("scale:2.5").label(), "scale2.5");
        assert_eq!(spec("signflip").label(), "signflip1");
        assert_eq!(spec("stale").label(), "stale1");
        assert!(AdversarySpec::parse("stale:0").is_none());
        assert!(AdversarySpec::parse("scale:inf").is_none());
        assert!(AdversarySpec::parse("gremlin").is_none());
    }

    #[test]
    fn adversary_roles_take_highest_node_ids() {
        let s = spec("byzantine:2");
        assert!(!s.is_adversary(0, 4));
        assert!(!s.is_adversary(1, 4));
        assert!(s.is_adversary(2, 4));
        assert!(s.is_adversary(3, 4));
        assert!(!s.is_adversary(9, 4), "out-of-range ids are not adversaries");
        assert!(!spec("byzantine:0").is_adversary(3, 4), "k = 0 is a no-op spec");
    }

    /// A no-op spec must be fully transparent — the whole conformance
    /// suite (incl. subscription + concurrent pushes) over a wrapped
    /// backend.
    #[test]
    fn noop_spec_is_transparent() {
        store_tests::stack_conformance(|| {
            AdversaryStore::new(MemoryStore::new(), spec("byzantine:0"), 8, 42)
        });
    }

    #[test]
    fn corrupts_only_configured_pushes() {
        let s = AdversaryStore::new(MemoryStore::new(), spec("signflip:1"), 4, 7);
        for node in 0..4 {
            s.push(store_tests::push_req(node, 0, 2.0)).unwrap();
        }
        for node in 0..3 {
            let e = s.latest_for_node(node).unwrap().unwrap();
            assert_eq!(e.params.0, vec![2.0; 8], "honest node {node} untouched");
        }
        let e = s.latest_for_node(3).unwrap().unwrap();
        assert_eq!(e.params.0, vec![-2.0; 8], "adversarial push sign-flipped");
        assert_eq!(s.corrupted(), 1);
    }

    #[test]
    fn scale_boosts_and_byzantine_replaces() {
        let s = AdversaryStore::new(MemoryStore::new(), spec("scale:10"), 2, 7);
        s.push(store_tests::push_req(1, 0, 1.5)).unwrap();
        let e = s.latest_for_node(1).unwrap().unwrap();
        assert_eq!(e.params.0, vec![15.0; 8]);

        let s = AdversaryStore::new(MemoryStore::new(), spec("byzantine:1"), 2, 7);
        s.push(store_tests::push_req(1, 0, 1.5)).unwrap();
        let e = s.latest_for_node(1).unwrap().unwrap();
        assert_ne!(e.params.0, vec![1.5; 8], "weights replaced by noise");
        assert!(e.params.0.iter().any(|x| x.abs() > 1e3), "noise is large-variance");
        // wire accounting is untouched by the rewrite
        assert_eq!(e.wire_bytes, crate::tensor::codec::raw_wire_bytes(8));
    }

    #[test]
    fn byzantine_noise_is_order_independent_and_seeded() {
        let mk = || AdversaryStore::new(MemoryStore::new(), spec("byzantine:1"), 4, 42);
        let (a, b) = (mk(), mk());
        // same pushes, different arrival order
        for node in [0, 1, 2, 3] {
            a.push(store_tests::push_req(node, 0, 1.0)).unwrap();
        }
        for node in [3, 2, 1, 0] {
            b.push(store_tests::push_req(node, 0, 1.0)).unwrap();
        }
        let pa = &a.latest_for_node(3).unwrap().unwrap().params.0;
        let pb = &b.latest_for_node(3).unwrap().unwrap().params.0;
        assert_eq!(pa, pb, "noise depends on (seed, node, round), not arrival order");
        // a different seed draws different noise
        let c = AdversaryStore::new(MemoryStore::new(), spec("byzantine:1"), 4, 43);
        c.push(store_tests::push_req(3, 0, 1.0)).unwrap();
        assert_ne!(pa, &c.latest_for_node(3).unwrap().unwrap().params.0);
    }

    #[test]
    fn stale_replays_earlier_pushes() {
        let s = AdversaryStore::new(MemoryStore::new(), spec("stale:1"), 2, 7);
        for round in 0..3u64 {
            s.push(store_tests::push_req(1, round, round as f32)).unwrap();
        }
        // round 0 had no history -> passed through; rounds 1, 2 replay
        assert_eq!(s.entries_for_round(0).unwrap()[0].params.0[0], 0.0);
        assert_eq!(s.entries_for_round(1).unwrap()[0].params.0[0], 0.0);
        assert_eq!(s.entries_for_round(2).unwrap()[0].params.0[0], 1.0);
        assert_eq!(s.corrupted(), 2, "the pass-through push does not count as corrupted");
        // clear drops the replay history along with the entries
        s.clear().unwrap();
        s.push(store_tests::push_req(1, 0, 9.0)).unwrap();
        assert_eq!(s.entries_for_round(0).unwrap()[0].params.0[0], 9.0);
    }

    /// Regression (PR-3 bug class): the subscription path must never be
    /// intercepted — a waiter parked through the adversarial wrapper
    /// still wakes on a peer's push landing on the shared inner store.
    #[test]
    fn subscription_path_is_never_intercepted() {
        use std::time::Instant;

        let inner: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let s = Arc::new(AdversaryStore::new(Arc::clone(&inner), spec("byzantine:4"), 4, 7));
        let v0 = s.version().unwrap();
        let waiter = {
            let s = Arc::clone(&s);
            std::thread::spawn(move || s.wait_for_change(v0, Duration::from_secs(20)).unwrap())
        };
        std::thread::sleep(Duration::from_millis(30));
        let t = Instant::now();
        inner.push(store_tests::push_req(1, 0, 2.0)).unwrap();
        assert!(waiter.join().unwrap() > v0, "waiter observes the push through the wrapper");
        assert!(t.elapsed() < Duration::from_secs(10), "woken by the push, not the timeout");
    }

    /// Flip-sweep contrast: an adversarial rewrite *re-frames* a valid
    /// v2 blob — decode, corrupt the weights, re-encode with the hash
    /// recomputed — so integrity checking accepts it exactly like an
    /// honest push (the store hash is a checksum, not a signature; only
    /// robust aggregation defends against it). A hashless bit-flip, by
    /// contrast, is rejected at read time.
    #[test]
    fn reframed_blob_is_indistinguishable_from_honest() {
        let meta = BlobMeta { node_id: 3, round: 5, epoch: 5, n_examples: 100 };
        let honest = FlatParams(vec![1.25; 16]);
        let payload: Vec<u8> = honest.as_slice().iter().flat_map(|x| x.to_le_bytes()).collect();
        let blob = encode_blob_v2(&meta, 0, 0, honest.len(), &payload);

        // naive corruption: flip one payload bit without re-hashing
        let mut torn = blob.clone();
        let last = torn.len() - 1;
        torn[last] ^= 0x01;
        assert!(read_blob(&torn).is_err(), "hashless bit-flip is caught");

        // adversarial re-framing: rewrite the decoded weights, rebuild
        // the blob (encode_blob_v2 recomputes the whole-blob hash)
        let parsed = read_blob(&blob).unwrap();
        let decoded =
            crate::tensor::codec::decode_raw_payload(&parsed.payload, parsed.uncomp_len).unwrap();
        let corrupted = FlatParams(decoded.as_slice().iter().map(|x| -x).collect());
        let evil_payload: Vec<u8> =
            corrupted.as_slice().iter().flat_map(|x| x.to_le_bytes()).collect();
        let evil = encode_blob_v2(&meta, 0, 0, corrupted.len(), &evil_payload);

        let reparsed = read_blob(&evil).expect("re-framed blob passes every integrity check");
        assert_eq!(reparsed.meta, meta, "header metadata identical to the honest push");
        assert_eq!(reparsed.codec_id, parsed.codec_id);
        assert_eq!(evil.len(), blob.len(), "same wire size as the honest blob");
        let back =
            crate::tensor::codec::decode_raw_payload(&reparsed.payload, reparsed.uncomp_len)
                .unwrap();
        assert_eq!(back.0, vec![-1.25; 16], "peers decode the corrupted weights");
    }
}
