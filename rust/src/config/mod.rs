//! Experiment configuration: the programmatic [`ExperimentConfig`] plus a
//! small `key = value` config-file format for the `fedless` CLI.

mod file;

pub use file::{parse_config_text, ConfigError};

use std::path::PathBuf;
use std::time::Duration;

use crate::store::{AdversarySpec, FaultModel, LatencyConfig};
use crate::strategy::StrategyKind;

pub use crate::compress::CodecKind;
pub use crate::sched::{AvailabilitySpec, SchedulerKind};
pub use crate::time::ClockKind;

/// Peers pulled per epoch when `mode = gossip` gives no explicit fanout.
pub const DEFAULT_GOSSIP_FANOUT: usize = 2;

/// Parse a `threads` config/CLI value: `auto` (one kernel-pool worker
/// per hardware thread) or an explicit count ≥ 1. Returns the config
/// encoding (`0` = auto); rejects `0` and non-numbers.
pub fn parse_threads(s: &str) -> Option<usize> {
    if s.eq_ignore_ascii_case("auto") {
        return Some(0);
    }
    s.parse::<usize>().ok().filter(|&n| n >= 1)
}

/// Canonical label for a `threads` value (inverse of [`parse_threads`]):
/// `auto` for 0, the count otherwise. Used in sweep cell labels and
/// report columns.
pub fn threads_label(threads: usize) -> String {
    if threads == 0 {
        "auto".into()
    } else {
        threads.to_string()
    }
}

/// How nodes federate (which [`crate::protocol::FederationProtocol`] each
/// node runs after every local epoch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FederationMode {
    /// Serverless synchronous: barrier on the weight store each round.
    Sync,
    /// Serverless asynchronous: FedAvgAsync, paper Algorithm 1.
    Async,
    /// No federation. With `n_nodes = 1` this is the centralized baseline
    /// of the paper's tables; with more nodes it is the independent-silos
    /// lower bound (nodes never communicate; the driver still averages
    /// their final weights once, so grids can include a no-federation row).
    Local,
    /// Serverless gossip: each epoch a node pulls and merges with a
    /// seeded random subset of peers — no global barrier, no full fan-in.
    Gossip {
        /// Peers pulled per epoch (clamped to `n_nodes - 1` at runtime).
        fanout: usize,
    },
}

impl FederationMode {
    /// Parse a config/CLI mode name: `sync`, `async`, `local`, or
    /// `gossip[:m]` (e.g. `gossip:3`; bare `gossip` uses
    /// [`DEFAULT_GOSSIP_FANOUT`]).
    pub fn parse(s: &str) -> Option<FederationMode> {
        match s.to_ascii_lowercase().as_str() {
            "sync" => Some(FederationMode::Sync),
            "async" => Some(FederationMode::Async),
            "local" | "centralized" => Some(FederationMode::Local),
            "gossip" => Some(FederationMode::Gossip { fanout: DEFAULT_GOSSIP_FANOUT }),
            other => other
                .strip_prefix("gossip:")
                .and_then(|m| m.parse::<usize>().ok())
                .filter(|&fanout| fanout >= 1)
                .map(|fanout| FederationMode::Gossip { fanout }),
        }
    }

    /// Canonical lowercase protocol-family name (`gossip:3` and `gossip`
    /// both name the `gossip` family; see [`FederationMode::label`] for
    /// the parameterized form).
    pub fn name(self) -> &'static str {
        match self {
            FederationMode::Sync => "sync",
            FederationMode::Async => "async",
            FederationMode::Local => "local",
            FederationMode::Gossip { .. } => "gossip",
        }
    }

    /// Filesystem- and table-safe label including parameters, e.g.
    /// `gossip3` — distinct fanouts must land in distinct sweep cells and
    /// store namespaces, so labels (unlike [`FederationMode::name`])
    /// carry the fanout.
    pub fn label(self) -> String {
        match self {
            FederationMode::Gossip { fanout } => format!("gossip{fanout}"),
            other => other.name().to_string(),
        }
    }
}

/// Experiment scale preset (used by `fedbench --scale`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Seconds per cell; CI smoke.
    Smoke,
    /// Minutes per table; the EXPERIMENTS.md default.
    Small,
    /// Paper-sized steps/epochs/trials (hours on CPU).
    Paper,
}

impl Scale {
    /// Parse a CLI scale name (`smoke` / `small` / `paper`).
    pub fn parse(s: &str) -> Option<Scale> {
        match s.to_ascii_lowercase().as_str() {
            "smoke" => Some(Scale::Smoke),
            "small" => Some(Scale::Small),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    /// Canonical lowercase name (inverse of [`Scale::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Scale::Smoke => "smoke",
            Scale::Small => "small",
            Scale::Paper => "paper",
        }
    }
}

/// Where weights are exchanged.
#[derive(Clone, Debug, PartialEq)]
pub enum StoreKind {
    /// Single-lock in-process store ([`crate::store::MemoryStore`]).
    Memory,
    /// In-process store with this many independently locked shards
    /// ([`crate::store::ShardedStore`]) — use for 8+ nodes or sweeps.
    Sharded(usize),
    /// Directory of blob files ([`crate::store::FsStore`]) — shareable
    /// across OS processes, like the paper's S3 bucket.
    Fs(PathBuf),
}

impl StoreKind {
    /// Parse a config value: `memory`, `sharded`, `sharded:N`, or
    /// `fs:/path/to/dir`.
    pub fn parse(s: &str) -> Option<StoreKind> {
        if s == "memory" {
            Some(StoreKind::Memory)
        } else if s == "sharded" {
            Some(StoreKind::Sharded(crate::store::DEFAULT_SHARDS))
        } else if let Some(n) = s.strip_prefix("sharded:") {
            n.parse::<usize>().ok().filter(|&n| n >= 1).map(StoreKind::Sharded)
        } else {
            s.strip_prefix("fs:").map(|path| StoreKind::Fs(path.into()))
        }
    }
}

/// Failure injection: crash a node partway through training (§4.2.1
/// robustness experiments), optionally restarting it after a delay.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashSpec {
    /// Which node to crash.
    pub node: usize,
    /// Crash at the start of this 0-based epoch.
    pub at_epoch: usize,
    /// `Some(delay)`: the node restarts `delay` after crashing, restores
    /// its state from its own latest store entry (checkpoint-resume) and
    /// continues training. `None`: the crash is permanent (the original
    /// behaviour).
    pub restart: Option<Duration>,
}

impl CrashSpec {
    /// A permanent crash of `node` at `at_epoch` (no restart).
    pub fn at(node: usize, at_epoch: usize) -> Self {
        CrashSpec { node, at_epoch, restart: None }
    }
}

/// Full description of one federated training experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Model/dataset family: "mnist", "cifar", "lm" (+ lm_medium/lm14m).
    pub model: String,
    /// Number of federated nodes (clients).
    pub n_nodes: usize,
    /// Federation protocol: sync barrier, async Algorithm 1, gossip, or
    /// local (see [`crate::protocol`]).
    pub mode: FederationMode,
    /// Client-side aggregation strategy.
    pub strategy: StrategyKind,
    /// Label skew s ∈ [0, 1] (paper §4.1). Ignored for LM (random split).
    pub skew: f64,
    /// Local training epochs per node; federation happens at epoch ends.
    pub epochs: usize,
    /// Local SGD/Adam steps per epoch.
    pub steps_per_epoch: usize,
    /// Client-sampling probability C (Algorithm 1). 1.0 = every epoch.
    pub sample_prob: f64,
    /// Training examples across all nodes.
    pub train_size: usize,
    /// Held-out (un-partitioned) eval examples.
    pub test_size: usize,
    /// Trial seed: drives data synthesis, partitioning, init and sampling.
    pub seed: u64,
    /// Which weight-store backend the nodes share.
    pub store: StoreKind,
    /// Simulated store latency (None = instantaneous in-memory).
    pub latency: Option<LatencyConfig>,
    /// Per-node artificial per-step delay in ms (straggler simulation);
    /// empty = all nodes run at natural speed.
    pub node_delays_ms: Vec<f64>,
    /// Crash injection.
    pub crash: Option<CrashSpec>,
    /// Content-level adversary injection (`adversary = byzantine:k |
    /// scale:<f> | signflip:k | stale:<rounds>`): the configured number
    /// of clients — always the *highest* node ids — have their pushed
    /// weights rewritten by an [`crate::store::AdversaryStore`] wrapped
    /// around the experiment's store stack. Pair with a robust
    /// `strategy` (median / trimmed-mean / krum / trust-weighted) to
    /// measure attack resilience; `None` = all clients honest.
    pub adversary: Option<AdversarySpec>,
    /// Transient store-fault injection (`fault = <p>` sets the per-op
    /// Bernoulli rate; `outage = <start_s>:<dur_s>[, ...]` adds
    /// scheduled outage windows on the experiment clock). When the model
    /// is active each node's store stack gets a per-node
    /// [`crate::store::FaultStore`] under a retrying
    /// [`crate::store::RetryStore`] client, so injected failures are
    /// absorbed by backoff instead of killing the node. The per-node
    /// fault streams and retry jitter are seeded, so fault runs replay
    /// bit-identically under both schedulers.
    pub fault: FaultModel,
    /// Sync-barrier poll timeout before a node gives up on the round.
    pub sync_timeout: Duration,
    /// Sync-barrier quorum fraction in (0, 1] (`sync_quorum = <frac>`).
    /// At 1.0 (the default) a round needs the full cohort: a node whose
    /// peers never arrive stalls at `sync_timeout` (today's behaviour).
    /// Below 1.0 the barrier degrades gracefully: once half the timeout
    /// has passed (the soft deadline) a round closes as soon as
    /// `ceil(quorum * k)` cohort members have pushed, counting a
    /// `degraded_round` instead of stalling the node.
    pub sync_quorum: f64,
    /// Time domain of the experiment (`clock = real | virtual`): under
    /// [`ClockKind::Virtual`] straggler/latency sleeps and barrier
    /// timeouts consume simulated time — a discrete-event scheduler
    /// advances the clock whenever every node is blocked — so timing
    /// scenarios run at CPU speed with deterministic timelines.
    pub clock: ClockKind,
    /// Wire codec for weight exchange (`compress = none | q8 |
    /// topk:<frac> | delta-q8`): every push is encoded, its blob size
    /// charged by the latency layer and accounted by the traffic meter,
    /// and the store deposits the decoded reconstruction — so lossy
    /// compression has real (not modeled) accuracy effects. `none`
    /// keeps today's v1 blobs byte-for-byte.
    pub compress: CodecKind,
    /// Kernel-pool worker count (`threads = auto | N`; 0 = auto =
    /// one worker per hardware thread — see
    /// [`crate::par::ChunkPool::from_config`]). Drives the fused
    /// aggregation, codec encode/decode, and content-hash kernels.
    /// Results are bit-identical for every value (the [`crate::par`]
    /// determinism contract), so this is a pure wall-clock knob; the
    /// default of 1 keeps nested parallelism under the sweep
    /// scheduler opt-in.
    pub threads: usize,
    /// Node scheduler (`scheduler = threads | events`): `threads` (the
    /// default) runs one OS thread per node with an isolated PJRT engine;
    /// `events` steps every node as a resumable task on one
    /// discrete-event executor thread with a single shared engine — the
    /// 10k-client regime. Requires `clock = virtual`; simulated timelines
    /// and model digests match the threaded scheduler bit-for-bit, so
    /// this is a capacity knob, not an experiment variable (and run names
    /// carry no scheduler suffix).
    pub scheduler: SchedulerKind,
    /// Per-round client sampling fraction (`participation = <frac>` in
    /// (0, 1]): each round a seeded cohort of `max(1, round(frac * N))`
    /// of the online nodes trains and federates; the rest skip the round
    /// entirely (no training, no push, no simulated time). 1.0 = full
    /// participation (today's behavior, zero overhead).
    pub participation: f64,
    /// Per-node availability trace (`availability = none | churn:<p> |
    /// diurnal:<period> | stragglers:<frac>:<mult>`): seeded round-level
    /// churn, phase-shifted day/night cycles, or a persistently slow
    /// device fraction. Composes with `participation` — cohorts are
    /// sampled from the currently *online* nodes.
    pub availability: AvailabilitySpec,
    /// Structured tracing (`trace = true | false`): record typed
    /// per-node train/push/pull/aggregate events stamped on the
    /// experiment clock and export `trace.jsonl`,
    /// `trace_chrome.json` (Perfetto-loadable), and `analysis.json`
    /// (per-round divergence + per-node span shares, the input to
    /// `fedbench inspect`) into the run's log directory. On by default
    /// for `fedbench run` (opt out with `--no-trace`); off by default
    /// here so library embedders pay nothing unasked.
    pub trace: bool,
    /// Write metrics.csv / events.jsonl here.
    pub log_dir: Option<PathBuf>,
    /// Print per-epoch progress.
    pub verbose: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "mnist".into(),
            n_nodes: 2,
            mode: FederationMode::Async,
            strategy: StrategyKind::FedAvg,
            skew: 0.0,
            epochs: 3,
            steps_per_epoch: 120,
            sample_prob: 1.0,
            train_size: 8_000,
            test_size: 1_600,
            seed: 42,
            store: StoreKind::Memory,
            latency: None,
            node_delays_ms: Vec::new(),
            crash: None,
            adversary: None,
            fault: FaultModel::default(),
            sync_timeout: Duration::from_secs(120),
            sync_quorum: 1.0,
            clock: ClockKind::Real,
            compress: CodecKind::None,
            threads: 1,
            scheduler: SchedulerKind::Threads,
            participation: 1.0,
            availability: AvailabilitySpec::None,
            trace: false,
            log_dir: None,
            verbose: false,
        }
    }
}

impl ExperimentConfig {
    /// Validate invariants early with readable errors.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_nodes >= 1, "n_nodes must be >= 1");
        anyhow::ensure!((0.0..=1.0).contains(&self.skew), "skew in [0,1]");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.sample_prob),
            "sample_prob in [0,1]"
        );
        anyhow::ensure!(self.epochs >= 1, "epochs must be >= 1");
        anyhow::ensure!(self.steps_per_epoch >= 1, "steps_per_epoch >= 1");
        anyhow::ensure!(
            self.train_size >= self.n_nodes,
            "train_size must cover all nodes"
        );
        if let Some(c) = &self.crash {
            anyhow::ensure!(c.node < self.n_nodes, "crash.node out of range");
            if let Some(delay) = c.restart {
                anyhow::ensure!(delay > Duration::ZERO, "crash restart delay must be > 0");
            }
        }
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.fault.p_fail),
            "fault probability in [0, 1]"
        );
        anyhow::ensure!(
            self.sync_quorum > 0.0 && self.sync_quorum <= 1.0,
            "sync_quorum in (0, 1]"
        );
        if let Some(a) = &self.adversary {
            anyhow::ensure!(
                a.n_adversaries() < self.n_nodes,
                "adversary count {} must leave at least one honest node (n_nodes = {})",
                a.n_adversaries(),
                self.n_nodes
            );
        }
        if let FederationMode::Gossip { fanout } = self.mode {
            anyhow::ensure!(fanout >= 1, "gossip fanout must be >= 1");
        }
        anyhow::ensure!(
            self.participation > 0.0 && self.participation <= 1.0,
            "participation in (0, 1]"
        );
        if self.scheduler == SchedulerKind::Events {
            // the event executor *is* a discrete-event simulator; there
            // is no real-time variant of it
            anyhow::ensure!(
                self.clock == ClockKind::Virtual,
                "scheduler = events requires clock = virtual"
            );
        }
        match self.availability {
            AvailabilitySpec::None => {}
            AvailabilitySpec::Churn { p } => {
                anyhow::ensure!((0.0..1.0).contains(&p), "churn probability in [0, 1)");
            }
            AvailabilitySpec::Diurnal { period } => {
                anyhow::ensure!(period >= 2, "diurnal period must be >= 2 rounds");
            }
            AvailabilitySpec::Stragglers { frac, mult } => {
                anyhow::ensure!((0.0..=1.0).contains(&frac), "straggler fraction in [0, 1]");
                anyhow::ensure!(mult >= 1.0, "straggler multiplier must be >= 1");
            }
        }
        Ok(())
    }

    /// Short run identifier, e.g. `mnist_async_fedavg_n2_s0.9_seed42`
    /// (gossip runs carry the fanout, `mnist_gossip2_...`; parameterized
    /// strategies carry their parameter, `..._krum1_...`; compressed
    /// runs carry the codec, `..._seed42_q8`; attacked runs carry the
    /// adversary label, `..._byz1`; partial-participation runs carry the
    /// fraction, `..._p0.1`, and availability traces their label,
    /// `..._churn0.3`). The scheduler adds **no** suffix: both schedulers
    /// replay the same timelines and digests, so they are the same run.
    pub fn run_name(&self) -> String {
        let compress = match self.compress {
            CodecKind::None => String::new(),
            other => format!("_{}", other.label()),
        };
        let adversary = match &self.adversary {
            None => String::new(),
            Some(a) => format!("_{}", a.label()),
        };
        let participation = if self.participation < 1.0 {
            format!("_p{}", self.participation)
        } else {
            String::new()
        };
        let availability = match self.availability.label() {
            l if l.is_empty() => String::new(),
            l => format!("_{l}"),
        };
        let fault = if self.fault.p_fail > 0.0 {
            format!("_f{}", self.fault.p_fail)
        } else {
            String::new()
        };
        let quorum = if self.sync_quorum < 1.0 {
            format!("_sq{}", self.sync_quorum)
        } else {
            String::new()
        };
        format!(
            "{}_{}_{}_n{}_s{}_seed{}{compress}{adversary}{participation}{availability}{fault}{quorum}",
            self.model,
            self.mode.label(),
            self.strategy.label(),
            self.n_nodes,
            self.skew,
            self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_configs() {
        let c = ExperimentConfig { n_nodes: 0, ..Default::default() };
        assert!(c.validate().is_err());

        let c = ExperimentConfig { skew: 1.5, ..Default::default() };
        assert!(c.validate().is_err());

        let c = ExperimentConfig {
            crash: Some(CrashSpec::at(5, 0)),
            ..Default::default()
        };
        assert!(c.validate().is_err());

        let c = ExperimentConfig {
            crash: Some(CrashSpec { node: 0, at_epoch: 1, restart: Some(Duration::ZERO) }),
            ..Default::default()
        };
        assert!(c.validate().is_err(), "zero restart delay is rejected");

        let c = ExperimentConfig {
            fault: FaultModel { p_fail: 1.5, ..Default::default() },
            ..Default::default()
        };
        assert!(c.validate().is_err());

        let c = ExperimentConfig { sync_quorum: 0.0, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ExperimentConfig { sync_quorum: 1.5, ..Default::default() };
        assert!(c.validate().is_err());

        let c = ExperimentConfig {
            mode: FederationMode::Gossip { fanout: 0 },
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn local_with_many_nodes_is_the_silo_baseline() {
        let c = ExperimentConfig {
            mode: FederationMode::Local,
            n_nodes: 3,
            ..Default::default()
        };
        c.validate().unwrap();
    }

    #[test]
    fn mode_and_scale_parse() {
        assert_eq!(FederationMode::parse("SYNC"), Some(FederationMode::Sync));
        assert_eq!(FederationMode::parse("centralized"), Some(FederationMode::Local));
        assert_eq!(Scale::parse("paper"), Some(Scale::Paper));
        assert_eq!(Scale::parse("x"), None);
    }

    #[test]
    fn gossip_mode_parse_and_label() {
        assert_eq!(
            FederationMode::parse("gossip"),
            Some(FederationMode::Gossip { fanout: DEFAULT_GOSSIP_FANOUT })
        );
        assert_eq!(
            FederationMode::parse("gossip:3"),
            Some(FederationMode::Gossip { fanout: 3 })
        );
        assert_eq!(FederationMode::parse("gossip:0"), None);
        assert_eq!(FederationMode::parse("gossip:x"), None);
        let g = FederationMode::Gossip { fanout: 3 };
        assert_eq!(g.name(), "gossip");
        assert_eq!(g.label(), "gossip3");
        assert_eq!(FederationMode::parse(g.name()), Some(FederationMode::Gossip {
            fanout: DEFAULT_GOSSIP_FANOUT
        }));
        assert_eq!(FederationMode::Sync.label(), "sync");
    }

    #[test]
    fn store_kind_parse() {
        assert_eq!(StoreKind::parse("memory"), Some(StoreKind::Memory));
        assert_eq!(
            StoreKind::parse("sharded"),
            Some(StoreKind::Sharded(crate::store::DEFAULT_SHARDS))
        );
        assert_eq!(StoreKind::parse("sharded:4"), Some(StoreKind::Sharded(4)));
        assert_eq!(StoreKind::parse("fs:/tmp/ws"), Some(StoreKind::Fs("/tmp/ws".into())));
        assert_eq!(StoreKind::parse("sharded:0"), None);
        assert_eq!(StoreKind::parse("s3"), None);
    }

    #[test]
    fn run_name_is_stable() {
        let c = ExperimentConfig::default();
        assert_eq!(c.run_name(), "mnist_async_fedavg_n2_s0_seed42");
        // compressed runs must land in distinct log/store namespaces
        let c = ExperimentConfig { compress: CodecKind::Q8, ..Default::default() };
        assert_eq!(c.run_name(), "mnist_async_fedavg_n2_s0_seed42_q8");
    }

    #[test]
    fn adversary_validates_and_suffixes_run_name() {
        assert!(ExperimentConfig::default().adversary.is_none(), "honest by default");
        let c = ExperimentConfig {
            adversary: AdversarySpec::parse("byzantine:1"),
            ..Default::default()
        };
        c.validate().unwrap();
        assert_eq!(c.run_name(), "mnist_async_fedavg_n2_s0_seed42_byz1");
        // at least one honest node must remain
        let c = ExperimentConfig {
            adversary: AdversarySpec::parse("byzantine:2"),
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn run_name_carries_strategy_parameters() {
        let c = ExperimentConfig {
            strategy: StrategyKind::parse("krum:2").unwrap(),
            ..Default::default()
        };
        assert_eq!(c.run_name(), "mnist_async_krum2_n2_s0_seed42");
    }

    #[test]
    fn compress_defaults_to_none_and_validates() {
        assert_eq!(ExperimentConfig::default().compress, CodecKind::None);
        let c = ExperimentConfig {
            compress: CodecKind::TopK { frac: 0.2 },
            ..Default::default()
        };
        c.validate().unwrap();
    }

    #[test]
    fn threads_parse_label_and_default() {
        assert_eq!(ExperimentConfig::default().threads, 1, "parallel kernels are opt-in");
        assert_eq!(parse_threads("auto"), Some(0));
        assert_eq!(parse_threads("AUTO"), Some(0));
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads("0"), None, "explicit 0 is rejected; use auto");
        assert_eq!(parse_threads("-1"), None);
        assert_eq!(parse_threads("many"), None);
        assert_eq!(threads_label(0), "auto");
        assert_eq!(threads_label(8), "8");
        for v in ["auto", "1", "16"] {
            assert_eq!(threads_label(parse_threads(v).unwrap()), v.to_lowercase());
        }
    }

    #[test]
    fn participation_validates_and_suffixes_run_name() {
        let d = ExperimentConfig::default();
        assert_eq!(d.participation, 1.0, "full participation by default");
        assert_eq!(d.scheduler, SchedulerKind::Threads);
        assert_eq!(d.availability, AvailabilitySpec::None);

        let c = ExperimentConfig { participation: 0.1, ..Default::default() };
        c.validate().unwrap();
        assert_eq!(c.run_name(), "mnist_async_fedavg_n2_s0_seed42_p0.1");

        for bad in [0.0, -0.5, 1.5] {
            let c = ExperimentConfig { participation: bad, ..Default::default() };
            assert!(c.validate().is_err(), "participation {bad} must be rejected");
        }
    }

    #[test]
    fn availability_validates_and_suffixes_run_name() {
        let c = ExperimentConfig {
            availability: AvailabilitySpec::parse("churn:0.3").unwrap(),
            ..Default::default()
        };
        c.validate().unwrap();
        assert_eq!(c.run_name(), "mnist_async_fedavg_n2_s0_seed42_churn0.3");

        // churn p = 1 would take every node offline every round
        let c = ExperimentConfig {
            availability: AvailabilitySpec::Churn { p: 1.0 },
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            availability: AvailabilitySpec::Diurnal { period: 1 },
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = ExperimentConfig {
            availability: AvailabilitySpec::Stragglers { frac: 0.2, mult: 0.5 },
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn events_scheduler_requires_virtual_clock_and_keeps_run_name() {
        let c = ExperimentConfig {
            scheduler: SchedulerKind::Events,
            ..Default::default()
        };
        assert!(c.validate().is_err(), "events on a real clock is rejected");
        let c = ExperimentConfig {
            scheduler: SchedulerKind::Events,
            clock: ClockKind::Virtual,
            ..Default::default()
        };
        c.validate().unwrap();
        // same run identity as the threaded scheduler: bit-identical replay
        assert_eq!(c.run_name(), "mnist_async_fedavg_n2_s0_seed42");
    }

    #[test]
    fn fault_and_quorum_validate_and_suffix_run_name() {
        let d = ExperimentConfig::default();
        assert!(!d.fault.is_active(), "no faults by default");
        assert_eq!(d.sync_quorum, 1.0, "full quorum by default");

        let c = ExperimentConfig {
            fault: FaultModel { p_fail: 0.05, ..Default::default() },
            ..Default::default()
        };
        c.validate().unwrap();
        assert_eq!(c.run_name(), "mnist_async_fedavg_n2_s0_seed42_f0.05");

        let c = ExperimentConfig {
            mode: FederationMode::Sync,
            sync_quorum: 0.5,
            ..Default::default()
        };
        c.validate().unwrap();
        assert_eq!(c.run_name(), "mnist_sync_fedavg_n2_s0_seed42_sq0.5");

        // outage-only fault models are active but carry no p suffix
        let c = ExperimentConfig {
            fault: FaultModel {
                p_fail: 0.0,
                outages: vec![crate::store::OutageWindow {
                    start: Duration::from_secs(1),
                    duration: Duration::from_secs(1),
                }],
            },
            ..Default::default()
        };
        c.validate().unwrap();
        assert!(c.fault.is_active());
        assert_eq!(c.run_name(), "mnist_async_fedavg_n2_s0_seed42");

        // restartable crash validates
        let c = ExperimentConfig {
            crash: Some(CrashSpec { node: 1, at_epoch: 1, restart: Some(Duration::from_secs(5)) }),
            ..Default::default()
        };
        c.validate().unwrap();
    }

    #[test]
    fn clock_kind_defaults_real_and_parses() {
        assert_eq!(ExperimentConfig::default().clock, ClockKind::Real);
        assert_eq!(ClockKind::parse("virtual"), Some(ClockKind::Virtual));
        assert_eq!(ClockKind::parse("Real"), Some(ClockKind::Real));
        assert_eq!(ClockKind::parse("wallclock"), None);
        let c = ExperimentConfig { clock: ClockKind::Virtual, ..Default::default() };
        c.validate().unwrap();
    }
}
