//! FedAdam (Reddi et al. 2021, "Adaptive Federated Optimization") — Adam on
//! the server pseudo-gradient, run client-side in the serverless setting.
//!
//! `Δ = w_avg - w_prev;  m <- β1 m + (1-β1)Δ;  v <- β2 v + (1-β2)Δ²;
//!  w <- w_prev + lr * m / (sqrt(v) + τ)`

use super::{fedavg_of, Contribution, Strategy};
use crate::par::ChunkPool;
use crate::tensor::FlatParams;

/// Adam over the aggregation pseudo-gradient, with client-held moments.
pub struct FedAdam {
    lr: f32,
    b1: f32,
    b2: f32,
    tau: f32,
    m: Option<Vec<f32>>,
    v: Option<Vec<f32>>,
    prev: Option<FlatParams>,
}

impl FedAdam {
    /// Server learning rate `lr`, moment decays `b1`/`b2`, and adaptivity
    /// floor `tau` (FedOpt's defaults: 1e-2, 0.9, 0.999, 1e-3).
    pub fn new(lr: f32, b1: f32, b2: f32, tau: f32) -> Self {
        FedAdam { lr, b1, b2, tau, m: None, v: None, prev: None }
    }
}

impl Strategy for FedAdam {
    fn name(&self) -> &'static str {
        "fedadam"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        if contribs.is_empty() {
            return None;
        }
        let avg = fedavg_of(contribs, pool);
        let prev = match &self.prev {
            None => {
                self.m = Some(vec![0.0; avg.len()]);
                // FedOpt initializes v to tau^2
                self.v = Some(vec![self.tau * self.tau; avg.len()]);
                self.prev = Some(avg.clone());
                return Some(avg);
            }
            Some(p) => p.clone(),
        };
        let delta = prev.delta_to(&avg);
        let m = self.m.as_mut().unwrap();
        let v = self.v.as_mut().unwrap();
        let mut next = prev;
        for i in 0..delta.len() {
            let d = delta.0[i];
            m[i] = self.b1 * m[i] + (1.0 - self.b1) * d;
            v[i] = self.b2 * v[i] + (1.0 - self.b2) * d * d;
            next.0[i] += self.lr * m[i] / (v[i].sqrt() + self.tau);
        }
        self.prev = Some(next.clone());
        Some(next)
    }

    fn reset(&mut self) {
        self.m = None;
        self.v = None;
        self.prev = None;
    }
}

#[cfg(test)]
mod tests {
    use super::super::strategy_tests::contrib;
    use super::*;

    #[test]
    fn first_call_adopts_average() {
        let mut s = FedAdam::new(1e-2, 0.9, 0.999, 1e-3);
        let out = s
            .aggregate(&[contrib(0, 1, true, &[1.0]), contrib(1, 1, false, &[3.0])])
            .unwrap();
        assert_eq!(out.0, vec![2.0]);
    }

    #[test]
    fn moves_toward_average() {
        let mut s = FedAdam::new(1e-1, 0.9, 0.999, 1e-3);
        s.aggregate(&[contrib(0, 1, true, &[0.0])]).unwrap();
        let out = s.aggregate(&[contrib(0, 1, true, &[10.0])]).unwrap();
        assert!(out.0[0] > 0.0, "must step toward the new average");
        assert!(out.0[0] < 10.0, "adaptive step is damped");
    }

    #[test]
    fn step_size_bounded_by_lr_over_sqrt_v() {
        // With a huge delta the normalized step approaches lr * (1-b1) scale
        let mut s = FedAdam::new(1e-2, 0.9, 0.999, 1e-3);
        s.aggregate(&[contrib(0, 1, true, &[0.0])]).unwrap();
        let out = s.aggregate(&[contrib(0, 1, true, &[1e6])]).unwrap();
        assert!(out.0[0].abs() < 1.0, "step must be normalized, got {}", out.0[0]);
    }

    #[test]
    fn reset_forgets_moments() {
        let mut s = FedAdam::new(1e-2, 0.9, 0.999, 1e-3);
        s.aggregate(&[contrib(0, 1, true, &[5.0])]).unwrap();
        s.reset();
        let out = s.aggregate(&[contrib(0, 1, true, &[7.0])]).unwrap();
        assert_eq!(out.0, vec![7.0]);
    }
}
