//! [`NodeRunner`] — one federated node as a resumable state machine.
//!
//! The epoch loop that used to live inline in the worker thread body is
//! now a [`Task`]: train → federate → repeat, suspending at protocol
//! wait points instead of blocking. Both schedulers drive the same
//! machine — the threaded worker ([`super::spawn_node`]) parks on
//! [`crate::store::WeightStore::wait_for_change`] between steps, the
//! event executor ([`crate::sched::EventExecutor`]) queues a deadline —
//! so node behavior (store call sequence, timeline spans, metrics,
//! crash/stall/participation handling) is defined once, here.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::compress::CodecState;
use crate::config::ExperimentConfig;
use crate::data::BatchLoader;
use crate::metrics::timeline::{SpanKind, Timeline};
use crate::metrics::{EventField, RunLogger};
use crate::protocol::{EpochCtx, EpochStep, FederationProtocol, ProtocolKind};
use crate::runtime::{ModelBundle, TrainState};
use crate::sched::{ParticipationPlan, StepOutcome, Task};
use crate::store::{FaultStore, RetryPolicy, RetryStore, WeightStore};
use crate::strategy::Strategy;
use crate::time::Clock;

use super::{NodeReport, NodeStatus};

enum Phase {
    Train,
    Federate,
    Done,
}

/// A node's whole lifecycle as a resumable task. Borrows the (expensive,
/// immutable) [`ModelBundle`]: the threaded worker loads one per node
/// thread as before, while the event executor shares a single bundle
/// across every runner in the fleet — the allocation that makes
/// 10k-client trials feasible.
pub struct NodeRunner<'a> {
    node_id: usize,
    cfg: Arc<ExperimentConfig>,
    store: Arc<dyn WeightStore>,
    clock: Arc<dyn Clock>,
    logger: Option<Arc<RunLogger>>,
    plan: Arc<ParticipationPlan>,
    bundle: &'a ModelBundle,
    loader: BatchLoader,
    strategy: Box<dyn Strategy>,
    protocol: Box<dyn FederationProtocol>,
    state: TrainState,
    codec: CodecState,
    pool: crate::par::ChunkPool,
    step_delay: Duration,
    tracer: Option<Arc<crate::trace::Tracer>>,
    epoch: usize,
    phase: Phase,
    /// A restartable crash fires at most once (the epoch counter does
    /// not advance across the recovery, so the trigger would re-fire).
    crash_consumed: bool,
    /// Handle on this node's fault/retry store stack (when the config's
    /// fault model is active) for counter harvesting at report time.
    chaos: Option<Arc<RetryStore<FaultStore<Arc<dyn WeightStore>>>>>,
    report: NodeReport,
    timeline: Timeline,
}

impl<'a> NodeRunner<'a> {
    /// Build a runner ready for epoch 0: initial weights from the shared
    /// seed ("initialize w_0", Algorithm 1), protocol and codec state
    /// from the config, straggler delay from `node_delays_ms` scaled by
    /// the availability trace's persistent multiplier.
    #[allow(clippy::too_many_arguments)] // one-time wiring, named fields at both call sites
    pub fn new(
        node_id: usize,
        cfg: Arc<ExperimentConfig>,
        store: Arc<dyn WeightStore>,
        clock: Arc<dyn Clock>,
        logger: Option<Arc<RunLogger>>,
        plan: Arc<ParticipationPlan>,
        strategy: Box<dyn Strategy>,
        loader: BatchLoader,
        bundle: &'a ModelBundle,
        tracer: Option<Arc<crate::trace::Tracer>>,
    ) -> Result<NodeRunner<'a>> {
        let params = bundle.init_params(cfg.seed)?;
        let protocol = ProtocolKind::from(cfg.mode).build(node_id, &cfg);
        // Fault-tolerance stack: when the config injects store faults,
        // this node talks to the shared store through its own
        // FaultStore (per-node Bernoulli stream — a node's op order is
        // deterministic under both schedulers, so per-node instances
        // replay bit-identically where one shared RNG would be
        // call-order-dependent; outage windows are pure in simulated
        // time and therefore global) under a RetryStore client that
        // absorbs the transients with seeded backoff.
        let (store, chaos) = if cfg.fault.is_active() {
            let seed = cfg.seed ^ (node_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let faulty = FaultStore::with_model(
                Arc::clone(&store),
                &cfg.fault,
                Arc::clone(&clock),
                seed,
            );
            let retry = Arc::new(RetryStore::new(
                faulty,
                RetryPolicy::default(),
                Arc::clone(&clock),
                seed ^ 0xD1B5_4A32_D192_ED03,
            ));
            (Arc::clone(&retry) as Arc<dyn WeightStore>, Some(retry))
        } else {
            (store, None)
        };
        // the node's kernel pool (threads = auto | N): codec encode/decode
        // and strategy aggregation run chunk-parallel on it, with results
        // bit-identical to threads = 1
        let pool = crate::par::ChunkPool::from_config(cfg.threads);
        let step_delay = cfg
            .node_delays_ms
            .get(node_id)
            .copied()
            .map(|ms| Duration::from_secs_f64(ms / 1000.0))
            .unwrap_or(Duration::ZERO)
            .mul_f64(plan.delay_multiplier(node_id));
        let report = NodeReport {
            node_id,
            status: NodeStatus::Completed,
            epochs_done: 0,
            final_params: None,
            // n_k: examples this node trains on per epoch (the FedAvg
            // weight numerator), from the manifest's authoritative batch
            // size carried by the bundle
            n_examples_per_epoch: (cfg.steps_per_epoch * bundle.info.batch_size) as u64,
            epoch_losses: vec![],
            epoch_accs: vec![],
            aggregations: 0,
            pushes: 0,
            timeline: Timeline::new(node_id),
            train_time: Duration::ZERO,
            wait_time: Duration::ZERO,
            injected_faults: 0,
            store_retries: 0,
            store_give_ups: 0,
            degraded_rounds: 0,
            restarts: 0,
        };
        Ok(NodeRunner {
            node_id,
            state: TrainState::new(params),
            codec: CodecState::new(cfg.compress),
            cfg,
            store,
            clock,
            logger,
            plan,
            bundle,
            loader,
            strategy,
            protocol,
            pool,
            step_delay,
            tracer,
            epoch: 0,
            phase: Phase::Train,
            crash_consumed: false,
            chaos,
            report,
            timeline: Timeline::new(node_id),
        })
    }

    /// Record a driver-side error (e.g. a failed store wait) the same
    /// way an internal one is recorded: `Failed` status, task over. The
    /// failure leaves forensic marks — a zero-width `Crashed` timeline
    /// span and a typed `node_failed` trace instant — so a failed node
    /// is visible in the ASCII timeline and the trace exports instead of
    /// silently truncating.
    pub fn fail(&mut self, err: &anyhow::Error) {
        if self.report.status == NodeStatus::Completed {
            self.report.status = NodeStatus::Failed(format!("{err:#}"));
            let t = self.clock.now();
            self.timeline.record(SpanKind::Crashed, t, t);
            if let Some(tracer) = &self.tracer {
                tracer.instant(
                    self.node_id,
                    self.epoch as u64,
                    t,
                    crate::trace::TraceEventKind::NodeFailed,
                );
            }
            if let Some(lg) = &self.logger {
                let _ = lg.log_event_typed(
                    "node_failed",
                    &[
                        ("node", EventField::Int(self.node_id as u64)),
                        ("epoch", EventField::Int(self.epoch as u64)),
                    ],
                );
            }
        }
        self.phase = Phase::Done;
    }

    /// Finalize and hand back the node's report.
    pub fn into_report(mut self) -> NodeReport {
        self.report.train_time = self.timeline.total(SpanKind::Train);
        self.report.wait_time = self.timeline.total(SpanKind::Wait);
        self.report.timeline = self.timeline;
        if let Some(chaos) = &self.chaos {
            self.report.injected_faults = chaos.inner().injected();
            let stats = chaos.stats();
            self.report.store_retries = stats.retries;
            self.report.store_give_ups = stats.give_ups;
        }
        self.report
    }

    fn step_inner(&mut self) -> Result<StepOutcome> {
        match self.phase {
            Phase::Done => Ok(StepOutcome::Done),
            Phase::Train => {
                // Zero-time transitions (completion, crash, off-cohort
                // rounds) loop inline; training ends the step because it
                // advances the clock.
                loop {
                    if self.epoch >= self.cfg.epochs {
                        self.report.final_params = Some(self.state.params.clone());
                        self.phase = Phase::Done;
                        return Ok(StepOutcome::Done);
                    }
                    if let Some(crash) = self.cfg.crash {
                        // crash fires by epoch index whether or not the
                        // node is in that round's cohort — a device dies
                        // on its own schedule
                        if !self.crash_consumed
                            && crash.node == self.node_id
                            && crash.at_epoch == self.epoch
                        {
                            self.crash_consumed = true;
                            if let Some(lg) = &self.logger {
                                let _ = lg.log_event_typed(
                                    "node_crash",
                                    &[
                                        ("node", EventField::Int(self.node_id as u64)),
                                        ("epoch", EventField::Int(self.epoch as u64)),
                                    ],
                                );
                            }
                            let t = self.clock.now();
                            match crash.restart {
                                None => {
                                    // permanent crash: the original §4.2.1
                                    // failure experiment
                                    self.report.status =
                                        NodeStatus::Crashed { at_epoch: self.epoch };
                                    self.timeline.record(SpanKind::Crashed, t, t);
                                    self.phase = Phase::Done;
                                    return Ok(StepOutcome::Done);
                                }
                                Some(delay) => {
                                    // crash–restart: down for `delay` of
                                    // experiment-clock time, then recover
                                    self.recover_after(delay, t)?;
                                    continue;
                                }
                            }
                        }
                    }
                    if !self.plan.participates(self.node_id, self.epoch) {
                        // off-cohort round: no training, no push, no
                        // simulated time, no metrics row
                        self.epoch += 1;
                        continue;
                    }
                    break;
                }
                self.train_epoch()?;
                self.phase = Phase::Federate;
                Ok(StepOutcome::Yield)
            }
            Phase::Federate => {
                let mut pctx = EpochCtx {
                    node_id: self.node_id,
                    n_nodes: self.cfg.n_nodes,
                    round_k: self.plan.round_k(self.epoch),
                    epoch: self.epoch,
                    n_examples: self.report.n_examples_per_epoch,
                    store: self.store.as_ref(),
                    strategy: self.strategy.as_mut(),
                    timeline: &mut self.timeline,
                    sync_timeout: self.cfg.sync_timeout,
                    clock: self.clock.as_ref(),
                    codec: &mut self.codec,
                    pool: self.pool,
                    tracer: self.tracer.as_deref(),
                };
                match self.protocol.poll_epoch(&mut pctx, &mut self.state.params)? {
                    EpochStep::Wait { since, timeout } => {
                        Ok(StepOutcome::Wait { since, timeout })
                    }
                    EpochStep::Done(out) => {
                        self.report.pushes += out.pushes;
                        self.report.aggregations += out.aggregations;
                        self.report.degraded_rounds += out.degraded_rounds;
                        if let Some(round) = out.stalled_at {
                            // The node is stuck at the barrier, not dead:
                            // its current weights still exist (and were
                            // pushed), so report them — the driver can
                            // evaluate what training achieved before the
                            // stall.
                            self.report.status = NodeStatus::Stalled { at_round: round };
                            if let Some(lg) = &self.logger {
                                let _ = lg.log_event_typed(
                                    "sync_stall",
                                    &[
                                        ("node", EventField::Int(self.node_id as u64)),
                                        ("round", EventField::Int(round as u64)),
                                    ],
                                );
                            }
                            self.report.final_params = Some(self.state.params.clone());
                            self.phase = Phase::Done;
                            return Ok(StepOutcome::Done);
                        }
                        self.epoch += 1;
                        self.phase = Phase::Train;
                        Ok(StepOutcome::Yield)
                    }
                }
            }
        }
    }

    /// Crash–restart recovery: the node is down for `delay` of
    /// experiment-clock time (recorded as a `Crashed` timeline span from
    /// `t_down`), then comes back as a fresh process — weights restored
    /// from its own latest store entry (the checkpoint it pushed at its
    /// last federated epoch; a node that never pushed restarts from the
    /// seeded initial weights), optimizer moments, codec delta base and
    /// protocol state rebuilt from scratch. The epoch counter does not
    /// rewind: recovery resumes the epoch the crash interrupted.
    fn recover_after(&mut self, delay: Duration, t_down: Duration) -> Result<()> {
        self.clock.sleep(delay);
        let t_up = self.clock.now();
        self.timeline.record(SpanKind::Crashed, t_down, t_up);
        if let Some(tracer) = &self.tracer {
            tracer.span(
                self.node_id,
                self.epoch as u64,
                t_down,
                t_up,
                crate::trace::TraceEventKind::Restart,
            );
        }
        // The checkpoint read goes through the node's own fault/retry
        // stack: a restart landing inside an outage window retries like
        // any other pull instead of failing the recovery.
        if let Some(entry) = self.store.latest_for_node(self.node_id)? {
            self.state = TrainState::new((*entry.params).clone());
        } else {
            self.state = TrainState::new(self.bundle.init_params(self.cfg.seed)?);
        }
        self.codec = CodecState::new(self.cfg.compress);
        self.protocol = ProtocolKind::from(self.cfg.mode).build(self.node_id, &self.cfg);
        self.report.restarts += 1;
        if let Some(lg) = &self.logger {
            let _ = lg.log_event_typed(
                "node_restart",
                &[
                    ("node", EventField::Int(self.node_id as u64)),
                    ("epoch", EventField::Int(self.epoch as u64)),
                    ("down_s", EventField::Num(delay.as_secs_f64())),
                ],
            );
        }
        Ok(())
    }

    fn train_epoch(&mut self) -> Result<()> {
        let clock = Arc::clone(&self.clock);
        let step_delay = self.step_delay;
        let t_train = clock.now();
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut steps_run = 0usize;
        let mut acc_steps = 0usize;
        self.bundle.run_steps(
            &mut self.state,
            &mut self.loader,
            self.cfg.steps_per_epoch,
            |_i, m| {
                steps_run += 1;
                loss_sum += m.loss as f64;
                // a batch with no labeled predictions contributes no
                // accuracy sample instead of a NaN that poisons the mean
                if m.n_preds > 0 {
                    acc_sum += m.acc_count as f64 / m.n_preds as f64;
                    acc_steps += 1;
                }
                // Straggler simulation: per-step delay on the experiment
                // clock (instant real time under a virtual clock).
                clock.sleep(step_delay);
            },
        )?;
        self.timeline.record(SpanKind::Train, t_train, clock.now());
        if let Some(tracer) = &self.tracer {
            tracer.span(
                self.node_id,
                self.epoch as u64,
                t_train,
                clock.now(),
                crate::trace::TraceEventKind::Train,
            );
        }
        // divide by the steps actually run, not the configured count: a
        // short epoch (exhausted loader) must not deflate the mean
        let mean_loss = loss_sum / steps_run.max(1) as f64;
        let mean_acc = if acc_steps > 0 { acc_sum / acc_steps as f64 } else { 0.0 };
        self.report.epoch_losses.push(mean_loss);
        self.report.epoch_accs.push(mean_acc);
        self.report.epochs_done = self.epoch + 1;
        if let Some(lg) = &self.logger {
            let _ = lg.log_metrics(&[
                ("node", self.node_id as f64),
                ("epoch", self.epoch as f64),
                ("train_loss", mean_loss),
                ("train_acc", mean_acc),
                ("elapsed_s", clock.now().as_secs_f64()),
            ]);
        }
        if self.cfg.verbose {
            eprintln!(
                "[node {} epoch {}] loss={mean_loss:.4} acc={mean_acc:.4}",
                self.node_id, self.epoch
            );
        }
        Ok(())
    }
}

impl Task for NodeRunner<'_> {
    fn step(&mut self) -> StepOutcome {
        match self.step_inner() {
            Ok(out) => out,
            Err(e) => {
                self.fail(&e);
                StepOutcome::Done
            }
        }
    }
}
