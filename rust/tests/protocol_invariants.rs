//! Property-based protocol invariants, randomized across many seeds with
//! the crate's deterministic RNG (the image carries no proptest; failures
//! print the offending seed so any case replays exactly).

use std::sync::Arc;

use fedless::config::{ExperimentConfig, FederationMode};
use fedless::data::Partitioner;
use fedless::protocol::gossip_peers;
use fedless::sim::run_experiment;
use fedless::store::{MemoryStore, PushRequest, WeightStore};
use fedless::strategy::{Contribution, StrategyKind};
use fedless::tensor::codec::{decode_blob, encode_blob, BlobMeta};
use fedless::tensor::flat::weighted_average;
use fedless::tensor::FlatParams;
use fedless::util::Rng;

// ---------------------------------------------------------------------------
// aggregation properties

/// FedAvg output is a convex combination: every coordinate lies within the
/// per-coordinate min/max envelope of the inputs.
#[test]
fn prop_fedavg_is_convex_combination() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed);
        let k = 2 + rng.below(4);
        let n = 1 + rng.below(200);
        let xs: Vec<FlatParams> = (0..k)
            .map(|_| FlatParams((0..n).map(|_| rng.normal_f32() * 10.0).collect()))
            .collect();
        let mut w: Vec<f32> = (0..k).map(|_| rng.f32() + 1e-3).collect();
        let tot: f32 = w.iter().sum();
        w.iter_mut().for_each(|x| *x /= tot);
        let refs: Vec<&FlatParams> = xs.iter().collect();
        let avg = weighted_average(&refs, &w);
        for i in 0..n {
            let lo = xs.iter().map(|x| x.0[i]).fold(f32::INFINITY, f32::min);
            let hi = xs.iter().map(|x| x.0[i]).fold(f32::NEG_INFINITY, f32::max);
            assert!(
                avg.0[i] >= lo - 1e-4 && avg.0[i] <= hi + 1e-4,
                "seed {seed} coord {i}: {} outside [{lo}, {hi}]",
                avg.0[i]
            );
        }
    }
}

/// Aggregating K identical parameter vectors is the identity for every
/// strategy (first call; fixed-point property of Eq. 1).
#[test]
fn prop_identical_inputs_are_fixed_point() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let n = 1 + rng.below(100);
        let x = FlatParams((0..n).map(|_| rng.normal_f32()).collect());
        for kind in [StrategyKind::FedAvg, StrategyKind::FedAvgM, StrategyKind::FedAdam] {
            let mut s = kind.build();
            let contribs: Vec<Contribution> = (0..3)
                .map(|i| Contribution {
                    node_id: i,
                    n_examples: 100,
                    is_self: i == 0,
                    seq: i as u64,
                    params: Arc::new(x.clone()),
                })
                .collect();
            let out = s.aggregate(&contribs).unwrap();
            let diff = out.max_abs_diff(&x);
            assert!(diff < 1e-5, "seed {seed} strategy {} diff {diff}", kind.name());
        }
    }
}

// ---------------------------------------------------------------------------
// partitioner properties

#[test]
fn prop_partition_is_exact_cover_at_any_skew() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x9999);
        let n_nodes = 1 + rng.below(5);
        let skew = rng.f64();
        let n = 200 + rng.below(2000);
        let labels: Vec<usize> = (0..n).map(|_| rng.below(10)).collect();
        let shards = Partitioner::new(n_nodes, skew, 10).assign(&labels, seed);
        let mut seen = vec![false; n];
        for shard in &shards {
            for &i in shard {
                assert!(!seen[i], "seed {seed}: duplicate assignment");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "seed {seed}: examples dropped");
    }
}

#[test]
fn prop_higher_skew_increases_home_fraction() {
    // monotonicity in expectation: home-label fraction grows with s
    let mut rng = Rng::new(0xF00);
    let labels: Vec<usize> = (0..20_000).map(|_| rng.below(10)).collect();
    let mut last = 0.0;
    for (i, skew) in [0.0, 0.5, 0.9, 1.0].iter().enumerate() {
        let p = Partitioner::new(2, *skew, 10);
        let shards = p.assign(&labels, 77);
        let home: usize = shards
            .iter()
            .enumerate()
            .map(|(node, shard)| {
                shard.iter().filter(|&&ix| p.home_node(labels[ix]) == node).count()
            })
            .sum();
        let frac = home as f64 / labels.len() as f64;
        assert!(frac >= last - 0.02, "skew {skew}: home frac {frac} < prev {last}");
        if i == 3 {
            assert!(frac > 0.999, "full skew must be fully partitioned");
        }
        last = frac;
    }
}

// ---------------------------------------------------------------------------
// codec properties

#[test]
fn prop_codec_roundtrip_random_payloads() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0xC0DEC);
        let n = rng.below(3000);
        let params = FlatParams(
            (0..n)
                .map(|_| f32::from_bits(rng.next_u64() as u32))
                .map(|f| if f.is_nan() { 0.0 } else { f }) // NaN != NaN
                .collect(),
        );
        let meta = BlobMeta {
            node_id: rng.next_u64() as u32,
            round: rng.next_u64(),
            epoch: rng.next_u64(),
            n_examples: rng.next_u64(),
        };
        let blob = encode_blob(&meta, &params);
        let (m2, p2) = decode_blob(&blob).unwrap();
        assert_eq!(meta, m2, "seed {seed}");
        assert_eq!(params, p2, "seed {seed}");
    }
}

#[test]
fn prop_codec_rejects_any_single_bitflip_in_payload() {
    let mut rng = Rng::new(42);
    let params = FlatParams((0..100).map(|_| rng.normal_f32()).collect());
    let meta = BlobMeta { node_id: 1, round: 2, epoch: 3, n_examples: 4 };
    let blob = encode_blob(&meta, &params);
    let header = fedless::tensor::codec::HEADER_LEN;
    for trial in 0..30 {
        let mut corrupted = blob.clone();
        let pos = header + (trial * 13) % (corrupted.len() - header);
        corrupted[pos] ^= 1 << (trial % 8);
        assert!(decode_blob(&corrupted).is_err(), "bitflip at {pos} undetected");
    }
}

// ---------------------------------------------------------------------------
// store properties

/// latest_per_node is exactly the highest-seq entry per node, for any
/// random push interleaving.
#[test]
fn prop_store_latest_is_max_seq() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0x5708E);
        let store = MemoryStore::new();
        let mut expected: std::collections::BTreeMap<usize, (u64, f32)> = Default::default();
        for _ in 0..rng.below(60) + 1 {
            let node = rng.below(6);
            let val = rng.normal_f32();
            let seq = store
                .push(PushRequest::raw(node, 0, 0, 1, Arc::new(FlatParams(vec![val; 3]))))
                .unwrap();
            expected.insert(node, (seq, val));
        }
        let latest = store.latest_per_node().unwrap();
        assert_eq!(latest.len(), expected.len(), "seed {seed}");
        for e in latest {
            let (seq, val) = expected[&e.node_id];
            assert_eq!(e.seq, seq, "seed {seed}");
            assert_eq!(e.params.0[0], val, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// gossip schedule properties (pure; no artifacts needed)

/// The gossip peer schedule is a pure function of
/// `(seed, node, epoch, n_nodes, fanout)`: replayable, self-free, within
/// bounds, and not constant across epochs.
#[test]
fn prop_gossip_schedule_deterministic_and_well_formed() {
    let mut varied = false;
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x605_51F);
        let n_nodes = 2 + rng.below(6);
        let fanout = 1 + rng.below(n_nodes);
        let first = gossip_peers(seed, 0, 0, n_nodes, fanout);
        for epoch in 0..12 {
            for node in 0..n_nodes {
                let a = gossip_peers(seed, node, epoch, n_nodes, fanout);
                let b = gossip_peers(seed, node, epoch, n_nodes, fanout);
                assert_eq!(a, b, "seed {seed}: schedule must replay");
                assert_eq!(a.len(), fanout.min(n_nodes - 1), "seed {seed}");
                assert!(a.iter().all(|&p| p < n_nodes && p != node), "seed {seed}");
                let mut dedup = a.clone();
                dedup.dedup();
                assert_eq!(dedup, a, "seed {seed}: sorted, no duplicates");
                if node == 0 && a != first {
                    varied = true;
                }
            }
        }
    }
    assert!(varied, "schedules must vary across epochs somewhere in the grid");
}

// ---------------------------------------------------------------------------
// protocol-level invariant (needs artifacts)

/// In synchronous serverless federation every node aggregates the same
/// round set, so all nodes must end up with bit-identical weights — the
/// core correctness claim of server-free sync federation (§3), which must
/// survive the barrier's move from sleep-polling to blocking on
/// `WeightStore::wait_for_change` notification.
#[test]
fn sync_nodes_end_with_identical_weights() {
    for seed in [3u64, 17] {
        let cfg = ExperimentConfig {
            model: "mnist".into(),
            n_nodes: 3,
            mode: FederationMode::Sync,
            epochs: 2,
            steps_per_epoch: 8,
            train_size: 900,
            test_size: 96,
            seed,
            ..Default::default()
        };
        let res = run_experiment(&cfg).unwrap();
        assert!(res.all_completed);
        let finals: Vec<&FlatParams> =
            res.reports.iter().map(|r| r.final_params.as_ref().unwrap()).collect();
        for (i, f) in finals.iter().enumerate().skip(1) {
            let diff = finals[0].max_abs_diff(f);
            assert_eq!(
                diff, 0.0,
                "seed {seed}: node {i} diverged from node 0 by {diff}"
            );
        }
    }
}

/// Async with C = 1 and a memory store: every node aggregates at least
/// once, and the store ends holding exactly one latest entry per node.
#[test]
fn async_all_nodes_aggregate_and_store_converges() {
    let cfg = ExperimentConfig {
        model: "mnist".into(),
        n_nodes: 3,
        mode: FederationMode::Async,
        epochs: 3,
        steps_per_epoch: 8,
        train_size: 900,
        test_size: 96,
        seed: 5,
        ..Default::default()
    };
    let res = run_experiment(&cfg).unwrap();
    assert!(res.all_completed);
    assert_eq!(res.store_pushes, 9, "3 nodes x 3 epochs with C=1");
    for r in &res.reports {
        assert!(r.aggregations >= 1, "node {} never aggregated", r.node_id);
    }
}
