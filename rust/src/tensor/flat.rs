//! [`FlatParams`] — a flat `f32` parameter vector with the small amount of
//! linear algebra the federation strategies need (axpy, scale, lerp).

use crate::util::hash::hash_f32s;

/// A model's full parameter (or optimizer-moment) vector.
#[derive(Clone, Debug, PartialEq)]
pub struct FlatParams(
    /// The raw element storage.
    pub Vec<f32>,
);

impl FlatParams {
    /// An all-zero vector of length `n`.
    pub fn zeros(n: usize) -> Self {
        FlatParams(vec![0.0; n])
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// True when the vector has no elements.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Borrow the elements as a slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    /// Content hash (used in store entries and change detection).
    pub fn content_hash(&self) -> u64 {
        hash_f32s(&self.0)
    }

    /// `self += alpha * other` (fused multiply-add per element; the
    /// aggregation hot path — see benches/microbench.rs).
    pub fn axpy(&mut self, alpha: f32, other: &FlatParams) {
        assert_eq!(self.len(), other.len(), "axpy length mismatch");
        for (a, b) in self.0.iter_mut().zip(other.0.iter()) {
            *a = b.mul_add(alpha, *a);
        }
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.0.iter_mut() {
            *a *= alpha;
        }
    }

    /// `self = (1 - t) * self + t * other` — the staleness-mixing update
    /// used by FedAsync.
    pub fn lerp(&mut self, t: f32, other: &FlatParams) {
        assert_eq!(self.len(), other.len(), "lerp length mismatch");
        for (a, b) in self.0.iter_mut().zip(other.0.iter()) {
            *a = *a + t * (*b - *a);
        }
    }

    /// Element-wise difference `other - self` (pseudo-gradient for
    /// server-side optimizers à la FedOpt).
    pub fn delta_to(&self, other: &FlatParams) -> FlatParams {
        assert_eq!(self.len(), other.len(), "delta length mismatch");
        FlatParams(
            self.0
                .iter()
                .zip(other.0.iter())
                .map(|(a, b)| b - a)
                .collect(),
        )
    }

    /// Max |a_i - b_i|; used by tests/parity checks.
    pub fn max_abs_diff(&self, other: &FlatParams) -> f32 {
        assert_eq!(self.len(), other.len());
        self.0
            .iter()
            .zip(other.0.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.0.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// True when every element is finite (no NaN/inf).
    pub fn all_finite(&self) -> bool {
        self.0.iter().all(|x| x.is_finite())
    }
}

/// Weighted average of parameter vectors: `sum_k w[k] * xs[k]` — Eq. (1) of
/// the paper, computed client-side. This is the pure-rust reference used by
/// every strategy; `runtime::agg` offers the same computation through the
/// lowered Pallas artifact, and `rust/tests/artifact_parity.rs` checks they
/// agree.
pub fn weighted_average(xs: &[&FlatParams], weights: &[f32]) -> FlatParams {
    assert_eq!(xs.len(), weights.len(), "weights/params arity mismatch");
    assert!(!xs.is_empty(), "cannot average zero clients");
    let n = xs[0].len();
    for x in xs {
        assert_eq!(x.len(), n, "client param length mismatch");
    }
    let mut out = FlatParams::zeros(n);
    for (x, &w) in xs.iter().zip(weights.iter()) {
        out.axpy(w, x);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(xs: &[f32]) -> FlatParams {
        FlatParams(xs.to_vec())
    }

    #[test]
    fn axpy_basic() {
        let mut a = fp(&[1.0, 2.0]);
        a.axpy(0.5, &fp(&[4.0, 8.0]));
        assert_eq!(a.0, vec![3.0, 6.0]);
    }

    #[test]
    fn lerp_endpoints() {
        let mut a = fp(&[1.0, 2.0]);
        a.lerp(0.0, &fp(&[5.0, 5.0]));
        assert_eq!(a.0, vec![1.0, 2.0]);
        a.lerp(1.0, &fp(&[5.0, 6.0]));
        assert_eq!(a.0, vec![5.0, 6.0]);
    }

    #[test]
    fn weighted_average_equal_weights_is_mean() {
        let out = weighted_average(&[&fp(&[0.0, 2.0]), &fp(&[2.0, 4.0])], &[0.5, 0.5]);
        assert_eq!(out.0, vec![1.0, 3.0]);
    }

    #[test]
    fn weighted_average_single_identity() {
        let x = fp(&[1.5, -2.5, 3.0]);
        let out = weighted_average(&[&x], &[1.0]);
        assert_eq!(out, x);
    }

    #[test]
    fn weighted_average_respects_weights() {
        let out = weighted_average(&[&fp(&[1.0]), &fp(&[3.0])], &[0.75, 0.25]);
        assert!((out.0[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn weighted_average_arity_mismatch_panics() {
        weighted_average(&[&fp(&[1.0])], &[0.5, 0.5]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn weighted_average_length_mismatch_panics() {
        weighted_average(&[&fp(&[1.0]), &fp(&[1.0, 2.0])], &[0.5, 0.5]);
    }

    #[test]
    fn delta_and_norm() {
        let a = fp(&[1.0, 1.0]);
        let b = fp(&[4.0, 5.0]);
        let d = a.delta_to(&b);
        assert_eq!(d.0, vec![3.0, 4.0]);
        assert!((d.norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn content_hash_changes_with_content() {
        let a = fp(&[1.0, 2.0]);
        let mut b = a.clone();
        assert_eq!(a.content_hash(), b.content_hash());
        b.0[0] = 1.0001;
        assert_ne!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn finite_check() {
        assert!(fp(&[1.0, -2.0]).all_finite());
        assert!(!fp(&[f32::NAN]).all_finite());
        assert!(!fp(&[f32::INFINITY]).all_finite());
    }
}
