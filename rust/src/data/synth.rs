//! Deterministic synthetic image datasets standing in for MNIST / CIFAR-10.
//!
//! Each class `c` gets a smooth prototype image (a mixture of random 2-D
//! Gaussians drawn from a class-seeded RNG). An example is
//! `prototype + per-example Gaussian-bump distortion + pixel noise`,
//! normalized to roughly zero mean / unit variance. The classes are
//! separable by a small CNN but not linearly trivial — centralized training
//! reaches high accuracy after a few hundred steps, leaving headroom for
//! the federated-skew degradations the paper's tables show.
//!
//! Every example is generated on the fly from `(dataset seed, split,
//! index)` — nothing is stored, so a 60k-example dataset costs no memory
//! and is bit-reproducible across nodes and trials.

use crate::util::Rng;

/// Which synthetic dataset family (shapes match the paper's).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// 28×28×1, 10 classes (MNIST stand-in).
    Mnist,
    /// 32×32×3, 10 classes (CIFAR-10 stand-in).
    Cifar,
}

impl DatasetKind {
    /// Image dimensions `(height, width, channels)`.
    pub fn dims(self) -> (usize, usize, usize) {
        match self {
            DatasetKind::Mnist => (28, 28, 1),
            DatasetKind::Cifar => (32, 32, 3),
        }
    }

    /// Number of label classes.
    pub fn num_classes(self) -> usize {
        10
    }

    /// Flattened per-example feature length (h × w × c).
    pub fn example_len(self) -> usize {
        let (h, w, c) = self.dims();
        h * w * c
    }

    /// Parse a dataset name (`mnist` / `cifar`).
    pub fn parse(s: &str) -> Option<DatasetKind> {
        match s {
            "mnist" => Some(DatasetKind::Mnist),
            "cifar" => Some(DatasetKind::Cifar),
            _ => None,
        }
    }
}

/// Per-kind difficulty profile, tuned (see EXPERIMENTS.md §Calibration) so
/// centralized reference accuracy lands near the paper's (~0.99 MNIST,
/// ~0.80 CIFAR) and federated skew degradations are visible.
struct Difficulty {
    proto_blobs: usize,
    distort_blobs: usize,
    distort_amp: f32,
    noise_std: f32,
    proto_amp: f32,
}

impl DatasetKind {
    fn difficulty(self) -> Difficulty {
        match self {
            DatasetKind::Mnist => Difficulty {
                proto_blobs: 6,
                distort_blobs: 3,
                distort_amp: 1.0,
                noise_std: 1.1,
                proto_amp: 1.0,
            },
            DatasetKind::Cifar => Difficulty {
                proto_blobs: 5,
                distort_blobs: 6,
                distort_amp: 1.6,
                noise_std: 1.6,
                proto_amp: 0.8,
            },
        }
    }
}

#[derive(Clone, Debug)]
struct Blob {
    cx: f32,
    cy: f32,
    sigma: f32,
    amp: f32,
    channel: usize,
}

/// A synthetic labelled image dataset.
pub struct SynthDataset {
    /// Which dataset family (shapes/classes).
    pub kind: DatasetKind,
    /// Generation seed: same seed → bit-identical dataset.
    pub seed: u64,
    /// Number of train examples.
    pub train_len: usize,
    /// Number of test examples.
    pub test_len: usize,
    prototypes: Vec<Vec<Blob>>, // per class
    /// Pre-rendered prototype images (perf: renders each class's Gaussian
    /// mixture once instead of per example — EXPERIMENTS.md §Perf; the
    /// output is bit-identical to re-rendering because blob order and
    /// accumulation order are preserved).
    proto_images: Vec<Vec<f32>>,
}

impl SynthDataset {
    /// Generate (lazily — prototypes only) a dataset of the given sizes.
    pub fn new(kind: DatasetKind, seed: u64, train_len: usize, test_len: usize) -> Self {
        let mut proto_rng = Rng::new(seed ^ 0xDA7A_5E1D);
        let (_, _, ch) = kind.dims();
        let d = kind.difficulty();
        let prototypes = (0..kind.num_classes())
            .map(|c| {
                let mut r = proto_rng.fork(c as u64 + 1);
                (0..d.proto_blobs)
                    .map(|_| Blob {
                        cx: r.f32() * 0.8 + 0.1,
                        cy: r.f32() * 0.8 + 0.1,
                        sigma: 0.05 + 0.12 * r.f32(),
                        amp: if r.chance(0.5) { 1.0 } else { -1.0 }
                            * d.proto_amp
                            * (0.8 + 0.8 * r.f32()),
                        channel: r.below(ch),
                    })
                    .collect()
            })
            .collect();
        let mut ds =
            SynthDataset { kind, seed, train_len, test_len, prototypes, proto_images: vec![] };
        ds.proto_images = (0..kind.num_classes())
            .map(|c| {
                let mut img = vec![0.0; kind.example_len()];
                ds.render(&ds.prototypes[c], &mut img, 1.0);
                img
            })
            .collect();
        ds
    }

    /// The label of train/test example `idx` (uniform over classes,
    /// assigned deterministically by hashing the index).
    pub fn label(&self, split: Split, idx: usize) -> usize {
        let mut r = Rng::new(self.seed ^ split.tag() ^ (idx as u64).wrapping_mul(0x9E37));
        r.below(self.kind.num_classes())
    }

    fn render(&self, blobs: &[Blob], img: &mut [f32], scale: f32) {
        let (h, w, ch) = self.kind.dims();
        for b in blobs {
            let inv2s2 = 1.0 / (2.0 * b.sigma * b.sigma);
            for y in 0..h {
                let fy = y as f32 / h as f32 - b.cy;
                for x in 0..w {
                    let fx = x as f32 / w as f32 - b.cx;
                    let v = b.amp * scale * (-(fx * fx + fy * fy) * inv2s2).exp();
                    img[(y * w + x) * ch + b.channel] += v;
                }
            }
        }
    }

    /// Generate example `idx` of the split into `out` (len = example_len),
    /// returning its label.
    pub fn example_into(&self, split: Split, idx: usize, out: &mut [f32]) -> usize {
        assert_eq!(out.len(), self.kind.example_len());
        let label = self.label(split, idx);
        out.copy_from_slice(&self.proto_images[label]);

        let mut r = Rng::new(
            self.seed ^ split.tag().rotate_left(17) ^ (idx as u64).wrapping_mul(0x5851_F42D_4C95_7F2D),
        );
        // per-example distortion: extra random bumps
        let (_, _, ch) = self.kind.dims();
        let d = self.kind.difficulty();
        let distort: Vec<Blob> = (0..d.distort_blobs)
            .map(|_| Blob {
                cx: r.f32(),
                cy: r.f32(),
                sigma: 0.05 + 0.1 * r.f32(),
                amp: r.normal_f32() * d.distort_amp,
                channel: r.below(ch),
            })
            .collect();
        self.render(&distort, out, 1.0);
        // pixel noise
        for v in out.iter_mut() {
            *v += d.noise_std * r.normal_f32();
        }
        label
    }

    /// Allocating variant of [`SynthDataset::example_into`].
    pub fn example(&self, split: Split, idx: usize) -> (Vec<f32>, usize) {
        let mut out = vec![0.0; self.kind.example_len()];
        let label = self.example_into(split, idx, &mut out);
        (out, label)
    }

    /// All labels of a split (used by the partitioner).
    pub fn labels(&self, split: Split) -> Vec<usize> {
        let n = match split {
            Split::Train => self.train_len,
            Split::Test => self.test_len,
        };
        (0..n).map(|i| self.label(split, i)).collect()
    }
}

/// Train/test split selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// The training split (partitioned across nodes).
    Train,
    /// The held-out evaluation split.
    Test,
}

impl Split {
    fn tag(self) -> u64 {
        match self {
            Split::Train => 0x7261_494E,
            Split::Test => 0x7465_5354,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_examples() {
        let d1 = SynthDataset::new(DatasetKind::Mnist, 7, 100, 20);
        let d2 = SynthDataset::new(DatasetKind::Mnist, 7, 100, 20);
        let (x1, y1) = d1.example(Split::Train, 3);
        let (x2, y2) = d2.example(Split::Train, 3);
        assert_eq!(y1, y2);
        assert_eq!(x1, x2);
    }

    #[test]
    fn different_indices_differ() {
        let d = SynthDataset::new(DatasetKind::Mnist, 7, 100, 20);
        let (x1, _) = d.example(Split::Train, 0);
        let (x2, _) = d.example(Split::Train, 1);
        assert_ne!(x1, x2);
    }

    #[test]
    fn train_test_streams_differ() {
        let d = SynthDataset::new(DatasetKind::Cifar, 7, 100, 100);
        let (x1, _) = d.example(Split::Train, 5);
        let (x2, _) = d.example(Split::Test, 5);
        assert_ne!(x1, x2);
    }

    #[test]
    fn labels_roughly_balanced() {
        let d = SynthDataset::new(DatasetKind::Mnist, 11, 5000, 0);
        let labels = d.labels(Split::Train);
        let mut counts = [0usize; 10];
        for &l in &labels {
            counts[l] += 1;
        }
        for &c in &counts {
            assert!(c > 300 && c < 700, "counts={counts:?}");
        }
    }

    #[test]
    fn same_class_examples_are_correlated() {
        // Examples of one class share the prototype: their correlation
        // should clearly exceed cross-class correlation on average.
        let d = SynthDataset::new(DatasetKind::Mnist, 3, 2000, 0);
        let labels = d.labels(Split::Train);
        let idx_of = |cls: usize, skip: usize| {
            labels
                .iter()
                .enumerate()
                .filter(|(_, &l)| l == cls)
                .map(|(i, _)| i)
                .nth(skip)
                .unwrap()
        };
        let dot = |a: &[f32], b: &[f32]| -> f32 {
            let na = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>() / (na * nb)
        };
        let (a0, _) = d.example(Split::Train, idx_of(0, 0));
        let (a1, _) = d.example(Split::Train, idx_of(0, 1));
        let (b0, _) = d.example(Split::Train, idx_of(1, 0));
        let same = dot(&a0, &a1);
        let cross = dot(&a0, &b0);
        assert!(
            same > cross + 0.1,
            "same-class corr {same} not above cross-class {cross}"
        );
    }

    #[test]
    fn cifar_dims() {
        let d = SynthDataset::new(DatasetKind::Cifar, 1, 10, 10);
        let (x, _) = d.example(Split::Train, 0);
        assert_eq!(x.len(), 32 * 32 * 3);
    }
}
