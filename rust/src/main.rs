//! `fedless` — the serverless federated learning launcher.
//!
//! ```text
//! fedless run --config exp.cfg [--set key=value ...] [--trials N]
//! fedless run --set model=mnist --set mode=async ...   config-less run
//! fedless info                                         show artifact manifest
//! ```

use std::process::ExitCode;

use fedless::config::parse_config_text;
use fedless::runtime::Manifest;
use fedless::sim::run_experiment;

fn usage() -> ! {
    eprintln!(
        "usage:\n  fedless run [--config FILE] [--set key=value ...] [--trials N]\n  fedless info\n\
         \nconfig keys: model n_nodes mode strategy skew epochs steps_per_epoch\n\
         sample_prob train_size test_size seed store latency node_delays_ms\n\
         crash sync_timeout_s clock compress log_dir verbose"
    );
    std::process::exit(2);
}

fn cmd_info() -> anyhow::Result<()> {
    let m = Manifest::discover()?;
    println!("artifacts dir : {}", m.dir.display());
    println!("pallas kernels: {}", m.use_pallas);
    println!("agg chunk     : {}", m.chunk);
    println!("agg K         : {:?}", m.agg.keys().collect::<Vec<_>>());
    for (name, info) in &m.models {
        println!(
            "model {name:10} params={:>10} batch={:<4} input={:?} {} lr={}",
            info.param_count, info.batch_size, info.input_shape, info.input_dtype, info.lr
        );
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> anyhow::Result<()> {
    let mut config_text = String::new();
    let mut overrides: Vec<String> = Vec::new();
    let mut trials = 1usize;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                let path = args.get(i).unwrap_or_else(|| usage());
                config_text = std::fs::read_to_string(path)?;
            }
            "--set" => {
                i += 1;
                overrides.push(args.get(i).unwrap_or_else(|| usage()).clone());
            }
            "--trials" => {
                i += 1;
                trials = args.get(i).unwrap_or_else(|| usage()).parse()?;
            }
            _ => usage(),
        }
        i += 1;
    }
    for ov in &overrides {
        let kv = ov.replacen('=', " = ", 1);
        config_text.push('\n');
        config_text.push_str(&kv);
    }
    let cfg = parse_config_text(&config_text).map_err(|e| anyhow::anyhow!("{e}"))?;
    eprintln!("running {} ({} trial(s))...", cfg.run_name(), trials);

    if trials == 1 {
        let res = run_experiment(&cfg)?;
        println!("accuracy     : {:.4}", res.final_accuracy);
        println!("test loss    : {:.4}", res.final_loss);
        println!("wall clock   : {:.2}s", res.wall_clock_s);
        println!("store pushes : {}", res.store_pushes);
        println!("mean idle    : {:.1}%", 100.0 * res.mean_idle_fraction);
        println!("all completed: {}", res.all_completed);
        println!("{}", res.render_timelines(72));
    } else {
        let set = fedless::sim::run_trials(&cfg, trials)?;
        println!("accuracy  : {}", set.accuracy.fmt_paper());
        println!("test loss : {}", set.loss.fmt_paper());
        println!("wall clock: {}", set.wall_clock.fmt_paper());
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("info") => cmd_info(),
        _ => usage(),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
