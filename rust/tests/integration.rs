//! End-to-end integration tests over the full stack: artifacts + runtime +
//! data + store + protocols + evaluation. Requires `make artifacts`.
//!
//! Sizes are "smoke" scale so the suite stays fast; the accuracy assertions
//! are deliberately loose (they check learning happened, not paper numbers
//! — those are fedbench's job).

use std::time::Duration;

use fedless::config::{CrashSpec, ExperimentConfig, FederationMode, StoreKind};
use fedless::node::NodeStatus;
use fedless::sim::{run_experiment, run_trials};
use fedless::strategy::StrategyKind;

fn smoke_cfg() -> ExperimentConfig {
    ExperimentConfig {
        model: "mnist".into(),
        n_nodes: 2,
        mode: FederationMode::Async,
        strategy: StrategyKind::FedAvg,
        skew: 0.0,
        epochs: 2,
        steps_per_epoch: 25,
        train_size: 2_000,
        test_size: 320,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn async_mnist_learns() {
    let res = run_experiment(&smoke_cfg()).unwrap();
    assert!(res.all_completed);
    assert!(
        res.final_accuracy > 0.5,
        "2x25 steps should beat chance by far, got {}",
        res.final_accuracy
    );
    assert_eq!(res.reports.len(), 2);
    for r in &res.reports {
        assert_eq!(r.status, NodeStatus::Completed);
        assert_eq!(r.epochs_done, 2);
        assert!(r.pushes >= 1);
        // loss decreased across epochs
        assert!(r.epoch_losses[1] < r.epoch_losses[0] * 1.2);
    }
    // async: every node pushed every epoch (sample_prob = 1)
    assert_eq!(res.store_pushes, 4);
}

#[test]
fn sync_mnist_learns_and_waits() {
    let mut cfg = smoke_cfg();
    cfg.mode = FederationMode::Sync;
    let res = run_experiment(&cfg).unwrap();
    assert!(res.all_completed);
    assert!(res.final_accuracy > 0.5, "{}", res.final_accuracy);
    for r in &res.reports {
        // sync: one aggregation per epoch, all K entries present
        assert_eq!(r.aggregations, cfg.epochs as u64);
    }
}

#[test]
fn centralized_baseline_runs() {
    let mut cfg = smoke_cfg();
    cfg.mode = FederationMode::Local;
    cfg.n_nodes = 1;
    let res = run_experiment(&cfg).unwrap();
    assert!(res.all_completed);
    assert_eq!(res.store_pushes, 0, "local mode must not touch the store");
    assert!(res.final_accuracy > 0.5);
}

#[test]
fn results_are_reproducible_for_same_seed() {
    // Sync federation is bit-deterministic: every round aggregates the
    // same K entries regardless of thread timing. (Async is inherently
    // timing-dependent — a pull races peers' pushes — so only sync can be
    // asserted bit-identical; that looseness is the protocol's design,
    // not a bug.)
    let mut cfg = smoke_cfg();
    cfg.mode = FederationMode::Sync;
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.final_loss, b.final_loss);
}

#[test]
fn different_seeds_differ() {
    let mut cfg = smoke_cfg();
    cfg.mode = FederationMode::Sync;
    let a = run_experiment(&cfg).unwrap();
    cfg.seed = 8;
    let b = run_experiment(&cfg).unwrap();
    assert_ne!(a.final_accuracy, b.final_accuracy);
}

#[test]
fn fs_store_full_run() {
    let dir = std::env::temp_dir().join(format!("fedless_it_fs_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = smoke_cfg();
    cfg.store = StoreKind::Fs(dir.clone());
    let res = run_experiment(&cfg).unwrap();
    assert!(res.all_completed);
    assert!(res.final_accuracy > 0.5);
    // blobs actually landed on disk
    let n_files = std::fs::read_dir(&dir).unwrap().count();
    assert!(n_files >= 2, "expected blob files, found {n_files}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn crash_async_survives_sync_stalls() {
    let mut cfg = smoke_cfg();
    cfg.n_nodes = 3;
    cfg.crash = Some(CrashSpec::at(1, 1));
    cfg.sync_timeout = Duration::from_secs(2);

    // async: healthy nodes complete
    cfg.mode = FederationMode::Async;
    let res = run_experiment(&cfg).unwrap();
    assert!(!res.all_completed);
    let crashed: Vec<_> = res
        .reports
        .iter()
        .filter(|r| matches!(r.status, NodeStatus::Crashed { .. }))
        .collect();
    assert_eq!(crashed.len(), 1);
    let healthy_done = res
        .reports
        .iter()
        .filter(|r| r.status == NodeStatus::Completed)
        .count();
    assert_eq!(healthy_done, 2, "async healthy nodes must finish");

    // sync: healthy nodes stall at the barrier of the crashed round
    cfg.mode = FederationMode::Sync;
    let res = run_experiment(&cfg).unwrap();
    let stalled = res
        .reports
        .iter()
        .filter(|r| matches!(r.status, NodeStatus::Stalled { .. }))
        .count();
    assert_eq!(stalled, 2, "sync healthy nodes must stall: {:?}",
        res.reports.iter().map(|r| &r.status).collect::<Vec<_>>());
}

#[test]
fn gossip_mnist_learns() {
    let mut cfg = smoke_cfg();
    cfg.mode = FederationMode::Gossip { fanout: 1 };
    cfg.n_nodes = 3;
    let res = run_experiment(&cfg).unwrap();
    assert!(res.all_completed);
    assert!(res.final_accuracy > 0.5, "{}", res.final_accuracy);
    // one push per node per epoch, like sync — but no barrier
    assert_eq!(res.store_pushes, (cfg.n_nodes * cfg.epochs) as u64);
    for r in &res.reports {
        assert_eq!(r.status, NodeStatus::Completed);
        assert!(r.pushes >= 1);
    }
}

#[test]
fn four_mode_sweep_completes_end_to_end() {
    use fedless::sweep::{run_sweep, SweepSpec};

    let mut base = smoke_cfg();
    base.epochs = 2;
    base.steps_per_epoch = 10;
    base.train_size = 900;
    base.test_size = 96;
    base.n_nodes = 3;
    let mut spec = SweepSpec::from_base(base);
    spec.modes = vec![
        FederationMode::Local,
        FederationMode::Sync,
        FederationMode::Async,
        FederationMode::Gossip { fanout: 1 },
    ];
    spec.node_counts = vec![3];
    spec.jobs = 2;
    let report = run_sweep(&spec).unwrap();
    assert_eq!(report.n_trials, 4);
    assert_eq!(report.n_failures, 0, "{}", report.to_markdown());
    let md = report.to_markdown();
    for mode in ["local", "sync", "async", "gossip1"] {
        assert!(md.contains(&format!("| {mode} |")), "missing {mode} row:\n{md}");
    }
}

#[test]
fn straggler_makes_sync_slower_than_async() {
    let mut cfg = smoke_cfg();
    cfg.n_nodes = 2;
    cfg.epochs = 2;
    cfg.steps_per_epoch = 15;
    cfg.node_delays_ms = vec![0.0, 30.0]; // node 1 ~30ms/step slower

    cfg.mode = FederationMode::Sync;
    let sync = run_experiment(&cfg).unwrap();
    cfg.mode = FederationMode::Async;
    let asyn = run_experiment(&cfg).unwrap();

    // the fast sync node idles at the barrier; async one doesn't
    let sync_idle = sync.reports[0].wait_time;
    let async_idle = asyn.reports[0].wait_time;
    assert!(
        sync_idle > async_idle + Duration::from_millis(100),
        "sync fast-node idle {sync_idle:?} must exceed async idle {async_idle:?}"
    );
}

#[test]
fn sample_prob_zero_means_no_async_pushes_after_warmup() {
    let mut cfg = smoke_cfg();
    cfg.sample_prob = 0.0;
    let res = run_experiment(&cfg).unwrap();
    assert_eq!(res.store_pushes, 0, "C=0 -> WeightUpdate never runs");
    assert!(res.all_completed);
}

#[test]
fn trials_summarize() {
    let cfg = smoke_cfg();
    let set = run_trials(&cfg, 2).unwrap();
    assert_eq!(set.results.len(), 2);
    assert!(set.accuracy.mean > 0.4);
    assert!(set.accuracy.ci95 >= 0.0);
    assert!(!set.cell().is_empty());
}

#[test]
fn strategies_all_run_end_to_end() {
    for kind in [
        StrategyKind::FedAvg,
        StrategyKind::FedAvgM,
        StrategyKind::FedAdam,
        StrategyKind::FedAsync,
        StrategyKind::FedBuff,
    ] {
        let mut cfg = smoke_cfg();
        cfg.epochs = 2;
        cfg.steps_per_epoch = 10;
        cfg.strategy = kind;
        let res = run_experiment(&cfg)
            .unwrap_or_else(|e| panic!("strategy {} failed: {e}", kind.name()));
        assert!(res.all_completed, "strategy {}", kind.name());
        assert!(
            res.final_accuracy > 0.2,
            "strategy {} acc {}",
            kind.name(),
            res.final_accuracy
        );
    }
}

#[test]
fn lm_end_to_end_smoke() {
    let cfg = ExperimentConfig {
        model: "lm".into(),
        n_nodes: 2,
        mode: FederationMode::Async,
        epochs: 2,
        steps_per_epoch: 15,
        train_size: 600,
        test_size: 80,
        seed: 3,
        ..Default::default()
    };
    let res = run_experiment(&cfg).unwrap();
    assert!(res.all_completed);
    // next-token accuracy on the structured corpus beats uniform-random
    // (1/256) after a handful of steps (spaces dominate)
    assert!(res.final_accuracy > 0.05, "{}", res.final_accuracy);
    for r in &res.reports {
        assert!(r.epoch_losses[1] < r.epoch_losses[0], "{:?}", r.epoch_losses);
    }
}

#[test]
fn latency_store_run_is_correct() {
    // The injected delay itself is asserted at the store level
    // (store::latency unit tests); end-to-end wall-clock comparisons are
    // too noisy on a shared 1-core box (artifact-compile variance >> the
    // injected RTTs), so here we only require that federation through a
    // high-latency store still completes and learns.
    use fedless::store::LatencyConfig;
    let mut cfg = smoke_cfg();
    cfg.epochs = 2;
    cfg.steps_per_epoch = 8;
    cfg.latency = Some(LatencyConfig {
        base: Duration::from_millis(80),
        jitter: Duration::ZERO,
        bytes_per_sec: 0,
    });
    let slow = run_experiment(&cfg).unwrap();
    assert!(slow.all_completed);
    assert!(slow.final_accuracy > 0.4);
    assert_eq!(slow.store_pushes, 4, "federation went through the latency store");
}
