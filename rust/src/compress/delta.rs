//! [`DeltaQ8`] — delta against a pulled base, then int8 quantization
//! (codec id 3).

use anyhow::{bail, Result};

use crate::tensor::FlatParams;

use super::q8::{q8_decode, q8_encode, q8_error_bound};
use super::{Codec, CodecKind};

/// Payload flag: self-contained full quantization (no base used).
const FLAG_FULL: u8 = 0;
/// Payload flag: quantized delta against the base vector.
const FLAG_DELTA: u8 = 1;

/// Delta codec: encode `params - base` with the [`super::Q8`] quantizer
/// (weight *changes* between federation rounds have a far tighter range
/// than the weights themselves, so the same 8 bits buy much finer
/// resolution). Falls back to a full Q8 encoding — flagged in the first
/// payload byte — whenever the base is missing or shape-mismatched, so
/// a cold start or a model resize never fails a push.
///
/// Wire cost: `1 + n + 8 · ceil(n / 256)` bytes, same as [`super::Q8`]
/// plus the flag byte. Error bound (per element): half a quantization
/// step of the *encoded* vector — the delta in delta mode, the raw
/// params in fallback mode.
pub struct DeltaQ8;

fn usable_base<'a>(params: &FlatParams, base: Option<&'a FlatParams>) -> Option<&'a FlatParams> {
    base.filter(|b| b.len() == params.len())
}

impl Codec for DeltaQ8 {
    fn kind(&self) -> CodecKind {
        CodecKind::DeltaQ8
    }

    fn encode(&self, params: &FlatParams, base: Option<&FlatParams>) -> Vec<u8> {
        match usable_base(params, base) {
            Some(b) => {
                let delta: Vec<f32> =
                    params.as_slice().iter().zip(b.as_slice()).map(|(x, y)| x - y).collect();
                let mut out = q8_encode(&delta);
                out.insert(0, FLAG_DELTA);
                out
            }
            None => {
                let mut out = q8_encode(params.as_slice());
                out.insert(0, FLAG_FULL);
                out
            }
        }
    }

    fn decode(&self, payload: &[u8], n: usize, base: Option<&FlatParams>) -> Result<FlatParams> {
        let Some((&flag, body)) = payload.split_first() else {
            bail!("delta-q8 payload is empty");
        };
        match flag {
            FLAG_FULL => Ok(FlatParams(q8_decode(body, n)?)),
            FLAG_DELTA => {
                let Some(b) = base.filter(|b| b.len() == n) else {
                    bail!(
                        "delta-q8 payload needs an {n}-element base to decode \
                         (got {:?})",
                        base.map(FlatParams::len)
                    );
                };
                let delta = q8_decode(body, n)?;
                Ok(FlatParams(
                    b.as_slice().iter().zip(delta.iter()).map(|(y, d)| y + d).collect(),
                ))
            }
            other => bail!("unknown delta-q8 flag byte {other}"),
        }
    }

    fn error_bound(&self, params: &FlatParams, base: Option<&FlatParams>) -> f32 {
        match usable_base(params, base) {
            Some(b) => {
                let delta: Vec<f32> =
                    params.as_slice().iter().zip(b.as_slice()).map(|(x, y)| x - y).collect();
                // the reconstruction adds the exact base back: the error
                // is the delta's quantization plus one f32 add's rounding,
                // which scales with the base's magnitude
                let base_mag = b.as_slice().iter().fold(0.0f32, |m, x| m.max(x.abs()));
                q8_error_bound(&delta) + base_mag * f32::EPSILON
            }
            None => q8_error_bound(params.as_slice()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: usize, seed: f32) -> FlatParams {
        FlatParams((0..n).map(|i| ((i as f32) * 0.13 + seed).sin()).collect())
    }

    #[test]
    fn without_base_behaves_like_q8_plus_flag() {
        let p = params(700, 0.0);
        let enc = DeltaQ8.encode(&p, None);
        assert_eq!(enc[0], FLAG_FULL);
        assert_eq!(enc.len(), 1 + 700 + 8 * 3);
        let dec = DeltaQ8.decode(&enc, 700, None).unwrap();
        assert!(p.max_abs_diff(&dec) <= DeltaQ8.error_bound(&p, None));
    }

    #[test]
    fn shape_mismatched_base_falls_back_to_full() {
        let p = params(100, 0.0);
        let wrong = params(64, 1.0);
        let enc = DeltaQ8.encode(&p, Some(&wrong));
        assert_eq!(enc[0], FLAG_FULL, "mismatched base must not be used");
        // full-mode payloads decode without any base at all
        assert!(DeltaQ8.decode(&enc, 100, None).is_ok());
    }

    #[test]
    fn delta_mode_is_much_finer_than_full_q8_near_the_base() {
        let base = params(2_000, 0.0);
        // a small training step away from the base
        let p = FlatParams(
            base.0.iter().enumerate().map(|(i, x)| x + 1e-3 * ((i % 5) as f32 - 2.0)).collect(),
        );
        let enc = DeltaQ8.encode(&p, Some(&base));
        assert_eq!(enc[0], FLAG_DELTA);
        let dec = DeltaQ8.decode(&enc, 2_000, Some(&base)).unwrap();
        let bound = DeltaQ8.error_bound(&p, Some(&base));
        assert!(p.max_abs_diff(&dec) <= bound, "{} > {}", p.max_abs_diff(&dec), bound);
        // delta range is ~4e-3 vs the params' ~2: the bound tightens by
        // orders of magnitude
        let full_bound = DeltaQ8.error_bound(&p, None);
        assert!(bound < full_bound / 50.0, "delta {bound} vs full {full_bound}");
    }

    #[test]
    fn delta_payload_without_base_errors_cleanly() {
        let base = params(64, 0.0);
        let p = params(64, 0.01);
        let enc = DeltaQ8.encode(&p, Some(&base));
        assert_eq!(enc[0], FLAG_DELTA);
        assert!(DeltaQ8.decode(&enc, 64, None).is_err());
        let wrong = params(32, 0.0);
        assert!(DeltaQ8.decode(&enc, 64, Some(&wrong)).is_err());
    }

    #[test]
    fn malformed_payloads_error() {
        assert!(DeltaQ8.decode(&[], 4, None).is_err());
        assert!(DeltaQ8.decode(&[7, 0, 0], 4, None).is_err(), "unknown flag");
        let enc = DeltaQ8.encode(&params(10, 0.0), None);
        assert!(DeltaQ8.decode(&enc[..enc.len() - 1], 10, None).is_err());
    }
}
