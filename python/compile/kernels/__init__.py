"""Layer-1 Pallas kernels (build-time only; lowered with interpret=True).

Each kernel has a pure-jnp oracle in `ref.py`; pytest checks parity across a
shape/dtype sweep. On a real TPU these BlockSpecs map HBM<->VMEM tiles; on
this image interpret=True lowers them to plain HLO so the CPU PJRT client in
rust can execute the surrounding computation.
"""

from .fedavg_agg import fedavg_aggregate
from .adam_step import fused_adam_step
from .matmul import tiled_matmul

__all__ = ["fedavg_aggregate", "fused_adam_step", "tiled_matmul"]
