//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime: which HLO files exist, each model's parameter
//! count, batch size, input layout and hyperparameters.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Per-model artifact info.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// Model name (the manifest key, e.g. "mnist").
    pub name: String,
    /// Flat parameter vector length P.
    pub param_count: usize,
    /// Batch size the artifacts were lowered with.
    pub batch_size: usize,
    /// Per-example feature shape (no batch dim), e.g. `[28, 28, 1]` or `[65]`.
    pub input_shape: Vec<usize>,
    /// "f32" for images, "i32" for token windows.
    pub input_dtype: String,
    /// Output classes (vocab size for LM models).
    pub num_classes: usize,
    /// Local Adam learning rate baked into the train artifact.
    pub lr: f64,
    /// Path to the init HLO artifact.
    pub init_file: PathBuf,
    /// Path to the train-step HLO artifact.
    pub train_file: PathBuf,
    /// Path to the eval HLO artifact.
    pub eval_file: PathBuf,
}

impl ModelInfo {
    /// Predictions per eval batch (LM models predict seq_len next tokens
    /// per example; classifiers predict one label per example).
    pub fn preds_per_batch(&self) -> usize {
        if self.input_dtype == "i32" {
            self.batch_size * (self.input_shape[0] - 1)
        } else {
            self.batch_size
        }
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// The artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Whether the artifacts were lowered with Pallas kernels.
    pub use_pallas: bool,
    /// Chunk width of the aggregation kernel artifacts.
    pub chunk: usize,
    /// Per-model artifact info, keyed by model name.
    pub models: BTreeMap<String, ModelInfo>,
    /// Aggregation artifacts: K -> file.
    pub agg: BTreeMap<usize, PathBuf>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        Self::parse(&text, dir)
    }

    /// Locate the artifacts directory: `$FEDLESS_ARTIFACTS` or `artifacts/`
    /// relative to cwd or the crate root.
    pub fn discover() -> Result<Manifest> {
        if let Ok(dir) = std::env::var("FEDLESS_ARTIFACTS") {
            return Self::load(dir);
        }
        for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::load(cand);
            }
        }
        Err(anyhow!(
            "artifacts/manifest.json not found — run `make artifacts` \
             (or set FEDLESS_ARTIFACTS)"
        ))
    }

    fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let req = |v: Option<&Json>, what: &str| {
            v.cloned().ok_or_else(|| anyhow!("manifest missing {what}"))
        };

        let mut models = BTreeMap::new();
        for (name, m) in req(j.get("models"), "models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?
        {
            let file = |kind: &str| -> Result<PathBuf> {
                let f = m
                    .get("artifacts")
                    .and_then(|a| a.get(kind))
                    .and_then(|e| e.get("file"))
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model {name}: missing artifact {kind}"))?;
                Ok(dir.join(f))
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    param_count: req(m.get("param_count"), "param_count")?
                        .as_usize()
                        .ok_or_else(|| anyhow!("param_count not a number"))?,
                    batch_size: req(m.get("batch_size"), "batch_size")?
                        .as_usize()
                        .unwrap_or(32),
                    input_shape: req(m.get("input_shape"), "input_shape")?
                        .as_arr()
                        .ok_or_else(|| anyhow!("input_shape not an array"))?
                        .iter()
                        .map(|v| v.as_usize().unwrap_or(0))
                        .collect(),
                    input_dtype: req(m.get("input_dtype"), "input_dtype")?
                        .as_str()
                        .unwrap_or("f32")
                        .to_string(),
                    num_classes: m
                        .get("num_classes")
                        .and_then(Json::as_usize)
                        .unwrap_or(10),
                    lr: m.get("lr").and_then(Json::as_f64).unwrap_or(1e-3),
                    init_file: file("init")?,
                    train_file: file("train")?,
                    eval_file: file("eval")?,
                },
            );
        }

        let mut agg = BTreeMap::new();
        let mut chunk = 262_144;
        if let Some(a) = j.get("agg") {
            if let Some(c) = a.get("chunk").and_then(Json::as_usize) {
                chunk = c;
            }
            if let Some(ks) = a.get("k").and_then(Json::as_obj) {
                for (k, v) in ks {
                    if let (Ok(k), Some(f)) =
                        (k.parse::<usize>(), v.get("file").and_then(Json::as_str))
                    {
                        agg.insert(k, dir.join(f));
                    }
                }
            }
        }

        Ok(Manifest {
            dir,
            use_pallas: j.get("use_pallas").and_then(Json::as_bool).unwrap_or(true),
            chunk,
            models,
            agg,
        })
    }

    /// Look up a model by name, with a readable error listing what exists.
    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name:?} not in manifest (have: {:?}) — rebuild artifacts",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "use_pallas": true, "chunk": 262144,
      "models": {
        "mnist": {
          "param_count": 20490, "batch_size": 32,
          "input_shape": [28, 28, 1], "input_dtype": "f32",
          "num_classes": 10, "lr": 0.001, "weight_decay": 0.0,
          "extra": {},
          "artifacts": {
            "init": {"file": "mnist_init.hlo.txt", "sha256_16": "x"},
            "train": {"file": "mnist_train.hlo.txt", "sha256_16": "x"},
            "eval": {"file": "mnist_eval.hlo.txt", "sha256_16": "x"}
          }
        }
      },
      "agg": {"chunk": 262144, "k": {"2": {"file": "agg_k2.hlo.txt", "sha256_16": "x"}}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let mi = m.model("mnist").unwrap();
        assert_eq!(mi.param_count, 20490);
        assert_eq!(mi.input_shape, vec![28, 28, 1]);
        assert_eq!(mi.train_file, PathBuf::from("/tmp/a/mnist_train.hlo.txt"));
        assert_eq!(m.agg[&2], PathBuf::from("/tmp/a/agg_k2.hlo.txt"));
        assert_eq!(m.chunk, 262144);
        assert_eq!(mi.preds_per_batch(), 32);
    }

    #[test]
    fn lm_preds_per_batch() {
        let mi = ModelInfo {
            name: "lm".into(),
            param_count: 1,
            batch_size: 8,
            input_shape: vec![65],
            input_dtype: "i32".into(),
            num_classes: 256,
            lr: 2e-5,
            init_file: "i".into(),
            train_file: "t".into(),
            eval_file: "e".into(),
        };
        assert_eq!(mi.preds_per_batch(), 8 * 64);
    }

    #[test]
    fn unknown_model_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(r#"{"models": {"x": {}}}"#, PathBuf::from("/")).is_err());
        assert!(Manifest::parse("[]", PathBuf::from("/")).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Validate against the actual artifacts when they exist.
        if let Ok(m) = Manifest::discover() {
            assert!(m.models.contains_key("mnist"));
            let mi = m.model("mnist").unwrap();
            assert!(mi.train_file.exists());
        }
    }
}
