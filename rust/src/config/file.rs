//! `key = value` config files for the `fedless` CLI (a TOML-subset; the
//! image carries no serde, and experiments only need flat scalar keys).
//!
//! ```text
//! # mnist async experiment
//! model = mnist
//! n_nodes = 2
//! mode = async            # sync | async | local | gossip[:m]
//! strategy = fedavg       # fedavg | fedavgm | fedadam | fedasync | fedbuff
//! skew = 0.9
//! epochs = 3
//! steps_per_epoch = 120
//! store = memory          # memory | sharded[:N] | fs:/path/to/dir
//! node_delays_ms = 0,40   # per-node straggler delays
//! crash = 1@2             # crash node 1 at epoch 2 (permanent)
//! crash = 1@2:restart:5   # ...or restart it 5s later from its checkpoint
//! adversary = byzantine:1 # none | byzantine:k | scale:<f> | signflip:k | stale:<r>
//! fault = 0.05            # per-op transient store-failure probability
//! outage = 2:1, 10:0.5    # store outage windows `<start_s>:<dur_s>`
//! sync_quorum = 0.75      # sync rounds may close degraded at 75% of the cohort
//! clock = virtual         # real (default) | virtual simulated time
//! compress = q8           # none | q8 | topk:<frac> | delta-q8
//! threads = auto          # kernel-pool workers: auto | N (default 1)
//! scheduler = events      # threads (default) | events (10k-client DES)
//! participation = 0.1     # per-round client sampling fraction in (0,1]
//! availability = churn:0.3 # none | churn:<p> | diurnal:<period> | stragglers:<frac>:<mult>
//! ```

use std::fmt;
use std::time::Duration;

use super::{CrashSpec, ExperimentConfig, FederationMode, StoreKind};
use crate::store::LatencyConfig;
use crate::strategy::StrategyKind;

/// A parse error pointing at the offending config line.
#[derive(Debug)]
pub struct ConfigError {
    /// 1-based line number in the config text.
    pub line: usize,
    /// Human-readable description of what went wrong.
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

fn err(line: usize, msg: impl Into<String>) -> ConfigError {
    ConfigError { line, msg: msg.into() }
}

/// Parse config text into an [`ExperimentConfig`] (starting from defaults).
pub fn parse_config_text(text: &str) -> Result<ExperimentConfig, ConfigError> {
    let mut cfg = ExperimentConfig::default();
    for (ln, raw) in text.lines().enumerate() {
        let line_no = ln + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err(line_no, "expected `key = value`"))?;
        let key = key.trim();
        let value = value.trim();
        let parse_f64 = |v: &str| {
            v.parse::<f64>().map_err(|_| err(line_no, format!("bad number {v:?}")))
        };
        let parse_usize = |v: &str| {
            v.parse::<usize>().map_err(|_| err(line_no, format!("bad integer {v:?}")))
        };
        match key {
            "model" => cfg.model = value.to_string(),
            "n_nodes" => cfg.n_nodes = parse_usize(value)?,
            "mode" => {
                cfg.mode = FederationMode::parse(value)
                    .ok_or_else(|| err(line_no, format!("unknown mode {value:?}")))?
            }
            "strategy" => {
                cfg.strategy = StrategyKind::parse(value)
                    .ok_or_else(|| err(line_no, format!("unknown strategy {value:?}")))?
            }
            "skew" => cfg.skew = parse_f64(value)?,
            "epochs" => cfg.epochs = parse_usize(value)?,
            "steps_per_epoch" => cfg.steps_per_epoch = parse_usize(value)?,
            "sample_prob" => cfg.sample_prob = parse_f64(value)?,
            "train_size" => cfg.train_size = parse_usize(value)?,
            "test_size" => cfg.test_size = parse_usize(value)?,
            "seed" => {
                cfg.seed = value
                    .parse::<u64>()
                    .map_err(|_| err(line_no, format!("bad seed {value:?}")))?
            }
            "store" => {
                cfg.store = StoreKind::parse(value)
                    .ok_or_else(|| err(line_no, format!("unknown store {value:?}")))?
            }
            "latency" => {
                cfg.latency = match value {
                    "none" => None,
                    "s3" => Some(LatencyConfig::s3_like()),
                    ms => Some(LatencyConfig::from_ms(parse_f64(ms)?)),
                }
            }
            "node_delays_ms" => {
                cfg.node_delays_ms = value
                    .split(',')
                    .map(|v| parse_f64(v.trim()))
                    .collect::<Result<_, _>>()?;
            }
            "crash" => {
                let (node, rest) = value.split_once('@').ok_or_else(|| {
                    err(line_no, "crash must be `node@epoch[:restart:<secs>]`")
                })?;
                let (at, restart) = match rest.split_once(':') {
                    None => (rest, None),
                    Some((at, tail)) => {
                        let secs = tail
                            .trim()
                            .strip_prefix("restart:")
                            .and_then(|d| d.trim().parse::<f64>().ok())
                            .filter(|d| d.is_finite() && *d > 0.0)
                            .ok_or_else(|| {
                                err(line_no, "crash restart must be `restart:<secs>` with secs > 0")
                            })?;
                        (at, Some(Duration::from_secs_f64(secs)))
                    }
                };
                cfg.crash = Some(CrashSpec {
                    node: parse_usize(node.trim())?,
                    at_epoch: parse_usize(at.trim())?,
                    restart,
                });
            }
            "adversary" => {
                cfg.adversary = match value {
                    "none" => None,
                    spec => Some(crate::store::AdversarySpec::parse(spec).ok_or_else(
                        || err(line_no, format!("unknown adversary {value:?}")),
                    )?),
                }
            }
            "sync_timeout_s" => {
                cfg.sync_timeout = Duration::from_secs_f64(parse_f64(value)?)
            }
            "sync_quorum" => cfg.sync_quorum = parse_f64(value)?,
            "fault" => cfg.fault.p_fail = parse_f64(value)?,
            "outage" => {
                cfg.fault.outages = value
                    .split(',')
                    .map(|w| {
                        crate::store::OutageWindow::parse(w.trim()).ok_or_else(|| {
                            err(line_no, format!("outage must be `<start_s>:<dur_s>`, got {w:?}"))
                        })
                    })
                    .collect::<Result<_, _>>()?;
            }
            "clock" => {
                cfg.clock = crate::time::ClockKind::parse(value)
                    .ok_or_else(|| err(line_no, format!("unknown clock {value:?}")))?
            }
            "compress" => {
                cfg.compress = crate::compress::CodecKind::parse(value)
                    .ok_or_else(|| err(line_no, format!("unknown compress codec {value:?}")))?
            }
            "threads" => {
                cfg.threads = super::parse_threads(value).ok_or_else(|| {
                    err(line_no, format!("threads must be `auto` or >= 1, got {value:?}"))
                })?
            }
            "scheduler" => {
                cfg.scheduler = super::SchedulerKind::parse(value)
                    .ok_or_else(|| err(line_no, format!("unknown scheduler {value:?}")))?
            }
            "participation" => cfg.participation = parse_f64(value)?,
            "availability" => {
                cfg.availability = super::AvailabilitySpec::parse(value)
                    .ok_or_else(|| err(line_no, format!("unknown availability {value:?}")))?
            }
            "trace" => cfg.trace = value == "true" || value == "1",
            "log_dir" => cfg.log_dir = Some(value.into()),
            "verbose" => cfg.verbose = value == "true" || value == "1",
            _ => return Err(err(line_no, format!("unknown key {key:?}"))),
        }
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_example() {
        let cfg = parse_config_text(
            "# comment\n\
             model = cifar\n\
             n_nodes = 5\n\
             mode = sync\n\
             strategy = fedavgm\n\
             skew = 0.99   # trailing comment\n\
             epochs = 20\n\
             steps_per_epoch = 50\n\
             store = fs:/tmp/ws\n\
             node_delays_ms = 0, 40, 80\n\
             crash = 1@2\n",
        )
        .unwrap();
        assert_eq!(cfg.model, "cifar");
        assert_eq!(cfg.n_nodes, 5);
        assert_eq!(cfg.mode, FederationMode::Sync);
        assert_eq!(cfg.strategy, StrategyKind::FedAvgM);
        assert_eq!(cfg.skew, 0.99);
        assert_eq!(cfg.store, StoreKind::Fs("/tmp/ws".into()));
        assert_eq!(cfg.node_delays_ms, vec![0.0, 40.0, 80.0]);
        assert_eq!(cfg.crash, Some(CrashSpec::at(1, 2)));
    }

    #[test]
    fn empty_text_gives_defaults() {
        let cfg = parse_config_text("").unwrap();
        assert_eq!(cfg.model, "mnist");
        assert_eq!(cfg.n_nodes, 2);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_config_text("model = mnist\nbogus_key = 3\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_config_text("n_nodes = x\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse_config_text("just a line\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn gossip_mode_values() {
        let cfg = parse_config_text("mode = gossip:3\n").unwrap();
        assert_eq!(cfg.mode, FederationMode::Gossip { fanout: 3 });
        let cfg = parse_config_text("mode = gossip\n").unwrap();
        assert!(matches!(cfg.mode, FederationMode::Gossip { .. }));
        assert!(parse_config_text("mode = gossip:0\n").is_err());
    }

    #[test]
    fn sharded_store_values() {
        let cfg = parse_config_text("store = sharded\n").unwrap();
        assert_eq!(cfg.store, StoreKind::Sharded(crate::store::DEFAULT_SHARDS));
        let cfg = parse_config_text("store = sharded:16\n").unwrap();
        assert_eq!(cfg.store, StoreKind::Sharded(16));
        assert!(parse_config_text("store = sharded:zero\n").is_err());
    }

    #[test]
    fn clock_values() {
        use crate::time::ClockKind;
        let cfg = parse_config_text("clock = virtual\n").unwrap();
        assert_eq!(cfg.clock, ClockKind::Virtual);
        let cfg = parse_config_text("clock = real\n").unwrap();
        assert_eq!(cfg.clock, ClockKind::Real);
        let cfg = parse_config_text("").unwrap();
        assert_eq!(cfg.clock, ClockKind::Real, "real is the default");
        assert!(parse_config_text("clock = sundial\n").is_err());
    }

    #[test]
    fn compress_values() {
        use crate::compress::CodecKind;
        let cfg = parse_config_text("compress = q8\n").unwrap();
        assert_eq!(cfg.compress, CodecKind::Q8);
        let cfg = parse_config_text("compress = topk:0.1\n").unwrap();
        assert_eq!(cfg.compress, CodecKind::TopK { frac: 0.1 });
        let cfg = parse_config_text("compress = delta-q8\n").unwrap();
        assert_eq!(cfg.compress, CodecKind::DeltaQ8);
        let cfg = parse_config_text("").unwrap();
        assert_eq!(cfg.compress, CodecKind::None, "none is the default");
        assert!(parse_config_text("compress = zip\n").is_err());
        assert!(parse_config_text("compress = topk:2\n").is_err());
    }

    #[test]
    fn threads_values() {
        let cfg = parse_config_text("threads = auto\n").unwrap();
        assert_eq!(cfg.threads, 0, "auto encodes as 0");
        let cfg = parse_config_text("threads = 8\n").unwrap();
        assert_eq!(cfg.threads, 8);
        let cfg = parse_config_text("").unwrap();
        assert_eq!(cfg.threads, 1, "single-threaded kernels are the default");
        assert!(parse_config_text("threads = 0\n").is_err());
        assert!(parse_config_text("threads = lots\n").is_err());
    }

    #[test]
    fn adversary_values() {
        use crate::store::{AdversaryKind, AdversarySpec};
        let cfg = parse_config_text("adversary = byzantine:2\n").unwrap();
        assert_eq!(cfg.adversary, AdversarySpec::parse("byzantine:2"));
        let cfg = parse_config_text("adversary = scale:5\n").unwrap();
        assert_eq!(cfg.adversary.unwrap().kind, AdversaryKind::Scale { factor: 5.0 });
        let cfg = parse_config_text("adversary = none\n").unwrap();
        assert!(cfg.adversary.is_none());
        let cfg = parse_config_text("").unwrap();
        assert!(cfg.adversary.is_none(), "honest is the default");
        assert!(parse_config_text("adversary = gremlin\n").is_err());
        assert!(parse_config_text("adversary = stale:0\n").is_err());
    }

    #[test]
    fn scheduler_participation_availability_values() {
        use super::super::{AvailabilitySpec, SchedulerKind};
        let cfg = parse_config_text(
            "scheduler = events\nclock = virtual\nparticipation = 0.1\navailability = churn:0.3\n",
        )
        .unwrap();
        assert_eq!(cfg.scheduler, SchedulerKind::Events);
        assert_eq!(cfg.participation, 0.1);
        assert_eq!(cfg.availability, AvailabilitySpec::Churn { p: 0.3 });
        cfg.validate().unwrap();

        let cfg = parse_config_text("").unwrap();
        assert_eq!(cfg.scheduler, SchedulerKind::Threads, "threads is the default");
        assert_eq!(cfg.participation, 1.0, "full participation is the default");
        assert_eq!(cfg.availability, AvailabilitySpec::None);

        assert!(parse_config_text("scheduler = fibers\n").is_err());
        assert!(parse_config_text("participation = lots\n").is_err());
        assert!(parse_config_text("availability = weekly:3\n").is_err());
    }

    #[test]
    fn crash_restart_values() {
        let cfg = parse_config_text("crash = 1@2:restart:5\n").unwrap();
        assert_eq!(
            cfg.crash,
            Some(CrashSpec { node: 1, at_epoch: 2, restart: Some(Duration::from_secs(5)) })
        );
        let cfg = parse_config_text("crash = 0@1:restart:0.5\n").unwrap();
        assert_eq!(cfg.crash.unwrap().restart, Some(Duration::from_millis(500)));
        assert!(parse_config_text("crash = 1@2:restart:0\n").is_err());
        assert!(parse_config_text("crash = 1@2:reboot:5\n").is_err());
        assert!(parse_config_text("crash = 1\n").is_err());
    }

    #[test]
    fn fault_outage_and_quorum_values() {
        use crate::store::OutageWindow;
        let cfg = parse_config_text("fault = 0.05\noutage = 2:1, 10:0.5\nsync_quorum = 0.75\n")
            .unwrap();
        assert_eq!(cfg.fault.p_fail, 0.05);
        assert_eq!(
            cfg.fault.outages,
            vec![
                OutageWindow { start: Duration::from_secs(2), duration: Duration::from_secs(1) },
                OutageWindow {
                    start: Duration::from_secs(10),
                    duration: Duration::from_millis(500)
                },
            ]
        );
        assert_eq!(cfg.sync_quorum, 0.75);
        cfg.validate().unwrap();

        let cfg = parse_config_text("").unwrap();
        assert!(!cfg.fault.is_active(), "faultless by default");
        assert_eq!(cfg.sync_quorum, 1.0, "full quorum by default");

        assert!(parse_config_text("outage = 5\n").is_err());
        assert!(parse_config_text("outage = 5:0\n").is_err());
        assert!(parse_config_text("fault = lots\n").is_err());
    }

    #[test]
    fn latency_presets() {
        let cfg = parse_config_text("latency = s3\n").unwrap();
        assert!(cfg.latency.is_some());
        let cfg = parse_config_text("latency = 50\n").unwrap();
        assert_eq!(cfg.latency.unwrap().base, Duration::from_millis(50));
        let cfg = parse_config_text("latency = none\n").unwrap();
        assert!(cfg.latency.is_none());
    }
}
