//! Weight-compression codecs — the communication lever of the system.
//!
//! In serverless FL the dominant cost is shipping full model weights
//! through shared storage every epoch: the paper's S3-backed design pays
//! it on every push *and* every pull, and FedLess (Grafberger et al.,
//! 2021) identifies transfer volume as the main cost/latency driver of
//! serverless FL. This module makes the wire encoding pluggable, the way
//! Flower treats update serialization as a first-class extension point:
//!
//! | config value  | codec                  | wire bytes (n f32 params) | per-element error bound        |
//! |---------------|------------------------|---------------------------|--------------------------------|
//! | `none`        | [`Raw`] passthrough    | `52 + 4n` (v1 blob)       | 0 (bit-exact)                  |
//! | `q8`          | [`Q8`] affine int8     | `72 + n + 8⌈n/256⌉`       | `(chunk range)/255/2`          |
//! | `topk:<f>`    | [`TopK`] sparsifier    | `72 + 4 + 8⌈f·n⌉`         | largest dropped magnitude      |
//! | `delta-q8`    | [`DeltaQ8`] delta+int8 | `72 + 1 + n + 8⌈n/256⌉`   | `(delta range)/255/2`          |
//!
//! A codec is selected per experiment (`compress = …` config key, the
//! `"compress"` sweep axis, `fedbench run --compress …`) and applied at
//! the protocol boundary: [`CodecState::encode_for_push`] turns a push
//! into a v2 wire blob ([`crate::tensor::codec::encode_blob_v2`]),
//! round-trips the payload through the codec, and deposits the *decoded
//! reconstruction* in the store — so every peer trains against exactly
//! what the wire carried, and lossy-codec accuracy effects are real, not
//! modeled. The blob's byte length rides along as
//! [`crate::store::WeightEntry::wire_bytes`], which is what
//! [`crate::store::LatencyStore`] charges bandwidth on and what
//! [`crate::metrics::TrafficMeter`] accounts per node.
//!
//! `compress = none` skips the v2 path entirely and keeps the original
//! v1 blob byte-for-byte (the bit-exactness contract the store tests
//! pin down).

mod delta;
mod q8;
mod raw;
mod topk;

pub use delta::DeltaQ8;
pub use q8::{Q8, Q8_CHUNK};
pub use raw::Raw;
pub use topk::{TopK, DEFAULT_TOPK_FRACTION};

use anyhow::{bail, Result};

use crate::par::ChunkPool;
use crate::tensor::codec::{encode_blob_v2, raw_wire_bytes, read_blob, BlobMeta, WireBlob};
use crate::tensor::FlatParams;

/// A weight-compression codec: turn a flat parameter vector into wire
/// payload bytes and back, optionally against a base vector (the
/// delta family). Implementations are stateless; per-node state (the
/// base) lives in [`CodecState`].
///
/// The required methods take a [`ChunkPool`]: every codec here splits
/// its work on fixed chunk boundaries (never a function of the thread
/// count), so the payload bytes and reconstructions are bit-identical
/// for `threads = 1` and `threads = N` — the [`crate::par`] determinism
/// contract, pinned by `rust/tests/determinism.rs`.
pub trait Codec: Send + Sync {
    /// Which [`CodecKind`] this codec implements.
    fn kind(&self) -> CodecKind;

    /// Encode `params` into payload bytes, running chunk-parallel work
    /// on `pool`. `base` is the last-pulled base vector; codecs that
    /// don't delta ignore it, [`DeltaQ8`] falls back to a
    /// self-contained encoding when it is absent or shape-mismatched.
    fn encode_pooled(
        &self,
        params: &FlatParams,
        base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Vec<u8>;

    /// Decode `n` elements from payload bytes (against `base` for delta
    /// payloads), running chunk-parallel work on `pool`. Must return
    /// `Err` — never panic — on malformed input.
    fn decode_pooled(
        &self,
        payload: &[u8],
        n: usize,
        base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Result<FlatParams>;

    /// Documented per-element reconstruction-error bound for encoding
    /// `params` (against `base`): `decode(encode(x)) - x` is bounded by
    /// this in absolute value, element-wise. `0.0` means bit-exact.
    fn error_bound(&self, params: &FlatParams, base: Option<&FlatParams>) -> f32;

    /// Single-threaded [`Codec::encode_pooled`] (bit-identical).
    fn encode(&self, params: &FlatParams, base: Option<&FlatParams>) -> Vec<u8> {
        self.encode_pooled(params, base, ChunkPool::sequential())
    }

    /// Single-threaded [`Codec::decode_pooled`] (bit-identical).
    fn decode(&self, payload: &[u8], n: usize, base: Option<&FlatParams>) -> Result<FlatParams> {
        self.decode_pooled(payload, n, base, ChunkPool::sequential())
    }
}

/// Which codec an experiment ships weights with (`compress = …`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum CodecKind {
    /// No compression: v1 raw-f32 blobs, bit-exact (the default).
    #[default]
    None,
    /// Per-chunk affine int8 quantization ([`Q8`]), ~3.9× smaller.
    Q8,
    /// Magnitude sparsification ([`TopK`]) keeping this fraction.
    TopK {
        /// Kept fraction in `(0, 1]` (`topk:0.1` syntax).
        frac: f64,
    },
    /// Delta against the last-pulled base, then int8 ([`DeltaQ8`]).
    DeltaQ8,
}

impl CodecKind {
    /// Parse a config/CLI value: `none` (or `raw`), `q8`,
    /// `topk[:<frac>]` (e.g. `topk:0.1`), or `delta-q8`.
    pub fn parse(s: &str) -> Option<CodecKind> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "raw" => Some(CodecKind::None),
            "q8" => Some(CodecKind::Q8),
            "topk" => Some(CodecKind::TopK { frac: DEFAULT_TOPK_FRACTION }),
            "delta-q8" | "deltaq8" => Some(CodecKind::DeltaQ8),
            other => other
                .strip_prefix("topk:")
                .and_then(|f| f.parse::<f64>().ok())
                .filter(|&f| f > 0.0 && f <= 1.0)
                .map(|frac| CodecKind::TopK { frac }),
        }
    }

    /// Wire codec id stored in the v2 blob header.
    pub fn id(self) -> u16 {
        match self {
            CodecKind::None => 0,
            CodecKind::Q8 => 1,
            CodecKind::TopK { .. } => 2,
            CodecKind::DeltaQ8 => 3,
        }
    }

    /// Filesystem- and table-safe label, e.g. `q8`, `topk0.1`,
    /// `delta-q8` (inverse of [`CodecKind::parse`] up to the `topk:`
    /// separator).
    pub fn label(self) -> String {
        match self {
            CodecKind::None => "none".into(),
            CodecKind::Q8 => "q8".into(),
            CodecKind::TopK { frac } => format!("topk{frac}"),
            CodecKind::DeltaQ8 => "delta-q8".into(),
        }
    }

    /// Instantiate the codec.
    pub fn build(self) -> Box<dyn Codec> {
        match self {
            CodecKind::None => Box::new(Raw),
            CodecKind::Q8 => Box::new(Q8),
            CodecKind::TopK { frac } => Box::new(TopK::new(frac)),
            CodecKind::DeltaQ8 => Box::new(DeltaQ8),
        }
    }
}

/// Per-node codec state: the codec instance plus the delta family's
/// base vector (the weights the node adopted at its last pull, tagged
/// with a monotone version for the v2 blob header). One `CodecState`
/// lives in each node thread and is threaded to the protocols through
/// [`crate::protocol::EpochCtx`].
pub struct CodecState {
    kind: CodecKind,
    codec: Box<dyn Codec>,
    /// `(version, params)` of the last-pulled base; only retained for
    /// codecs that delta against it.
    base: Option<(u64, FlatParams)>,
}

impl CodecState {
    /// Fresh per-node state for `kind` (no base yet — the first push of
    /// a delta codec self-contains). The kernel pool is not state: it
    /// rides in on each call (from [`crate::protocol::EpochCtx::pool`]),
    /// so there is exactly one source of truth for the thread count.
    pub fn new(kind: CodecKind) -> CodecState {
        CodecState { kind, codec: kind.build(), base: None }
    }

    /// Which codec this state drives.
    pub fn kind(&self) -> CodecKind {
        self.kind
    }

    /// Record the weights the node just adopted from a pull (the
    /// aggregate it will train on) as the delta base, tagged with a
    /// monotone `version` (the store seq of the newest pulled entry).
    /// No-op for codecs that never delta, so non-delta experiments pay
    /// no clone.
    pub fn set_base(&mut self, version: u64, params: &FlatParams) {
        if matches!(self.kind, CodecKind::DeltaQ8) {
            self.base = Some((version, params.clone()));
        }
    }

    /// Encode `params` for a push on `pool`: returns the wire byte
    /// count of the full blob (header included) and the decoded
    /// reconstruction the store should deposit (bit-exact for `none`,
    /// and byte-identical for any thread count). The lossy path
    /// round-trips through the actual v2 wire format, so what peers
    /// aggregate is exactly what the wire carried.
    pub fn encode_for_push(
        &self,
        meta: &BlobMeta,
        params: &FlatParams,
        pool: ChunkPool,
    ) -> Result<(u64, FlatParams)> {
        if self.kind == CodecKind::None {
            // v1 fast path: today's blob, byte-for-byte; no re-encode.
            return Ok((raw_wire_bytes(params.len()), params.clone()));
        }
        let base = self
            .base
            .as_ref()
            .filter(|(_, b)| b.len() == params.len());
        let (base_version, base_params) = match base {
            Some((v, b)) => (*v, Some(b)),
            None => (0, None),
        };
        let payload = self.codec.encode_pooled(params, base_params, pool);
        let blob = encode_blob_v2(meta, self.kind.id(), base_version, params.len(), &payload);
        // Round-trip through the real wire format: any writer/reader
        // disagreement fails the push loudly instead of corrupting
        // training silently.
        let wire = read_blob(&blob)?;
        let stored = self.decode_wire(&wire, pool)?;
        Ok((blob.len() as u64, stored))
    }

    /// Decode a parsed wire blob into params on `pool`, resolving delta
    /// payloads against this state's base. The blob borrows the pulled
    /// wire buffer ([`read_blob`] is zero-copy), so decoding a raw
    /// payload performs exactly one allocation — the output params.
    pub fn decode_wire(&self, wire: &WireBlob<'_>, pool: ChunkPool) -> Result<FlatParams> {
        if wire.codec_id != self.kind.id() {
            bail!(
                "blob codec id {} does not match configured codec {} (id {})",
                wire.codec_id,
                self.kind.label(),
                self.kind.id()
            );
        }
        let base = self.base.as_ref().map(|(_, b)| b);
        self.codec.decode_pooled(wire.payload, wire.uncomp_len, base, pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::codec::encode_blob;

    fn meta() -> BlobMeta {
        BlobMeta { node_id: 1, round: 4, epoch: 4, n_examples: 320 }
    }

    fn training_like_params(n: usize) -> FlatParams {
        FlatParams((0..n).map(|i| ((i as f32) * 0.071).sin() * 0.8).collect())
    }

    #[test]
    fn kind_parse_label_round_trip() {
        for (s, kind) in [
            ("none", CodecKind::None),
            ("raw", CodecKind::None),
            ("q8", CodecKind::Q8),
            ("topk", CodecKind::TopK { frac: DEFAULT_TOPK_FRACTION }),
            ("topk:0.25", CodecKind::TopK { frac: 0.25 }),
            ("delta-q8", CodecKind::DeltaQ8),
        ] {
            assert_eq!(CodecKind::parse(s), Some(kind), "{s}");
        }
        assert_eq!(CodecKind::parse("Q8"), Some(CodecKind::Q8), "case-insensitive");
        for bad in ["", "zip", "topk:0", "topk:1.5", "topk:-1", "topk:x", "q16"] {
            assert_eq!(CodecKind::parse(bad), None, "{bad}");
        }
        // labels round-trip for the un-parameterized codecs (topk's
        // label drops the `:` separator, like gossip's fanout label)
        for kind in [CodecKind::None, CodecKind::Q8, CodecKind::DeltaQ8] {
            assert_eq!(CodecKind::parse(&kind.label()), Some(kind), "label round-trip");
        }
        for kind in [
            CodecKind::None,
            CodecKind::Q8,
            CodecKind::TopK { frac: 0.1 },
            CodecKind::DeltaQ8,
        ] {
            assert_eq!(kind.build().kind(), kind, "build reports its kind");
        }
    }

    #[test]
    fn codec_ids_are_distinct_and_stable() {
        assert_eq!(CodecKind::None.id(), 0);
        assert_eq!(CodecKind::Q8.id(), 1);
        assert_eq!(CodecKind::TopK { frac: 0.5 }.id(), 2);
        assert_eq!(CodecKind::DeltaQ8.id(), 3);
    }

    /// Shared lossy-codec conformance: for every codec, on several input
    /// shapes, the wire round-trip must reconstruct within the codec's
    /// documented [`Codec::error_bound`] — and [`Raw`] must be bit-exact.
    #[test]
    fn error_bound_conformance_for_every_codec() {
        let inputs = [
            FlatParams(vec![]),
            FlatParams(vec![0.0; 17]),
            training_like_params(1),
            training_like_params(255),
            training_like_params(256),
            training_like_params(257),
            training_like_params(5_000),
            FlatParams((0..1_000).map(|i| (i % 13) as f32 * 1e3 - 6e3).collect()),
        ];
        let base = training_like_params(5_000);
        for kind in [
            CodecKind::None,
            CodecKind::Q8,
            CodecKind::TopK { frac: 0.1 },
            CodecKind::TopK { frac: 1.0 },
            CodecKind::DeltaQ8,
        ] {
            let codec = kind.build();
            for p in &inputs {
                let b = (p.len() == base.len()).then_some(&base);
                let enc = codec.encode(p, b);
                let dec = codec.decode(&enc, p.len(), b).unwrap_or_else(|e| {
                    panic!("{}: decode failed on len {}: {e}", kind.label(), p.len())
                });
                assert_eq!(dec.len(), p.len(), "{}", kind.label());
                let bound = codec.error_bound(p, b);
                if p.is_empty() {
                    continue;
                }
                let err = p.max_abs_diff(&dec);
                assert!(
                    err <= bound,
                    "{}: max err {err} > documented bound {bound} (len {})",
                    kind.label(),
                    p.len()
                );
                if kind == CodecKind::None {
                    assert_eq!(bound, 0.0);
                    assert_eq!(p.0, dec.0, "raw must be bit-exact");
                }
            }
        }
    }

    #[test]
    fn none_push_is_bit_identical_to_todays_v1_blob() {
        let p = training_like_params(300);
        let state = CodecState::new(CodecKind::None);
        let (wire_bytes, stored) = state.encode_for_push(&meta(), &p, ChunkPool::sequential()).unwrap();
        assert_eq!(stored.0, p.0, "no-compression reconstruction is the input");
        assert_eq!(
            wire_bytes,
            encode_blob(&meta(), &p).len() as u64,
            "compress = none wire cost is exactly the v1 blob"
        );
    }

    #[test]
    fn q8_push_shrinks_wire_at_least_3x_and_stays_in_bound() {
        let p = training_like_params(4_096);
        let state = CodecState::new(CodecKind::Q8);
        let (wire, stored) = state.encode_for_push(&meta(), &p, ChunkPool::sequential()).unwrap();
        let raw = raw_wire_bytes(p.len());
        assert!(
            raw as f64 / wire as f64 >= 3.0,
            "q8 must shrink the wire >= 3x: {raw} -> {wire}"
        );
        let bound = CodecKind::Q8.build().error_bound(&p, None);
        assert!(p.max_abs_diff(&stored) <= bound);
    }

    #[test]
    fn delta_state_uses_base_after_set_base() {
        let base = training_like_params(512);
        let p = FlatParams(base.0.iter().map(|x| x + 1e-3).collect());
        let mut state = CodecState::new(CodecKind::DeltaQ8);

        // cold start: no base, self-contained
        let (w0, s0) = state.encode_for_push(&meta(), &p, ChunkPool::sequential()).unwrap();
        assert!(p.max_abs_diff(&s0) <= CodecKind::DeltaQ8.build().error_bound(&p, None));

        state.set_base(9, &base);
        let (w1, s1) = state.encode_for_push(&meta(), &p, ChunkPool::sequential()).unwrap();
        assert_eq!(w0, w1, "delta flag keeps the wire size identical");
        // against a nearby base the reconstruction is far tighter
        let delta_bound = CodecKind::DeltaQ8.build().error_bound(&p, Some(&base));
        assert!(p.max_abs_diff(&s1) <= delta_bound);
        assert!(p.max_abs_diff(&s1) < p.max_abs_diff(&s0) / 10.0 + 1e-9);

        // a shape-mismatched base falls back to full encoding
        state.set_base(10, &training_like_params(100));
        let (_, s2) = state.encode_for_push(&meta(), &p, ChunkPool::sequential()).unwrap();
        assert!(p.max_abs_diff(&s2) <= CodecKind::DeltaQ8.build().error_bound(&p, None));
    }

    #[test]
    fn set_base_is_a_no_op_for_non_delta_codecs() {
        let p = training_like_params(64);
        for kind in [CodecKind::None, CodecKind::Q8, CodecKind::TopK { frac: 0.5 }] {
            let mut state = CodecState::new(kind);
            state.set_base(3, &p);
            assert!(state.base.is_none(), "{}", kind.label());
        }
    }

    #[test]
    fn pooled_state_produces_identical_wire_blobs() {
        // the threads config key must never change a byte on the wire
        let p = training_like_params(4_096);
        for kind in [
            CodecKind::None,
            CodecKind::Q8,
            CodecKind::TopK { frac: 0.1 },
            CodecKind::DeltaQ8,
        ] {
            let state = CodecState::new(kind);
            let (wb_s, st_s) =
                state.encode_for_push(&meta(), &p, ChunkPool::sequential()).unwrap();
            let (wb_p, st_p) =
                state.encode_for_push(&meta(), &p, crate::par::ChunkPool::new(8)).unwrap();
            assert_eq!(wb_s, wb_p, "{}: wire bytes must match", kind.label());
            assert_eq!(
                st_s.0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                st_p.0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{}: stored reconstruction must be bit-identical",
                kind.label()
            );
        }
    }

    #[test]
    fn decode_wire_rejects_codec_mismatch() {
        let p = training_like_params(128);
        let payload = Q8.encode(&p, None);
        let blob = encode_blob_v2(&meta(), CodecKind::Q8.id(), 0, p.len(), &payload);
        let wire = read_blob(&blob).unwrap();
        let state = CodecState::new(CodecKind::TopK { frac: 0.1 });
        assert!(state.decode_wire(&wire, ChunkPool::sequential()).is_err());
        let state = CodecState::new(CodecKind::Q8);
        assert!(state.decode_wire(&wire, ChunkPool::sequential()).is_ok());
    }
}
