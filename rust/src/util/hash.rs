//! FNV-1a 64-bit hashing — used for weight-store state hashes (the paper's
//! "check if the remote server has changed state (as reported by a unique
//! hash)") and for blob integrity headers in the on-disk codec.

/// FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_multi(&[bytes])
}

/// FNV-1a over the concatenation of several byte slices, without
/// materializing the concatenation — used by the blob codec to hash a
/// header with its hash field treated as zeroed.
pub fn fnv1a64_multi(parts: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Hash an f32 slice by its raw little-endian bytes.
pub fn hash_f32s(xs: &[f32]) -> u64 {
    // Safety-free path: serialize in chunks to avoid an extra allocation.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Combine hashes order-dependently (for store state hashes).
pub fn combine(a: u64, b: u64) -> u64 {
    a ^ b
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(a << 6)
        .wrapping_add(a >> 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_fnv_vector() {
        // FNV-1a("") = offset basis
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        // differs for different inputs
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
    }

    #[test]
    fn f32_hash_matches_byte_hash() {
        let xs = [1.0f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        for x in &xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(hash_f32s(&xs), fnv1a64(&bytes));
    }

    #[test]
    fn combine_is_order_sensitive() {
        assert_ne!(combine(1, 2), combine(2, 1));
    }

    #[test]
    fn multi_part_hash_matches_concatenation() {
        assert_eq!(fnv1a64_multi(&[b"ab", b"", b"cd"]), fnv1a64(b"abcd"));
        assert_eq!(fnv1a64_multi(&[]), fnv1a64(b""));
    }
}
