//! Read-through cache for pull-heavy stores — the standard production
//! optimization over a remote weight store: `latest_per_node` results are
//! served from a local cache keyed by the store's state hash, so a client
//! that polls an *unchanged* store (a fast node between slow peers' pushes)
//! pays one cheap LIST (`state_hash`) instead of re-downloading every blob.
//!
//! With the simulated-S3 `LatencyStore` underneath, this converts the
//! async protocol's pull cost from O(K·P·4 bytes) per federation to ~one
//! RTT in the unchanged case (measured in EXPERIMENTS.md §Perf).

use std::sync::Mutex;
use std::time::Duration;

use anyhow::Result;

use super::{PushRequest, WeightEntry, WeightStore};

/// Caches `latest_per_node` keyed by `state_hash`.
pub struct CachedStore<S> {
    inner: S,
    cache: Mutex<Option<(u64, Vec<WeightEntry>)>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl<S: WeightStore> CachedStore<S> {
    /// Wrap `inner` with an (initially empty) read-through cache.
    pub fn new(inner: S) -> Self {
        CachedStore {
            inner,
            cache: Mutex::new(None),
            hits: Default::default(),
            misses: Default::default(),
        }
    }

    /// The wrapped store.
    pub fn inner(&self) -> &S {
        &self.inner
    }

    /// (cache hits, cache misses) on `latest_per_node`.
    pub fn stats(&self) -> (u64, u64) {
        use std::sync::atomic::Ordering::Relaxed;
        (self.hits.load(Relaxed), self.misses.load(Relaxed))
    }
}

impl<S: WeightStore> WeightStore for CachedStore<S> {
    fn push(&self, req: PushRequest) -> Result<u64> {
        // a push invalidates our own view immediately
        let seq = self.inner.push(req)?;
        *self.cache.lock().unwrap() = None;
        Ok(seq)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        use std::sync::atomic::Ordering::Relaxed;
        let h = self.inner.state_hash()?;
        {
            let cache = self.cache.lock().unwrap();
            if let Some((ch, entries)) = cache.as_ref() {
                if *ch == h {
                    self.hits.fetch_add(1, Relaxed);
                    return Ok(entries.clone()); // Arc'd params: cheap clone
                }
            }
        }
        self.misses.fetch_add(1, Relaxed);
        let entries = self.inner.latest_per_node()?;
        *self.cache.lock().unwrap() = Some((h, entries.clone()));
        Ok(entries)
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        self.inner.entries_for_round(round)
    }

    fn state_hash(&self) -> Result<u64> {
        self.inner.state_hash()
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        self.inner.latest_for_node(node_id)
    }

    fn version(&self) -> Result<u64> {
        self.inner.version()
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        self.inner.wait_for_change(since, timeout)
    }

    fn push_count(&self) -> u64 {
        self.inner.push_count()
    }

    fn clear(&self) -> Result<()> {
        *self.cache.lock().unwrap() = None;
        self.inner.clear()
    }

    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // forward the CAS to the inner store's atomic implementation; a
        // landed put invalidates our view just like a plain push
        let out = self.inner.push_if_version(req, expected)?;
        if out.is_some() {
            *self.cache.lock().unwrap() = None;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::store_tests::{self, push_req};
    use crate::store::MemoryStore;

    #[test]
    fn conformance() {
        store_tests::conformance(&CachedStore::new(MemoryStore::new()));
    }

    #[test]
    fn concurrent() {
        store_tests::concurrent_pushes(std::sync::Arc::new(CachedStore::new(
            MemoryStore::new(),
        )));
    }

    #[test]
    fn repeated_pulls_hit_cache() {
        let s = CachedStore::new(MemoryStore::new());
        s.push(push_req(0, 0, 1.0)).unwrap();
        let a = s.latest_per_node().unwrap();
        let b = s.latest_per_node().unwrap();
        let c = s.latest_per_node().unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(b[0].params.0, c[0].params.0);
        let (hits, misses) = s.stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 2);
    }

    #[test]
    fn push_invalidates() {
        let s = CachedStore::new(MemoryStore::new());
        s.push(push_req(0, 0, 1.0)).unwrap();
        assert_eq!(s.latest_per_node().unwrap()[0].params.0[0], 1.0);
        s.push(push_req(0, 1, 2.0)).unwrap();
        assert_eq!(s.latest_per_node().unwrap()[0].params.0[0], 2.0);
    }

    #[test]
    fn foreign_push_detected_via_hash() {
        // two handles on one inner store: a pull through handle A after a
        // push through handle B must see the new entry (hash changed)
        let inner: std::sync::Arc<dyn WeightStore> =
            std::sync::Arc::new(MemoryStore::new());
        let a = CachedStore::new(std::sync::Arc::clone(&inner));
        a.push(push_req(0, 0, 1.0)).unwrap();
        let _ = a.latest_per_node().unwrap();
        inner.push(push_req(1, 0, 5.0)).unwrap();
        let entries = a.latest_per_node().unwrap();
        assert_eq!(entries.len(), 2, "cached handle must observe foreign push");
        let (_, misses) = a.stats();
        assert_eq!(misses, 2);
    }
}
