//! Summary statistics for repeated trials: mean and 95% confidence
//! interval, matching the paper's table format ("Mean and 95% confidence
//! intervals are reported for repeated trials").

/// Two-sided 95% critical values of Student's t distribution, indexed by
/// degrees of freedom (1-based; df > 30 uses the normal approximation).
const T95: [f64; 31] = [
    f64::NAN, 12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262,
    2.228, 2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093,
    2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045,
    2.042,
];

fn t95(df: usize) -> f64 {
    if df == 0 {
        f64::NAN
    } else if df < T95.len() {
        T95[df]
    } else {
        1.96
    }
}

/// Mean ± half-width of the 95% CI over a set of trial results.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Sample standard deviation (Bessel-corrected; 0 when n == 1).
    pub std: f64,
    /// Half-width of the 95% confidence interval (0 when n == 1).
    pub ci95: f64,
}

impl Summary {
    /// Summarize a non-empty sample. Panics on an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        assert!(n > 0, "summary of empty sample");
        let mean = xs.iter().sum::<f64>() / n as f64;
        if n == 1 {
            return Summary { n, mean, std: 0.0, ci95: 0.0 };
        }
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
        let std = var.sqrt();
        let ci95 = t95(n - 1) * std / (n as f64).sqrt();
        Summary { n, mean, std, ci95 }
    }

    /// Paper-table formatting: `.983 ± .002`.
    pub fn fmt_paper(&self) -> String {
        if self.n == 1 {
            format!("{:.3}", self.mean)
        } else {
            format!("{:.3} ± {:.3}", self.mean, self.ci95)
        }
    }

    /// `mean ± std` formatting (used by sweep reports, where std across
    /// seeds is the more natural spread measure than a CI half-width).
    pub fn fmt_mean_std(&self) -> String {
        if self.n == 1 {
            format!("{:.3}", self.mean)
        } else {
            format!("{:.3} ± {:.3}", self.mean, self.std)
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.fmt_paper())
    }
}

/// Percentile of a non-empty ascending-sorted sample (nearest-rank);
/// used by the bench report paths.
///
/// Boundary ranks are defined explicitly: `p = 0` is the sample minimum
/// (first element) and `p = 100` the maximum (last element); in between
/// the value at rank `ceil(p / 100 · n)` is returned. An empty sample or
/// a `p` outside `[0, 100]` is an *error*, not a panic — report
/// generators aggregate whatever samples a run produced, and a
/// degenerate run must surface a message instead of aborting the
/// harness.
pub fn percentile(sorted: &[f64], p: f64) -> anyhow::Result<f64> {
    anyhow::ensure!(!sorted.is_empty(), "percentile of an empty sample");
    anyhow::ensure!(
        (0.0..=100.0).contains(&p),
        "percentile p = {p} outside [0, 100]"
    );
    if p == 0.0 {
        return Ok(sorted[0]);
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    Ok(sorted[rank.min(sorted.len()) - 1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_sample() {
        let s = Summary::of(&[0.5]);
        assert_eq!(s.mean, 0.5);
        assert_eq!(s.ci95, 0.0);
        assert_eq!(s.fmt_paper(), "0.500");
    }

    #[test]
    fn known_ci() {
        // n=2: mean 1.0, std = sqrt(2)*0.5.. check against hand computation
        let s = Summary::of(&[0.9, 1.1]);
        assert!((s.mean - 1.0).abs() < 1e-12);
        // std = sqrt(((0.1)^2 + (0.1)^2)/1) = 0.1414..; ci = 12.706 * std / sqrt(2)
        let expect = 12.706 * (0.02f64).sqrt() / (2f64).sqrt();
        assert!((s.ci95 - expect).abs() < 1e-9, "{} vs {}", s.ci95, expect);
    }

    #[test]
    fn large_n_uses_normal_approx() {
        let xs: Vec<f64> = (0..100).map(|i| (i % 2) as f64).collect();
        let s = Summary::of(&xs);
        assert!((s.mean - 0.5).abs() < 1e-12);
        assert!(s.ci95 > 0.09 && s.ci95 < 0.11, "{}", s.ci95);
    }

    #[test]
    fn zero_variance() {
        let s = Summary::of(&[2.0, 2.0, 2.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.ci95, 0.0);
    }

    #[test]
    fn mean_std_formatting() {
        assert_eq!(Summary::of(&[0.5]).fmt_mean_std(), "0.500");
        let s = Summary::of(&[0.9, 1.1]);
        // std = sqrt(0.02) = 0.1414...
        assert_eq!(s.fmt_mean_std(), "1.000 ± 0.141");
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&xs, 50.0).unwrap(), 5.0);
        assert_eq!(percentile(&xs, 99.0).unwrap(), 10.0);
        assert_eq!(percentile(&xs, 1.0).unwrap(), 1.0);
    }

    #[test]
    fn percentile_boundary_ranks() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        // p = 0 / p = 100 are pinned to min / max
        assert_eq!(percentile(&xs, 0.0).unwrap(), 1.0);
        assert_eq!(percentile(&xs, 100.0).unwrap(), 5.0);
        // the smallest positive p still lands on the first rank
        assert_eq!(percentile(&xs, 1e-9).unwrap(), 1.0);
        // just below 100 stays on the last rank (ceil rounds up)
        assert_eq!(percentile(&xs, 99.999).unwrap(), 5.0);
        // single-element samples answer every p with that element
        assert_eq!(percentile(&[7.0], 0.0).unwrap(), 7.0);
        assert_eq!(percentile(&[7.0], 50.0).unwrap(), 7.0);
        assert_eq!(percentile(&[7.0], 100.0).unwrap(), 7.0);
    }

    #[test]
    fn percentile_rejects_empty_and_out_of_range() {
        assert!(percentile(&[], 50.0).is_err(), "empty sample is an error");
        let xs = [1.0, 2.0];
        assert!(percentile(&xs, -0.1).is_err());
        assert!(percentile(&xs, 100.1).is_err());
        assert!(percentile(&xs, f64::NAN).is_err());
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }
}
