//! [`Q8`] — per-chunk affine int8 quantization (codec id 1).

use anyhow::{bail, Result};

use crate::tensor::FlatParams;

use super::{Codec, CodecKind};

/// Elements per quantization chunk: small enough that one outlier only
/// coarsens 256 neighbours, large enough that the 8-byte per-chunk
/// header (min + scale) stays ~3% overhead.
pub const Q8_CHUNK: usize = 256;

/// Affine int8 quantizer: each [`Q8_CHUNK`]-element chunk stores
/// `(min: f32, scale: f32)` followed by one byte per element, with
/// `x ≈ min + scale * q`, `q ∈ [0, 255]`, `scale = (max - min) / 255`.
///
/// Wire cost: `n + 8 * ceil(n / 256)` bytes — ~3.88× smaller than raw
/// f32. Error bound (per element): half a quantization step,
/// `(chunk_max - chunk_min) / 255 / 2`, plus f32 rounding slop (see
/// [`Codec::error_bound`]).
pub struct Q8;

/// Encode one chunk in place onto `out`. Quantizer arithmetic runs in
/// f64 so a chunk spanning huge magnitudes (where `max - min` overflows
/// f32 to inf) still yields a finite scale and finite reconstructions —
/// a silent-NaN here would poison every peer's aggregation.
fn encode_chunk(chunk: &[f32], out: &mut Vec<u8>) {
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in chunk {
        min = min.min(x);
        max = max.max(x);
    }
    if !min.is_finite() || !max.is_finite() {
        // Degenerate chunk (empty or non-finite): store a zero range so
        // decode reproduces the min for every slot.
        min = if min.is_finite() { min } else { 0.0 };
        max = min;
    }
    // f64 range never overflows for finite f32 inputs; the f32 scale is
    // finite (<= f32::MAX / 255 * 2).
    let scale = ((max as f64 - min as f64) / 255.0) as f32;
    out.extend_from_slice(&min.to_le_bytes());
    out.extend_from_slice(&scale.to_le_bytes());
    for &x in chunk {
        let q = if scale > 0.0 {
            ((x as f64 - min as f64) / scale as f64).round().clamp(0.0, 255.0) as u8
        } else {
            0
        };
        out.push(q);
    }
}

/// Quantize a full vector (shared with [`super::DeltaQ8`], which runs
/// the same quantizer over a delta vector).
pub(crate) fn q8_encode(xs: &[f32]) -> Vec<u8> {
    let chunks = xs.len().div_ceil(Q8_CHUNK);
    let mut out = Vec::with_capacity(xs.len() + 8 * chunks);
    for chunk in xs.chunks(Q8_CHUNK) {
        encode_chunk(chunk, &mut out);
    }
    out
}

/// Dequantize `n` elements from a [`q8_encode`] payload.
pub(crate) fn q8_decode(payload: &[u8], n: usize) -> Result<Vec<f32>> {
    let chunks = n.div_ceil(Q8_CHUNK);
    let want = n
        .checked_add(chunks.checked_mul(8).ok_or_else(|| anyhow::anyhow!("q8 size overflow"))?)
        .ok_or_else(|| anyhow::anyhow!("q8 size overflow"))?;
    if payload.len() != want {
        bail!("q8 payload is {} bytes, want {} for {} elements", payload.len(), want, n);
    }
    let mut out = Vec::with_capacity(n);
    let mut at = 0usize;
    let mut remaining = n;
    while remaining > 0 {
        let take = remaining.min(Q8_CHUNK);
        let min = f32::from_le_bytes(payload[at..at + 4].try_into().unwrap());
        let scale = f32::from_le_bytes(payload[at + 4..at + 8].try_into().unwrap());
        if !min.is_finite() || !scale.is_finite() || scale < 0.0 {
            bail!("q8 chunk header is not a finite (min, scale >= 0) pair");
        }
        at += 8;
        for &q in &payload[at..at + take] {
            // f64 keeps min + scale * 255 finite even for chunks spanning
            // the full f32 range (mirrors the encoder's arithmetic)
            out.push((min as f64 + scale as f64 * q as f64) as f32);
        }
        at += take;
        remaining -= take;
    }
    Ok(out)
}

/// Documented per-element bound for [`q8_encode`]: half a quantization
/// step on the widest chunk, with slop for the f32 arithmetic of the
/// quantizer itself (a few ulps of the chunk magnitude, covered by the
/// relative term, plus an absolute floor for near-zero ranges).
pub(crate) fn q8_error_bound(xs: &[f32]) -> f32 {
    let mut worst = 0.0f32;
    let mut mag = 0.0f32;
    for chunk in xs.chunks(Q8_CHUNK) {
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        for &x in chunk {
            min = min.min(x);
            max = max.max(x);
        }
        if min.is_finite() && max.is_finite() {
            worst = worst.max(((max as f64 - min as f64) / 255.0 * 0.5) as f32);
            mag = mag.max(min.abs().max(max.abs()));
        }
    }
    worst * (1.0 + 1e-3) + mag * 8.0 * f32::EPSILON + f32::EPSILON
}

impl Codec for Q8 {
    fn kind(&self) -> CodecKind {
        CodecKind::Q8
    }

    fn encode(&self, params: &FlatParams, _base: Option<&FlatParams>) -> Vec<u8> {
        q8_encode(params.as_slice())
    }

    fn decode(&self, payload: &[u8], n: usize, _base: Option<&FlatParams>) -> Result<FlatParams> {
        Ok(FlatParams(q8_decode(payload, n)?))
    }

    fn error_bound(&self, params: &FlatParams, _base: Option<&FlatParams>) -> f32 {
        q8_error_bound(params.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_size_is_about_a_quarter_of_raw() {
        let p = FlatParams((0..10_000).map(|i| (i as f32).sin()).collect());
        let enc = Q8.encode(&p, None);
        assert_eq!(enc.len(), 10_000 + 8 * 40);
        assert!((p.len() * 4) as f64 / enc.len() as f64 > 3.8);
    }

    #[test]
    fn uniform_chunk_is_lossless() {
        let p = FlatParams(vec![3.25; 600]);
        let dec = Q8.decode(&Q8.encode(&p, None), 600, None).unwrap();
        assert_eq!(dec.0, p.0, "zero-range chunks reproduce exactly");
    }

    #[test]
    fn respects_error_bound_on_varied_data() {
        let p = FlatParams(
            (0..5_000)
                .map(|i| ((i as f32) * 0.37).sin() * (1.0 + (i % 7) as f32))
                .collect(),
        );
        let bound = Q8.error_bound(&p, None);
        let dec = Q8.decode(&Q8.encode(&p, None), p.len(), None).unwrap();
        assert!(bound > 0.0);
        assert!(
            p.max_abs_diff(&dec) <= bound,
            "max err {} > bound {}",
            p.max_abs_diff(&dec),
            bound
        );
    }

    #[test]
    fn full_f32_range_chunk_stays_finite() {
        // max - min overflows f32 to inf here; the f64 quantizer path
        // must still produce a finite scale and finite reconstructions
        // (a silent NaN would poison every peer's aggregation).
        let mut xs = vec![0.0f32; 300];
        xs[0] = 3.0e38;
        xs[1] = -3.0e38;
        let p = FlatParams(xs);
        let enc = Q8.encode(&p, None);
        let dec = Q8.decode(&enc, 300, None).unwrap();
        assert!(dec.all_finite(), "reconstruction must never contain NaN/inf");
        let bound = Q8.error_bound(&p, None);
        assert!(bound.is_finite());
        assert!(p.max_abs_diff(&dec) <= bound);
    }

    #[test]
    fn non_finite_chunk_header_is_an_error() {
        let p = FlatParams(vec![1.0; 10]);
        let mut enc = Q8.encode(&p, None);
        enc[4..8].copy_from_slice(&f32::NAN.to_le_bytes()); // scale slot
        assert!(Q8.decode(&enc, 10, None).is_err());
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let p = FlatParams(vec![1.0; 300]);
        let enc = Q8.encode(&p, None);
        assert!(Q8.decode(&enc[..enc.len() - 1], 300, None).is_err());
        assert!(Q8.decode(&enc, 299, None).is_err());
    }

    #[test]
    fn empty_vector_round_trips() {
        let p = FlatParams(vec![]);
        let enc = Q8.encode(&p, None);
        assert!(enc.is_empty());
        assert!(Q8.decode(&enc, 0, None).unwrap().is_empty());
    }
}
