//! Wire-path contract tests: unaligned wire buffers, the bulk
//! little-endian slab write, and the zero-copy pull allocation budget.
//!
//! The codec layer promises (see `rust/src/tensor/codec.rs` and
//! ARCHITECTURE.md §11):
//!
//! * blob bytes decode bit-identically at **any** buffer alignment —
//!   the borrowed fast path and the misaligned copy fallback are
//!   indistinguishable except in allocation count;
//! * the v1 payload slab write is byte-for-byte the old per-element
//!   `to_le_bytes` loop;
//! * a raw pull (parse + materialize params) performs at most one
//!   allocation.
//!
//! The allocation assertions use a counting global allocator with a
//! thread-local counter, so parallel test threads don't pollute each
//! other's counts.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use fedless::compress::{Codec, CodecKind, CodecState};
use fedless::par::ChunkPool;
use fedless::tensor::codec::{
    decode_blob, encode_blob, encode_blob_v2, read_blob, view_raw_payload, BlobMeta, HEADER_LEN,
};
use fedless::tensor::FlatParams;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the counter update has
// no side effect on allocation behavior (Cell<u64> TLS access never
// allocates — no Drop, so no destructor registration).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations performed by `f` on this thread.
fn allocs_in<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = ALLOCS.with(|c| c.get());
    let r = f();
    (ALLOCS.with(|c| c.get()) - before, r)
}

fn meta() -> BlobMeta {
    BlobMeta { node_id: 2, round: 9, epoch: 4, n_examples: 1280 }
}

fn training_like(n: usize) -> FlatParams {
    FlatParams((0..n).map(|i| ((i as f32) * 0.071).sin() * 0.8).collect())
}

/// 8-byte-aligned byte storage (backed by `Vec<u64>`), so placing a blob
/// at byte offset `o` gives its payload a *known* alignment — `Vec<u8>`
/// alone doesn't let a test control the base address.
struct AlignedBuf {
    storage: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    /// Place `bytes` at byte offset `offset` from an 8-aligned base.
    fn place(bytes: &[u8], offset: usize) -> AlignedBuf {
        let len = offset + bytes.len();
        let mut buf = AlignedBuf { storage: vec![0u64; len.div_ceil(8)], len };
        buf.as_mut()[offset..].copy_from_slice(bytes);
        buf
    }

    fn as_mut(&mut self) -> &mut [u8] {
        let n = self.len;
        // SAFETY: the u64 storage covers n bytes; u8 has no alignment
        // or validity requirements.
        unsafe { std::slice::from_raw_parts_mut(self.storage.as_mut_ptr() as *mut u8, n) }
    }

    /// The placed bytes, starting at `offset` from the 8-aligned base.
    fn slice(&self, offset: usize) -> &[u8] {
        // SAFETY: as above, shared view.
        let all =
            unsafe { std::slice::from_raw_parts(self.storage.as_ptr() as *const u8, self.len) };
        &all[offset..]
    }
}

fn bits(p: &FlatParams) -> Vec<u32> {
    p.as_slice().iter().map(|x| x.to_bits()).collect()
}

#[test]
fn every_codec_decodes_bit_identically_at_every_alignment() {
    let p = training_like(1000);
    for kind in [
        CodecKind::None, // exercised as a raw-payload v2 blob
        CodecKind::Q8,
        CodecKind::TopK { frac: 0.1 },
        CodecKind::DeltaQ8, // no base set: self-contained delta blob
    ] {
        let codec = kind.build();
        let payload = codec.encode(&p, None);
        let blob = encode_blob_v2(&meta(), kind.id(), 0, p.len(), &payload);
        let state = CodecState::new(kind);
        let reference = state
            .decode_wire(&read_blob(&blob).unwrap(), ChunkPool::sequential())
            .unwrap();
        for offset in 0..8 {
            let buf = AlignedBuf::place(&blob, offset);
            let wire = read_blob(buf.slice(offset)).unwrap();
            let dec = state.decode_wire(&wire, ChunkPool::sequential()).unwrap();
            assert_eq!(
                bits(&dec),
                bits(&reference),
                "{} at offset {offset} must decode bit-identically",
                kind.label()
            );
        }
    }
    // and the v1 format through its own entry point
    let blob = encode_blob(&meta(), &p);
    let reference = decode_blob(&blob).unwrap().1;
    for offset in 0..8 {
        let buf = AlignedBuf::place(&blob, offset);
        let (m, dec) = decode_blob(buf.slice(offset)).unwrap();
        assert_eq!(m, meta(), "v1 meta at offset {offset}");
        assert_eq!(bits(&dec), bits(&reference), "v1 at offset {offset}");
    }
}

#[test]
fn raw_view_borrows_when_aligned_and_copies_when_not() {
    let p = training_like(256);
    let blob = encode_blob(&meta(), &p);
    assert_eq!(HEADER_LEN % 4, 0, "payload alignment is the buffer base's");
    for offset in 0..8 {
        let buf = AlignedBuf::place(&blob, offset);
        let wire = read_blob(buf.slice(offset)).unwrap();
        let view = view_raw_payload(wire.payload, wire.uncomp_len).unwrap();
        if cfg!(target_endian = "little") {
            assert_eq!(
                view.is_borrowed(),
                offset % 4 == 0,
                "offset {offset}: borrow exactly when the payload is 4-aligned"
            );
        } else {
            assert!(!view.is_borrowed(), "big-endian never borrows");
        }
        assert_eq!(
            view.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            p.as_slice().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "offset {offset}: values identical through either path"
        );
    }
}

#[test]
fn bulk_slab_write_is_byte_identical_to_the_old_loop() {
    // Adversarial bit patterns: NaNs (quiet and signaling patterns),
    // signed zeros, denormals, infinities — the slab write must move
    // them untouched, exactly like the replaced per-element loop.
    let xs = vec![
        f32::NAN,
        f32::from_bits(0xFFC0_0001),
        f32::from_bits(0x7F80_0001),
        -0.0,
        0.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::from_bits(1),
        f32::MIN_POSITIVE,
        3.25e37,
        -1.0e-40,
    ];
    let p = FlatParams(xs.clone());
    let blob = encode_blob(&meta(), &p);
    // reference: the old encode loop, reconstructed
    let mut old = blob[..HEADER_LEN].to_vec();
    for x in &xs {
        old.extend_from_slice(&x.to_le_bytes());
    }
    assert_eq!(blob, old, "v1 payload bytes must match the old per-element loop");
    // the Raw codec shares the slab write
    let raw_payload = CodecKind::None.build().encode(&p, None);
    assert_eq!(raw_payload, old[HEADER_LEN..], "raw codec payload matches too");
}

#[test]
fn raw_pull_costs_at_most_one_allocation() {
    let p = training_like(4096);
    let blob = encode_blob(&meta(), &p);
    // warm up anyhow/TLS one-time costs outside the measured window
    let _ = decode_blob(&blob).unwrap();

    for offset in [0usize, 1] {
        let buf = AlignedBuf::place(&blob, offset);
        let slice = buf.slice(offset);

        // parse + view: zero allocations when the buffer is aligned
        // (borrowed view), exactly one when the fallback has to copy
        let (n_view, view) = allocs_in(|| {
            let wire = read_blob(slice).unwrap();
            view_raw_payload(wire.payload, wire.uncomp_len).unwrap()
        });
        let aligned_borrow = cfg!(target_endian = "little") && offset % 4 == 0;
        assert_eq!(
            n_view,
            u64::from(!aligned_borrow),
            "offset {offset}: parse+view allocation count"
        );

        // materializing params brings the total for a full pull to one
        let (n_total, params) = allocs_in(|| view.into_params());
        assert_eq!(n_view + n_total, 1, "offset {offset}: a raw pull is one allocation");
        assert_eq!(bits(&params), bits(&p));
    }
}
