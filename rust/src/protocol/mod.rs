//! The federation protocol layer — *what a node does at an epoch end*.
//!
//! The paper's two protocols (the synchronous store barrier of §3 and
//! asynchronous FedAvgAsync, Algorithm 1) used to be hard-wired into the
//! node thread body; every new federation scenario meant editing the
//! worker. This module makes the protocol a first-class, pluggable
//! object: [`FederationProtocol`] is per-node state with one hook,
//! [`FederationProtocol::after_epoch`], called by the node thread after
//! each local epoch with an [`EpochCtx`] (store + strategy + timeline)
//! and the node's current weights.
//!
//! Implementations (selected by [`ProtocolKind`], which resolves from the
//! config-level [`FederationMode`]):
//!
//! * [`LocalOnly`]   — no federation; the centralized / independent-silos
//!   baseline.
//! * [`SyncBarrier`] — push for round `r`, then **block on store change
//!   notification** ([`WeightStore::wait_for_change`]) until all K
//!   round-`r` entries exist, aggregate the identical set client-side.
//!   No sleep-polling: the barrier parks until a peer's push bumps the
//!   store version.
//! * [`AsyncHash`]   — FedAvgAsync: push, detect store change via the
//!   monotone [`WeightStore::version`] counter, pull `latest_per_node`,
//!   set `ω[k] ← w^k`, aggregate. The version token is recorded *at pull
//!   time*, so a peer push racing the aggregation is re-detected next
//!   epoch instead of being silently masked.
//! * [`Gossip`]      — each epoch pull and merge with a seeded random
//!   subset of `fanout` peers ([`gossip_peers`] is the replayable
//!   schedule): no global barrier, no full fan-in — the protocol grid's
//!   scenario-diversity proof.
//!
//! All four report what happened through [`ProtocolOutcome`] (pushes,
//! aggregations, barrier stalls), which the worker folds into its
//! [`crate::node::NodeReport`].
//!
//! Protocols build their [`crate::strategy::Contribution`]s from *store
//! entries* — including a node's own round entry. That is deliberate for
//! the adversary model: when an [`crate::store::AdversaryStore`] rewrites
//! a push, every node (the adversary included) aggregates the corrupted
//! entry it finds in the store, exactly as with a malicious client and a
//! real bucket. Robust strategies (`crate::strategy::robust`) defend at
//! this aggregation point; the protocols themselves stay attack-agnostic.

mod async_hash;
mod gossip;
mod local;
mod sync;

pub use async_hash::AsyncHash;
pub use gossip::{gossip_peers, Gossip};
pub use local::LocalOnly;
pub use sync::SyncBarrier;

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::compress::CodecState;
use crate::config::{ExperimentConfig, FederationMode};
use crate::metrics::timeline::Timeline;
use crate::par::ChunkPool;
use crate::store::{PushRequest, WeightEntry, WeightStore};
use crate::strategy::Strategy;
use crate::tensor::codec::BlobMeta;
use crate::tensor::FlatParams;
use crate::time::Clock;

/// Everything a protocol may touch while federating at an epoch end.
/// Borrowed from the node thread for the duration of one
/// [`FederationProtocol::after_epoch`] call.
pub struct EpochCtx<'a> {
    /// This node's id.
    pub node_id: usize,
    /// Total nodes in the experiment (sizes the gossip peer universe and
    /// the async `latest_per_node` fan-in).
    pub n_nodes: usize,
    /// Entries that complete this round's sync barrier — `n_nodes` under
    /// full participation, the sampled cohort size under
    /// `participation < 1` (every cohort member computes the same seeded
    /// cohort, so they agree on this fan-in without a coordinator).
    pub round_k: usize,
    /// The just-finished 0-based local epoch (doubles as the sync round).
    pub epoch: usize,
    /// Examples this node trains on per epoch (the FedAvg numerator n_k).
    pub n_examples: u64,
    /// The shared weight store.
    pub store: &'a dyn WeightStore,
    /// This node's own client-side aggregation strategy.
    pub strategy: &'a mut dyn Strategy,
    /// The node's timeline, for Wait/Aggregate span accounting.
    pub timeline: &'a mut Timeline,
    /// How long the sync barrier may wait before reporting a stall
    /// (measured on [`EpochCtx::clock`], so simulated under a virtual
    /// clock).
    pub sync_timeout: Duration,
    /// The experiment's clock: every protocol timestamp, wait deadline,
    /// and timeline span is measured on it, which is what lets a
    /// [`crate::time::VirtualClock`] run timing scenarios at CPU speed.
    pub clock: &'a dyn Clock,
    /// This node's wire codec state ([`crate::compress`]): every push
    /// goes through it (encode → wire blob → decoded reconstruction),
    /// and aggregation results feed back into it as the delta base.
    pub codec: &'a mut CodecState,
    /// The kernel pool ([`crate::par`], from the `threads` config key):
    /// protocols run every aggregation on it via
    /// [`crate::strategy::Strategy::aggregate_pooled`]. Results are
    /// bit-identical for any thread count, so `threads` is a pure
    /// wall-clock knob.
    pub pool: ChunkPool,
    /// Optional structured tracer ([`crate::trace`]). When set, the ctx
    /// helpers emit typed push/pull/aggregate events (stamped on
    /// [`EpochCtx::clock`]) as a side effect, so every protocol is traced
    /// uniformly under both the threaded and the event scheduler. `None`
    /// costs nothing.
    pub tracer: Option<&'a crate::trace::Tracer>,
}

impl EpochCtx<'_> {
    /// Deposit `params` as this node's round-`round` entry; returns the
    /// store-assigned sequence number.
    ///
    /// The push runs through the configured [`crate::compress`] codec:
    /// what lands in the store is the wire blob's *decoded
    /// reconstruction* (bit-exact under `compress = none`), the entry's
    /// [`WeightEntry::wire_bytes`] is the encoded blob size, and the
    /// node's [`crate::metrics::TrafficMeter`] records the upload.
    pub fn push_weights(&mut self, params: &FlatParams, round: u64) -> Result<u64> {
        let meta = BlobMeta {
            node_id: self.node_id as u32,
            round,
            epoch: round,
            n_examples: self.n_examples,
        };
        let (wire_bytes, stored) = self.codec.encode_for_push(&meta, params, self.pool)?;
        // Digest what actually lands in the store (the decoded
        // reconstruction), before the push consumes it.
        let digest = self.tracer.map(|_| stored.content_hash_pooled(self.pool));
        let seq = self.store.push(PushRequest {
            node_id: self.node_id,
            round,
            epoch: round,
            n_examples: self.n_examples,
            wire_bytes,
            params: Arc::new(stored),
        })?;
        self.timeline.traffic.record_push(wire_bytes);
        if let (Some(tracer), Some(digest)) = (self.tracer, digest) {
            tracer.instant(
                self.node_id,
                round,
                self.clock.now(),
                crate::trace::TraceEventKind::Push { wire_bytes, digest },
            );
        }
        Ok(seq)
    }

    /// Account downloaded entries against this node's traffic meter
    /// (each entry's encoded wire bytes). Protocols call this on every
    /// pull, including the sync barrier's incomplete-round re-pulls —
    /// the wire carried those bytes whether or not the round was ready.
    pub fn record_pull(&mut self, entries: &[WeightEntry]) {
        for e in entries {
            self.timeline.traffic.record_pull(e.wire_bytes);
        }
        if let Some(tracer) = self.tracer {
            if !entries.is_empty() {
                let wire_bytes: u64 = entries.iter().map(|e| e.wire_bytes).sum();
                tracer.instant(
                    self.node_id,
                    self.epoch as u64,
                    self.clock.now(),
                    crate::trace::TraceEventKind::Pull {
                        entries: entries.len() as u64,
                        wire_bytes,
                    },
                );
            }
        }
    }

    /// Feed an adopted aggregate back into the codec as the delta base,
    /// tagged with the newest store seq among `entries` (what
    /// [`crate::compress::DeltaQ8`] deltas the next push against).
    pub fn adopt_aggregate(&mut self, params: &FlatParams, entries: &[WeightEntry]) {
        let version = entries.iter().map(|e| e.seq).max().unwrap_or(0);
        self.codec.set_base(version, params);
        if let Some(tracer) = self.tracer {
            tracer.instant(
                self.node_id,
                self.epoch as u64,
                self.clock.now(),
                crate::trace::TraceEventKind::Aggregate {
                    digest: params.content_hash_pooled(self.pool),
                },
            );
        }
    }
}

/// What one federation step did (folded into the node report).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProtocolOutcome {
    /// Pushes performed this step.
    pub pushes: u64,
    /// Aggregations actually applied this step.
    pub aggregations: u64,
    /// Set when the sync barrier gave up waiting at this round; the node
    /// stops with [`crate::node::NodeStatus::Stalled`].
    pub stalled_at: Option<u64>,
    /// Rounds this step closed *degraded*: the sync barrier's quorum
    /// rule (`sync_quorum < 1`) aggregated a partial cohort after the
    /// soft deadline instead of stalling the node. 0 or 1 per step.
    pub degraded_rounds: u64,
}

/// One resumable federation step: either the epoch finished, or the
/// protocol needs the store to change before it can make progress.
///
/// This is the non-blocking face of the protocol layer: a blocking
/// driver (the threaded node worker) turns `Wait` into a
/// [`WeightStore::wait_for_change`] park, while the event-driven
/// executor ([`crate::sched`]) suspends the node task and re-polls it
/// when a peer's push advances the store version (or the timeout
/// deadline arrives) — same protocol state machine, no thread.
#[derive(Debug)]
pub enum EpochStep {
    /// The epoch's federation completed (or stalled) with this outcome.
    Done(ProtocolOutcome),
    /// No progress until the store version exceeds `since` or `timeout`
    /// of clock time elapses; then poll again.
    Wait {
        /// Store version token observed *before* the blocked predicate
        /// was checked (the lost-wakeup-free subscription protocol).
        since: u64,
        /// Remaining clock time before the protocol will declare a stall.
        timeout: Duration,
    },
}

/// A federation protocol: per-node state plus the epoch-end hook.
///
/// Implementations own whatever per-node state the scenario needs (the
/// async change token, sampling RNG, gossip seed, …); one instance is
/// built per node via [`ProtocolKind::build`] and lives for the whole
/// trial.
///
/// The two hooks are mutual defaults: [`FederationProtocol::after_epoch`]
/// drives [`FederationProtocol::poll_epoch`] to completion by blocking on
/// the store between polls, and `poll_epoch` falls back to a one-shot
/// `after_epoch` for protocols that never block. **Every implementation
/// must override at least one of the two** — non-blocking protocols
/// (local / async / gossip) implement `after_epoch`, blocking ones (the
/// sync barrier) implement `poll_epoch` so the same state machine serves
/// both the threaded and the event-driven scheduler.
pub trait FederationProtocol: Send {
    /// Canonical lowercase protocol name (matches
    /// [`FederationMode::name`]).
    fn name(&self) -> &'static str;

    /// Federate after a finished local epoch, possibly replacing
    /// `params` with aggregated weights (the node's optimizer moments
    /// stay local, as in the paper: only weights travel).
    ///
    /// Default: poll [`FederationProtocol::poll_epoch`], parking on
    /// [`WeightStore::wait_for_change`] whenever it asks to wait — the
    /// exact store call sequence the pre-poll blocking implementations
    /// made.
    fn after_epoch(
        &mut self,
        ctx: &mut EpochCtx<'_>,
        params: &mut FlatParams,
    ) -> Result<ProtocolOutcome> {
        loop {
            match self.poll_epoch(ctx, params)? {
                EpochStep::Done(out) => return Ok(out),
                EpochStep::Wait { since, timeout } => {
                    ctx.store.wait_for_change(since, timeout)?;
                }
            }
        }
    }

    /// One non-blocking federation step. Returns
    /// [`EpochStep::Wait`] instead of blocking; callers re-poll after
    /// the store changes (or the timeout elapses). Protocol state must
    /// survive across polls of the same epoch.
    ///
    /// Default: delegate to [`FederationProtocol::after_epoch`] and wrap
    /// the outcome — correct for protocols that never block.
    fn poll_epoch(
        &mut self,
        ctx: &mut EpochCtx<'_>,
        params: &mut FlatParams,
    ) -> Result<EpochStep> {
        self.after_epoch(ctx, params).map(EpochStep::Done)
    }
}

/// Protocol selector — the protocol-layer resolution of the config-level
/// [`FederationMode`] (`ProtocolKind::from(cfg.mode)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtocolKind {
    /// No federation ([`LocalOnly`]).
    Local,
    /// Notification-based store barrier each round ([`SyncBarrier`]).
    Sync,
    /// FedAvgAsync change-detection protocol ([`AsyncHash`]).
    Async,
    /// Seeded random peer-subset merging ([`Gossip`]).
    Gossip {
        /// Peers pulled per epoch (clamped to `n_nodes - 1` at runtime).
        fanout: usize,
    },
}

impl From<FederationMode> for ProtocolKind {
    fn from(mode: FederationMode) -> ProtocolKind {
        match mode {
            FederationMode::Local => ProtocolKind::Local,
            FederationMode::Sync => ProtocolKind::Sync,
            FederationMode::Async => ProtocolKind::Async,
            FederationMode::Gossip { fanout } => ProtocolKind::Gossip { fanout },
        }
    }
}

impl ProtocolKind {
    /// Canonical lowercase name (matches [`FederationMode::name`]).
    pub fn name(self) -> &'static str {
        match self {
            ProtocolKind::Local => "local",
            ProtocolKind::Sync => "sync",
            ProtocolKind::Async => "async",
            ProtocolKind::Gossip { .. } => "gossip",
        }
    }

    /// Instantiate this node's protocol state for one trial.
    pub fn build(self, node_id: usize, cfg: &ExperimentConfig) -> Box<dyn FederationProtocol> {
        match self {
            ProtocolKind::Local => Box::new(LocalOnly),
            ProtocolKind::Sync => Box::new(SyncBarrier::with_quorum(cfg.sync_quorum)),
            ProtocolKind::Async => Box::new(AsyncHash::new(cfg.sample_prob, cfg.seed, node_id)),
            ProtocolKind::Gossip { fanout } => Box::new(Gossip::new(fanout, cfg.seed)),
        }
    }
}

#[cfg(test)]
pub(crate) mod protocol_tests {
    //! Protocol-level harness: drive protocols directly against an
    //! in-process store, no artifacts or PJRT runtime required.

    use super::*;
    use crate::strategy::StrategyKind;
    use crate::time::RealClock;

    /// One simulated node: protocol + strategy + timeline + weights.
    pub struct TestNode {
        /// The node id the harness drives.
        pub node_id: usize,
        /// The node's protocol instance under test.
        pub protocol: Box<dyn FederationProtocol>,
        /// The node's own strategy (FedAvg).
        pub strategy: Box<dyn Strategy>,
        /// Timeline sink for span accounting.
        pub timeline: Timeline,
        /// Current weights.
        pub params: FlatParams,
        /// The clock this node's epochs run on.
        pub clock: Arc<dyn Clock>,
        /// Wire codec state (from `cfg.compress`).
        pub codec: CodecState,
    }

    impl TestNode {
        pub fn new(node_id: usize, cfg: &ExperimentConfig) -> TestNode {
            TestNode::with_clock(node_id, cfg, RealClock::shared())
        }

        pub fn with_clock(
            node_id: usize,
            cfg: &ExperimentConfig,
            clock: Arc<dyn Clock>,
        ) -> TestNode {
            TestNode {
                node_id,
                protocol: ProtocolKind::from(cfg.mode).build(node_id, cfg),
                strategy: StrategyKind::FedAvg.build(),
                timeline: Timeline::new(node_id),
                // distinct starting weights per node so averaging is visible
                params: FlatParams(vec![node_id as f32 * 10.0; 4]),
                clock,
                codec: CodecState::new(cfg.compress),
            }
        }

        pub fn epoch(
            &mut self,
            store: &dyn WeightStore,
            n_nodes: usize,
            epoch: usize,
            sync_timeout: Duration,
        ) -> ProtocolOutcome {
            let mut ctx = EpochCtx {
                node_id: self.node_id,
                n_nodes,
                round_k: n_nodes,
                epoch,
                n_examples: 100,
                store,
                strategy: self.strategy.as_mut(),
                timeline: &mut self.timeline,
                sync_timeout,
                clock: self.clock.as_ref(),
                codec: &mut self.codec,
                pool: ChunkPool::sequential(),
                tracer: None,
            };
            self.protocol.after_epoch(&mut ctx, &mut self.params).unwrap()
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::protocol_tests::TestNode;
    use super::*;
    use crate::store::MemoryStore;

    #[test]
    fn kind_resolves_from_mode() {
        assert_eq!(ProtocolKind::from(FederationMode::Sync), ProtocolKind::Sync);
        assert_eq!(
            ProtocolKind::from(FederationMode::Gossip { fanout: 3 }),
            ProtocolKind::Gossip { fanout: 3 }
        );
        for mode in [
            FederationMode::Local,
            FederationMode::Sync,
            FederationMode::Async,
            FederationMode::Gossip { fanout: 2 },
        ] {
            assert_eq!(ProtocolKind::from(mode).name(), mode.name());
            let cfg = ExperimentConfig { mode, ..Default::default() };
            assert_eq!(ProtocolKind::from(mode).build(0, &cfg).name(), mode.name());
        }
    }

    #[test]
    fn local_only_never_touches_the_store() {
        let cfg = ExperimentConfig { mode: FederationMode::Local, ..Default::default() };
        let store = MemoryStore::new();
        let mut node = TestNode::new(0, &cfg);
        for epoch in 0..3 {
            let out = node.epoch(&store, 1, epoch, Duration::from_secs(1));
            assert_eq!(out, ProtocolOutcome::default());
        }
        assert_eq!(store.push_count(), 0);
        assert_eq!(node.params.0, vec![0.0; 4]);
    }

    #[test]
    fn sync_barrier_two_threads_converge_bit_identically() {
        // Two real threads against one store: the notification-based
        // barrier must hand both nodes the same round set every epoch,
        // so their weights stay bit-identical.
        let cfg = ExperimentConfig {
            mode: FederationMode::Sync,
            n_nodes: 2,
            ..Default::default()
        };
        let store: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let run = |node_id: usize| {
            let store = Arc::clone(&store);
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let mut node = TestNode::new(node_id, &cfg);
                for epoch in 0..3 {
                    let out = node.epoch(&*store, 2, epoch, Duration::from_secs(30));
                    assert_eq!(out.pushes, 1);
                    assert_eq!(out.aggregations, 1);
                    assert_eq!(out.stalled_at, None);
                }
                node.params
            })
        };
        let (a, b) = (run(0), run(1));
        let (pa, pb) = (a.join().unwrap(), b.join().unwrap());
        assert_eq!(pa.0, pb.0, "sync nodes must end bit-identical");
        // equal n_examples: round 0 average of [0,0,0,0] and [10,10,10,10]
        // is 5s, and identical inputs stay fixed thereafter.
        assert_eq!(pa.0, vec![5.0; 4]);
    }

    #[test]
    fn sync_barrier_stalls_cleanly_without_peers() {
        let cfg = ExperimentConfig {
            mode: FederationMode::Sync,
            n_nodes: 2,
            ..Default::default()
        };
        let store = MemoryStore::new();
        let mut node = TestNode::new(0, &cfg);
        let t = std::time::Instant::now();
        let out = node.epoch(&store, 2, 0, Duration::from_millis(60));
        assert!(t.elapsed() >= Duration::from_millis(50), "must wait out the timeout");
        assert_eq!(out.stalled_at, Some(0));
        assert_eq!(out.pushes, 1);
        assert_eq!(out.aggregations, 0);
        assert_eq!(out.degraded_rounds, 0, "a full-quorum barrier never degrades");
    }

    #[test]
    fn sync_quorum_closes_round_degraded_instead_of_stalling() {
        // 1 of 2 nodes present, quorum 0.5 -> quorum_k = 1: the round
        // must close on the partial set at the soft deadline (timeout/2)
        // rather than stalling at the hard timeout.
        let cfg = ExperimentConfig {
            mode: FederationMode::Sync,
            n_nodes: 2,
            sync_quorum: 0.5,
            ..Default::default()
        };
        let store = MemoryStore::new();
        let mut node = TestNode::new(1, &cfg);
        let t = std::time::Instant::now();
        let out = node.epoch(&store, 2, 0, Duration::from_millis(100));
        let dt = t.elapsed();
        assert!(dt >= Duration::from_millis(45), "must wait to the soft deadline, got {dt:?}");
        assert!(dt < Duration::from_millis(95), "must not ride out the hard timeout, got {dt:?}");
        assert_eq!(out.stalled_at, None, "quorum demotes the stall");
        assert_eq!(out.degraded_rounds, 1);
        assert_eq!(out.pushes, 1);
        assert_eq!(out.aggregations, 1, "the partial set is aggregated");
        // aggregating own entry alone keeps own weights
        assert_eq!(node.params.0, vec![10.0; 4]);
    }

    #[test]
    fn sync_quorum_still_stalls_below_quorum() {
        // quorum 0.9 of k = 3 -> quorum_k = 3: one node alone never
        // reaches it, so the hard timeout still stalls.
        let cfg = ExperimentConfig {
            mode: FederationMode::Sync,
            n_nodes: 3,
            sync_quorum: 0.9,
            ..Default::default()
        };
        let store = MemoryStore::new();
        let mut node = TestNode::new(0, &cfg);
        let out = node.epoch(&store, 3, 0, Duration::from_millis(60));
        assert_eq!(out.stalled_at, Some(0));
        assert_eq!(out.degraded_rounds, 0);
    }

    #[test]
    fn sync_quorum_full_round_is_not_degraded() {
        // both nodes arrive promptly: a quorum barrier behaves exactly
        // like the full barrier, no degraded count
        let cfg = ExperimentConfig {
            mode: FederationMode::Sync,
            n_nodes: 2,
            sync_quorum: 0.5,
            ..Default::default()
        };
        let store: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let run = |node_id: usize| {
            let store = Arc::clone(&store);
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let mut node = TestNode::new(node_id, &cfg);
                let out = node.epoch(&*store, 2, 0, Duration::from_secs(30));
                assert_eq!(out.degraded_rounds, 0, "complete rounds are never degraded");
                assert_eq!(out.stalled_at, None);
                node.params
            })
        };
        let (a, b) = (run(0), run(1));
        let (pa, pb) = (a.join().unwrap(), b.join().unwrap());
        assert_eq!(pa.0, pb.0);
        assert_eq!(pa.0, vec![5.0; 4]);
    }
}
