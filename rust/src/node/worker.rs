//! The node thread body: spawn one OS thread per node and drive its
//! [`NodeRunner`] state machine to completion.
//!
//! The node lifecycle itself (training, federation, crash injection,
//! participation, metrics) lives in [`super::runner::NodeRunner`] and is
//! shared with the event scheduler ([`crate::sched`]); this file owns
//! only the *threaded* concerns: reserving the clock participant slot,
//! loading a per-thread PJRT engine (the paper simulated clients with
//! Python threads; real threads + isolated runtimes are strictly closer
//! to independent processes, §5), the start barrier, and turning
//! [`StepOutcome::Wait`] into a blocking
//! [`crate::store::WeightStore::wait_for_change`] park.
//!
//! All delays, timeouts, and timeline stamps go through the experiment's
//! [`crate::time::Clock`]: under a virtual clock the straggler
//! `node_delays_ms` sleeps consume *simulated* time, so a delay grid
//! runs at CPU speed while the reported timelines stay faithful.

use std::sync::Arc;
use std::time::Duration;

use crate::config::ExperimentConfig;
use crate::data::BatchLoader;
use crate::metrics::timeline::Timeline;
use crate::metrics::RunLogger;
use crate::runtime::{Engine, Manifest, ModelBundle};
use crate::sched::{ParticipationPlan, StepOutcome, Task};
use crate::store::WeightStore;
use crate::strategy::Strategy;
use crate::time::{Clock, ParticipantGuard};

use super::runner::NodeRunner;
use super::{NodeHandle, NodeReport, NodeStatus};

/// Everything a node thread needs (moved into the thread).
pub struct NodeCtx {
    /// This node's id (also its index into per-node config vectors).
    pub node_id: usize,
    /// The experiment configuration (shared, read-only).
    pub cfg: Arc<ExperimentConfig>,
    /// Artifact manifest for loading the model bundle.
    pub manifest: Arc<Manifest>,
    /// The weight store shared by all nodes of the experiment.
    pub store: Arc<dyn WeightStore>,
    /// This node's own aggregation strategy instance (client-side state).
    pub strategy: Box<dyn Strategy>,
    /// Batch loader over this node's data shard.
    pub loader: BatchLoader,
    /// The experiment's shared clock (timeline origin, straggler delays,
    /// barrier timeouts).
    pub clock: Arc<dyn Clock>,
    /// The experiment's shared participation schedule (cohort sampling +
    /// availability traces; one instance so the cohort cache is computed
    /// once per round, not once per node per round).
    pub plan: Arc<ParticipationPlan>,
    /// Shared start barrier so all nodes begin epoch 0 together.
    pub start: Arc<std::sync::Barrier>,
    /// Optional shared run logger (CSV metrics + JSONL events).
    pub logger: Option<Arc<RunLogger>>,
    /// Optional shared structured tracer ([`crate::trace`]): typed
    /// train/push/pull/aggregate events stamped on the experiment clock.
    pub tracer: Option<Arc<crate::trace::Tracer>>,
}

/// Spawn the node thread.
pub fn spawn_node(ctx: NodeCtx) -> NodeHandle {
    spawn_node_with(ctx, |builder, body| builder.spawn(body)).expect("spawn node thread")
}

/// [`spawn_node`] with the actual thread spawn injected — the seam that
/// lets tests exercise the spawn-failure path without exhausting real
/// OS threads.
pub(crate) fn spawn_node_with<S>(ctx: NodeCtx, spawn: S) -> std::io::Result<NodeHandle>
where
    S: FnOnce(
        std::thread::Builder,
        Box<dyn FnOnce() -> NodeReport + Send + 'static>,
    ) -> std::io::Result<std::thread::JoinHandle<NodeReport>>,
{
    let node_id = ctx.node_id;
    let clock = Arc::clone(&ctx.clock);
    // Register with the clock *before* the thread exists: a virtual
    // clock must know every participant up front, or it could advance
    // simulated time while later nodes are still spawning.
    clock.enter();
    let builder = std::thread::Builder::new().name(format!("fed-node-{node_id}"));
    match spawn(builder, Box::new(move || run_node(ctx))) {
        Ok(join) => Ok(NodeHandle { node_id, join }),
        Err(e) => {
            // The reserved slot belongs to a thread that will never
            // attach: release it, or a virtual clock's advance quorum
            // waits forever and every surviving node hangs.
            clock.exit();
            Err(e)
        }
    }
}

/// A `Failed` report for a node that never got a runner off the ground.
fn failed_report(node_id: usize, err: &anyhow::Error) -> NodeReport {
    NodeReport {
        node_id,
        status: NodeStatus::Failed(format!("{err:#}")),
        epochs_done: 0,
        final_params: None,
        n_examples_per_epoch: 0,
        epoch_losses: vec![],
        epoch_accs: vec![],
        aggregations: 0,
        pushes: 0,
        timeline: Timeline::new(node_id),
        train_time: Duration::ZERO,
        wait_time: Duration::ZERO,
        injected_faults: 0,
        store_retries: 0,
        store_give_ups: 0,
        degraded_rounds: 0,
        restarts: 0,
    }
}

fn run_node(ctx: NodeCtx) -> NodeReport {
    // Adopt the registration made by spawn_node; dropping the guard
    // deregisters on every exit path (completion, crash, error, panic),
    // so a dead node never freezes a virtual clock.
    let _participant = ParticipantGuard::adopt(Arc::clone(&ctx.clock));
    let NodeCtx {
        node_id,
        cfg,
        manifest,
        store,
        strategy,
        loader,
        clock,
        plan,
        start,
        logger,
        tracer,
    } = ctx;

    // Engine + bundle are per-thread (the PJRT client is not Send); an
    // unknown model is a hard error here, never a silently wrong default.
    let built = (|| -> anyhow::Result<ModelBundle> {
        let info = manifest.model(&cfg.model)?.clone();
        let engine = Engine::new()?;
        ModelBundle::load(&engine, &info)
    })();
    let bundle = match built {
        Ok(b) => b,
        Err(e) => return failed_report(node_id, &e),
    };
    let mut runner = match NodeRunner::new(
        node_id,
        cfg,
        Arc::clone(&store),
        Arc::clone(&clock),
        logger,
        plan,
        strategy,
        loader,
        &bundle,
        tracer,
    ) {
        Ok(r) => r,
        Err(e) => return failed_report(node_id, &e),
    };

    start.wait();
    loop {
        match runner.step() {
            StepOutcome::Yield => {}
            StepOutcome::Wait { since, timeout } => {
                // The blocking twin of the event executor's parked task:
                // wake when the store version moves past `since` (or the
                // protocol's timeout budget elapses), then re-poll.
                if let Err(e) = store.wait_for_change(since, timeout) {
                    runner.fail(&e);
                    break;
                }
            }
            StepOutcome::Done => break,
        }
    }
    runner.into_report()
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;
    use std::path::PathBuf;
    use std::time::{Duration, Instant};

    use crate::data::{BatchLoader, DataSource, DatasetKind, Split, SynthDataset};
    use crate::sched::{AvailabilitySpec, ParticipationPlan};
    use crate::store::MemoryStore;
    use crate::strategy::StrategyKind;
    use crate::time::VirtualClock;

    use super::*;

    fn test_ctx(clock: Arc<dyn Clock>) -> NodeCtx {
        let cfg = Arc::new(ExperimentConfig::default());
        // an empty manifest is fine: the failing-spawn seam never runs
        // the thread body, so no model is ever looked up
        let manifest = Arc::new(Manifest {
            dir: PathBuf::new(),
            use_pallas: false,
            chunk: 256,
            models: BTreeMap::new(),
            agg: BTreeMap::new(),
        });
        let ds = Arc::new(SynthDataset::new(DatasetKind::Mnist, 0, 16, 4));
        let loader = BatchLoader::new(
            DataSource::Image { ds, split: Split::Train },
            (0..16).collect(),
            4,
            0,
        );
        NodeCtx {
            node_id: 0,
            plan: Arc::new(ParticipationPlan::new(
                1.0,
                AvailabilitySpec::None,
                cfg.seed,
                cfg.n_nodes,
            )),
            cfg,
            manifest,
            store: Arc::new(MemoryStore::new()),
            strategy: StrategyKind::FedAvg.build(),
            loader,
            clock,
            start: Arc::new(std::sync::Barrier::new(1)),
            logger: None,
            tracer: None,
        }
    }

    /// The participant-slot leak: `spawn_node` reserves a VirtualClock
    /// slot before spawning, and a failed spawn must release it — or the
    /// never-attaching ghost participant freezes the advance quorum and
    /// every other node's sleep hangs forever.
    #[test]
    fn failed_spawn_releases_its_clock_slot() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let ctx = test_ctx(Arc::clone(&clock));
        let err = spawn_node_with(ctx, |_builder, _body| {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "injected spawn failure"))
        });
        assert!(err.is_err(), "the seam's failure must propagate");

        // Behavioral quorum check: with the failed node's slot released,
        // a surviving participant is the *only* registrant, so its sleep
        // advances simulated time immediately. With the leaked slot it
        // would block forever (the pre-fix hang).
        let t_real = Instant::now();
        clock.enter();
        clock.attach();
        clock.sleep(Duration::from_secs(3600));
        clock.detach();
        clock.exit();
        assert!(
            t_real.elapsed() < Duration::from_secs(5),
            "survivor's sleep must complete in simulated time; the leaked \
             slot would have hung the quorum (took {:?})",
            t_real.elapsed()
        );
        assert!(clock.now() >= Duration::from_secs(3600));
    }

    /// The happy path through the seam still spawns a real thread and
    /// keeps the slot paired with it.
    #[test]
    fn successful_spawn_still_runs_the_node() {
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        let ctx = test_ctx(Arc::clone(&clock));
        let handle = spawn_node_with(ctx, |builder, body| builder.spawn(body)).unwrap();
        let report = handle.wait();
        // no artifacts in unit-test environments: the node fails at
        // bundle load but must still deregister (join returns, and a
        // follow-up sleep advances)
        assert!(matches!(report.status, NodeStatus::Failed(_)) || report.epochs_done > 0);
        clock.enter();
        clock.attach();
        clock.sleep(Duration::from_millis(10));
        clock.detach();
        clock.exit();
    }
}
