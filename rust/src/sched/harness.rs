//! Artifact-free trial harness for the event executor — the
//! protocol-level twin of the threaded `run_sim` harness in
//! `rust/tests/timing.rs`.
//!
//! Each simulated node is a [`Task`] that per epoch: checks its crash
//! and participation schedule, "trains" by sleeping its per-node delay
//! on the [`TaskClock`], then drives its protocol's
//! [`crate::protocol::FederationProtocol::poll_epoch`] until the epoch
//! federates or stalls. No PJRT, no artifacts — pure protocol + store +
//! clock, which is what the conformance tests compare against the
//! threaded harness and what the 10k-client scale test runs.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::compress::{CodecKind, CodecState};
use crate::config::{ExperimentConfig, FederationMode};
use crate::metrics::timeline::{Span, SpanKind, Timeline};
use crate::protocol::{EpochCtx, EpochStep, FederationProtocol, ProtocolKind};
use crate::store::{FaultModel, FaultStore, MemoryStore, RetryPolicy, RetryStore, WeightStore};
use crate::strategy::{Strategy, StrategyKind};
use crate::tensor::FlatParams;
use crate::time::Clock;

use super::{
    AvailabilitySpec, EventExecutor, ParticipationPlan, StepOutcome, Task, TaskClock,
};

/// One executor-harness trial: `delays.len()` simulated nodes, FedAvg
/// aggregation, a fresh in-memory store on a fresh [`TaskClock`].
pub struct TrialSpec {
    /// Federation mode (drives [`ProtocolKind`]).
    pub mode: FederationMode,
    /// Per-node per-epoch training delay; its length is the fleet size.
    pub delays: Vec<Duration>,
    /// Epochs per node.
    pub epochs: usize,
    /// Sync-barrier stall timeout.
    pub sync_timeout: Duration,
    /// `(node, epoch)`: that node exits at the start of that epoch
    /// without pushing (the §4.2.1 crash scenario).
    pub crash: Option<(usize, usize)>,
    /// When set with `crash`, the crashed node restarts after this much
    /// simulated downtime, restoring weights from its own latest store
    /// entry (mirrors `crash = node@epoch:restart:<secs>` in configs).
    pub crash_restart: Option<Duration>,
    /// Store fault model: each node's store traffic goes through its own
    /// [`FaultStore`] + [`RetryStore`] stack when the model is active,
    /// exactly as [`crate::node::NodeRunner`] builds it.
    pub fault: FaultModel,
    /// Sync-barrier quorum fraction in `(0, 1]`; below 1.0 a round may
    /// close degraded after the soft deadline (see
    /// [`crate::protocol::sync`]).
    pub sync_quorum: f64,
    /// Per-round cohort fraction in `(0, 1]`.
    pub participation: f64,
    /// Availability trace.
    pub availability: AvailabilitySpec,
    /// Trial seed (cohorts, availability, gossip schedules).
    pub seed: u64,
    /// Wire codec for pushes.
    pub compress: CodecKind,
    /// Kernel pool width (the config `threads` knob): a pure wall-clock
    /// knob — results are bit-identical for any value.
    pub threads: usize,
    /// Initial weights per node (the threaded harness uses
    /// `FlatParams(vec![node_id as f32; 4])` so averaging is visible).
    pub init: fn(usize) -> FlatParams,
    /// Optional structured tracer ([`crate::trace`]): when set, each
    /// node emits train spans and push/pull/aggregate instants stamped
    /// on the trial's [`TaskClock`]. `None` (the default) costs nothing.
    pub tracer: Option<Arc<crate::trace::Tracer>>,
}

impl TrialSpec {
    /// The conformance-default spec: full participation, no crash, no
    /// compression, the threaded harness's initial weights, seed from
    /// the default config.
    pub fn new(mode: FederationMode, delays: Vec<Duration>, epochs: usize) -> TrialSpec {
        TrialSpec {
            mode,
            delays,
            epochs,
            sync_timeout: Duration::from_secs(3600),
            crash: None,
            crash_restart: None,
            fault: FaultModel::default(),
            sync_quorum: 1.0,
            participation: 1.0,
            availability: AvailabilitySpec::None,
            seed: ExperimentConfig::default().seed,
            compress: CodecKind::default(),
            threads: ExperimentConfig::default().threads,
            init: |node_id| FlatParams(vec![node_id as f32; 4]),
            tracer: None,
        }
    }
}

/// What one simulated node reports back (mirrors the threaded harness's
/// `SimNode`).
pub struct SimNodeResult {
    /// The node's id.
    pub node_id: usize,
    /// Simulated instant the node finished (completion, crash or stall).
    pub finish: Duration,
    /// The node's recorded timeline spans.
    pub spans: Vec<Span>,
    /// Final local weights.
    pub params: FlatParams,
    /// Whether the node stalled at a sync barrier.
    pub stalled: bool,
    /// Whether the node died on a store error (retry layer gave up, or
    /// no retry layer was configured to absorb the fault).
    pub failed: bool,
    /// Crash–restart recoveries this node performed.
    pub restarts: u64,
    /// Sync rounds this node closed degraded (quorum reached, full
    /// cohort not).
    pub degraded_rounds: u64,
    /// Faults its store stack injected (0 without a fault model).
    pub injected_faults: u64,
    /// Transient store failures absorbed by retry.
    pub store_retries: u64,
    /// Store operations that exhausted the retry budget.
    pub store_give_ups: u64,
    /// The node's wire-traffic accounting.
    pub traffic: crate::metrics::TrafficMeter,
}

enum Phase {
    Train,
    Federate,
}

struct SimNode {
    node_id: usize,
    cfg: Arc<ExperimentConfig>,
    store: Arc<dyn WeightStore>,
    clock: Arc<TaskClock>,
    plan: Arc<ParticipationPlan>,
    delay: Duration,
    protocol: Box<dyn FederationProtocol>,
    strategy: Box<dyn Strategy>,
    codec: CodecState,
    timeline: Timeline,
    params: FlatParams,
    epoch: usize,
    phase: Phase,
    stalled: bool,
    failed: bool,
    /// A restartable crash fires at most once (the epoch counter does
    /// not advance across the recovery, so the trigger would re-fire).
    crash_consumed: bool,
    restarts: u64,
    degraded_rounds: u64,
    /// Handle on this node's fault/retry stack for counter harvesting
    /// (present iff the spec's fault model is active).
    chaos: Option<Arc<RetryStore<FaultStore<Arc<dyn WeightStore>>>>>,
    init: fn(usize) -> FlatParams,
    finish: Duration,
    tracer: Option<Arc<crate::trace::Tracer>>,
}

impl SimNode {
    fn finish_now(&mut self) -> StepOutcome {
        self.finish = self.clock.now();
        StepOutcome::Done
    }

    /// Store-layer death, mirroring [`crate::node::NodeRunner::fail`]:
    /// a zero-width `Crashed` timeline marker plus a `node_failed` trace
    /// instant at the failure point.
    fn fail_now(&mut self) -> StepOutcome {
        self.failed = true;
        let t = self.clock.now();
        self.timeline.record(SpanKind::Crashed, t, t);
        if let Some(tracer) = &self.tracer {
            tracer.instant(
                self.node_id,
                self.epoch as u64,
                t,
                crate::trace::TraceEventKind::NodeFailed,
            );
        }
        self.finish_now()
    }

    /// Crash–restart recovery, mirroring
    /// `NodeRunner::recover_after`: down for `delay` of simulated time
    /// (a `Crashed` span from `t_down`), then weights restored from the
    /// node's own latest store entry — through the fault/retry stack, so
    /// a restart landing inside an outage retries like any pull — and
    /// codec/protocol state rebuilt from scratch. The epoch counter does
    /// not rewind.
    fn recover_after(&mut self, delay: Duration, t_down: Duration) -> Result<()> {
        self.clock.sleep(delay);
        let t_up = self.clock.now();
        self.timeline.record(SpanKind::Crashed, t_down, t_up);
        if let Some(tracer) = &self.tracer {
            tracer.span(
                self.node_id,
                self.epoch as u64,
                t_down,
                t_up,
                crate::trace::TraceEventKind::Restart,
            );
        }
        self.params = match self.store.latest_for_node(self.node_id)? {
            Some(entry) => (*entry.params).clone(),
            None => (self.init)(self.node_id),
        };
        self.codec = CodecState::new(self.cfg.compress);
        self.protocol = ProtocolKind::from(self.cfg.mode).build(self.node_id, &self.cfg);
        self.restarts += 1;
        Ok(())
    }
}

impl Task for SimNode {
    fn step(&mut self) -> StepOutcome {
        match self.phase {
            Phase::Train => {
                // Zero-time skips (finished epochs, crash, off-cohort
                // rounds) loop inline; anything that advances the clock
                // or touches the store ends the step so the executor can
                // interleave peers.
                loop {
                    if self.epoch >= self.cfg.epochs {
                        return self.finish_now();
                    }
                    if let Some(crash) = self.cfg.crash {
                        if !self.crash_consumed
                            && crash.node == self.node_id
                            && crash.at_epoch == self.epoch
                        {
                            self.crash_consumed = true;
                            let t = self.clock.now();
                            match crash.restart {
                                None => {
                                    self.timeline.record(SpanKind::Crashed, t, t);
                                    return self.finish_now(); // dies without pushing
                                }
                                Some(delay) => {
                                    // crash–restart: down for `delay` of
                                    // simulated time, then back with the
                                    // checkpointed weights
                                    if self.recover_after(delay, t).is_err() {
                                        return self.fail_now();
                                    }
                                    return StepOutcome::Yield;
                                }
                            }
                        }
                    }
                    if !self.plan.participates(self.node_id, self.epoch) {
                        self.epoch += 1; // off-cohort: zero simulated time
                        continue;
                    }
                    break;
                }
                let t = self.clock.now();
                self.clock
                    .sleep(self.delay.mul_f64(self.plan.delay_multiplier(self.node_id)));
                self.timeline.record(SpanKind::Train, t, self.clock.now());
                if let Some(tracer) = &self.tracer {
                    tracer.span(
                        self.node_id,
                        self.epoch as u64,
                        t,
                        self.clock.now(),
                        crate::trace::TraceEventKind::Train,
                    );
                }
                self.phase = Phase::Federate;
                StepOutcome::Yield
            }
            Phase::Federate => {
                let mut ctx = EpochCtx {
                    node_id: self.node_id,
                    n_nodes: self.cfg.n_nodes,
                    round_k: self.plan.round_k(self.epoch),
                    epoch: self.epoch,
                    n_examples: 100,
                    store: self.store.as_ref(),
                    strategy: self.strategy.as_mut(),
                    timeline: &mut self.timeline,
                    sync_timeout: self.cfg.sync_timeout,
                    clock: self.clock.as_ref() as &dyn Clock,
                    codec: &mut self.codec,
                    pool: crate::par::ChunkPool::from_config(self.cfg.threads),
                    tracer: self.tracer.as_deref(),
                };
                // Without a fault model the in-memory store cannot fail;
                // with one, an error here means the retry layer gave up
                // and the node dies like a threaded worker would.
                match self.protocol.poll_epoch(&mut ctx, &mut self.params) {
                    Err(_) => self.fail_now(),
                    Ok(EpochStep::Wait { since, timeout }) => {
                        StepOutcome::Wait { since, timeout }
                    }
                    Ok(EpochStep::Done(out)) => {
                        self.degraded_rounds += out.degraded_rounds;
                        if out.stalled_at.is_some() {
                            self.stalled = true;
                            return self.finish_now();
                        }
                        self.epoch += 1;
                        self.phase = Phase::Train;
                        StepOutcome::Yield
                    }
                }
            }
        }
    }
}

/// Run one trial on the event executor and return per-node results in
/// node-id order.
pub fn run_events_trial(spec: &TrialSpec) -> Result<Vec<SimNodeResult>> {
    run_events_trial_captured(spec).map(|(nodes, _)| nodes)
}

/// [`run_events_trial`] that also hands back the trial's store, so
/// callers can replay its round archive through the
/// [`crate::trace::analyze`] divergence analytics.
pub fn run_events_trial_captured(
    spec: &TrialSpec,
) -> Result<(Vec<SimNodeResult>, Arc<dyn WeightStore>)> {
    let n = spec.delays.len();
    let clock = Arc::new(TaskClock::new());
    let cfg = Arc::new(ExperimentConfig {
        mode: spec.mode,
        n_nodes: n,
        epochs: spec.epochs,
        sync_timeout: spec.sync_timeout,
        seed: spec.seed,
        compress: spec.compress,
        threads: spec.threads,
        crash: spec.crash.map(|(node, at_epoch)| {
            let mut c = crate::config::CrashSpec::at(node, at_epoch);
            c.restart = spec.crash_restart;
            c
        }),
        fault: spec.fault.clone(),
        sync_quorum: spec.sync_quorum,
        ..Default::default()
    });
    let store: Arc<dyn WeightStore> =
        Arc::new(MemoryStore::with_clock(Arc::clone(&clock) as Arc<dyn Clock>));
    let plan = Arc::new(ParticipationPlan::new(
        spec.participation,
        spec.availability,
        spec.seed,
        n,
    ));
    let mut nodes: Vec<SimNode> = (0..n)
        .map(|node_id| {
            // Per-node fault/retry stack when the model is active, built
            // exactly like NodeRunner's: a per-node FaultStore (its own
            // deterministic Bernoulli stream) under a RetryStore client
            // with seeded backoff on the trial clock.
            let (node_store, chaos) = if cfg.fault.is_active() {
                let seed = cfg.seed ^ (node_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let faulty = FaultStore::with_model(
                    Arc::clone(&store),
                    &cfg.fault,
                    Arc::clone(&clock) as Arc<dyn Clock>,
                    seed,
                );
                let retry = Arc::new(RetryStore::new(
                    faulty,
                    RetryPolicy::default(),
                    Arc::clone(&clock) as Arc<dyn Clock>,
                    seed ^ 0xD1B5_4A32_D192_ED03,
                ));
                (Arc::clone(&retry) as Arc<dyn WeightStore>, Some(retry))
            } else {
                (Arc::clone(&store), None)
            };
            SimNode {
                node_id,
                cfg: Arc::clone(&cfg),
                store: node_store,
                clock: Arc::clone(&clock),
                plan: Arc::clone(&plan),
                delay: spec.delays[node_id],
                protocol: ProtocolKind::from(cfg.mode).build(node_id, &cfg),
                strategy: StrategyKind::FedAvg.build(),
                codec: CodecState::new(cfg.compress),
                timeline: Timeline::new(node_id),
                params: (spec.init)(node_id),
                epoch: 0,
                phase: Phase::Train,
                stalled: false,
                failed: false,
                crash_consumed: false,
                restarts: 0,
                degraded_rounds: 0,
                chaos,
                init: spec.init,
                finish: Duration::ZERO,
                tracer: spec.tracer.clone(),
            }
        })
        .collect();

    let executor = EventExecutor::new(Arc::clone(&clock), Arc::clone(&store));
    let mut tasks: Vec<&mut dyn Task> =
        nodes.iter_mut().map(|t| t as &mut dyn Task).collect();
    executor.run(&mut tasks)?;

    let results = nodes
        .into_iter()
        .map(|node| {
            let (injected, retry_stats) = match &node.chaos {
                Some(chaos) => (chaos.inner().injected(), chaos.stats()),
                None => (0, Default::default()),
            };
            SimNodeResult {
                node_id: node.node_id,
                finish: node.finish,
                traffic: node.timeline.traffic,
                spans: node.timeline.spans,
                params: node.params,
                stalled: node.stalled,
                failed: node.failed,
                restarts: node.restarts,
                degraded_rounds: node.degraded_rounds,
                injected_faults: injected,
                store_retries: retry_stats.retries,
                store_give_ups: retry_stats.give_ups,
            }
        })
        .collect();
    Ok((results, store))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn async_straggler_finishes_on_analytic_schedule() {
        let spec = TrialSpec::new(FederationMode::Async, vec![ms(50), ms(500)], 5);
        let nodes = run_events_trial(&spec).unwrap();
        assert_eq!(nodes[0].finish, ms(250), "fast node: 5 × 50ms");
        assert_eq!(nodes[1].finish, ms(2500), "straggler: 5 × 500ms");
        assert!(!nodes[0].stalled && !nodes[1].stalled);
    }

    #[test]
    fn sync_barrier_drags_everyone_to_the_straggler_and_converges() {
        let spec = TrialSpec::new(FederationMode::Sync, vec![ms(50), ms(500)], 3);
        let nodes = run_events_trial(&spec).unwrap();
        // both nodes finish at the straggler's pace, exactly
        assert_eq!(nodes[0].finish, ms(1500));
        assert_eq!(nodes[1].finish, ms(1500));
        // FedAvg over identical-weight contributions: (0 + 1)/2
        assert_eq!(nodes[0].params.0, vec![0.5; 4]);
        assert_eq!(nodes[0].params.0, nodes[1].params.0);
    }

    #[test]
    fn crash_stalls_sync_survivors_after_the_simulated_timeout() {
        let mut spec =
            TrialSpec::new(FederationMode::Sync, vec![ms(50), ms(70), ms(230)], 3);
        spec.sync_timeout = Duration::from_secs(300);
        spec.crash = Some((2, 1));
        let nodes = run_events_trial(&spec).unwrap();
        for survivor in &nodes[0..2] {
            assert!(survivor.stalled);
            let wait: Duration = survivor
                .spans
                .iter()
                .filter(|s| s.kind == SpanKind::Wait)
                .map(|s| s.end - s.start)
                .sum();
            assert!(wait >= Duration::from_secs(300), "waited {wait:?}");
        }
        assert!(!nodes[2].stalled);
        assert_eq!(nodes[2].finish, ms(230), "crashed at round 0's completion");
    }

    #[test]
    fn partial_participation_trains_only_the_cohort() {
        let mut spec =
            TrialSpec::new(FederationMode::Async, vec![ms(10); 20], 4);
        spec.participation = 0.25;
        let nodes = run_events_trial(&spec).unwrap();
        let plan = ParticipationPlan::new(0.25, AvailabilitySpec::None, spec.seed, 20);
        for node in &nodes {
            let rounds_in: usize =
                (0..4).filter(|&r| plan.participates(node.node_id, r)).count();
            let trained =
                node.spans.iter().filter(|s| s.kind == SpanKind::Train).count();
            assert_eq!(trained, rounds_in, "node {} trains cohort rounds only", node.node_id);
            assert_eq!(node.finish, ms(10) * rounds_in as u32, "skips cost zero time");
        }
        let total: usize = nodes
            .iter()
            .map(|n| n.spans.iter().filter(|s| s.kind == SpanKind::Train).count())
            .sum();
        assert_eq!(total, 4 * 5, "4 rounds × cohort of 5");
    }

    #[test]
    fn crash_restart_rejoins_and_completes() {
        let mut spec = TrialSpec::new(FederationMode::Async, vec![ms(50), ms(70)], 4);
        spec.crash = Some((1, 2));
        spec.crash_restart = Some(ms(300));
        let nodes = run_events_trial(&spec).unwrap();
        assert!(!nodes[1].failed && !nodes[1].stalled);
        assert_eq!(nodes[1].restarts, 1);
        // downtime costs exactly its delay: 4 epochs × 70ms + 300ms down
        assert_eq!(nodes[1].finish, ms(4 * 70 + 300));
        assert!(
            nodes[1]
                .spans
                .iter()
                .any(|s| s.kind == SpanKind::Crashed && s.end - s.start == ms(300)),
            "the outage must be a 300ms Crashed span"
        );
        assert_eq!(nodes[0].restarts, 0);
    }

    #[test]
    fn fault_model_is_absorbed_and_replays_bit_identically() {
        let mk = || {
            let mut spec = TrialSpec::new(FederationMode::Async, vec![ms(10); 4], 5);
            spec.fault = FaultModel {
                p_fail: 0.2,
                outages: vec![crate::store::OutageWindow {
                    start: ms(25),
                    duration: ms(40),
                }],
            };
            spec.seed = 42;
            run_events_trial(&spec).unwrap()
        };
        let a = mk();
        assert!(a.iter().all(|n| !n.failed), "retry must absorb every fault");
        assert!(a.iter().all(|n| !n.stalled));
        let injected: u64 = a.iter().map(|n| n.injected_faults).sum();
        let retried: u64 = a.iter().map(|n| n.store_retries).sum();
        assert!(injected >= 1, "p=0.2 plus an outage must inject something");
        assert_eq!(retried, injected, "every transient is retried, none gave up");
        assert_eq!(a.iter().map(|n| n.store_give_ups).sum::<u64>(), 0);
        let b = mk();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.finish, y.finish, "node {}", x.node_id);
            assert_eq!(x.params.0, y.params.0);
            assert_eq!(x.injected_faults, y.injected_faults);
            assert_eq!(x.store_retries, y.store_retries);
        }
    }

    #[test]
    fn churn_trace_replays_bit_identically() {
        let mk = || {
            let mut spec = TrialSpec::new(
                FederationMode::Async,
                (0..12).map(|i| ms(20 + i)).collect(),
                5,
            );
            spec.availability = AvailabilitySpec::Churn { p: 0.3 };
            spec.seed = 1234;
            run_events_trial(&spec).unwrap()
        };
        let a = mk();
        let b = mk();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.finish, y.finish);
            assert_eq!(x.spans, y.spans, "node {}", x.node_id);
            assert_eq!(x.params.0, y.params.0);
            assert_eq!(x.stalled, y.stalled);
        }
    }
}
