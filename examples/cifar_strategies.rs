//! CIFAR strategy comparison (paper §4.3, Tables 5/6) extended with the two
//! strategies the paper's §5 leaves as future work: staleness-aware
//! FedAsync and buffered FedBuff — both run through the *same* serverless
//! async protocol, demonstrating the paper's point that client-side
//! aggregation makes strategies pluggable per node.
//!
//! ```sh
//! cargo run --release --example cifar_strategies [n_nodes] [skew]
//! ```

use fedless::config::{ExperimentConfig, FederationMode};
use fedless::sim::run_trials;
use fedless::strategy::StrategyKind;

fn main() -> anyhow::Result<()> {
    let n_nodes: usize = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(3);
    let skew: f64 = std::env::args().nth(2).map(|s| s.parse().unwrap()).unwrap_or(0.9);
    let trials = 2;

    let base = ExperimentConfig {
        model: "cifar".into(),
        n_nodes,
        mode: FederationMode::Async,
        skew,
        epochs: 3,
        steps_per_epoch: 50,
        train_size: 4_800,
        test_size: 960,
        ..Default::default()
    };

    println!(
        "CIFAR-like ResNet, {n_nodes} nodes, skew {skew}, async serverless \
         federation, {trials} trials each\n"
    );
    println!("| strategy  | accuracy (mean ± 95% CI) | note |");
    println!("|-----------|--------------------------|------|");
    for (kind, note) in [
        (StrategyKind::FedAvg, "paper baseline (Eq. 1)"),
        (StrategyKind::FedAvgM, "server momentum, client-side"),
        (StrategyKind::FedAdam, "server Adam, client-side"),
        (StrategyKind::FedAsync, "staleness-aware (paper §5 future work)"),
        (StrategyKind::FedBuff, "buffered async (paper §5 future work)"),
    ] {
        let mut cfg = base.clone();
        cfg.strategy = kind;
        let set = run_trials(&cfg, trials)?;
        println!("| {:9} | {:24} | {note} |", kind.name(), set.accuracy.fmt_paper());
    }
    Ok(())
}
