//! The data-parallel kernel layer — [`ChunkPool`], the scoped worker
//! pool behind every hot-path kernel in the coordinator.
//!
//! With the virtual clock (simulated waiting is free) and the codec
//! layer (the wire is cheap) in place, the real-world cost of a sweep is
//! CPU time in three kernels: weight aggregation
//! ([`crate::tensor::flat::weighted_average_pooled`]), codec
//! encode/decode ([`crate::compress`]), and content hashing
//! ([`crate::util::hash::chunked_hash_f32s`]). This module gives them a
//! shared parallel substrate with one non-negotiable contract:
//!
//! # The determinism contract
//!
//! **Chunk boundaries are fixed by constants, never by the thread
//! count.** Every kernel splits its input into fixed-size chunks (each
//! kernel documents its width — e.g. [`crate::tensor::flat::PAR_CHUNK`]),
//! computes each chunk independently, and combines per-chunk results in
//! chunk-index order. Threads only decide *who* computes a chunk, never
//! *what* is computed — so results are bit-identical for `threads = 1`
//! and `threads = N` (asserted by `rust/tests/determinism.rs`), and a
//! `threads` sweep axis can never change a single experiment metric,
//! only wall-clock speed.
//!
//! # Implementation
//!
//! `ChunkPool` is deliberately hand-rolled on `std::thread::scope` (the
//! image vendors no rayon): a call-site-scoped fork/join in which
//! workers drain a shared work queue (a mutexed iterator — chunks are
//! tens of kilobytes, so one uncontended lock per chunk is noise) and
//! write results into per-index slots. No threads persist between
//! calls, so the pool composes safely with the sweep scheduler's own
//! worker threads and with node threads parked on a virtual clock
//! (compute takes zero simulated time regardless of `threads`).
//!
//! Configured per experiment via the `threads = auto | N` config key
//! (default 1 — nested parallelism under a sweep is opt-in), the
//! `"threads"` sweep axis, and `fedbench run --threads`.

use std::sync::Mutex;

/// A fixed-width chunk-parallel worker pool; see the module docs for the
/// determinism contract. Copy-cheap (it is only a thread count): thread
/// it by value through [`crate::protocol::EpochCtx`] and
/// [`crate::compress::CodecState`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkPool {
    threads: usize,
}

impl Default for ChunkPool {
    fn default() -> Self {
        ChunkPool::sequential()
    }
}

impl ChunkPool {
    /// A pool running work items on `threads` scoped workers (>= 1).
    pub fn new(threads: usize) -> ChunkPool {
        assert!(threads >= 1, "ChunkPool needs at least one thread");
        ChunkPool { threads }
    }

    /// The single-threaded pool: every kernel runs inline on the calling
    /// thread. The default, and the reference the determinism suite
    /// compares every other thread count against.
    pub fn sequential() -> ChunkPool {
        ChunkPool { threads: 1 }
    }

    /// One worker per available hardware thread (`threads = auto`).
    pub fn auto() -> ChunkPool {
        ChunkPool {
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }

    /// Resolve the `threads` config value: `0` means `auto`, anything
    /// else is an explicit worker count.
    pub fn from_config(threads: usize) -> ChunkPool {
        if threads == 0 {
            ChunkPool::auto()
        } else {
            ChunkPool::new(threads)
        }
    }

    /// Worker count this pool runs with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(index, item)` for every item, distributing items across up
    /// to [`ChunkPool::threads`] scoped workers. `f` must only write
    /// state owned by its item (e.g. the `&mut [f32]` chunk it was
    /// handed) — that, plus caller-fixed chunk boundaries, is what makes
    /// the result independent of the thread count.
    pub fn for_each<T, F>(&self, items: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(usize, T) + Sync,
    {
        let workers = self.threads.min(items.len());
        if workers <= 1 {
            for (i, item) in items.into_iter().enumerate() {
                f(i, item);
            }
            return;
        }
        let queue = Mutex::new(items.into_iter().enumerate());
        std::thread::scope(|scope| {
            for _ in 1..workers {
                scope.spawn(|| drain(&queue, &f));
            }
            drain(&queue, &f);
        });
    }

    /// Like [`ChunkPool::for_each`], collecting `f`'s results in item
    /// order (slot `i` holds `f(i, items[i])` no matter which worker ran
    /// it) — the fork/join primitive behind per-chunk digests and
    /// candidate lists.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = items.len();
        let workers = self.threads.min(n);
        if workers <= 1 {
            return items.into_iter().enumerate().map(|(i, item)| f(i, item)).collect();
        }
        let queue = Mutex::new(items.into_iter().enumerate());
        let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
        let work = |queue: &Mutex<std::iter::Enumerate<std::vec::IntoIter<T>>>| loop {
            let next = queue.lock().unwrap().next();
            match next {
                Some((i, item)) => {
                    // compute outside the slot lock; store under it
                    let r = f(i, item);
                    slots.lock().unwrap()[i] = Some(r);
                }
                None => return,
            }
        };
        std::thread::scope(|scope| {
            for _ in 1..workers {
                scope.spawn(|| work(&queue));
            }
            work(&queue);
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every work item stores its slot"))
            .collect()
    }
}

/// Worker body for [`ChunkPool::for_each`]: pop-and-run until the queue
/// is empty. The lock is released before `f` runs, so workers only
/// contend for the (trivial) queue pop.
fn drain<T, F>(queue: &Mutex<std::iter::Enumerate<std::vec::IntoIter<T>>>, f: &F)
where
    F: Fn(usize, T),
{
    loop {
        let next = queue.lock().unwrap().next();
        match next {
            Some((i, item)) => f(i, item),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;

    #[test]
    fn from_config_resolves_auto_and_explicit() {
        assert!(ChunkPool::from_config(0).threads() >= 1, "auto is at least one worker");
        assert_eq!(ChunkPool::from_config(3).threads(), 3);
        assert_eq!(ChunkPool::sequential().threads(), 1);
        assert_eq!(ChunkPool::default(), ChunkPool::sequential());
    }

    #[test]
    #[should_panic(expected = "at least one thread")]
    fn zero_threads_is_rejected() {
        ChunkPool::new(0);
    }

    #[test]
    fn for_each_visits_every_item_exactly_once() {
        for threads in [1, 2, 8] {
            let pool = ChunkPool::new(threads);
            let mut out = vec![0u64; 100];
            let items: Vec<&mut u64> = out.iter_mut().collect();
            pool.for_each(items, |i, slot| *slot = (i as u64 + 1) * 3);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, (i as u64 + 1) * 3, "threads={threads} item {i}");
            }
        }
    }

    #[test]
    fn map_preserves_item_order() {
        for threads in [1, 2, 8] {
            let pool = ChunkPool::new(threads);
            let items: Vec<usize> = (0..57).collect();
            let out = pool.map(items, |i, x| {
                assert_eq!(i, x);
                x * x
            });
            assert_eq!(out, (0..57).map(|x| x * x).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn more_threads_than_items_is_fine() {
        let pool = ChunkPool::new(16);
        assert_eq!(pool.map(vec![7usize], |_, x| x + 1), vec![8]);
        assert_eq!(pool.map(Vec::<usize>::new(), |_, x| x), Vec::<usize>::new());
        pool.for_each(Vec::<usize>::new(), |_, _| panic!("no items, no calls"));
    }

    #[test]
    fn every_worker_sees_disjoint_items() {
        // 8 threads over 1000 items: the visit count must be exactly one
        // per item even under contention.
        let pool = ChunkPool::new(8);
        let visits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        let items: Vec<usize> = (0..1000).collect();
        pool.for_each(items, |_, i| {
            visits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(visits.iter().all(|v| v.load(Ordering::SeqCst) == 1));
    }
}
