//! Flat-parameter tensors and the on-disk/on-wire blob codec.
//!
//! Every model's weights cross the L2/L3 boundary as a single flat `f32`
//! vector (see `python/compile/train.py`), so the whole coordinator is
//! architecture-agnostic: aggregation, stores and protocols only ever see
//! [`FlatParams`].

pub mod codec;
pub mod flat;

pub use codec::{decode_blob, encode_blob};
pub use flat::FlatParams;
