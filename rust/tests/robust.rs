//! Robust aggregation under adversarial clients — the attack-scenario
//! suite for the `strategy/robust/` family and the [`AdversaryStore`]
//! content-fault layer.
//!
//! Every scenario runs the real protocol stack (sync barrier, shared
//! store, per-node threads) on a [`fedless::time::VirtualClock`], so the
//! whole grid — every adversary kind crossed with every aggregation
//! strategy — finishes at CPU speed with *exact* assertions: FedAvg
//! collapses under a single byzantine client while median, trimmed
//! mean, Krum and trust-weighted averaging stay within tolerance of the
//! clean run, bit-identically across replays and thread counts.
//!
//! The aggregator property tests (permutation invariance, breakdown
//! points, Krum selection, trust-weight decay) drive the `Strategy`
//! implementations directly through hand-built [`Contribution`]s.
//!
//! The golden sweep snapshot at `golden/robust_sweep.md` pins the full
//! robust × adversary grid, including the paired `acc clean` /
//! `acc attacked` report columns.
//!
//! CI runs this file inside the same hard real-time budget as
//! `rust/tests/timing.rs` (see `.github/workflows/ci.yml`); a regression
//! into real sleeping times the job out. No artifacts or PJRT runtime
//! are needed.

use std::sync::Arc;
use std::time::Duration;

use fedless::config::{ExperimentConfig, FederationMode};
use fedless::metrics::timeline::Timeline;
use fedless::par::ChunkPool;
use fedless::protocol::ProtocolKind;
use fedless::store::{AdversarySpec, AdversaryStore, MemoryStore, WeightStore};
use fedless::strategy::{
    Contribution, Krum, Median, Strategy, StrategyKind, TrimmedMean, TrustWeighted,
};
use fedless::tensor::FlatParams;
use fedless::time::{Clock, ParticipantGuard, VirtualClock};

fn ms(n: u64) -> Duration {
    Duration::from_millis(n)
}

// ---------------------------------------------------------------------------
// attack-scenario harness (no artifacts, no PJRT)

/// Parameter dimension for the scenario grid — tiny on purpose: the
/// interesting structure is *which* contributions survive aggregation,
/// not their size. The thread-invariance test widens this past
/// `PAR_CHUNK` to cross chunk boundaries.
const DIM: usize = 8;

/// Scenario node count; the adversary spec claims the highest node ids.
const N_NODES: usize = 4;

/// What one simulated node reports back.
struct SimNode {
    finish: Duration,
    params: FlatParams,
}

/// The honest model after local epoch `e`: `1 − 2^{−(e+1)}`, an exact
/// dyadic that converges toward 1.0 — so aggregation arithmetic over
/// honest clients is exact in f32 and any drift in the final params is
/// attributable to the adversary, not to rounding.
fn honest(epoch: usize) -> f32 {
    1.0 - 0.5f32.powi(epoch as i32 + 1)
}

/// Scalar "accuracy" of a model: `1 / (1 + ‖params − 1‖₂)` in f64 —
/// 1.0 at the honest fixed point, falling toward 0 as an attack drags
/// the aggregate away. Deterministic, so golden snapshots are safe.
fn accuracy_of(params: &FlatParams) -> f64 {
    let dist = params
        .0
        .iter()
        .map(|x| {
            let e = f64::from(*x) - 1.0;
            e * e
        })
        .sum::<f64>()
        .sqrt();
    1.0 / (1.0 + dist)
}

/// Exact bit pattern of a parameter vector (for bit-identity claims —
/// `==` on f32 would conflate `-0.0` and `0.0`).
fn bits(p: &FlatParams) -> Vec<u32> {
    p.0.iter().map(|x| x.to_bits()).collect()
}

/// Drive [`N_NODES`] real threads through `epochs` sync-federated
/// epochs on one shared virtual-clocked store, optionally wrapped in an
/// [`AdversaryStore`]: each epoch is one `clock.sleep` ("training",
/// node `i` takes `10·(i+1)` ms so pushes land in node order), an
/// honest overwrite of the local params to [`honest`]`(epoch)`, then
/// the sync protocol's `after_epoch`. The adversary rewrites the
/// configured nodes' pushes *in the store layer* — the protocol code is
/// attack-agnostic.
fn run_attack_sim(
    kind: StrategyKind,
    adversary: Option<AdversarySpec>,
    seed: u64,
    threads: usize,
    epochs: usize,
    dim: usize,
) -> Vec<SimNode> {
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let cfg = ExperimentConfig {
        mode: FederationMode::Sync,
        n_nodes: N_NODES,
        strategy: kind,
        adversary,
        seed,
        threads,
        ..Default::default()
    };
    let base: Arc<dyn WeightStore> = Arc::new(MemoryStore::with_clock(Arc::clone(&clock)));
    let store: Arc<dyn WeightStore> = match adversary {
        None => base,
        Some(spec) => Arc::new(AdversaryStore::new(base, spec, N_NODES, seed)),
    };
    // Register every node before any thread runs, so the clock never
    // advances while some nodes are still spawning.
    for _ in 0..N_NODES {
        clock.enter();
    }
    let start = Arc::new(std::sync::Barrier::new(N_NODES));
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..N_NODES)
            .map(|node_id| {
                let clock = Arc::clone(&clock);
                let store = Arc::clone(&store);
                let cfg = cfg.clone();
                let start = Arc::clone(&start);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    let mut protocol = ProtocolKind::from(cfg.mode).build(node_id, &cfg);
                    let mut strategy = cfg.strategy.build();
                    let mut codec = fedless::compress::CodecState::new(cfg.compress);
                    let mut timeline = Timeline::new(node_id);
                    let mut params = FlatParams(vec![0.0; dim]);
                    start.wait();
                    for epoch in 0..epochs {
                        clock.sleep(ms(10 * (node_id as u64 + 1)));
                        // honest local training moves every client to
                        // the same point; only the adversary deviates
                        params = FlatParams(vec![honest(epoch); dim]);
                        let mut ctx = fedless::protocol::EpochCtx {
                            node_id,
                            n_nodes: N_NODES,
                            round_k: N_NODES,
                            epoch,
                            n_examples: 100,
                            store: store.as_ref(),
                            strategy: strategy.as_mut(),
                            timeline: &mut timeline,
                            sync_timeout: ms(60_000),
                            clock: clock.as_ref(),
                            codec: &mut codec,
                            pool: ChunkPool::from_config(cfg.threads),
                            tracer: None,
                        };
                        let out = protocol.after_epoch(&mut ctx, &mut params).unwrap();
                        assert!(out.stalled_at.is_none(), "node {node_id} stalled");
                    }
                    SimNode { finish: clock.now(), params }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

// ---------------------------------------------------------------------------
// the headline scenario grid: every adversary × every strategy

/// Plain FedAvg has no defense — one byzantine client costs it ≥30% of
/// clean accuracy (here: effectively all of it) and every other attack
/// drags it strictly below clean — while each robust aggregator holds
/// ≥90% of its clean accuracy under *every* attack kind.
#[test]
fn fedavg_collapses_under_attack_while_robust_strategies_hold() {
    let strategies = ["fedavg", "median", "trimmed-mean:0.25", "krum:1", "trust-weighted"];
    let attacks = ["byzantine:1", "signflip:1", "scale:10", "stale:1"];
    for name in strategies {
        let kind = StrategyKind::parse(name).unwrap();
        let clean = accuracy_of(&run_attack_sim(kind, None, 42, 1, 3, DIM)[0].params);
        assert!(clean > 0.7, "{name}: clean accuracy {clean}");
        for attack in attacks {
            let spec = AdversarySpec::parse(attack).unwrap();
            let got = accuracy_of(&run_attack_sim(kind, Some(spec), 42, 1, 3, DIM)[0].params);
            if kind == StrategyKind::FedAvg {
                if attack == "byzantine:1" {
                    assert!(
                        got <= 0.7 * clean,
                        "fedavg must lose ≥30% under {attack}: {got} vs clean {clean}"
                    );
                }
                assert!(got < clean, "fedavg under {attack}: {got} vs clean {clean}");
            } else {
                assert!(
                    got >= 0.9 * clean,
                    "{name} under {attack}: {got} vs clean {clean}"
                );
            }
        }
    }
}

/// Every node converges to the *same* aggregate: the corrupted push is
/// in the shared store, so honest and adversarial nodes alike aggregate
/// it — there is one global model per round, not per-node forks.
#[test]
fn all_nodes_agree_on_the_attacked_aggregate() {
    let spec = AdversarySpec::parse("signflip:1").unwrap();
    for name in ["fedavg", "median", "krum:1"] {
        let kind = StrategyKind::parse(name).unwrap();
        let nodes = run_attack_sim(kind, Some(spec), 42, 1, 3, DIM);
        let first = bits(&nodes[0].params);
        for node in &nodes[1..] {
            assert_eq!(first, bits(&node.params), "{name}: nodes diverged");
        }
    }
}

/// A zero-strength spec (`byzantine:0`) is bitwise transparent: the
/// wrapped run is indistinguishable from running without the wrapper.
#[test]
fn zero_strength_adversary_is_bitwise_transparent() {
    let spec = AdversarySpec::parse("byzantine:0").unwrap();
    let plain = run_attack_sim(StrategyKind::FedAvg, None, 42, 1, 3, DIM);
    let wrapped = run_attack_sim(StrategyKind::FedAvg, Some(spec), 42, 1, 3, DIM);
    for (a, b) in plain.iter().zip(&wrapped) {
        assert_eq!(bits(&a.params), bits(&b.params));
        assert_eq!(a.finish, b.finish);
    }
}

// ---------------------------------------------------------------------------
// determinism: replays and thread counts

/// The same (strategy, adversary, seed) replays bit-identically — the
/// byzantine noise stream is a pure function of (seed, node, round), the
/// stale history is per-node, and sync pushes land in virtual-time
/// order, so nothing depends on OS scheduling.
#[test]
fn attack_scenarios_replay_bit_identically() {
    for name in ["fedavg", "median", "trust-weighted"] {
        let kind = StrategyKind::parse(name).unwrap();
        for attack in ["byzantine:2", "stale:1"] {
            let spec = AdversarySpec::parse(attack).unwrap();
            let a = run_attack_sim(kind, Some(spec), 7, 1, 3, DIM);
            let b = run_attack_sim(kind, Some(spec), 7, 1, 3, DIM);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(bits(&x.params), bits(&y.params), "{name} / {attack}");
                assert_eq!(x.finish, y.finish, "{name} / {attack}");
            }
        }
    }
}

/// `threads` stays a pure wall-clock knob under attack: with parameters
/// wide enough to span several `PAR_CHUNK` chunks (so the per-coordinate
/// sort kernels really do fan out), `threads = 1` and `threads = 8`
/// produce bit-identical aggregates and identical simulated finish
/// times for every robust strategy.
#[test]
fn thread_count_is_invisible_to_attacked_aggregates() {
    let dim = 40_000;
    assert!(dim > 2 * fedless::tensor::flat::PAR_CHUNK, "must span chunks");
    let spec = AdversarySpec::parse("byzantine:1").unwrap();
    for name in ["fedavg", "median", "trimmed-mean:0.25", "krum:1", "trust-weighted"] {
        let kind = StrategyKind::parse(name).unwrap();
        let t1 = run_attack_sim(kind, Some(spec), 42, 1, 2, dim);
        let t8 = run_attack_sim(kind, Some(spec), 42, 8, 2, dim);
        for (a, b) in t1.iter().zip(&t8) {
            assert_eq!(bits(&a.params), bits(&b.params), "{name}: threads changed bits");
            assert_eq!(a.finish, b.finish, "{name}: threads changed simulated time");
        }
    }
}

// ---------------------------------------------------------------------------
// aggregator property tests (direct, no simulation)

fn contrib(node_id: usize, vals: Vec<f32>) -> Contribution {
    Contribution {
        node_id,
        n_examples: 100,
        is_self: node_id == 0,
        seq: node_id as u64 + 1,
        params: Arc::new(FlatParams(vals)),
    }
}

/// Robust aggregates are permutation-invariant *bit for bit*: the
/// kernels canonicalize by node id, so arrival order cannot leak into
/// the result (the property FedAvg's FMA order explicitly does not
/// have).
#[test]
fn robust_aggregates_are_permutation_invariant() {
    let dim = 33;
    let contribs: Vec<Contribution> = (0..5)
        .map(|node| {
            let vals = (0..dim).map(|j| ((node * 31 + j * 7) % 17) as f32 * 0.125 - 1.0).collect();
            contrib(node, vals)
        })
        .collect();
    let reversed: Vec<Contribution> = contribs.iter().rev().cloned().collect();
    let rotated: Vec<Contribution> = contribs[2..].iter().chain(&contribs[..2]).cloned().collect();
    for name in ["median", "trimmed-mean:0.25", "krum:1", "trust-weighted"] {
        let kind = StrategyKind::parse(name).unwrap();
        let base = kind.build().aggregate(&contribs).unwrap();
        for order in [&reversed, &rotated] {
            let got = kind.build().aggregate(order).unwrap();
            assert_eq!(bits(&base), bits(&got), "{name}: order leaked into aggregate");
        }
    }
}

/// Coordinate-wise median has breakdown point ⌊(n−1)/2⌋: with n = 5 it
/// shrugs off 2 arbitrarily-placed outliers exactly, and the 3rd one
/// captures it — both directions asserted.
#[test]
fn median_tolerates_up_to_half_minus_one_outliers() {
    let make = |outliers: usize| -> Vec<Contribution> {
        (0..5)
            .map(|node| {
                let v = if node < 5 - outliers { 1.0 } else { 1.0e9 };
                contrib(node, vec![v; 4])
            })
            .collect()
    };
    let mut median = Median::new();
    let held = median.aggregate(&make(2)).unwrap();
    assert!(held.0.iter().all(|x| *x == 1.0), "2 of 5 outliers must not move the median");
    let captured = median.aggregate(&make(3)).unwrap();
    assert!(captured.0.iter().all(|x| *x > 1.0e8), "3 of 5 outliers must capture the median");
}

/// Trimmed mean with `frac = 0.25` trims ⌊0.25·n⌋ per side: at n = 8
/// that absorbs exactly 2 outliers (result is the exact honest mean)
/// and breaks on the 3rd (one outlier survives trimming).
#[test]
fn trimmed_mean_breaks_exactly_past_its_trim_budget() {
    let make = |outliers: usize| -> Vec<Contribution> {
        (0..8)
            .map(|node| {
                let v = if node < 8 - outliers { 2.0 } else { 1.0e9 };
                contrib(node, vec![v; 4])
            })
            .collect()
    };
    let mut tm = TrimmedMean::new(0.25);
    let held = tm.aggregate(&make(2)).unwrap();
    assert!(held.0.iter().all(|x| *x == 2.0), "2 of 8 outliers fit the trim budget");
    let captured = tm.aggregate(&make(3)).unwrap();
    assert!(captured.0.iter().all(|x| *x > 1.0e6), "3rd outlier must survive trimming");
}

/// Krum with `f = 1` over one far-away outlier and a tied honest
/// cluster selects the *lowest-id honest* update and returns it
/// verbatim — selection is by score with a deterministic tie-break,
/// never the outlier.
#[test]
fn krum_selects_the_lowest_id_honest_update() {
    // the outlier sits at node 0, so "never index 0" is a real claim
    let contribs: Vec<Contribution> = (0..4)
        .map(|node| {
            let v = if node == 0 { 100.0 } else { 0.5 };
            contrib(node, vec![v; 6])
        })
        .collect();
    let refs: Vec<&Contribution> = contribs.iter().collect();
    let picked = Krum::new(1).select(&refs, ChunkPool::sequential());
    assert_eq!(picked, 1, "lowest-id member of the honest cluster");
    let agg = Krum::new(1).aggregate(&contribs).unwrap();
    assert_eq!(bits(&agg), bits(&contribs[picked].params), "krum must return the pick verbatim");
}

/// Trust weights always form a distribution (sum to 1) and the weight
/// of a persistently-deviating client *strictly* decreases round over
/// round as its residual EMA accumulates.
#[test]
fn trust_weights_normalize_and_punish_a_persistent_outlier() {
    let mut tw = TrustWeighted::default();
    let mut last_bad = f32::MAX;
    for round in 0..3 {
        let contribs: Vec<Contribution> = (0..4)
            .map(|node| contrib(node, vec![if node == 3 { 5.0 } else { 1.0 }; 8]))
            .collect();
        tw.aggregate(&contribs).unwrap();
        let weights = tw.last_weights();
        let sum: f32 = weights.iter().map(|(_, w)| w).sum();
        assert!((sum - 1.0).abs() < 1e-5, "round {round}: weights must normalize, got {sum}");
        let bad = weights.iter().find(|(n, _)| *n == 3).unwrap().1;
        let good = weights.iter().find(|(n, _)| *n == 0).unwrap().1;
        assert!(bad < good, "round {round}: outlier must weigh less than honest");
        assert!(bad < last_bad, "round {round}: outlier weight must strictly decay");
        last_bad = bad;
    }
}

// ---------------------------------------------------------------------------
// golden robust × adversary sweep snapshot

/// The full grid — {fedavg, median, trimmed-mean:0.25, krum:1} ×
/// {none, byzantine:1, signflip:1, scale:10, stale:1} over two seeds —
/// rendered through the sweep reporter must match the committed
/// snapshot byte for byte, replay identically, and carry the ISSUE's
/// acceptance numbers: fedavg loses ≥30% of clean accuracy under one
/// byzantine client while every robust strategy retains ≥90% under
/// every attack.
#[test]
fn golden_robust_adversary_sweep_report() {
    use fedless::sweep::{run_sweep_with, SweepSpec};

    let spec = SweepSpec::parse_json(
        r#"{
            "modes": "sync",
            "strategies": "fedavg",
            "robust": ["median", "trimmed-mean:0.25", "krum:1"],
            "adversary": ["none", "byzantine:1", "signflip:1", "scale:10", "stale:1"],
            "n_nodes": 4,
            "epochs": 3,
            "seeds": [42, 43],
            "jobs": 1,
            "clock": "virtual"
        }"#,
    )
    .unwrap();

    let runner = |cfg: &ExperimentConfig| -> anyhow::Result<fedless::sim::ExperimentResult> {
        let nodes =
            run_attack_sim(cfg.strategy, cfg.adversary, cfg.seed, cfg.threads, cfg.epochs, DIM);
        let wall = nodes.iter().map(|n| n.finish).max().unwrap();
        let acc = accuracy_of(&nodes[0].params);
        Ok(fedless::sim::ExperimentResult {
            final_accuracy: acc,
            final_loss: 1.0 - acc,
            wall_clock_s: wall.as_secs_f64(),
            reports: vec![],
            global_hash: 0,
            store_pushes: 0,
            mean_idle_fraction: 0.0,
            all_completed: true,
            divergence: None,
            trace_dir: None,
        })
    };

    let body = |md: &str| -> String {
        // skip the header line: it carries the sweep's *real* wall-clock
        md.lines().skip(1).collect::<Vec<_>>().join("\n")
    };

    let r1 = run_sweep_with(&spec, runner).unwrap();
    let r2 = run_sweep_with(&spec, runner).unwrap();
    assert_eq!(r1.n_failures, 0, "{}", r1.to_markdown());
    assert_eq!(body(&r1.to_markdown()), body(&r2.to_markdown()), "must replay identically");

    let acc_of = |strategy: &str, adversary: &str| -> f64 {
        r1.cells
            .iter()
            .find(|c| {
                c.cell.strategy.label() == strategy
                    && c.cell.adversary.map_or("none".to_string(), |a| a.label()) == adversary
            })
            .and_then(|c| c.accuracy.as_ref())
            .unwrap_or_else(|| panic!("missing cell {strategy}/{adversary}"))
            .mean
    };
    let clean = acc_of("fedavg", "none");
    assert!(
        acc_of("fedavg", "byz1") <= 0.7 * clean,
        "fedavg must lose ≥30% relative accuracy under byzantine:1"
    );
    for robust in ["median", "trimmed-mean0.25", "krum1"] {
        let robust_clean = acc_of(robust, "none");
        for adv in ["byz1", "signflip1", "scale10", "stale1"] {
            let got = acc_of(robust, adv);
            assert!(
                got >= 0.9 * robust_clean,
                "{robust} under {adv}: {got} vs clean {robust_clean}"
            );
        }
    }

    let golden = include_str!("golden/robust_sweep.md");
    assert_eq!(
        format!("{}\n", body(&r1.to_markdown())),
        golden,
        "sweep body diverged from the committed snapshot:\n{}",
        r1.to_markdown()
    );
}
