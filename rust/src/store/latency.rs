//! Latency-injecting store wrapper — the simulated-S3 layer.
//!
//! The paper's weight store is an S3 bucket; this wrapper reproduces the
//! *timing* behaviour (per-op latency with jitter, payload-proportional
//! transfer time) on top of any inner store, so experiments can measure the
//! protocol's sensitivity to store round-trip cost (DESIGN.md
//! §Substitutions).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::{PushRequest, WeightEntry, WeightStore};
use crate::time::{Clock, RealClock};
use crate::util::Rng;

/// Timing model for a remote object store.
#[derive(Clone, Copy, Debug)]
pub struct LatencyConfig {
    /// Fixed per-operation round-trip.
    pub base: Duration,
    /// Uniform jitter added on top: `U[0, jitter]`.
    pub jitter: Duration,
    /// Simulated bandwidth for payload transfer (bytes/sec); 0 = infinite.
    pub bytes_per_sec: u64,
}

impl LatencyConfig {
    /// Rough S3 same-region profile scaled for simulation: ~20ms RTT,
    /// 10ms jitter, 200 MB/s.
    pub fn s3_like() -> Self {
        LatencyConfig {
            base: Duration::from_millis(20),
            jitter: Duration::from_millis(10),
            bytes_per_sec: 200_000_000,
        }
    }

    /// Zero latency, infinite bandwidth (a transparent wrapper).
    pub fn none() -> Self {
        LatencyConfig { base: Duration::ZERO, jitter: Duration::ZERO, bytes_per_sec: 0 }
    }

    /// The config-value timing model: `ms` RTT, half as much jitter, and
    /// the simulated-S3 bandwidth. Shared by the `latency = <ms>` config
    /// key and the sweep spec's `"latency": <ms>` so the two formats can
    /// never drift apart.
    pub fn from_ms(ms: f64) -> Self {
        LatencyConfig {
            base: Duration::from_secs_f64(ms / 1000.0),
            jitter: Duration::from_secs_f64(ms / 2000.0),
            bytes_per_sec: 200_000_000,
        }
    }
}

/// Wraps an inner store, sleeping a seeded-random latency on each op.
pub struct LatencyStore<S> {
    inner: S,
    cfg: LatencyConfig,
    rng: Mutex<Rng>,
    clock: Arc<dyn Clock>,
}

impl<S: WeightStore> LatencyStore<S> {
    /// Wrap `inner` with the `cfg` timing model; jitter is deterministic
    /// in `seed`. Delays are real `thread::sleep`s.
    pub fn new(inner: S, cfg: LatencyConfig, seed: u64) -> Self {
        LatencyStore::with_clock(inner, cfg, seed, RealClock::shared())
    }

    /// Like [`LatencyStore::new`], but delays sleep in `clock`'s time
    /// domain — under a [`crate::time::VirtualClock`] the simulated-S3
    /// round-trips consume simulated time only, so latency sweeps run at
    /// CPU speed.
    pub fn with_clock(inner: S, cfg: LatencyConfig, seed: u64, clock: Arc<dyn Clock>) -> Self {
        LatencyStore { inner, cfg, rng: Mutex::new(Rng::new(seed ^ 0x1A7E_4C1)), clock }
    }

    /// The wrapped store.
    pub fn inner(&self) -> &S {
        &self.inner
    }

    fn delay(&self, wire_bytes: u64) {
        let jit = {
            let mut rng = self.rng.lock().unwrap();
            self.cfg.jitter.mul_f64(rng.f64())
        };
        let mut d = self.cfg.base + jit;
        if self.cfg.bytes_per_sec > 0 && wire_bytes > 0 {
            d += Duration::from_secs_f64(wire_bytes as f64 / self.cfg.bytes_per_sec as f64);
        }
        self.clock.sleep(d);
    }

    /// Charge a multi-entry pull: one GET round-trip per downloaded
    /// entry, each transferring that entry's *encoded* wire bytes
    /// (header included) — an empty result still costs the LIST that
    /// found nothing. The old behaviour (one summed delay on bare
    /// `params.len() * 4`) undercounted both the per-entry RTTs and the
    /// fixed blob header, and ignored compression entirely.
    fn charge_entries(&self, entries: &[WeightEntry]) {
        if entries.is_empty() {
            self.delay(0);
            return;
        }
        for e in entries {
            self.delay(e.wire_bytes);
        }
    }
}

impl<S: WeightStore> WeightStore for LatencyStore<S> {
    fn push(&self, req: PushRequest) -> Result<u64> {
        self.delay(req.wire_bytes);
        self.inner.push(req)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        let out = self.inner.latest_per_node()?;
        self.charge_entries(&out);
        Ok(out)
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        let out = self.inner.entries_for_round(round)?;
        self.charge_entries(&out);
        Ok(out)
    }

    fn state_hash(&self) -> Result<u64> {
        self.delay(0); // LIST-like op: RTT only
        self.inner.state_hash()
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        let out = self.inner.latest_for_node(node_id)?;
        self.delay(out.as_ref().map(|e| e.wire_bytes).unwrap_or(0));
        Ok(out)
    }

    fn version(&self) -> Result<u64> {
        self.delay(0); // LIST-like op: RTT only
        self.inner.version()
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        // The park itself costs no round-trips; charge one RTT for the
        // LIST that observes the wake-up.
        let v = self.inner.wait_for_change(since, timeout)?;
        self.delay(0);
        Ok(v)
    }

    fn push_count(&self) -> u64 {
        self.inner.push_count()
    }

    fn clear(&self) -> Result<()> {
        self.inner.clear()
    }

    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // a conditional put costs the same upload round-trip whether the
        // store accepts it or not (the server rejects after receiving)
        self.delay(req.wire_bytes);
        self.inner.push_if_version(req, expected)
    }
}

#[cfg(test)]
mod tests {
    use std::time::Instant;

    use super::*;
    use crate::store::store_tests;
    use crate::store::MemoryStore;

    #[test]
    fn conformance_with_zero_latency() {
        let s = LatencyStore::new(MemoryStore::new(), LatencyConfig::none(), 1);
        store_tests::conformance(&s);
    }

    #[test]
    fn injects_measurable_latency() {
        let cfg = LatencyConfig {
            base: Duration::from_millis(15),
            jitter: Duration::ZERO,
            bytes_per_sec: 0,
        };
        let s = LatencyStore::new(MemoryStore::new(), cfg, 1);
        let t0 = Instant::now();
        s.state_hash().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(14));
    }

    #[test]
    fn bandwidth_term_scales_with_payload() {
        let cfg = LatencyConfig {
            base: Duration::ZERO,
            jitter: Duration::ZERO,
            bytes_per_sec: 1_000_000, // 1 MB/s
        };
        let s = LatencyStore::new(MemoryStore::new(), cfg, 1);
        let t0 = Instant::now();
        // 100k f32 = 400 KB -> ~400ms at 1MB/s
        s.push(super::super::PushRequest::raw(
            0,
            0,
            0,
            1,
            std::sync::Arc::new(crate::tensor::FlatParams(vec![0.0; 100_000])),
        ))
        .unwrap();
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(350), "dt={dt:?}");
    }

    #[test]
    fn charges_encoded_wire_bytes_header_included() {
        use crate::tensor::codec::HEADER_LEN;
        use crate::time::{Clock, VirtualClock};

        // Deterministic accounting on a virtual clock: no base RTT, no
        // jitter, 1 byte/sec -> simulated seconds == charged wire bytes.
        let clock: std::sync::Arc<dyn Clock> = std::sync::Arc::new(VirtualClock::new());
        clock.enter();
        let _guard = crate::time::ParticipantGuard::adopt(std::sync::Arc::clone(&clock));
        let cfg = LatencyConfig { base: Duration::ZERO, jitter: Duration::ZERO, bytes_per_sec: 1 };
        let s = LatencyStore::with_clock(
            MemoryStore::with_clock(std::sync::Arc::clone(&clock)),
            cfg,
            1,
            std::sync::Arc::clone(&clock),
        );

        let t0 = clock.now();
        s.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        let push_cost = (clock.now() - t0).as_secs();
        // 8 f32 + v1 header: the fixed header is charged, not just the
        // payload (the old code's `params.len() * 4`)
        assert_eq!(push_cost, (HEADER_LEN + 8 * 4) as u64);

        // a compressed entry charges its (smaller) encoded size
        let t0 = clock.now();
        s.push(super::super::PushRequest {
            node_id: 1,
            round: 0,
            epoch: 0,
            n_examples: 1,
            wire_bytes: 10,
            params: std::sync::Arc::new(crate::tensor::FlatParams(vec![0.0; 8])),
        })
        .unwrap();
        assert_eq!((clock.now() - t0).as_secs(), 10);

        // multi-entry pulls charge per entry: both wire sizes, summed
        let t0 = clock.now();
        let entries = s.latest_per_node().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!((clock.now() - t0).as_secs(), (HEADER_LEN + 32) as u64 + 10);

        // single-entry pull charges exactly that entry's wire size
        let t0 = clock.now();
        let e = s.latest_for_node(1).unwrap().unwrap();
        assert_eq!(e.wire_bytes, 10);
        assert_eq!((clock.now() - t0).as_secs(), 10);
    }

    #[test]
    fn multi_entry_pull_pays_one_rtt_per_entry() {
        let cfg = LatencyConfig {
            base: Duration::from_millis(10),
            jitter: Duration::ZERO,
            bytes_per_sec: 0,
        };
        let s = LatencyStore::new(MemoryStore::new(), cfg, 1);
        for node in 0..3 {
            s.push(store_tests::push_req(node, 0, 1.0)).unwrap();
        }
        let t0 = Instant::now();
        assert_eq!(s.entries_for_round(0).unwrap().len(), 3);
        assert!(
            t0.elapsed() >= Duration::from_millis(28),
            "3 GETs must cost ~3 RTTs, took {:?}",
            t0.elapsed()
        );
        // an empty pull still costs the LIST round-trip
        let t0 = Instant::now();
        assert!(s.entries_for_round(9).unwrap().is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }
}
