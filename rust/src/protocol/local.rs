//! [`LocalOnly`] — the no-federation protocol.

use anyhow::Result;

use crate::tensor::FlatParams;

use super::{EpochCtx, FederationProtocol, ProtocolOutcome};

/// No federation: the node never touches the weight store.
///
/// With one node this is the paper's centralized baseline; with several
/// it is the independent-silos lower bound (the experiment driver still
/// averages the final weights once, so grids can carry a no-federation
/// row next to the real protocols).
pub struct LocalOnly;

impl FederationProtocol for LocalOnly {
    fn name(&self) -> &'static str {
        "local"
    }

    fn after_epoch(
        &mut self,
        _ctx: &mut EpochCtx<'_>,
        _params: &mut FlatParams,
    ) -> Result<ProtocolOutcome> {
        Ok(ProtocolOutcome::default())
    }
}
