//! Label-skew partitioning — the paper's §4.1 sampling procedure, verbatim:
//!
//! 1. "The training examples are first partitioned into n mutually
//!    exclusive subsets based on the label" (e.g. n=2 on MNIST: digits 0-4
//!    -> partition 0, digits 5-9 -> partition 1).
//! 2. "to simulate a skew of s (0 < s < 1), with probability s each
//!    training example is assigned to a node based on the partition; with
//!    probability 1-s, the training example is assigned to a random node."
//!
//! `s = 0` is a uniform random split, `s = 1` gives fully disjoint label
//! sets (the paper's "full skew").

use crate::util::Rng;

/// Assigns example indices to federated nodes with controllable label skew.
#[derive(Clone, Debug)]
pub struct Partitioner {
    /// Number of nodes to split across.
    pub n_nodes: usize,
    /// Label skew s ∈ [0, 1].
    pub skew: f64,
    /// Total label classes (defines the home-node ranges).
    pub num_classes: usize,
}

impl Partitioner {
    /// A partitioner for `n_nodes` nodes at label skew `skew` over
    /// `num_classes` classes.
    pub fn new(n_nodes: usize, skew: f64, num_classes: usize) -> Self {
        assert!(n_nodes >= 1, "need at least one node");
        assert!((0.0..=1.0).contains(&skew), "skew must be in [0,1]");
        Partitioner { n_nodes, skew, num_classes }
    }

    /// The "home" node of a label: classes are split into n contiguous
    /// groups (paper step 1).
    pub fn home_node(&self, label: usize) -> usize {
        assert!(label < self.num_classes);
        // contiguous ranges, e.g. 10 classes / 3 nodes -> sizes 4,3,3
        let base = self.num_classes / self.n_nodes;
        let extra = self.num_classes % self.n_nodes;
        let mut start = 0;
        for node in 0..self.n_nodes {
            let size = base + usize::from(node < extra);
            if label < start + size {
                return node;
            }
            start += size;
        }
        self.n_nodes - 1
    }

    /// Assign every example to a node (paper step 2). Deterministic in
    /// `seed`.
    pub fn assign(&self, labels: &[usize], seed: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::new(seed ^ 0x5045_5254);
        let mut shards: Vec<Vec<usize>> = vec![Vec::new(); self.n_nodes];
        for (idx, &label) in labels.iter().enumerate() {
            let node = if rng.chance(self.skew) {
                self.home_node(label)
            } else {
                rng.below(self.n_nodes)
            };
            shards[node].push(idx);
        }
        // Guarantee no node is empty (can only happen at tiny dataset
        // sizes); move one example from the largest shard.
        for i in 0..self.n_nodes {
            if shards[i].is_empty() {
                let donor = (0..self.n_nodes).max_by_key(|&j| shards[j].len()).unwrap();
                if shards[donor].len() > 1 {
                    let ex = shards[donor].pop().unwrap();
                    shards[i].push(ex);
                }
            }
        }
        shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn labels(n: usize, classes: usize, seed: u64) -> Vec<usize> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.below(classes)).collect()
    }

    #[test]
    fn home_node_splits_mnist_digits_like_paper() {
        // n=2 on 10 classes: digits 0-4 -> node 0, 5-9 -> node 1 (paper)
        let p = Partitioner::new(2, 1.0, 10);
        for l in 0..5 {
            assert_eq!(p.home_node(l), 0);
        }
        for l in 5..10 {
            assert_eq!(p.home_node(l), 1);
        }
    }

    #[test]
    fn home_node_covers_all_nodes() {
        for n in 1..=5 {
            let p = Partitioner::new(n, 1.0, 10);
            let mut seen = vec![false; n];
            for l in 0..10 {
                seen[p.home_node(l)] = true;
            }
            assert!(seen.iter().all(|&s| s), "n={n}");
        }
    }

    #[test]
    fn assign_is_a_partition() {
        let ls = labels(10_000, 10, 3);
        let p = Partitioner::new(3, 0.7, 10);
        let shards = p.assign(&ls, 42);
        let total: usize = shards.iter().map(Vec::len).sum();
        assert_eq!(total, ls.len());
        let mut seen = vec![false; ls.len()];
        for shard in &shards {
            for &i in shard {
                assert!(!seen[i], "example {i} assigned twice");
                seen[i] = true;
            }
        }
    }

    #[test]
    fn skew_zero_is_roughly_uniform() {
        let ls = labels(30_000, 10, 5);
        let p = Partitioner::new(3, 0.0, 10);
        let shards = p.assign(&ls, 7);
        for s in &shards {
            let frac = s.len() as f64 / ls.len() as f64;
            assert!((frac - 1.0 / 3.0).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn skew_one_is_fully_disjoint() {
        let ls = labels(5_000, 10, 9);
        let p = Partitioner::new(2, 1.0, 10);
        let shards = p.assign(&ls, 7);
        for (node, shard) in shards.iter().enumerate() {
            for &i in shard {
                assert_eq!(p.home_node(ls[i]), node);
            }
        }
    }

    #[test]
    fn partial_skew_mixes_labels() {
        // paper's 0.9 skew: each node mostly home labels + some others
        let ls = labels(20_000, 10, 13);
        let p = Partitioner::new(2, 0.9, 10);
        let shards = p.assign(&ls, 21);
        for (node, shard) in shards.iter().enumerate() {
            let home = shard.iter().filter(|&&i| p.home_node(ls[i]) == node).count();
            let frac = home as f64 / shard.len() as f64;
            // expect ~ s + (1-s)/2 = 0.95 of examples to be home-labelled
            assert!((frac - 0.95).abs() < 0.02, "node {node} home frac {frac}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let ls = labels(1000, 10, 1);
        let p = Partitioner::new(5, 0.5, 10);
        assert_eq!(p.assign(&ls, 5), p.assign(&ls, 5));
        assert_ne!(p.assign(&ls, 5), p.assign(&ls, 6));
    }

    #[test]
    fn no_empty_shards_small_data() {
        let ls = vec![0, 0, 0, 0, 0]; // all one class, 3 nodes, full skew
        let p = Partitioner::new(3, 1.0, 10);
        let shards = p.assign(&ls, 1);
        assert!(shards.iter().all(|s| !s.is_empty()), "{shards:?}");
    }
}
