//! Trace determinism suite (PR 9 acceptance).
//!
//! Pins the observability contract end to end on synthetic traced runs
//! under the virtual clock:
//!
//! * a 4-node async run's divergence tables and per-node span shares
//!   are **bit-identical** across node schedulers (`threads` vs
//!   `events`) and kernel thread counts (1 vs 8) — down to the exported
//!   `analysis.json` bytes and the rendered `inspect` text;
//! * the golden `inspect` divergence table for a hand-checkable
//!   archive matches character for character;
//! * the exported Chrome trace is valid JSON whose per-node tracks are
//!   monotone non-decreasing in time;
//! * `export_run` → `load_summary` round-trips, so `fedbench run` and
//!   `fedbench inspect` render the same bytes.

use std::sync::Arc;

use fedless::config::{FederationMode, SchedulerKind};
use fedless::par::ChunkPool;
use fedless::store::{MemoryStore, PushRequest};
use fedless::tensor::FlatParams;
use fedless::trace::export::{chrome_trace_json, export_run, load_summary, summary_json};
use fedless::trace::{compute_divergence, run_synthetic, SyntheticRun, SyntheticSpec};
use fedless::util::json::Json;

const N_NODES: usize = 4;
const EPOCHS: usize = 3;

fn traced_run(scheduler: SchedulerKind, threads: usize) -> (SyntheticRun, String, String) {
    let mut spec = SyntheticSpec::new(FederationMode::Async, N_NODES, EPOCHS);
    spec.scheduler = scheduler;
    spec.threads = threads;
    let run = run_synthetic(&spec).expect("synthetic run");
    let summary = run
        .summary("trace_accept", EPOCHS as u64, ChunkPool::from_config(threads))
        .expect("summary");
    let rendered = summary.render();
    let json = summary_json(&summary);
    (run, rendered, json)
}

/// The acceptance scenario: a traced 4-node async virtual-clock run's
/// per-round divergence and per-node span shares are bit-identical
/// across schedulers and thread counts.
#[test]
fn divergence_and_spans_bit_identical_across_schedulers_and_threads() {
    let (base_run, base_render, base_json) = traced_run(SchedulerKind::Threads, 1);
    assert!(
        base_render.contains("per-round divergence"),
        "async traced run must archive rounds:\n{base_render}"
    );
    assert!(base_render.contains("node | train s"), "{base_render}");
    for (scheduler, threads) in [
        (SchedulerKind::Events, 1),
        (SchedulerKind::Threads, 8),
        (SchedulerKind::Events, 8),
    ] {
        let (run, render, json) = traced_run(scheduler, threads);
        assert_eq!(
            json, base_json,
            "analysis.json must be byte-identical ({scheduler:?}, threads={threads})"
        );
        assert_eq!(
            render, base_render,
            "rendered inspect text must be byte-identical ({scheduler:?}, threads={threads})"
        );
        assert_eq!(
            run.tracer.events(),
            base_run.tracer.events(),
            "trace events must agree ({scheduler:?}, threads={threads})"
        );
    }
}

/// Golden `inspect` divergence table: clients at `[0; 4]` and `[2; 4]`
/// with equal example counts average to `[1; 4]`; both sit L2 = 2 from
/// the aggregate, the zero vector's cosine is defined 0, the other's is
/// exactly 1 — so every rendered digit is hand-checkable.
#[test]
fn golden_inspect_divergence_table() {
    let store = MemoryStore::new();
    for (node_id, value) in [(0usize, 0.0f32), (1, 2.0)] {
        store
            .push(PushRequest {
                node_id,
                round: 0,
                epoch: 0,
                n_examples: 100,
                wire_bytes: 16,
                params: Arc::new(FlatParams(vec![value; 4])),
            })
            .unwrap();
    }
    let report = compute_divergence(&store, 1, ChunkPool::sequential())
        .unwrap()
        .expect("non-empty archive");
    let golden = "\
per-round divergence (client update vs round aggregate):
round | clients | mean L2 | mean cos
    0 |       2 |   2.000000 | 0.500000

client drift (L2 per round, `-` = not archived):
node   0: 2.000000
node   1: 2.000000

pairwise cosine, final round (nodes [0, 1]):
   0.0000  0.0000
   0.0000  1.0000
cosine clusters (threshold 0.9): [[0], [1]]
";
    assert_eq!(report.render(), golden);
}

/// The exported Chrome trace of a real synthetic run is valid JSON and
/// every per-node (`tid`) track is monotone non-decreasing in `ts` — the
/// Perfetto-loadability contract.
#[test]
fn chrome_trace_export_is_valid_json_with_monotone_node_tracks() {
    let (run, _, _) = traced_run(SchedulerKind::Threads, 1);
    let timelines: Vec<&fedless::metrics::timeline::Timeline> = run.timelines.iter().collect();
    let src = chrome_trace_json(&run.tracer.events(), &timelines);
    let j = Json::parse(&src).expect("chrome trace must parse as JSON");
    let rows = j.as_arr().expect("chrome trace is a JSON array");
    assert!(
        rows.len() >= N_NODES * EPOCHS,
        "expected at least one event per node-epoch, got {}",
        rows.len()
    );
    let mut last_ts = vec![0u64; N_NODES];
    let mut seen = vec![false; N_NODES];
    for row in rows {
        let tid = row.get("tid").unwrap().as_usize().expect("tid");
        let ts = row.get("ts").unwrap().as_f64().expect("ts") as u64;
        let ph = row.get("ph").unwrap().as_str().expect("ph");
        assert!(ph == "X" || ph == "i", "unknown phase {ph:?}");
        if ph == "X" {
            assert!(row.get("dur").unwrap().as_f64().is_some(), "complete events carry dur");
        }
        assert!(tid < N_NODES, "tid {tid} out of range");
        if seen[tid] {
            assert!(ts >= last_ts[tid], "track {tid} went backwards: {ts} < {}", last_ts[tid]);
        }
        last_ts[tid] = ts;
        seen[tid] = true;
    }
    assert!(seen.iter().all(|s| *s), "every node contributes a track");
}

/// Full disk round-trip: `export_run` writes the three artifacts and
/// `load_summary` (the `fedbench inspect` loader) re-renders the same
/// bytes `fedbench run` printed — the two commands can never disagree.
#[test]
fn export_then_inspect_round_trips_the_summary() {
    let (run, rendered, _) = traced_run(SchedulerKind::Events, 1);
    let summary = run
        .summary("trace_accept", EPOCHS as u64, ChunkPool::sequential())
        .unwrap();
    let dir = std::env::temp_dir().join(format!(
        "fedless_trace_export_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let timelines: Vec<&fedless::metrics::timeline::Timeline> = run.timelines.iter().collect();
    let out = export_run(&dir, &run.tracer, &timelines, &summary).unwrap();
    assert_eq!(out, dir);
    for f in ["trace.jsonl", "trace_chrome.json", "analysis.json"] {
        assert!(dir.join(f).is_file(), "missing export {f}");
    }
    // every trace.jsonl line parses, in canonical node order
    let jsonl = std::fs::read_to_string(dir.join("trace.jsonl")).unwrap();
    let mut last_node = 0usize;
    for line in jsonl.lines() {
        let j = Json::parse(line).expect("jsonl line parses");
        let node = j.get("node").unwrap().as_usize().unwrap();
        assert!(node >= last_node, "jsonl must be in node-merge order");
        last_node = node;
    }
    let loaded = load_summary(&dir).expect("inspect loads the archive");
    assert_eq!(loaded, summary);
    assert_eq!(loaded.render(), rendered);
    let _ = std::fs::remove_dir_all(&dir);
}
