//! Run logging: CSV (step metrics) + JSONL (events) under `runs/<name>/`.
//! This is the substitution for the paper's Weights & Biases tracking
//! (DESIGN.md §Substitutions) — every experiment leaves a reproducible
//! on-disk record.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// Thread-safe append-only logger for one run.
pub struct RunLogger {
    dir: PathBuf,
    csv: Mutex<BufWriter<File>>,
    events: Mutex<BufWriter<File>>,
    csv_header: Mutex<Option<Vec<String>>>,
}

impl RunLogger {
    /// Create `runs/<name>/{metrics.csv,events.jsonl}` (truncating).
    pub fn create<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
        let csv = BufWriter::new(File::create(dir.join("metrics.csv"))?);
        let events = BufWriter::new(File::create(dir.join("events.jsonl"))?);
        Ok(RunLogger {
            dir,
            csv: Mutex::new(csv),
            events: Mutex::new(events),
            csv_header: Mutex::new(None),
        })
    }

    /// The run directory this logger writes into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Log one row of named metric values; the first call fixes the column
    /// set and writes the header.
    pub fn log_metrics(&self, fields: &[(&str, f64)]) -> Result<()> {
        let mut header = self.csv_header.lock().unwrap();
        let mut csv = self.csv.lock().unwrap();
        match header.as_ref() {
            None => {
                let cols: Vec<String> = fields.iter().map(|(k, _)| k.to_string()).collect();
                writeln!(csv, "{}", cols.join(","))?;
                *header = Some(cols);
            }
            Some(cols) => {
                let now: Vec<&str> = fields.iter().map(|(k, _)| *k).collect();
                anyhow::ensure!(
                    cols.iter().map(String::as_str).eq(now.iter().copied()),
                    "metric columns changed mid-run: {:?} vs {:?}",
                    cols,
                    now
                );
            }
        }
        let row: Vec<String> = fields.iter().map(|(_, v)| format!("{v}")).collect();
        writeln!(csv, "{}", row.join(","))?;
        csv.flush()?;
        Ok(())
    }

    /// Log a structured event as one JSON line.
    pub fn log_event(&self, kind: &str, fields: &[(&str, String)]) -> Result<()> {
        let mut ev = self.events.lock().unwrap();
        let mut line = format!("{{\"event\":\"{}\"", escape(kind));
        for (k, v) in fields {
            line.push_str(&format!(",\"{}\":\"{}\"", escape(k), escape(v)));
        }
        line.push('}');
        writeln!(ev, "{line}")?;
        ev.flush()?;
        Ok(())
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "fedless_logger_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn writes_csv_with_header() {
        let dir = tmpdir("csv");
        let lg = RunLogger::create(&dir).unwrap();
        lg.log_metrics(&[("step", 1.0), ("loss", 2.5)]).unwrap();
        lg.log_metrics(&[("step", 2.0), ("loss", 2.0)]).unwrap();
        let text = fs::read_to_string(dir.join("metrics.csv")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "step,loss");
        assert_eq!(lines.len(), 3);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_changed_columns() {
        let dir = tmpdir("cols");
        let lg = RunLogger::create(&dir).unwrap();
        lg.log_metrics(&[("a", 1.0)]).unwrap();
        assert!(lg.log_metrics(&[("b", 1.0)]).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn events_are_valid_jsonl() {
        let dir = tmpdir("ev");
        let lg = RunLogger::create(&dir).unwrap();
        lg.log_event("node_crash", &[("node", "3".into()), ("msg", "a\"b".into())])
            .unwrap();
        let text = fs::read_to_string(dir.join("events.jsonl")).unwrap();
        let parsed = crate::util::json::Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.get("event").unwrap().as_str(), Some("node_crash"));
        assert_eq!(parsed.get("msg").unwrap().as_str(), Some("a\"b"));
        fs::remove_dir_all(&dir).unwrap();
    }
}
