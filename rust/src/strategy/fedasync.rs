//! FedAsync (Xie et al. 2019) — staleness-aware asynchronous mixing; one
//! of the extensions the paper's §5 lists as unimplemented future work
//! ("We did not implement staleness-aware asynchronous strategies ... that
//! were shown to produce higher accuracy").
//!
//! The node mixes its local weights toward the peers' average with a
//! staleness-attenuated factor:
//! `α_eff = α / (1 + s)^a`, `w <- (1 - α_eff) w_local + α_eff w_peers`,
//! where staleness `s` is how many store sequence numbers behind the
//! freshest entry the peer average is (polynomial attenuation, the paper's
//! `α_t = α (t - τ + 1)^{-a}` adapted to the serverless store).

use super::{example_weights, Contribution, Strategy};
use crate::par::ChunkPool;
use crate::tensor::FlatParams;

/// Staleness-attenuated asynchronous mixing toward the peer average.
pub struct FedAsync {
    /// Base mixing weight α.
    alpha: f32,
    /// Polynomial staleness exponent a.
    exponent: f32,
}

impl FedAsync {
    /// Base mixing weight `alpha` ∈ [0, 1] and polynomial staleness
    /// exponent `exponent` ≥ 0 (0 disables staleness attenuation).
    pub fn new(alpha: f32, exponent: f32) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        assert!(exponent >= 0.0);
        FedAsync { alpha, exponent }
    }
}

impl Strategy for FedAsync {
    fn name(&self) -> &'static str {
        "fedasync"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        let own = contribs.iter().find(|c| c.is_self)?;
        let peers: Vec<&Contribution> = contribs.iter().filter(|c| !c.is_self).collect();
        if peers.is_empty() {
            // None means "keep the caller's current weights" — no deep
            // copy. Deliberate semantic choice: under a lossy codec the
            // self *store entry* is the wire reconstruction, so the old
            // `Some(own.params.clone())` would adopt quantized weights
            // when training alone; keeping the local full-precision
            // vector is both cheaper and strictly more faithful.
            return None;
        }

        // Example-weighted average of the peers only — borrowed straight
        // out of `contribs`; params are Arc'd, nothing is deep-copied.
        let w = example_weights(peers.iter().copied());
        let refs: Vec<&FlatParams> = peers.iter().map(|c| c.params.as_ref()).collect();
        let peer_avg = crate::tensor::flat::weighted_average_pooled(&refs, &w, pool);

        // Staleness: how far the average peer entry lags the freshest seq
        // seen in this pull (own push is typically the freshest).
        let max_seq = contribs.iter().map(|c| c.seq).max().unwrap_or(0);
        let mean_peer_seq =
            peers.iter().map(|c| c.seq as f64).sum::<f64>() / peers.len() as f64;
        let staleness = (max_seq as f64 - mean_peer_seq).max(0.0);
        let alpha_eff = self.alpha * (1.0 + staleness as f32).powf(-self.exponent);

        let mut next = own.params.as_ref().clone();
        next.lerp_pooled(alpha_eff, &peer_avg, pool);
        Some(next)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::strategy_tests::contrib;
    use super::*;

    fn contrib_seq(node: usize, n: u64, is_self: bool, vals: &[f32], seq: u64) -> Contribution {
        Contribution {
            node_id: node,
            n_examples: n,
            is_self,
            seq,
            params: Arc::new(FlatParams(vals.to_vec())),
        }
    }

    #[test]
    fn no_peers_keeps_own_without_copying() {
        // None = "keep current weights" (the self contribution is the
        // caller's current weights), avoiding a needless deep copy
        let mut s = FedAsync::new(0.6, 0.5);
        assert!(s.aggregate(&[contrib(0, 1, true, &[2.0])]).is_none());
    }

    #[test]
    fn fresh_peer_mixes_by_alpha() {
        let mut s = FedAsync::new(0.5, 0.5);
        // own seq = peer seq -> staleness 0 -> alpha_eff = 0.5
        let out = s
            .aggregate(&[
                contrib_seq(0, 1, true, &[0.0], 5),
                contrib_seq(1, 1, false, &[4.0], 5),
            ])
            .unwrap();
        assert!((out.0[0] - 2.0).abs() < 1e-6, "{}", out.0[0]);
    }

    #[test]
    fn stale_peer_gets_attenuated() {
        let mut s = FedAsync::new(0.5, 1.0);
        // peer 9 seqs behind -> alpha_eff = 0.5 / 10 = 0.05
        let out = s
            .aggregate(&[
                contrib_seq(0, 1, true, &[0.0], 10),
                contrib_seq(1, 1, false, &[4.0], 1),
            ])
            .unwrap();
        assert!((out.0[0] - 0.2).abs() < 1e-6, "{}", out.0[0]);
    }

    #[test]
    fn exponent_zero_ignores_staleness() {
        let mut s = FedAsync::new(0.5, 0.0);
        let out = s
            .aggregate(&[
                contrib_seq(0, 1, true, &[0.0], 100),
                contrib_seq(1, 1, false, &[4.0], 1),
            ])
            .unwrap();
        assert!((out.0[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn missing_self_returns_none() {
        let mut s = FedAsync::new(0.5, 0.5);
        assert!(s.aggregate(&[contrib(1, 1, false, &[1.0])]).is_none());
    }
}
