//! Scale suite — the event scheduler's reason to exist: trials with
//! thousands to tens of thousands of simulated clients in seconds of
//! real time, which thread-per-node cannot touch (10k OS threads and
//! VirtualClock participant slots).
//!
//! Everything here runs the artifact-free [`fedless::sched`] harness
//! (synthetic weights, no PJRT) with partial participation, so per-round
//! work is the *cohort's*, not the fleet's. The 10k-client acceptance
//! trial is `#[ignore]`d to keep the default debug test run lean; CI
//! runs it `--release --include-ignored` inside the timing job's hard
//! real-time budget (`.github/workflows/ci.yml`).

use std::time::{Duration, Instant};

use fedless::config::FederationMode;
use fedless::metrics::timeline::SpanKind;
use fedless::sched::{
    run_events_trial, AvailabilitySpec, ParticipationPlan, SimNodeResult, TrialSpec,
};

fn ms(n: u64) -> Duration {
    Duration::from_millis(n)
}

/// Order-sensitive digest over every node's final weights — the trial's
/// global model fingerprint for replay assertions.
fn fleet_digest(nodes: &[SimNodeResult]) -> u64 {
    nodes
        .iter()
        .fold(0u64, |acc, n| acc.rotate_left(1) ^ n.params.content_hash())
}

fn trains(node: &SimNodeResult) -> usize {
    node.spans.iter().filter(|s| s.kind == SpanKind::Train).count()
}

/// The headline acceptance trial: 10 000 async clients, 3 rounds, 1%
/// participation — completes in seconds of real time, does exactly the
/// cohorts' work, and replays to the same fleet digest.
#[test]
#[ignore = "scale smoke: run with --release --include-ignored (CI timing job)"]
fn ten_thousand_client_async_trial_runs_in_seconds() {
    let n = 10_000;
    let epochs = 3;
    let mk = || {
        let mut spec = TrialSpec::new(
            FederationMode::Async,
            (0..n).map(|i| ms(10 + (i % 97) as u64)).collect(),
            epochs,
        );
        spec.participation = 0.01;
        run_events_trial(&spec).unwrap()
    };

    let t_real = Instant::now();
    let a = mk();
    let real = t_real.elapsed();
    assert!(
        real < Duration::from_secs(30),
        "a 10k-client trial must take seconds, took {real:?}"
    );

    // cohort accounting: k = round(0.01 * 10_000) = 100 per round, and
    // only cohort members ever train
    let seed = fedless::config::ExperimentConfig::default().seed;
    let plan = ParticipationPlan::new(0.01, AvailabilitySpec::None, seed, n);
    let total: usize = a.iter().map(trains).sum();
    assert_eq!(total, epochs * 100, "3 rounds x cohort of 100");
    for node in &a {
        let rounds_in =
            (0..epochs).filter(|&r| plan.participates(node.node_id, r)).count();
        assert_eq!(trains(node), rounds_in, "node {}", node.node_id);
        if rounds_in == 0 {
            assert_eq!(node.finish, Duration::ZERO, "never-sampled nodes cost zero time");
        }
        assert!(!node.stalled, "async never stalls");
    }

    // replay bit-identity at full scale
    let b = mk();
    assert_eq!(fleet_digest(&a), fleet_digest(&b), "10k-client replay must be bit-identical");
}

/// A 1000-client trial small enough for the default debug run: fast,
/// cohort-exact, and bit-identical on replay.
#[test]
fn thousand_client_async_trial_is_fast_and_replays() {
    let n = 1000;
    let mk = || {
        let mut spec = TrialSpec::new(
            FederationMode::Async,
            (0..n).map(|i| ms(5 + (i % 31) as u64)).collect(),
            3,
        );
        spec.participation = 0.1;
        run_events_trial(&spec).unwrap()
    };
    let t_real = Instant::now();
    let a = mk();
    let b = mk();
    assert!(
        t_real.elapsed() < Duration::from_secs(60),
        "two 1k-client trials must be fast even in debug, took {:?}",
        t_real.elapsed()
    );
    let total: usize = a.iter().map(trains).sum();
    assert_eq!(total, 3 * 100, "3 rounds x cohort of 100");
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.finish, y.finish, "node {}", x.node_id);
        assert_eq!(x.spans, y.spans, "node {}", x.node_id);
        assert_eq!(x.params.0, y.params.0, "node {}", x.node_id);
    }
}

/// Partial participation under the sync barrier: the fan-in is the
/// *cohort* size, so k-member rounds close without the other N - k
/// clients — nobody stalls and only cohort members ever wait.
#[test]
fn partial_participation_sync_barrier_uses_the_cohort_fan_in() {
    let n = 200;
    let epochs = 3;
    let mut spec = TrialSpec::new(
        FederationMode::Sync,
        (0..n).map(|i| ms(10 + i as u64)).collect(),
        epochs,
    );
    spec.participation = 0.05; // k = 10 of 200
    spec.sync_timeout = Duration::from_secs(60);
    let nodes = run_events_trial(&spec).unwrap();
    for node in &nodes {
        assert!(!node.stalled, "node {}: a cohort barrier must close", node.node_id);
    }
    let total: usize = nodes.iter().map(trains).sum();
    assert_eq!(total, epochs * 10, "3 rounds x cohort of 10");
}

/// A churning 2000-client fleet replays bit-identically: the whole
/// availability trace is a pure function of `(seed, node, round)`, so
/// rerunning the trial reproduces every span and every weight.
#[test]
fn churn_trace_at_scale_replays_bit_identically() {
    let n = 2000;
    let mk = || {
        let mut spec = TrialSpec::new(
            FederationMode::Async,
            (0..n).map(|i| ms(5 + (i % 53) as u64)).collect(),
            4,
        );
        spec.participation = 0.05;
        spec.availability = AvailabilitySpec::Churn { p: 0.3 };
        spec.seed = 1234;
        run_events_trial(&spec).unwrap()
    };
    let a = mk();
    let b = mk();
    assert!(a.iter().any(|node| trains(node) > 0), "someone must have trained");
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.finish, y.finish, "node {}", x.node_id);
        assert_eq!(x.spans, y.spans, "node {}", x.node_id);
        assert_eq!(x.params.0, y.params.0, "node {}", x.node_id);
        assert_eq!(x.stalled, y.stalled, "node {}", x.node_id);
    }
    assert_eq!(fleet_digest(&a), fleet_digest(&b));
}
