"""Lowered-jax -> HLO *text* conversion (the AOT interchange format).

HLO text, NOT `.serialize()`: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`). The text parser on the rust side reassigns ids,
so text round-trips cleanly. See /opt/xla-example/README.md.
"""

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """Convert a `jax.jit(fn).lower(...)` result to XLA HLO text.

    Lowers via stablehlo then converts with ``return_tuple=True`` so the rust
    side always unwraps a tuple (xla::Literal::to_tuple*), regardless of the
    function's arity.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, *arg_specs) -> str:
    """jit + lower `fn` at the given ShapeDtypeStructs and return HLO text.

    `keep_unused=True` pins the artifact signature: without it jit prunes
    unused args (e.g. the LM's dummy `y`) and the rust caller's argument
    count no longer matches the compiled program.
    """
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*arg_specs))
