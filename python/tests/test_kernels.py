"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py,
swept across shapes (padded and unpadded), K values, dtypes, and magnitudes.
This is the core correctness signal for the kernels that end up inside the
lowered train/aggregation artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import fedavg_aggregate, fused_adam_step, tiled_matmul
from compile.kernels.ref import adam_step_ref, fedavg_aggregate_ref, matmul_ref


def rngs(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# fedavg aggregation


@pytest.mark.parametrize("k", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("c", [1, 7, 128, 1000, 65536, 65537, 200_000])
def test_fedavg_agg_matches_ref(k, c):
    r = rngs(k * 1_000_003 + c)
    stack = r.standard_normal((k, c), dtype=np.float32)
    w = r.random(k).astype(np.float32)
    w /= w.sum()
    got = fedavg_aggregate(jnp.asarray(stack), jnp.asarray(w), block_c=65536)
    want = fedavg_aggregate_ref(jnp.asarray(stack), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_fedavg_agg_small_block():
    """Exercise multi-block grids with a tiny block size."""
    r = rngs(7)
    stack = r.standard_normal((3, 1030), dtype=np.float32)
    w = np.asarray([0.5, 0.3, 0.2], np.float32)
    got = fedavg_aggregate(jnp.asarray(stack), jnp.asarray(w), block_c=128)
    want = fedavg_aggregate_ref(jnp.asarray(stack), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_fedavg_agg_identity_single_client():
    """K=1 with weight 1.0 must be an exact pass-through."""
    r = rngs(11)
    stack = r.standard_normal((1, 5000), dtype=np.float32)
    got = fedavg_aggregate(jnp.asarray(stack), jnp.ones((1,), jnp.float32))
    np.testing.assert_allclose(np.asarray(got), stack[0], rtol=0, atol=0)


def test_fedavg_agg_equal_weights_is_mean():
    r = rngs(13)
    stack = r.standard_normal((4, 999), dtype=np.float32)
    w = np.full((4,), 0.25, np.float32)
    got = fedavg_aggregate(jnp.asarray(stack), jnp.asarray(w), block_c=256)
    np.testing.assert_allclose(np.asarray(got), stack.mean(0), rtol=1e-5, atol=1e-6)


def test_fedavg_agg_huge_magnitudes():
    r = rngs(17)
    stack = (r.standard_normal((2, 300)) * 1e6).astype(np.float32)
    w = np.asarray([0.9, 0.1], np.float32)
    got = fedavg_aggregate(jnp.asarray(stack), jnp.asarray(w), block_c=128)
    want = fedavg_aggregate_ref(jnp.asarray(stack), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1.0)


# ---------------------------------------------------------------------------
# fused adam


@pytest.mark.parametrize("p", [1, 100, 65536, 70_001])
@pytest.mark.parametrize("step", [1, 2, 1000])
def test_fused_adam_matches_ref(p, step):
    r = rngs(p + step)
    params = r.standard_normal(p).astype(np.float32)
    m = (r.standard_normal(p) * 0.1).astype(np.float32)
    v = np.abs(r.standard_normal(p) * 0.01).astype(np.float32)
    g = r.standard_normal(p).astype(np.float32)
    s = jnp.asarray(step, jnp.int32)
    got = fused_adam_step(
        jnp.asarray(params), jnp.asarray(m), jnp.asarray(v), jnp.asarray(g), s
    )
    want = adam_step_ref(
        jnp.asarray(params), jnp.asarray(m), jnp.asarray(v), jnp.asarray(g), s
    )
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("wd", [0.0, 0.01, 0.1])
def test_fused_adam_weight_decay(wd):
    r = rngs(42)
    p = 5000
    params = r.standard_normal(p).astype(np.float32)
    zeros = np.zeros(p, np.float32)
    g = r.standard_normal(p).astype(np.float32)
    s = jnp.asarray(1, jnp.int32)
    got = fused_adam_step(
        jnp.asarray(params), jnp.asarray(zeros), jnp.asarray(zeros),
        jnp.asarray(g), s, weight_decay=wd,
    )
    want = adam_step_ref(
        jnp.asarray(params), jnp.asarray(zeros), jnp.asarray(zeros),
        jnp.asarray(g), s, weight_decay=wd,
    )
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)


def test_fused_adam_zero_grad_decays_moments_only():
    """With g=0 and wd=0, params move only by the m-momentum term."""
    p = 256
    params = np.ones(p, np.float32)
    m = np.full(p, 0.5, np.float32)
    v = np.full(p, 0.25, np.float32)
    g = np.zeros(p, np.float32)
    s = jnp.asarray(3, jnp.int32)
    p2, m2, v2 = fused_adam_step(
        jnp.asarray(params), jnp.asarray(m), jnp.asarray(v), jnp.asarray(g), s
    )
    np.testing.assert_allclose(np.asarray(m2), 0.9 * m, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v2), 0.999 * v, rtol=1e-6)
    assert not np.allclose(np.asarray(p2), params)  # momentum still moves p


# ---------------------------------------------------------------------------
# tiled matmul


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 16, 8),
        (128, 128, 128),
        (130, 100, 70),   # all dims unpadded
        (256, 384, 128),  # multi-tile every axis
        (33, 257, 65),
    ],
)
def test_tiled_matmul_matches_ref(m, k, n):
    r = rngs(m * 31 + k * 7 + n)
    x = r.standard_normal((m, k)).astype(np.float32)
    y = r.standard_normal((k, n)).astype(np.float32)
    got = tiled_matmul(jnp.asarray(x), jnp.asarray(y))
    want = matmul_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_tiled_matmul_small_tiles():
    """Multi-tile K accumulation loop with non-default tile sizes."""
    r = rngs(99)
    x = r.standard_normal((20, 50)).astype(np.float32)
    y = r.standard_normal((50, 30)).astype(np.float32)
    got = tiled_matmul(jnp.asarray(x), jnp.asarray(y), bm=8, bn=8, bk=8)
    want = matmul_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_tiled_matmul_identity():
    x = np.eye(64, dtype=np.float32)
    y = rngs(3).standard_normal((64, 64)).astype(np.float32)
    got = tiled_matmul(jnp.asarray(x), jnp.asarray(y), bm=32, bn=32, bk=32)
    np.testing.assert_allclose(np.asarray(got), y, rtol=1e-6, atol=1e-6)
