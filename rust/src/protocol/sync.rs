//! [`SyncBarrier`] — the synchronous serverless protocol (§3), now
//! blocking on store change notification instead of sleep-polling.

use std::sync::Arc;

use anyhow::Result;

use crate::metrics::timeline::SpanKind;
use crate::strategy::Contribution;
use crate::tensor::FlatParams;

use super::{EpochCtx, FederationProtocol, ProtocolOutcome};

/// Synchronous serverless federation: push for round `r`, park on
/// [`crate::store::WeightStore::wait_for_change`] until all K round-`r`
/// entries exist, aggregate the identical set client-side (so all nodes
/// compute bit-identical weights — `rust/tests/protocol_invariants.rs`).
///
/// The barrier is event-driven: a waiting node wakes only when a peer's
/// push (or any store mutation) advances the store version, never on a
/// sleep timer. A `sync_timeout` still bounds the wait so a crashed peer
/// turns the node's status into `Stalled` instead of hanging (§4.2.1).
pub struct SyncBarrier;

impl FederationProtocol for SyncBarrier {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn after_epoch(
        &mut self,
        ctx: &mut EpochCtx<'_>,
        params: &mut FlatParams,
    ) -> Result<ProtocolOutcome> {
        let round = ctx.epoch as u64;
        ctx.push_weights(params, round)?;
        let mut out = ProtocolOutcome { pushes: 1, ..Default::default() };

        // barrier: park until all K entries of this round exist; elapsed
        // time and the stall timeout are measured on the experiment
        // clock, so a crashed peer releases survivors within *simulated*
        // timeout under a virtual clock — no real-time wait.
        let t_wait = ctx.clock.now();
        let entries = loop {
            // Read the version token *before* listing: a push landing
            // between the two can only cause a spurious wake-up, never a
            // missed one.
            let seen = ctx.store.version()?;
            let entries = ctx.store.entries_for_round(round)?;
            // every re-pull downloaded these bytes, complete or not
            ctx.record_pull(&entries);
            if entries.len() >= ctx.n_nodes {
                break entries;
            }
            let elapsed = ctx.clock.now().saturating_sub(t_wait);
            if elapsed >= ctx.sync_timeout {
                ctx.timeline.record(SpanKind::Wait, t_wait, ctx.clock.now());
                out.stalled_at = Some(round);
                return Ok(out);
            }
            ctx.store.wait_for_change(seen, ctx.sync_timeout - elapsed)?;
        };
        ctx.timeline.record(SpanKind::Wait, t_wait, ctx.clock.now());

        let t_agg = ctx.clock.now();
        let contribs: Vec<Contribution> = entries
            .iter()
            .map(|e| Contribution {
                node_id: e.node_id,
                n_examples: e.n_examples,
                is_self: e.node_id == ctx.node_id,
                seq: e.seq,
                params: Arc::clone(&e.params),
            })
            .collect();
        if let Some(new_params) = ctx.strategy.aggregate_pooled(&contribs, ctx.pool) {
            *params = new_params;
            out.aggregations = 1;
            // the adopted aggregate is the next push's delta base
            ctx.adopt_aggregate(params, &entries);
        }
        ctx.timeline.record(SpanKind::Aggregate, t_agg, ctx.clock.now());
        Ok(out)
    }
}
