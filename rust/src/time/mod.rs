//! Time virtualization — the [`Clock`] abstraction behind every delay,
//! timeout, and timestamp in the experiment stack.
//!
//! The paper's headline claim is about *time*: asynchronous serverless
//! federation removes the wall-clock bottleneck imposed by slow or
//! fragile clients (§4). Arguing that with real `thread::sleep` calls
//! makes time-to-accuracy experiments slow and timing assertions flaky.
//! This module abstracts the clock behind a trait with two
//! implementations:
//!
//! * [`RealClock`] — wall-clock time: `sleep` is `std::thread::sleep`,
//!   conditions are plain `Condvar`s. The default; behaviour is
//!   identical to the pre-clock code.
//! * [`VirtualClock`] — a discrete-event scheduler. Simulated time
//!   advances **only** when every registered participant thread is
//!   blocked in a clock primitive (a [`Clock::sleep`] or a
//!   [`Condition`] wait); it then jumps straight to the earliest
//!   pending deadline. A 10-node run with 500 ms/step straggler delays
//!   completes in milliseconds of real time while reporting faithful
//!   simulated wall-clock — and, because time only moves under
//!   unanimity, the simulated timeline is a pure function of the
//!   configuration: repeated runs are bit-identical.
//!
//! Everything time-dependent threads a clock through:
//! the node worker's straggler delay, the simulated-S3
//! [`crate::store::LatencyStore`], the store subscription layer
//! ([`crate::store::WeightStore::wait_for_change`] parks on a
//! [`Condition`]), the sync barrier's `sync_timeout`, and the
//! [`crate::metrics::timeline::Timeline`] spans behind `wall_clock_s`.
//! Select with the `clock = real | virtual` config key or
//! `fedbench ... --virtual-clock`.
//!
//! # Participants
//!
//! A virtual clock must know how many threads are *supposed* to be
//! running, or it would advance time while a node is still mid-compute.
//! [`Clock::enter`] reserves a participant slot (the experiment driver
//! reserves each node's slot before spawning it — see
//! [`crate::node::spawn_node`]), [`Clock::attach`] marks the node's own
//! thread as that participant, and [`Clock::exit`]/[`Clock::detach`]
//! undo both on thread end ([`ParticipantGuard`] makes the pair
//! drop-safe). Only **attached** threads count toward the advance
//! quorum — an unattached thread blocking on the clock (say, a monitor
//! polling the store) parks harmlessly and can never advance time while
//! a node is still computing. Real compute takes zero simulated time;
//! only sleeps and timeouts move the clock. With zero registered
//! participants any blocking call advances immediately, which gives
//! single-threaded use (tests, standalone stores) the obvious
//! semantics.
//!
//! # Determinism caveat
//!
//! Two store operations issued at the *same* simulated instant (e.g.
//! identical per-node delays) still race in real time; their relative
//! order is not fixed by the clock. Scenarios with distinct per-node
//! delays are fully deterministic — the regression tests in
//! `rust/tests/timing.rs` assert bit-identical timelines.

mod real;
mod virtual_clock;

pub use real::RealClock;
pub use virtual_clock::VirtualClock;

use std::sync::Arc;
use std::time::Duration;

/// A source of time plus blocking primitives in that time domain. All
/// methods are thread-safe; `&self` receivers allow `Arc<dyn Clock>`
/// sharing across node threads.
pub trait Clock: Send + Sync {
    /// Elapsed time since this clock's origin (monotone).
    fn now(&self) -> Duration;

    /// Block the calling thread for `d` of this clock's time. A zero
    /// duration returns immediately.
    fn sleep(&self, d: Duration);

    /// Create a condition variable in this clock's time domain (see
    /// [`Condition`]). Waits on it consume simulated time under a
    /// virtual clock and real time under a real one.
    fn condition(&self) -> Arc<dyn Condition>;

    /// Reserve one participant slot (virtual clocks advance only when
    /// all participants are blocked). Callable from any thread — the
    /// experiment driver reserves each node's slot *before* spawning
    /// it. No-op for [`RealClock`].
    fn enter(&self);

    /// Mark the **calling** thread as one of this clock's participant
    /// threads: only attached threads count toward a virtual clock's
    /// advance quorum, so a stray unattached thread blocking on the
    /// clock (e.g. a monitor polling the store) can never advance
    /// simulated time while a node is still computing. Pairs with
    /// [`Clock::detach`]; [`ParticipantGuard`] manages both. No-op for
    /// [`RealClock`].
    fn attach(&self) {}

    /// Unmark the calling thread (inverse of [`Clock::attach`]). No-op
    /// for [`RealClock`].
    fn detach(&self) {}

    /// Release one participant slot (must pair with a prior
    /// [`Clock::enter`]). No-op for [`RealClock`].
    fn exit(&self);
}

/// A clock-domain condition variable with an epoch counter instead of a
/// guarded predicate: [`Condition::notify_all`] advances the epoch and
/// wakes every waiter, and [`Condition::wait_past`] parks until the
/// epoch exceeds a caller-held token or a timeout (in the owning
/// clock's time) elapses.
///
/// The token protocol makes the check-then-wait race benign: read
/// [`Condition::epoch`] *before* checking your predicate, and a notify
/// that lands in between turns the subsequent `wait_past` into an
/// immediate return instead of a lost wake-up. Spurious returns are
/// allowed — callers re-check their predicate in a loop.
pub trait Condition: Send + Sync {
    /// Current notification epoch (monotone; advances on every
    /// [`Condition::notify_all`]).
    fn epoch(&self) -> u64;

    /// Park until `epoch() > seen` or `timeout` of the owning clock's
    /// time elapses. May return spuriously.
    fn wait_past(&self, seen: u64, timeout: Duration);

    /// Advance the epoch and wake every parked waiter.
    fn notify_all(&self);
}

/// Which [`Clock`] an experiment runs under — the config-level selector
/// (`clock = real | virtual`), parallel to `StoreKind` for stores.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClockKind {
    /// Wall-clock time ([`RealClock`]); the default.
    #[default]
    Real,
    /// Discrete-event simulated time ([`VirtualClock`]): straggler and
    /// latency sleeps complete instantly in real time, `wall_clock_s`
    /// reports simulated seconds, and timelines are deterministic.
    Virtual,
}

impl ClockKind {
    /// Parse a config/CLI value: `real` or `virtual`.
    pub fn parse(s: &str) -> Option<ClockKind> {
        match s.to_ascii_lowercase().as_str() {
            "real" => Some(ClockKind::Real),
            "virtual" => Some(ClockKind::Virtual),
            _ => None,
        }
    }

    /// Canonical lowercase name (inverse of [`ClockKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            ClockKind::Real => "real",
            ClockKind::Virtual => "virtual",
        }
    }

    /// Build a fresh clock of this kind (origin = now). Each experiment
    /// gets its own instance so timeline offsets start near zero.
    pub fn build(self) -> Arc<dyn Clock> {
        match self {
            ClockKind::Real => Arc::new(RealClock::new()),
            ClockKind::Virtual => Arc::new(VirtualClock::new()),
        }
    }
}

/// RAII participant registration: calls [`Clock::exit`] on drop, so a
/// node thread deregisters even when it crashes, errors, or panics.
pub struct ParticipantGuard {
    clock: Arc<dyn Clock>,
}

impl ParticipantGuard {
    /// Reserve a participant slot, attach the calling thread to it, and
    /// guard both.
    pub fn enter(clock: Arc<dyn Clock>) -> ParticipantGuard {
        clock.enter();
        clock.attach();
        ParticipantGuard { clock }
    }

    /// Attach the calling thread to a slot reserved earlier by someone
    /// else (e.g. the driver calling [`Clock::enter`] before spawning
    /// the node thread) and guard it.
    pub fn adopt(clock: Arc<dyn Clock>) -> ParticipantGuard {
        clock.attach();
        ParticipantGuard { clock }
    }
}

impl Drop for ParticipantGuard {
    fn drop(&mut self) {
        self.clock.detach();
        self.clock.exit();
    }
}

#[cfg(test)]
pub(crate) mod clock_tests {
    //! Conformance suite shared by [`RealClock`] and [`VirtualClock`]
    //! (mirroring the store subscription-conformance pattern): monotone
    //! `now()`, `sleep` ordering, and park/notify wake-ups behave
    //! identically in both time domains.

    use std::sync::Arc;
    use std::time::Duration;

    use super::*;

    pub fn conformance(clock: Arc<dyn Clock>) {
        // now() is monotone
        let t0 = clock.now();
        let t1 = clock.now();
        assert!(t1 >= t0, "now must be monotone");

        // sleep(0) is a no-op that returns
        clock.sleep(Duration::ZERO);

        // sleep(d) advances now() by at least d
        let before = clock.now();
        clock.sleep(Duration::from_millis(30));
        let after = clock.now();
        assert!(
            after.saturating_sub(before) >= Duration::from_millis(30),
            "sleep must advance the clock by at least the slept duration \
             ({before:?} -> {after:?})"
        );

        // park/notify: a waiter parked with a long timeout wakes on a
        // peer's notify, at the peer's (clock-domain) notify instant.
        let cond = clock.condition();
        let tok = cond.epoch();
        clock.enter(); // waiter
        clock.enter(); // notifier
        std::thread::scope(|scope| {
            let waiter = {
                let clock = Arc::clone(&clock);
                let cond = Arc::clone(&cond);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    let t0 = clock.now();
                    cond.wait_past(tok, Duration::from_secs(60));
                    (t0, clock.now(), cond.epoch())
                })
            };
            let notifier = {
                let clock = Arc::clone(&clock);
                let cond = Arc::clone(&cond);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    clock.sleep(Duration::from_millis(20));
                    cond.notify_all();
                })
            };
            notifier.join().unwrap();
            let (t0, t_wake, epoch) = waiter.join().unwrap();
            assert!(epoch > tok, "waiter must observe the notify epoch");
            assert!(
                t_wake.saturating_sub(t0) < Duration::from_secs(30),
                "waiter must wake on the notify, not ride out the timeout"
            );
        });

        // clean timeout: an unnotified wait consumes exactly-at-least
        // its timeout of clock time, then returns
        let cond = clock.condition();
        let tok = cond.epoch();
        let t0 = clock.now();
        cond.wait_past(tok, Duration::from_millis(25));
        assert!(
            clock.now().saturating_sub(t0) >= Duration::from_millis(25),
            "clean timeout must consume the full timeout of clock time"
        );
        assert_eq!(cond.epoch(), tok, "no notify happened");

        // a notify that lands before the wait (stale token) returns
        // immediately instead of being lost
        let cond = clock.condition();
        let tok = cond.epoch();
        cond.notify_all();
        let t0 = clock.now();
        cond.wait_past(tok, Duration::from_secs(60));
        assert!(
            clock.now().saturating_sub(t0) < Duration::from_secs(30),
            "a pre-wait notify must not be lost"
        );
    }

    #[test]
    fn clock_kind_parse_and_name() {
        assert_eq!(ClockKind::parse("real"), Some(ClockKind::Real));
        assert_eq!(ClockKind::parse("VIRTUAL"), Some(ClockKind::Virtual));
        assert_eq!(ClockKind::parse("simulated"), None);
        assert_eq!(ClockKind::Real.name(), "real");
        assert_eq!(ClockKind::Virtual.name(), "virtual");
        assert_eq!(ClockKind::default(), ClockKind::Real);
        for kind in [ClockKind::Real, ClockKind::Virtual] {
            assert_eq!(ClockKind::parse(kind.name()), Some(kind));
        }
    }

    #[test]
    fn real_clock_conformance() {
        conformance(Arc::new(RealClock::new()));
    }

    #[test]
    fn virtual_clock_conformance() {
        conformance(Arc::new(VirtualClock::new()));
    }
}
