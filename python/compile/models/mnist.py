"""MNIST CNN (paper §4.2): two conv layers with max pooling and ReLU.

"It consists of two convolutional layers with max pooling and ReLU
activation. We used the Adam optimizer with a fixed learning rate of 1e-3,
a batch size of 32, 1200 steps per epoch for 3 epochs."
"""

import jax
import jax.numpy as jnp

from . import common as c

NUM_CLASSES = 10
INPUT_SHAPE = (28, 28, 1)


def init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "conv1": c.conv_init(k1, 3, 3, 1, 16),
        "conv2": c.conv_init(k2, 3, 3, 16, 32),
        # two 2x2 pools: 28 -> 14 -> 7
        "head": c.dense_init(k3, 7 * 7 * 32, NUM_CLASSES),
    }


def apply(params, x, train=False):
    """x: f32[B, 28, 28, 1] -> logits f32[B, 10]."""
    del train  # no dropout/batchnorm in this model
    h = jax.nn.relu(c.conv2d(params["conv1"], x))
    h = c.max_pool(h)
    h = jax.nn.relu(c.conv2d(params["conv2"], h))
    h = c.max_pool(h)
    h = h.reshape(h.shape[0], -1)
    return c.dense(params["head"], h)


def loss_and_metrics(params, batch, train=False):
    x, y = batch
    logits = apply(params, x, train)
    return c.softmax_xent(logits, y), c.accuracy_count(logits, y)
