//! [`DeltaQ8`] — delta against a pulled base, then int8 quantization
//! (codec id 3).

use anyhow::{bail, Result};

use crate::par::ChunkPool;
use crate::tensor::flat::PAR_CHUNK;
use crate::tensor::FlatParams;

use super::q8::{q8_decode_pooled, q8_encode_pooled, q8_error_bound};
use super::{Codec, CodecKind};

/// Payload flag: self-contained full quantization (no base used).
const FLAG_FULL: u8 = 0;
/// Payload flag: quantized delta against the base vector.
const FLAG_DELTA: u8 = 1;

/// Delta codec: encode `params - base` with the [`super::Q8`] quantizer
/// (weight *changes* between federation rounds have a far tighter range
/// than the weights themselves, so the same 8 bits buy much finer
/// resolution). Falls back to a full Q8 encoding — flagged in the first
/// payload byte — whenever the base is missing or shape-mismatched, so
/// a cold start or a model resize never fails a push.
///
/// Wire cost: `1 + n + 8 · ceil(n / 256)` bytes, same as [`super::Q8`]
/// plus the flag byte. Error bound (per element): half a quantization
/// step of the *encoded* vector — the delta in delta mode, the raw
/// params in fallback mode.
///
/// Both directions run chunk-parallel: the delta subtraction / base
/// re-addition split on fixed [`PAR_CHUNK`] boundaries and the quantizer
/// on its own fixed chunks, so payloads and reconstructions are
/// bit-identical for any thread count.
pub struct DeltaQ8;

fn usable_base<'a>(params: &FlatParams, base: Option<&'a FlatParams>) -> Option<&'a FlatParams> {
    base.filter(|b| b.len() == params.len())
}

impl Codec for DeltaQ8 {
    fn kind(&self) -> CodecKind {
        CodecKind::DeltaQ8
    }

    fn encode_pooled(
        &self,
        params: &FlatParams,
        base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Vec<u8> {
        match usable_base(params, base) {
            Some(b) => {
                let mut delta = vec![0.0f32; params.len()];
                let items: Vec<((&mut [f32], &[f32]), &[f32])> = delta
                    .chunks_mut(PAR_CHUNK)
                    .zip(params.as_slice().chunks(PAR_CHUNK))
                    .zip(b.as_slice().chunks(PAR_CHUNK))
                    .collect();
                pool.for_each(items, |_, ((d, x), y)| {
                    for ((d, &x), &y) in d.iter_mut().zip(x).zip(y) {
                        *d = x - y;
                    }
                });
                let mut out = q8_encode_pooled(&delta, pool);
                out.insert(0, FLAG_DELTA);
                out
            }
            None => {
                let mut out = q8_encode_pooled(params.as_slice(), pool);
                out.insert(0, FLAG_FULL);
                out
            }
        }
    }

    fn decode_pooled(
        &self,
        payload: &[u8],
        n: usize,
        base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Result<FlatParams> {
        let Some((&flag, body)) = payload.split_first() else {
            bail!("delta-q8 payload is empty");
        };
        match flag {
            FLAG_FULL => Ok(FlatParams(q8_decode_pooled(body, n, pool)?)),
            FLAG_DELTA => {
                let Some(b) = base.filter(|b| b.len() == n) else {
                    bail!(
                        "delta-q8 payload needs an {n}-element base to decode \
                         (got {:?})",
                        base.map(FlatParams::len)
                    );
                };
                let mut delta = q8_decode_pooled(body, n, pool)?;
                let items: Vec<(&mut [f32], &[f32])> =
                    delta.chunks_mut(PAR_CHUNK).zip(b.as_slice().chunks(PAR_CHUNK)).collect();
                pool.for_each(items, |_, (d, y)| {
                    for (d, &y) in d.iter_mut().zip(y) {
                        *d = y + *d;
                    }
                });
                Ok(FlatParams(delta))
            }
            other => bail!("unknown delta-q8 flag byte {other}"),
        }
    }

    fn error_bound(&self, params: &FlatParams, base: Option<&FlatParams>) -> f32 {
        match usable_base(params, base) {
            Some(b) => {
                let delta: Vec<f32> =
                    params.as_slice().iter().zip(b.as_slice()).map(|(x, y)| x - y).collect();
                // the reconstruction adds the exact base back: the error
                // is the delta's quantization plus one f32 add's rounding,
                // which scales with the base's magnitude
                let base_mag = b.as_slice().iter().fold(0.0f32, |m, x| m.max(x.abs()));
                q8_error_bound(&delta) + base_mag * f32::EPSILON
            }
            None => q8_error_bound(params.as_slice()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(n: usize, seed: f32) -> FlatParams {
        FlatParams((0..n).map(|i| ((i as f32) * 0.13 + seed).sin()).collect())
    }

    #[test]
    fn without_base_behaves_like_q8_plus_flag() {
        let p = params(700, 0.0);
        let enc = DeltaQ8.encode(&p, None);
        assert_eq!(enc[0], FLAG_FULL);
        assert_eq!(enc.len(), 1 + 700 + 8 * 3);
        let dec = DeltaQ8.decode(&enc, 700, None).unwrap();
        assert!(p.max_abs_diff(&dec) <= DeltaQ8.error_bound(&p, None));
    }

    #[test]
    fn shape_mismatched_base_falls_back_to_full() {
        let p = params(100, 0.0);
        let wrong = params(64, 1.0);
        let enc = DeltaQ8.encode(&p, Some(&wrong));
        assert_eq!(enc[0], FLAG_FULL, "mismatched base must not be used");
        // full-mode payloads decode without any base at all
        assert!(DeltaQ8.decode(&enc, 100, None).is_ok());
    }

    #[test]
    fn delta_mode_is_much_finer_than_full_q8_near_the_base() {
        let base = params(2_000, 0.0);
        // a small training step away from the base
        let p = FlatParams(
            base.0.iter().enumerate().map(|(i, x)| x + 1e-3 * ((i % 5) as f32 - 2.0)).collect(),
        );
        let enc = DeltaQ8.encode(&p, Some(&base));
        assert_eq!(enc[0], FLAG_DELTA);
        let dec = DeltaQ8.decode(&enc, 2_000, Some(&base)).unwrap();
        let bound = DeltaQ8.error_bound(&p, Some(&base));
        assert!(p.max_abs_diff(&dec) <= bound, "{} > {}", p.max_abs_diff(&dec), bound);
        // delta range is ~4e-3 vs the params' ~2: the bound tightens by
        // orders of magnitude
        let full_bound = DeltaQ8.error_bound(&p, None);
        assert!(bound < full_bound / 50.0, "delta {bound} vs full {full_bound}");
    }

    #[test]
    fn pooled_delta_round_trip_matches_sequential_bitwise() {
        let n = 2 * PAR_CHUNK + 77;
        let base = params(n, 0.0);
        let p = FlatParams(base.0.iter().map(|x| x + 2e-3).collect());
        let enc_seq = DeltaQ8.encode(&p, Some(&base));
        let dec_seq = DeltaQ8.decode(&enc_seq, n, Some(&base)).unwrap();
        for threads in [2, 8] {
            let pool = ChunkPool::new(threads);
            assert_eq!(
                DeltaQ8.encode_pooled(&p, Some(&base), pool),
                enc_seq,
                "threads={threads}"
            );
            let dec_par = DeltaQ8.decode_pooled(&enc_seq, n, Some(&base), pool).unwrap();
            assert_eq!(
                dec_seq.0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                dec_par.0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn delta_payload_without_base_errors_cleanly() {
        let base = params(64, 0.0);
        let p = params(64, 0.01);
        let enc = DeltaQ8.encode(&p, Some(&base));
        assert_eq!(enc[0], FLAG_DELTA);
        assert!(DeltaQ8.decode(&enc, 64, None).is_err());
        let wrong = params(32, 0.0);
        assert!(DeltaQ8.decode(&enc, 64, Some(&wrong)).is_err());
    }

    #[test]
    fn malformed_payloads_error() {
        assert!(DeltaQ8.decode(&[], 4, None).is_err());
        assert!(DeltaQ8.decode(&[7, 0, 0], 4, None).is_err(), "unknown flag");
        let enc = DeltaQ8.encode(&params(10, 0.0), None);
        assert!(DeltaQ8.decode(&enc[..enc.len() - 1], 10, None).is_err());
    }
}
