//! The node thread body: local training plus federation through the
//! pluggable protocol layer.
//!
//! The protocol logic itself (sync barrier, async Algorithm 1, gossip,
//! local baseline) lives in [`crate::protocol`]; this thread only trains
//! `steps_per_epoch` local steps per epoch, hands its weights to
//! [`crate::protocol::FederationProtocol::after_epoch`], and folds the
//! [`crate::protocol::ProtocolOutcome`] into its [`NodeReport`]. Crash
//! injection and run logging are worker concerns and stay here.
//!
//! All delays, timeouts, and timeline stamps go through the experiment's
//! [`crate::time::Clock`]: under a virtual clock the straggler
//! `node_delays_ms` sleeps consume *simulated* time, so a delay grid
//! runs at CPU speed while the reported timelines stay faithful.

use std::sync::Arc;
use std::time::Duration;

use crate::compress::CodecState;
use crate::config::ExperimentConfig;
use crate::data::BatchLoader;
use crate::metrics::timeline::{SpanKind, Timeline};
use crate::metrics::RunLogger;
use crate::protocol::{EpochCtx, ProtocolKind};
use crate::runtime::{Engine, Manifest, ModelBundle, TrainState};
use crate::store::WeightStore;
use crate::strategy::Strategy;
use crate::time::{Clock, ParticipantGuard};

use super::{NodeHandle, NodeReport, NodeStatus};

/// Everything a node thread needs (moved into the thread).
pub struct NodeCtx {
    /// This node's id (also its index into per-node config vectors).
    pub node_id: usize,
    /// The experiment configuration (shared, read-only).
    pub cfg: Arc<ExperimentConfig>,
    /// Artifact manifest for loading the model bundle.
    pub manifest: Arc<Manifest>,
    /// The weight store shared by all nodes of the experiment.
    pub store: Arc<dyn WeightStore>,
    /// This node's own aggregation strategy instance (client-side state).
    pub strategy: Box<dyn Strategy>,
    /// Batch loader over this node's data shard.
    pub loader: BatchLoader,
    /// The experiment's shared clock (timeline origin, straggler delays,
    /// barrier timeouts).
    pub clock: Arc<dyn Clock>,
    /// Shared start barrier so all nodes begin epoch 0 together.
    pub start: Arc<std::sync::Barrier>,
    /// Optional shared run logger (CSV metrics + JSONL events).
    pub logger: Option<Arc<RunLogger>>,
}

/// Spawn the node thread.
pub fn spawn_node(ctx: NodeCtx) -> NodeHandle {
    let node_id = ctx.node_id;
    // Register with the clock *before* the thread exists: a virtual
    // clock must know every participant up front, or it could advance
    // simulated time while later nodes are still spawning.
    ctx.clock.enter();
    let join = std::thread::Builder::new()
        .name(format!("fed-node-{node_id}"))
        .spawn(move || run_node(ctx))
        .expect("spawn node thread");
    NodeHandle { node_id, join }
}

fn run_node(mut ctx: NodeCtx) -> NodeReport {
    // Adopt the registration made by spawn_node; dropping the guard
    // deregisters on every exit path (completion, crash, error, panic),
    // so a dead node never freezes a virtual clock.
    let _participant = ParticipantGuard::adopt(Arc::clone(&ctx.clock));
    let mut timeline = Timeline::new(ctx.node_id);
    let mut report = NodeReport {
        node_id: ctx.node_id,
        status: NodeStatus::Completed,
        epochs_done: 0,
        final_params: None,
        // set from the manifest in run_node_inner; an unknown model is a
        // hard error there, never a silently wrong default weight
        n_examples_per_epoch: 0,
        epoch_losses: vec![],
        epoch_accs: vec![],
        aggregations: 0,
        pushes: 0,
        timeline: Timeline::new(ctx.node_id),
        train_time: Duration::ZERO,
        wait_time: Duration::ZERO,
    };

    match run_node_inner(&mut ctx, &mut report, &mut timeline) {
        Ok(()) => {}
        Err(e) => {
            if report.status == NodeStatus::Completed {
                report.status = NodeStatus::Failed(format!("{e:#}"));
            }
        }
    }
    report.train_time = timeline.total(SpanKind::Train);
    report.wait_time = timeline.total(SpanKind::Wait);
    report.timeline = timeline;
    report
}

fn run_node_inner(
    ctx: &mut NodeCtx,
    report: &mut NodeReport,
    timeline: &mut Timeline,
) -> anyhow::Result<()> {
    let cfg = Arc::clone(&ctx.cfg);
    let clock = Arc::clone(&ctx.clock);
    let info = ctx.manifest.model(&cfg.model)?.clone();
    // n_k: examples this node trains on per epoch (the FedAvg weight
    // numerator), from the manifest's authoritative batch size
    report.n_examples_per_epoch = (cfg.steps_per_epoch * info.batch_size) as u64;
    let engine = Engine::new()?;
    let bundle = ModelBundle::load(&engine, &info)?;

    // Same seed on every node -> identical w_0 ("initialize w_0",
    // Algorithm 1).
    let params = bundle.init_params(cfg.seed)?;
    let mut state = TrainState::new(params);
    let mut protocol = ProtocolKind::from(cfg.mode).build(ctx.node_id, &cfg);
    // the node's kernel pool (threads = auto | N): codec encode/decode
    // and strategy aggregation below run chunk-parallel on it, with
    // results bit-identical to threads = 1
    let pool = crate::par::ChunkPool::from_config(cfg.threads);
    // per-node wire codec state (compress = none | q8 | topk:<f> |
    // delta-q8): every push below runs through it
    let mut codec = CodecState::new(cfg.compress);

    let step_delay = cfg
        .node_delays_ms
        .get(ctx.node_id)
        .copied()
        .map(|ms| Duration::from_secs_f64(ms / 1000.0))
        .unwrap_or(Duration::ZERO);

    ctx.start.wait();

    for epoch in 0..cfg.epochs {
        if let Some(crash) = &cfg.crash {
            if crash.node == ctx.node_id && crash.at_epoch == epoch {
                report.status = NodeStatus::Crashed { at_epoch: epoch };
                if let Some(lg) = &ctx.logger {
                    let _ = lg.log_event(
                        "node_crash",
                        &[("node", ctx.node_id.to_string()), ("epoch", epoch.to_string())],
                    );
                }
                let t = clock.now();
                timeline.record(SpanKind::Crashed, t, t);
                return Ok(());
            }
        }

        // ---- local training -------------------------------------------
        let t_train = clock.now();
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        bundle.run_steps(&mut state, &mut ctx.loader, cfg.steps_per_epoch, |_i, m| {
            loss_sum += m.loss as f64;
            acc_sum += m.acc_count as f64 / m.n_preds as f64;
            // Straggler simulation: per-step delay on the experiment
            // clock (instant real time under a virtual clock).
            clock.sleep(step_delay);
        })?;
        timeline.record(SpanKind::Train, t_train, clock.now());
        let mean_loss = loss_sum / cfg.steps_per_epoch as f64;
        let mean_acc = acc_sum / cfg.steps_per_epoch as f64;
        report.epoch_losses.push(mean_loss);
        report.epoch_accs.push(mean_acc);
        report.epochs_done = epoch + 1;
        if let Some(lg) = &ctx.logger {
            let _ = lg.log_metrics(&[
                ("node", ctx.node_id as f64),
                ("epoch", epoch as f64),
                ("train_loss", mean_loss),
                ("train_acc", mean_acc),
                ("elapsed_s", clock.now().as_secs_f64()),
            ]);
        }
        if cfg.verbose {
            eprintln!(
                "[node {} epoch {}] loss={mean_loss:.4} acc={mean_acc:.4}",
                ctx.node_id, epoch
            );
        }

        // ---- federation (protocol layer) -------------------------------
        let mut pctx = EpochCtx {
            node_id: ctx.node_id,
            n_nodes: cfg.n_nodes,
            epoch,
            n_examples: report.n_examples_per_epoch,
            store: ctx.store.as_ref(),
            strategy: ctx.strategy.as_mut(),
            timeline: &mut *timeline,
            sync_timeout: cfg.sync_timeout,
            clock: clock.as_ref(),
            codec: &mut codec,
            pool,
        };
        let out = protocol.after_epoch(&mut pctx, &mut state.params)?;
        report.pushes += out.pushes;
        report.aggregations += out.aggregations;
        if let Some(round) = out.stalled_at {
            // The node is stuck at the barrier, not dead: its current
            // weights still exist (and were pushed), so report them — the
            // driver can evaluate what training achieved before the stall.
            report.status = NodeStatus::Stalled { at_round: round };
            if let Some(lg) = &ctx.logger {
                let _ = lg.log_event(
                    "sync_stall",
                    &[("node", ctx.node_id.to_string()), ("round", round.to_string())],
                );
            }
            report.final_params = Some(state.params.clone());
            return Ok(());
        }
    }

    report.final_params = Some(state.params.clone());
    Ok(())
}
