//! Minimal recursive-descent JSON parser — just enough to read
//! `artifacts/manifest.json` (the build image has no serde). Supports the
//! full JSON grammar minus `\u` surrogate pairs (unneeded for the manifest).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A JSON string.
    Str(String),
    /// A JSON array.
    Arr(Vec<Json>),
    /// A JSON object (key order normalized by the map).
    Obj(BTreeMap<String, Json>),
}

/// A parse error with the byte offset of the problem.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset into the input where parsing failed.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key → value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through intact)
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"version": 1, "models": {"mnist": {"param_count": 20490,
               "artifacts": {"train": {"file": "mnist_train.hlo.txt"}}}}}"#,
        )
        .unwrap();
        let m = j.get("models").unwrap().get("mnist").unwrap();
        assert_eq!(m.get("param_count").unwrap().as_usize(), Some(20490));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
