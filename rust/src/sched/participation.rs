//! Partial participation and availability traces — which clients take
//! part in which round.
//!
//! Cross-device FL never sees the whole fleet at once: FedLess-style
//! serverless clients are *sampled* into per-round cohorts, and
//! syft-flwr-style device fleets churn offline, follow diurnal cycles,
//! and harbor persistent stragglers. Both effects are modeled here as
//! pure seeded functions of `(seed, node, round)` so every node — and
//! every replay — computes the identical schedule with no coordinator,
//! preserving the serverless narrative *and* bit-exact determinism.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::Rng;

/// Mixing constants for keying per-node / per-round RNG streams (the
/// same idiom as [`crate::protocol::gossip_peers`]).
const MIX_NODE: u64 = 0x9E37_79B9_7F4A_7C15;
const MIX_ROUND: u64 = 0xD1B5_4A32_D192_ED03;
/// Tag separating the straggler-assignment stream from churn/diurnal.
const TAG_STRAGGLER: u64 = 0x5EED_5EED_5EED_5EED;

/// Per-node availability over rounds (`availability = <spec>` config
/// key). All variants are pure functions of `(seed, node, round)`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum AvailabilitySpec {
    /// Everyone is always online (the default).
    #[default]
    None,
    /// I.i.d. churn: each node is independently offline in each round
    /// with probability `p`.
    Churn {
        /// Per-round offline probability, in `[0, 1)`.
        p: f64,
    },
    /// Diurnal cycle: each node gets a seeded phase offset and is online
    /// for the first half of every `period`-round cycle — a fleet
    /// spread over time zones.
    Diurnal {
        /// Cycle length in rounds (>= 2).
        period: usize,
    },
    /// Persistent stragglers: a seeded `frac` of nodes run every
    /// training step `mult`× slower; everyone stays online.
    Stragglers {
        /// Fraction of the fleet that straggles, in `[0, 1]`.
        frac: f64,
        /// Step-delay multiplier for straggler nodes (>= 1).
        mult: f64,
    },
}

impl AvailabilitySpec {
    /// Parse a config/CLI value: `none`, `churn:<p>`, `diurnal:<period>`
    /// or `stragglers:<frac>:<mult>`. Range checks live in config
    /// validation, not here.
    pub fn parse(s: &str) -> Option<AvailabilitySpec> {
        let s = s.trim().to_ascii_lowercase();
        if s == "none" {
            return Some(AvailabilitySpec::None);
        }
        if let Some(p) = s.strip_prefix("churn:") {
            return p.parse().ok().map(|p| AvailabilitySpec::Churn { p });
        }
        if let Some(period) = s.strip_prefix("diurnal:") {
            return period.parse().ok().map(|period| AvailabilitySpec::Diurnal { period });
        }
        if let Some(rest) = s.strip_prefix("stragglers:") {
            let (frac, mult) = rest.split_once(':')?;
            return Some(AvailabilitySpec::Stragglers {
                frac: frac.parse().ok()?,
                mult: mult.parse().ok()?,
            });
        }
        None
    }

    /// Run-name fragment: empty for [`AvailabilitySpec::None`], else
    /// `churn<p>` / `diurnal<period>` / `strag<frac>x<mult>`.
    pub fn label(&self) -> String {
        match self {
            AvailabilitySpec::None => String::new(),
            AvailabilitySpec::Churn { p } => format!("churn{p}"),
            AvailabilitySpec::Diurnal { period } => format!("diurnal{period}"),
            AvailabilitySpec::Stragglers { frac, mult } => format!("strag{frac}x{mult}"),
        }
    }

    /// Is `node` reachable in `round`? Pure in `(seed, node, round)`.
    pub fn is_online(&self, seed: u64, node: usize, round: usize) -> bool {
        match *self {
            AvailabilitySpec::None | AvailabilitySpec::Stragglers { .. } => true,
            AvailabilitySpec::Churn { p } => {
                let mut rng = Rng::new(
                    seed ^ (node as u64 + 1).wrapping_mul(MIX_NODE)
                        ^ (round as u64 + 1).wrapping_mul(MIX_ROUND),
                );
                !rng.chance(p)
            }
            AvailabilitySpec::Diurnal { period } => {
                let phase = Rng::new(seed ^ (node as u64 + 1).wrapping_mul(MIX_NODE))
                    .below(period.max(1));
                (round + phase) % period.max(1) < period.max(1).div_ceil(2)
            }
        }
    }

    /// Step-delay multiplier for `node` (>= 1; persistent across the
    /// trial). Only [`AvailabilitySpec::Stragglers`] deviates from 1.
    pub fn delay_multiplier(&self, seed: u64, node: usize) -> f64 {
        match *self {
            AvailabilitySpec::Stragglers { frac, mult } => {
                let mut rng = Rng::new(
                    seed ^ (node as u64 + 1).wrapping_mul(MIX_NODE) ^ TAG_STRAGGLER,
                );
                if rng.chance(frac) {
                    mult
                } else {
                    1.0
                }
            }
            _ => 1.0,
        }
    }
}

/// One round's sampled cohort: sorted member list plus a membership
/// bitmap for O(1) `participates` checks.
struct CohortInfo {
    members: Vec<usize>,
    member_set: Vec<bool>,
}

/// The trial's participation schedule: a seeded per-round cohort of
/// `k = round(participation · n)` online clients.
///
/// The cohort is a pure function of `(seed, round)` — every node
/// computes the same answer, so the fleet agrees on each round's barrier
/// fan-in ([`crate::protocol::EpochCtx::round_k`]) without any
/// coordinator. The `Mutex` cache is purely an implementation detail of
/// that pure function: one `Arc<ParticipationPlan>` is shared by all
/// node runners so the O(n) shuffle runs once per round instead of once
/// per node per round (3·10⁸ ops at 10k nodes).
pub struct ParticipationPlan {
    participation: f64,
    availability: AvailabilitySpec,
    seed: u64,
    n_nodes: usize,
    cohorts: Mutex<HashMap<usize, Arc<CohortInfo>>>,
}

impl ParticipationPlan {
    /// A plan for `n_nodes` clients; `participation` in `(0, 1]` (config
    /// validation enforces the range).
    pub fn new(
        participation: f64,
        availability: AvailabilitySpec,
        seed: u64,
        n_nodes: usize,
    ) -> ParticipationPlan {
        ParticipationPlan {
            participation,
            availability,
            seed,
            n_nodes,
            cohorts: Mutex::new(HashMap::new()),
        }
    }

    /// Does the whole fleet participate in every round? (The default
    /// config; lets the hot paths skip cohort computation entirely.)
    fn is_full(&self) -> bool {
        self.participation >= 1.0 && self.availability == AvailabilitySpec::None
    }

    fn cohort(&self, round: usize) -> Arc<CohortInfo> {
        let mut cache = self.cohorts.lock().expect("cohort cache poisoned");
        if let Some(c) = cache.get(&round) {
            return Arc::clone(c);
        }
        // available set under the trace, then a seeded k-of-available
        // sample (shuffle + truncate + sort, the gossip_peers idiom)
        let mut available: Vec<usize> = (0..self.n_nodes)
            .filter(|&n| self.availability.is_online(self.seed, n, round))
            .collect();
        let k = ((self.participation * self.n_nodes as f64).round() as usize)
            .max(1)
            .min(available.len());
        let mut rng =
            Rng::new(self.seed ^ (round as u64 + 1).wrapping_mul(MIX_ROUND));
        rng.shuffle(&mut available);
        available.truncate(k);
        available.sort_unstable();
        let mut member_set = vec![false; self.n_nodes];
        for &m in &available {
            member_set[m] = true;
        }
        let info = Arc::new(CohortInfo { members: available, member_set });
        cache.insert(round, Arc::clone(&info));
        info
    }

    /// Is `node` in `round`'s cohort?
    pub fn participates(&self, node: usize, round: usize) -> bool {
        if self.is_full() {
            return true;
        }
        self.cohort(round).member_set.get(node).copied().unwrap_or(false)
    }

    /// This round's cohort size — the sync barrier's fan-in
    /// ([`crate::protocol::EpochCtx::round_k`]).
    pub fn round_k(&self, round: usize) -> usize {
        if self.is_full() {
            return self.n_nodes;
        }
        self.cohort(round).members.len()
    }

    /// Sorted member list of `round`'s cohort (tests, reporting).
    pub fn members(&self, round: usize) -> Vec<usize> {
        if self.is_full() {
            return (0..self.n_nodes).collect();
        }
        self.cohort(round).members.clone()
    }

    /// The node's persistent step-delay multiplier (straggler traces).
    pub fn delay_multiplier(&self, node: usize) -> f64 {
        self.availability.delay_multiplier(self.seed, node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_and_label_round_trip() {
        assert_eq!(AvailabilitySpec::parse("none"), Some(AvailabilitySpec::None));
        assert_eq!(
            AvailabilitySpec::parse("churn:0.3"),
            Some(AvailabilitySpec::Churn { p: 0.3 })
        );
        assert_eq!(
            AvailabilitySpec::parse("diurnal:8"),
            Some(AvailabilitySpec::Diurnal { period: 8 })
        );
        assert_eq!(
            AvailabilitySpec::parse("stragglers:0.2:10"),
            Some(AvailabilitySpec::Stragglers { frac: 0.2, mult: 10.0 })
        );
        assert_eq!(AvailabilitySpec::parse("weekly:3"), None);
        assert_eq!(AvailabilitySpec::parse("churn:x"), None);
        assert_eq!(AvailabilitySpec::parse("stragglers:0.2"), None);

        assert_eq!(AvailabilitySpec::None.label(), "");
        assert_eq!(AvailabilitySpec::Churn { p: 0.3 }.label(), "churn0.3");
        assert_eq!(AvailabilitySpec::Diurnal { period: 8 }.label(), "diurnal8");
        assert_eq!(
            AvailabilitySpec::Stragglers { frac: 0.2, mult: 10.0 }.label(),
            "strag0.2x10"
        );
        assert_eq!(AvailabilitySpec::default(), AvailabilitySpec::None);
    }

    #[test]
    fn full_participation_short_circuits() {
        let plan = ParticipationPlan::new(1.0, AvailabilitySpec::None, 42, 5);
        for round in 0..4 {
            assert_eq!(plan.round_k(round), 5);
            assert_eq!(plan.members(round), vec![0, 1, 2, 3, 4]);
            for node in 0..5 {
                assert!(plan.participates(node, round));
                assert_eq!(plan.delay_multiplier(node), 1.0);
            }
        }
    }

    #[test]
    fn cohorts_are_seeded_sized_and_vary_by_round() {
        let plan = ParticipationPlan::new(0.3, AvailabilitySpec::None, 42, 100);
        let twin = ParticipationPlan::new(0.3, AvailabilitySpec::None, 42, 100);
        let mut distinct = false;
        for round in 0..6 {
            let a = plan.members(round);
            assert_eq!(a, twin.members(round), "pure in (seed, round)");
            assert_eq!(a.len(), 30, "k = round(0.3 * 100)");
            assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
            assert_eq!(plan.round_k(round), 30);
            for &m in &a {
                assert!(plan.participates(m, round));
            }
            let in_cohort = (0..100).filter(|&n| plan.participates(n, round)).count();
            assert_eq!(in_cohort, 30, "bitmap agrees with member list");
            if round > 0 && a != plan.members(0) {
                distinct = true;
            }
        }
        assert!(distinct, "cohorts must vary across rounds");
        let other_seed = ParticipationPlan::new(0.3, AvailabilitySpec::None, 43, 100);
        assert_ne!(plan.members(0), other_seed.members(0), "seed matters");
    }

    #[test]
    fn tiny_fractions_keep_at_least_one_client() {
        let plan = ParticipationPlan::new(0.001, AvailabilitySpec::None, 7, 50);
        assert_eq!(plan.round_k(0), 1, "k is floored at 1");
    }

    #[test]
    fn churn_thins_the_cohort_and_is_deterministic() {
        let avail = AvailabilitySpec::Churn { p: 0.5 };
        let plan = ParticipationPlan::new(1.0, avail, 42, 200);
        let twin = ParticipationPlan::new(1.0, avail, 42, 200);
        let mut sizes = Vec::new();
        for round in 0..5 {
            let m = plan.members(round);
            assert_eq!(m, twin.members(round), "churn trace must replay");
            assert!(m.len() < 200, "some nodes must drop offline");
            assert!(!m.is_empty());
            for &n in &m {
                assert!(avail.is_online(42, n, round));
            }
            sizes.push(m.len());
        }
        // p = 0.5 over 200 nodes: survivor counts hug the binomial mean
        let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        assert!((mean - 100.0).abs() < 25.0, "mean online {mean} far from 100");
    }

    #[test]
    fn diurnal_nodes_alternate_with_per_node_phase() {
        let avail = AvailabilitySpec::Diurnal { period: 4 };
        for node in 0..16 {
            let online: Vec<bool> =
                (0..8).map(|r| avail.is_online(9, node, r)).collect();
            // online exactly half of each 4-round cycle, cycle-periodic
            assert_eq!(online.iter().filter(|&&b| b).count(), 4);
            assert_eq!(&online[..4], &online[4..], "period-4 cycle repeats");
        }
        // phases differ across the fleet: not all nodes share a schedule
        let first: Vec<bool> = (0..4).map(|r| avail.is_online(9, 0, r)).collect();
        assert!(
            (1..16).any(|n| (0..4).map(|r| avail.is_online(9, n, r)).collect::<Vec<_>>() != first),
            "at least one node must be phase-shifted"
        );
    }

    #[test]
    fn stragglers_slow_a_seeded_fraction() {
        let avail = AvailabilitySpec::Stragglers { frac: 0.25, mult: 10.0 };
        let plan = ParticipationPlan::new(1.0, avail, 42, 400);
        let slow = (0..400).filter(|&n| plan.delay_multiplier(n) == 10.0).count();
        let fast = (0..400).filter(|&n| plan.delay_multiplier(n) == 1.0).count();
        assert_eq!(slow + fast, 400, "multiplier is 1 or mult, nothing else");
        assert!((50..=150).contains(&slow), "~25% stragglers, got {slow}");
        // stragglers stay online and in cohorts
        assert_eq!(plan.round_k(0), 400);
        // assignment is persistent and replayable
        let twin = ParticipationPlan::new(1.0, avail, 42, 400);
        for n in 0..400 {
            assert_eq!(plan.delay_multiplier(n), twin.delay_multiplier(n));
        }
    }
}
