//! [`TrustWeighted`] — EMA-of-residual trust weighting (DSFB-style).

use std::collections::BTreeMap;

use crate::par::ChunkPool;
use crate::tensor::flat::weighted_average_pooled;
use crate::tensor::FlatParams;

use super::super::{Contribution, Strategy};
use super::median::sorted_median;
use super::{by_node, per_coordinate, residual_rms};

/// Trust-weighted averaging: each round, score every client by the RMS
/// residual of its update against the coordinate-wise median of the
/// cohort (a robust reference no single client controls), fold the
/// residual into a per-client exponential moving average, and average
/// the updates with weights proportional to `1 / (eps + ema)`,
/// normalized to sum to one.
///
/// A client that keeps pushing outliers sees its EMA rise monotonically
/// toward its residual, so its normalized weight *strictly decreases*
/// round over round while honest clients (near-zero residual) keep full
/// weight — the property test in `rust/tests/robust.rs` pins this. The
/// EMA is applied *before* weighting, so a large outlier is down-weighted
/// already in the round it first appears.
///
/// Per-node state (the EMA map) follows the serverless design: every
/// node keeps its own trust ledger, there is no central scorer.
#[derive(Clone, Debug)]
pub struct TrustWeighted {
    beta: f64,
    eps: f64,
    ema: BTreeMap<usize, f64>,
    last_weights: Vec<(usize, f32)>,
}

impl TrustWeighted {
    /// `beta` — EMA retention per round (0 = memoryless, 1 = frozen);
    /// `eps` — residual floor that caps the trust of a perfect client.
    pub fn new(beta: f64, eps: f64) -> Self {
        TrustWeighted {
            beta: beta.clamp(0.0, 1.0),
            eps: eps.max(f64::MIN_POSITIVE),
            ema: BTreeMap::new(),
            last_weights: Vec::new(),
        }
    }

    /// The normalized `(node_id, weight)` pairs used by the most recent
    /// aggregation, in node-id order. Exposed for the trust property
    /// tests in `rust/tests/robust.rs`.
    pub fn last_weights(&self) -> &[(usize, f32)] {
        &self.last_weights
    }
}

impl Default for TrustWeighted {
    fn default() -> Self {
        TrustWeighted::new(0.5, 1e-3)
    }
}

impl Strategy for TrustWeighted {
    fn name(&self) -> &'static str {
        "trust-weighted"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        if contribs.is_empty() {
            return None;
        }
        let sorted = by_node(contribs);
        let reference = per_coordinate(&sorted, pool, sorted_median);
        let residuals = residual_rms(&sorted, &reference, pool);
        let mut trust = Vec::with_capacity(sorted.len());
        for (c, r) in sorted.iter().zip(&residuals) {
            let e = self.ema.entry(c.node_id).or_insert(0.0);
            *e = self.beta * *e + (1.0 - self.beta) * *r;
            trust.push(1.0 / (self.eps + *e));
        }
        let total: f64 = trust.iter().sum();
        let weights: Vec<f32> = trust.iter().map(|t| (t / total) as f32).collect();
        let refs: Vec<&FlatParams> = sorted.iter().map(|c| c.params.as_ref()).collect();
        let out = weighted_average_pooled(&refs, &weights, pool);
        self.last_weights =
            sorted.iter().map(|c| c.node_id).zip(weights.iter().copied()).collect();
        Some(out)
    }

    fn reset(&mut self) {
        self.ema.clear();
        self.last_weights.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::strategy_tests::contrib;
    use super::*;

    fn cohort(bad_val: f32) -> Vec<Contribution> {
        vec![
            contrib(0, 100, true, &[1.0, 1.0]),
            contrib(1, 100, false, &[1.0, 1.0]),
            contrib(2, 100, false, &[1.0, 1.0]),
            contrib(3, 100, false, &[bad_val, bad_val]),
        ]
    }

    #[test]
    fn weights_normalize_and_downweight_outlier() {
        let mut s = TrustWeighted::default();
        let out = s.aggregate(&cohort(1000.0)).unwrap();
        let sum: f32 = s.last_weights().iter().map(|(_, w)| w).sum();
        assert!((sum - 1.0).abs() < 1e-4, "weights sum to 1, got {sum}");
        let w_bad = s.last_weights().iter().find(|(n, _)| *n == 3).unwrap().1;
        let w_good = s.last_weights().iter().find(|(n, _)| *n == 0).unwrap().1;
        assert!(w_bad < w_good / 100.0, "outlier weight {w_bad} vs honest {w_good}");
        // the aggregate stays near the honest cluster in round one
        assert!((out.0[0] - 1.0).abs() < 0.1, "got {}", out.0[0]);
    }

    #[test]
    fn honest_uniform_cohort_gets_uniform_weights() {
        let mut s = TrustWeighted::default();
        s.aggregate(&cohort(1.0)).unwrap();
        for (_, w) in s.last_weights() {
            assert!((w - 0.25).abs() < 1e-6, "uniform weight, got {w}");
        }
    }

    #[test]
    fn reset_clears_the_trust_ledger() {
        let mut s = TrustWeighted::default();
        s.aggregate(&cohort(1000.0)).unwrap();
        let w_bad_first = s.last_weights().iter().find(|(n, _)| *n == 3).unwrap().1;
        s.aggregate(&cohort(1000.0)).unwrap();
        let w_bad_second = s.last_weights().iter().find(|(n, _)| *n == 3).unwrap().1;
        assert!(w_bad_second < w_bad_first, "EMA keeps decreasing trust");
        s.reset();
        s.aggregate(&cohort(1000.0)).unwrap();
        let w_bad_reset = s.last_weights().iter().find(|(n, _)| *n == 3).unwrap().1;
        assert_eq!(w_bad_reset, w_bad_first, "reset forgets the ledger");
    }
}
