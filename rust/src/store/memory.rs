//! In-process weight store: an `RwLock`ed entry log. The default for
//! simulated experiments (paper §5 notes their experiments also simulate
//! concurrency in-process; ours uses real OS threads + this store).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::time::Duration;

use anyhow::Result;

use super::{ChangeNotifier, EntryLog, PushRequest, WeightEntry, WeightStore};
use crate::util::hash::combine;

/// Shared-memory store; cheap Arc-based blob sharing, no serialization.
/// The [`EntryLog`]'s maintained latest index makes async pulls O(nodes)
/// — the log grows every epoch, so the scan it replaces made them
/// O(epochs² · nodes) over a run.
pub struct MemoryStore {
    inner: RwLock<EntryLog>,
    seq: AtomicU64,
    pushes: AtomicU64,
    notify: ChangeNotifier,
}

impl Default for MemoryStore {
    fn default() -> Self {
        MemoryStore::new()
    }
}

impl MemoryStore {
    /// An empty store (change waits park in real time).
    pub fn new() -> Self {
        MemoryStore::with_notifier(ChangeNotifier::default())
    }

    /// An empty store whose change subscriptions park in `clock`'s time
    /// domain — pass the experiment's [`crate::time::VirtualClock`] so
    /// `wait_for_change` consumes simulated time.
    pub fn with_clock(clock: std::sync::Arc<dyn crate::time::Clock>) -> Self {
        MemoryStore::with_notifier(ChangeNotifier::new(clock))
    }

    fn with_notifier(notify: ChangeNotifier) -> Self {
        MemoryStore {
            inner: RwLock::new(EntryLog::default()),
            seq: AtomicU64::new(0),
            pushes: AtomicU64::new(0),
            notify,
        }
    }
}

impl WeightStore for MemoryStore {
    fn push(&self, req: PushRequest) -> Result<u64> {
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        let entry = WeightEntry {
            node_id: req.node_id,
            round: req.round,
            epoch: req.epoch,
            n_examples: req.n_examples,
            seq,
            wire_bytes: req.wire_bytes,
            params: req.params,
        };
        self.inner.write().unwrap().push(entry);
        self.pushes.fetch_add(1, Ordering::Relaxed);
        // bump only after the entry is visible, so woken waiters see it
        self.notify.bump();
        Ok(seq)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        // O(nodes) off the maintained index (node-id order, like the
        // BTreeMap merge the scan used to produce).
        Ok(self.inner.read().unwrap().latest.values().cloned().collect())
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        Ok(self
            .inner
            .read()
            .unwrap()
            .log
            .iter()
            .filter(|e| e.round == round)
            .cloned()
            .collect())
    }

    fn state_hash(&self) -> Result<u64> {
        let inner = self.inner.read().unwrap();
        let mut h = 0xfeed_f00d_u64;
        for e in inner.log.iter() {
            h = combine(h, (e.node_id as u64) << 48 | e.seq);
        }
        Ok(h)
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        Ok(self.inner.read().unwrap().latest.get(&node_id).cloned())
    }

    fn version(&self) -> Result<u64> {
        Ok(self.notify.version())
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        Ok(self.notify.wait_for_change(since, timeout))
    }

    fn push_count(&self) -> u64 {
        self.pushes.load(Ordering::Relaxed)
    }

    fn clear(&self) -> Result<()> {
        self.inner.write().unwrap().clear();
        self.notify.bump();
        Ok(())
    }

    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // Check, insert, and bump all under the write lock: two racing
        // CAS writers serialize here, and the loser observes the
        // winner's bump. (A plain `push` racing this window keeps its
        // pre-assigned lower seq, so a successful CAS still never
        // shadows anything newer than its token.)
        let mut inner = self.inner.write().unwrap();
        if self.notify.version() != expected {
            return Ok(None);
        }
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        inner.push(WeightEntry {
            node_id: req.node_id,
            round: req.round,
            epoch: req.epoch,
            n_examples: req.n_examples,
            seq,
            wire_bytes: req.wire_bytes,
            params: req.params,
        });
        self.pushes.fetch_add(1, Ordering::Relaxed);
        self.notify.bump();
        Ok(Some(seq))
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::store::store_tests;

    #[test]
    fn conformance() {
        store_tests::conformance(&MemoryStore::new());
    }

    #[test]
    fn concurrent() {
        store_tests::concurrent_pushes(Arc::new(MemoryStore::new()));
    }

    #[test]
    fn subscription() {
        store_tests::subscription(Arc::new(MemoryStore::new()));
    }

    #[test]
    fn state_hash_differs_by_order() {
        let a = MemoryStore::new();
        a.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        a.push(store_tests::push_req(1, 0, 1.0)).unwrap();
        let b = MemoryStore::new();
        b.push(store_tests::push_req(1, 0, 1.0)).unwrap();
        b.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        assert_ne!(a.state_hash().unwrap(), b.state_hash().unwrap());
    }

    #[test]
    fn latest_index_matches_full_log_scan() {
        store_tests::latest_index_matches_scan(&MemoryStore::new());
    }

    #[test]
    fn cas_conformance() {
        store_tests::cas_conformance(&MemoryStore::new());
    }

    #[test]
    fn cas_lost_update() {
        store_tests::cas_lost_update(Arc::new(MemoryStore::new()));
    }
}
