//! Federated aggregation strategies, applied **client-side** (serverless:
//! "each client may implement its own aggregation strategy", §3).
//!
//! Implemented: the paper's three (FedAvg, FedAvgM, FedAdam — §4.2.2) plus
//! the two asynchronous extensions its §5 lists as future work:
//! staleness-aware FedAsync [Xie et al. 2019] and buffered FedBuff
//! [Nguyen et al. 2022] — and the [`robust`] family (coordinate-wise
//! median/trimmed-mean, Krum, trust-weighted averaging) defending the
//! serverless store against adversarial clients.
//!
//! A strategy is stateful *per node* (e.g. each node carries its own
//! server-momentum buffer) — exactly what the serverless design implies.
//!
//! # Example
//!
//! A strategy consumes [`Contribution`]s (one per node, exactly one
//! marked `is_self`) and produces the node's next weights:
//!
//! ```no_run
//! use std::sync::Arc;
//!
//! use fedless::strategy::{Contribution, StrategyKind};
//! use fedless::tensor::FlatParams;
//!
//! let mut strategy = StrategyKind::FedAvg.build();
//! let contribs = vec![
//!     Contribution {
//!         node_id: 0,
//!         n_examples: 300,
//!         is_self: true,
//!         seq: 2,
//!         params: Arc::new(FlatParams(vec![1.0; 4])),
//!     },
//!     Contribution {
//!         node_id: 1,
//!         n_examples: 100,
//!         is_self: false,
//!         seq: 1,
//!         params: Arc::new(FlatParams(vec![5.0; 4])),
//!     },
//! ];
//! // example-weighted: 0.75 * 1.0 + 0.25 * 5.0 = 2.0 per coordinate
//! let next = strategy.aggregate(&contribs).unwrap();
//! assert_eq!(next.0, vec![2.0; 4]);
//! ```

mod fedadam;
mod fedasync;
mod fedavg;
mod fedavgm;
mod fedbuff;
pub mod robust;

pub use fedadam::FedAdam;
pub use fedasync::FedAsync;
pub use fedavg::FedAvg;
pub use fedavgm::FedAvgM;
pub use fedbuff::FedBuff;
pub use robust::{Krum, Median, TrimmedMean, TrustWeighted};

use std::sync::Arc;

use crate::par::ChunkPool;
use crate::tensor::FlatParams;

/// One client's weights entering an aggregation.
#[derive(Clone, Debug)]
pub struct Contribution {
    /// The contributing node.
    pub node_id: usize,
    /// Examples that node trained on (the FedAvg weight numerator n_k).
    pub n_examples: u64,
    /// True for the aggregating node's own current weights (Algorithm 1's
    /// `ω[k] ← w^k`).
    pub is_self: bool,
    /// Store sequence number of the entry (novelty/staleness signal).
    pub seq: u64,
    /// The contributed flat weight vector.
    pub params: Arc<FlatParams>,
}

/// Client-side aggregation strategy.
pub trait Strategy: Send {
    /// Canonical lowercase strategy name (matches [`StrategyKind::name`]).
    fn name(&self) -> &'static str;

    /// Aggregate the contributions into new local weights, running the
    /// data-parallel kernels (the fused weighted average, axpy, lerp) on
    /// `pool`. Returns `None` when the strategy decides not to update
    /// (e.g. FedBuff's buffer has not filled) — the caller then keeps
    /// its current weights. Results are bit-identical for any thread
    /// count (the [`crate::par`] determinism contract).
    ///
    /// `contribs` always contains exactly one `is_self` entry.
    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams>;

    /// Single-threaded [`Strategy::aggregate_pooled`] (bit-identical).
    fn aggregate(&mut self, contribs: &[Contribution]) -> Option<FlatParams> {
        self.aggregate_pooled(contribs, ChunkPool::sequential())
    }

    /// Reset per-node state (between trials).
    fn reset(&mut self) {}
}

/// `n_k / n` weights over borrowed contributions (Eq. 1) — iterator-based
/// so callers holding `&[Contribution]` *or* `&[&Contribution]` (e.g.
/// FedAsync's peer filter) avoid deep-copying contributions just to
/// compute their weights.
pub(crate) fn example_weights<'a, I>(contribs: I) -> Vec<f32>
where
    I: ExactSizeIterator<Item = &'a Contribution> + Clone,
{
    let n = contribs.len();
    let total: u64 = contribs.clone().map(|c| c.n_examples).sum();
    if total == 0 {
        // degenerate: fall back to uniform
        return vec![1.0 / n as f32; n];
    }
    contribs.map(|c| c.n_examples as f32 / total as f32).collect()
}

/// Plain example-weighted average of the contributions, computed with
/// the fused one-pass kernel on `pool`.
pub(crate) fn fedavg_of(contribs: &[Contribution], pool: ChunkPool) -> FlatParams {
    let weights = example_weights(contribs.iter());
    let refs: Vec<&FlatParams> = contribs.iter().map(|c| c.params.as_ref()).collect();
    crate::tensor::flat::weighted_average_pooled(&refs, &weights, pool)
}

/// Default per-tail trim fraction for `trimmed-mean` (as permille).
const DEFAULT_TRIM_PERMILLE: u16 = 200;

/// Default Byzantine tolerance for `krum`.
const DEFAULT_KRUM_F: usize = 1;

/// Strategy selector used in configs / CLI (`--strategy fedavg`).
///
/// Parameterized robust kinds carry their hyperparameter in an
/// `Eq`-safe integer encoding (`trim_permille` = frac × 1000) so the
/// selector stays `Copy + Eq` for sweep-cell keys and config equality.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    /// Example-weighted averaging (paper Eq. 1).
    FedAvg,
    /// FedAvg with (client-held) server momentum.
    FedAvgM,
    /// Adam on the aggregation pseudo-gradient.
    FedAdam,
    /// Staleness-aware asynchronous mixing (Xie et al. 2019).
    FedAsync,
    /// Buffered asynchronous aggregation (Nguyen et al. 2022).
    FedBuff,
    /// Coordinate-wise median (robust; `median`).
    Median,
    /// Coordinate-wise trimmed mean (robust; `trimmed-mean[:frac]`,
    /// `trim_permille` = frac × 1000 per tail).
    TrimmedMean {
        /// Per-tail trim fraction in permille (`250` = trim 25% per tail).
        trim_permille: u16,
    },
    /// Krum selection (robust; `krum[:f]` tolerating `f` Byzantine clients).
    Krum {
        /// Number of Byzantine clients tolerated.
        f: usize,
    },
    /// EMA-of-residual trust weighting (robust; `trust-weighted`).
    TrustWeighted,
}

impl StrategyKind {
    /// Parse a config/CLI strategy name. Robust kinds accept an optional
    /// parameter suffix: `trimmed-mean:0.25` (per-tail trim fraction in
    /// `(0, 0.5)`) and `krum:2` (Byzantine tolerance).
    pub fn parse(s: &str) -> Option<StrategyKind> {
        let lower = s.to_ascii_lowercase();
        if let Some(frac) = lower.strip_prefix("trimmed-mean:") {
            let f: f64 = frac.parse().ok()?;
            if !(f.is_finite() && f > 0.0 && f < 0.5) {
                return None;
            }
            return Some(StrategyKind::TrimmedMean {
                trim_permille: (f * 1000.0).round() as u16,
            });
        }
        if let Some(f) = lower.strip_prefix("krum:") {
            return f.parse().ok().map(|f| StrategyKind::Krum { f });
        }
        match lower.as_str() {
            "fedavg" => Some(StrategyKind::FedAvg),
            "fedavgm" => Some(StrategyKind::FedAvgM),
            "fedadam" => Some(StrategyKind::FedAdam),
            "fedasync" => Some(StrategyKind::FedAsync),
            "fedbuff" => Some(StrategyKind::FedBuff),
            "median" => Some(StrategyKind::Median),
            "trimmed-mean" => {
                Some(StrategyKind::TrimmedMean { trim_permille: DEFAULT_TRIM_PERMILLE })
            }
            "krum" => Some(StrategyKind::Krum { f: DEFAULT_KRUM_F }),
            "trust-weighted" | "trustweighted" => Some(StrategyKind::TrustWeighted),
            _ => None,
        }
    }

    /// Canonical lowercase family name (inverse of
    /// [`StrategyKind::parse`] for the default hyperparameters).
    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::FedAvg => "fedavg",
            StrategyKind::FedAvgM => "fedavgm",
            StrategyKind::FedAdam => "fedadam",
            StrategyKind::FedAsync => "fedasync",
            StrategyKind::FedBuff => "fedbuff",
            StrategyKind::Median => "median",
            StrategyKind::TrimmedMean { .. } => "trimmed-mean",
            StrategyKind::Krum { .. } => "krum",
            StrategyKind::TrustWeighted => "trust-weighted",
        }
    }

    /// Parameter-distinct label for run names and sweep-cell labels
    /// (`trimmed-mean0.25`, `krum2`; equals [`StrategyKind::name`] for
    /// everything unparameterized).
    pub fn label(self) -> String {
        match self {
            StrategyKind::TrimmedMean { trim_permille } => {
                format!("trimmed-mean{}", trim_permille as f64 / 1000.0)
            }
            StrategyKind::Krum { f } => format!("krum{f}"),
            other => other.name().to_string(),
        }
    }

    /// True for the robust-aggregation family (`rust/src/strategy/robust/`).
    pub fn is_robust(self) -> bool {
        matches!(
            self,
            StrategyKind::Median
                | StrategyKind::TrimmedMean { .. }
                | StrategyKind::Krum { .. }
                | StrategyKind::TrustWeighted
        )
    }

    /// Instantiate with default hyperparameters (paper-faithful).
    pub fn build(self) -> Box<dyn Strategy> {
        match self {
            StrategyKind::FedAvg => Box::new(FedAvg::new()),
            StrategyKind::FedAvgM => Box::new(FedAvgM::new(0.9, 1.0)),
            StrategyKind::FedAdam => Box::new(FedAdam::new(1e-2, 0.9, 0.999, 1e-3)),
            StrategyKind::FedAsync => Box::new(FedAsync::new(0.6, 0.5)),
            StrategyKind::FedBuff => Box::new(FedBuff::new(2)),
            StrategyKind::Median => Box::new(Median::new()),
            StrategyKind::TrimmedMean { trim_permille } => {
                Box::new(TrimmedMean::new(trim_permille as f64 / 1000.0))
            }
            StrategyKind::Krum { f } => Box::new(Krum::new(f)),
            StrategyKind::TrustWeighted => Box::new(TrustWeighted::default()),
        }
    }
}

impl std::fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
pub(crate) mod strategy_tests {
    use super::*;

    pub fn contrib(node: usize, n: u64, is_self: bool, vals: &[f32]) -> Contribution {
        Contribution {
            node_id: node,
            n_examples: n,
            is_self,
            seq: node as u64 + 1,
            params: Arc::new(FlatParams(vals.to_vec())),
        }
    }

    #[test]
    fn example_weights_normalize() {
        let cs = [contrib(0, 300, true, &[0.0]), contrib(1, 100, false, &[0.0])];
        let w = example_weights(cs.iter());
        assert_eq!(w, vec![0.75, 0.25]);
        // works over borrowed refs too (the FedAsync peer-filter shape)
        let refs: Vec<&Contribution> = cs.iter().collect();
        assert_eq!(example_weights(refs.iter().copied()), vec![0.75, 0.25]);
    }

    #[test]
    fn example_weights_zero_total_uniform() {
        let cs = [contrib(0, 0, true, &[0.0]), contrib(1, 0, false, &[0.0])];
        let w = example_weights(cs.iter());
        assert_eq!(w, vec![0.5, 0.5]);
    }

    #[test]
    fn kind_parse_round_trip() {
        for k in [
            StrategyKind::FedAvg,
            StrategyKind::FedAvgM,
            StrategyKind::FedAdam,
            StrategyKind::FedAsync,
            StrategyKind::FedBuff,
            StrategyKind::Median,
            StrategyKind::TrimmedMean { trim_permille: 200 },
            StrategyKind::Krum { f: 1 },
            StrategyKind::TrustWeighted,
        ] {
            assert_eq!(StrategyKind::parse(k.name()), Some(k));
        }
        assert_eq!(StrategyKind::parse("nope"), None);
    }

    #[test]
    fn robust_kinds_parse_parameters() {
        assert_eq!(
            StrategyKind::parse("trimmed-mean:0.25"),
            Some(StrategyKind::TrimmedMean { trim_permille: 250 })
        );
        assert_eq!(StrategyKind::parse("krum:3"), Some(StrategyKind::Krum { f: 3 }));
        assert_eq!(StrategyKind::parse("trimmed-mean:0.5"), None, "frac must be < 0.5");
        assert_eq!(StrategyKind::parse("trimmed-mean:0"), None, "frac must be > 0");
        assert_eq!(StrategyKind::parse("krum:x"), None);
    }

    #[test]
    fn labels_distinguish_parameters() {
        assert_eq!(StrategyKind::FedAvg.label(), "fedavg");
        assert_eq!(StrategyKind::TrimmedMean { trim_permille: 250 }.label(), "trimmed-mean0.25");
        assert_eq!(StrategyKind::Krum { f: 2 }.label(), "krum2");
        assert!(StrategyKind::Krum { f: 2 }.is_robust());
        assert!(!StrategyKind::FedAvg.is_robust());
    }

    #[test]
    fn robust_kinds_build_their_strategy() {
        for (kind, name) in [
            (StrategyKind::Median, "median"),
            (StrategyKind::TrimmedMean { trim_permille: 250 }, "trimmed-mean"),
            (StrategyKind::Krum { f: 1 }, "krum"),
            (StrategyKind::TrustWeighted, "trust-weighted"),
        ] {
            assert_eq!(kind.build().name(), name);
        }
    }
}
