//! Filesystem weight store — the direct analogue of the paper's
//! `S3Folder("mybucket/experiment1")`: a directory of self-validating blob
//! files that genuinely separate OS processes can share.
//!
//! Layout: `<root>/n{node}_s{seq}.flwr`, written atomically
//! (`.tmp` + rename) so readers never observe torn files; the blob codec's
//! payload hash catches anything that slips through (e.g. a copied
//! partial file on a network mount).
//!
//! Files are always written in the self-contained v1 (raw f32) format so
//! a directory never needs codec state to read back — the compression
//! layer's wire accounting happens at the protocol boundary, and scanned
//! entries report their actual on-disk byte size as `wire_bytes`. Both
//! v1 and raw v2 blobs decode on scan (see [`crate::tensor::codec`]).
//!
//! # Read-path I/O discipline
//!
//! Reads are tiered so each operation pays only what it needs (see
//! ARCHITECTURE.md §11):
//!
//! * **polling** ([`WeightStore::state_hash`] / `version` /
//!   `wait_for_change`) reads at most [`PEEK_LEN`] bytes per file — the
//!   fixed-size blob header — never a payload;
//! * **round filtering** (`entries_for_round`) peeks every header but
//!   fully reads only the files whose header matches the round;
//! * **latest reads** (`latest_per_node` / `latest_for_node`) read files
//!   in descending filename-seq order per node and stop at the first one
//!   that decodes, falling back past corrupt newer files;
//! * full-file reads go through `fs::read`, or — with the non-default
//!   `mmap` cargo feature on unix — a read-only private file mapping
//!   with a transparent `fs::read` fallback. Safe here because store
//!   files are immutable once renamed into place (never truncated).
//!
//! Every byte read from the directory is tallied in a per-handle counter
//! exposed as [`FsStore::io_bytes`], which the regression tests use to
//! pin the "polling is O(header) per file" contract.

use std::collections::BTreeMap;
use std::fs::{self, File};
use std::io::Read;
use std::ops::Deref;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::{PushRequest, WeightEntry, WeightStore};
use crate::tensor::codec::{decode_blob, encode_blob, peek_blob_header, BlobMeta, PEEK_LEN};
use crate::time::{Clock, RealClock};
use crate::util::hash::{combine, fnv1a64};

/// Weight store backed by a directory of blob files (sharable across OS
/// processes; see the module docs for the layout and read tiers).
pub struct FsStore {
    root: PathBuf,
    /// Sequence counter; files from other processes are merged by seq
    /// order at read time, so cross-process seq collisions are harmless.
    seq: AtomicU64,
    pushes: AtomicU64,
    /// Cumulative bytes read from the directory by this handle.
    io_bytes: AtomicU64,
    /// Serializes directory scans (cheap; pushes stay concurrent).
    scan_lock: Mutex<()>,
    /// Handle-local monotone version: `(last observed state hash, counter)`.
    /// There is no cross-process notification on a plain directory, so the
    /// counter advances whenever a LIST observes a different hash — the
    /// mtime-watching analogue for a bucket prefix.
    change: Mutex<(u64, u64)>,
    /// Time domain for the `wait_for_change` backoff polling.
    clock: Arc<dyn Clock>,
}

impl FsStore {
    /// Open (creating if needed) a store rooted at `root` (change waits
    /// poll in real time).
    pub fn open<P: AsRef<Path>>(root: P) -> Result<Self> {
        FsStore::open_with_clock(root, RealClock::shared())
    }

    /// Like [`FsStore::open`], but the `wait_for_change` polling sleeps
    /// in `clock`'s time domain — under a
    /// [`crate::time::VirtualClock`] the backoff consumes simulated
    /// time, so directory watching costs no real wall-clock.
    pub fn open_with_clock<P: AsRef<Path>>(root: P, clock: Arc<dyn Clock>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root).with_context(|| format!("mkdir {root:?}"))?;
        // resume the seq counter past any existing files
        let mut max_seq = 0;
        for f in fs::read_dir(&root)? {
            if let Some((_, seq)) = parse_name(&f?.path()) {
                max_seq = max_seq.max(seq);
            }
        }
        Ok(FsStore {
            root,
            seq: AtomicU64::new(max_seq),
            pushes: AtomicU64::new(0),
            io_bytes: AtomicU64::new(0),
            scan_lock: Mutex::new(()),
            change: Mutex::new((0, 0)),
            clock,
        })
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Cumulative bytes this handle has read from the directory (headers
    /// and full blobs alike; mapped files count their full length). The
    /// I/O-budget regression tests assert on deltas of this counter.
    pub fn io_bytes(&self) -> u64 {
        self.io_bytes.load(Ordering::Relaxed)
    }

    /// All parseable blob filenames: `(node, seq, path)`. No file I/O
    /// beyond the directory listing itself.
    fn list(&self) -> Result<Vec<(usize, u64, PathBuf)>> {
        let mut out = Vec::new();
        for f in fs::read_dir(&self.root)? {
            let path = f?.path();
            if let Some((node, seq)) = parse_name(&path) {
                out.push((node, seq, path));
            }
        }
        Ok(out)
    }

    /// Read at most `n` bytes from the start of `path`.
    fn read_prefix(&self, path: &Path, n: usize) -> std::io::Result<Vec<u8>> {
        let mut buf = Vec::with_capacity(n);
        File::open(path)?.take(n as u64).read_to_end(&mut buf)?;
        self.io_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(buf)
    }

    /// Whole-file read: a private read-only mapping when the `mmap`
    /// feature is on (and the map succeeds), an owned `fs::read`
    /// otherwise.
    fn read_file(&self, path: &Path) -> std::io::Result<FileBytes> {
        #[cfg(all(feature = "mmap", unix))]
        if let Some(mapped) = self.try_map(path) {
            return Ok(mapped);
        }
        let bytes = fs::read(path)?;
        self.io_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(FileBytes::Owned(bytes))
    }

    #[cfg(all(feature = "mmap", unix))]
    fn try_map(&self, path: &Path) -> Option<FileBytes> {
        let file = File::open(path).ok()?;
        let len = file.metadata().ok()?.len() as usize;
        let map = mapped::Mmap::map(&file, len)?;
        self.io_bytes.fetch_add(len as u64, Ordering::Relaxed);
        Some(FileBytes::Mapped(map))
    }

    /// [`WeightStore::state_hash`] body; caller holds `scan_lock`.
    fn state_hash_locked(&self) -> Result<u64> {
        let mut names = self.list()?;
        names.sort_by_key(|&(node, seq, _)| (node, seq));
        let mut h = 0xfeed_f00d_u64;
        for (node, seq, path) in names {
            h = combine(h, (node as u64) << 48 | seq);
            // A vanished file (racing rename) simply contributes no
            // header bytes this poll; the next poll converges.
            if let Ok(prefix) = self.read_prefix(&path, PEEK_LEN) {
                h = combine(h, fnv1a64(&prefix));
            }
        }
        Ok(h)
    }

    /// [`WeightStore::version`] body; caller holds `scan_lock`. Observes
    /// the current listing hash and advances the handle-local counter if
    /// it changed.
    fn observe_version_locked(&self) -> Result<u64> {
        let h = self.state_hash_locked()?;
        let mut g = self.change.lock().unwrap();
        if g.0 != h {
            g.0 = h;
            g.1 += 1;
        }
        Ok(g.1)
    }

    /// Encode and atomically place one blob file (the shared write path
    /// of `push` and `push_if_version`).
    fn write_blob(&self, req: &PushRequest, seq: u64) -> Result<()> {
        let meta = BlobMeta {
            node_id: req.node_id as u32,
            round: req.round,
            epoch: req.epoch,
            n_examples: req.n_examples,
        };
        let blob = encode_blob(&meta, &req.params);
        let final_path = self.root.join(format!("n{}_s{}.flwr", req.node_id, seq));
        let tmp_path = self.root.join(format!(".tmp_n{}_s{}", req.node_id, seq));
        fs::write(&tmp_path, &blob).with_context(|| format!("write {tmp_path:?}"))?;
        fs::rename(&tmp_path, &final_path)
            .with_context(|| format!("rename to {final_path:?}"))?;
        self.pushes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Fully read and decode one blob file into an entry. `None` for a
    /// racing rename or a torn/corrupt blob — eventual consistency, like
    /// listing a bucket mid-upload.
    fn load_entry(&self, seq: u64, path: &Path) -> Option<WeightEntry> {
        let bytes = self.read_file(path).ok()?;
        let (meta, params) = decode_blob(&bytes).ok()?;
        Some(WeightEntry {
            node_id: meta.node_id as usize,
            round: meta.round,
            epoch: meta.epoch,
            n_examples: meta.n_examples,
            seq,
            // the file *is* the wire blob: its size is the entry's wire
            // cost, whatever version wrote it
            wire_bytes: bytes.len() as u64,
            params: std::sync::Arc::new(params),
        })
    }
}

fn parse_name(path: &Path) -> Option<(usize, u64)> {
    let name = path.file_name()?.to_str()?;
    let stem = name.strip_suffix(".flwr")?;
    let (n, s) = stem.split_once("_s")?;
    let node = n.strip_prefix('n')?.parse().ok()?;
    let seq = s.parse().ok()?;
    Some((node, seq))
}

/// Bytes of one blob file: an owned buffer, or (with the `mmap` feature)
/// a read-only file mapping. Derefs to `&[u8]` either way, so the decode
/// path is agnostic.
enum FileBytes {
    Owned(Vec<u8>),
    #[cfg(all(feature = "mmap", unix))]
    Mapped(mapped::Mmap),
}

impl Deref for FileBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match self {
            FileBytes::Owned(v) => v,
            #[cfg(all(feature = "mmap", unix))]
            FileBytes::Mapped(m) => m,
        }
    }
}

#[cfg(all(feature = "mmap", unix))]
mod mapped {
    //! Minimal read-only `mmap` wrapper (the image vendors no mmap
    //! crate, so this goes through `libc` directly). Store files are
    //! immutable once renamed into place and never truncated, so a
    //! mapping cannot observe a shrinking file (the SIGBUS hazard).

    use std::fs::File;
    use std::ops::Deref;
    use std::os::unix::io::AsRawFd;

    /// A read-only `MAP_PRIVATE` mapping of a whole file.
    pub(super) struct Mmap {
        ptr: *mut libc::c_void,
        len: usize,
    }

    // SAFETY: the mapping is PROT_READ and never mutated or aliased
    // mutably; sharing the pointer across threads is sound.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        /// Map `len` bytes of `file`; `None` on any failure (zero-length
        /// files included — mmap rejects them), letting the caller fall
        /// back to an owned read.
        pub(super) fn map(file: &File, len: usize) -> Option<Mmap> {
            if len == 0 {
                return None;
            }
            // SAFETY: the fd is valid for the duration of the call; a
            // read-only private mapping of a regular file has no aliasing
            // requirements; failure returns MAP_FAILED, checked below.
            let ptr = unsafe {
                libc::mmap(
                    std::ptr::null_mut(),
                    len,
                    libc::PROT_READ,
                    libc::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == libc::MAP_FAILED {
                None
            } else {
                Some(Mmap { ptr, len })
            }
        }
    }

    impl Deref for Mmap {
        type Target = [u8];
        fn deref(&self) -> &[u8] {
            // SAFETY: ptr..ptr+len is a live PROT_READ mapping owned by
            // `self` (unmapped only in Drop).
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            // SAFETY: ptr/len came from a successful mmap and are
            // unmapped exactly once.
            unsafe {
                libc::munmap(self.ptr, self.len);
            }
        }
    }
}

impl WeightStore for FsStore {
    fn push(&self, req: PushRequest) -> Result<u64> {
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        self.write_blob(&req, seq)?;
        Ok(seq)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        // Group by the filename's node and read newest-seq-first, so each
        // node costs one full read in the common case; a corrupt or
        // mid-rename newer file falls back to the next older seq.
        let _g = self.scan_lock.lock().unwrap();
        let mut by_node: BTreeMap<usize, Vec<(u64, PathBuf)>> = BTreeMap::new();
        for (node, seq, path) in self.list()? {
            by_node.entry(node).or_default().push((seq, path));
        }
        let mut out = Vec::new();
        for mut files in by_node.into_values() {
            files.sort_by_key(|(seq, _)| std::cmp::Reverse(*seq));
            for (seq, path) in files {
                if let Some(e) = self.load_entry(seq, &path) {
                    out.push(e);
                    break;
                }
            }
        }
        Ok(out)
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        // Header peek first: only files whose header claims the round pay
        // a full read (the peek is not integrity-checked — decode still
        // validates, and a lying header just costs one wasted read).
        let _g = self.scan_lock.lock().unwrap();
        let mut out = Vec::new();
        for (_node, seq, path) in self.list()? {
            let Ok(prefix) = self.read_prefix(&path, PEEK_LEN) else { continue };
            let Ok(peek) = peek_blob_header(&prefix) else { continue };
            if peek.meta.round != round {
                continue;
            }
            if let Some(e) = self.load_entry(seq, &path) {
                out.push(e);
            }
        }
        out.sort_by_key(|e| e.seq);
        Ok(out)
    }

    fn state_hash(&self) -> Result<u64> {
        // Header-only poll: hash the sorted filename keys plus the first
        // PEEK_LEN bytes of each file. Unlike a pure-LIST hash this
        // notices an in-place rewrite under a reused name, and unlike a
        // full scan it never reads a payload — polling I/O stays
        // O(header) per file (pinned by a regression test below).
        let _g = self.scan_lock.lock().unwrap();
        self.state_hash_locked()
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        // Newest filename seq first, falling back past corrupt files —
        // the gossip per-peer pull reads exactly one blob when healthy.
        let _g = self.scan_lock.lock().unwrap();
        let mut files: Vec<(u64, PathBuf)> = self
            .list()?
            .into_iter()
            .filter(|&(node, _, _)| node == node_id)
            .map(|(_, seq, path)| (seq, path))
            .collect();
        files.sort_by_key(|(seq, _)| std::cmp::Reverse(*seq));
        for (seq, path) in files {
            if let Some(e) = self.load_entry(seq, &path) {
                return Ok(Some(e));
            }
        }
        Ok(None)
    }

    fn version(&self) -> Result<u64> {
        // Derive a handle-local monotone counter from the listing hash:
        // any observed change (our own pushes included, and foreign
        // processes') advances it exactly once.
        let _g = self.scan_lock.lock().unwrap();
        self.observe_version_locked()
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        // No cross-process notification on a directory: poll the listing
        // with exponential backoff, bounded by the caller's timeout. The
        // backoff sleeps in the store's clock domain, so a virtual clock
        // turns the whole poll loop into simulated time.
        let start = self.clock.now();
        let mut backoff = Duration::from_micros(500);
        loop {
            let v = self.version()?;
            if v > since {
                return Ok(v);
            }
            let elapsed = self.clock.now().saturating_sub(start);
            if elapsed >= timeout {
                return Ok(v);
            }
            self.clock.sleep(backoff.min(timeout - elapsed));
            backoff = (backoff * 2).min(Duration::from_millis(20));
        }
    }

    fn push_count(&self) -> u64 {
        self.pushes.load(Ordering::Relaxed)
    }

    fn clear(&self) -> Result<()> {
        let _g = self.scan_lock.lock().unwrap();
        for f in fs::read_dir(&self.root)? {
            let p = f?.path();
            if parse_name(&p).is_some() {
                let _ = fs::remove_file(p);
            }
        }
        Ok(())
    }

    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // Hold the scan lock across observe + write + re-observe: racing
        // CAS writers (and version observers) on *this handle* serialize
        // here, and the re-observation advances the handle-local counter
        // past our own write so a stale token is refused afterwards.
        // Like `version` itself the guarantee is handle-local — a
        // foreign process writing between the check and the rename is
        // the bucket's eventual consistency, not a torn entry.
        let _g = self.scan_lock.lock().unwrap();
        if self.observe_version_locked()? != expected {
            return Ok(None);
        }
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        self.write_blob(&req, seq)?;
        let _ = self.observe_version_locked()?;
        Ok(Some(seq))
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::store::store_tests;
    use crate::tensor::FlatParams;

    fn tmp_store(tag: &str) -> (FsStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "fedless_fsstore_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        (FsStore::open(&dir).unwrap(), dir)
    }

    #[test]
    fn conformance() {
        let (s, dir) = tmp_store("conf");
        store_tests::conformance(&s);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn concurrent() {
        let (s, dir) = tmp_store("conc");
        store_tests::concurrent_pushes(Arc::new(s));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn subscription() {
        let (s, dir) = tmp_store("subs");
        store_tests::subscription(Arc::new(s));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn cas_conformance() {
        let (s, dir) = tmp_store("cas");
        store_tests::cas_conformance(&s);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn cas_lost_update() {
        let (s, dir) = tmp_store("cas_race");
        store_tests::cas_lost_update(Arc::new(s));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn foreign_handle_push_advances_version() {
        // Version is handle-local but must observe *other* handles'
        // writes to the shared directory (the cross-process case).
        let (a, dir) = tmp_store("foreign_ver");
        let b = FsStore::open(&dir).unwrap();
        let v = a.version().unwrap();
        b.push(store_tests::push_req(1, 0, 2.0)).unwrap();
        assert!(a.version().unwrap() > v);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn survives_reopen() {
        let (s, dir) = tmp_store("reopen");
        s.push(store_tests::push_req(2, 5, 9.0)).unwrap();
        drop(s);
        let s2 = FsStore::open(&dir).unwrap();
        let latest = s2.latest_per_node().unwrap();
        assert_eq!(latest.len(), 1);
        assert_eq!(latest[0].round, 5);
        // seq counter resumes: next push gets a higher seq
        let seq = s2.push(store_tests::push_req(2, 6, 1.0)).unwrap();
        assert!(seq > latest[0].seq);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn ignores_corrupt_files() {
        let (s, dir) = tmp_store("corrupt");
        s.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        fs::write(dir.join("n9_s99.flwr"), b"not a blob").unwrap();
        let latest = s.latest_per_node().unwrap();
        assert_eq!(latest.len(), 1, "corrupt entry must be skipped");
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn latest_falls_back_past_a_corrupt_newer_seq() {
        // A corrupt file with a HIGHER seq for the same node must not
        // shadow the older good entry (the descending-read fallback).
        let (s, dir) = tmp_store("fallback");
        s.push(store_tests::push_req(3, 1, 7.0)).unwrap();
        fs::write(dir.join("n3_s999.flwr"), b"garbage").unwrap();
        let e = s
            .latest_for_node(3)
            .unwrap()
            .expect("falls back to the older good seq");
        assert_eq!(e.round, 1);
        assert_eq!(e.params.0[0], 7.0);
        let all = s.latest_per_node().unwrap();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].round, 1);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn two_handles_share_the_directory() {
        // Two FsStore handles on one root = two "processes" sharing a bucket.
        let (a, dir) = tmp_store("share");
        let b = FsStore::open(&dir).unwrap();
        a.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        b.push(store_tests::push_req(1, 0, 2.0)).unwrap();
        assert_eq!(a.latest_per_node().unwrap().len(), 2);
        assert_eq!(b.latest_per_node().unwrap().len(), 2);
        assert_eq!(a.state_hash().unwrap(), b.state_hash().unwrap());
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn large_payload_roundtrip() {
        let (s, dir) = tmp_store("large");
        let params = Arc::new(FlatParams((0..500_000).map(|i| i as f32).collect()));
        s.push(super::super::PushRequest::raw(0, 0, 0, 1, Arc::clone(&params))).unwrap();
        let latest = s.latest_per_node().unwrap();
        assert_eq!(latest[0].params.0, params.0);
        assert_eq!(
            latest[0].wire_bytes,
            crate::tensor::codec::raw_wire_bytes(500_000),
            "scanned entries report the on-disk blob size as wire cost"
        );
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn polling_io_stays_header_sized_per_file() {
        // Satellite regression: the poll hash must never read payloads.
        // Three ~400 KB blobs; a full-scan regression would read
        // megabytes below, while the header budget is a few KB.
        let (s, dir) = tmp_store("pollio");
        let params = Arc::new(FlatParams(vec![0.5f32; 100_000]));
        for node in 0..3 {
            s.push(super::super::PushRequest::raw(node, 0, 0, 1, Arc::clone(&params)))
                .unwrap();
        }
        let before = s.io_bytes();
        let polls = 10u64;
        for _ in 0..polls {
            s.state_hash().unwrap();
            s.version().unwrap(); // also one state_hash internally
        }
        let delta = s.io_bytes() - before;
        assert!(delta > 0, "the poll hash does read file headers");
        assert!(
            delta <= 2 * polls * 3 * PEEK_LEN as u64,
            "polling read {delta} bytes across {polls} polls of 3 files; \
             the budget is O(PEEK_LEN={PEEK_LEN}) per file per poll"
        );
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn latest_read_costs_one_blob_not_the_history() {
        // Five generations for one node: the per-peer pull must read
        // exactly the newest blob, not all five.
        let (s, dir) = tmp_store("latestio");
        let params = Arc::new(FlatParams(vec![1.0f32; 50_000]));
        for round in 0..5 {
            s.push(super::super::PushRequest::raw(0, round, 0, 1, Arc::clone(&params)))
                .unwrap();
        }
        let before = s.io_bytes();
        let e = s.latest_for_node(0).unwrap().unwrap();
        assert_eq!(e.round, 4);
        let delta = s.io_bytes() - before;
        assert_eq!(
            delta,
            crate::tensor::codec::raw_wire_bytes(50_000),
            "latest_for_node read exactly one on-disk blob"
        );
        fs::remove_dir_all(dir).unwrap();
    }
}
