//! [`VirtualClock`] — a conservative discrete-event scheduler behind the
//! [`Clock`] trait.
//!
//! Simulated time advances **only** when every registered participant
//! thread is blocked inside a clock primitive; it then jumps straight to
//! the earliest pending deadline and wakes the threads whose wait is
//! over. Real compute between blocking calls takes zero simulated time,
//! so a straggler grid that would burn minutes of `thread::sleep` runs
//! at CPU speed while reporting faithful simulated wall-clock — and the
//! unanimity rule makes the simulated timeline independent of OS thread
//! scheduling: with distinct per-node delays, repeated runs produce
//! bit-identical timelines.
//!
//! # Blocked-count bookkeeping
//!
//! The subtle invariant is *when a waiter stops counting as blocked*. A
//! waiter woken by [`Condition::notify_all`] is discounted **at notify
//! time** (by the notifier, under the clock lock), not when its OS
//! thread happens to resume — otherwise the notifier could race ahead,
//! block again, and re-establish unanimity while the logically-awake
//! waiter still counted as blocked, advancing time past the instant the
//! waiter is about to observe. Each wait therefore registers a
//! [`Waiter`] record; `notify_all` flips its `woken` flag and
//! decrements `blocked` on its behalf, and the waiter skips the
//! decrement when it finds the flag set.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::{Clock, Condition};

thread_local! {
    /// Clocks (by `VcShared` address) the current thread is attached to
    /// as a participant ([`Clock::attach`]); only attached threads count
    /// toward a clock's advance quorum.
    static ATTACHED: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
}

/// One thread parked in a virtual-clock primitive.
struct Waiter {
    /// Unique id of this wait (for removal).
    id: u64,
    /// Virtual instant at which the wait times out.
    deadline: Duration,
    /// `Some(condition id)` for condition waits, `None` for sleeps.
    cond: Option<u64>,
    /// Whether the parked thread is an attached participant (counts in
    /// `blocked` rather than `blocked_others`).
    participant: bool,
    /// Set by `notify_all`: the waiter is logically runnable and has
    /// already been discounted from its blocked counter.
    woken: bool,
}

struct VcState {
    /// Current simulated time since the clock's origin.
    now: Duration,
    /// Registered participant threads ([`Clock::enter`]).
    participants: usize,
    /// Participant threads currently parked in a clock primitive
    /// (excluding waiters already marked `woken`).
    blocked: usize,
    /// Non-participant threads currently parked. They never count
    /// toward the quorum while participants exist — a stray monitor
    /// thread blocking on the store must not let time advance while a
    /// node is still computing — but with zero participants any blocked
    /// thread advances (single-threaded simulation semantics).
    blocked_others: usize,
    /// All currently parked waits.
    waiters: Vec<Waiter>,
    /// Id source for waits and conditions.
    next_id: u64,
}

struct VcShared {
    state: Mutex<VcState>,
    wake: Condvar,
}

impl VcShared {
    /// Whether the calling thread is attached to this clock.
    fn current_thread_attached(this: &Arc<VcShared>) -> bool {
        let token = Arc::as_ptr(this) as usize;
        ATTACHED.with(|a| a.borrow().contains(&token))
    }

    /// If every participant is blocked, advance `now` to the earliest
    /// live deadline and wake everyone to re-check their predicates.
    /// With zero participants any single blocked thread advances
    /// immediately (single-threaded simulation semantics).
    fn try_advance(state: &mut VcState, wake: &Condvar) {
        let quorum = if state.participants > 0 {
            state.blocked >= state.participants
        } else {
            state.blocked + state.blocked_others > 0
        };
        if !quorum {
            return;
        }
        if let Some(d) = state
            .waiters
            .iter()
            .filter(|w| !w.woken)
            .map(|w| w.deadline)
            .min()
        {
            if d > state.now {
                state.now = d;
            }
            wake.notify_all();
        }
    }

    /// Park-entry bookkeeping shared by sleeps and condition waits.
    fn add_blocked(state: &mut VcState, participant: bool) {
        if participant {
            state.blocked += 1;
        } else {
            state.blocked_others += 1;
        }
    }

    /// Park-exit bookkeeping (skipped when `notify_all` already
    /// discounted the waiter).
    fn remove_blocked(state: &mut VcState, participant: bool) {
        if participant {
            state.blocked -= 1;
        } else {
            state.blocked_others -= 1;
        }
    }
}

/// Discrete-event simulated [`Clock`]; see the module docs for the
/// advancement rule. Construct one per experiment
/// ([`crate::time::ClockKind::build`]); conditions created from it share
/// its time domain.
pub struct VirtualClock {
    shared: Arc<VcShared>,
}

impl VirtualClock {
    /// A virtual clock at `now == 0` with no participants.
    pub fn new() -> VirtualClock {
        VirtualClock {
            shared: Arc::new(VcShared {
                state: Mutex::new(VcState {
                    now: Duration::ZERO,
                    participants: 0,
                    blocked: 0,
                    blocked_others: 0,
                    waiters: Vec::new(),
                    next_id: 0,
                }),
                wake: Condvar::new(),
            }),
        }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        self.shared.state.lock().unwrap().now
    }

    fn sleep(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        let sh = &self.shared;
        let participant = VcShared::current_thread_attached(sh);
        let mut st = sh.state.lock().unwrap();
        let deadline = st.now.saturating_add(d);
        let id = st.next_id;
        st.next_id += 1;
        st.waiters.push(Waiter { id, deadline, cond: None, participant, woken: false });
        VcShared::add_blocked(&mut st, participant);
        VcShared::try_advance(&mut st, &sh.wake);
        while st.now < deadline {
            st = sh.wake.wait(st).unwrap();
        }
        let pos = st.waiters.iter().position(|w| w.id == id).unwrap();
        st.waiters.swap_remove(pos);
        VcShared::remove_blocked(&mut st, participant);
        // A departing non-participant may leave the participants
        // unanimous again (for a participant the quorum is now false,
        // so this is a no-op — time stays frozen while it runs).
        VcShared::try_advance(&mut st, &sh.wake);
    }

    fn condition(&self) -> Arc<dyn Condition> {
        let id = {
            let mut st = self.shared.state.lock().unwrap();
            let id = st.next_id;
            st.next_id += 1;
            id
        };
        Arc::new(VirtualCondition {
            shared: Arc::clone(&self.shared),
            id,
            epoch: AtomicU64::new(0),
        })
    }

    fn enter(&self) {
        self.shared.state.lock().unwrap().participants += 1;
    }

    fn attach(&self) {
        let token = Arc::as_ptr(&self.shared) as usize;
        ATTACHED.with(|a| a.borrow_mut().push(token));
    }

    fn detach(&self) {
        let token = Arc::as_ptr(&self.shared) as usize;
        ATTACHED.with(|a| {
            let mut v = a.borrow_mut();
            if let Some(pos) = v.iter().position(|&t| t == token) {
                v.swap_remove(pos);
            }
        });
    }

    fn exit(&self) {
        let sh = &self.shared;
        let mut st = sh.state.lock().unwrap();
        st.participants = st.participants.saturating_sub(1);
        // The remaining blocked threads may now be unanimous.
        VcShared::try_advance(&mut st, &sh.wake);
    }
}

/// A [`Condition`] in a [`VirtualClock`]'s time domain. The epoch cell
/// is only read/written under the clock's state lock, which pairs every
/// notify with its blocked-count bookkeeping (no lost wake-ups, no
/// premature advance).
struct VirtualCondition {
    shared: Arc<VcShared>,
    id: u64,
    epoch: AtomicU64,
}

impl Condition for VirtualCondition {
    fn epoch(&self) -> u64 {
        let _st = self.shared.state.lock().unwrap();
        self.epoch.load(Ordering::SeqCst)
    }

    fn wait_past(&self, seen: u64, timeout: Duration) {
        let sh = &self.shared;
        let participant = VcShared::current_thread_attached(sh);
        let mut st = sh.state.lock().unwrap();
        if self.epoch.load(Ordering::SeqCst) > seen || timeout.is_zero() {
            return;
        }
        let deadline = st.now.saturating_add(timeout);
        let id = st.next_id;
        st.next_id += 1;
        st.waiters.push(Waiter { id, deadline, cond: Some(self.id), participant, woken: false });
        VcShared::add_blocked(&mut st, participant);
        VcShared::try_advance(&mut st, &sh.wake);
        loop {
            let me = st.waiters.iter().find(|w| w.id == id).unwrap();
            if me.woken || st.now >= deadline {
                break;
            }
            st = sh.wake.wait(st).unwrap();
        }
        let pos = st.waiters.iter().position(|w| w.id == id).unwrap();
        let was_woken = st.waiters.swap_remove(pos).woken;
        if !was_woken {
            // Timed out: notify_all never discounted us.
            VcShared::remove_blocked(&mut st, participant);
        }
        // See VirtualClock::sleep: a departing non-participant may leave
        // the participants unanimous again.
        VcShared::try_advance(&mut st, &sh.wake);
    }

    fn notify_all(&self) {
        let sh = &self.shared;
        let mut st = sh.state.lock().unwrap();
        self.epoch.fetch_add(1, Ordering::SeqCst);
        // Discount every waiter on this condition *now*: they are
        // logically runnable from this instant, and counting them as
        // blocked until their OS thread resumes would let the clock
        // advance past the moment they are about to observe.
        let state = &mut *st;
        let (mut woke, mut woke_others) = (0, 0);
        for w in state.waiters.iter_mut() {
            if w.cond == Some(self.id) && !w.woken {
                w.woken = true;
                if w.participant {
                    woke += 1;
                } else {
                    woke_others += 1;
                }
            }
        }
        state.blocked -= woke;
        state.blocked_others -= woke_others;
        sh.wake.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::ParticipantGuard;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn starts_at_zero() {
        assert_eq!(VirtualClock::new().now(), Duration::ZERO);
    }

    #[test]
    fn single_thread_sleep_advances_exactly() {
        // No participants: a lone sleeper advances immediately, by
        // exactly the slept duration — no real time passes.
        let c = VirtualClock::new();
        let t0 = std::time::Instant::now();
        c.sleep(ms(250));
        c.sleep(ms(750));
        assert_eq!(c.now(), ms(1000));
        assert!(t0.elapsed() < Duration::from_secs(1), "must not sleep for real");
    }

    #[test]
    fn zero_sleep_is_free() {
        let c = VirtualClock::new();
        c.sleep(Duration::ZERO);
        assert_eq!(c.now(), Duration::ZERO);
    }

    #[test]
    fn advances_to_earliest_deadline_among_participants() {
        // Two participants sleeping different durations: the clock must
        // step 100 -> 300, never past a live deadline.
        let clock = Arc::new(VirtualClock::new());
        clock.enter();
        clock.enter();
        let wakes: Vec<Duration> = std::thread::scope(|scope| {
            let handles: Vec<_> = [ms(100), ms(300)]
                .into_iter()
                .map(|d| {
                    let clock = Arc::clone(&clock);
                    scope.spawn(move || {
                        let _p =
                            ParticipantGuard::adopt(Arc::clone(&clock));
                        clock.sleep(d);
                        clock.now()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(wakes, vec![ms(100), ms(300)]);
        assert_eq!(clock.now(), ms(300));
    }

    #[test]
    fn clock_does_not_advance_while_a_participant_runs() {
        // One participant sleeps while the other is busy (never blocks):
        // time must stay frozen until the busy one exits.
        let clock = Arc::new(VirtualClock::new());
        clock.enter();
        clock.enter();
        std::thread::scope(|scope| {
            let sleeper = {
                let clock = Arc::clone(&clock);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    clock.sleep(ms(50));
                    clock.now()
                })
            };
            let busy = {
                let clock = Arc::clone(&clock);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    // Busy for real; the sleeper must not time-travel
                    // while we are runnable.
                    std::thread::sleep(ms(30));
                    clock.now()
                })
            };
            let seen_by_busy = busy.join().unwrap();
            assert_eq!(seen_by_busy, Duration::ZERO, "time frozen while runnable");
            // After busy exits (guard drop), the sleeper is unanimous.
            assert_eq!(sleeper.join().unwrap(), ms(50));
        });
    }

    #[test]
    fn notify_wakes_condition_waiter_at_the_notify_instant() {
        let clock = Arc::new(VirtualClock::new());
        let cond = clock.condition();
        let tok = cond.epoch();
        clock.enter();
        clock.enter();
        std::thread::scope(|scope| {
            let waiter = {
                let clock = Arc::clone(&clock);
                let cond = Arc::clone(&cond);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    cond.wait_past(tok, Duration::from_secs(3600));
                    clock.now()
                })
            };
            let notifier = {
                let clock = Arc::clone(&clock);
                let cond = Arc::clone(&cond);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    clock.sleep(ms(40));
                    cond.notify_all();
                })
            };
            notifier.join().unwrap();
            assert_eq!(waiter.join().unwrap(), ms(40), "woken at the notify instant");
        });
    }

    #[test]
    fn unnotified_wait_consumes_exactly_its_timeout() {
        let c = VirtualClock::new();
        let cond = c.condition();
        cond.wait_past(cond.epoch(), ms(120));
        assert_eq!(c.now(), ms(120));
    }

    #[test]
    fn stale_token_returns_without_advancing() {
        let c = VirtualClock::new();
        let cond = c.condition();
        let tok = cond.epoch();
        cond.notify_all();
        cond.wait_past(tok, Duration::from_secs(3600));
        assert_eq!(c.now(), Duration::ZERO, "pre-wait notify must not be lost");
    }

    #[test]
    fn conditions_are_independent() {
        // A notify on one condition must not wake (or discount) a
        // waiter on another.
        let c = VirtualClock::new();
        let a = c.condition();
        let b = c.condition();
        b.notify_all();
        let tok = a.epoch();
        a.wait_past(tok, ms(80)); // times out despite b's notify
        assert_eq!(c.now(), ms(80));
        assert_eq!(a.epoch(), tok);
    }

    #[test]
    fn unattached_thread_cannot_advance_time_while_participant_runs() {
        // An unattached thread (e.g. a monitor polling the store) may
        // park on the clock, but it must never count toward the advance
        // quorum: time stays frozen until the *attached* participant
        // blocks, and the monitor's departure hands the advance back to
        // the participants.
        let clock = Arc::new(VirtualClock::new());
        clock.enter(); // slot reserved; its thread attaches below
        std::thread::scope(|scope| {
            let monitor = {
                let clock = Arc::clone(&clock);
                scope.spawn(move || {
                    // deliberately NOT attached
                    clock.sleep(ms(10));
                    clock.now()
                })
            };
            let participant = {
                let clock = Arc::clone(&clock);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    // busy for real so the monitor is parked by now
                    std::thread::sleep(Duration::from_millis(200));
                    let before = clock.now();
                    clock.sleep(ms(50));
                    (before, clock.now())
                })
            };
            let monitor_wake = monitor.join().unwrap();
            let (before, after) = participant.join().unwrap();
            assert_eq!(
                before,
                Duration::ZERO,
                "an unattached park must not advance time past a running participant"
            );
            // The monitor wakes at its 10 ms deadline, but its own
            // departure may hand the advance to the participant before
            // it reads the clock again — it observes 10..=50 ms.
            assert!(
                monitor_wake >= ms(10) && monitor_wake <= ms(50),
                "monitor wake read {monitor_wake:?}"
            );
            assert_eq!(after, ms(50), "participant's sleep is unaffected");
        });
    }

    #[test]
    fn same_deadline_wakes_all_sleepers() {
        let clock = Arc::new(VirtualClock::new());
        clock.enter();
        clock.enter();
        let wakes: Vec<Duration> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let clock = Arc::clone(&clock);
                    scope.spawn(move || {
                        let _p =
                            ParticipantGuard::adopt(Arc::clone(&clock));
                        clock.sleep(ms(500));
                        clock.now()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(wakes, vec![ms(500), ms(500)]);
    }
}
