//! [`Gossip`] — epidemic federation: merge with a seeded random subset
//! of peers each epoch. No global barrier, no full fan-in.

use std::sync::Arc;

use anyhow::Result;

use crate::metrics::timeline::SpanKind;
use crate::strategy::Contribution;
use crate::tensor::FlatParams;
use crate::util::Rng;

use super::{EpochCtx, FederationProtocol, ProtocolOutcome};

/// The peers node `node_id` pulls in `epoch`: a uniform `fanout`-subset
/// of the other nodes, drawn from a fresh RNG keyed by
/// `(seed, node_id, epoch)` — replayable and history-free, so the whole
/// gossip schedule of a trial is determined by its config alone.
/// Returned sorted for a stable contribution order.
pub fn gossip_peers(
    seed: u64,
    node_id: usize,
    epoch: usize,
    n_nodes: usize,
    fanout: usize,
) -> Vec<usize> {
    let mut peers: Vec<usize> = (0..n_nodes).filter(|&p| p != node_id).collect();
    let mut rng = Rng::new(
        seed ^ (node_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (epoch as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03),
    );
    rng.shuffle(&mut peers);
    peers.truncate(fanout.min(peers.len()));
    peers.sort_unstable();
    peers
}

/// Gossip federation: after each epoch, push `w^k`, then pull the latest
/// entry of each of `fanout` seeded-random peers and merge client-side.
///
/// Per epoch a node reads at most `fanout` peer blobs instead of the
/// async protocol's full `latest_per_node` fan-in, so pull traffic is
/// O(m) per node per epoch regardless of K — the scalable regime for
/// large fleets. Information still spreads to every node in O(log K)
/// epochs in expectation, the classic epidemic bound.
pub struct Gossip {
    fanout: usize,
    seed: u64,
}

impl Gossip {
    /// Per-node protocol state; `seed` is the trial seed, which (with the
    /// node id and epoch) fixes the whole peer schedule.
    pub fn new(fanout: usize, seed: u64) -> Gossip {
        Gossip { fanout, seed }
    }
}

impl FederationProtocol for Gossip {
    fn name(&self) -> &'static str {
        "gossip"
    }

    fn after_epoch(
        &mut self,
        ctx: &mut EpochCtx<'_>,
        params: &mut FlatParams,
    ) -> Result<ProtocolOutcome> {
        let round = ctx.epoch as u64;
        let own_seq = ctx.push_weights(params, round)?;
        let mut out = ProtocolOutcome { pushes: 1, ..Default::default() };

        let t_agg = ctx.clock.now();
        let peers = gossip_peers(self.seed, ctx.node_id, ctx.epoch, ctx.n_nodes, self.fanout);
        let mut pulled = Vec::with_capacity(peers.len());
        for peer in peers {
            // Per-peer pulls, not a full latest_per_node fan-in: a peer
            // that has not pushed yet simply contributes nothing.
            if let Some(e) = ctx.store.latest_for_node(peer)? {
                pulled.push(e);
            }
        }
        ctx.record_pull(&pulled);
        let mut contribs = vec![Contribution {
            node_id: ctx.node_id,
            n_examples: ctx.n_examples,
            is_self: true,
            seq: own_seq,
            params: Arc::new(params.clone()),
        }];
        for e in &pulled {
            contribs.push(Contribution {
                node_id: e.node_id,
                n_examples: e.n_examples,
                is_self: false,
                seq: e.seq,
                params: Arc::clone(&e.params),
            });
        }
        if contribs.len() > 1 {
            if let Some(new_params) = ctx.strategy.aggregate_pooled(&contribs, ctx.pool) {
                *params = new_params;
                out.aggregations = 1;
                ctx.adopt_aggregate(params, &pulled);
            }
        }
        ctx.timeline.record(SpanKind::Aggregate, t_agg, ctx.clock.now());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::super::protocol_tests::TestNode;
    use super::*;
    use crate::config::{ExperimentConfig, FederationMode};
    use crate::store::{MemoryStore, WeightStore};

    #[test]
    fn peer_schedule_is_deterministic_and_well_formed() {
        for seed in [1u64, 42, 1234] {
            for node_id in 0..5 {
                for epoch in 0..8 {
                    let a = gossip_peers(seed, node_id, epoch, 5, 2);
                    let b = gossip_peers(seed, node_id, epoch, 5, 2);
                    assert_eq!(a, b, "same inputs must give the same peers");
                    assert_eq!(a.len(), 2);
                    assert!(a.iter().all(|&p| p < 5 && p != node_id));
                    assert!(a[0] < a[1], "sorted, no duplicates");
                }
            }
        }
    }

    #[test]
    fn peer_schedule_varies_across_epochs_and_clamps_fanout() {
        let schedules: Vec<Vec<usize>> =
            (0..10).map(|e| gossip_peers(7, 0, e, 6, 2)).collect();
        assert!(
            schedules.iter().any(|s| s != &schedules[0]),
            "schedule must not be constant across epochs: {schedules:?}"
        );
        // fanout larger than the peer set: everyone else, once
        assert_eq!(gossip_peers(7, 1, 0, 3, 10), vec![0, 2]);
        assert!(gossip_peers(7, 0, 0, 1, 2).is_empty(), "no peers when alone");
    }

    /// Drive a 3-node gossip schedule sequentially (node order within an
    /// epoch fixed) — the whole run must replay bit-identically from the
    /// seed.
    #[test]
    fn sequential_gossip_run_replays_bit_identically() {
        let run = || {
            let cfg = ExperimentConfig {
                mode: FederationMode::Gossip { fanout: 1 },
                n_nodes: 3,
                ..Default::default()
            };
            let store = MemoryStore::new();
            let mut nodes: Vec<TestNode> =
                (0..3).map(|id| TestNode::new(id, &cfg)).collect();
            for epoch in 0..4 {
                for node in nodes.iter_mut() {
                    let out = node.epoch(&store, 3, epoch, Duration::from_secs(1));
                    assert_eq!(out.pushes, 1);
                    assert_eq!(out.stalled_at, None);
                    if epoch >= 1 {
                        // every peer has pushed by now, so the fanout-1
                        // pull always finds an entry and merges
                        assert_eq!(out.aggregations, 1, "node {} epoch {epoch}", node.node_id);
                    }
                }
            }
            (store.push_count(), nodes.into_iter().map(|n| n.params).collect::<Vec<_>>())
        };
        let (pushes_a, params_a) = run();
        let (pushes_b, params_b) = run();
        assert_eq!(pushes_a, 12, "3 nodes x 4 epochs, one push each");
        assert_eq!(pushes_a, pushes_b);
        for (a, b) in params_a.iter().zip(&params_b) {
            assert_eq!(a.0, b.0, "fixed seed must replay bit-identically");
        }
    }
}
