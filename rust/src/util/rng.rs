//! Deterministic PRNG (splitmix64 seeding + xoshiro256**) for everything
//! random on the rust side: data synthesis, label-skew partitioning,
//! C-sampling in FedAvgAsync, latency jitter, failure injection.
//!
//! Determinism matters here: every experiment in EXPERIMENTS.md is
//! reproducible from `(experiment config, trial seed)` alone.

/// xoshiro256** — fast, high-quality, no_std-friendly.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per node, per epoch).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output of the generator.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // modulo bias is < 2^-40 for the n we use (< 2^24).
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..10).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn chance_rate_roughly_matches() {
        let mut r = Rng::new(11);
        let hits = (0..100_000).filter(|_| r.chance(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffled order changed");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
