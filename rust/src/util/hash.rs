//! Hashing — two distinct families with two distinct contracts:
//!
//! * **FNV-1a 64-bit** ([`fnv1a64`], [`fnv1a64_multi`], [`hash_f32s`]) —
//!   the *persisted* hash: v1/v2 blob integrity headers
//!   ([`crate::tensor::codec`]) are FNV over the serialized bytes, and
//!   on-disk compatibility pins these functions byte-for-byte. They are
//!   frozen: a faster hash here would silently invalidate every stored
//!   blob.
//! * **Chunked word-at-a-time hash** ([`chunked_hash_f32s`]) — the
//!   *in-memory* change-detection hash ([`crate::tensor::FlatParams::content_hash`],
//!   weight-level store state checks). It mixes 8 bytes per multiply
//!   instead of FNV's 1 and digests fixed [`HASH_CHUNK_ELEMS`]-element
//!   chunks that combine in chunk order, so it parallelizes on a
//!   [`ChunkPool`] with bit-identical results for any thread count. Its
//!   value never touches disk, so it owes no compatibility to anything.

use crate::par::ChunkPool;

/// FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_multi(&[bytes])
}

/// FNV-1a over the concatenation of several byte slices, without
/// materializing the concatenation — used by the blob codec to hash a
/// header with its hash field treated as zeroed.
pub fn fnv1a64_multi(parts: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Hash an f32 slice by its raw little-endian bytes (sequential FNV-1a;
/// see the module docs for when to prefer [`chunked_hash_f32s`]).
pub fn hash_f32s(xs: &[f32]) -> u64 {
    // Safety-free path: serialize in chunks to avoid an extra allocation.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Combine hashes order-dependently (for store state hashes and the
/// chunk-digest combine of [`chunked_hash_f32s`]).
pub fn combine(a: u64, b: u64) -> u64 {
    a ^ b
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(a << 6)
        .wrapping_add(a >> 2)
}

/// f32 elements per chunk of the chunked content hash: 16 Ki elements =
/// 64 KiB, the kernel layer's standard chunk width. Fixed — never a
/// function of the thread count (the [`crate::par`] determinism
/// contract).
pub const HASH_CHUNK_ELEMS: usize = 16 * 1024;

/// One multiply-xorshift mixing step over a 64-bit word (two f32s per
/// step vs FNV's one byte): the multiply diffuses low bits upward, the
/// shift folds high bits back down, and both are bijective — any
/// single-bit change in `w` changes the result.
#[inline]
fn mix64(h: u64, w: u64) -> u64 {
    let m = (h ^ w).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    m ^ (m >> 33)
}

/// Word-at-a-time digest of one chunk (two f32 bit patterns packed per
/// 64-bit mixing step; an odd trailing element mixes alone with a tag
/// bit so `[x]` and `[x, 0.0]` digest differently).
fn chunk_digest(xs: &[f32]) -> u64 {
    let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut pairs = xs.chunks_exact(2);
    for p in pairs.by_ref() {
        let w = (p[0].to_bits() as u64) | ((p[1].to_bits() as u64) << 32);
        h = mix64(h, w);
    }
    if let [tail] = pairs.remainder() {
        h = mix64(h, (1u64 << 63) | tail.to_bits() as u64);
    }
    h
}

/// Fast change-detection hash of an f32 slice: word-at-a-time digests
/// over fixed [`HASH_CHUNK_ELEMS`]-element chunks, combined in chunk
/// order. **Not** FNV-compatible and never persisted — the blob formats
/// keep [`fnv1a64`] (module docs).
pub fn chunked_hash_f32s(xs: &[f32]) -> u64 {
    chunked_hash_f32s_pooled(xs, ChunkPool::sequential())
}

/// [`chunked_hash_f32s`] with the per-chunk digests computed on `pool`.
/// Chunk boundaries and the combine order are fixed, so the result is
/// bit-identical for any thread count.
pub fn chunked_hash_f32s_pooled(xs: &[f32], pool: ChunkPool) -> u64 {
    let digests = pool.map(xs.chunks(HASH_CHUNK_ELEMS).collect(), |_, chunk| chunk_digest(chunk));
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ xs.len() as u64;
    for d in digests {
        h = combine(h, d);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_fnv_vector() {
        // FNV-1a("") = offset basis
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        // differs for different inputs
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
    }

    #[test]
    fn f32_hash_matches_byte_hash() {
        let xs = [1.0f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        for x in &xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(hash_f32s(&xs), fnv1a64(&bytes));
    }

    #[test]
    fn combine_is_order_sensitive() {
        assert_ne!(combine(1, 2), combine(2, 1));
    }

    #[test]
    fn multi_part_hash_matches_concatenation() {
        assert_eq!(fnv1a64_multi(&[b"ab", b"", b"cd"]), fnv1a64(b"abcd"));
        assert_eq!(fnv1a64_multi(&[]), fnv1a64(b""));
    }

    fn training_like(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * 0.0173).sin() * 0.8).collect()
    }

    #[test]
    fn chunked_hash_is_thread_count_independent() {
        // spans several chunks plus an odd tail
        for n in [0, 1, 2, 3, HASH_CHUNK_ELEMS, HASH_CHUNK_ELEMS + 1, 3 * HASH_CHUNK_ELEMS + 7] {
            let xs = training_like(n);
            let reference = chunked_hash_f32s(&xs);
            for threads in [1, 2, 8] {
                assert_eq!(
                    chunked_hash_f32s_pooled(&xs, ChunkPool::new(threads)),
                    reference,
                    "n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn chunked_hash_sees_every_position() {
        // flipping any single element (first, chunk-boundary, odd tail)
        // must change the hash
        let mut xs = training_like(2 * HASH_CHUNK_ELEMS + 5);
        let h0 = chunked_hash_f32s(&xs);
        for i in [0, 1, HASH_CHUNK_ELEMS - 1, HASH_CHUNK_ELEMS, 2 * HASH_CHUNK_ELEMS + 4] {
            let old = xs[i];
            xs[i] += 1.0e-4;
            assert_ne!(chunked_hash_f32s(&xs), h0, "flip at {i} must change the hash");
            xs[i] = old;
        }
        assert_eq!(chunked_hash_f32s(&xs), h0, "restored input restores the hash");
    }

    #[test]
    fn chunked_hash_distinguishes_length_and_padding() {
        assert_ne!(chunked_hash_f32s(&[1.0]), chunked_hash_f32s(&[1.0, 0.0]));
        assert_ne!(chunked_hash_f32s(&[]), chunked_hash_f32s(&[0.0]));
        // a zero tail after a chunk boundary is not invisible
        let a = vec![0.5; HASH_CHUNK_ELEMS];
        let mut b = a.clone();
        b.push(0.0);
        assert_ne!(chunked_hash_f32s(&a), chunked_hash_f32s(&b));
    }
}
