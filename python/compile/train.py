"""Flat-parameter train/eval/init step builders (the L2 <-> L3 boundary).

Every artifact exchanges model state as flat f32 vectors so the rust
coordinator can treat all models uniformly and client-side aggregation
(the paper's core mechanism) is architecture-independent:

  init_step(seed u32[2])                          -> params f32[P]
  train_step(params, m, v, step i32[], x, y)      -> (params', m', v',
                                                      step', loss, acc_count)
  eval_step(params, x, y)                         -> (loss_sum, acc_count)

The pytree <-> flat mapping comes from `ravel_pytree` at trace time; the
Adam update runs on the flat vector through the fused L1 Pallas kernel
(or the jnp oracle when `use_pallas=False`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import fused_adam_step
from .kernels.ref import adam_step_ref
from .models import ModelSpec
from .models import common as model_common


def param_count(spec: ModelSpec) -> int:
    def flat_init(key):
        flat, _ = ravel_pytree(spec.init(key))
        return flat

    out = jax.eval_shape(flat_init, jax.random.PRNGKey(0))
    return int(out.size)


def _unravel_fn(spec: ModelSpec):
    """Build the static flat->pytree function (shapes only, no compute)."""
    shapes = jax.eval_shape(spec.init, jax.random.PRNGKey(0))
    zeros = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    _, unravel = ravel_pytree(zeros)
    return unravel


def make_init_step(spec: ModelSpec):
    def init_step(seed):
        """seed: u32[2] raw PRNG key data -> flat params f32[P]."""
        key = jax.random.wrap_key_data(seed.astype(jnp.uint32))
        params = spec.init(key)
        flat, _ = ravel_pytree(params)
        return (flat.astype(jnp.float32),)

    return init_step


def make_train_step(spec: ModelSpec, use_pallas: bool = True):
    unravel = _unravel_fn(spec)
    adam = (
        functools.partial(
            fused_adam_step, lr=spec.lr, weight_decay=spec.weight_decay
        )
        if use_pallas
        else functools.partial(
            adam_step_ref, lr=spec.lr, weight_decay=spec.weight_decay
        )
    )

    def train_step(flat, m, v, step, x, y):
        """One SGD step with Adam(W). step is the 0-based counter *before*
        this update; loss is the pre-update minibatch loss."""
        model_common.set_pallas_dense(use_pallas)

        def loss_fn(fp):
            loss, acc = spec.loss_and_metrics(unravel(fp), (x, y), train=True)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        new_step = step + 1
        flat2, m2, v2 = adam(flat, m, v, grads, new_step)
        return flat2, m2, v2, new_step, loss, acc

    return train_step


def make_eval_step(spec: ModelSpec, use_pallas: bool = True):
    unravel = _unravel_fn(spec)

    def eval_step(flat, x, y):
        """Returns (sum of per-batch mean loss, correct-prediction count) so
        rust can accumulate over an un-partitioned held-out set."""
        model_common.set_pallas_dense(use_pallas)
        loss, acc = spec.loss_and_metrics(unravel(flat), (x, y), train=False)
        return loss, acc

    return eval_step


def example_batch(spec: ModelSpec):
    """ShapeDtypeStructs for (x, y) used to lower the jitted steps."""
    b = spec.batch_size
    if spec.input_dtype == "i32":
        x = jax.ShapeDtypeStruct((b, *spec.input_shape), jnp.int32)
    else:
        x = jax.ShapeDtypeStruct((b, *spec.input_shape), jnp.float32)
    y = jax.ShapeDtypeStruct((b,), jnp.int32)
    return x, y
