//! [`AsyncHash`] — asynchronous FedAvgAsync (paper Algorithm 1), with
//! change detection on the store's monotone version counter.

use std::sync::Arc;

use anyhow::Result;

use crate::metrics::timeline::SpanKind;
use crate::strategy::Contribution;
use crate::tensor::FlatParams;
use crate::util::Rng;

use super::{EpochCtx, FederationProtocol, ProtocolOutcome};

/// Asynchronous federation — Algorithm 1's WeightUpdate: with sampling
/// probability `C`, push `w^k`, check whether the store changed since the
/// last pull, and if so pull `ω`, set `ω[k] ← w^k`, aggregate
/// client-side. No global round and no waiting — a straggler never
/// blocks anyone.
///
/// Change detection uses [`crate::store::WeightStore::version`] (an O(1)
/// counter read) instead of re-hashing the entry log. Note that on a
/// sampled epoch the node's *own* push has just advanced the counter, so
/// the store necessarily reads as changed and the pull proceeds — same
/// as the paper's hash check, whose value is also moved by the client's
/// own deposit. The token's real job is pull bookkeeping: it is
/// recorded *before* the pull, so a peer push racing the pull is either
/// included in it or re-detected next epoch — never silently masked,
/// which is what the old "re-read `state_hash` after aggregating"
/// bookkeeping did. (Redundant *downloads* on an unchanged store are
/// avoided one layer down, by [`crate::store::CachedStore`].)
pub struct AsyncHash {
    sample_prob: f64,
    rng: Rng,
    /// Store version observed at the last pull.
    last_seen: Option<u64>,
}

impl AsyncHash {
    /// Per-node protocol state; the sampling stream derives from the
    /// trial seed and node id (same schedule for the same config).
    pub fn new(sample_prob: f64, seed: u64, node_id: usize) -> AsyncHash {
        AsyncHash {
            sample_prob,
            rng: Rng::new(seed ^ ((node_id as u64 + 1) << 20)),
            last_seen: None,
        }
    }

    #[cfg(test)]
    pub(crate) fn last_seen(&self) -> Option<u64> {
        self.last_seen
    }
}

impl FederationProtocol for AsyncHash {
    fn name(&self) -> &'static str {
        "async"
    }

    fn after_epoch(
        &mut self,
        ctx: &mut EpochCtx<'_>,
        params: &mut FlatParams,
    ) -> Result<ProtocolOutcome> {
        // Algorithm 1: sampling gates the WeightUpdate step; a non-sampled
        // client keeps training on its own weights.
        if !self.rng.chance(self.sample_prob) {
            return Ok(ProtocolOutcome::default());
        }

        let t_agg = ctx.clock.now();
        ctx.push_weights(params, ctx.epoch as u64)?;
        let mut out = ProtocolOutcome { pushes: 1, ..Default::default() };

        // "performs a check to see if the remote server has changed state"
        let v_now = ctx.store.version()?;
        let changed = self.last_seen.map(|v| v != v_now).unwrap_or(true);
        if changed {
            // v_now was read before this pull: anything the pull misses
            // is newer than v_now and re-detected next epoch.
            let entries = ctx.store.latest_per_node()?;
            ctx.record_pull(&entries);
            // ω[k] <- w^k : own current weights replace our stored entry
            // (we keep the store-assigned seq so staleness-aware
            // strategies see honest sequence numbers).
            let mut contribs: Vec<Contribution> = entries
                .iter()
                .map(|e| Contribution {
                    node_id: e.node_id,
                    n_examples: e.n_examples,
                    is_self: e.node_id == ctx.node_id,
                    seq: e.seq,
                    params: if e.node_id == ctx.node_id {
                        Arc::new(params.clone())
                    } else {
                        Arc::clone(&e.params)
                    },
                })
                .collect();
            if !contribs.iter().any(|c| c.is_self) {
                // our push raced a clear() or failed partially; contribute
                // locally anyway
                let max_seq = contribs.iter().map(|c| c.seq).max().unwrap_or(0);
                contribs.push(Contribution {
                    node_id: ctx.node_id,
                    n_examples: ctx.n_examples,
                    is_self: true,
                    seq: max_seq,
                    params: Arc::new(params.clone()),
                });
            }
            if contribs.len() > 1 {
                if let Some(new_params) = ctx.strategy.aggregate_pooled(&contribs, ctx.pool) {
                    *params = new_params;
                    out.aggregations = 1;
                    ctx.adopt_aggregate(params, &entries);
                }
            }
            self.last_seen = Some(v_now);
        }
        ctx.timeline.record(SpanKind::Aggregate, t_agg, ctx.clock.now());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    use super::super::protocol_tests::TestNode;
    use super::*;
    use crate::config::{ExperimentConfig, FederationMode};
    use crate::store::{MemoryStore, PushRequest, WeightEntry, WeightStore};

    fn async_cfg() -> ExperimentConfig {
        ExperimentConfig { mode: FederationMode::Async, ..Default::default() }
    }

    fn peer_push(store: &dyn WeightStore, node: usize, val: f32) {
        store
            .push(PushRequest::raw(node, 0, 0, 100, Arc::new(FlatParams(vec![val; 4]))))
            .unwrap();
    }

    #[test]
    fn aggregates_when_peers_present_and_skips_alone() {
        let cfg = async_cfg();
        let store = MemoryStore::new();
        let mut node = TestNode::new(0, &cfg);
        // alone: push happens, but a 1-entry pull set is not aggregated
        let out = node.epoch(&store, 2, 0, Duration::from_secs(1));
        assert_eq!((out.pushes, out.aggregations), (1, 0));
        // with a peer entry, the next epoch aggregates
        peer_push(&store, 1, 8.0);
        let out = node.epoch(&store, 2, 1, Duration::from_secs(1));
        assert_eq!((out.pushes, out.aggregations), (1, 1));
        assert_eq!(node.params.0, vec![4.0; 4], "mean of own 0s and peer 8s");
    }

    /// A store whose `latest_per_node` races a peer push in *after* the
    /// snapshot it returns — the exact interleaving the old bookkeeping
    /// (recording the post-aggregation hash) silently masked.
    struct RacingStore {
        inner: MemoryStore,
        injected: AtomicBool,
    }

    impl WeightStore for RacingStore {
        fn push(&self, req: PushRequest) -> anyhow::Result<u64> {
            self.inner.push(req)
        }
        fn latest_per_node(&self) -> anyhow::Result<Vec<WeightEntry>> {
            let snapshot = self.inner.latest_per_node()?;
            if !self.injected.swap(true, Ordering::SeqCst) {
                peer_push(&self.inner, 1, 42.0); // lands just after the pull
            }
            Ok(snapshot)
        }
        fn entries_for_round(&self, round: u64) -> anyhow::Result<Vec<WeightEntry>> {
            self.inner.entries_for_round(round)
        }
        fn state_hash(&self) -> anyhow::Result<u64> {
            self.inner.state_hash()
        }
        fn latest_for_node(&self, node_id: usize) -> anyhow::Result<Option<WeightEntry>> {
            self.inner.latest_for_node(node_id)
        }
        fn version(&self) -> anyhow::Result<u64> {
            self.inner.version()
        }
        fn wait_for_change(&self, since: u64, timeout: Duration) -> anyhow::Result<u64> {
            self.inner.wait_for_change(since, timeout)
        }
        fn push_count(&self) -> u64 {
            self.inner.push_count()
        }
        fn clear(&self) -> anyhow::Result<()> {
            self.inner.clear()
        }
    }

    #[test]
    fn push_racing_the_pull_is_never_masked() {
        use crate::metrics::timeline::Timeline;
        use crate::strategy::StrategyKind;
        use crate::time::RealClock;

        let store = RacingStore { inner: MemoryStore::new(), injected: AtomicBool::new(false) };
        peer_push(&store.inner, 1, 8.0);

        // Drive AsyncHash directly (not via the harness) so the test can
        // inspect the recorded pull token.
        let clock = RealClock::shared();
        let mut proto = AsyncHash::new(1.0, 42, 0);
        let mut strategy = StrategyKind::FedAvg.build();
        let mut timeline = Timeline::new(0);
        let mut codec = crate::compress::CodecState::new(Default::default());
        let mut params = FlatParams(vec![0.0; 4]);
        let mut epoch = |proto: &mut AsyncHash,
                         params: &mut FlatParams,
                         strategy: &mut Box<dyn crate::strategy::Strategy>,
                         timeline: &mut Timeline,
                         epoch: usize| {
            let mut ctx = EpochCtx {
                node_id: 0,
                n_nodes: 2,
                round_k: 2,
                epoch,
                n_examples: 100,
                store: &store,
                strategy: strategy.as_mut(),
                timeline,
                sync_timeout: Duration::from_secs(1),
                clock: clock.as_ref(),
                codec: &mut codec,
                pool: crate::par::ChunkPool::sequential(),
                tracer: None,
            };
            proto.after_epoch(&mut ctx, params).unwrap()
        };

        let out = epoch(&mut proto, &mut params, &mut strategy, &mut timeline, 0);
        assert_eq!(out.aggregations, 1);
        assert_eq!(params.0, vec![4.0; 4], "racing push must not be in this pull");

        // The recorded token predates the racing push, so the store still
        // reads as changed — the old post-aggregation re-read recorded
        // the newer version here and masked the entry forever.
        let seen = proto.last_seen().expect("async protocol records a pull token");
        assert_ne!(store.version().unwrap(), seen, "store must still read as changed");

        // ...and the next epoch folds the racing weights in.
        let out = epoch(&mut proto, &mut params, &mut strategy, &mut timeline, 1);
        assert_eq!(out.aggregations, 1);
        assert_eq!(params.0, vec![23.0; 4], "mean of own 4s and racing 42s");
    }
}
