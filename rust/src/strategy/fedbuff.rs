//! FedBuff (Nguyen et al. 2022) — buffered asynchronous aggregation,
//! adapted to the serverless store: the node only aggregates once it has
//! observed `buffer_size` *new* peer entries since its last aggregation;
//! until then it keeps training on its current weights.
//!
//! This is the second §5 future-work strategy; it trades update frequency
//! for lower variance per update.

use std::collections::HashMap;

use super::{fedavg_of, Contribution, Strategy};
use crate::par::ChunkPool;
use crate::tensor::FlatParams;

/// Buffered asynchronous aggregation: wait for `buffer_size` fresh peer
/// entries before averaging.
pub struct FedBuff {
    buffer_size: usize,
    /// Last seq seen per peer at the last aggregation.
    seen: HashMap<usize, u64>,
}

impl FedBuff {
    /// Aggregate only once `buffer_size` (≥ 1) fresh peer entries arrive.
    pub fn new(buffer_size: usize) -> Self {
        assert!(buffer_size >= 1);
        FedBuff { buffer_size, seen: HashMap::new() }
    }

    fn count_new(&self, contribs: &[Contribution]) -> usize {
        contribs
            .iter()
            .filter(|c| !c.is_self)
            .filter(|c| self.seen.get(&c.node_id).map(|&s| c.seq > s).unwrap_or(true))
            .count()
    }
}

impl Strategy for FedBuff {
    fn name(&self) -> &'static str {
        "fedbuff"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        contribs.iter().find(|c| c.is_self)?;
        let fresh = self.count_new(contribs);
        if fresh < self.buffer_size {
            return None; // buffer not full: keep local weights
        }
        for c in contribs.iter().filter(|c| !c.is_self) {
            self.seen.insert(c.node_id, c.seq);
        }
        Some(fedavg_of(contribs, pool))
    }

    fn reset(&mut self) {
        self.seen.clear();
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::strategy_tests::contrib;
    use super::*;

    fn contrib_seq(node: usize, is_self: bool, val: f32, seq: u64) -> Contribution {
        Contribution {
            node_id: node,
            n_examples: 1,
            is_self,
            seq,
            params: Arc::new(FlatParams(vec![val])),
        }
    }

    #[test]
    fn waits_for_buffer_to_fill() {
        let mut s = FedBuff::new(2);
        // only one fresh peer -> no update
        assert!(s
            .aggregate(&[contrib_seq(0, true, 0.0, 10), contrib_seq(1, false, 4.0, 1)])
            .is_none());
        // two fresh peers -> aggregate
        let out = s
            .aggregate(&[
                contrib_seq(0, true, 0.0, 10),
                contrib_seq(1, false, 3.0, 1),
                contrib_seq(2, false, 6.0, 2),
            ])
            .unwrap();
        assert!((out.0[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn already_seen_entries_do_not_count() {
        let mut s = FedBuff::new(1);
        let c1 = contrib_seq(1, false, 4.0, 7);
        assert!(s.aggregate(&[contrib_seq(0, true, 0.0, 9), c1.clone()]).is_some());
        // same peer seq again -> stale -> buffered, no update
        assert!(s.aggregate(&[contrib_seq(0, true, 2.0, 10), c1]).is_none());
        // newer seq from that peer counts again
        assert!(s
            .aggregate(&[contrib_seq(0, true, 2.0, 11), contrib_seq(1, false, 4.0, 8)])
            .is_some());
    }

    #[test]
    fn reset_clears_seen() {
        let mut s = FedBuff::new(1);
        let c1 = contrib_seq(1, false, 4.0, 7);
        s.aggregate(&[contrib_seq(0, true, 0.0, 9), c1.clone()]).unwrap();
        s.reset();
        assert!(s.aggregate(&[contrib_seq(0, true, 0.0, 9), c1]).is_some());
    }

    #[test]
    fn requires_self_entry() {
        let mut s = FedBuff::new(1);
        assert!(s.aggregate(&[contrib(1, 1, false, &[1.0])]).is_none());
    }
}
