//! Synthetic byte-level corpus standing in for WikiText-103 (offline image;
//! DESIGN.md §Substitutions).
//!
//! The generator is a seeded hidden-state automaton over a word vocabulary:
//! a hidden "topic" chain picks among word groups; words are drawn from the
//! active group and emitted as bytes with spaces/punctuation. The result
//! has genuine sequential structure at three scales (character, word,
//! topic), so a small causal LM's next-token accuracy improves smoothly
//! with training — which is all the paper's Table 7 comparison needs.

use crate::util::Rng;

/// Number of hidden topics and words per topic.
const TOPICS: usize = 8;
const WORDS_PER_TOPIC: usize = 24;
const WORD_MIN: usize = 2;
const WORD_MAX: usize = 9;
/// Probability of switching topic at a word boundary.
const TOPIC_SWITCH: f64 = 0.08;

/// A deterministic synthetic corpus of bytes (vocab = 256, like the
/// byte-level tokenizer on the python side).
pub struct TextCorpus {
    pub tokens: Vec<u8>,
}

impl TextCorpus {
    /// Generate `len` tokens from `seed`.
    pub fn generate(seed: u64, len: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x7E57_C0DE);
        // Build the vocabulary: TOPICS groups of lowercase words.
        let vocab: Vec<Vec<Vec<u8>>> = (0..TOPICS)
            .map(|t| {
                let mut r = rng.fork(t as u64 + 100);
                (0..WORDS_PER_TOPIC)
                    .map(|_| {
                        let wl = WORD_MIN + r.below(WORD_MAX - WORD_MIN + 1);
                        (0..wl).map(|_| b'a' + r.below(26) as u8).collect()
                    })
                    .collect()
            })
            .collect();

        let mut tokens = Vec::with_capacity(len + 16);
        let mut topic = 0usize;
        let mut words_in_sentence = 0usize;
        while tokens.len() < len {
            if rng.chance(TOPIC_SWITCH) {
                topic = rng.below(TOPICS);
            }
            // Zipf-ish word choice: favor low indices within the topic.
            let u = rng.f64();
            let w = ((u * u) * WORDS_PER_TOPIC as f64) as usize;
            tokens.extend_from_slice(&vocab[topic][w.min(WORDS_PER_TOPIC - 1)]);
            words_in_sentence += 1;
            if words_in_sentence > 6 && rng.chance(0.25) {
                tokens.extend_from_slice(b". ");
                words_in_sentence = 0;
            } else {
                tokens.push(b' ');
            }
        }
        tokens.truncate(len);
        TextCorpus { tokens }
    }

    /// Number of (seq_len+1)-token training windows with stride seq_len.
    pub fn num_windows(&self, seq_len: usize) -> usize {
        if self.tokens.len() <= seq_len {
            0
        } else {
            (self.tokens.len() - 1) / seq_len
        }
    }

    /// Window `idx` as `seq_len + 1` i32 tokens (input + next-token target
    /// come from the same window on the model side).
    pub fn window(&self, idx: usize, seq_len: usize) -> Vec<i32> {
        let start = idx * seq_len;
        let end = (start + seq_len + 1).min(self.tokens.len());
        let mut w: Vec<i32> = self.tokens[start..end].iter().map(|&b| b as i32).collect();
        while w.len() < seq_len + 1 {
            w.push(b' ' as i32); // pad the tail window with spaces
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = TextCorpus::generate(1, 1000);
        let b = TextCorpus::generate(1, 1000);
        assert_eq!(a.tokens, b.tokens);
        let c = TextCorpus::generate(2, 1000);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn exact_length_and_byte_range() {
        let c = TextCorpus::generate(3, 5000);
        assert_eq!(c.tokens.len(), 5000);
        assert!(c.tokens.iter().all(|&b| b == b' ' || b == b'.' || b.is_ascii_lowercase()));
    }

    #[test]
    fn windows_cover_and_pad() {
        let c = TextCorpus::generate(5, 1000);
        let n = c.num_windows(64);
        assert_eq!(n, 999 / 64);
        for i in 0..n {
            let w = c.window(i, 64);
            assert_eq!(w.len(), 65);
            assert!(w.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn corpus_is_compressible_structure() {
        // Repeated words => the corpus must reuse byte 3-grams far more
        // than uniform-random bytes would.
        let c = TextCorpus::generate(7, 20_000);
        let mut set = std::collections::HashSet::new();
        for win in c.tokens.windows(3) {
            set.insert([win[0], win[1], win[2]]);
        }
        // uniform random over 27 chars would give ~19k distinct 3-grams;
        // our structured corpus should stay well under 4k.
        assert!(set.len() < 4000, "distinct 3-grams = {}", set.len());
    }
}
