//! Compression-codec microbench: bytes-on-wire and encode/decode
//! throughput for every [`fedless::compress`] codec over an
//! mnist-sized parameter vector.
//!
//! Results land in `BENCH_compress.json` (the communication-cost
//! trajectory; re-run after codec changes and compare). CI runs this in
//! check mode (`--check`: tiny vector, few iterations) to keep the
//! artifact fresh without burning minutes.
//!
//! Run: `cargo bench --offline --bench compress [-- --check]` —
//! codec-only, needs no artifacts.

use std::fs;
use std::time::Instant;

use fedless::compress::{CodecKind, CodecState};
use fedless::tensor::codec::{raw_wire_bytes, BlobMeta};
use fedless::tensor::FlatParams;

struct Row {
    codec: String,
    wire_bytes: u64,
    ratio: f64,
    enc_gbps: f64,
    dec_gbps: f64,
    max_abs_err: f32,
}

/// Training-shaped pseudo-weights: smooth, bounded, non-trivial.
fn weights(n: usize) -> FlatParams {
    FlatParams((0..n).map(|i| ((i as f32) * 0.0137).sin() * 0.5).collect())
}

fn measure(kind: CodecKind, n: usize, iters: usize) -> Row {
    let params = weights(n);
    let base = FlatParams(params.0.iter().map(|x| x - 1e-3).collect());
    let codec = kind.build();
    let raw_bytes = (n * 4) as f64;

    // wire size through the real push path (header included)
    let mut state = CodecState::new(kind);
    state.set_base(1, &base);
    let meta = BlobMeta { node_id: 0, round: 0, epoch: 0, n_examples: 1 };
    let (wire_bytes, reconstruction) =
        state.encode_for_push(&meta, &params, fedless::par::ChunkPool::sequential()).expect("encode_for_push");

    // encode / decode payload throughput (codec only, no blob framing)
    let b = Some(&base);
    let mut payload = Vec::new();
    let t = Instant::now();
    for _ in 0..iters {
        payload = codec.encode(&params, b);
        std::hint::black_box(&payload);
    }
    let enc_gbps = raw_bytes * iters as f64 / t.elapsed().as_secs_f64() / 1e9;
    let t = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(codec.decode(&payload, n, b).expect("decode"));
    }
    let dec_gbps = raw_bytes * iters as f64 / t.elapsed().as_secs_f64() / 1e9;

    let row = Row {
        codec: kind.label(),
        wire_bytes,
        ratio: raw_wire_bytes(n) as f64 / wire_bytes as f64,
        enc_gbps,
        dec_gbps,
        max_abs_err: params.max_abs_diff(&reconstruction),
    };
    println!(
        "{:>9}  wire {:>9} B  ratio {:>5.2}x  enc {:>6.2} GB/s  dec {:>6.2} GB/s  max|err| {:.2e}",
        row.codec, row.wire_bytes, row.ratio, row.enc_gbps, row.dec_gbps, row.max_abs_err
    );
    row
}

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    // check mode: small vector + few iters, same artifact shape
    let (n, iters) = if check { (20_000, 5) } else { (1_000_000, 30) };
    println!(
        "weight-compression codecs over {n} f32 params ({} mode, {iters} iters)",
        if check { "check" } else { "full" }
    );

    let kinds = [
        CodecKind::None,
        CodecKind::Q8,
        CodecKind::TopK { frac: 0.1 },
        CodecKind::DeltaQ8,
    ];
    let rows: Vec<Row> = kinds.iter().map(|&k| measure(k, n, iters)).collect();

    let mut json = String::from("{\n  \"bench\": \"weight_compression_codecs\",\n");
    json.push_str(&format!(
        "  \"params\": {n},\n  \"raw_wire_bytes\": {},\n  \"iters\": {iters},\n  \"check_mode\": {check},\n  \"results\": [\n",
        raw_wire_bytes(n)
    ));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"codec\": \"{}\", \"wire_bytes\": {}, \"compression_ratio\": {:.3}, \
             \"encode_gbps\": {:.3}, \"decode_gbps\": {:.3}, \"max_abs_err\": {:e}}}{}\n",
            r.codec,
            r.wire_bytes,
            r.ratio,
            r.enc_gbps,
            r.dec_gbps,
            r.max_abs_err,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    fs::write("BENCH_compress.json", &json).expect("write BENCH_compress.json");
    println!("\nwrote BENCH_compress.json");
}
