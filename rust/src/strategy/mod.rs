//! Federated aggregation strategies, applied **client-side** (serverless:
//! "each client may implement its own aggregation strategy", §3).
//!
//! Implemented: the paper's three (FedAvg, FedAvgM, FedAdam — §4.2.2) plus
//! the two asynchronous extensions its §5 lists as future work:
//! staleness-aware FedAsync [Xie et al. 2019] and buffered FedBuff
//! [Nguyen et al. 2022].
//!
//! A strategy is stateful *per node* (e.g. each node carries its own
//! server-momentum buffer) — exactly what the serverless design implies.
//!
//! # Example
//!
//! A strategy consumes [`Contribution`]s (one per node, exactly one
//! marked `is_self`) and produces the node's next weights:
//!
//! ```no_run
//! use std::sync::Arc;
//!
//! use fedless::strategy::{Contribution, StrategyKind};
//! use fedless::tensor::FlatParams;
//!
//! let mut strategy = StrategyKind::FedAvg.build();
//! let contribs = vec![
//!     Contribution {
//!         node_id: 0,
//!         n_examples: 300,
//!         is_self: true,
//!         seq: 2,
//!         params: Arc::new(FlatParams(vec![1.0; 4])),
//!     },
//!     Contribution {
//!         node_id: 1,
//!         n_examples: 100,
//!         is_self: false,
//!         seq: 1,
//!         params: Arc::new(FlatParams(vec![5.0; 4])),
//!     },
//! ];
//! // example-weighted: 0.75 * 1.0 + 0.25 * 5.0 = 2.0 per coordinate
//! let next = strategy.aggregate(&contribs).unwrap();
//! assert_eq!(next.0, vec![2.0; 4]);
//! ```

mod fedadam;
mod fedasync;
mod fedavg;
mod fedavgm;
mod fedbuff;

pub use fedadam::FedAdam;
pub use fedasync::FedAsync;
pub use fedavg::FedAvg;
pub use fedavgm::FedAvgM;
pub use fedbuff::FedBuff;

use std::sync::Arc;

use crate::par::ChunkPool;
use crate::tensor::FlatParams;

/// One client's weights entering an aggregation.
#[derive(Clone, Debug)]
pub struct Contribution {
    /// The contributing node.
    pub node_id: usize,
    /// Examples that node trained on (the FedAvg weight numerator n_k).
    pub n_examples: u64,
    /// True for the aggregating node's own current weights (Algorithm 1's
    /// `ω[k] ← w^k`).
    pub is_self: bool,
    /// Store sequence number of the entry (novelty/staleness signal).
    pub seq: u64,
    /// The contributed flat weight vector.
    pub params: Arc<FlatParams>,
}

/// Client-side aggregation strategy.
pub trait Strategy: Send {
    /// Canonical lowercase strategy name (matches [`StrategyKind::name`]).
    fn name(&self) -> &'static str;

    /// Aggregate the contributions into new local weights, running the
    /// data-parallel kernels (the fused weighted average, axpy, lerp) on
    /// `pool`. Returns `None` when the strategy decides not to update
    /// (e.g. FedBuff's buffer has not filled) — the caller then keeps
    /// its current weights. Results are bit-identical for any thread
    /// count (the [`crate::par`] determinism contract).
    ///
    /// `contribs` always contains exactly one `is_self` entry.
    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams>;

    /// Single-threaded [`Strategy::aggregate_pooled`] (bit-identical).
    fn aggregate(&mut self, contribs: &[Contribution]) -> Option<FlatParams> {
        self.aggregate_pooled(contribs, ChunkPool::sequential())
    }

    /// Reset per-node state (between trials).
    fn reset(&mut self) {}
}

/// `n_k / n` weights over borrowed contributions (Eq. 1) — iterator-based
/// so callers holding `&[Contribution]` *or* `&[&Contribution]` (e.g.
/// FedAsync's peer filter) avoid deep-copying contributions just to
/// compute their weights.
pub(crate) fn example_weights<'a, I>(contribs: I) -> Vec<f32>
where
    I: ExactSizeIterator<Item = &'a Contribution> + Clone,
{
    let n = contribs.len();
    let total: u64 = contribs.clone().map(|c| c.n_examples).sum();
    if total == 0 {
        // degenerate: fall back to uniform
        return vec![1.0 / n as f32; n];
    }
    contribs.map(|c| c.n_examples as f32 / total as f32).collect()
}

/// Plain example-weighted average of the contributions, computed with
/// the fused one-pass kernel on `pool`.
pub(crate) fn fedavg_of(contribs: &[Contribution], pool: ChunkPool) -> FlatParams {
    let weights = example_weights(contribs.iter());
    let refs: Vec<&FlatParams> = contribs.iter().map(|c| c.params.as_ref()).collect();
    crate::tensor::flat::weighted_average_pooled(&refs, &weights, pool)
}

/// Strategy selector used in configs / CLI (`--strategy fedavg`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    /// Example-weighted averaging (paper Eq. 1).
    FedAvg,
    /// FedAvg with (client-held) server momentum.
    FedAvgM,
    /// Adam on the aggregation pseudo-gradient.
    FedAdam,
    /// Staleness-aware asynchronous mixing (Xie et al. 2019).
    FedAsync,
    /// Buffered asynchronous aggregation (Nguyen et al. 2022).
    FedBuff,
}

impl StrategyKind {
    /// Parse a config/CLI strategy name.
    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s.to_ascii_lowercase().as_str() {
            "fedavg" => Some(StrategyKind::FedAvg),
            "fedavgm" => Some(StrategyKind::FedAvgM),
            "fedadam" => Some(StrategyKind::FedAdam),
            "fedasync" => Some(StrategyKind::FedAsync),
            "fedbuff" => Some(StrategyKind::FedBuff),
            _ => None,
        }
    }

    /// Canonical lowercase name (inverse of [`StrategyKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::FedAvg => "fedavg",
            StrategyKind::FedAvgM => "fedavgm",
            StrategyKind::FedAdam => "fedadam",
            StrategyKind::FedAsync => "fedasync",
            StrategyKind::FedBuff => "fedbuff",
        }
    }

    /// Instantiate with default hyperparameters (paper-faithful).
    pub fn build(self) -> Box<dyn Strategy> {
        match self {
            StrategyKind::FedAvg => Box::new(FedAvg::new()),
            StrategyKind::FedAvgM => Box::new(FedAvgM::new(0.9, 1.0)),
            StrategyKind::FedAdam => Box::new(FedAdam::new(1e-2, 0.9, 0.999, 1e-3)),
            StrategyKind::FedAsync => Box::new(FedAsync::new(0.6, 0.5)),
            StrategyKind::FedBuff => Box::new(FedBuff::new(2)),
        }
    }
}

impl std::fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
pub(crate) mod strategy_tests {
    use super::*;

    pub fn contrib(node: usize, n: u64, is_self: bool, vals: &[f32]) -> Contribution {
        Contribution {
            node_id: node,
            n_examples: n,
            is_self,
            seq: node as u64 + 1,
            params: Arc::new(FlatParams(vals.to_vec())),
        }
    }

    #[test]
    fn example_weights_normalize() {
        let cs = [contrib(0, 300, true, &[0.0]), contrib(1, 100, false, &[0.0])];
        let w = example_weights(cs.iter());
        assert_eq!(w, vec![0.75, 0.25]);
        // works over borrowed refs too (the FedAsync peer-filter shape)
        let refs: Vec<&Contribution> = cs.iter().collect();
        assert_eq!(example_weights(refs.iter().copied()), vec![0.75, 0.25]);
    }

    #[test]
    fn example_weights_zero_total_uniform() {
        let cs = [contrib(0, 0, true, &[0.0]), contrib(1, 0, false, &[0.0])];
        let w = example_weights(cs.iter());
        assert_eq!(w, vec![0.5, 0.5]);
    }

    #[test]
    fn kind_parse_round_trip() {
        for k in [
            StrategyKind::FedAvg,
            StrategyKind::FedAvgM,
            StrategyKind::FedAdam,
            StrategyKind::FedAsync,
            StrategyKind::FedBuff,
        ] {
            assert_eq!(StrategyKind::parse(k.name()), Some(k));
        }
        assert_eq!(StrategyKind::parse("nope"), None);
    }
}
