//! Batch loading: shuffled, cycling iteration over a node's local shard.
//!
//! A [`BatchLoader`] owns a list of example indices (produced by the
//! [`crate::data::Partitioner`]) plus a data source, and materializes fixed-size
//! batches in the exact layout the AOT train artifact expects
//! (`x: f32[B, ...]` or `i32[B, T+1]`, `y: i32[B]`).

use std::sync::Arc;

use super::synth::{Split, SynthDataset};
use super::text::TextCorpus;
use crate::util::Rng;

/// Batch feature data — images are f32, LM token windows are i32.
#[derive(Clone, Debug, PartialEq)]
pub enum BatchData {
    /// Flattened f32 image features.
    F32(Vec<f32>),
    /// Flattened i32 token windows.
    I32(Vec<i32>),
}

/// One training/eval batch in artifact layout.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Flattened feature data.
    pub x: BatchData,
    /// Class labels (images) or all-zeros dummy (LM — targets come from the
    /// token window itself).
    pub y: Vec<i32>,
    /// Leading x dims including batch, e.g. `[32, 28, 28, 1]` or `[8, 65]`.
    pub x_dims: Vec<i64>,
}

/// Where a loader's examples come from.
#[derive(Clone)]
pub enum DataSource {
    /// A split of a synthetic image dataset.
    Image {
        /// The shared dataset.
        ds: Arc<SynthDataset>,
        /// Which split to read.
        split: Split,
    },
    /// Fixed-stride windows over a synthetic text corpus.
    Text {
        /// The shared corpus.
        corpus: Arc<TextCorpus>,
        /// Window length in tokens (the model sees `seq_len + 1`).
        seq_len: usize,
    },
}

/// Shuffled cycling batch iterator over a shard (list of example indices).
pub struct BatchLoader {
    source: DataSource,
    indices: Vec<usize>,
    batch_size: usize,
    cursor: usize,
    rng: Rng,
    /// Completed passes over the shard.
    pub passes: usize,
}

impl BatchLoader {
    /// Loader over `indices` (this node's shard) of `source`. The shard
    /// must be non-empty; iteration order is deterministic in `seed`.
    pub fn new(source: DataSource, mut indices: Vec<usize>, batch_size: usize, seed: u64) -> Self {
        assert!(!indices.is_empty(), "empty shard");
        assert!(batch_size > 0);
        let mut rng = Rng::new(seed ^ 0x10AD_E7);
        rng.shuffle(&mut indices);
        BatchLoader { source, indices, batch_size, cursor: 0, rng, passes: 0 }
    }

    /// Number of examples in this shard.
    pub fn shard_len(&self) -> usize {
        self.indices.len()
    }

    /// Produce the next batch, reshuffling at each epoch boundary over the
    /// shard (sampling with cycling, like `tf.data.repeat + shuffle`).
    pub fn next_batch(&mut self) -> Batch {
        let idxs: Vec<usize> = (0..self.batch_size)
            .map(|_| {
                if self.cursor >= self.indices.len() {
                    self.cursor = 0;
                    self.passes += 1;
                    self.rng.shuffle(&mut self.indices);
                }
                let i = self.indices[self.cursor];
                self.cursor += 1;
                i
            })
            .collect();
        self.materialize(&idxs)
    }

    /// Materialize a specific set of example indices (used by eval).
    pub fn materialize(&self, idxs: &[usize]) -> Batch {
        match &self.source {
            DataSource::Image { ds, split } => {
                let elen = ds.kind.example_len();
                let (h, w, c) = ds.kind.dims();
                let mut x = vec![0.0f32; idxs.len() * elen];
                let mut y = Vec::with_capacity(idxs.len());
                for (bi, &i) in idxs.iter().enumerate() {
                    let label = ds.example_into(*split, i, &mut x[bi * elen..(bi + 1) * elen]);
                    y.push(label as i32);
                }
                Batch {
                    x: BatchData::F32(x),
                    y,
                    x_dims: vec![idxs.len() as i64, h as i64, w as i64, c as i64],
                }
            }
            DataSource::Text { corpus, seq_len } => {
                let mut x = Vec::with_capacity(idxs.len() * (seq_len + 1));
                for &i in idxs {
                    x.extend_from_slice(&corpus.window(i, *seq_len));
                }
                Batch {
                    x: BatchData::I32(x),
                    y: vec![0; idxs.len()],
                    x_dims: vec![idxs.len() as i64, (*seq_len + 1) as i64],
                }
            }
        }
    }

    /// Iterate the shard once in fixed order as full batches (dropping the
    /// ragged tail) — used for evaluation.
    pub fn full_batches(&self) -> Vec<Batch> {
        self.indices
            .chunks_exact(self.batch_size)
            .map(|c| self.materialize(c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::DatasetKind;

    fn image_loader(n: usize, b: usize) -> BatchLoader {
        let ds = Arc::new(SynthDataset::new(DatasetKind::Mnist, 1, n, 10));
        BatchLoader::new(
            DataSource::Image { ds, split: Split::Train },
            (0..n).collect(),
            b,
            9,
        )
    }

    #[test]
    fn batch_shapes() {
        let mut l = image_loader(100, 32);
        let b = l.next_batch();
        assert_eq!(b.x_dims, vec![32, 28, 28, 1]);
        assert_eq!(b.y.len(), 32);
        match &b.x {
            BatchData::F32(v) => assert_eq!(v.len(), 32 * 28 * 28),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn cycles_and_counts_passes() {
        let mut l = image_loader(50, 32);
        assert_eq!(l.passes, 0);
        let _ = l.next_batch();
        let _ = l.next_batch(); // 64 > 50 -> must have wrapped
        assert_eq!(l.passes, 1);
    }

    #[test]
    fn text_batches() {
        let corpus = Arc::new(TextCorpus::generate(3, 10_000));
        let n = corpus.num_windows(64);
        let mut l = BatchLoader::new(
            DataSource::Text { corpus, seq_len: 64 },
            (0..n).collect(),
            8,
            4,
        );
        let b = l.next_batch();
        assert_eq!(b.x_dims, vec![8, 65]);
        assert_eq!(b.y, vec![0; 8]);
        match &b.x {
            BatchData::I32(v) => assert_eq!(v.len(), 8 * 65),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn full_batches_cover_shard_once() {
        let l = image_loader(100, 32);
        let bs = l.full_batches();
        assert_eq!(bs.len(), 3); // 96 of 100 examples, tail dropped
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = image_loader(100, 16);
        let mut b = image_loader(100, 16);
        let ba = a.next_batch();
        let bb = b.next_batch();
        assert_eq!(ba.y, bb.y);
        assert_eq!(ba.x, bb.x);
    }
}
