"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes and asserts
allclose between each kernel and its oracle here. The rust side additionally
parity-tests its native aggregation against the lowered kernel artifact.
"""

import jax
import jax.numpy as jnp


def fedavg_aggregate_ref(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """out[c] = sum_k weights[k] * stack[k, c] (Eq. 1, client-side)."""
    return jnp.einsum(
        "k,kc->c", weights.astype(jnp.float32), stack.astype(jnp.float32)
    )


def adam_step_ref(params, m, v, grads, step, *, lr=1e-3, b1=0.9, b2=0.999,
                  eps=1e-8, weight_decay=0.0):
    """Adam(W), "efficient version" of Kingma & Ba §2: bias correction is
    folded into the step size ``lr_t = lr * sqrt(1-b2^t) / (1-b1^t)`` so the
    update is ``lr_t * m' / (sqrt(v') + eps)``. This is the exact math the
    fused kernel implements (eps sits next to the *uncorrected* sqrt(v'))."""
    t = step.astype(jnp.float32)
    m_new = b1 * m + (1.0 - b1) * grads
    v_new = b2 * v + (1.0 - b2) * grads * grads
    lr_t = lr * jnp.sqrt(1.0 - b2**t) / (1.0 - b1**t)
    upd = lr_t * m_new / (jnp.sqrt(v_new) + eps)
    if weight_decay != 0.0:
        upd = upd + lr * weight_decay * params
    return params - upd, m_new, v_new


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.matmul(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
