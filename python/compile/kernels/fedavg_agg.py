"""Pallas kernel: weighted federated aggregation (the FedAvg hot path).

Computes ``out[c] = sum_k w[k] * stack[k, c]`` — Eq. (1) of the paper applied
client-side, where ``stack`` holds K flattened client parameter vectors and
``w`` the normalized example counts ``n_k / n``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the C axis is tiled into
VMEM-resident blocks via BlockSpec; the K reduction happens on the VPU inside
a single block so each parameter chunk makes exactly one HBM->VMEM round
trip. K is small (paper: 2..5), so (K, BLOCK_C) fp32 fits VMEM comfortably
(K=5, BLOCK_C=65536 -> 1.25 MiB in + 0.25 MiB out).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One VMEM tile of the flattened parameter axis. Multiple of 128 lanes.
BLOCK_C = 65536


def _agg_kernel(stack_ref, w_ref, o_ref):
    # stack_ref: (K, BLOCK_C) VMEM tile; w_ref: (K, 1); o_ref: (BLOCK_C,)
    stack = stack_ref[...]  # (K, BLOCK_C)
    w = w_ref[...]  # (K, 1)
    o_ref[...] = jnp.sum(stack * w, axis=0)


@functools.partial(jax.jit, static_argnames=("block_c",))
def fedavg_aggregate(stack: jax.Array, weights: jax.Array, block_c: int = BLOCK_C):
    """Weighted sum over the leading axis of ``stack``.

    Args:
      stack:   f32[K, C] — K client parameter vectors (C may be un-padded).
      weights: f32[K]    — aggregation weights (typically n_k / n).
      block_c: VMEM tile width along C.

    Returns:
      f32[C] — the aggregated parameter vector.
    """
    k, c = stack.shape
    pad = (-c) % block_c
    if pad:
        stack = jnp.pad(stack, ((0, 0), (0, pad)))
    cp = c + pad
    w2 = weights.reshape(k, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _agg_kernel,
        grid=(cp // block_c,),
        in_specs=[
            pl.BlockSpec((k, block_c), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp,), jnp.float32),
        interpret=True,
    )(stack.astype(jnp.float32), w2)
    return out[:c]
