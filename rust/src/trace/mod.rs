//! Structured tracing + round-history analytics — the observability
//! layer that turns every run into a queryable, exportable,
//! bit-reproducible record.
//!
//! Three pieces:
//!
//! * **[`Tracer`]** — per-node (mutex-per-node) event buffers recording
//!   typed [`TraceEvent`]s (train spans, push/pull/aggregate instants
//!   with wire bytes and weight digests). Every timestamp comes from the
//!   active [`crate::time::Clock`], so under a
//!   [`crate::time::VirtualClock`] the whole trace is *simulated* time
//!   and replays bit-identically across schedulers (`threads` vs
//!   `events`) and kernel thread counts. Events are emitted from the
//!   protocol layer's [`crate::protocol::EpochCtx`] helpers and the node
//!   drivers, so all four protocols are traced uniformly with no
//!   per-protocol code.
//! * **Round-history analytics** ([`analyze`]) — the store-side
//!   `EntryLog` retains every deposited entry, and
//!   [`crate::store::WeightStore::entries_for_round`] exposes it as a
//!   round archive; [`compute_divergence`] replays that archive into
//!   per-round model divergence (L2 / cosine of each client update vs.
//!   the round aggregate), client-drift trajectories, and a pairwise
//!   cosine matrix with greedy threshold clustering — all on the
//!   deterministic chunked kernels of [`crate::tensor::flat`], so the
//!   numbers are bit-identical for any thread count.
//! * **Exporters** ([`export`]) — `trace.jsonl` (one JSON object per
//!   event), `trace_chrome.json` (Chrome trace-event format,
//!   Perfetto-loadable), and `analysis.json` (the figure-ready
//!   [`RunSummary`]) written under the run directory. `fedbench inspect
//!   <run-dir>` parses `analysis.json` back and renders it through the
//!   *same* [`RunSummary::render`] path `fedbench run` prints, so the
//!   two can never disagree.
//!
//! [`synthetic`] drives an artifact-free 4-node federation (threaded or
//! event-scheduled) with tracing on — the backbone of the trace
//! determinism tests and of CI's sample Perfetto artifact.

pub mod analyze;
pub mod export;
pub mod synthetic;

pub use analyze::{
    compute_divergence, ClientDivergence, DivergenceReport, RoundDivergence,
    DEFAULT_CLUSTER_THRESHOLD, PAIRWISE_MAX_NODES,
};
pub use export::{chrome_trace_json, export_run, load_summary};
pub use synthetic::{run_synthetic, SyntheticRun, SyntheticSpec};

use std::sync::Mutex;
use std::time::Duration;

use crate::metrics::timeline::{SpanKind, Timeline};

/// What a [`TraceEvent`] records. Spans carry a start *and* end instant;
/// instants have `start == end`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceEventKind {
    /// One local training epoch (a span).
    Train,
    /// A weight deposit into the store (an instant).
    Push {
        /// Encoded wire size of the deposited blob, header included.
        wire_bytes: u64,
        /// Content digest of what landed in the store (the codec's
        /// decoded reconstruction — bit-exact under `compress = none`).
        digest: u64,
    },
    /// A pull of peer entries from the store (an instant).
    Pull {
        /// Entries downloaded in this pull.
        entries: u64,
        /// Summed encoded wire size of the pulled entries.
        wire_bytes: u64,
    },
    /// A client-side aggregation adoption (an instant).
    Aggregate {
        /// Content digest of the adopted aggregate.
        digest: u64,
    },
    /// The node died on an unrecoverable runtime error (an instant) —
    /// e.g. a store operation whose retries were exhausted. Emitted by
    /// [`crate::node::NodeRunner`]'s failure path so a failed node
    /// leaves a typed mark in the exports instead of silently
    /// truncating its event stream.
    NodeFailed,
    /// A crash–restart recovery (a span): from the crash instant to the
    /// moment the node came back and restored its checkpoint.
    Restart,
}

impl TraceEventKind {
    /// Canonical lowercase event name (the `kind` field in exports).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEventKind::Train => "train",
            TraceEventKind::Push { .. } => "push",
            TraceEventKind::Pull { .. } => "pull",
            TraceEventKind::Aggregate { .. } => "aggregate",
            TraceEventKind::NodeFailed => "node_failed",
            TraceEventKind::Restart => "restart",
        }
    }
}

/// One typed, clock-stamped observation of a node's federation life.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// The observed node.
    pub node_id: usize,
    /// Federation round (sync) / the node's local epoch count (async).
    pub round: u64,
    /// Event start on the experiment clock (simulated under a virtual
    /// clock; equal to [`TraceEvent::end`] for instants).
    pub start: Duration,
    /// Event end on the experiment clock.
    pub end: Duration,
    /// What happened.
    pub kind: TraceEventKind,
}

/// Per-node trace event buffers. One mutex per node, so concurrently
/// federating node threads never contend with each other; within a
/// node's buffer, events sit in program order (deterministic under the
/// virtual clock), and [`Tracer::events`] merges buffers in node order —
/// a total order that is a pure function of the run.
pub struct Tracer {
    buffers: Vec<Mutex<Vec<TraceEvent>>>,
}

impl Tracer {
    /// A tracer with one event buffer per node.
    pub fn new(n_nodes: usize) -> Tracer {
        Tracer { buffers: (0..n_nodes).map(|_| Mutex::new(Vec::new())).collect() }
    }

    /// Number of node buffers.
    pub fn n_nodes(&self) -> usize {
        self.buffers.len()
    }

    /// Append `ev` to its node's buffer. Events for node ids beyond the
    /// buffer count are dropped (never panics inside a node thread).
    pub fn record(&self, ev: TraceEvent) {
        if let Some(buf) = self.buffers.get(ev.node_id) {
            buf.lock().unwrap().push(ev);
        }
    }

    /// Record an instantaneous event at clock instant `at`.
    pub fn instant(&self, node_id: usize, round: u64, at: Duration, kind: TraceEventKind) {
        self.record(TraceEvent { node_id, round, start: at, end: at, kind });
    }

    /// Record a spanning event from `start` to `end`.
    pub fn span(
        &self,
        node_id: usize,
        round: u64,
        start: Duration,
        end: Duration,
        kind: TraceEventKind,
    ) {
        self.record(TraceEvent { node_id, round, start, end, kind });
    }

    /// All events, merged in (node id, program order) — the canonical
    /// deterministic export order.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for buf in &self.buffers {
            out.extend(buf.lock().unwrap().iter().copied());
        }
        out
    }
}

/// One node's share-of-time accounting, distilled from its
/// [`Timeline`] and traffic meter — the per-node row of a
/// [`RunSummary`].
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSpanSummary {
    /// The node.
    pub node_id: usize,
    /// Simulated seconds spent training.
    pub train_s: f64,
    /// Simulated seconds parked on store waits.
    pub wait_s: f64,
    /// Simulated seconds aggregating.
    pub aggregate_s: f64,
    /// The node's finish instant (max span end), simulated seconds.
    pub total_s: f64,
    /// Rounds this node actually trained (its Train span count) — the
    /// cohort-participation accounting under partial participation.
    pub rounds_trained: u64,
    /// Wire bytes this node uploaded.
    pub bytes_pushed: u64,
    /// Wire bytes this node downloaded.
    pub bytes_pulled: u64,
    /// Push count.
    pub pushes: u64,
    /// Entries pulled.
    pub entries_pulled: u64,
    /// False when the node crashed or stalled before its last epoch.
    pub completed: bool,
}

impl NodeSpanSummary {
    /// Distill a node's timeline (+ completion flag) into its summary
    /// row.
    pub fn from_timeline(timeline: &Timeline, completed: bool) -> NodeSpanSummary {
        NodeSpanSummary {
            node_id: timeline.node_id,
            train_s: timeline.total(SpanKind::Train).as_secs_f64(),
            wait_s: timeline.total(SpanKind::Wait).as_secs_f64(),
            aggregate_s: timeline.total(SpanKind::Aggregate).as_secs_f64(),
            total_s: timeline
                .spans
                .iter()
                .map(|s| s.end)
                .max()
                .unwrap_or(Duration::ZERO)
                .as_secs_f64(),
            rounds_trained: timeline
                .spans
                .iter()
                .filter(|s| s.kind == SpanKind::Train)
                .count() as u64,
            bytes_pushed: timeline.traffic.bytes_pushed,
            bytes_pulled: timeline.traffic.bytes_pulled,
            pushes: timeline.traffic.pushes,
            entries_pulled: timeline.traffic.entries_pulled,
            completed,
        }
    }

    /// This node's share of `kind`-time in its own busy+idle total;
    /// 0.0 for an empty timeline (never NaN).
    fn share(&self, part_s: f64) -> f64 {
        if self.total_s == 0.0 {
            0.0
        } else {
            part_s / self.total_s
        }
    }
}

/// Fleet-wide totals from the fault-tolerance layer: injected store
/// failures, retry-client activity, quorum-degraded sync rounds, and
/// crash–restart recoveries. All five are zero on a clean run, in
/// which case [`RunSummary::render`] omits the chaos block entirely
/// (clean-run output stays byte-identical to the pre-fault-layer
/// format).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultTotals {
    /// Transient failures injected by per-node
    /// [`crate::store::FaultStore`] instances (`fault` / `outage`).
    pub injected_faults: u64,
    /// Store operations retried by the nodes'
    /// [`crate::store::RetryStore`] clients.
    pub store_retries: u64,
    /// Store operations the retry clients gave up on.
    pub store_give_ups: u64,
    /// Sync rounds closed degraded (quorum reached, full cohort not).
    pub degraded_rounds: u64,
    /// Crash–restart recoveries performed across the fleet.
    pub restarts: u64,
}

impl FaultTotals {
    /// True when any counter is nonzero — gates the render block.
    pub fn any(&self) -> bool {
        self.injected_faults != 0
            || self.store_retries != 0
            || self.store_give_ups != 0
            || self.degraded_rounds != 0
            || self.restarts != 0
    }
}

/// The analytics record of one run — everything `fedbench run` prints
/// about wire traffic, idle shares, digests, and divergence, and
/// everything `fedbench inspect` re-renders from `analysis.json`.
/// Both commands go through [`RunSummary::render`], so they can never
/// disagree.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSummary {
    /// The run's directory-name label.
    pub run_name: String,
    /// Fleet size.
    pub n_nodes: usize,
    /// Run wall-clock in seconds (simulated under `clock = virtual`).
    pub wall_clock_s: f64,
    /// Content digest of the final weighted-average global model.
    pub global_digest: u64,
    /// Total entries deposited in the store.
    pub store_pushes: u64,
    /// Mean of the nodes' idle (wait) fractions; 0.0 for an empty fleet.
    pub mean_idle_fraction: f64,
    /// True when no node crashed or stalled.
    pub all_completed: bool,
    /// Fault-tolerance-layer totals (all zero on a clean run).
    pub faults: FaultTotals,
    /// Per-node span/traffic rows, in node order.
    pub nodes: Vec<NodeSpanSummary>,
    /// Round-history divergence analytics, when the round archive was
    /// analyzed.
    pub divergence: Option<DivergenceReport>,
}

impl RunSummary {
    /// Summed traffic across all node rows.
    pub fn total_traffic(&self) -> crate::metrics::TrafficMeter {
        let mut t = crate::metrics::TrafficMeter::default();
        for n in &self.nodes {
            t.bytes_pushed += n.bytes_pushed;
            t.bytes_pulled += n.bytes_pulled;
            t.pushes += n.pushes;
            t.entries_pulled += n.entries_pulled;
        }
        t
    }

    /// Render the human-facing analytics block: run totals, the
    /// per-node span-share table, straggler accounting, and (when
    /// present) the per-round divergence tables. Deterministic: the
    /// output is a pure function of the summary's numbers.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let t = self.total_traffic();
        out.push_str(&format!(
            "model digest : {:016x}\nstore pushes : {}\nwire pushed  : {:.2} MB in {} pushes\nwire pulled  : {:.2} MB in {} entries\nmean idle    : {:.1}%\nall completed: {}\n",
            self.global_digest,
            self.store_pushes,
            t.mb_pushed(),
            t.pushes,
            t.mb_pulled(),
            t.entries_pulled,
            100.0 * self.mean_idle_fraction,
            self.all_completed,
        ));
        if self.faults.any() {
            let f = &self.faults;
            out.push_str(&format!(
                "fault layer  : {} injected, {} retried, {} gave up\nrecovery     : {} restarts, {} degraded rounds\n",
                f.injected_faults, f.store_retries, f.store_give_ups, f.restarts, f.degraded_rounds,
            ));
        }
        if !self.nodes.is_empty() {
            out.push_str(
                "\nnode | train s | wait s | agg s | train% | wait% | agg% | rounds | MB push | MB pull | done\n",
            );
            for n in &self.nodes {
                out.push_str(&format!(
                    "{:>4} | {:>7.3} | {:>6.3} | {:>5.3} | {:>5.1}% | {:>4.1}% | {:>3.1}% | {:>6} | {:>7.3} | {:>7.3} | {}\n",
                    n.node_id,
                    n.train_s,
                    n.wait_s,
                    n.aggregate_s,
                    100.0 * n.share(n.train_s),
                    100.0 * n.share(n.wait_s),
                    100.0 * n.share(n.aggregate_s),
                    n.rounds_trained,
                    n.bytes_pushed as f64 / 1e6,
                    n.bytes_pulled as f64 / 1e6,
                    if n.completed { "yes" } else { "NO" },
                ));
            }
            if let Some(slow) = self
                .nodes
                .iter()
                .max_by(|a, b| a.total_s.partial_cmp(&b.total_s).unwrap_or(std::cmp::Ordering::Equal))
            {
                let trained = self.nodes.iter().filter(|n| n.rounds_trained > 0).count();
                out.push_str(&format!(
                    "straggler    : node {} finished last at {:.3} s; {} of {} nodes trained ≥1 round\n",
                    slow.node_id, slow.total_s, trained, self.nodes.len(),
                ));
            }
        }
        if let Some(div) = &self.divergence {
            out.push('\n');
            out.push_str(&div.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracer_buffers_merge_in_node_order() {
        let tracer = Tracer::new(2);
        tracer.instant(1, 0, Duration::from_millis(5), TraceEventKind::Train);
        tracer.instant(0, 0, Duration::from_millis(9), TraceEventKind::Train);
        tracer.instant(
            0,
            1,
            Duration::from_millis(10),
            TraceEventKind::Push { wire_bytes: 4, digest: 7 },
        );
        let evs = tracer.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].node_id, 0);
        assert_eq!(evs[1].node_id, 0);
        assert_eq!(evs[2].node_id, 1);
        assert_eq!(evs[1].kind.name(), "push");
        // out-of-range node ids are dropped, not panicked on
        tracer.instant(9, 0, Duration::ZERO, TraceEventKind::Train);
        assert_eq!(tracer.events().len(), 3);
    }

    #[test]
    fn node_summary_shares_never_nan() {
        let t = Timeline::new(3);
        let s = NodeSpanSummary::from_timeline(&t, true);
        assert_eq!(s.total_s, 0.0);
        assert_eq!(s.share(s.train_s), 0.0);
        let summary = RunSummary {
            run_name: "r".into(),
            n_nodes: 1,
            wall_clock_s: 0.0,
            global_digest: 0,
            store_pushes: 0,
            mean_idle_fraction: 0.0,
            all_completed: true,
            faults: FaultTotals::default(),
            nodes: vec![s],
            divergence: None,
        };
        assert!(!summary.render().contains("NaN"));
        // a clean run must not even mention the fault layer
        assert!(!summary.render().contains("fault layer"));
        let mut chaotic = summary.clone();
        chaotic.faults.store_retries = 3;
        chaotic.faults.restarts = 1;
        let rendered = chaotic.render();
        assert!(rendered.contains("fault layer  : 0 injected, 3 retried, 0 gave up"));
        assert!(rendered.contains("recovery     : 1 restarts, 0 degraded rounds"));
    }
}
