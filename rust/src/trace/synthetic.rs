//! Artifact-free traced federation runs — no datasets, no PJRT runtime.
//!
//! [`run_synthetic`] drives a real protocol + store + virtual-clock
//! federation with synthetic weights and tracing on, under either node
//! scheduler: `threads` runs one OS thread per node (the
//! `rust/tests/timing.rs` harness shape, plus participation and
//! tracing), `events` delegates to the discrete-event executor harness
//! ([`crate::sched::run_events_trial_captured`]) with the same
//! participation plan, initial weights, and tracer wiring. Both paths
//! produce bit-identical traces, timelines, weights, and divergence
//! analytics — the claim `rust/tests/trace.rs` pins.
//!
//! This is also what `fedbench run --synthetic` executes, so CI can
//! produce a real Perfetto-loadable trace artifact without model
//! artifacts.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::compress::{CodecKind, CodecState};
use crate::config::{ExperimentConfig, FederationMode, SchedulerKind};
use crate::metrics::timeline::{SpanKind, Timeline};
use crate::par::ChunkPool;
use crate::protocol::{EpochCtx, FederationProtocol, ProtocolKind};
use crate::sched::{
    run_events_trial_captured, AvailabilitySpec, ParticipationPlan, TrialSpec,
};
use crate::store::{MemoryStore, WeightStore};
use crate::strategy::StrategyKind;
use crate::tensor::flat::weighted_average_pooled;
use crate::tensor::FlatParams;
use crate::time::{Clock, ParticipantGuard, VirtualClock};
use crate::trace::{
    compute_divergence, NodeSpanSummary, RunSummary, TraceEventKind, Tracer,
};

/// Parameter-vector width of the synthetic model (a few codec chunks'
/// worth — big enough that compression and divergence are non-trivial,
/// small enough that a traced run is instant).
pub const SYNTH_DIM: usize = 1024;

/// Distinct, training-like initial weights per node (a `fn` pointer so
/// the event harness's [`TrialSpec::init`] can carry it).
fn synth_init(node_id: usize) -> FlatParams {
    FlatParams(
        (0..SYNTH_DIM)
            .map(|i| ((i as f32) * 0.0137 + node_id as f32 * 0.11).sin() * 0.8)
            .collect(),
    )
}

/// One synthetic traced trial.
pub struct SyntheticSpec {
    /// Federation mode.
    pub mode: FederationMode,
    /// Per-node per-epoch training delay; its length is the fleet size.
    pub delays: Vec<Duration>,
    /// Epochs per node.
    pub epochs: usize,
    /// Node scheduler to drive the trial with.
    pub scheduler: SchedulerKind,
    /// Kernel pool width (bit-identical results for any value).
    pub threads: usize,
    /// Wire codec for pushes.
    pub compress: CodecKind,
    /// Per-round cohort fraction in `(0, 1]`.
    pub participation: f64,
    /// Trial seed (cohorts, gossip schedules).
    pub seed: u64,
    /// Sync-barrier stall timeout.
    pub sync_timeout: Duration,
}

impl SyntheticSpec {
    /// A 4-node default: distinct per-node delays (so no two events
    /// share a simulated instant), full participation, no compression.
    pub fn new(mode: FederationMode, n_nodes: usize, epochs: usize) -> SyntheticSpec {
        SyntheticSpec {
            mode,
            delays: (0..n_nodes)
                .map(|i| Duration::from_millis(40 + 9 * i as u64))
                .collect(),
            epochs,
            scheduler: SchedulerKind::Threads,
            threads: 1,
            compress: CodecKind::None,
            participation: 1.0,
            seed: ExperimentConfig::default().seed,
            sync_timeout: Duration::from_secs(3600),
        }
    }

    /// Derive the spec from an experiment config (the `fedbench run
    /// --synthetic` path): mode, fleet size, epochs, scheduler, threads,
    /// codec, participation, and seed carry over; `node_delays_ms` is
    /// honored when set.
    pub fn from_config(cfg: &ExperimentConfig) -> SyntheticSpec {
        let mut spec = SyntheticSpec::new(cfg.mode, cfg.n_nodes, cfg.epochs);
        if !cfg.node_delays_ms.is_empty() {
            spec.delays = (0..cfg.n_nodes)
                .map(|i| {
                    Duration::from_secs_f64(
                        cfg.node_delays_ms[i % cfg.node_delays_ms.len()] / 1000.0,
                    )
                })
                .collect();
        }
        spec.scheduler = cfg.scheduler;
        spec.threads = cfg.threads;
        spec.compress = cfg.compress;
        spec.participation = cfg.participation;
        spec.seed = cfg.seed;
        spec.sync_timeout = cfg.sync_timeout;
        spec
    }
}

/// Everything a synthetic traced trial observed.
pub struct SyntheticRun {
    /// The trial's tracer (all typed events).
    pub tracer: Arc<Tracer>,
    /// Per-node timelines (spans + traffic), in node order.
    pub timelines: Vec<Timeline>,
    /// Per-node finish instants.
    pub finishes: Vec<Duration>,
    /// Per-node stall flags.
    pub stalled: Vec<bool>,
    /// Per-node final weights.
    pub params: Vec<FlatParams>,
    /// The trial's store (round archive included).
    pub store: Arc<dyn WeightStore>,
}

impl SyntheticRun {
    /// Distill the run into a [`RunSummary`] (divergence analytics
    /// included), computing everything on `pool`'s deterministic
    /// kernels.
    pub fn summary(&self, run_name: &str, epochs: u64, pool: ChunkPool) -> Result<RunSummary> {
        let refs: Vec<&FlatParams> = self.params.iter().collect();
        let w = vec![1.0 / refs.len() as f32; refs.len()];
        let global = weighted_average_pooled(&refs, &w, pool);
        let nodes: Vec<NodeSpanSummary> = self
            .timelines
            .iter()
            .zip(&self.stalled)
            .map(|(t, stalled)| NodeSpanSummary::from_timeline(t, !stalled))
            .collect();
        let n = self.timelines.len();
        let mean_idle_fraction = if n == 0 {
            0.0
        } else {
            self.timelines.iter().map(|t| t.idle_fraction()).sum::<f64>() / n as f64
        };
        Ok(RunSummary {
            run_name: run_name.to_string(),
            n_nodes: n,
            wall_clock_s: self
                .finishes
                .iter()
                .max()
                .copied()
                .unwrap_or(Duration::ZERO)
                .as_secs_f64(),
            global_digest: global.content_hash_pooled(pool),
            store_pushes: nodes.iter().map(|s| s.pushes).sum(),
            mean_idle_fraction,
            all_completed: !self.stalled.iter().any(|s| *s),
            // the synthetic harness carries no fault layer
            faults: crate::trace::FaultTotals::default(),
            nodes,
            divergence: compute_divergence(self.store.as_ref(), epochs, pool)?,
        })
    }
}

/// Run one synthetic traced trial under `spec.scheduler`.
pub fn run_synthetic(spec: &SyntheticSpec) -> Result<SyntheticRun> {
    match spec.scheduler {
        SchedulerKind::Threads => run_threads(spec),
        SchedulerKind::Events => run_events(spec),
    }
}

fn run_events(spec: &SyntheticSpec) -> Result<SyntheticRun> {
    let tracer = Arc::new(Tracer::new(spec.delays.len()));
    let mut trial = TrialSpec::new(spec.mode, spec.delays.clone(), spec.epochs);
    trial.sync_timeout = spec.sync_timeout;
    trial.participation = spec.participation;
    trial.seed = spec.seed;
    trial.compress = spec.compress;
    trial.threads = spec.threads;
    trial.init = synth_init;
    trial.tracer = Some(Arc::clone(&tracer));
    let (nodes, store) = run_events_trial_captured(&trial)?;
    let mut timelines = Vec::new();
    let mut finishes = Vec::new();
    let mut stalled = Vec::new();
    let mut params = Vec::new();
    for node in nodes {
        let mut t = Timeline::new(node.node_id);
        t.spans = node.spans;
        t.traffic = node.traffic;
        timelines.push(t);
        finishes.push(node.finish);
        stalled.push(node.stalled);
        params.push(node.params);
    }
    Ok(SyntheticRun { tracer, timelines, finishes, stalled, params, store })
}

fn run_threads(spec: &SyntheticSpec) -> Result<SyntheticRun> {
    let n = spec.delays.len();
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let cfg = ExperimentConfig {
        mode: spec.mode,
        n_nodes: n,
        epochs: spec.epochs,
        sync_timeout: spec.sync_timeout,
        seed: spec.seed,
        compress: spec.compress,
        threads: spec.threads,
        participation: spec.participation,
        ..Default::default()
    };
    let store: Arc<dyn WeightStore> =
        Arc::new(MemoryStore::with_clock(Arc::clone(&clock)));
    let plan = Arc::new(ParticipationPlan::new(
        spec.participation,
        AvailabilitySpec::None,
        spec.seed,
        n,
    ));
    let tracer = Arc::new(Tracer::new(n));
    // Register every node before any thread runs, so the clock never
    // advances while some nodes are still spawning.
    for _ in 0..n {
        clock.enter();
    }
    let start = Arc::new(std::sync::Barrier::new(n));
    struct NodeOut {
        timeline: Timeline,
        finish: Duration,
        stalled: bool,
        params: FlatParams,
    }
    let outs: Vec<NodeOut> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|node_id| {
                let clock = Arc::clone(&clock);
                let store = Arc::clone(&store);
                let plan = Arc::clone(&plan);
                let tracer = Arc::clone(&tracer);
                let cfg = cfg.clone();
                let start = Arc::clone(&start);
                let delay = spec.delays[node_id];
                scope.spawn(move || -> Result<NodeOut> {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    let mut protocol = ProtocolKind::from(cfg.mode).build(node_id, &cfg);
                    let mut strategy = StrategyKind::FedAvg.build();
                    let mut codec = CodecState::new(cfg.compress);
                    let mut timeline = Timeline::new(node_id);
                    let mut params = synth_init(node_id);
                    let mut stalled = false;
                    start.wait();
                    for epoch in 0..cfg.epochs {
                        if !plan.participates(node_id, epoch) {
                            continue; // off-cohort: zero simulated time
                        }
                        let t = clock.now();
                        clock.sleep(delay.mul_f64(plan.delay_multiplier(node_id)));
                        timeline.record(SpanKind::Train, t, clock.now());
                        tracer.span(
                            node_id,
                            epoch as u64,
                            t,
                            clock.now(),
                            TraceEventKind::Train,
                        );
                        let mut ctx = EpochCtx {
                            node_id,
                            n_nodes: n,
                            round_k: plan.round_k(epoch),
                            epoch,
                            n_examples: 100,
                            store: store.as_ref(),
                            strategy: strategy.as_mut(),
                            timeline: &mut timeline,
                            sync_timeout: cfg.sync_timeout,
                            clock: clock.as_ref(),
                            codec: &mut codec,
                            pool: ChunkPool::from_config(cfg.threads),
                            tracer: Some(tracer.as_ref()),
                        };
                        let out = protocol.after_epoch(&mut ctx, &mut params)?;
                        if out.stalled_at.is_some() {
                            stalled = true;
                            break;
                        }
                    }
                    Ok(NodeOut { timeline, finish: clock.now(), stalled, params })
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("synthetic node thread panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let mut timelines = Vec::new();
    let mut finishes = Vec::new();
    let mut stalled = Vec::new();
    let mut params = Vec::new();
    for out in outs {
        timelines.push(out.timeline);
        finishes.push(out.finish);
        stalled.push(out.stalled);
        params.push(out.params);
    }
    Ok(SyntheticRun { tracer, timelines, finishes, stalled, params, store })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The two schedulers observe the same synthetic federation: same
    /// trace events, spans, finishes, weights, and the same divergence
    /// analytics — rendered bytes included.
    #[test]
    fn schedulers_agree_on_the_traced_run() {
        for mode in [FederationMode::Sync, FederationMode::Async] {
            let mut spec = SyntheticSpec::new(mode, 3, 3);
            let threaded = run_synthetic(&spec).unwrap();
            spec.scheduler = SchedulerKind::Events;
            let events = run_synthetic(&spec).unwrap();
            assert_eq!(threaded.tracer.events(), events.tracer.events(), "{mode:?}");
            assert_eq!(threaded.finishes, events.finishes, "{mode:?}");
            for (a, b) in threaded.timelines.iter().zip(&events.timelines) {
                assert_eq!(a.spans, b.spans, "{mode:?} node {}", a.node_id);
                assert_eq!(a.traffic, b.traffic, "{mode:?} node {}", a.node_id);
            }
            for (a, b) in threaded.params.iter().zip(&events.params) {
                assert_eq!(a.0, b.0, "{mode:?}");
            }
            let sa = threaded.summary("t", 3, ChunkPool::sequential()).unwrap();
            let sb = events.summary("t", 3, ChunkPool::sequential()).unwrap();
            assert_eq!(sa, sb, "{mode:?}");
            assert_eq!(sa.render(), sb.render(), "{mode:?}");
        }
    }
}
