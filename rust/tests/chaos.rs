//! Chaos suite — the fault-tolerance layer end to end, at protocol
//! level (no artifacts, no PJRT), in simulated time.
//!
//! What lives here:
//!
//! * the **acceptance scenario**: a 4-node async virtual-clock run with
//!   Bernoulli store faults *and* a scheduled outage window completes
//!   with zero failed nodes under the retry client, bit-identically
//!   across replays and across kernel-pool widths;
//! * **crash–restart recovery**: a crashed node re-enters after its
//!   downtime and demonstrably resumes from its own last *pushed*
//!   checkpoint (digest-checked against the store entry), not from its
//!   in-memory weights;
//! * **quorum degradation**: a sync round with a dead peer closes
//!   degraded at `ceil(quorum·k)` members after the soft deadline
//!   instead of stalling;
//! * **scheduler conformance**: fault outcomes — retries, restarts,
//!   degraded rounds, final weights, every timeline span — agree
//!   bit-for-bit between the thread-per-node harness and the event
//!   executor.
//!
//! CI runs this file under the same hard real-time budget as
//! `rust/tests/timing.rs`: every second of backoff, downtime, and
//! barrier wait below is simulated, so a regression into real sleeping
//! times the job out.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fedless::compress::CodecState;
use fedless::config::{ExperimentConfig, FederationMode};
use fedless::metrics::timeline::{Span, SpanKind, Timeline};
use fedless::protocol::ProtocolKind;
use fedless::sched::{run_events_trial, run_events_trial_captured, SimNodeResult, TrialSpec};
use fedless::store::{
    FaultModel, FaultStore, MemoryStore, OutageWindow, RetryPolicy, RetryStore, WeightStore,
};
use fedless::strategy::StrategyKind;
use fedless::tensor::FlatParams;
use fedless::time::{Clock, ParticipantGuard, VirtualClock};
use fedless::util::hash::chunked_hash_f32s;

fn ms(n: u64) -> Duration {
    Duration::from_millis(n)
}

fn digest(params: &[f32]) -> u64 {
    chunked_hash_f32s(params)
}

// ---------------------------------------------------------------------------
// a chaos-capable thread-per-node harness (the fault twin of
// `rust/tests/timing.rs::run_sim`, against which the event executor's
// harness is checked below)

/// One chaos trial, runnable on either scheduler.
#[derive(Clone)]
struct ChaosSpec {
    mode: FederationMode,
    delays: Vec<Duration>,
    epochs: usize,
    sync_timeout: Duration,
    crash: Option<(usize, usize)>,
    crash_restart: Option<Duration>,
    fault: FaultModel,
    sync_quorum: f64,
    seed: u64,
}

impl ChaosSpec {
    fn new(mode: FederationMode, delays: Vec<Duration>, epochs: usize) -> ChaosSpec {
        ChaosSpec {
            mode,
            delays,
            epochs,
            sync_timeout: Duration::from_secs(3600),
            crash: None,
            crash_restart: None,
            fault: FaultModel::default(),
            sync_quorum: 1.0,
            seed: ExperimentConfig::default().seed,
        }
    }

    fn to_trial(&self) -> TrialSpec {
        let mut spec = TrialSpec::new(self.mode, self.delays.clone(), self.epochs);
        spec.sync_timeout = self.sync_timeout;
        spec.crash = self.crash;
        spec.crash_restart = self.crash_restart;
        spec.fault = self.fault.clone();
        spec.sync_quorum = self.sync_quorum;
        spec.seed = self.seed;
        spec
    }
}

/// What one threaded chaos node reports back (the fault superset of
/// `timing.rs::SimNode`).
struct ChaosNode {
    finish: Duration,
    spans: Vec<Span>,
    params: FlatParams,
    stalled: bool,
    failed: bool,
    restarts: u64,
    degraded_rounds: u64,
    injected_faults: u64,
    store_retries: u64,
    store_give_ups: u64,
}

/// Drive `spec.delays.len()` real threads through the chaos scenario on
/// one shared virtual clock: per-node fault/retry store stacks (built
/// exactly like `NodeRunner`'s), crash–restart recovery from the node's
/// own checkpoint, and quorum-degraded sync rounds.
fn run_threads_chaos(spec: &ChaosSpec) -> Vec<ChaosNode> {
    let n = spec.delays.len();
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let cfg = ExperimentConfig {
        mode: spec.mode,
        n_nodes: n,
        seed: spec.seed,
        fault: spec.fault.clone(),
        sync_quorum: spec.sync_quorum,
        ..Default::default()
    };
    let shared: Arc<dyn WeightStore> =
        Arc::new(MemoryStore::with_clock(Arc::clone(&clock)));
    for _ in 0..n {
        clock.enter();
    }
    let start = Arc::new(std::sync::Barrier::new(n));
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|node_id| {
                let clock = Arc::clone(&clock);
                let shared = Arc::clone(&shared);
                let cfg = cfg.clone();
                let spec = spec.clone();
                let start = Arc::clone(&start);
                let delay = spec.delays[node_id];
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    // per-node fault/retry stack, same seed mixing as
                    // NodeRunner and the event harness
                    let (store, chaos) = if cfg.fault.is_active() {
                        let seed =
                            cfg.seed ^ (node_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                        let faulty = FaultStore::with_model(
                            Arc::clone(&shared),
                            &cfg.fault,
                            Arc::clone(&clock),
                            seed,
                        );
                        let retry = Arc::new(RetryStore::new(
                            faulty,
                            RetryPolicy::default(),
                            Arc::clone(&clock),
                            seed ^ 0xD1B5_4A32_D192_ED03,
                        ));
                        (Arc::clone(&retry) as Arc<dyn WeightStore>, Some(retry))
                    } else {
                        (Arc::clone(&shared), None)
                    };
                    let mut protocol = ProtocolKind::from(cfg.mode).build(node_id, &cfg);
                    let mut strategy = StrategyKind::FedAvg.build();
                    let mut codec = CodecState::new(cfg.compress);
                    let mut timeline = Timeline::new(node_id);
                    let mut params = FlatParams(vec![node_id as f32; 4]);
                    let mut stalled = false;
                    let mut failed = false;
                    let mut crash_consumed = false;
                    let mut restarts = 0u64;
                    let mut degraded_rounds = 0u64;
                    start.wait();
                    let mut epoch = 0;
                    while epoch < spec.epochs {
                        if let Some((c_node, c_epoch)) = spec.crash {
                            if !crash_consumed && c_node == node_id && c_epoch == epoch {
                                crash_consumed = true;
                                let t_down = clock.now();
                                match spec.crash_restart {
                                    None => {
                                        timeline.record(SpanKind::Crashed, t_down, t_down);
                                        break; // dies without pushing
                                    }
                                    Some(down) => {
                                        // down for `down` of simulated
                                        // time, then restore the node's
                                        // own checkpoint via its stack
                                        clock.sleep(down);
                                        let t_up = clock.now();
                                        timeline.record(SpanKind::Crashed, t_down, t_up);
                                        match store.latest_for_node(node_id) {
                                            Ok(Some(entry)) => {
                                                params = (*entry.params).clone();
                                            }
                                            Ok(None) => {
                                                params =
                                                    FlatParams(vec![node_id as f32; 4]);
                                            }
                                            Err(_) => {
                                                failed = true;
                                                break;
                                            }
                                        }
                                        codec = CodecState::new(cfg.compress);
                                        protocol =
                                            ProtocolKind::from(cfg.mode).build(node_id, &cfg);
                                        restarts += 1;
                                        continue; // resume the same epoch
                                    }
                                }
                            }
                        }
                        let t = clock.now();
                        clock.sleep(delay);
                        timeline.record(SpanKind::Train, t, clock.now());
                        let mut ctx = fedless::protocol::EpochCtx {
                            node_id,
                            n_nodes: n,
                            round_k: n,
                            epoch,
                            n_examples: 100,
                            store: store.as_ref(),
                            strategy: strategy.as_mut(),
                            timeline: &mut timeline,
                            sync_timeout: spec.sync_timeout,
                            clock: clock.as_ref(),
                            codec: &mut codec,
                            pool: fedless::par::ChunkPool::from_config(cfg.threads),
                            tracer: None,
                        };
                        match protocol.after_epoch(&mut ctx, &mut params) {
                            Err(_) => {
                                // the retry layer gave up: the node dies
                                // at the failure instant, like a worker
                                let t = clock.now();
                                timeline.record(SpanKind::Crashed, t, t);
                                failed = true;
                                break;
                            }
                            Ok(out) => {
                                degraded_rounds += out.degraded_rounds;
                                if out.stalled_at.is_some() {
                                    stalled = true;
                                    break;
                                }
                            }
                        }
                        epoch += 1;
                    }
                    let (injected, stats) = match &chaos {
                        Some(c) => (c.inner().injected(), c.stats()),
                        None => (0, Default::default()),
                    };
                    ChaosNode {
                        finish: clock.now(),
                        spans: timeline.spans,
                        params,
                        stalled,
                        failed,
                        restarts,
                        degraded_rounds,
                        injected_faults: injected,
                        store_retries: stats.retries,
                        store_give_ups: stats.give_ups,
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// The full observable chaos surface must agree between schedulers.
fn assert_chaos_agree(threaded: &[ChaosNode], events: &[SimNodeResult]) {
    assert_eq!(threaded.len(), events.len());
    for (t, e) in threaded.iter().zip(events) {
        assert_eq!(t.finish, e.finish, "node {}: finish instant", e.node_id);
        assert_eq!(t.spans, e.spans, "node {}: timeline spans", e.node_id);
        assert_eq!(t.params.0, e.params.0, "node {}: weights", e.node_id);
        assert_eq!(t.stalled, e.stalled, "node {}: stall flag", e.node_id);
        assert_eq!(t.failed, e.failed, "node {}: failure flag", e.node_id);
        assert_eq!(t.restarts, e.restarts, "node {}: restarts", e.node_id);
        assert_eq!(
            t.degraded_rounds, e.degraded_rounds,
            "node {}: degraded rounds",
            e.node_id
        );
        assert_eq!(
            t.injected_faults, e.injected_faults,
            "node {}: injected faults",
            e.node_id
        );
        assert_eq!(
            t.store_retries, e.store_retries,
            "node {}: store retries",
            e.node_id
        );
        assert_eq!(
            t.store_give_ups, e.store_give_ups,
            "node {}: store give-ups",
            e.node_id
        );
    }
}

// ---------------------------------------------------------------------------
// the acceptance scenario

/// 4 async nodes on a virtual clock, p = 0.05 Bernoulli store faults plus
/// one 50 ms outage window: every node completes (zero failures — the
/// retry client absorbs everything), faults were actually injected, and
/// the run replays bit-identically — including across kernel-pool widths
/// 1 vs 8 (`threads` is a pure wall-clock knob).
#[test]
fn chaos_acceptance_async_run_survives_faults_and_an_outage() {
    let t_real = Instant::now();
    let mk = |threads: usize| {
        let mut spec = TrialSpec::new(
            FederationMode::Async,
            (0..4).map(|i| ms(40 + 3 * i)).collect(),
            6,
        );
        spec.fault = FaultModel {
            p_fail: 0.05,
            outages: vec![OutageWindow { start: ms(60), duration: ms(50) }],
        };
        spec.seed = 2026;
        spec.threads = threads;
        run_events_trial(&spec).unwrap()
    };
    let a = mk(1);
    for node in &a {
        assert!(
            !node.failed && !node.stalled,
            "node {} must survive the chaos",
            node.node_id
        );
    }
    let injected: u64 = a.iter().map(|n| n.injected_faults).sum();
    assert!(injected >= 1, "the fault model must actually fire");
    assert_eq!(
        a.iter().map(|n| n.store_give_ups).sum::<u64>(),
        0,
        "no operation may exhaust its retry budget"
    );
    assert_eq!(
        a.iter().map(|n| n.store_retries).sum::<u64>(),
        injected,
        "every injected transient is absorbed by a retry"
    );

    // bit-identical replay, and kernel-pool width is a non-factor
    let b = mk(1);
    let c = mk(8);
    for ((x, y), z) in a.iter().zip(&b).zip(&c) {
        assert_eq!(x.finish, y.finish, "node {}: replay finish", x.node_id);
        assert_eq!(x.spans, y.spans, "node {}: replay spans", x.node_id);
        assert_eq!(
            digest(&x.params.0),
            digest(&y.params.0),
            "node {}: replay weight digest",
            x.node_id
        );
        assert_eq!(x.injected_faults, y.injected_faults);
        assert_eq!(x.store_retries, y.store_retries);
        assert_eq!(x.finish, z.finish, "node {}: threads 1 vs 8 finish", x.node_id);
        assert_eq!(
            digest(&x.params.0),
            digest(&z.params.0),
            "node {}: threads 1 vs 8 weight digest",
            x.node_id
        );
    }
    assert!(
        t_real.elapsed() < Duration::from_secs(10),
        "all backoff must be simulated, took {:?}",
        t_real.elapsed()
    );
}

// ---------------------------------------------------------------------------
// crash–restart recovery

/// A restarted node resumes from its last *pushed* checkpoint, not from
/// its in-memory weights. 2-node sync, node 1 crashes at epoch 1 and
/// restarts: its round-0 store entry is its initial weights `[1;4]`
/// (pushes happen before aggregation), so after the restore it pushes
/// `[1;4]` again and round 1 averages to `(0.5 + 1.0)/2 = 0.75` — had it
/// kept its post-aggregate `[0.5;4]` the round would average to 0.5.
#[test]
fn restart_node_resumes_from_its_last_pushed_checkpoint() {
    let mut spec = TrialSpec::new(FederationMode::Sync, vec![ms(50), ms(70)], 2);
    spec.crash = Some((1, 1));
    spec.crash_restart = Some(ms(100));
    let (nodes, store) = run_events_trial_captured(&spec).unwrap();
    for node in &nodes {
        assert!(!node.failed && !node.stalled, "node {}", node.node_id);
    }
    assert_eq!(nodes[1].restarts, 1);
    assert_eq!(nodes[0].restarts, 0);

    // the checkpoint the restore used, digest-checked in the store
    let round0 = store.entries_for_round(0).unwrap();
    let ckpt = round0.iter().find(|e| e.node_id == 1).expect("node 1 pushed round 0");
    assert_eq!(
        digest(&ckpt.params.0),
        digest(&[1.0f32; 4]),
        "node 1's round-0 checkpoint is its initial weights"
    );
    // ...and both nodes' final weights carry the checkpoint's signature
    for node in &nodes {
        assert_eq!(node.params.0, vec![0.75; 4], "node {}", node.node_id);
        assert_eq!(digest(&node.params.0), digest(&[0.75f32; 4]));
    }
    // downtime is a Crashed span of exactly the restart delay
    assert!(nodes[1]
        .spans
        .iter()
        .any(|s| s.kind == SpanKind::Crashed && s.end - s.start == ms(100)));
}

// ---------------------------------------------------------------------------
// quorum-degraded sync rounds

/// With a dead peer, a full barrier stalls the survivors at the hard
/// timeout; `sync_quorum = 0.5` instead closes every post-crash round
/// degraded at the soft deadline (timeout/2) on the partial set, with
/// survivors in exact agreement — analytically-timed, zero real waiting.
#[test]
fn quorum_closes_rounds_degraded_where_full_barrier_stalls() {
    let delays = vec![ms(50), ms(70), ms(230)];
    let timeout = Duration::from_secs(300);
    let t_real = Instant::now();

    let strict = {
        let mut s = TrialSpec::new(FederationMode::Sync, delays.clone(), 3);
        s.sync_timeout = timeout;
        s.crash = Some((2, 1));
        run_events_trial(&s).unwrap()
    };
    assert!(strict[0].stalled && strict[1].stalled, "full barrier stalls");
    assert_eq!(strict[0].degraded_rounds, 0);

    let relaxed = {
        let mut s = TrialSpec::new(FederationMode::Sync, delays, 3);
        s.sync_timeout = timeout;
        s.crash = Some((2, 1));
        s.sync_quorum = 0.5; // ceil(0.5 * 3) = 2: the two survivors
        run_events_trial(&s).unwrap()
    };
    for survivor in &relaxed[0..2] {
        assert!(!survivor.stalled && !survivor.failed, "node {}", survivor.node_id);
        assert_eq!(
            survivor.degraded_rounds, 2,
            "node {}: rounds 1 and 2 close degraded",
            survivor.node_id
        );
    }
    // analytic finish: round 0 closes at the straggler's 230 ms; each
    // degraded round then costs one train delay plus the 150 s soft
    // deadline from the survivor's own push
    let soft = timeout / 2;
    assert_eq!(relaxed[0].finish, ms(230) + (ms(50) + soft) * 2);
    assert_eq!(relaxed[1].finish, ms(230) + (ms(70) + soft) * 2);
    // both survivors aggregated the same partial sets
    assert_eq!(relaxed[0].params.0, relaxed[1].params.0);
    assert!(
        t_real.elapsed() < Duration::from_secs(10),
        "stalls and soft deadlines must be simulated, took {:?}",
        t_real.elapsed()
    );
}

// ---------------------------------------------------------------------------
// threads-vs-events conformance on fault outcomes

/// The retry path under Bernoulli faults plus an outage window: both
/// schedulers observe the identical chaos — same injected-fault and
/// retry counts, same backoff-stretched timeline, same weights.
///
/// Single-node on purpose: a node's backoff sleeps run *inside* one
/// executor step, so a peer's store op whose simulated instant falls
/// inside the retry window executes on a different side of it under the
/// two schedulers — cross-node store visibility mid-retry is the one
/// place the schedulers legitimately differ (akin to the sync quorum's
/// partial-set drift). The node's *own* chaos — every injection, every
/// jitter draw, every give-up decision — is scheduler-independent, and
/// that is what this test pins. The multi-node conformance cases below
/// (crash–restart, quorum) have fault-free retry windows and agree on
/// the full fleet.
#[test]
fn schedulers_agree_on_retry_and_backoff_outcomes() {
    let mut spec = ChaosSpec::new(FederationMode::Async, vec![ms(10)], 6);
    spec.fault = FaultModel {
        p_fail: 0.3,
        outages: vec![OutageWindow { start: ms(25), duration: ms(40) }],
    };
    spec.seed = 7;
    let threaded = run_threads_chaos(&spec);
    let events = run_events_trial(&spec.to_trial()).unwrap();
    assert!(
        threaded[0].injected_faults >= 1,
        "scenario must actually inject faults"
    );
    assert!(threaded[0].store_retries >= 1, "retries must actually fire");
    assert_chaos_agree(&threaded, &events);
}

/// Sync crash–restart: the crashed node re-enters after the same
/// simulated downtime, restores the same checkpoint, and the round
/// closes complete at the same instant under both schedulers.
#[test]
fn schedulers_agree_on_crash_restart_recovery() {
    let mut spec =
        ChaosSpec::new(FederationMode::Sync, vec![ms(50), ms(70), ms(230)], 3);
    spec.crash = Some((2, 1));
    spec.crash_restart = Some(ms(200));
    let threaded = run_threads_chaos(&spec);
    let events = run_events_trial(&spec.to_trial()).unwrap();
    assert_eq!(threaded[2].restarts, 1);
    assert!(threaded.iter().all(|n| !n.stalled && !n.failed));
    assert_chaos_agree(&threaded, &events);
}

/// Quorum-degraded rounds: survivors close the same rounds degraded at
/// the same soft-deadline instants under both schedulers.
#[test]
fn schedulers_agree_on_quorum_degraded_rounds() {
    let mut spec =
        ChaosSpec::new(FederationMode::Sync, vec![ms(50), ms(70), ms(230)], 3);
    spec.sync_timeout = Duration::from_secs(300);
    spec.crash = Some((2, 1));
    spec.sync_quorum = 0.5;
    let threaded = run_threads_chaos(&spec);
    let events = run_events_trial(&spec.to_trial()).unwrap();
    assert_eq!(threaded[0].degraded_rounds, 2);
    assert_chaos_agree(&threaded, &events);
}
