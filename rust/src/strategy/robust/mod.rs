//! Robust aggregation strategies — client-side defenses against
//! adversarial peers (ROADMAP open item 2; the FedLess line of work flags
//! unreliable/Byzantine clients as the open security problem for
//! serverless FL, since any node that can write to the shared store can
//! poison the global model).
//!
//! Four aggregators, all behind the ordinary [`Strategy`] trait so they
//! ride the existing config/sweep/CLI plumbing:
//!
//! | strategy | defense | defeats |
//! |----------|---------|---------|
//! | [`Median`] | coordinate-wise median | up to ⌊(n−1)/2⌋ arbitrary vectors |
//! | [`TrimmedMean`] | drop ⌊frac·n⌋ extremes per tail, average the rest | up to ⌊frac·n⌋ arbitrary vectors |
//! | [`Krum`] | select the single update closest to its n−f−2 nearest peers | up to `f` Byzantine clients (n ≥ f+3) |
//! | [`TrustWeighted`] | EMA-of-residual trust weights (DSFB-style) | persistent outlier pushers |
//!
//! # Determinism contract
//!
//! Every kernel here follows the [`crate::par`] rule: work splits into
//! fixed [`PAR_CHUNK`]-wide coordinate chunks, each chunk is computed
//! independently, and per-chunk partial results combine in chunk-index
//! order — so results are bit-identical for `threads = 1` vs `N`. On top
//! of that, robust aggregators canonicalize the *client* order (sort by
//! node id) before any arithmetic, so unlike FedAvg's client-order FMA
//! their output is also invariant under permutations of the contribution
//! slice (pinned by `rust/tests/robust.rs`).
//!
//! Robust aggregators deliberately ignore `n_examples`: example-count
//! weighting is itself attacker-controlled metadata, so each client
//! counts once.

mod krum;
mod median;
mod trimmed;
mod trust;

pub use krum::Krum;
pub use median::Median;
pub use trimmed::TrimmedMean;
pub use trust::TrustWeighted;

use crate::par::ChunkPool;
use crate::tensor::flat::PAR_CHUNK;
use crate::tensor::FlatParams;

use super::Contribution;

/// Contributions in canonical (node-id) order. All robust aggregators
/// start here so client-order permutations cannot change a single bit of
/// the result.
pub(crate) fn by_node(contribs: &[Contribution]) -> Vec<&Contribution> {
    let mut sorted: Vec<&Contribution> = contribs.iter().collect();
    sorted.sort_by_key(|c| c.node_id);
    sorted
}

/// Common length of the sorted contributions' parameter vectors.
pub(crate) fn common_len(sorted: &[&Contribution]) -> usize {
    let n = sorted[0].params.len();
    for c in sorted {
        assert_eq!(c.params.len(), n, "client param length mismatch");
    }
    n
}

/// Coordinate-wise robust reduction: for every output coordinate, gather
/// that coordinate's value from all clients, sort the column with the
/// `f32` total order, and reduce the sorted column with `f`. Chunked on
/// [`PAR_CHUNK`] boundaries so pooled results are bit-identical to the
/// sequential form.
pub(crate) fn per_coordinate<F>(sorted: &[&Contribution], pool: ChunkPool, reduce: F) -> FlatParams
where
    F: Fn(&[f32]) -> f32 + Sync,
{
    let n = common_len(sorted);
    let m = sorted.len();
    let mut out = FlatParams::zeros(n);
    let items: Vec<&mut [f32]> = out.0.chunks_mut(PAR_CHUNK).collect();
    pool.for_each(items, |ci, dst| {
        let start = ci * PAR_CHUNK;
        let rows: Vec<&[f32]> =
            sorted.iter().map(|c| &c.params.as_slice()[start..start + dst.len()]).collect();
        let mut col = vec![0.0f32; m];
        for (j, d) in dst.iter_mut().enumerate() {
            for (slot, row) in col.iter_mut().zip(&rows) {
                *slot = row[j];
            }
            col.sort_unstable_by(f32::total_cmp);
            *d = reduce(&col);
        }
    });
    out
}

/// Per-client RMS residual against a reference vector, computed as
/// fixed-chunk partial sums combined in chunk-index order (bit-identical
/// for any thread count).
pub(crate) fn residual_rms(
    sorted: &[&Contribution],
    reference: &FlatParams,
    pool: ChunkPool,
) -> Vec<f64> {
    let n = common_len(sorted);
    let m = sorted.len();
    let n_chunks = n.div_ceil(PAR_CHUNK).max(1);
    let partials: Vec<Vec<f64>> = pool.map((0..n_chunks).collect(), |_, ci| {
        let lo = ci * PAR_CHUNK;
        let hi = (lo + PAR_CHUNK).min(n);
        let base = &reference.as_slice()[lo..hi];
        sorted
            .iter()
            .map(|c| {
                let row = &c.params.as_slice()[lo..hi];
                let mut acc = 0.0f64;
                for (x, r) in row.iter().zip(base) {
                    let d = (*x - *r) as f64;
                    acc += d * d;
                }
                acc
            })
            .collect()
    });
    let mut sums = vec![0.0f64; m];
    for part in &partials {
        for (acc, v) in sums.iter_mut().zip(part) {
            *acc += *v;
        }
    }
    let denom = n.max(1) as f64;
    sums.into_iter().map(|s| (s / denom).sqrt()).collect()
}

#[cfg(test)]
mod tests {
    use super::super::strategy_tests::contrib;
    use super::*;

    #[test]
    fn by_node_sorts_and_common_len_checks() {
        let cs = [contrib(2, 1, false, &[0.0]), contrib(0, 1, true, &[1.0]), contrib(1, 1, false, &[2.0])];
        let sorted = by_node(&cs);
        let ids: Vec<usize> = sorted.iter().map(|c| c.node_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(common_len(&sorted), 1);
    }

    #[test]
    fn per_coordinate_min_reduction() {
        let cs = [
            contrib(0, 1, true, &[3.0, -1.0]),
            contrib(1, 1, false, &[1.0, 5.0]),
            contrib(2, 1, false, &[2.0, 0.0]),
        ];
        let sorted = by_node(&cs);
        let out = per_coordinate(&sorted, ChunkPool::sequential(), |col| col[0]);
        assert_eq!(out.0, vec![1.0, -1.0]);
    }

    #[test]
    fn residual_rms_matches_hand_computation() {
        let cs = [contrib(0, 1, true, &[1.0, 1.0]), contrib(1, 1, false, &[4.0, 5.0])];
        let sorted = by_node(&cs);
        let reference = FlatParams(vec![1.0, 1.0]);
        let r = residual_rms(&sorted, &reference, ChunkPool::sequential());
        assert_eq!(r[0], 0.0);
        // sqrt((9 + 16) / 2) = sqrt(12.5)
        assert!((r[1] - 12.5f64.sqrt()).abs() < 1e-12, "{}", r[1]);
    }

    #[test]
    fn kernels_are_thread_invariant() {
        let n = PAR_CHUNK + 7;
        let cs: Vec<Contribution> = (0..5)
            .map(|k| {
                let vals: Vec<f32> = (0..n).map(|i| ((i * (k + 2)) as f32 * 0.013).sin()).collect();
                contrib(k, 1, k == 0, &vals)
            })
            .collect();
        let sorted = by_node(&cs);
        let seq = per_coordinate(&sorted, ChunkPool::sequential(), |col| col[col.len() / 2]);
        let reference = seq.clone();
        let rms_seq = residual_rms(&sorted, &reference, ChunkPool::sequential());
        for threads in [2, 8] {
            let pool = ChunkPool::new(threads);
            let par = per_coordinate(&sorted, pool, |col| col[col.len() / 2]);
            assert_eq!(seq.0, par.0, "per_coordinate threads={threads}");
            let rms_par = residual_rms(&sorted, &reference, pool);
            assert_eq!(rms_seq, rms_par, "residual_rms threads={threads}");
        }
    }
}
