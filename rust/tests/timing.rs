//! Deterministic timing suite — every test here runs timing scenarios
//! that would take minutes of real `thread::sleep` under the
//! [`fedless::time::VirtualClock`], at CPU speed, with *exact*
//! assertions on simulated durations (no tolerance windows, no
//! flakiness: simulated time is a pure function of the configuration).
//!
//! The suite covers the paper's §4.2 time argument (async removes the
//! straggler bottleneck), the §4.2.1 crash scenario (the sync barrier
//! releases survivors within *simulated* timeout), the store layer's
//! virtual-time subscriptions and latency injection, and a golden sweep
//! report (cells are deterministic under the virtual clock, so a
//! snapshot is finally safe).
//!
//! CI runs this file under a hard real-time budget (see
//! `.github/workflows/ci.yml`): if the virtual clock ever regresses
//! into real sleeping, the job times out.
//!
//! The protocol-level harness below needs no artifacts or PJRT runtime;
//! the two `run_experiment` end-to-end tests skip themselves when the
//! artifacts are not built (same environment contract as
//! `rust/tests/integration.rs`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use fedless::config::{ClockKind, CrashSpec, ExperimentConfig, FederationMode};
use fedless::metrics::timeline::{Span, SpanKind, Timeline};
use fedless::protocol::ProtocolKind;
use fedless::store::{LatencyConfig, LatencyStore, MemoryStore, WeightStore};
use fedless::strategy::StrategyKind;
use fedless::tensor::FlatParams;
use fedless::time::{Clock, ParticipantGuard, VirtualClock};

fn ms(n: u64) -> Duration {
    Duration::from_millis(n)
}

// ---------------------------------------------------------------------------
// protocol-level simulation harness (no artifacts, no PJRT)

/// What one simulated node reports back.
struct SimNode {
    finish: Duration,
    spans: Vec<Span>,
    params: FlatParams,
    stalled: bool,
}

/// Drive `delays.len()` real threads through `epochs` epochs of
/// `mode`-federation on one shared virtual-clocked store: each epoch is
/// one `clock.sleep(delay)` ("training") followed by the protocol's
/// `after_epoch`. `crash` = `(node, epoch)` makes that node exit at the
/// start of that epoch without pushing (the §4.2.1 scenario).
fn run_sim(
    mode: FederationMode,
    delays: &[Duration],
    epochs: usize,
    sync_timeout: Duration,
    crash: Option<(usize, usize)>,
) -> Vec<SimNode> {
    let n = delays.len();
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let cfg = ExperimentConfig { mode, n_nodes: n, ..Default::default() };
    let store: Arc<dyn WeightStore> =
        Arc::new(MemoryStore::with_clock(Arc::clone(&clock)));
    // Register every node before any thread runs, so the clock never
    // advances while some nodes are still spawning.
    for _ in 0..n {
        clock.enter();
    }
    let start = Arc::new(std::sync::Barrier::new(n));
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|node_id| {
                let clock = Arc::clone(&clock);
                let store = Arc::clone(&store);
                let cfg = cfg.clone();
                let start = Arc::clone(&start);
                let delay = delays[node_id];
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    let mut protocol = ProtocolKind::from(cfg.mode).build(node_id, &cfg);
                    let mut strategy = StrategyKind::FedAvg.build();
                    let mut codec = fedless::compress::CodecState::new(cfg.compress);
                    let mut timeline = Timeline::new(node_id);
                    // distinct starting weights so averaging is visible
                    let mut params = FlatParams(vec![node_id as f32; 4]);
                    let mut stalled = false;
                    start.wait();
                    for epoch in 0..epochs {
                        if crash == Some((node_id, epoch)) {
                            // dies without pushing this round; the
                            // zero-width Crashed marker mirrors
                            // NodeRunner (and the event harness)
                            let t = clock.now();
                            timeline.record(SpanKind::Crashed, t, t);
                            break;
                        }
                        let t = clock.now();
                        clock.sleep(delay);
                        timeline.record(SpanKind::Train, t, clock.now());
                        let mut ctx = fedless::protocol::EpochCtx {
                            node_id,
                            n_nodes: n,
                            round_k: n,
                            epoch,
                            n_examples: 100,
                            store: store.as_ref(),
                            strategy: strategy.as_mut(),
                            timeline: &mut timeline,
                            sync_timeout,
                            clock: clock.as_ref(),
                            codec: &mut codec,
                            pool: fedless::par::ChunkPool::from_config(cfg.threads),
                            tracer: None,
                        };
                        let out = protocol.after_epoch(&mut ctx, &mut params).unwrap();
                        if out.stalled_at.is_some() {
                            stalled = true;
                            break;
                        }
                    }
                    SimNode { finish: clock.now(), spans: timeline.spans, params, stalled }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

// ---------------------------------------------------------------------------
// the paper's §4.2 straggler scenario, deterministic

/// Under the virtual clock, async's simulated time-to-final-epoch beats
/// sync by *exactly* the straggler ratio when one node is 10× slower —
/// the paper's Figure-1 phenomenon as an exact regression test.
#[test]
fn async_beats_sync_by_exactly_the_straggler_ratio() {
    let epochs = 5;
    let d = ms(50);
    let delays = [d, 10 * d]; // node 1 is the 10x straggler
    let t_real = Instant::now();

    let sync = run_sim(FederationMode::Sync, &delays, epochs, Duration::from_secs(3600), None);
    let asyn = run_sim(FederationMode::Async, &delays, epochs, Duration::from_secs(3600), None);

    assert!(
        t_real.elapsed() < Duration::from_secs(5),
        "virtual clock must run at CPU speed, took {:?}",
        t_real.elapsed()
    );

    // sync: the fast node is dragged to the straggler's pace, exactly
    assert_eq!(sync[0].finish, 10 * d * epochs as u32);
    assert_eq!(sync[1].finish, 10 * d * epochs as u32);
    // async: the fast node finishes on its own schedule, exactly
    assert_eq!(asyn[0].finish, d * epochs as u32);
    assert_eq!(asyn[1].finish, 10 * d * epochs as u32);
    let ratio = sync[0].finish.as_secs_f64() / asyn[0].finish.as_secs_f64();
    assert_eq!(ratio, 10.0, "time-to-final-epoch ratio must be the delay ratio");

    // the fast sync node's idle time is exactly what the straggler costs
    let sync_wait: Duration = sync[0]
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::Wait)
        .map(|s| s.end - s.start)
        .sum();
    assert_eq!(sync_wait, (10 * d - d) * epochs as u32);
    let async_wait: Duration = asyn[0]
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::Wait)
        .map(|s| s.end - s.start)
        .sum();
    assert_eq!(async_wait, Duration::ZERO, "async never waits");
}

/// The same scenario replayed twice is bit-identical: every timeline
/// span and every weight — simulated time has no scheduling noise.
#[test]
fn straggler_runs_replay_bit_identically() {
    let delays = [ms(50), ms(500)];
    for mode in [FederationMode::Sync, FederationMode::Async] {
        let a = run_sim(mode, &delays, 4, Duration::from_secs(3600), None);
        let b = run_sim(mode, &delays, 4, Duration::from_secs(3600), None);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.finish, y.finish, "{mode:?}: finish times must replay");
            assert_eq!(x.spans, y.spans, "{mode:?}: timelines must be bit-identical");
            assert_eq!(x.params.0, y.params.0, "{mode:?}: weights must be bit-identical");
        }
    }
}

/// The acceptance scenario: a 10-node, 20-epoch run with ~500 ms/epoch
/// delays completes in well under 5 s of real time, reports the exact
/// analytic simulated duration per node, and replays bit-identically.
#[test]
fn ten_node_straggler_grid_runs_at_cpu_speed() {
    let epochs = 20;
    // 500 ms base plus a distinct per-node skew so no two events share a
    // simulated instant (full determinism, see module docs)
    let delays: Vec<Duration> = (0..10).map(|i| ms(500 + i)).collect();
    let t_real = Instant::now();
    let a = run_sim(FederationMode::Async, &delays, epochs, Duration::from_secs(3600), None);
    let b = run_sim(FederationMode::Async, &delays, epochs, Duration::from_secs(3600), None);
    assert!(
        t_real.elapsed() < Duration::from_secs(5),
        "two 10-node 20-epoch straggler runs must finish in < 5 s real, took {:?}",
        t_real.elapsed()
    );
    for (i, node) in a.iter().enumerate() {
        // analytic: node i trains 20 epochs at (500 + i) ms each
        assert_eq!(node.finish, ms(500 + i as u64) * epochs as u32, "node {i}");
    }
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.spans, y.spans, "repeated runs must be bit-identical");
        assert_eq!(x.params.0, y.params.0);
    }
}

// ---------------------------------------------------------------------------
// §4.2.1 crash: the barrier releases survivors in simulated time

/// A node dies mid-run under sync mode; the survivors' barrier times out
/// after *simulated* `sync_timeout` — 300 simulated seconds of stall
/// cost (asserted exactly) at milliseconds of real time.
#[test]
fn crashed_peer_releases_sync_survivors_within_simulated_timeout() {
    let sync_timeout = Duration::from_secs(300);
    let delays = [ms(50), ms(70), ms(230)];
    let t_real = Instant::now();
    // node 2 dies at the start of epoch 1 (after round 0 completed)
    let nodes = run_sim(FederationMode::Sync, &delays, 3, sync_timeout, Some((2, 1)));
    let real = t_real.elapsed();
    assert!(
        real < Duration::from_secs(10),
        "the 300 s stall must be simulated, not real (took {real:?})"
    );
    for survivor in &nodes[0..2] {
        assert!(survivor.stalled, "survivors must stall at the crashed round");
        let wait: Duration = survivor
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Wait)
            .map(|s| s.end - s.start)
            .sum();
        // round 0's barrier waits are free of the crash; the stalled
        // round's wait is exactly the timeout
        assert!(
            wait >= sync_timeout,
            "stall must ride out the full simulated timeout, waited {wait:?}"
        );
    }
    assert!(!nodes[2].stalled, "the crashed node never reached a barrier");
    // the crashed node stopped at round 0's completion instant
    assert_eq!(nodes[2].finish, ms(230));
}

// ---------------------------------------------------------------------------
// executor-vs-threads conformance: the event scheduler is a drop-in
// replacement for thread-per-node, proven bit-for-bit

/// Assert a threaded run and an event-executor run observed the same
/// federation: same finish instants, same timeline spans, same weights,
/// same stall flags — the full observable surface of the protocol
/// harness.
fn assert_schedulers_agree(threaded: &[SimNode], events: &[fedless::sched::SimNodeResult]) {
    assert_eq!(threaded.len(), events.len());
    for (t, e) in threaded.iter().zip(events) {
        assert_eq!(t.finish, e.finish, "node {}: finish instant", e.node_id);
        assert_eq!(t.spans, e.spans, "node {}: timeline spans", e.node_id);
        assert_eq!(t.params.0, e.params.0, "node {}: weights", e.node_id);
        assert_eq!(t.stalled, e.stalled, "node {}: stall flag", e.node_id);
    }
}

/// Sync and async 10-node straggler grids replay bit-identically under
/// both schedulers (distinct per-node delays, so the threaded schedule
/// is itself deterministic — see module docs).
#[test]
fn event_executor_matches_threads_on_the_straggler_grid() {
    use fedless::sched::{run_events_trial, TrialSpec};
    for mode in [FederationMode::Sync, FederationMode::Async] {
        let delays: Vec<Duration> = (0..10).map(|i| ms(500 + i)).collect();
        let threaded = run_sim(mode, &delays, 4, Duration::from_secs(3600), None);
        let events = run_events_trial(&TrialSpec::new(mode, delays, 4)).unwrap();
        assert_schedulers_agree(&threaded, &events);
    }
}

/// The §4.2.1 crash scenario: survivors stall at the same simulated
/// instants with the same Wait spans under both schedulers, and the
/// crashed node stops at the same round-0 completion instant.
#[test]
fn event_executor_matches_threads_on_the_crash_scenario() {
    use fedless::sched::{run_events_trial, TrialSpec};
    let delays = [ms(50), ms(70), ms(230)];
    let timeout = Duration::from_secs(300);
    let threaded = run_sim(FederationMode::Sync, &delays, 3, timeout, Some((2, 1)));
    let mut spec = TrialSpec::new(FederationMode::Sync, delays.to_vec(), 3);
    spec.sync_timeout = timeout;
    spec.crash = Some((2, 1));
    let events = run_events_trial(&spec).unwrap();
    assert_schedulers_agree(&threaded, &events);
}

// ---------------------------------------------------------------------------
// store layer in virtual time

#[test]
fn store_wait_for_change_parks_in_simulated_time() {
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let store: Arc<dyn WeightStore> =
        Arc::new(MemoryStore::with_clock(Arc::clone(&clock)));
    let v0 = store.version().unwrap();
    clock.enter();
    clock.enter();
    let t_real = Instant::now();
    let (woke_at, v) = std::thread::scope(|scope| {
        let waiter = {
            let clock = Arc::clone(&clock);
            let store = Arc::clone(&store);
            scope.spawn(move || {
                let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                let v = store.wait_for_change(v0, Duration::from_secs(600)).unwrap();
                (clock.now(), v)
            })
        };
        let pusher = {
            let clock = Arc::clone(&clock);
            let store = Arc::clone(&store);
            scope.spawn(move || {
                let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                clock.sleep(ms(50));
                store
                    .push(fedless::store::PushRequest::raw(
                        0,
                        0,
                        0,
                        1,
                        Arc::new(FlatParams(vec![1.0; 4])),
                    ))
                    .unwrap();
            })
        };
        pusher.join().unwrap();
        waiter.join().unwrap()
    });
    assert!(v > v0, "waiter must observe the push");
    assert_eq!(woke_at, ms(50), "woken at the push's simulated instant");
    assert!(t_real.elapsed() < Duration::from_secs(5), "no real waiting");

    // clean timeout: consumes exactly the timeout of simulated time
    let before = clock.now();
    let v2 = store.wait_for_change(v, ms(200)).unwrap();
    assert_eq!(v2, v, "clean timeout returns the unchanged version");
    assert_eq!(clock.now() - before, ms(200));
}

#[test]
fn latency_store_delays_are_simulated() {
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let cfg = LatencyConfig {
        base: ms(20),
        jitter: Duration::ZERO,
        bytes_per_sec: 0,
    };
    let store = LatencyStore::with_clock(
        MemoryStore::with_clock(Arc::clone(&clock)),
        cfg,
        1,
        Arc::clone(&clock),
    );
    let t_real = Instant::now();
    store.state_hash().unwrap(); // one RTT
    store.state_hash().unwrap(); // another
    assert_eq!(clock.now(), ms(40), "two RTTs of simulated latency");
    assert!(t_real.elapsed() < Duration::from_secs(2), "no real sleeping");
}

#[test]
fn fs_store_polling_backoff_is_simulated() {
    let dir = std::env::temp_dir().join(format!(
        "fedless_timing_fs_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let store = fedless::store::FsStore::open_with_clock(&dir, Arc::clone(&clock)).unwrap();
    let v0 = store.version().unwrap();
    let t_real = Instant::now();
    let v = store.wait_for_change(v0, ms(200)).unwrap();
    assert_eq!(v, v0, "nothing changed");
    assert_eq!(clock.now(), ms(200), "the poll backoff consumed simulated time");
    assert!(t_real.elapsed() < Duration::from_secs(2), "no real sleeping");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// golden sweep report: deterministic cells make snapshots safe

/// A tiny 2×2 sweep (mode × skew, two seeds per cell) whose trial runner
/// simulates the protocols on a fresh virtual clock per trial: every
/// cell — including the wall-clock column — is deterministic, so the
/// whole Markdown body snapshots exactly.
#[test]
fn golden_sweep_report_under_virtual_clock() {
    use fedless::sweep::{run_sweep_with, SweepSpec};

    let base = ExperimentConfig {
        clock: ClockKind::Virtual,
        n_nodes: 2,
        epochs: 3,
        seed: 42,
        ..Default::default()
    };
    let mut spec = SweepSpec::from_base(base);
    spec.modes = vec![FederationMode::Sync, FederationMode::Async];
    spec.skews = vec![0.0, 0.5];
    spec.seeds = vec![42, 43];
    spec.jobs = 1;

    let runner = |cfg: &ExperimentConfig| -> anyhow::Result<fedless::sim::ExperimentResult> {
        // Simulate the trial's protocol on its own virtual clock:
        // distinct per-node delays so the whole timeline is exact.
        let nodes = run_sim(
            cfg.mode,
            &[ms(50), ms(230)],
            cfg.epochs,
            Duration::from_secs(3600),
            None,
        );
        let wall = nodes.iter().map(|n| n.finish).max().unwrap();
        // pure, hand-checkable cell metrics (accuracy is not the point
        // of this golden; deterministic *timing* is)
        let accuracy = 0.9
            - 0.1 * cfg.skew
            - if cfg.mode == FederationMode::Async { 0.02 } else { 0.0 };
        Ok(fedless::sim::ExperimentResult {
            final_accuracy: accuracy,
            final_loss: 1.0 - accuracy,
            wall_clock_s: wall.as_secs_f64(),
            reports: vec![],
            global_hash: 0,
            store_pushes: 0,
            mean_idle_fraction: 0.0,
            all_completed: !nodes.iter().any(|n| n.stalled),
            divergence: None,
            trace_dir: None,
        })
    };

    let body = |md: &str| -> String {
        // skip the header line: it carries the sweep's *real* wall-clock
        md.lines().skip(1).collect::<Vec<_>>().join("\n")
    };

    let r1 = run_sweep_with(&spec, runner).unwrap();
    let r2 = run_sweep_with(&spec, runner).unwrap();
    assert_eq!(r1.n_failures, 0, "{}", r1.to_markdown());
    assert_eq!(
        body(&r1.to_markdown()),
        body(&r2.to_markdown()),
        "repeated sweeps must render identically"
    );

    let golden = "\n\
| mode | strategy | skew | nodes | compress | threads | part | adversary | trials | accuracy (mean ± std) | acc clean | acc attacked | loss (mean ± std) | wall-clock s | MB pushed | MB pulled |\n\
|------|----------|------|-------|----------|---------|------|-----------|--------|-----------------------|-----------|--------------|-------------------|--------------|-----------|-----------|\n\
| sync | fedavg | 0 | 2 | none | 1 | 1 | none | 2 | 0.900 ± 0.000 | 0.900 | - | 0.100 ± 0.000 | 0.690 ± 0.000 | 0.00 | 0.00 |\n\
| sync | fedavg | 0.5 | 2 | none | 1 | 1 | none | 2 | 0.850 ± 0.000 | 0.850 | - | 0.150 ± 0.000 | 0.690 ± 0.000 | 0.00 | 0.00 |\n\
| async | fedavg | 0 | 2 | none | 1 | 1 | none | 2 | 0.880 ± 0.000 | 0.880 | - | 0.120 ± 0.000 | 0.690 ± 0.000 | 0.00 | 0.00 |\n\
| async | fedavg | 0.5 | 2 | none | 1 | 1 | none | 2 | 0.830 ± 0.000 | 0.830 | - | 0.170 ± 0.000 | 0.690 ± 0.000 | 0.00 | 0.00 |";
    assert_eq!(
        body(&r1.to_markdown()),
        golden,
        "sweep body diverged from the golden snapshot:\n{}",
        r1.to_markdown()
    );
}

// ---------------------------------------------------------------------------
// end-to-end through run_experiment (skipped without artifacts)

fn have_artifacts() -> bool {
    fedless::runtime::Manifest::discover().is_ok()
}

/// `CrashSpec` node dies mid-run under sync mode + `clock = virtual`:
/// the barrier's `sync_timeout` releases the surviving peers within
/// *simulated* (not real) timeout.
#[test]
fn e2e_crash_recovery_releases_survivors_in_simulated_time() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let cfg = ExperimentConfig {
        model: "mnist".into(),
        n_nodes: 3,
        mode: FederationMode::Sync,
        epochs: 3,
        steps_per_epoch: 8,
        train_size: 900,
        test_size: 96,
        seed: 7,
        crash: Some(CrashSpec::at(1, 1)),
        sync_timeout: Duration::from_secs(300),
        clock: ClockKind::Virtual,
        ..Default::default()
    };
    let t_real = Instant::now();
    let res = fedless::sim::run_experiment(&cfg).unwrap();
    let real = t_real.elapsed();
    assert!(
        real < Duration::from_secs(120),
        "the 300 s barrier timeout must not be waited for real (took {real:?})"
    );
    let stalled = res
        .reports
        .iter()
        .filter(|r| matches!(r.status, fedless::node::NodeStatus::Stalled { .. }))
        .count();
    assert_eq!(stalled, 2, "survivors must stall: {:?}",
        res.reports.iter().map(|r| &r.status).collect::<Vec<_>>());
    assert!(
        res.wall_clock_s >= 300.0,
        "reported wall-clock must include the simulated stall, got {}",
        res.wall_clock_s
    );
    for r in res.reports.iter().filter(|r| matches!(r.status,
        fedless::node::NodeStatus::Stalled { .. }))
    {
        assert!(
            r.wait_time >= Duration::from_secs(300),
            "node {} stalled wait must be the simulated timeout, got {:?}",
            r.node_id,
            r.wait_time
        );
    }
}

/// The acceptance scenario end-to-end: 10 nodes × 20 epochs × 2 steps
/// with 500 ms/step delays is 20 s of simulated training per node; under
/// `clock = virtual` the run reports exactly that while real time is
/// bounded by compute only.
#[test]
fn e2e_ten_node_delay_run_reports_analytic_simulated_wall_clock() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let cfg = ExperimentConfig {
        model: "mnist".into(),
        n_nodes: 10,
        mode: FederationMode::Async,
        epochs: 20,
        steps_per_epoch: 2,
        train_size: 2_000,
        test_size: 320,
        seed: 11,
        node_delays_ms: vec![500.0; 10],
        clock: ClockKind::Virtual,
        ..Default::default()
    };
    let t_real = Instant::now();
    let res = fedless::sim::run_experiment(&cfg).unwrap();
    let real = t_real.elapsed();
    assert!(res.all_completed);
    // analytic: 20 epochs × 2 steps × 500 ms = 20 s simulated per node
    assert!(
        (res.wall_clock_s - 20.0).abs() < 1e-6,
        "simulated wall-clock must match the analytic 20 s, got {}",
        res.wall_clock_s
    );
    assert!(
        real < Duration::from_secs(120),
        "200 s of cumulative simulated delay must not be slept for real \
         (took {real:?})"
    );
}
