//! Repeated trials: run an experiment `n` times with different seeds and
//! summarize accuracy as mean ± 95% CI — one paper-table cell.

use anyhow::Result;

use super::{run_experiment, ExperimentResult};
use crate::config::ExperimentConfig;
use crate::metrics::stats::Summary;

/// Results of repeated trials of one configuration.
#[derive(Debug)]
pub struct TrialSet {
    /// The configuration's `run_name` (base seed's name).
    pub cfg_name: String,
    /// Per-trial results, in seed order.
    pub results: Vec<ExperimentResult>,
    /// Accuracy across trials.
    pub accuracy: Summary,
    /// Test loss across trials.
    pub loss: Summary,
    /// Wall-clock seconds across trials.
    pub wall_clock: Summary,
}

impl TrialSet {
    /// Paper-style cell text, e.g. `.983 ± .002`.
    pub fn cell(&self) -> String {
        self.accuracy.fmt_paper()
    }
}

/// Run `n_trials` trials, offsetting the seed each time.
pub fn run_trials(cfg: &ExperimentConfig, n_trials: usize) -> Result<TrialSet> {
    anyhow::ensure!(n_trials >= 1);
    let mut results = Vec::with_capacity(n_trials);
    for t in 0..n_trials {
        let mut c = cfg.clone();
        c.seed = cfg.seed.wrapping_add(1000 * t as u64);
        results.push(run_experiment(&c)?);
    }
    let accs: Vec<f64> = results.iter().map(|r| r.final_accuracy).collect();
    let losses: Vec<f64> = results.iter().map(|r| r.final_loss).collect();
    let walls: Vec<f64> = results.iter().map(|r| r.wall_clock_s).collect();
    Ok(TrialSet {
        cfg_name: cfg.run_name(),
        accuracy: Summary::of(&accs),
        loss: Summary::of(&losses),
        wall_clock: Summary::of(&walls),
        results,
    })
}
