//! [`SyncBarrier`] — the synchronous serverless protocol (§3), now a
//! resumable state machine polled via
//! [`FederationProtocol::poll_epoch`] instead of blocking inline.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::metrics::timeline::SpanKind;
use crate::strategy::Contribution;
use crate::tensor::FlatParams;

use super::{EpochCtx, EpochStep, FederationProtocol, ProtocolOutcome};

/// A barrier wait in flight: the round we pushed for and when (on the
/// experiment clock) the wait began — carried across polls so elapsed
/// time and the Wait span survive suspension.
struct PendingRound {
    round: u64,
    wait_start: Duration,
}

/// Synchronous serverless federation: push for round `r`, wait until all
/// `round_k` round-`r` entries exist, aggregate the identical set
/// client-side (so all nodes compute bit-identical weights —
/// `rust/tests/protocol_invariants.rs`).
///
/// The barrier is event-driven: the protocol never blocks itself — it
/// returns [`EpochStep::Wait`] and the *driver* parks. The threaded
/// worker parks on [`crate::store::WeightStore::wait_for_change`] (woken
/// only when a peer's push advances the store version, never on a sleep
/// timer); the event executor suspends the node task until the store
/// version moves or the timeout deadline fires. A `sync_timeout` still
/// bounds the wait so a crashed peer turns the node's status into
/// `Stalled` instead of hanging (§4.2.1).
///
/// With a `quorum < 1` (`sync_quorum` config key) the barrier degrades
/// gracefully instead of stalling: once half the timeout has passed (the
/// *soft* deadline) a round closes as soon as `ceil(quorum * round_k)`
/// entries exist, aggregating the partial set and counting a
/// [`ProtocolOutcome::degraded_rounds`]. Only a round still *below*
/// quorum at the hard timeout stalls the node. Every quorum decision is
/// a pure function of (store contents, clock) that each cohort member
/// evaluates identically, so no coordinator is needed — though members
/// may close a round on different partial sets if pushes race the soft
/// deadline, which is the accepted consistency cost of availability
/// here (the async protocol lives with the same drift every epoch).
pub struct SyncBarrier {
    pending: Option<PendingRound>,
    quorum: f64,
}

impl SyncBarrier {
    /// A barrier with no round in flight, requiring the full cohort.
    pub fn new() -> SyncBarrier {
        SyncBarrier::with_quorum(1.0)
    }

    /// A barrier that may close rounds degraded at `ceil(quorum * k)`
    /// members after the soft deadline. `quorum` must be in (0, 1];
    /// 1.0 behaves exactly like [`SyncBarrier::new`].
    pub fn with_quorum(quorum: f64) -> SyncBarrier {
        assert!(quorum > 0.0 && quorum <= 1.0, "quorum in (0, 1]");
        SyncBarrier { pending: None, quorum }
    }

    fn quorum_k(&self, round_k: usize) -> usize {
        ((self.quorum * round_k as f64).ceil() as usize).clamp(1, round_k)
    }
}

impl Default for SyncBarrier {
    fn default() -> Self {
        SyncBarrier::new()
    }
}

impl FederationProtocol for SyncBarrier {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn poll_epoch(
        &mut self,
        ctx: &mut EpochCtx<'_>,
        params: &mut FlatParams,
    ) -> Result<EpochStep> {
        let round = ctx.epoch as u64;
        // First poll of this round pushes and starts the wait clock;
        // re-polls resume the pending wait without pushing again.
        let wait_start = match &self.pending {
            Some(p) if p.round == round => p.wait_start,
            _ => {
                ctx.push_weights(params, round)?;
                let t = ctx.clock.now();
                self.pending = Some(PendingRound { round, wait_start: t });
                t
            }
        };

        // Read the version token *before* listing: a push landing
        // between the two can only cause a spurious wake-up, never a
        // missed one.
        let seen = ctx.store.version()?;
        let entries = ctx.store.entries_for_round(round)?;
        // every re-pull downloaded these bytes, complete or not
        ctx.record_pull(&entries);
        let complete = entries.len() >= ctx.round_k;
        if !complete {
            // barrier still open: elapsed time and the stall timeout are
            // measured on the experiment clock, so a crashed peer
            // releases survivors within *simulated* timeout under a
            // virtual clock — no real-time wait.
            let elapsed = ctx.clock.now().saturating_sub(wait_start);
            // the soft deadline after which a quorum may close degraded
            let soft = ctx.sync_timeout / 2;
            let quorum_met =
                self.quorum < 1.0 && entries.len() >= self.quorum_k(ctx.round_k);
            if elapsed < ctx.sync_timeout && !(quorum_met && elapsed >= soft) {
                // Keep waiting — for the full cohort until the hard
                // timeout, or (quorum already met) for late peers until
                // the soft deadline, whichever re-poll comes first.
                let deadline = if quorum_met { soft } else { ctx.sync_timeout };
                return Ok(EpochStep::Wait { since: seen, timeout: deadline - elapsed });
            }
            if !quorum_met {
                // hard timeout below quorum: the legacy stall
                ctx.timeline.record(SpanKind::Wait, wait_start, ctx.clock.now());
                self.pending = None;
                return Ok(EpochStep::Done(ProtocolOutcome {
                    pushes: 1,
                    stalled_at: Some(round),
                    ..Default::default()
                }));
            }
            // fall through: close the round degraded on the partial set
        }
        self.pending = None;
        ctx.timeline.record(SpanKind::Wait, wait_start, ctx.clock.now());

        let t_agg = ctx.clock.now();
        let contribs: Vec<Contribution> = entries
            .iter()
            .map(|e| Contribution {
                node_id: e.node_id,
                n_examples: e.n_examples,
                is_self: e.node_id == ctx.node_id,
                seq: e.seq,
                params: Arc::clone(&e.params),
            })
            .collect();
        let mut out = ProtocolOutcome {
            pushes: 1,
            degraded_rounds: if complete { 0 } else { 1 },
            ..Default::default()
        };
        if let Some(new_params) = ctx.strategy.aggregate_pooled(&contribs, ctx.pool) {
            *params = new_params;
            out.aggregations = 1;
            // the adopted aggregate is the next push's delta base
            ctx.adopt_aggregate(params, &entries);
        }
        ctx.timeline.record(SpanKind::Aggregate, t_agg, ctx.clock.now());
        Ok(EpochStep::Done(out))
    }
}
