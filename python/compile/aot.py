"""AOT compiler: lower every model's init/train/eval step and the chunked
aggregation kernels to `artifacts/*.hlo.txt` + `manifest.json`.

This is the ONLY python entrypoint in the build (`make artifacts`); the rust
coordinator is self-contained afterwards. Python never runs on the request
path.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--models mnist,cifar,lm]
                          [--agg-k 2,3,5] [--no-pallas] [--chunk 262144]
"""

import argparse
import hashlib
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

from . import train as T
from .hlo import lower_fn
from .kernels import fedavg_aggregate
from .models import get_model

# Chunk width for aggregation artifacts: one artifact serves every model;
# rust pads the last chunk. 262144 f32 = 1 MiB per client row.
DEFAULT_CHUNK = 262144
DEFAULT_AGG_K = (2, 3, 5)


def _write(out_dir: pathlib.Path, name: str, text: str) -> dict:
    path = out_dir / name
    path.write_text(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    print(f"  wrote {name:28s} {len(text):>10,} chars  sha256:{digest}")
    return {"file": name, "sha256_16": digest}


def build_model_artifacts(out_dir, name, spec, use_pallas: bool) -> dict:
    p = T.param_count(spec)
    print(f"[{name}] param_count={p:,} batch={spec.batch_size}")
    x, y = T.example_batch(spec)
    fp = jax.ShapeDtypeStruct((p,), jnp.float32)
    seed = jax.ShapeDtypeStruct((2,), jnp.uint32)
    step = jax.ShapeDtypeStruct((), jnp.int32)

    arts = {}
    t0 = time.time()
    arts["init"] = _write(
        out_dir, f"{name}_init.hlo.txt", lower_fn(T.make_init_step(spec), seed)
    )
    arts["train"] = _write(
        out_dir,
        f"{name}_train.hlo.txt",
        lower_fn(T.make_train_step(spec, use_pallas), fp, fp, fp, step, x, y),
    )
    arts["eval"] = _write(
        out_dir,
        f"{name}_eval.hlo.txt",
        lower_fn(T.make_eval_step(spec, use_pallas), fp, x, y),
    )
    print(f"[{name}] lowered in {time.time() - t0:.1f}s")

    return {
        "param_count": p,
        "batch_size": spec.batch_size,
        "input_shape": list(spec.input_shape),
        "input_dtype": spec.input_dtype,
        "num_classes": spec.num_classes,
        "lr": spec.lr,
        "weight_decay": spec.weight_decay,
        "extra": spec.extra,
        "artifacts": arts,
    }


def build_agg_artifacts(out_dir, ks, chunk) -> dict:
    out = {}
    for k in ks:
        stack = jax.ShapeDtypeStruct((k, chunk), jnp.float32)
        w = jax.ShapeDtypeStruct((k,), jnp.float32)
        fn = lambda s, ww: (fedavg_aggregate(s, ww),)
        out[str(k)] = _write(out_dir, f"agg_k{k}.hlo.txt", lower_fn(fn, stack, w))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="mnist,cifar,lm")
    ap.add_argument("--agg-k", default="2,3,5")
    ap.add_argument("--chunk", type=int, default=DEFAULT_CHUNK)
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="route Dense/Adam through jnp oracles instead of Pallas kernels",
    )
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    use_pallas = not args.no_pallas

    manifest = {
        "version": 1,
        "use_pallas": use_pallas,
        "chunk": args.chunk,
        "models": {},
        "agg": {},
    }
    for name in filter(None, args.models.split(",")):
        spec = get_model(name)
        manifest["models"][name] = build_model_artifacts(
            out_dir, name, spec, use_pallas
        )
    ks = [int(k) for k in filter(None, args.agg_k.split(","))]
    manifest["agg"] = {"chunk": args.chunk, "k": build_agg_artifacts(out_dir, ks, args.chunk)}

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
