//! Filesystem weight store — the direct analogue of the paper's
//! `S3Folder("mybucket/experiment1")`: a directory of self-validating blob
//! files that genuinely separate OS processes can share.
//!
//! Layout: `<root>/n{node}_s{seq}.flwr`, written atomically
//! (`.tmp` + rename) so readers never observe torn files; the blob codec's
//! payload hash catches anything that slips through (e.g. a copied
//! partial file on a network mount).
//!
//! Files are always written in the self-contained v1 (raw f32) format so
//! a directory never needs codec state to read back — the compression
//! layer's wire accounting happens at the protocol boundary, and scanned
//! entries report their actual on-disk byte size as `wire_bytes`. Both
//! v1 and raw v2 blobs decode on scan (see [`crate::tensor::codec`]).

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::{PushRequest, WeightEntry, WeightStore};
use crate::tensor::codec::{decode_blob, encode_blob, BlobMeta};
use crate::time::{Clock, RealClock};
use crate::util::hash::combine;

/// Weight store backed by a directory of blob files (sharable across OS
/// processes; see the module docs for the layout).
pub struct FsStore {
    root: PathBuf,
    /// Sequence counter; files from other processes are merged by mtime
    /// order at read time, so cross-process seq collisions are harmless.
    seq: AtomicU64,
    pushes: AtomicU64,
    /// Serializes directory scans (cheap; pushes stay concurrent).
    scan_lock: Mutex<()>,
    /// Handle-local monotone version: `(last observed state hash, counter)`.
    /// There is no cross-process notification on a plain directory, so the
    /// counter advances whenever a LIST observes a different hash — the
    /// mtime-watching analogue for a bucket prefix.
    change: Mutex<(u64, u64)>,
    /// Time domain for the `wait_for_change` backoff polling.
    clock: Arc<dyn Clock>,
}

impl FsStore {
    /// Open (creating if needed) a store rooted at `root` (change waits
    /// poll in real time).
    pub fn open<P: AsRef<Path>>(root: P) -> Result<Self> {
        FsStore::open_with_clock(root, RealClock::shared())
    }

    /// Like [`FsStore::open`], but the `wait_for_change` polling sleeps
    /// in `clock`'s time domain — under a
    /// [`crate::time::VirtualClock`] the backoff consumes simulated
    /// time, so directory watching costs no real wall-clock.
    pub fn open_with_clock<P: AsRef<Path>>(root: P, clock: Arc<dyn Clock>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root).with_context(|| format!("mkdir {root:?}"))?;
        // resume the seq counter past any existing files
        let mut max_seq = 0;
        for f in fs::read_dir(&root)? {
            if let Some((_, seq)) = parse_name(&f?.path()) {
                max_seq = max_seq.max(seq);
            }
        }
        Ok(FsStore {
            root,
            seq: AtomicU64::new(max_seq),
            pushes: AtomicU64::new(0),
            scan_lock: Mutex::new(()),
            change: Mutex::new((0, 0)),
            clock,
        })
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn scan(&self) -> Result<Vec<WeightEntry>> {
        let _g = self.scan_lock.lock().unwrap();
        let mut out = Vec::new();
        for f in fs::read_dir(&self.root)? {
            let path = f?.path();
            let Some((_node, seq)) = parse_name(&path) else { continue };
            let bytes = match fs::read(&path) {
                Ok(b) => b,
                Err(_) => continue, // racing a concurrent rename; skip
            };
            // A torn/corrupt blob is skipped, not fatal — eventual
            // consistency, like listing a bucket mid-upload.
            if let Ok((meta, params)) = decode_blob(&bytes) {
                out.push(WeightEntry {
                    node_id: meta.node_id as usize,
                    round: meta.round,
                    epoch: meta.epoch,
                    n_examples: meta.n_examples,
                    seq,
                    // the file *is* the wire blob: its size is the
                    // entry's wire cost, whatever version wrote it
                    wire_bytes: bytes.len() as u64,
                    params: std::sync::Arc::new(params),
                });
            }
        }
        out.sort_by_key(|e| e.seq);
        Ok(out)
    }
}

fn parse_name(path: &Path) -> Option<(usize, u64)> {
    let name = path.file_name()?.to_str()?;
    let stem = name.strip_suffix(".flwr")?;
    let (n, s) = stem.split_once("_s")?;
    let node = n.strip_prefix('n')?.parse().ok()?;
    let seq = s.parse().ok()?;
    Some((node, seq))
}

impl WeightStore for FsStore {
    fn push(&self, req: PushRequest) -> Result<u64> {
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        let meta = BlobMeta {
            node_id: req.node_id as u32,
            round: req.round,
            epoch: req.epoch,
            n_examples: req.n_examples,
        };
        let blob = encode_blob(&meta, &req.params);
        let final_path = self.root.join(format!("n{}_s{}.flwr", req.node_id, seq));
        let tmp_path = self.root.join(format!(".tmp_n{}_s{}", req.node_id, seq));
        fs::write(&tmp_path, &blob).with_context(|| format!("write {tmp_path:?}"))?;
        fs::rename(&tmp_path, &final_path)
            .with_context(|| format!("rename to {final_path:?}"))?;
        self.pushes.fetch_add(1, Ordering::Relaxed);
        Ok(seq)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        let mut latest: std::collections::BTreeMap<usize, WeightEntry> = Default::default();
        for e in self.scan()? {
            match latest.get(&e.node_id) {
                Some(prev) if prev.seq >= e.seq => {}
                _ => {
                    latest.insert(e.node_id, e);
                }
            }
        }
        Ok(latest.into_values().collect())
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        Ok(self.scan()?.into_iter().filter(|e| e.round == round).collect())
    }

    fn state_hash(&self) -> Result<u64> {
        // hash filenames only — no blob reads, mirroring a LIST request
        let _g = self.scan_lock.lock().unwrap();
        let mut names: Vec<(usize, u64)> = Vec::new();
        for f in fs::read_dir(&self.root)? {
            if let Some(ns) = parse_name(&f?.path()) {
                names.push(ns);
            }
        }
        names.sort();
        let mut h = 0xfeed_f00d_u64;
        for (node, seq) in names {
            h = combine(h, (node as u64) << 48 | seq);
        }
        Ok(h)
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        Ok(self
            .scan()?
            .into_iter()
            .filter(|e| e.node_id == node_id)
            .max_by_key(|e| e.seq))
    }

    fn version(&self) -> Result<u64> {
        // Derive a handle-local monotone counter from the listing hash:
        // any observed change (our own pushes included, and foreign
        // processes') advances it exactly once.
        let h = self.state_hash()?;
        let mut g = self.change.lock().unwrap();
        if g.0 != h {
            g.0 = h;
            g.1 += 1;
        }
        Ok(g.1)
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        // No cross-process notification on a directory: poll the listing
        // with exponential backoff, bounded by the caller's timeout. The
        // backoff sleeps in the store's clock domain, so a virtual clock
        // turns the whole poll loop into simulated time.
        let start = self.clock.now();
        let mut backoff = Duration::from_micros(500);
        loop {
            let v = self.version()?;
            if v > since {
                return Ok(v);
            }
            let elapsed = self.clock.now().saturating_sub(start);
            if elapsed >= timeout {
                return Ok(v);
            }
            self.clock.sleep(backoff.min(timeout - elapsed));
            backoff = (backoff * 2).min(Duration::from_millis(20));
        }
    }

    fn push_count(&self) -> u64 {
        self.pushes.load(Ordering::Relaxed)
    }

    fn clear(&self) -> Result<()> {
        let _g = self.scan_lock.lock().unwrap();
        for f in fs::read_dir(&self.root)? {
            let p = f?.path();
            if parse_name(&p).is_some() {
                let _ = fs::remove_file(p);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::store::store_tests;
    use crate::tensor::FlatParams;

    fn tmp_store(tag: &str) -> (FsStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "fedless_fsstore_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        (FsStore::open(&dir).unwrap(), dir)
    }

    #[test]
    fn conformance() {
        let (s, dir) = tmp_store("conf");
        store_tests::conformance(&s);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn concurrent() {
        let (s, dir) = tmp_store("conc");
        store_tests::concurrent_pushes(Arc::new(s));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn subscription() {
        let (s, dir) = tmp_store("subs");
        store_tests::subscription(Arc::new(s));
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn foreign_handle_push_advances_version() {
        // Version is handle-local but must observe *other* handles'
        // writes to the shared directory (the cross-process case).
        let (a, dir) = tmp_store("foreign_ver");
        let b = FsStore::open(&dir).unwrap();
        let v = a.version().unwrap();
        b.push(store_tests::push_req(1, 0, 2.0)).unwrap();
        assert!(a.version().unwrap() > v);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn survives_reopen() {
        let (s, dir) = tmp_store("reopen");
        s.push(store_tests::push_req(2, 5, 9.0)).unwrap();
        drop(s);
        let s2 = FsStore::open(&dir).unwrap();
        let latest = s2.latest_per_node().unwrap();
        assert_eq!(latest.len(), 1);
        assert_eq!(latest[0].round, 5);
        // seq counter resumes: next push gets a higher seq
        let seq = s2.push(store_tests::push_req(2, 6, 1.0)).unwrap();
        assert!(seq > latest[0].seq);
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn ignores_corrupt_files() {
        let (s, dir) = tmp_store("corrupt");
        s.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        fs::write(dir.join("n9_s99.flwr"), b"not a blob").unwrap();
        let latest = s.latest_per_node().unwrap();
        assert_eq!(latest.len(), 1, "corrupt entry must be skipped");
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn two_handles_share_the_directory() {
        // Two FsStore handles on one root = two "processes" sharing a bucket.
        let (a, dir) = tmp_store("share");
        let b = FsStore::open(&dir).unwrap();
        a.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        b.push(store_tests::push_req(1, 0, 2.0)).unwrap();
        assert_eq!(a.latest_per_node().unwrap().len(), 2);
        assert_eq!(b.latest_per_node().unwrap().len(), 2);
        assert_eq!(a.state_hash().unwrap(), b.state_hash().unwrap());
        fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn large_payload_roundtrip() {
        let (s, dir) = tmp_store("large");
        let params = Arc::new(FlatParams((0..500_000).map(|i| i as f32).collect()));
        s.push(super::super::PushRequest::raw(0, 0, 0, 1, Arc::clone(&params))).unwrap();
        let latest = s.latest_per_node().unwrap();
        assert_eq!(latest[0].params.0, params.0);
        assert_eq!(
            latest[0].wire_bytes,
            crate::tensor::codec::raw_wire_bytes(500_000),
            "scanned entries report the on-disk blob size as wire cost"
        );
        fs::remove_dir_all(dir).unwrap();
    }
}
