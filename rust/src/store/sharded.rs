//! Sharded in-process weight store — the scalable backend for many-node
//! trials and concurrent sweeps.
//!
//! [`super::MemoryStore`] serializes every operation behind one `RwLock`,
//! which is fine for 2–5 nodes but becomes the contention point at 8+
//! concurrent nodes (and across the sweep scheduler's parallel trials,
//! where many node threads hammer stores at once). `ShardedStore`
//! partitions the blob namespace by `node_id` across N independently
//! locked shards:
//!
//! * `push` from node k only takes shard `k % N`'s write lock — pushes
//!   from different nodes proceed in parallel;
//! * the store-wide sequence counter stays a single atomic (uncontended
//!   fetch-add), so `seq` ordering is still global and strictly
//!   increasing, as the [`super::WeightStore`] contract requires;
//! * read operations (`latest_per_node`, `entries_for_round`,
//!   `state_hash`) take the shard read locks one at a time and merge,
//!   so a reader never blocks more than one shard's writers at once.
//!
//! The merged [`WeightStore::state_hash`] combines per-shard partial
//! hashes in shard order; like every store, it changes whenever an entry
//! is added, which is all Algorithm 1's change detection needs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::Duration;

use anyhow::Result;

use super::{ChangeNotifier, EntryLog, PushRequest, WeightEntry, WeightStore};
use crate::util::hash::combine;

/// Default shard count: comfortably above the paper's node counts (2–5)
/// and the 8-node conformance stress test, while keeping the merge cost
/// of read operations trivial.
pub const DEFAULT_SHARDS: usize = 8;

/// In-process weight store partitioned by `node_id` across independently
/// locked shards. Drop-in replacement for [`super::MemoryStore`] wherever
/// push contention matters (8+ nodes, parallel sweep trials).
pub struct ShardedStore {
    shards: Vec<RwLock<EntryLog>>,
    seq: AtomicU64,
    pushes: AtomicU64,
    /// Store-wide change notification: one counter for all shards (the
    /// subscription API is a LIST-level signal, not per-shard), bumped
    /// after the owning shard's lock is released.
    notify: ChangeNotifier,
    /// Serializes conditional puts: `push_if_version` must check the
    /// store-wide version and insert atomically, which the per-shard
    /// locks alone cannot provide (two CAS writers may target different
    /// shards). Plain pushes never take this lock.
    cas_lock: Mutex<()>,
}

impl ShardedStore {
    /// Create a store with `n_shards` independently locked shards
    /// (change waits park in real time).
    pub fn new(n_shards: usize) -> Self {
        ShardedStore::with_notifier(n_shards, ChangeNotifier::default())
    }

    /// Like [`ShardedStore::new`], but change subscriptions park in
    /// `clock`'s time domain — pass the experiment's
    /// [`crate::time::VirtualClock`] so `wait_for_change` consumes
    /// simulated time.
    pub fn with_clock(n_shards: usize, clock: std::sync::Arc<dyn crate::time::Clock>) -> Self {
        ShardedStore::with_notifier(n_shards, ChangeNotifier::new(clock))
    }

    fn with_notifier(n_shards: usize, notify: ChangeNotifier) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        ShardedStore {
            shards: (0..n_shards).map(|_| RwLock::new(EntryLog::default())).collect(),
            seq: AtomicU64::new(0),
            pushes: AtomicU64::new(0),
            notify,
            cas_lock: Mutex::new(()),
        }
    }

    /// Number of shards this store was built with.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, node_id: usize) -> usize {
        node_id % self.shards.len()
    }
}

impl Default for ShardedStore {
    fn default() -> Self {
        ShardedStore::new(DEFAULT_SHARDS)
    }
}

impl WeightStore for ShardedStore {
    fn push(&self, req: PushRequest) -> Result<u64> {
        // Global ordering from one uncontended atomic; only the owning
        // shard's lock is taken, so pushes from different nodes run in
        // parallel.
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        let entry = WeightEntry {
            node_id: req.node_id,
            round: req.round,
            epoch: req.epoch,
            n_examples: req.n_examples,
            seq,
            wire_bytes: req.wire_bytes,
            params: req.params,
        };
        let shard = self.shard_of(entry.node_id);
        self.shards[shard].write().unwrap().push(entry);
        self.pushes.fetch_add(1, Ordering::Relaxed);
        self.notify.bump();
        Ok(seq)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        // O(nodes): merge the per-shard latest indexes (each maintained
        // on push) instead of scanning every shard's whole log.
        let mut latest: std::collections::BTreeMap<usize, WeightEntry> = Default::default();
        for shard in &self.shards {
            let inner = shard.read().unwrap();
            for (node, e) in inner.latest.iter() {
                latest.insert(*node, e.clone());
            }
        }
        Ok(latest.into_values().collect())
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let inner = shard.read().unwrap();
            out.extend(inner.log.iter().filter(|e| e.round == round).cloned());
        }
        // Deterministic order regardless of shard layout.
        out.sort_by_key(|e| e.seq);
        Ok(out)
    }

    fn state_hash(&self) -> Result<u64> {
        // Merge per-shard partial hashes in shard order. Entries carry
        // globally unique seqs, so any push changes its shard's partial
        // and therefore the merged hash.
        let mut h = 0xfeed_f00d_u64;
        for shard in &self.shards {
            let inner = shard.read().unwrap();
            let mut partial = 0x5A4D_ED51_u64;
            for e in inner.log.iter() {
                partial = combine(partial, (e.node_id as u64) << 48 | e.seq);
            }
            h = combine(h, partial);
        }
        Ok(h)
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        // A node's entries all live in one shard: single-lock indexed read.
        let shard = self.shards[self.shard_of(node_id)].read().unwrap();
        Ok(shard.latest.get(&node_id).cloned())
    }

    fn version(&self) -> Result<u64> {
        Ok(self.notify.version())
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        Ok(self.notify.wait_for_change(since, timeout))
    }

    fn push_count(&self) -> u64 {
        self.pushes.load(Ordering::Relaxed)
    }

    fn clear(&self) -> Result<()> {
        for shard in &self.shards {
            shard.write().unwrap().clear();
        }
        self.notify.bump();
        Ok(())
    }

    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // Hold the CAS lock across check + shard insert + bump: racing
        // CAS writers serialize here whatever shard they target, and the
        // loser observes the winner's bump. Plain pushes keep their
        // lock-free fast path (their entries carry pre-assigned lower
        // seqs, so a successful CAS never shadows newer state).
        let _cas = self.cas_lock.lock().unwrap();
        if self.notify.version() != expected {
            return Ok(None);
        }
        let seq = self.seq.fetch_add(1, Ordering::SeqCst) + 1;
        let shard = self.shard_of(req.node_id);
        self.shards[shard].write().unwrap().push(WeightEntry {
            node_id: req.node_id,
            round: req.round,
            epoch: req.epoch,
            n_examples: req.n_examples,
            seq,
            wire_bytes: req.wire_bytes,
            params: req.params,
        });
        self.pushes.fetch_add(1, Ordering::Relaxed);
        self.notify.bump();
        Ok(Some(seq))
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::store::store_tests::{self, push_req};

    #[test]
    fn conformance_single_shard() {
        store_tests::conformance(&ShardedStore::new(1));
    }

    #[test]
    fn conformance_default_shards() {
        store_tests::conformance(&ShardedStore::default());
    }

    #[test]
    fn conformance_more_shards_than_nodes() {
        store_tests::conformance(&ShardedStore::new(32));
    }

    #[test]
    fn concurrent() {
        store_tests::concurrent_pushes(Arc::new(ShardedStore::default()));
    }

    #[test]
    fn subscription() {
        store_tests::subscription(Arc::new(ShardedStore::default()));
    }

    #[test]
    fn latest_for_node_reads_only_its_shard() {
        let s = ShardedStore::new(4);
        for node in 0..8 {
            s.push(push_req(node, 0, node as f32)).unwrap();
            s.push(push_req(node, 1, 10.0 + node as f32)).unwrap();
        }
        let e = s.latest_for_node(6).unwrap().unwrap();
        assert_eq!(e.round, 1);
        assert_eq!(e.params.0[0], 16.0);
        assert!(s.latest_for_node(9).unwrap().is_none());
    }

    #[test]
    fn concurrent_with_colliding_shards() {
        // 8 nodes onto 3 shards: several nodes share each lock, global
        // seq/count invariants must still hold.
        store_tests::concurrent_pushes(Arc::new(ShardedStore::new(3)));
    }

    #[test]
    fn entries_land_in_expected_shard() {
        let s = ShardedStore::new(4);
        for node in 0..8 {
            s.push(push_req(node, 0, node as f32)).unwrap();
        }
        for (i, shard) in s.shards.iter().enumerate() {
            let inner = shard.read().unwrap();
            assert_eq!(inner.log.len(), 2, "shard {i}");
            for e in inner.log.iter() {
                assert_eq!(e.node_id % 4, i);
            }
            assert_eq!(inner.latest.len(), 2, "shard {i} latest index");
        }
    }

    #[test]
    fn merged_hash_sees_every_shard() {
        // A push into any shard must change the merged hash.
        let s = ShardedStore::new(4);
        let mut last = s.state_hash().unwrap();
        for node in 0..4 {
            s.push(push_req(node, 0, 1.0)).unwrap();
            let h = s.state_hash().unwrap();
            assert_ne!(h, last, "push into shard {node} must change hash");
            last = h;
        }
    }

    #[test]
    fn round_entries_sorted_by_seq() {
        let s = ShardedStore::new(4);
        // interleave pushes so shard iteration order != seq order
        s.push(push_req(3, 0, 1.0)).unwrap();
        s.push(push_req(0, 0, 2.0)).unwrap();
        s.push(push_req(2, 0, 3.0)).unwrap();
        s.push(push_req(1, 0, 4.0)).unwrap();
        let seqs: Vec<u64> = s.entries_for_round(0).unwrap().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![1, 2, 3, 4]);
    }

    #[test]
    fn latest_index_matches_full_log_scan_per_shard() {
        // fewer shards than nodes: colliding shards must still keep
        // exact per-node indexes
        store_tests::latest_index_matches_scan(&ShardedStore::new(3));
        store_tests::latest_index_matches_scan(&ShardedStore::new(1));
    }

    #[test]
    fn cas_conformance() {
        store_tests::cas_conformance(&ShardedStore::default());
        store_tests::cas_conformance(&ShardedStore::new(1));
    }

    #[test]
    fn cas_lost_update_across_shards() {
        // racing writers land in different shards; the store-wide
        // version check must still admit exactly one
        store_tests::cas_lost_update(Arc::new(ShardedStore::new(4)));
    }

    #[test]
    fn seq_is_globally_monotonic_across_shards() {
        let s = ShardedStore::new(2);
        let a = s.push(push_req(0, 0, 1.0)).unwrap();
        let b = s.push(push_req(1, 0, 1.0)).unwrap();
        let c = s.push(push_req(0, 1, 1.0)).unwrap();
        assert!(a < b && b < c);
    }
}
