//! [`TaskClock`] — the event executor's time source.
//!
//! The [`crate::time::VirtualClock`] advances simulated time by
//! negotiation: it waits until every registered participant *thread* is
//! blocked, then jumps to the earliest deadline. With one task per
//! client that negotiation is pure overhead — the executor already knows
//! the next deadline, because it owns the event queue. `TaskClock` is
//! the degenerate clock for that world: `set` is called by the executor
//! between task steps, and the blocking primitives never block — a
//! `sleep` advances time inline and a condition wait charges its full
//! timeout, exactly the zero-participant semantics the virtual clock
//! documents ("with zero registered participants any blocking call
//! advances immediately").
//!
//! The inline-advance semantics are also why `TaskClock` is *not* run
//! through `clock_tests::conformance`: that suite asserts a parked
//! waiter wakes on a peer thread's notify, which presumes blocking
//! primitives. `TaskClock` has no waiters by construction — protocols
//! running under the executor return [`crate::protocol::EpochStep::Wait`]
//! instead of touching a condition, and the executor turns that into a
//! queued deadline. The unit tests below pin the semantics it does have.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::time::{Clock, Condition};

/// Duration → nanos as u64 (u64 holds ~584 years of nanoseconds; every
/// simulated duration in the stack is far below that).
fn nanos(d: Duration) -> u64 {
    d.as_nanos() as u64
}

/// A clock whose time is set by the [`super::EventExecutor`] between
/// task steps. See the module docs for why its blocking primitives
/// advance time inline instead of parking.
pub struct TaskClock {
    now_ns: Arc<AtomicU64>,
}

impl TaskClock {
    /// A task clock at origin zero.
    pub fn new() -> TaskClock {
        TaskClock { now_ns: Arc::new(AtomicU64::new(0)) }
    }

    /// Set the current simulated instant. Executor-only: between task
    /// steps this may move *backward* (the heap dispatches by deadline,
    /// and a task seeded earlier can be stepped after a later one
    /// finishes), which is fine because no task ever observes another
    /// task's instants — `now()` is only read inside a step, where it is
    /// monotone.
    pub fn set(&self, t: Duration) {
        self.now_ns.store(nanos(t), Ordering::Relaxed);
    }
}

impl Default for TaskClock {
    fn default() -> Self {
        TaskClock::new()
    }
}

impl Clock for TaskClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns.load(Ordering::Relaxed))
    }

    fn sleep(&self, d: Duration) {
        // Inline advance: the sleeping task is the only runner, so the
        // sleep completes "immediately" at a later simulated instant.
        self.now_ns.fetch_add(nanos(d), Ordering::Relaxed);
    }

    fn condition(&self) -> Arc<dyn Condition> {
        Arc::new(TaskCondition {
            now_ns: Arc::clone(&self.now_ns),
            epoch: AtomicU64::new(0),
        })
    }

    fn enter(&self) {}

    fn exit(&self) {}
}

/// Condition in [`TaskClock`] time: an un-notified wait charges its full
/// timeout inline (zero-participant semantics); a stale token returns
/// immediately. Protocols under the executor never reach this path —
/// they return `EpochStep::Wait` — but stores built on the clock
/// ([`crate::store::WeightStore::wait_for_change`]) do, and must not
/// deadlock the single-threaded loop.
struct TaskCondition {
    now_ns: Arc<AtomicU64>,
    epoch: AtomicU64,
}

impl Condition for TaskCondition {
    fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    fn wait_past(&self, seen: u64, timeout: Duration) {
        if self.epoch.load(Ordering::SeqCst) > seen {
            return; // pre-wait notify: not lost
        }
        // No other runner can notify while this task holds the thread:
        // ride out the timeout in simulated time and return.
        self.now_ns.fetch_add(nanos(timeout), Ordering::Relaxed);
    }

    fn notify_all(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_and_now_round_trip() {
        let clock = TaskClock::new();
        assert_eq!(clock.now(), Duration::ZERO);
        clock.set(Duration::from_millis(1500));
        assert_eq!(clock.now(), Duration::from_millis(1500));
        // executor may rewind between steps
        clock.set(Duration::from_millis(200));
        assert_eq!(clock.now(), Duration::from_millis(200));
    }

    #[test]
    fn sleep_advances_inline() {
        let clock = TaskClock::new();
        clock.set(Duration::from_secs(1));
        clock.sleep(Duration::from_millis(250));
        assert_eq!(clock.now(), Duration::from_millis(1250));
        clock.sleep(Duration::ZERO);
        assert_eq!(clock.now(), Duration::from_millis(1250));
    }

    #[test]
    fn condition_charges_timeout_unless_pre_notified() {
        let clock = TaskClock::new();
        let cond = clock.condition();
        let tok = cond.epoch();

        // un-notified wait consumes its full timeout of simulated time
        cond.wait_past(tok, Duration::from_millis(40));
        assert_eq!(clock.now(), Duration::from_millis(40));

        // a notify before the wait returns immediately (token protocol)
        let tok = cond.epoch();
        cond.notify_all();
        cond.wait_past(tok, Duration::from_secs(60));
        assert_eq!(clock.now(), Duration::from_millis(40), "no time charged");
        assert_eq!(cond.epoch(), tok + 1);
    }

    #[test]
    fn participant_slots_are_no_ops() {
        let clock = TaskClock::new();
        clock.enter();
        clock.attach();
        clock.sleep(Duration::from_millis(5));
        clock.detach();
        clock.exit();
        assert_eq!(clock.now(), Duration::from_millis(5));
    }
}
