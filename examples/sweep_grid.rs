//! Sweep: reproduce a paper-style table in one call.
//!
//! Runs a sync-vs-async × FedAvg/FedAvgM grid (2 seeds per cell, 8 trials
//! total) on the work-stealing sweep scheduler and prints the aggregated
//! mean ± std table — the programmatic twin of:
//!
//! ```sh
//! cargo run --release --bin fedbench -- sweep examples/sweep_small.json
//! ```
//!
//! ```sh
//! make artifacts && cargo run --release --example sweep_grid
//! ```

use fedless::sweep::{run_sweep, SweepSpec};

fn main() -> anyhow::Result<()> {
    let spec = SweepSpec::parse_json(
        r#"{
            "model": "mnist",
            "modes": ["sync", "async"],
            "strategies": ["fedavg", "fedavgm"],
            "skews": 0.9,
            "n_nodes": 2,
            "trials": 2,
            "epochs": 2,
            "steps_per_epoch": 25,
            "train_size": 2000,
            "test_size": 320,
            "store": "sharded",
            "jobs": 4
        }"#,
    )?;

    println!(
        "running {} cells x {} seeds = {} trials on up to {} workers...\n",
        spec.cells().len(),
        spec.seeds.len(),
        spec.n_trials(),
        if spec.jobs == 0 { fedless::sweep::default_jobs() } else { spec.jobs },
    );

    let report = run_sweep(&spec)?;
    println!("{}", report.to_markdown());
    println!("csv:\n{}", report.to_csv());
    Ok(())
}
