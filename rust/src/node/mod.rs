//! Federated nodes — the serverless clients.
//!
//! A node's lifecycle is one [`NodeRunner`] state machine, driven by
//! either scheduler: under `scheduler = threads` (the default) each node
//! runs on its own OS thread with an isolated PJRT engine (the paper
//! simulated clients with Python threads; real threads + isolated
//! runtimes are strictly closer to independent processes, §5); under
//! `scheduler = events` the same machines are stepped by the
//! [`crate::sched::EventExecutor`] on one thread, which is how trials
//! scale to 10k clients. A node:
//!
//! 1. trains `steps_per_epoch` local steps via the AOT train artifact,
//! 2. federates through the weight store by calling its
//!    [`crate::protocol::FederationProtocol`] (sync barrier, async
//!    Algorithm 1, gossip, or the local baseline — resolved from
//!    `cfg.mode`), aggregating **client-side** with its own
//!    [`crate::strategy::Strategy`] instance,
//! 3. repeats for `epochs`, then reports its final weights.
//!
//! Most callers go through [`crate::sim::run_experiment`], which spawns
//! one node per data shard and collects the [`NodeReport`]s:
//!
//! ```no_run
//! use fedless::config::ExperimentConfig;
//! use fedless::node::NodeStatus;
//! use fedless::sim::run_experiment;
//!
//! let result = run_experiment(&ExperimentConfig::default()).unwrap();
//! for report in &result.reports {
//!     assert_eq!(report.status, NodeStatus::Completed);
//!     println!(
//!         "node {}: {} epochs, {} aggregations, idle {:.0}%",
//!         report.node_id,
//!         report.epochs_done,
//!         report.aggregations,
//!         100.0 * report.timeline.idle_fraction(),
//!     );
//! }
//! ```
//!
//! Driving nodes directly (custom orchestration) means building a
//! [`NodeCtx`] per node — shared store, shared start barrier, per-node
//! data shard — and calling [`spawn_node`]; see `sim/experiment.rs` for
//! the canonical wiring.

mod runner;
mod worker;

pub use runner::NodeRunner;
pub use worker::{spawn_node, NodeCtx};

use std::time::Duration;

use crate::metrics::timeline::Timeline;
use crate::tensor::FlatParams;

/// Why a node finished.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    /// Ran all epochs.
    Completed,
    /// Injected crash (failure experiments).
    Crashed {
        /// The 0-based epoch at which the crash was injected.
        at_epoch: usize,
    },
    /// Sync barrier timed out waiting for peers (e.g. a peer crashed —
    /// the paper's "in synchronous training, the other nodes are stuck").
    Stalled {
        /// The round whose barrier the node gave up on.
        at_round: u64,
    },
    /// Runtime error.
    Failed(String),
}

/// Everything a node thread reports back to the experiment driver.
#[derive(Debug)]
pub struct NodeReport {
    /// Which node this report came from.
    pub node_id: usize,
    /// How the node finished.
    pub status: NodeStatus,
    /// Completed local epochs.
    pub epochs_done: usize,
    /// Final local weights (after the last client-side aggregation).
    pub final_params: Option<FlatParams>,
    /// Examples this node trained on per epoch (n_k).
    pub n_examples_per_epoch: u64,
    /// Mean train loss per completed epoch.
    pub epoch_losses: Vec<f64>,
    /// Mean train accuracy per completed epoch.
    pub epoch_accs: Vec<f64>,
    /// Number of federated aggregations actually applied.
    pub aggregations: u64,
    /// Number of pushes to the weight store.
    pub pushes: u64,
    /// Wall-clock the node spent in each phase.
    pub timeline: Timeline,
    /// Total time spent in local training steps.
    pub train_time: Duration,
    /// Total time spent blocked on the sync barrier.
    pub wait_time: Duration,
    /// Transient store failures injected against this node (per-node
    /// [`crate::store::FaultStore`] under `fault` / `outage` config).
    pub injected_faults: u64,
    /// Store operations that failed transiently and were retried by the
    /// node's [`crate::store::RetryStore`] client.
    pub store_retries: u64,
    /// Store operations the retry client gave up on (attempts or
    /// deadline exhausted).
    pub store_give_ups: u64,
    /// Sync rounds this node closed degraded (quorum reached, full
    /// cohort not — `sync_quorum < 1`).
    pub degraded_rounds: u64,
    /// Crash–restart recoveries performed (`crash = n@e:restart:<s>`).
    pub restarts: u64,
}

/// Join handle + node id for a spawned node.
pub struct NodeHandle {
    /// Which node this handle joins.
    pub node_id: usize,
    /// The underlying OS thread handle.
    pub join: std::thread::JoinHandle<NodeReport>,
}

impl NodeHandle {
    /// Join the node thread; a panicked node yields a `Failed` report.
    pub fn wait(self) -> NodeReport {
        match self.join.join() {
            Ok(r) => r,
            Err(_) => NodeReport {
                node_id: self.node_id,
                status: NodeStatus::Failed("node thread panicked".into()),
                epochs_done: 0,
                final_params: None,
                n_examples_per_epoch: 0,
                epoch_losses: vec![],
                epoch_accs: vec![],
                aggregations: 0,
                pushes: 0,
                timeline: Timeline::new(self.node_id),
                train_time: Duration::ZERO,
                wait_time: Duration::ZERO,
                injected_faults: 0,
                store_retries: 0,
                store_give_ups: 0,
                degraded_rounds: 0,
                restarts: 0,
            },
        }
    }
}
