//! [`Raw`] — the bit-exact passthrough codec (codec id 0).

use anyhow::Result;

use crate::par::ChunkPool;
use crate::tensor::codec::{decode_raw_payload, extend_f32s_le};
use crate::tensor::FlatParams;

use super::{Codec, CodecKind};

/// Identity codec: the payload is the little-endian f32 bytes, exactly
/// as the v1 blob format stores them. Zero reconstruction error, zero
/// compression — the baseline every lossy codec is measured against.
/// Pure memcpy, so the pool is unused (and `compress = none` pushes
/// skip this codec entirely via the v1 fast path).
pub struct Raw;

impl Codec for Raw {
    fn kind(&self) -> CodecKind {
        CodecKind::None
    }

    fn encode_pooled(
        &self,
        params: &FlatParams,
        _base: Option<&FlatParams>,
        _pool: ChunkPool,
    ) -> Vec<u8> {
        let mut out = Vec::with_capacity(params.len() * 4);
        extend_f32s_le(&mut out, params.as_slice());
        out
    }

    fn decode_pooled(
        &self,
        payload: &[u8],
        n: usize,
        _base: Option<&FlatParams>,
        _pool: ChunkPool,
    ) -> Result<FlatParams> {
        decode_raw_payload(payload, n)
    }

    fn error_bound(&self, _params: &FlatParams, _base: Option<&FlatParams>) -> f32 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_exact_round_trip() {
        let p = FlatParams(vec![1.0, -2.5, f32::MIN_POSITIVE, 1e30, -0.0]);
        let enc = Raw.encode(&p, None);
        assert_eq!(enc.len(), p.len() * 4);
        let dec = Raw.decode(&enc, p.len(), None).unwrap();
        // bit-exact, including the sign of -0.0
        for (a, b) in p.0.iter().zip(dec.0.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn wrong_length_is_an_error() {
        let p = FlatParams(vec![1.0; 4]);
        let enc = Raw.encode(&p, None);
        assert!(Raw.decode(&enc, 3, None).is_err());
        assert!(Raw.decode(&enc[..15], 4, None).is_err());
    }
}
