//! Small self-contained substrates: deterministic RNG, a minimal JSON
//! parser (for `artifacts/manifest.json` — the image has no serde), and a
//! fast non-cryptographic hash used for weight-store state hashes and blob
//! integrity checks.

pub mod hash;
pub mod json;
pub mod rng;
pub mod simd;

pub use hash::fnv1a64;
pub use rng::Rng;
