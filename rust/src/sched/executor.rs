//! [`EventExecutor`] — the discrete-event loop that replaces
//! thread-per-node.
//!
//! Each node becomes a resumable [`Task`]; the executor owns a min-heap
//! of `(deadline, task)` events and steps exactly one task at a time,
//! setting the shared [`TaskClock`] to the event's instant first. A step
//! that returns [`StepOutcome::Wait`] parks its task until the weight
//! store's version moves past the step's token (the same
//! lost-wakeup-free subscription protocol the threaded barrier uses) or
//! the timeout deadline fires — whichever comes first. Compute inside a
//! step takes zero simulated time; only [`crate::time::Clock::sleep`]
//! calls (which [`TaskClock`] advances inline) and wait timeouts move
//! the clock, exactly the [`crate::time::VirtualClock`] semantics.
//!
//! # Determinism
//!
//! Events are ordered by `(deadline, task id)` — ties dispatch in task-id
//! order — and every wake is scheduled at a deterministic instant (a
//! peer's push instant, or the timeout deadline), so the whole schedule
//! is a pure function of the tasks' behavior. That is strictly stronger
//! than the threaded path, where same-instant store operations race in
//! real time (the documented VirtualClock caveat); on scenarios with
//! distinct per-node delays the two paths produce bit-identical
//! timelines, which the conformance tests in `rust/tests/timing.rs` and
//! `rust/tests/determinism.rs` pin.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::store::WeightStore;

use super::TaskClock;

/// What a task's step asks the executor to do next.
#[derive(Debug)]
pub enum StepOutcome {
    /// More work at the current instant: reschedule at the step's end
    /// time (which includes any inline clock sleeps the step made).
    Yield,
    /// Park until the store version exceeds `since` or `timeout` of
    /// simulated time elapses — the executor-level twin of
    /// [`crate::protocol::EpochStep::Wait`].
    Wait {
        /// Store version token read before the blocked predicate check.
        since: u64,
        /// Deadline after which the task is re-polled regardless.
        timeout: Duration,
    },
    /// The task is finished and must not be stepped again.
    Done,
}

/// A resumable node: one `step` runs to the next suspension point.
/// Steps are infallible — a node that hits an internal error records a
/// failed status in its own report and returns [`StepOutcome::Done`],
/// mirroring how the threaded worker folds errors into the
/// [`crate::node::NodeReport`] instead of tearing down the experiment.
pub trait Task {
    /// Advance to the next suspension point.
    fn step(&mut self) -> StepOutcome;
}

/// A scheduled dispatch. Ordered by `(at, id, gen)` so the heap breaks
/// same-instant ties by task id — the deterministic dispatch order.
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Event {
    at: Duration,
    id: usize,
    gen: u64,
}

/// A parked task: the version token it is waiting past and when it
/// parked (its wake must never be scheduled before that instant).
struct Park {
    since: u64,
    parked_at: Duration,
}

/// The single-threaded discrete-event scheduler. Owns the clock it sets
/// and the store whose version token drives wake-ups.
pub struct EventExecutor {
    clock: Arc<TaskClock>,
    store: Arc<dyn WeightStore>,
}

impl EventExecutor {
    /// An executor over `clock` and `store`; tasks must use the same
    /// clock for their timestamps and the same store for federation, or
    /// wake-ups and timelines will not line up.
    pub fn new(clock: Arc<TaskClock>, store: Arc<dyn WeightStore>) -> EventExecutor {
        EventExecutor { clock, store }
    }

    /// Run every task to completion. Only store `version()` errors
    /// propagate; task-internal failures surface through the tasks' own
    /// reports (see [`Task`]).
    pub fn run(&self, tasks: &mut [&mut dyn Task]) -> Result<()> {
        let n = tasks.len();
        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::with_capacity(n * 2);
        // Per-task generation counter: every (re)schedule bumps it, and
        // an event carrying a stale generation is a cancelled timeout or
        // superseded wake — skipped on pop. This is how a wake-up
        // invalidates the pending timeout event without heap surgery.
        let mut gen = vec![0u64; n];
        let mut parked: Vec<Option<Park>> = (0..n).map(|_| None).collect();
        let mut done = vec![false; n];
        // Latest instant any task reached; the clock lands here at exit
        // so the driver's wall_clock reads the trial's simulated length.
        let mut end_max = Duration::ZERO;

        // All tasks start at t = 0 (the threaded path's start barrier),
        // seeded in id order.
        for (id, g) in gen.iter().enumerate() {
            heap.push(Reverse(Event { at: Duration::ZERO, id, gen: *g }));
        }

        while let Some(Reverse(ev)) = heap.pop() {
            if done[ev.id] || ev.gen != gen[ev.id] {
                continue; // cancelled timeout / superseded wake
            }
            parked[ev.id] = None;
            self.clock.set(ev.at);
            let outcome = tasks[ev.id].step();
            // inline sleeps advanced the clock; this is the step's end
            let t_end = self.clock.now();
            end_max = end_max.max(t_end);
            gen[ev.id] += 1;
            match outcome {
                StepOutcome::Yield => {
                    heap.push(Reverse(Event { at: t_end, id: ev.id, gen: gen[ev.id] }));
                }
                StepOutcome::Wait { since, timeout } => {
                    parked[ev.id] = Some(Park { since, parked_at: t_end });
                    heap.push(Reverse(Event {
                        at: t_end + timeout,
                        id: ev.id,
                        gen: gen[ev.id],
                    }));
                }
                StepOutcome::Done => done[ev.id] = true,
            }

            // Wake pass: if this step advanced the store, re-poll every
            // parked task whose token it passed — at the notifying
            // step's end instant, the exact moment a threaded waiter's
            // condvar would have fired.
            let version = self.store.version()?;
            for (pid, slot) in parked.iter_mut().enumerate() {
                let wake = matches!(slot, Some(p) if version > p.since);
                if wake {
                    let p = slot.take().expect("checked Some above");
                    gen[pid] += 1;
                    heap.push(Reverse(Event {
                        at: t_end.max(p.parked_at),
                        id: pid,
                        gen: gen[pid],
                    }));
                }
            }
        }
        self.clock.set(end_max);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use std::cell::RefCell;
    use std::rc::Rc;

    use crate::store::{MemoryStore, PushRequest};
    use crate::tensor::FlatParams;
    use crate::time::Clock;

    use super::*;

    /// Script-driven test task: each entry is one step — an action run
    /// against the clock/store plus the outcome to return.
    struct Scripted<F: FnMut(usize) -> StepOutcome> {
        step_no: usize,
        f: F,
    }

    impl<F: FnMut(usize) -> StepOutcome> Task for Scripted<F> {
        fn step(&mut self) -> StepOutcome {
            let n = self.step_no;
            self.step_no += 1;
            (self.f)(n)
        }
    }

    fn scripted<F: FnMut(usize) -> StepOutcome>(f: F) -> Scripted<F> {
        Scripted { step_no: 0, f }
    }

    fn push(store: &Arc<dyn WeightStore>, node: usize) {
        store
            .push(PushRequest::raw(node, 0, 0, 100, Arc::new(FlatParams(vec![1.0; 4]))))
            .unwrap();
    }

    #[test]
    fn dispatches_in_deadline_order_with_id_tie_break() {
        let clock = Arc::new(TaskClock::new());
        let store: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let log: Rc<RefCell<Vec<(usize, u64)>>> = Rc::new(RefCell::new(vec![]));

        // task 0 sleeps 30ms/step, task 1 sleeps 10ms/step, 2 steps each;
        // expected instants: t0 steps at 0,30; t1 at 0,10. Seeding and
        // ties are id-ordered: (0,0) (1,0) (1,10) (0,30).
        let mk = |id: usize, ms: u64| {
            let clock = Arc::clone(&clock);
            let log = Rc::clone(&log);
            scripted(move |n| {
                log.borrow_mut().push((id, clock.now().as_millis() as u64));
                if n < 2 {
                    clock.sleep(Duration::from_millis(ms));
                    StepOutcome::Yield
                } else {
                    StepOutcome::Done
                }
            })
        };
        let mut t0 = mk(0, 30);
        let mut t1 = mk(1, 10);
        EventExecutor::new(Arc::clone(&clock), store)
            .run(&mut [&mut t0, &mut t1])
            .unwrap();
        assert_eq!(
            *log.borrow(),
            vec![(0, 0), (1, 0), (1, 10), (1, 20), (0, 30), (0, 60)],
        );
        // clock lands on the trial's end: task 0's last step at 60ms
        assert_eq!(clock.now(), Duration::from_millis(60));
    }

    #[test]
    fn wait_wakes_on_peer_push_at_the_push_instant() {
        let clock = Arc::new(TaskClock::new());
        let store: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let woken_at: Rc<RefCell<Vec<u64>>> = Rc::new(RefCell::new(vec![]));

        // waiter: parks immediately with a long timeout, records when it
        // is re-polled
        let mut waiter = {
            let clock = Arc::clone(&clock);
            let store = Arc::clone(&store);
            let woken_at = Rc::clone(&woken_at);
            scripted(move |n| {
                if n == 0 {
                    let since = store.version().unwrap();
                    StepOutcome::Wait { since, timeout: Duration::from_secs(60) }
                } else {
                    woken_at.borrow_mut().push(clock.now().as_millis() as u64);
                    StepOutcome::Done
                }
            })
        };
        // pusher: sleeps 30ms, pushes, finishes
        let mut pusher = {
            let clock = Arc::clone(&clock);
            let store = Arc::clone(&store);
            scripted(move |_| {
                clock.sleep(Duration::from_millis(30));
                push(&store, 1);
                StepOutcome::Done
            })
        };
        EventExecutor::new(Arc::clone(&clock), Arc::clone(&store))
            .run(&mut [&mut waiter, &mut pusher])
            .unwrap();
        assert_eq!(*woken_at.borrow(), vec![30], "woken at the push instant");
    }

    #[test]
    fn wait_times_out_at_the_deadline_without_a_push() {
        let clock = Arc::new(TaskClock::new());
        let store: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let polls: Rc<RefCell<Vec<u64>>> = Rc::new(RefCell::new(vec![]));

        let mut waiter = {
            let clock = Arc::clone(&clock);
            let store = Arc::clone(&store);
            let polls = Rc::clone(&polls);
            scripted(move |n| {
                polls.borrow_mut().push(clock.now().as_millis() as u64);
                if n == 0 {
                    let since = store.version().unwrap();
                    StepOutcome::Wait { since, timeout: Duration::from_millis(50) }
                } else {
                    StepOutcome::Done
                }
            })
        };
        EventExecutor::new(Arc::clone(&clock), store).run(&mut [&mut waiter]).unwrap();
        assert_eq!(*polls.borrow(), vec![0, 50], "re-polled exactly at the deadline");
        assert_eq!(clock.now(), Duration::from_millis(50));
    }

    #[test]
    fn a_wake_cancels_the_pending_timeout_event() {
        let clock = Arc::new(TaskClock::new());
        let store: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let steps: Rc<RefCell<u32>> = Rc::new(RefCell::new(0));

        // waiter parks with a 40ms timeout but a peer pushes at 10ms; the
        // stale 40ms timeout event must NOT produce a third step.
        let mut waiter = {
            let store = Arc::clone(&store);
            let steps = Rc::clone(&steps);
            scripted(move |n| {
                *steps.borrow_mut() += 1;
                if n == 0 {
                    let since = store.version().unwrap();
                    StepOutcome::Wait { since, timeout: Duration::from_millis(40) }
                } else {
                    StepOutcome::Done
                }
            })
        };
        let mut pusher = {
            let clock = Arc::clone(&clock);
            let store = Arc::clone(&store);
            scripted(move |_| {
                clock.sleep(Duration::from_millis(10));
                push(&store, 1);
                StepOutcome::Done
            })
        };
        EventExecutor::new(Arc::clone(&clock), Arc::clone(&store))
            .run(&mut [&mut waiter, &mut pusher])
            .unwrap();
        assert_eq!(*steps.borrow(), 2, "park step + wake step, no timeout replay");
    }

    #[test]
    fn many_tasks_complete_and_the_schedule_replays() {
        // 64 tasks with distinct delays: the dispatch log must replay
        // bit-identically run-to-run (pure function of the task set).
        let run = || {
            let clock = Arc::new(TaskClock::new());
            let store: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
            let log: Rc<RefCell<Vec<(usize, u64)>>> = Rc::new(RefCell::new(vec![]));
            let mut tasks: Vec<_> = (0..64)
                .map(|id| {
                    let clock = Arc::clone(&clock);
                    let log = Rc::clone(&log);
                    scripted(move |n| {
                        log.borrow_mut().push((id, clock.now().as_millis() as u64));
                        if n < 3 {
                            clock.sleep(Duration::from_millis(1 + id as u64 * 7));
                            StepOutcome::Yield
                        } else {
                            StepOutcome::Done
                        }
                    })
                })
                .collect();
            let mut refs: Vec<&mut dyn Task> =
                tasks.iter_mut().map(|t| t as &mut dyn Task).collect();
            EventExecutor::new(Arc::clone(&clock), store).run(&mut refs).unwrap();
            (log.borrow().clone(), clock.now())
        };
        let (log_a, end_a) = run();
        let (log_b, end_b) = run();
        assert_eq!(log_a.len(), 64 * 4, "every task stepped to completion");
        assert_eq!(log_a, log_b, "deterministic schedule");
        assert_eq!(end_a, end_b);
        // slowest task: 3 sleeps of (1 + 63*7) = 442ms
        assert_eq!(end_a, Duration::from_millis(3 * 442));
    }
}
