"""Pallas kernel: fused Adam/AdamW update on the flat parameter vector.

One pass over (p, m, v, g) per tile instead of the ~10 elementwise HLO ops an
unfused Adam emits — on TPU this is the difference between one HBM round trip
per tensor and several. Bias correction is folded into a scalar ``lr_t``
computed *outside* the kernel (it depends only on the step counter), so the
kernel body is pure elementwise VPU work.

update:  m' = b1*m + (1-b1)*g
         v' = b2*v + (1-b2)*g^2
         p' = p - lr_t * m' / (sqrt(v') + eps) - lr * wd * p
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 65536


def _adam_kernel(b1, b2, eps, lr, wd, p_ref, m_ref, v_ref, g_ref, s_ref,
                 po_ref, mo_ref, vo_ref):
    p = p_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    g = g_ref[...]
    lr_t = s_ref[0]  # bias-corrected step size, precomputed
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    upd = lr_t * m_new / (jnp.sqrt(v_new) + eps)
    if wd != 0.0:
        upd = upd + lr * wd * p
    po_ref[...] = p - upd
    mo_ref[...] = m_new
    vo_ref[...] = v_new


@functools.partial(
    jax.jit,
    static_argnames=("lr", "b1", "b2", "eps", "weight_decay", "block_p"),
)
def fused_adam_step(
    params: jax.Array,
    m: jax.Array,
    v: jax.Array,
    grads: jax.Array,
    step: jax.Array,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    block_p: int = BLOCK_P,
):
    """Fused Adam(W) update over flat f32 vectors.

    Args:
      params, m, v, grads: f32[P] flat vectors.
      step: i32[] or f32[] — 1-based step counter *after* this update.

    Returns:
      (params', m', v') — each f32[P].
    """
    (p_len,) = params.shape
    pad = (-p_len) % block_p
    if pad:
        params = jnp.pad(params, (0, pad))
        m = jnp.pad(m, (0, pad))
        v = jnp.pad(v, (0, pad))
        grads = jnp.pad(grads, (0, pad))
    pp = p_len + pad

    t = step.astype(jnp.float32)
    lr_t = lr * jnp.sqrt(1.0 - b2**t) / (1.0 - b1**t)
    lr_t = lr_t.reshape(1)

    kern = functools.partial(_adam_kernel, b1, b2, eps, lr, weight_decay)
    vec = pl.BlockSpec((block_p,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    p2, m2, v2 = pl.pallas_call(
        kern,
        grid=(pp // block_p,),
        in_specs=[vec, vec, vec, vec, scalar],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((pp,), jnp.float32)] * 3,
        interpret=True,
    )(params, m, v, grads, lr_t)
    return p2[:p_len], m2[:p_len], v2[:p_len]
