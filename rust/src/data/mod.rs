//! Datasets, label-skew partitioning (paper §4.1), and batch loading.
//!
//! The paper trains on MNIST, CIFAR-10 and WikiText-103. This image is
//! offline, so we substitute *deterministic synthetic* datasets with the
//! same shapes and class structure (DESIGN.md §Substitutions): the
//! experiments measure *relative* effects (sync vs async, skew, node
//! count, strategy), which require class-structured data and controllable
//! label skew — not the original pixels.

pub mod loader;
pub mod partition;
pub mod synth;
pub mod text;

pub use loader::{Batch, BatchData, BatchLoader, DataSource};
pub use partition::Partitioner;
pub use synth::{DatasetKind, Split, SynthDataset};
pub use text::TextCorpus;
