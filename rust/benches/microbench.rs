//! Microbenchmarks of the coordinator hot paths (EXPERIMENTS.md §Perf):
//!
//! * client-side aggregation: pure-rust FMA loop vs the lowered L1 Pallas
//!   kernel via PJRT (per model size, K = 2/5)
//! * train-step latency per model artifact (the inner loop of every node)
//! * weight-store ops: memory vs fs push/pull at model sizes
//! * blob codec encode/decode
//!
//! Run: `cargo bench --offline` (or `cargo bench -- agg` etc. — the filter
//! is matched against bench names).

mod common;

use std::sync::Arc;

use common::{bench, gbps};
use fedless::data::{BatchLoader, DataSource, DatasetKind, Split, SynthDataset};
use fedless::runtime::{AggExecutor, Engine, Manifest, ModelBundle, TrainState};
use fedless::store::{FsStore, MemoryStore, PushRequest, WeightStore};
use fedless::tensor::codec::{decode_blob, encode_blob, BlobMeta};
use fedless::tensor::flat::weighted_average;
use fedless::tensor::FlatParams;
use fedless::util::Rng;

fn filter() -> Option<String> {
    // `cargo bench -- foo` puts "foo" in argv; also skip `--bench` flag.
    std::env::args().skip(1).find(|a| !a.starts_with("--"))
}

fn enabled(name: &str) -> bool {
    filter().map(|f| name.contains(&f)).unwrap_or(true)
}

fn random_params(rng: &mut Rng, n: usize) -> FlatParams {
    FlatParams((0..n).map(|_| rng.normal_f32()).collect())
}

fn bench_aggregation(manifest: &Manifest) {
    if !enabled("agg") {
        return;
    }
    println!("\n--- aggregation: rust FMA vs Pallas artifact (PJRT) ---");
    let engine = Engine::new().unwrap();
    let mut rng = Rng::new(1);
    for &(label, n) in
        &[("mnist-20k", 20_490usize), ("cifar-78k", 78_058), ("lm-470k", 470_528), ("14M", 14_000_000)]
    {
        for &k in &[2usize, 5] {
            let params: Vec<FlatParams> = (0..k).map(|_| random_params(&mut rng, n)).collect();
            let refs: Vec<&FlatParams> = params.iter().collect();
            let w = vec![1.0 / k as f32; k];
            let bytes = n * 4 * k;
            let iters = if n > 1_000_000 { 5 } else { 30 };

            let r = bench(&format!("agg/rust/{label}/k{k}"), 2, iters, || {
                std::hint::black_box(weighted_average(&refs, &w));
            });
            println!("{:>60}  ({:.2} GB/s read)", "", gbps(bytes, r.mean));

            if n <= 1_000_000 {
                let agg = AggExecutor::load(&engine, manifest, k).unwrap();
                let r = bench(&format!("agg/pallas-pjrt/{label}/k{k}"), 2, iters, || {
                    std::hint::black_box(agg.aggregate(&refs, &w).unwrap());
                });
                println!("{:>60}  ({:.2} GB/s read)", "", gbps(bytes, r.mean));
            }
        }
    }
}

fn bench_train_steps(manifest: &Manifest) {
    if !enabled("train") {
        return;
    }
    println!("\n--- train-step latency per artifact (batch in literal form) ---");
    let engine = Engine::new().unwrap();
    for model in ["mnist", "cifar", "lm"] {
        let Ok(info) = manifest.model(model) else { continue };
        let bundle = ModelBundle::load(&engine, info).unwrap();
        let mut state = TrainState::new(bundle.init_params(1).unwrap());
        let mut loader = match model {
            "lm" => {
                let corpus = Arc::new(fedless::data::TextCorpus::generate(3, 100_000));
                let seq = info.input_shape[0] - 1;
                let n = corpus.num_windows(seq);
                BatchLoader::new(DataSource::Text { corpus, seq_len: seq }, (0..n).collect(), info.batch_size, 7)
            }
            _ => {
                let kind = DatasetKind::parse(model).unwrap();
                let ds = Arc::new(SynthDataset::new(kind, 2, 2000, 100));
                BatchLoader::new(
                    DataSource::Image { ds, split: Split::Train },
                    (0..2000).collect(),
                    info.batch_size,
                    7,
                )
            }
        };
        let iters = if model == "cifar" { 10 } else { 20 };
        bench(&format!("train/{model}/step"), 3, iters, || {
            bundle.run_steps(&mut state, &mut loader, 1, |_, _| {}).unwrap();
        });
    }
}

fn bench_store() {
    if !enabled("store") {
        return;
    }
    println!("\n--- weight store ops (mnist-sized blobs, 20k f32) ---");
    let mut rng = Rng::new(3);
    let params = Arc::new(random_params(&mut rng, 20_490));
    let req = |node: usize| PushRequest::raw(node, 0, 0, 1, Arc::clone(&params));

    let mem = MemoryStore::new();
    bench("store/memory/push", 10, 200, || {
        mem.push(req(0)).unwrap();
    });
    for n in 0..5 {
        mem.push(req(n)).unwrap();
    }
    bench("store/memory/latest_per_node(5)", 10, 200, || {
        std::hint::black_box(mem.latest_per_node().unwrap());
    });
    bench("store/memory/state_hash", 10, 200, || {
        std::hint::black_box(mem.state_hash().unwrap());
    });

    let dir = std::env::temp_dir().join(format!("fedless_bench_fs_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let fs = FsStore::open(&dir).unwrap();
    bench("store/fs/push", 5, 50, || {
        fs.push(req(0)).unwrap();
    });
    fs.clear().unwrap();
    for n in 0..5 {
        fs.push(req(n)).unwrap();
    }
    bench("store/fs/latest_per_node(5)", 5, 30, || {
        std::hint::black_box(fs.latest_per_node().unwrap());
    });
    bench("store/fs/state_hash", 5, 100, || {
        std::hint::black_box(fs.state_hash().unwrap());
    });
    let _ = std::fs::remove_dir_all(&dir);
}

fn bench_data() {
    if !enabled("data") {
        return;
    }
    println!("\n--- data pipeline: batch materialization (feeds every train step) ---");
    for (label, kind) in [("mnist", DatasetKind::Mnist), ("cifar", DatasetKind::Cifar)] {
        let ds = Arc::new(SynthDataset::new(kind, 2, 4000, 100));
        let mut loader = BatchLoader::new(
            DataSource::Image { ds, split: Split::Train },
            (0..4000).collect(),
            32,
            7,
        );
        bench(&format!("data/{label}/batch32"), 5, 50, || {
            std::hint::black_box(loader.next_batch());
        });
    }
    let corpus = Arc::new(fedless::data::TextCorpus::generate(3, 500_000));
    let n = corpus.num_windows(64);
    let mut loader =
        BatchLoader::new(DataSource::Text { corpus, seq_len: 64 }, (0..n).collect(), 8, 7);
    bench("data/lm/batch8", 5, 100, || {
        std::hint::black_box(loader.next_batch());
    });
}

fn bench_codec() {
    if !enabled("codec") {
        return;
    }
    println!("\n--- blob codec (470k f32 = lm-sized) ---");
    let mut rng = Rng::new(4);
    let params = random_params(&mut rng, 470_528);
    let meta = BlobMeta { node_id: 0, round: 0, epoch: 0, n_examples: 1 };
    let bytes = params.len() * 4;
    let r = bench("codec/encode/470k", 3, 50, || {
        std::hint::black_box(encode_blob(&meta, &params));
    });
    println!("{:>60}  ({:.2} GB/s)", "", gbps(bytes, r.mean));
    let blob = encode_blob(&meta, &params);
    let r = bench("codec/decode/470k", 3, 50, || {
        std::hint::black_box(decode_blob(&blob).unwrap());
    });
    println!("{:>60}  ({:.2} GB/s)", "", gbps(bytes, r.mean));
}

fn main() {
    let manifest = Manifest::discover().expect("run `make artifacts` first");
    println!("fedless microbench — hot paths (see EXPERIMENTS.md §Perf)");
    bench_aggregation(&manifest);
    bench_train_steps(&manifest);
    bench_store();
    bench_data();
    bench_codec();
}
