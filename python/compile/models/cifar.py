"""CIFAR-10 ResNet-lite (paper §4.3 used ResNet-18).

A 3-stage pre-activation residual network (16/32/64 channels, one residual
block per stage) — the same architectural family as ResNet-18, scaled so an
AOT-compiled CPU train step stays fast enough for repeated federated trials.
BatchNorm is replaced by per-channel LayerNorm-style normalization, which is
stateless and therefore federates cleanly (no running statistics to merge —
a known practical issue when averaging BN models; see DESIGN.md
§Substitutions).
"""

import jax
import jax.numpy as jnp

from . import common as c

NUM_CLASSES = 10
INPUT_SHAPE = (32, 32, 3)
STAGES = (16, 32, 64)


def _norm_init(ch):
    return {"g": jnp.ones((ch,), jnp.float32), "b": jnp.zeros((ch,), jnp.float32)}


def _norm(p, x, eps=1e-5):
    # normalize over H, W per (batch, channel): stateless "instance norm"
    mu = jnp.mean(x, axis=(1, 2), keepdims=True)
    var = jnp.var(x, axis=(1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def _block_init(key, cin, cout):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "n1": _norm_init(cin),
        "c1": c.conv_init(k1, 3, 3, cin, cout),
        "n2": _norm_init(cout),
        "c2": c.conv_init(k2, 3, 3, cout, cout),
    }
    if cin != cout:
        p["proj"] = c.conv_init(k3, 1, 1, cin, cout)
    return p


def _block(p, x, stride):
    h = jax.nn.relu(_norm(p["n1"], x))
    h = c.conv2d(p["c1"], h, stride=stride)
    h = jax.nn.relu(_norm(p["n2"], h))
    h = c.conv2d(p["c2"], h)
    if "proj" in p:
        x = c.conv2d(p["proj"], x, stride=stride)
    return x + h


def init(key):
    keys = jax.random.split(key, len(STAGES) + 2)
    params = {"stem": c.conv_init(keys[0], 3, 3, 3, STAGES[0])}
    cin = STAGES[0]
    for i, cout in enumerate(STAGES):
        params[f"stage{i}"] = _block_init(keys[i + 1], cin, cout)
        cin = cout
    params["head"] = c.dense_init(keys[-1], STAGES[-1], NUM_CLASSES)
    return params


def apply(params, x, train=False):
    """x: f32[B, 32, 32, 3] -> logits f32[B, 10]."""
    del train
    h = c.conv2d(params["stem"], x)
    for i in range(len(STAGES)):
        stride = 1 if i == 0 else 2  # 32 -> 32 -> 16 -> 8
        h = _block(params[f"stage{i}"], h, stride)
    h = jax.nn.relu(h)
    h = c.avg_pool_global(h)
    return c.dense(params["head"], h)


def loss_and_metrics(params, batch, train=False):
    x, y = batch
    logits = apply(params, x, train)
    return c.softmax_xent(logits, y), c.accuracy_count(logits, y)
