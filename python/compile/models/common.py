"""Shared layer primitives for the model zoo (pure jnp, no framework).

Parameters are plain pytrees (nested dicts); initializers take an explicit
PRNG key. Dense layers optionally route through the L1 Pallas tiled matmul
so the kernel sits on the real train path of the lowered artifact.
"""

import jax
import jax.numpy as jnp

from ..kernels import tiled_matmul

# Toggled by aot.py / tests: when True, Dense goes through the Pallas kernel.
_USE_PALLAS = {"dense": False}


def set_pallas_dense(enabled: bool) -> None:
    """Route Dense matmuls through the L1 Pallas kernel (artifact default)."""
    _USE_PALLAS["dense"] = bool(enabled)


def _matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    if _USE_PALLAS["dense"]:
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        out = tiled_matmul(x2, w)
        return out.reshape(*shape[:-1], w.shape[1])
    return jnp.matmul(x, w)


# ----------------------------------------------------------------------------
# initializers


def glorot(key, shape):
    fan_in, fan_out = shape[-2], shape[-1]
    if len(shape) == 4:  # HWIO conv
        rf = shape[0] * shape[1]
        fan_in, fan_out = rf * shape[2], rf * shape[3]
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def normal(key, shape, stddev=0.02):
    return stddev * jax.random.normal(key, shape, jnp.float32)


# ----------------------------------------------------------------------------
# layers


def dense_init(key, in_dim, out_dim, bias=True):
    kw, _ = jax.random.split(key)
    p = {"w": glorot(kw, (in_dim, out_dim))}
    if bias:
        p["b"] = jnp.zeros((out_dim,), jnp.float32)
    return p


def dense(p, x):
    y = _matmul(x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def conv_init(key, kh, kw, cin, cout):
    return {
        "w": glorot(key, (kh, kw, cin, cout)),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def conv2d(p, x, stride=1, padding="SAME"):
    """NHWC conv; weights HWIO."""
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def max_pool(x, window=2, stride=2):
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, window, window, 1),
        (1, stride, stride, 1),
        "VALID",
    )


def avg_pool_global(x):
    return jnp.mean(x, axis=(1, 2))


def layernorm_init(dim):
    return {"g": jnp.ones((dim,), jnp.float32), "b": jnp.zeros((dim,), jnp.float32)}


def layernorm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


# ----------------------------------------------------------------------------
# losses / metrics


def softmax_xent(logits, labels):
    """Mean cross-entropy; logits [..., C], integer labels [...]."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def accuracy_count(logits, labels):
    """Number of correct argmax predictions (f32 scalar)."""
    pred = jnp.argmax(logits, axis=-1)
    return jnp.sum((pred == labels).astype(jnp.float32))
