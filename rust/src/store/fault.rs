//! Fault-injecting store wrapper: seeded transient errors on push/pull,
//! used by the robustness experiments (§4.2.1: "real world model training
//! jobs can be fragile") and by failure-handling tests.

use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Result};

use super::{PushRequest, WeightEntry, WeightStore};
use crate::util::Rng;

/// Wraps an inner store; each operation fails with probability `p_fail`.
pub struct FaultStore<S> {
    inner: S,
    p_fail: f64,
    rng: Mutex<Rng>,
    injected: std::sync::atomic::AtomicU64,
}

impl<S: WeightStore> FaultStore<S> {
    /// Wrap `inner`; each operation fails with probability `p_fail`,
    /// deterministically in `seed`.
    pub fn new(inner: S, p_fail: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p_fail));
        FaultStore {
            inner,
            p_fail,
            rng: Mutex::new(Rng::new(seed ^ 0xFA_17)),
            injected: Default::default(),
        }
    }

    /// Number of injected failures so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn maybe_fail(&self, op: &str) -> Result<()> {
        let roll = self.rng.lock().unwrap().chance(self.p_fail);
        if roll {
            self.injected.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            bail!("injected store failure during {op}");
        }
        Ok(())
    }
}

impl<S: WeightStore> WeightStore for FaultStore<S> {
    fn push(&self, req: PushRequest) -> Result<u64> {
        self.maybe_fail("push")?;
        self.inner.push(req)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        self.maybe_fail("latest_per_node")?;
        self.inner.latest_per_node()
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        self.maybe_fail("entries_for_round")?;
        self.inner.entries_for_round(round)
    }

    fn state_hash(&self) -> Result<u64> {
        self.maybe_fail("state_hash")?;
        self.inner.state_hash()
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        self.maybe_fail("latest_for_node")?;
        self.inner.latest_for_node(node_id)
    }

    fn version(&self) -> Result<u64> {
        // Never fault-injected: `version`/`wait_for_change` are the
        // barrier notification path, and a poll that "fails" would
        // desert it — the sync barrier reads `version` for its wake-up
        // token every lap, so an injected error here aborted the whole
        // node instead of simulating a flaky *data* operation. Faults
        // belong on the data reads/writes around the subscription
        // (push/pull/state_hash), which the protocols handle.
        self.inner.version()
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        // The wait itself is a local blocking primitive, not a remote
        // round-trip: faults are injected on the reads around it, so a
        // flaky store still delivers wake-ups (see `version`).
        self.inner.wait_for_change(since, timeout)
    }

    fn push_count(&self) -> u64 {
        self.inner.push_count()
    }

    fn clear(&self) -> Result<()> {
        self.inner.clear()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::store_tests;
    use crate::store::MemoryStore;

    #[test]
    fn p_zero_is_transparent() {
        let s = FaultStore::new(MemoryStore::new(), 0.0, 1);
        store_tests::conformance(&s);
        assert_eq!(s.injected(), 0);
    }

    #[test]
    fn p_one_always_fails() {
        let s = FaultStore::new(MemoryStore::new(), 1.0, 1);
        assert!(s.push(store_tests::push_req(0, 0, 1.0)).is_err());
        assert!(s.latest_per_node().is_err());
        assert!(s.state_hash().is_err());
        assert_eq!(s.injected(), 3);
    }

    /// Regression: the subscription path (`version`/`wait_for_change`)
    /// must never be fault-injected. A poll that "fails" deserts the
    /// barrier notification path — the sync barrier reads `version` for
    /// its wake-up token every lap, so an injected error there aborted
    /// the node instead of simulating a flaky data op.
    #[test]
    fn subscription_path_is_never_fault_injected() {
        use std::sync::Arc;
        use std::time::Instant;

        let inner: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let s = Arc::new(FaultStore::new(Arc::clone(&inner), 1.0, 1));

        // version succeeds even at p = 1 (everything else fails)
        let v0 = s.version().expect("version must never be injected");
        assert!(s.state_hash().is_err(), "data ops still fail at p = 1");

        // ...and a waiter parked through the faulty wrapper still gets
        // the wake-up when a peer's push lands on the shared inner store.
        let waiter = {
            let s = Arc::clone(&s);
            std::thread::spawn(move || {
                s.wait_for_change(v0, Duration::from_secs(20))
                    .expect("wait_for_change must never be injected")
            })
        };
        std::thread::sleep(Duration::from_millis(30));
        let t = Instant::now();
        inner.push(store_tests::push_req(1, 0, 2.0)).unwrap();
        let v = waiter.join().unwrap();
        assert!(v > v0, "waiter must observe the push through the faulty wrapper");
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "waiter must wake on the push, not ride out the timeout"
        );

        // a clean timeout is also not an error
        let v = s.wait_for_change(v, Duration::from_millis(20)).unwrap();
        assert_eq!(v, s.version().unwrap());
    }

    #[test]
    fn failure_rate_roughly_matches() {
        let s = FaultStore::new(MemoryStore::new(), 0.3, 7);
        let fails = (0..1000)
            .filter(|_| s.push(store_tests::push_req(0, 0, 1.0)).is_err())
            .count();
        assert!((200..400).contains(&fails), "fails={fails}");
    }
}
