//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, built once
//! by `make artifacts`) and executes them from the rust hot path.
//!
//! The `xla` crate's handles are `Rc`-based (not `Send`), so each node
//! thread owns its own [`Engine`] (PJRT CPU client) and compiles its own
//! executables from the shared HLO text — which also mirrors real federated
//! clients, each with an isolated runtime. HLO *text* is the interchange
//! format (see `python/compile/hlo.py` for why not serialized protos).

pub mod agg;
pub mod engine;
pub mod manifest;

pub use agg::AggExecutor;
pub use engine::{Engine, EvalStep, InitStep, ModelBundle, StepMetrics, TrainState, TrainStep};
pub use manifest::{Manifest, ModelInfo};
