//! [`FlatParams`] — a flat `f32` parameter vector with the small amount of
//! linear algebra the federation strategies need (axpy, scale, lerp).
//!
//! The aggregation entry points come in pairs: a plain sequential form
//! (`weighted_average`, `axpy`, `lerp`) and a `_pooled` form running the
//! same arithmetic chunk-parallel on a [`ChunkPool`]. Chunks are fixed
//! [`PAR_CHUNK`] elements wide and every element's FP operation sequence
//! is identical in both forms, so sequential and pooled results are
//! bit-identical for any thread count (the [`crate::par`] determinism
//! contract, pinned by `rust/tests/determinism.rs`).

use crate::par::ChunkPool;
use crate::util::hash::{chunked_hash_f32s, chunked_hash_f32s_pooled};

/// Fixed element width of one parallel work chunk (16 Ki f32 = 64 KiB).
/// A constant of the kernel, never a function of the thread count — the
/// boundary independence that makes pooled results bit-identical.
pub const PAR_CHUNK: usize = 16 * 1024;

/// A model's full parameter (or optimizer-moment) vector.
#[derive(Clone, Debug, PartialEq)]
pub struct FlatParams(
    /// The raw element storage.
    pub Vec<f32>,
);

impl FlatParams {
    /// An all-zero vector of length `n`.
    pub fn zeros(n: usize) -> Self {
        FlatParams(vec![0.0; n])
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.0.len()
    }

    /// True when the vector has no elements.
    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Borrow the elements as a slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    /// Content hash for in-memory change detection (the chunked
    /// word-at-a-time hash — [`crate::util::hash::chunked_hash_f32s`]).
    /// Never persisted; the on-disk blob formats keep their frozen
    /// FNV-1a integrity hash.
    pub fn content_hash(&self) -> u64 {
        chunked_hash_f32s(&self.0)
    }

    /// [`FlatParams::content_hash`] with per-chunk digests computed on
    /// `pool` (bit-identical for any thread count).
    pub fn content_hash_pooled(&self, pool: ChunkPool) -> u64 {
        chunked_hash_f32s_pooled(&self.0, pool)
    }

    /// `self += alpha * other` (fused multiply-add per element; part of
    /// the aggregation hot path — see benches/kernels.rs).
    pub fn axpy(&mut self, alpha: f32, other: &FlatParams) {
        self.axpy_pooled(alpha, other, ChunkPool::sequential());
    }

    /// [`FlatParams::axpy`] chunk-parallel on `pool`; same per-element
    /// FMA, so bit-identical to the sequential form.
    pub fn axpy_pooled(&mut self, alpha: f32, other: &FlatParams, pool: ChunkPool) {
        assert_eq!(self.len(), other.len(), "axpy length mismatch");
        let items: Vec<(&mut [f32], &[f32])> =
            self.0.chunks_mut(PAR_CHUNK).zip(other.0.chunks(PAR_CHUNK)).collect();
        pool.for_each(items, |_, (dst, src)| {
            for (a, b) in dst.iter_mut().zip(src) {
                *a = b.mul_add(alpha, *a);
            }
        });
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.0.iter_mut() {
            *a *= alpha;
        }
    }

    /// `self = (1 - t) * self + t * other` — the staleness-mixing update
    /// used by FedAsync.
    pub fn lerp(&mut self, t: f32, other: &FlatParams) {
        self.lerp_pooled(t, other, ChunkPool::sequential());
    }

    /// [`FlatParams::lerp`] chunk-parallel on `pool`; same per-element
    /// arithmetic, so bit-identical to the sequential form.
    pub fn lerp_pooled(&mut self, t: f32, other: &FlatParams, pool: ChunkPool) {
        assert_eq!(self.len(), other.len(), "lerp length mismatch");
        let items: Vec<(&mut [f32], &[f32])> =
            self.0.chunks_mut(PAR_CHUNK).zip(other.0.chunks(PAR_CHUNK)).collect();
        pool.for_each(items, |_, (dst, src)| {
            for (a, b) in dst.iter_mut().zip(src) {
                *a = *a + t * (*b - *a);
            }
        });
    }

    /// Element-wise difference `other - self` (pseudo-gradient for
    /// server-side optimizers à la FedOpt).
    pub fn delta_to(&self, other: &FlatParams) -> FlatParams {
        assert_eq!(self.len(), other.len(), "delta length mismatch");
        FlatParams(
            self.0
                .iter()
                .zip(other.0.iter())
                .map(|(a, b)| b - a)
                .collect(),
        )
    }

    /// Max |a_i - b_i|; used by tests/parity checks.
    pub fn max_abs_diff(&self, other: &FlatParams) -> f32 {
        assert_eq!(self.len(), other.len());
        self.0
            .iter()
            .zip(other.0.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.0.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// True when every element is finite (no NaN/inf).
    pub fn all_finite(&self) -> bool {
        self.0.iter().all(|x| x.is_finite())
    }
}

/// Weighted average of parameter vectors: `sum_k w[k] * xs[k]` — Eq. (1) of
/// the paper, computed client-side. This is the pure-rust reference used by
/// every strategy; `runtime::agg` offers the same computation through the
/// lowered Pallas artifact, and `rust/tests/artifact_parity.rs` checks they
/// agree.
///
/// Sequential form of [`weighted_average_pooled`] (bit-identical).
pub fn weighted_average(xs: &[&FlatParams], weights: &[f32]) -> FlatParams {
    weighted_average_pooled(xs, weights, ChunkPool::sequential())
}

/// Fused one-pass weighted average: each [`PAR_CHUNK`]-wide output chunk
/// reads the matching chunk of **all K** client vectors and accumulates
/// every output element in a register before its single write — one
/// memory sweep over the output instead of the old K-sweep axpy loop
/// (kept as the baseline in `benches/kernels.rs`). Per element the FMA
/// sequence is `acc_k = fma(x_k, w_k, acc_{k-1})` with `acc_0 = 0`,
/// exactly the old loop's order, so fused, sequential, and pooled
/// results are all bit-identical.
pub fn weighted_average_pooled(
    xs: &[&FlatParams],
    weights: &[f32],
    pool: ChunkPool,
) -> FlatParams {
    assert_eq!(xs.len(), weights.len(), "weights/params arity mismatch");
    assert!(!xs.is_empty(), "cannot average zero clients");
    let n = xs[0].len();
    for x in xs {
        assert_eq!(x.len(), n, "client param length mismatch");
    }
    let mut out = FlatParams::zeros(n);
    let items: Vec<&mut [f32]> = out.0.chunks_mut(PAR_CHUNK).collect();
    pool.for_each(items, |ci, dst| {
        let start = ci * PAR_CHUNK;
        let rows: Vec<&[f32]> = xs.iter().map(|x| &x.as_slice()[start..start + dst.len()]).collect();
        for (j, d) in dst.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (row, &w) in rows.iter().zip(weights) {
                acc = row[j].mul_add(w, acc);
            }
            *d = acc;
        }
    });
    out
}

/// Deterministic chunked dot product `Σ a_i · b_i` in `f64`: per-chunk
/// partial sums are computed on `pool` over fixed [`PAR_CHUNK`]-wide
/// chunks (order-preserving [`ChunkPool::map`]) and combined
/// sequentially in chunk order, so the result is bit-identical for any
/// thread count. This is the kernel behind the round-divergence
/// analytics in [`crate::trace`].
pub fn dot_pooled(a: &FlatParams, b: &FlatParams, pool: ChunkPool) -> f64 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    let items: Vec<(&[f32], &[f32])> =
        a.0.chunks(PAR_CHUNK).zip(b.0.chunks(PAR_CHUNK)).collect();
    let partials = pool.map(items, |_, (xa, xb)| {
        let mut acc = 0.0f64;
        for (x, y) in xa.iter().zip(xb) {
            acc += (*x as f64) * (*y as f64);
        }
        acc
    });
    partials.into_iter().sum()
}

/// Sequential form of [`dot_pooled`] (bit-identical).
pub fn dot(a: &FlatParams, b: &FlatParams) -> f64 {
    dot_pooled(a, b, ChunkPool::sequential())
}

/// Deterministic chunked squared L2 distance `Σ (a_i - b_i)²` in `f64`,
/// with the same fixed-chunk partial-sum scheme as [`dot_pooled`] —
/// bit-identical for any thread count.
pub fn sq_l2_diff_pooled(a: &FlatParams, b: &FlatParams, pool: ChunkPool) -> f64 {
    assert_eq!(a.len(), b.len(), "l2 length mismatch");
    let items: Vec<(&[f32], &[f32])> =
        a.0.chunks(PAR_CHUNK).zip(b.0.chunks(PAR_CHUNK)).collect();
    let partials = pool.map(items, |_, (xa, xb)| {
        let mut acc = 0.0f64;
        for (x, y) in xa.iter().zip(xb) {
            let d = (*x as f64) - (*y as f64);
            acc += d * d;
        }
        acc
    });
    partials.into_iter().sum()
}

/// Cosine similarity of `a` and `b` computed with the deterministic
/// chunked kernels; defined as `0.0` when either vector has zero norm
/// (no NaN ever escapes into reports or exported JSON).
pub fn cosine_pooled(a: &FlatParams, b: &FlatParams, pool: ChunkPool) -> f64 {
    let na = dot_pooled(a, a, pool).sqrt();
    let nb = dot_pooled(b, b, pool).sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot_pooled(a, b, pool) / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(xs: &[f32]) -> FlatParams {
        FlatParams(xs.to_vec())
    }

    #[test]
    fn axpy_basic() {
        let mut a = fp(&[1.0, 2.0]);
        a.axpy(0.5, &fp(&[4.0, 8.0]));
        assert_eq!(a.0, vec![3.0, 6.0]);
    }

    #[test]
    fn lerp_endpoints() {
        let mut a = fp(&[1.0, 2.0]);
        a.lerp(0.0, &fp(&[5.0, 5.0]));
        assert_eq!(a.0, vec![1.0, 2.0]);
        a.lerp(1.0, &fp(&[5.0, 6.0]));
        assert_eq!(a.0, vec![5.0, 6.0]);
    }

    #[test]
    fn weighted_average_equal_weights_is_mean() {
        let out = weighted_average(&[&fp(&[0.0, 2.0]), &fp(&[2.0, 4.0])], &[0.5, 0.5]);
        assert_eq!(out.0, vec![1.0, 3.0]);
    }

    #[test]
    fn weighted_average_single_identity() {
        let x = fp(&[1.5, -2.5, 3.0]);
        let out = weighted_average(&[&x], &[1.0]);
        assert_eq!(out, x);
    }

    #[test]
    fn weighted_average_respects_weights() {
        let out = weighted_average(&[&fp(&[1.0]), &fp(&[3.0])], &[0.75, 0.25]);
        assert!((out.0[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn weighted_average_arity_mismatch_panics() {
        weighted_average(&[&fp(&[1.0])], &[0.5, 0.5]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn weighted_average_length_mismatch_panics() {
        weighted_average(&[&fp(&[1.0]), &fp(&[1.0, 2.0])], &[0.5, 0.5]);
    }

    /// The fused one-pass form must equal the K-sweep axpy loop it
    /// replaced bit-for-bit (same per-element FMA order).
    #[test]
    fn fused_average_matches_axpy_sweeps_bitwise() {
        let n = 3 * PAR_CHUNK + 17; // several chunks + ragged tail
        let clients: Vec<FlatParams> = (0..4)
            .map(|k| FlatParams((0..n).map(|i| ((i + 137 * k) as f32 * 0.013).sin()).collect()))
            .collect();
        let refs: Vec<&FlatParams> = clients.iter().collect();
        let w = [0.4, 0.3, 0.2, 0.1];
        // the replaced implementation, verbatim
        let mut old = FlatParams::zeros(n);
        for (x, &wk) in clients.iter().zip(w.iter()) {
            old.axpy(wk, x);
        }
        let fused = weighted_average(&refs, &w);
        assert_eq!(fused.0, old.0, "fused one-pass must be bit-identical to K-sweep axpy");
        for threads in [2, 8] {
            let pooled = weighted_average_pooled(&refs, &w, ChunkPool::new(threads));
            assert_eq!(pooled.0, old.0, "threads={threads}");
        }
    }

    #[test]
    fn pooled_axpy_and_lerp_match_sequential_bitwise() {
        let n = 2 * PAR_CHUNK + 3;
        let base = FlatParams((0..n).map(|i| (i as f32 * 0.017).cos()).collect());
        let other = FlatParams((0..n).map(|i| (i as f32 * 0.011).sin()).collect());
        for threads in [2, 8] {
            let pool = ChunkPool::new(threads);
            let mut seq = base.clone();
            seq.axpy(0.37, &other);
            let mut par = base.clone();
            par.axpy_pooled(0.37, &other, pool);
            assert_eq!(seq.0, par.0, "axpy threads={threads}");

            let mut seq = base.clone();
            seq.lerp(0.21, &other);
            let mut par = base.clone();
            par.lerp_pooled(0.21, &other, pool);
            assert_eq!(seq.0, par.0, "lerp threads={threads}");
        }
    }

    #[test]
    fn delta_and_norm() {
        let a = fp(&[1.0, 1.0]);
        let b = fp(&[4.0, 5.0]);
        let d = a.delta_to(&b);
        assert_eq!(d.0, vec![3.0, 4.0]);
        assert!((d.norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn content_hash_changes_with_content() {
        let a = fp(&[1.0, 2.0]);
        let mut b = a.clone();
        assert_eq!(a.content_hash(), b.content_hash());
        assert_eq!(a.content_hash(), a.content_hash_pooled(ChunkPool::new(4)));
        b.0[0] = 1.0001;
        assert_ne!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn dot_and_l2_hand_values() {
        let a = fp(&[1.0, 2.0, 3.0]);
        let b = fp(&[4.0, 5.0, 6.0]);
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(sq_l2_diff_pooled(&a, &b, ChunkPool::sequential()), 27.0);
        assert_eq!(cosine_pooled(&a, &a, ChunkPool::sequential()), 1.0);
        // zero-norm guard: never NaN
        let z = fp(&[0.0, 0.0, 0.0]);
        assert_eq!(cosine_pooled(&z, &b, ChunkPool::sequential()), 0.0);
        assert_eq!(cosine_pooled(&a, &z, ChunkPool::sequential()), 0.0);
    }

    /// The divergence kernels share the determinism contract: f64 bit
    /// identity between sequential and pooled forms at any thread count,
    /// across chunk-straddling sizes.
    #[test]
    fn pooled_dot_and_l2_match_sequential_bitwise() {
        for n in [1usize, 1000, PAR_CHUNK, PAR_CHUNK + 1, 3 * PAR_CHUNK + 17] {
            let a = FlatParams((0..n).map(|i| (i as f32 * 0.0137).sin() * 0.8).collect());
            let b = FlatParams((0..n).map(|i| (i as f32 * 0.0093).cos() * 0.6).collect());
            let dot_ref = dot(&a, &b);
            let l2_ref = sq_l2_diff_pooled(&a, &b, ChunkPool::sequential());
            let cos_ref = cosine_pooled(&a, &b, ChunkPool::sequential());
            for threads in [2usize, 8] {
                let pool = ChunkPool::new(threads);
                assert_eq!(
                    dot_pooled(&a, &b, pool).to_bits(),
                    dot_ref.to_bits(),
                    "dot n={n} threads={threads}"
                );
                assert_eq!(
                    sq_l2_diff_pooled(&a, &b, pool).to_bits(),
                    l2_ref.to_bits(),
                    "l2 n={n} threads={threads}"
                );
                assert_eq!(
                    cosine_pooled(&a, &b, pool).to_bits(),
                    cos_ref.to_bits(),
                    "cosine n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn finite_check() {
        assert!(fp(&[1.0, -2.0]).all_finite());
        assert!(!fp(&[f32::NAN]).all_finite());
        assert!(!fp(&[f32::INFINITY]).all_finite());
    }
}
