//! [`TopK`] — magnitude sparsification (codec id 2).

use std::cmp::Ordering;

use anyhow::{bail, Result};

use crate::par::ChunkPool;
use crate::tensor::FlatParams;

use super::{Codec, CodecKind};

/// Default kept fraction when `compress = topk` gives no explicit value.
pub const DEFAULT_TOPK_FRACTION: f64 = 0.1;

/// Elements per parallel selection chunk (64 KiB of f32s). Fixed — the
/// candidate split never depends on the thread count, and the selected
/// *set* is provably identical to the single-pass selection either way
/// (see [`TopK`]).
const SELECT_CHUNK: usize = 16 * 1024;

/// Keep only the `frac · n` largest-magnitude elements, encoded as
/// `(u32 index, f32 value)` pairs; everything else decodes to zero.
///
/// Wire cost: `4 + 8 · k` bytes with `k = ceil(frac · n)` — at the
/// default `frac = 0.1` that is ~5× smaller than raw f32. Error bound
/// (per element): the largest dropped magnitude, i.e. the `(k+1)`-th
/// largest `|x|` (zero when nothing is dropped). Ties at the threshold
/// break by lower index, so the selection is deterministic.
///
/// Parallel selection works per fixed [`SELECT_CHUNK`]: each chunk
/// selects its own top `min(k, chunk_len)` candidates under the same
/// (magnitude desc, index asc) total order, and a final select over the
/// merged candidates picks the global top k. The global top-k set can
/// contain at most `k` elements of any one chunk, so every global
/// winner survives its chunk's cut — and because the total order makes
/// the kept set unique, the result is *identical* to the single-pass
/// selection for any thread count.
pub struct TopK {
    frac: f64,
}

/// The selection's total order over indices: magnitude descending, ties
/// by ascending index — shared by the single-pass, per-chunk, and merge
/// selects so they all agree on the unique kept set. `total_cmp` (not
/// `partial_cmp`-with-an-Equal-fallback) keeps this a genuine total
/// order even when a diverged client ships NaN weights: an intransitive
/// comparator would let the per-chunk and single-pass selections keep
/// *different* sets, breaking the thread-count-independence contract on
/// the wire. (NaN magnitudes order above infinity, so they are kept —
/// and faithfully shipped — rather than silently dropped.)
#[inline]
fn by_magnitude(xs: &[f32]) -> impl Fn(&u32, &u32) -> Ordering + '_ {
    |&a, &b| {
        let ma = xs[a as usize].abs();
        let mb = xs[b as usize].abs();
        mb.total_cmp(&ma).then(a.cmp(&b))
    }
}

impl TopK {
    /// A sparsifier keeping the top `frac ∈ (0, 1]` fraction by
    /// magnitude (at least one element on non-empty input).
    pub fn new(frac: f64) -> TopK {
        assert!(frac > 0.0 && frac <= 1.0, "topk fraction must be in (0, 1], got {frac}");
        TopK { frac }
    }

    /// How many elements of an `n`-vector this codec keeps.
    pub fn kept(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        ((self.frac * n as f64).ceil() as usize).clamp(1, n)
    }

    /// Indices of the kept elements, sorted ascending. Selection is by
    /// descending magnitude with ties broken by ascending index — a
    /// total order, so the kept *set* is unique and deterministic.
    /// `select_nth_unstable_by` keeps this O(n) on the per-push hot
    /// path (a full sort of a 1M-param index vector per epoch is real
    /// money). With a multi-threaded pool the candidate pass runs
    /// chunk-parallel; either path returns the same set.
    fn select(&self, xs: &[f32], pool: ChunkPool) -> Vec<u32> {
        let k = self.kept(xs.len());
        let mut order: Vec<u32> = if pool.threads() > 1 && xs.len() > SELECT_CHUNK {
            // per-chunk candidates (each chunk's own top min(k, len)),
            // then a global select over the merged candidate list
            pool.map(xs.chunks(SELECT_CHUNK).collect(), |ci, chunk| {
                let base = (ci * SELECT_CHUNK) as u32;
                let kk = k.min(chunk.len());
                let mut cand: Vec<u32> = (base..base + chunk.len() as u32).collect();
                if kk < cand.len() {
                    cand.select_nth_unstable_by(kk - 1, by_magnitude(xs));
                    cand.truncate(kk);
                }
                cand
            })
            .concat()
        } else {
            (0..xs.len() as u32).collect()
        };
        if k < order.len() {
            order.select_nth_unstable_by(k - 1, by_magnitude(xs));
            order.truncate(k);
        }
        order.sort_unstable();
        order
    }
}

impl Codec for TopK {
    fn kind(&self) -> CodecKind {
        CodecKind::TopK { frac: self.frac }
    }

    fn encode_pooled(
        &self,
        params: &FlatParams,
        _base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Vec<u8> {
        let xs = params.as_slice();
        let kept = self.select(xs, pool);
        let mut out = Vec::with_capacity(4 + 8 * kept.len());
        out.extend_from_slice(&(kept.len() as u32).to_le_bytes());
        for &i in &kept {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&xs[i as usize].to_le_bytes());
        }
        out
    }

    // decode stays sequential (trait default): it is a sparse scatter of
    // k pairs into a zeroed vector, with no fixed chunk structure to
    // parallelize over.
    fn decode_pooled(
        &self,
        payload: &[u8],
        n: usize,
        _base: Option<&FlatParams>,
        _pool: ChunkPool,
    ) -> Result<FlatParams> {
        if payload.len() < 4 {
            bail!("topk payload too short: {} bytes", payload.len());
        }
        let k = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
        let want = 4 + k.checked_mul(8).ok_or_else(|| anyhow::anyhow!("topk size overflow"))?;
        if payload.len() != want {
            bail!("topk payload is {} bytes, want {} for k = {k}", payload.len(), want);
        }
        if k > n {
            bail!("topk keeps {k} of only {n} elements");
        }
        // the payload size does not determine n here, so enforce the blob
        // layer's allocation ceiling locally too (a hostile header must
        // not buy a multi-GB zeroed buffer)
        if n > crate::tensor::codec::MAX_DECODE_ELEMS {
            bail!("topk element count {n} exceeds the decode ceiling");
        }
        let mut xs = vec![0.0f32; n];
        for pair in payload[4..].chunks_exact(8) {
            let i = u32::from_le_bytes(pair[0..4].try_into().unwrap()) as usize;
            let v = f32::from_le_bytes(pair[4..8].try_into().unwrap());
            if i >= n {
                bail!("topk index {i} out of range for {n} elements");
            }
            xs[i] = v;
        }
        Ok(FlatParams(xs))
    }

    fn error_bound(&self, params: &FlatParams, _base: Option<&FlatParams>) -> f32 {
        let xs = params.as_slice();
        let k = self.kept(xs.len());
        if k >= xs.len() {
            return 0.0;
        }
        // the largest magnitude among dropped elements: the (k+1)-th
        // largest overall (O(n) selection, under `select`'s NaN-robust
        // total order)
        let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
        let (_, nth, _) = mags.select_nth_unstable_by(k, |a, b| b.total_cmp(a));
        *nth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topk(frac: f64) -> TopK {
        TopK::new(frac)
    }

    #[test]
    fn keeps_the_largest_magnitudes() {
        let p = FlatParams(vec![0.1, -9.0, 0.2, 8.0, -0.3, 0.0]);
        let dec = topk(0.34).decode(&topk(0.34).encode(&p, None), 6, None).unwrap();
        assert_eq!(dec.0, vec![0.0, -9.0, 0.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn frac_one_is_lossless() {
        let p = FlatParams(vec![1.0, -2.0, 3.5, 0.0]);
        let dec = topk(1.0).decode(&topk(1.0).encode(&p, None), 4, None).unwrap();
        assert_eq!(dec.0, p.0);
        assert_eq!(topk(1.0).error_bound(&p, None), 0.0);
    }

    #[test]
    fn respects_error_bound() {
        let p = FlatParams((0..4_000).map(|i| ((i as f32) * 1.7).sin()).collect());
        let t = topk(0.1);
        let bound = t.error_bound(&p, None);
        let dec = t.decode(&t.encode(&p, None), p.len(), None).unwrap();
        assert!(p.max_abs_diff(&dec) <= bound, "{} > {}", p.max_abs_diff(&dec), bound);
        // and it genuinely compresses: k = 400 pairs + count
        assert_eq!(t.encode(&p, None).len(), 4 + 8 * 400);
    }

    #[test]
    fn selection_is_deterministic_under_ties() {
        let p = FlatParams(vec![1.0; 10]);
        let a = topk(0.3).encode(&p, None);
        let b = topk(0.3).encode(&p, None);
        assert_eq!(a, b);
        // ties keep the lowest indices
        let dec = topk(0.3).decode(&a, 10, None).unwrap();
        assert_eq!(dec.0[..3], [1.0, 1.0, 1.0]);
        assert_eq!(dec.0[3..], [0.0; 7]);
    }

    #[test]
    fn nan_inputs_select_identically_across_thread_counts() {
        // a diverged client's NaN weights must not break the total
        // order: parallel and single-pass selections must still agree
        let n = 2 * SELECT_CHUNK + 50;
        let mut xs: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.37).sin()).collect();
        for i in [3, SELECT_CHUNK - 1, SELECT_CHUNK + 7, n - 2] {
            xs[i] = f32::NAN;
        }
        let p = FlatParams(xs);
        let seq = topk(0.05).encode(&p, None);
        for threads in [2, 8] {
            let par = topk(0.05).encode_pooled(&p, None, ChunkPool::new(threads));
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn parallel_select_matches_single_pass_bytewise() {
        // larger than SELECT_CHUNK so the candidate-merge path engages;
        // include heavy ties (quantized values) to stress the total
        // order's tie-break
        let n = 3 * SELECT_CHUNK + 123;
        let p = FlatParams(
            (0..n).map(|i| (((i * 37) % 19) as f32 - 9.0) * 0.125).collect(),
        );
        for frac in [0.01, 0.1, 0.9] {
            let seq = topk(frac).encode(&p, None);
            for threads in [2, 8] {
                let par = topk(frac).encode_pooled(&p, None, ChunkPool::new(threads));
                assert_eq!(par, seq, "frac={frac} threads={threads}");
            }
        }
    }

    #[test]
    fn malformed_payloads_error() {
        let p = FlatParams(vec![1.0, 2.0, 3.0]);
        let enc = topk(0.5).encode(&p, None);
        assert!(topk(0.5).decode(&enc[..enc.len() - 1], 3, None).is_err());
        assert!(topk(0.5).decode(&enc, 1, None).is_err(), "k > n must error");
        assert!(topk(0.5).decode(&[], 3, None).is_err());
        // an out-of-range index is rejected, not written out of bounds
        let mut bad = enc.clone();
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert!(topk(0.5).decode(&bad, 3, None).is_err());
    }

    #[test]
    fn empty_vector_round_trips() {
        let p = FlatParams(vec![]);
        let enc = topk(0.1).encode(&p, None);
        assert_eq!(enc.len(), 4);
        assert!(topk(0.1).decode(&enc, 0, None).unwrap().is_empty());
    }
}
