//! Protocol-layer microbench: sync-barrier wait, poll vs notify.
//!
//! The barrier used to busy-poll `entries_for_round` every 200µs; it now
//! parks on `WeightStore::wait_for_change`. This bench measures, for the
//! in-process backends, the two costs that trade off:
//!
//! * **wake latency** — time from the last peer's push to the waiter
//!   noticing the round is complete;
//! * **store reads** — how many LIST-equivalent reads the waiter issued
//!   while a straggler held the barrier open.
//!
//! Results land in `BENCH_protocols.json` (the protocol perf trajectory;
//! re-run after store/protocol changes and compare).
//!
//! Run: `cargo bench --offline --bench protocols` — store-only, needs no
//! artifacts.

use std::fs;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fedless::metrics::stats::percentile;
use fedless::store::{MemoryStore, PushRequest, ShardedStore, WeightStore};
use fedless::tensor::FlatParams;

const NODES: usize = 4;
const STRAGGLER_DELAY: Duration = Duration::from_millis(10);
const TRIALS: usize = 20;

fn req(node: usize) -> PushRequest {
    PushRequest::raw(node, 0, 0, 100, Arc::new(FlatParams(vec![node as f32; 256])))
}

/// One barrier wait: K-1 entries are present, the K-th lands after the
/// straggler delay. Returns (wake latency, store reads issued).
fn trial(store: &Arc<dyn WeightStore>, notify: bool) -> (Duration, u64) {
    store.clear().unwrap();
    for node in 0..NODES - 1 {
        store.push(req(node)).unwrap();
    }
    let pushed_at: Arc<Mutex<Option<Instant>>> = Arc::new(Mutex::new(None));
    let pusher = {
        let store = Arc::clone(store);
        let pushed_at = Arc::clone(&pushed_at);
        std::thread::spawn(move || {
            std::thread::sleep(STRAGGLER_DELAY);
            *pushed_at.lock().unwrap() = Some(Instant::now());
            store.push(req(NODES - 1)).unwrap();
        })
    };

    let mut reads = 0u64;
    loop {
        let seen = if notify { store.version().unwrap() } else { 0 };
        reads += 1;
        if store.entries_for_round(0).unwrap().len() >= NODES {
            break;
        }
        if notify {
            store.wait_for_change(seen, Duration::from_secs(10)).unwrap();
        } else {
            std::thread::sleep(Duration::from_micros(200)); // the old barrier
        }
    }
    let detected = Instant::now();
    let pushed = pushed_at.lock().unwrap().expect("barrier completed without the last push");
    pusher.join().unwrap();
    (detected.saturating_duration_since(pushed), reads)
}

struct Row {
    store: &'static str,
    waiter: &'static str,
    mean_wake_us: f64,
    p95_wake_us: f64,
    mean_reads: f64,
}

fn measure(store: Arc<dyn WeightStore>, store_name: &'static str, notify: bool) -> Row {
    // warmup
    for _ in 0..3 {
        trial(&store, notify);
    }
    let mut wakes_us: Vec<f64> = Vec::with_capacity(TRIALS);
    let mut reads: Vec<f64> = Vec::with_capacity(TRIALS);
    for _ in 0..TRIALS {
        let (wake, r) = trial(&store, notify);
        wakes_us.push(wake.as_secs_f64() * 1e6);
        reads.push(r as f64);
    }
    wakes_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    let row = Row {
        store: store_name,
        waiter: if notify { "notify" } else { "poll_200us" },
        mean_wake_us: mean(&wakes_us),
        p95_wake_us: percentile(&wakes_us, 95.0)
            .unwrap_or_else(|e| panic!("{store_name} wake samples: {e}")),
        mean_reads: mean(&reads),
    };
    println!(
        "{:>8}/{:<10}  wake mean {:>9.1}µs  p95 {:>9.1}µs  reads/wait {:>7.1}",
        row.store, row.waiter, row.mean_wake_us, row.p95_wake_us, row.mean_reads
    );
    row
}

fn main() {
    println!(
        "sync-barrier wait: poll vs notify ({NODES} nodes, {}ms straggler, {TRIALS} trials)",
        STRAGGLER_DELAY.as_millis()
    );
    let mut rows = Vec::new();
    for notify in [false, true] {
        rows.push(measure(Arc::new(MemoryStore::new()), "memory", notify));
        rows.push(measure(Arc::new(ShardedStore::default()), "sharded", notify));
    }

    let mut json = String::from("{\n  \"bench\": \"sync_barrier_wait_poll_vs_notify\",\n");
    json.push_str(&format!(
        "  \"nodes\": {NODES},\n  \"straggler_delay_ms\": {},\n  \"trials\": {TRIALS},\n  \"results\": [\n",
        STRAGGLER_DELAY.as_millis()
    ));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"store\": \"{}\", \"waiter\": \"{}\", \"mean_wake_us\": {:.1}, \
             \"p95_wake_us\": {:.1}, \"mean_store_reads_per_wait\": {:.1}}}{}\n",
            r.store,
            r.waiter,
            r.mean_wake_us,
            r.p95_wake_us,
            r.mean_reads,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    fs::write("BENCH_protocols.json", &json).expect("write BENCH_protocols.json");
    println!("\nwrote BENCH_protocols.json");
}
