//! [`TrafficMeter`] — per-node weight-store traffic accounting.
//!
//! Every protocol-layer push and pull records its *encoded wire bytes*
//! (blob header included, see [`crate::tensor::codec`]) here, so an
//! experiment reports exactly how much data each node would have moved
//! through the paper's S3 bucket — the quantity the
//! [`crate::compress`] codecs exist to shrink. The meter rides on each
//! node's [`crate::metrics::Timeline`] and surfaces in
//! `ExperimentResult::total_traffic`, the sweep-report traffic columns,
//! and `fedbench run` output.

/// Byte and operation counters for one node's weight-store traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrafficMeter {
    /// Encoded bytes this node pushed (wire blobs, headers included).
    pub bytes_pushed: u64,
    /// Encoded bytes this node pulled (sum over every downloaded entry).
    pub bytes_pulled: u64,
    /// Push operations recorded.
    pub pushes: u64,
    /// Entries downloaded (one pull of K entries counts K).
    pub entries_pulled: u64,
}

impl TrafficMeter {
    /// Record one push of `bytes` wire bytes.
    pub fn record_push(&mut self, bytes: u64) {
        self.bytes_pushed += bytes;
        self.pushes += 1;
    }

    /// Record one downloaded entry of `bytes` wire bytes.
    pub fn record_pull(&mut self, bytes: u64) {
        self.bytes_pulled += bytes;
        self.entries_pulled += 1;
    }

    /// Total bytes moved in either direction.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_pushed + self.bytes_pulled
    }

    /// Fold another meter into this one (for experiment-wide totals).
    pub fn merge(&mut self, other: &TrafficMeter) {
        self.bytes_pushed += other.bytes_pushed;
        self.bytes_pulled += other.bytes_pulled;
        self.pushes += other.pushes;
        self.entries_pulled += other.entries_pulled;
    }

    /// Megabytes pushed (decimal MB, for report columns).
    pub fn mb_pushed(&self) -> f64 {
        self.bytes_pushed as f64 / 1e6
    }

    /// Megabytes pulled (decimal MB, for report columns).
    pub fn mb_pulled(&self) -> f64 {
        self.bytes_pulled as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_totals() {
        let mut t = TrafficMeter::default();
        t.record_push(100);
        t.record_push(50);
        t.record_pull(30);
        assert_eq!(t.bytes_pushed, 150);
        assert_eq!(t.bytes_pulled, 30);
        assert_eq!(t.pushes, 2);
        assert_eq!(t.entries_pulled, 1);
        assert_eq!(t.total_bytes(), 180);
    }

    #[test]
    fn merge_sums_every_counter() {
        let mut a = TrafficMeter::default();
        a.record_push(10);
        let mut b = TrafficMeter::default();
        b.record_pull(7);
        b.record_pull(3);
        a.merge(&b);
        assert_eq!(
            a,
            TrafficMeter { bytes_pushed: 10, bytes_pulled: 10, pushes: 1, entries_pulled: 2 }
        );
    }

    #[test]
    fn mb_columns_are_decimal_megabytes() {
        let mut t = TrafficMeter::default();
        t.record_push(2_500_000);
        t.record_pull(500_000);
        assert!((t.mb_pushed() - 2.5).abs() < 1e-12);
        assert!((t.mb_pulled() - 0.5).abs() < 1e-12);
    }
}
