//! Hashing — two distinct families with two distinct contracts:
//!
//! * **FNV-1a 64-bit** ([`fnv1a64`], [`fnv1a64_multi`], [`hash_f32s`]) —
//!   the *persisted* hash: v1/v2 blob integrity headers
//!   ([`crate::tensor::codec`]) are FNV over the serialized bytes, and
//!   on-disk compatibility pins these functions byte-for-byte. The
//!   *values* are frozen; the *implementation* loads 8 bytes per memory
//!   access and folds them in registers ([`fnv1a64_fold`]'s inner loop),
//!   which is the identical per-byte xor/multiply sequence — a faster
//!   evaluation order, never a different hash (pinned by the
//!   `word_fold_matches_bytewise_reference` test).
//! * **Chunked multi-lane hash** ([`chunked_hash_f32s`]) — the
//!   *in-memory* change-detection hash ([`crate::tensor::FlatParams::content_hash`],
//!   weight-level store state checks). Each fixed
//!   [`HASH_CHUNK_ELEMS`]-element chunk is digested by [`DIGEST_LANES`]
//!   independent multiply-xorshift chains (8 bytes per step per lane, so
//!   the serial multiply latency overlaps across lanes) folded in fixed
//!   lane order, and chunk digests combine in chunk order — so it
//!   parallelizes on a [`ChunkPool`] with bit-identical results for any
//!   thread count. Its value never touches disk, so it owes no
//!   compatibility to anything (and this PR's lane widening changed it).

use crate::par::ChunkPool;

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Fold `bytes` into a running FNV-1a state. Word-at-a-time loads with
/// in-register byte folding: `(h ^ byte) * PRIME` per byte, in order —
/// byte-exact with the classic loop, ~2× fewer memory operations.
#[inline]
fn fnv1a64_fold(mut h: u64, bytes: &[u8]) -> u64 {
    let mut words = bytes.chunks_exact(8);
    for wbytes in words.by_ref() {
        let mut w = u64::from_le_bytes(wbytes.try_into().unwrap());
        for _ in 0..8 {
            h = (h ^ (w & 0xFF)).wrapping_mul(FNV_PRIME);
            w >>= 8;
        }
    }
    for &b in words.remainder() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_fold(FNV_OFFSET, bytes)
}

/// FNV-1a over the concatenation of several byte slices, without
/// materializing the concatenation — used by the blob codec to hash a
/// header with its hash field treated as zeroed. The running state
/// carries across part boundaries, so part splits never change the
/// value (same guarantee the word folding preserves within a part).
pub fn fnv1a64_multi(parts: &[&[u8]]) -> u64 {
    let mut h = FNV_OFFSET;
    for part in parts {
        h = fnv1a64_fold(h, part);
    }
    h
}

/// Hash an f32 slice by its raw little-endian bytes (sequential FNV-1a;
/// see the module docs for when to prefer [`chunked_hash_f32s`]).
pub fn hash_f32s(xs: &[f32]) -> u64 {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: any f32 is plain old data; viewed as bytes on a
        // little-endian host this is exactly the `to_le_bytes`
        // serialization the hash is specified over.
        let bytes =
            unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
        fnv1a64(bytes)
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut h = FNV_OFFSET;
        for x in xs {
            h = fnv1a64_fold(h, &x.to_le_bytes());
        }
        h
    }
}

/// Combine hashes order-dependently (for store state hashes and the
/// chunk-digest combine of [`chunked_hash_f32s`]). For fixed `a` this is
/// bijective in `b`, so a changed chunk digest always changes the
/// combined value.
pub fn combine(a: u64, b: u64) -> u64 {
    a ^ b
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(a << 6)
        .wrapping_add(a >> 2)
}

/// f32 elements per chunk of the chunked content hash: 16 Ki elements =
/// 64 KiB, the kernel layer's standard chunk width. Fixed — never a
/// function of the thread count (the [`crate::par`] determinism
/// contract).
pub const HASH_CHUNK_ELEMS: usize = 16 * 1024;

/// Independent mixing chains per chunk digest. The multiply in
/// [`mix64`] has multi-cycle latency but single-cycle throughput; eight
/// interleaved chains keep the multiplier busy instead of waiting on the
/// previous step. A constant of the digest definition (lane count
/// changes the value), never of the machine.
pub const DIGEST_LANES: usize = 8;

/// Per-lane seeds (odd, mutually distinct) so equal words feeding
/// different lanes contribute differently.
const LANE_SEEDS: [u64; DIGEST_LANES] = [
    0x910A_2DEC_89025CC1,
    0xBEEB_D7DE_D04BA03F,
    0x7C8C_D672_0F2B0305,
    0x4B09_71B1_5A1F3771,
    0x9E7A_7A6B_57D0DF09,
    0xD3B4_1998_A5D0C281,
    0x2F2E_44B9_3B3F66CD,
    0x6A1C_78A9_4C979E5B,
];

/// One multiply-xorshift mixing step over a 64-bit word (two f32s per
/// step vs FNV's one byte): the multiply diffuses low bits upward, the
/// shift folds high bits back down, and both are bijective — any
/// single-bit change in `w` changes the result.
#[inline]
fn mix64(h: u64, w: u64) -> u64 {
    let m = (h ^ w).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    m ^ (m >> 33)
}

/// Multi-lane digest of one chunk: words (two packed f32 bit patterns)
/// are dealt round-robin to [`DIGEST_LANES`] independent [`mix64`]
/// chains, which fold together in fixed lane order; leftover words and
/// an odd trailing element (tagged so `[x]` and `[x, 0.0]` digest
/// differently) mix into the folded state sequentially. Every element
/// feeds exactly one bijective chain, so any single-element change
/// changes the digest.
fn chunk_digest(xs: &[f32]) -> u64 {
    let mut lanes = LANE_SEEDS;
    let mut groups = xs.chunks_exact(2 * DIGEST_LANES);
    for g in groups.by_ref() {
        for (lane, p) in lanes.iter_mut().zip(g.chunks_exact(2)) {
            let w = (p[0].to_bits() as u64) | ((p[1].to_bits() as u64) << 32);
            *lane = mix64(*lane, w);
        }
    }
    let mut h: u64 = 0x9E37_79B9_7F4A_7C15;
    for lane in lanes {
        h = combine(h, lane);
    }
    let mut pairs = groups.remainder().chunks_exact(2);
    for p in pairs.by_ref() {
        let w = (p[0].to_bits() as u64) | ((p[1].to_bits() as u64) << 32);
        h = mix64(h, w);
    }
    if let [tail] = pairs.remainder() {
        h = mix64(h, (1u64 << 63) | tail.to_bits() as u64);
    }
    h
}

/// Fast change-detection hash of an f32 slice: multi-lane digests over
/// fixed [`HASH_CHUNK_ELEMS`]-element chunks, combined in chunk order.
/// **Not** FNV-compatible and never persisted — the blob formats keep
/// [`fnv1a64`] (module docs).
pub fn chunked_hash_f32s(xs: &[f32]) -> u64 {
    chunked_hash_f32s_pooled(xs, ChunkPool::sequential())
}

/// [`chunked_hash_f32s`] with the per-chunk digests computed on `pool`.
/// Chunk boundaries, lane count, and the combine order are fixed, so the
/// result is bit-identical for any thread count.
pub fn chunked_hash_f32s_pooled(xs: &[f32], pool: ChunkPool) -> u64 {
    let digests = pool.map(xs.chunks(HASH_CHUNK_ELEMS).collect(), |_, chunk| chunk_digest(chunk));
    let mut h = FNV_OFFSET ^ xs.len() as u64;
    for d in digests {
        h = combine(h, d);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The classic byte-at-a-time FNV-1a loop — the frozen reference the
    /// word-folding implementation must match on every input.
    fn fnv1a64_bytewise(parts: &[&[u8]]) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for part in parts {
            for &b in *part {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        h
    }

    #[test]
    fn known_fnv_vector() {
        // FNV-1a("") = offset basis
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        // differs for different inputs
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
    }

    #[test]
    fn word_fold_matches_bytewise_reference() {
        // every length through several words plus ragged tails, with
        // position-dependent bytes so a reordered fold can't pass
        let data: Vec<u8> = (0..64u32).map(|i| (i.wrapping_mul(37) ^ 0xA5) as u8).collect();
        for len in 0..data.len() {
            assert_eq!(fnv1a64(&data[..len]), fnv1a64_bytewise(&[&data[..len]]), "len={len}");
        }
        // multi-part folding carries state across part boundaries at
        // every split point, including mid-word splits
        for split in 0..data.len() {
            assert_eq!(
                fnv1a64_multi(&[&data[..split], &data[split..]]),
                fnv1a64_bytewise(&[&data]),
                "split={split}"
            );
        }
        assert_eq!(fnv1a64_multi(&[&data, &[], &data[..3]]), {
            let both: Vec<u8> = data.iter().chain(&data[..3]).copied().collect();
            fnv1a64_bytewise(&[&both])
        });
    }

    #[test]
    fn f32_hash_matches_byte_hash() {
        let xs = [1.0f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        for x in &xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(hash_f32s(&xs), fnv1a64(&bytes));
        assert_eq!(hash_f32s(&xs), fnv1a64_bytewise(&[&bytes]));
    }

    #[test]
    fn combine_is_order_sensitive() {
        assert_ne!(combine(1, 2), combine(2, 1));
    }

    #[test]
    fn multi_part_hash_matches_concatenation() {
        assert_eq!(fnv1a64_multi(&[b"ab", b"", b"cd"]), fnv1a64(b"abcd"));
        assert_eq!(fnv1a64_multi(&[]), fnv1a64(b""));
    }

    fn training_like(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * 0.0173).sin() * 0.8).collect()
    }

    #[test]
    fn chunked_hash_is_thread_count_independent() {
        // spans several chunks plus lane-group and odd tails
        for n in [
            0,
            1,
            2,
            3,
            2 * DIGEST_LANES - 1,
            2 * DIGEST_LANES,
            2 * DIGEST_LANES + 1,
            HASH_CHUNK_ELEMS,
            HASH_CHUNK_ELEMS + 1,
            3 * HASH_CHUNK_ELEMS + 7,
        ] {
            let xs = training_like(n);
            let reference = chunked_hash_f32s(&xs);
            for threads in [1, 2, 8] {
                assert_eq!(
                    chunked_hash_f32s_pooled(&xs, ChunkPool::new(threads)),
                    reference,
                    "n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn chunked_hash_sees_every_position() {
        // flipping any single element (first, lane boundaries, chunk
        // boundary, odd tail) must change the hash
        let mut xs = training_like(2 * HASH_CHUNK_ELEMS + 5);
        let h0 = chunked_hash_f32s(&xs);
        for i in [
            0,
            1,
            2 * DIGEST_LANES - 1,
            2 * DIGEST_LANES,
            HASH_CHUNK_ELEMS - 1,
            HASH_CHUNK_ELEMS,
            2 * HASH_CHUNK_ELEMS + 4,
        ] {
            let old = xs[i];
            xs[i] += 1.0e-4;
            assert_ne!(chunked_hash_f32s(&xs), h0, "flip at {i} must change the hash");
            xs[i] = old;
        }
        assert_eq!(chunked_hash_f32s(&xs), h0, "restored input restores the hash");
    }

    #[test]
    fn chunked_hash_distinguishes_length_and_padding() {
        assert_ne!(chunked_hash_f32s(&[1.0]), chunked_hash_f32s(&[1.0, 0.0]));
        assert_ne!(chunked_hash_f32s(&[]), chunked_hash_f32s(&[0.0]));
        // a zero tail after a chunk boundary is not invisible
        let a = vec![0.5; HASH_CHUNK_ELEMS];
        let mut b = a.clone();
        b.push(0.0);
        assert_ne!(chunked_hash_f32s(&a), chunked_hash_f32s(&b));
        // swapping equal-value positions across lanes is visible (the
        // lane seeds are distinct)
        let mut c = training_like(2 * DIGEST_LANES);
        let d0 = chunked_hash_f32s(&c);
        c.swap(0, 2); // same lane word positions, different lanes
        assert_ne!(chunked_hash_f32s(&c), d0);
    }
}
