//! FedAvgM — FedAvg with server-side momentum (Hsu et al. 2019), run
//! client-side here. Each aggregation computes the pseudo-gradient
//! `Δ = w_avg - w_prev`, updates the momentum buffer
//! `v <- β v + Δ`, and steps `w <- w_prev + lr * v`.
//!
//! In the serverless design every node owns its *own* momentum buffer —
//! a direct consequence of "each client may implement its own aggregation
//! strategy" (§3).

use super::{fedavg_of, Contribution, Strategy};
use crate::par::ChunkPool;
use crate::tensor::FlatParams;

/// FedAvg with a client-held server-momentum buffer.
pub struct FedAvgM {
    beta: f32,
    lr: f32,
    velocity: Option<FlatParams>,
    prev: Option<FlatParams>,
}

impl FedAvgM {
    /// Momentum decay `beta` ∈ [0, 1) and server learning rate `lr`
    /// (paper defaults: 0.9 and 1.0).
    pub fn new(beta: f32, lr: f32) -> Self {
        assert!((0.0..1.0).contains(&beta));
        FedAvgM { beta, lr, velocity: None, prev: None }
    }
}

impl Strategy for FedAvgM {
    fn name(&self) -> &'static str {
        "fedavgm"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        if contribs.is_empty() {
            return None;
        }
        let avg = fedavg_of(contribs, pool);
        let prev = match &self.prev {
            None => {
                // first federation: adopt the average, momentum starts at 0
                self.velocity = Some(FlatParams::zeros(avg.len()));
                self.prev = Some(avg.clone());
                return Some(avg);
            }
            Some(p) => p.clone(),
        };
        let delta = prev.delta_to(&avg);
        let v = self.velocity.as_mut().expect("velocity init'd with prev");
        v.scale(self.beta);
        v.axpy_pooled(1.0, &delta, pool);
        let mut next = prev;
        next.axpy_pooled(self.lr, v, pool);
        self.prev = Some(next.clone());
        Some(next)
    }

    fn reset(&mut self) {
        self.velocity = None;
        self.prev = None;
    }
}

#[cfg(test)]
mod tests {
    use super::super::strategy_tests::contrib;
    use super::*;

    #[test]
    fn first_call_adopts_average() {
        let mut s = FedAvgM::new(0.9, 1.0);
        let out = s
            .aggregate(&[contrib(0, 1, true, &[2.0]), contrib(1, 1, false, &[4.0])])
            .unwrap();
        assert_eq!(out.0, vec![3.0]);
    }

    #[test]
    fn momentum_accumulates_along_consistent_direction() {
        let mut s = FedAvgM::new(0.9, 1.0);
        // round 1 establishes prev=0
        s.aggregate(&[contrib(0, 1, true, &[0.0])]).unwrap();
        // each later round's average is prev+1 -> delta = 1 each time;
        // velocity compounds: v1=1, step to 1; v2=.9+1=1.9, step to 2.9...
        let w1 = s.aggregate(&[contrib(0, 1, true, &[1.0])]).unwrap();
        assert!((w1.0[0] - 1.0).abs() < 1e-6);
        let w2 = s.aggregate(&[contrib(0, 1, true, &[w1.0[0] + 1.0])]).unwrap();
        assert!((w2.0[0] - 2.9).abs() < 1e-5, "{}", w2.0[0]);
    }

    #[test]
    fn zero_beta_equals_fedavg_direction() {
        let mut s = FedAvgM::new(0.0, 1.0);
        s.aggregate(&[contrib(0, 1, true, &[0.0])]).unwrap();
        let out = s
            .aggregate(&[contrib(0, 1, true, &[2.0]), contrib(1, 1, false, &[4.0])])
            .unwrap();
        // beta=0, lr=1: w = prev + (avg - prev) = avg
        assert_eq!(out.0, vec![3.0]);
    }

    #[test]
    fn reset_forgets_state() {
        let mut s = FedAvgM::new(0.9, 1.0);
        s.aggregate(&[contrib(0, 1, true, &[5.0])]).unwrap();
        s.reset();
        let out = s.aggregate(&[contrib(0, 1, true, &[1.0])]).unwrap();
        assert_eq!(out.0, vec![1.0]); // re-adopts average
    }
}
