//! [`SweepSpec`] — declarative description of an experiment grid.
//!
//! A sweep is the cartesian product of up to five axes (federation mode ×
//! strategy × label skew × node count × seed) over a shared base
//! [`ExperimentConfig`]. The paper's tables are exactly such grids (e.g.
//! Table 2 is strategies × node counts at fixed skew, three seeds per
//! cell), so one spec regenerates one table.
//!
//! Specs are written as JSON and parsed with the crate's own
//! [`crate::util::json`] layer (the image carries no serde). Every scalar
//! config key doubles as a single-value axis: `"n_nodes": 2` and
//! `"n_nodes": [2, 3, 5]` are both accepted.

use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::compress::CodecKind;
use crate::config::{threads_label, ExperimentConfig, FederationMode, StoreKind};
use crate::store::{AdversarySpec, LatencyConfig};
use crate::strategy::StrategyKind;
use crate::util::json::Json;

/// One cell of the sweep grid: a unique (mode, strategy, skew, n_nodes,
/// compress, threads, adversary) combination. Seeds are *trials within* a
/// cell, not part of the key — the report aggregates across them.
#[derive(Clone, Debug, PartialEq)]
pub struct CellKey {
    /// Federation protocol of this cell.
    pub mode: FederationMode,
    /// Aggregation strategy of this cell.
    pub strategy: StrategyKind,
    /// Label skew of this cell.
    pub skew: f64,
    /// Node count of this cell.
    pub n_nodes: usize,
    /// Wire codec of this cell.
    pub compress: CodecKind,
    /// Kernel-pool worker count of this cell (0 = auto). A pure
    /// wall-clock axis: the [`crate::par`] determinism contract makes
    /// every experiment metric identical across `threads` cells.
    pub threads: usize,
    /// Per-round client sampling fraction of this cell (1.0 = full
    /// participation, the legacy behavior and label).
    pub participation: f64,
    /// Per-operation transient store-failure probability of this cell
    /// (`"fault"` axis; 0.0 = no injection, the legacy behavior and
    /// label). Faulty cells run every node behind a retrying store
    /// client, so the axis measures chaos overhead, not just failure.
    pub fault: f64,
    /// Content adversary of this cell (`None` = all clients honest). The
    /// report pairs each attacked cell with its clean sibling — the cell
    /// with the same key and `adversary = None` — in the
    /// `acc clean` / `acc attacked` columns.
    pub adversary: Option<AdversarySpec>,
}

impl CellKey {
    /// Filesystem- and table-safe label, e.g. `async_fedavg_s0.9_n2`
    /// (gossip cells carry the fanout — `gossip3_...` — parameterized
    /// strategies their parameter — `..._krum2_...` — compressed
    /// cells the codec — `..._n2_q8` — multi-threaded cells the
    /// worker count — `..._t8` / `..._tauto` — and attacked cells the
    /// adversary label — `..._byz1` — so no two cells ever share a
    /// store namespace or report row).
    pub fn label(&self) -> String {
        let compress = match self.compress {
            CodecKind::None => String::new(),
            other => format!("_{}", other.label()),
        };
        let threads = match self.threads {
            1 => String::new(),
            other => format!("_t{}", threads_label(other)),
        };
        let participation = if self.participation < 1.0 {
            format!("_p{}", self.participation)
        } else {
            String::new()
        };
        let fault = if self.fault > 0.0 {
            format!("_f{}", self.fault)
        } else {
            String::new()
        };
        let adversary = match &self.adversary {
            None => String::new(),
            Some(a) => format!("_{}", a.label()),
        };
        format!(
            "{}_{}_s{}_n{}{compress}{threads}{participation}{fault}{adversary}",
            self.mode.label(),
            self.strategy.label(),
            self.skew,
            self.n_nodes
        )
    }
}

/// One concrete trial produced by [`SweepSpec::expand`]: a fully resolved
/// [`ExperimentConfig`] plus its position in the grid.
#[derive(Clone, Debug)]
pub struct SweepTrial {
    /// Position in the expanded trial list (also the scheduler's queue id).
    pub trial_index: usize,
    /// Index into [`SweepSpec::cells`] — which grid cell this trial fills.
    pub cell_index: usize,
    /// The resolved per-trial configuration (seed and, for filesystem
    /// stores, a namespaced store path already applied).
    pub cfg: ExperimentConfig,
}

/// A grid of experiments: base config + axes + scheduler width.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Shared settings for every trial (model, epochs, sizes, store, ...).
    pub base: ExperimentConfig,
    /// Federation-mode axis.
    pub modes: Vec<FederationMode>,
    /// Strategy axis.
    pub strategies: Vec<StrategyKind>,
    /// Label-skew axis.
    pub skews: Vec<f64>,
    /// Node-count axis.
    pub node_counts: Vec<usize>,
    /// Wire-codec axis (`"compress"` key: `none`, `q8`, `topk:<frac>`,
    /// `delta-q8`).
    pub compressions: Vec<CodecKind>,
    /// Kernel-pool worker-count axis (`"threads"` key: integers or
    /// `"auto"`; 0 encodes auto). Wall-clock only — results are
    /// bit-identical across values.
    pub threads: Vec<usize>,
    /// Per-round client-sampling axis (`"participation"` key: fractions
    /// in (0, 1]; 1.0 cells run the legacy full-participation path).
    pub participations: Vec<f64>,
    /// Transient store-failure axis (`"fault"` key: probabilities in
    /// [0, 1]; 0.0 cells run without fault injection). Scheduled
    /// `"outage"` windows and `"sync_quorum"` are base scalars shared by
    /// every cell.
    pub faults: Vec<f64>,
    /// Content-adversary axis (`"adversary"` key: `"none"` or specs like
    /// `"byzantine:1"`). `None` cells run all-honest; the report pairs
    /// attacked cells with their clean siblings.
    pub adversaries: Vec<Option<AdversarySpec>>,
    /// Seeds to run per cell (each seed is one trial).
    pub seeds: Vec<u64>,
    /// Worker threads for the scheduler; 0 = automatic
    /// ([`crate::sweep::default_jobs`]).
    pub jobs: usize,
}

impl SweepSpec {
    /// A 1×1×1×1 sweep over `base` (every axis a singleton of the base
    /// value) — the starting point for programmatic construction.
    pub fn from_base(base: ExperimentConfig) -> Self {
        SweepSpec {
            modes: vec![base.mode],
            strategies: vec![base.strategy],
            skews: vec![base.skew],
            node_counts: vec![base.n_nodes],
            compressions: vec![base.compress],
            threads: vec![base.threads],
            participations: vec![base.participation],
            faults: vec![base.fault.p_fail],
            adversaries: vec![base.adversary],
            seeds: vec![base.seed],
            jobs: 0,
            base,
        }
    }

    /// Parse a JSON sweep spec.
    ///
    /// Recognized keys — axes (scalar or array): `modes`, `strategies`,
    /// `skews`, `n_nodes`, `compress` (wire codec: `"none"`, `"q8"`,
    /// `"topk:0.1"`, `"delta-q8"`), `adversary` (content attack:
    /// `"none"`, `"byzantine:k"`, `"scale:<f>"`, `"signflip:k"`,
    /// `"stale:<r>"`), `fault` (transient store-failure probabilities in
    /// [0, 1]), `robust` (robust strategies appended to the
    /// strategy axis: `"median"`, `"trimmed-mean:<frac>"`, `"krum:f"`,
    /// `"trust-weighted"`), `seeds`; `trials: T` is shorthand
    /// for `seeds = [seed, seed + 1000, ...]` (the
    /// [`crate::sim::run_trials`] seed schedule). Scalars forwarded to the base config: `model`, `epochs`,
    /// `steps_per_epoch`, `sample_prob`, `train_size`, `test_size`,
    /// `seed`, `store`, `latency`, `sync_timeout_s`, `clock` (`"virtual"`
    /// runs every trial on its own simulated clock — straggler/latency
    /// grids at CPU speed, deterministic per-cell `wall_clock_s`),
    /// `log_dir`, `verbose`, `divergence` (bool: trace every trial and
    /// add the `mean div L2` report column — see [`crate::trace`]),
    /// `outage` (scheduled store-outage windows `"<start_s>:<dur_s>"`,
    /// scalar or array, shared by every cell), `sync_quorum` (degraded
    /// sync-round quorum fraction in (0, 1], shared by every cell).
    /// Scheduler width: `jobs`. Unknown keys are errors (typo
    /// protection).
    pub fn parse_json(text: &str) -> Result<SweepSpec> {
        let j = Json::parse(text).map_err(|e| anyhow!("sweep spec: {e}"))?;
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow!("sweep spec must be a JSON object"))?;

        const KNOWN: &[&str] = &[
            "model", "epochs", "steps_per_epoch", "sample_prob", "train_size", "test_size",
            "seed", "store", "latency", "sync_timeout_s", "clock", "log_dir", "verbose",
            "modes", "strategies", "skews", "n_nodes", "compress", "threads", "seeds",
            "adversary", "robust", "trials", "jobs", "participation", "availability",
            "scheduler", "divergence", "fault", "outage", "sync_quorum",
        ];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                bail!("sweep spec: unknown key {key:?} (known keys: {KNOWN:?})");
            }
        }

        let mut base = ExperimentConfig::default();
        if let Some(v) = obj.get("model") {
            base.model = req_str(v, "model")?.to_string();
        }
        if let Some(v) = obj.get("epochs") {
            base.epochs = req_usize(v, "epochs")?;
        }
        if let Some(v) = obj.get("steps_per_epoch") {
            base.steps_per_epoch = req_usize(v, "steps_per_epoch")?;
        }
        if let Some(v) = obj.get("sample_prob") {
            base.sample_prob = req_f64(v, "sample_prob")?;
        }
        if let Some(v) = obj.get("train_size") {
            base.train_size = req_usize(v, "train_size")?;
        }
        if let Some(v) = obj.get("test_size") {
            base.test_size = req_usize(v, "test_size")?;
        }
        if let Some(v) = obj.get("seed") {
            base.seed = req_u64(v, "seed")?;
        }
        if let Some(v) = obj.get("store") {
            let s = req_str(v, "store")?;
            base.store = StoreKind::parse(s)
                .ok_or_else(|| anyhow!("sweep spec: unknown store {s:?}"))?;
        }
        if let Some(v) = obj.get("latency") {
            base.latency = parse_latency(v)?;
        }
        if let Some(v) = obj.get("sync_timeout_s") {
            base.sync_timeout = Duration::from_secs_f64(req_f64(v, "sync_timeout_s")?);
        }
        if let Some(v) = obj.get("clock") {
            let s = req_str(v, "clock")?;
            base.clock = crate::time::ClockKind::parse(s)
                .ok_or_else(|| anyhow!("sweep spec: unknown clock {s:?}"))?;
        }
        if let Some(v) = obj.get("scheduler") {
            let s = req_str(v, "scheduler")?;
            base.scheduler = crate::sched::SchedulerKind::parse(s)
                .ok_or_else(|| anyhow!("sweep spec: unknown scheduler {s:?}"))?;
        }
        if let Some(v) = obj.get("availability") {
            let s = req_str(v, "availability")?;
            base.availability = crate::sched::AvailabilitySpec::parse(s)
                .ok_or_else(|| anyhow!("sweep spec: unknown availability {s:?}"))?;
        }
        if let Some(v) = obj.get("outage") {
            // one window string or an array of them, shared by every cell
            base.fault.outages = axis(v, "outage", |x| {
                x.as_str().and_then(crate::store::OutageWindow::parse)
            })?;
        }
        if let Some(v) = obj.get("sync_quorum") {
            base.sync_quorum = req_f64(v, "sync_quorum")?;
        }
        if let Some(v) = obj.get("log_dir") {
            base.log_dir = Some(req_str(v, "log_dir")?.into());
        }
        if let Some(v) = obj.get("verbose") {
            base.verbose = v
                .as_bool()
                .ok_or_else(|| anyhow!("sweep spec: verbose must be a bool"))?;
        }
        if let Some(v) = obj.get("divergence") {
            base.trace = v
                .as_bool()
                .ok_or_else(|| anyhow!("sweep spec: divergence must be a bool"))?;
        }

        let modes = match obj.get("modes") {
            None => vec![base.mode],
            Some(v) => axis(v, "modes", |x| {
                x.as_str().and_then(FederationMode::parse)
            })?,
        };
        let mut strategies = match obj.get("strategies") {
            None => vec![base.strategy],
            Some(v) => axis(v, "strategies", |x| x.as_str().and_then(StrategyKind::parse))?,
        };
        // `robust` appends robust strategies to the strategy axis (so
        // attack grids read `"strategies": ["fedavg"], "robust":
        // ["median", "krum:1"]`); every entry must actually be robust.
        if let Some(v) = obj.get("robust") {
            let extra = axis(v, "robust", |x| {
                x.as_str().and_then(StrategyKind::parse).filter(|k| k.is_robust())
            })?;
            for kind in extra {
                if !strategies.contains(&kind) {
                    strategies.push(kind);
                }
            }
        }
        let skews = match obj.get("skews") {
            None => vec![base.skew],
            Some(v) => axis(v, "skews", Json::as_f64)?,
        };
        let node_counts = match obj.get("n_nodes") {
            None => vec![base.n_nodes],
            Some(v) => axis(v, "n_nodes", |x| int_of(x).map(|n| n as usize))?,
        };
        let compressions = match obj.get("compress") {
            None => vec![base.compress],
            Some(v) => axis(v, "compress", |x| x.as_str().and_then(CodecKind::parse))?,
        };
        let threads = match obj.get("threads") {
            None => vec![base.threads],
            // integers or the string "auto" (also accepted as a number
            // is rejected: 0 must be spelled auto, like the config key)
            Some(v) => axis(v, "threads", |x| match x.as_str() {
                Some(s) => crate::config::parse_threads(s),
                None => int_of(x).map(|n| n as usize).filter(|&n| n >= 1),
            })?,
        };
        let participations = match obj.get("participation") {
            None => vec![base.participation],
            Some(v) => axis(v, "participation", Json::as_f64)?,
        };
        let faults = match obj.get("fault") {
            None => vec![base.fault.p_fail],
            Some(v) => axis(v, "fault", |x| {
                x.as_f64().filter(|p| (0.0..=1.0).contains(p))
            })?,
        };
        let adversaries = match obj.get("adversary") {
            None => vec![base.adversary],
            Some(v) => axis(v, "adversary", |x| match x.as_str() {
                Some("none") => Some(None),
                Some(s) => AdversarySpec::parse(s).map(Some),
                None => None,
            })?,
        };

        let seeds = match (obj.get("seeds"), obj.get("trials")) {
            (Some(_), Some(_)) => {
                bail!("sweep spec: give either `seeds` or `trials`, not both")
            }
            (Some(v), None) => axis(v, "seeds", |x| int_of(x).map(|n| n as u64))?,
            (None, Some(v)) => {
                let t = req_usize(v, "trials")?;
                anyhow::ensure!(t >= 1, "sweep spec: trials must be >= 1");
                // Same seed schedule as crate::sim::run_trials.
                (0..t).map(|i| base.seed.wrapping_add(1000 * i as u64)).collect()
            }
            (None, None) => vec![base.seed],
        };

        let jobs = match obj.get("jobs") {
            None => 0,
            Some(v) => req_usize(v, "jobs")?,
        };

        Ok(SweepSpec {
            base,
            modes,
            strategies,
            skews,
            node_counts,
            compressions,
            threads,
            participations,
            faults,
            adversaries,
            seeds,
            jobs,
        })
    }

    /// The grid cells in deterministic (mode, strategy, skew, n_nodes,
    /// compress, threads, participation, fault, adversary) nested order
    /// — the row order of the report. The adversary axis is innermost,
    /// so each attacked cell sits right after its clean sibling when
    /// `"adversary"` starts with `"none"`.
    pub fn cells(&self) -> Vec<CellKey> {
        let mut out =
            Vec::with_capacity(self.modes.len() * self.strategies.len() * self.skews.len());
        for &mode in &self.modes {
            for &strategy in &self.strategies {
                for &skew in &self.skews {
                    for &n_nodes in &self.node_counts {
                        for &compress in &self.compressions {
                            for &threads in &self.threads {
                                for &participation in &self.participations {
                                    for &fault in &self.faults {
                                        for &adversary in &self.adversaries {
                                            out.push(CellKey {
                                                mode,
                                                strategy,
                                                skew,
                                                n_nodes,
                                                compress,
                                                threads,
                                                participation,
                                                fault,
                                                adversary,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Total trial count: cells × seeds.
    pub fn n_trials(&self) -> usize {
        self.cells().len() * self.seeds.len()
    }

    /// Expand the grid into concrete, validated trial configs.
    ///
    /// Per-trial store namespacing: with a filesystem store, each trial
    /// gets its own `<root>/<cell label>/seed<seed>` directory so
    /// concurrent trials never share a blob namespace (in-process stores
    /// are already private — [`crate::sim::run_experiment`] constructs a
    /// fresh one per call).
    pub fn expand(&self) -> Result<Vec<SweepTrial>> {
        anyhow::ensure!(!self.seeds.is_empty(), "sweep needs at least one seed");
        // Distinct seeds are what make trials distinct — a duplicate would
        // rerun the identical experiment and, for filesystem stores, share
        // (and mid-run clear) one blob namespace and log directory.
        let mut uniq = self.seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        anyhow::ensure!(
            uniq.len() == self.seeds.len(),
            "sweep seeds must be distinct, got {:?}",
            self.seeds
        );
        let mut out = Vec::with_capacity(self.n_trials());
        for (cell_index, cell) in self.cells().iter().enumerate() {
            for &seed in &self.seeds {
                let mut cfg = self.base.clone();
                cfg.mode = cell.mode;
                cfg.strategy = cell.strategy;
                cfg.skew = cell.skew;
                cfg.n_nodes = cell.n_nodes;
                cfg.compress = cell.compress;
                cfg.threads = cell.threads;
                cfg.participation = cell.participation;
                cfg.fault.p_fail = cell.fault; // base outage windows are shared
                cfg.adversary = cell.adversary;
                cfg.seed = seed;
                if let StoreKind::Fs(root) = &self.base.store {
                    cfg.store =
                        StoreKind::Fs(root.join(cell.label()).join(format!("seed{seed}")));
                }
                cfg.validate()
                    .with_context(|| format!("sweep cell {} seed {seed}", cell.label()))?;
                out.push(SweepTrial { trial_index: out.len(), cell_index, cfg });
            }
        }
        Ok(out)
    }
}

fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.as_str().ok_or_else(|| anyhow!("sweep spec: {key} must be a string"))
}

fn req_f64(v: &Json, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("sweep spec: {key} must be a number"))
}

/// The value as a non-negative integral number — rejects fractions,
/// negatives, and values beyond f64's exact-integer range (2^53) instead
/// of silently truncating/saturating them.
fn int_of(v: &Json) -> Option<f64> {
    const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    v.as_f64().filter(|n| n.fract() == 0.0 && (0.0..=MAX_EXACT).contains(n))
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    int_of(v)
        .map(|n| n as usize)
        .ok_or_else(|| anyhow!("sweep spec: {key} must be a non-negative integer"))
}

fn req_u64(v: &Json, key: &str) -> Result<u64> {
    int_of(v)
        .map(|n| n as u64)
        .ok_or_else(|| anyhow!("sweep spec: {key} must be a non-negative integer"))
}

/// Read an axis value that may be a scalar or an array of scalars.
fn axis<T>(v: &Json, key: &str, f: impl Fn(&Json) -> Option<T>) -> Result<Vec<T>> {
    let items: Vec<&Json> = match v {
        Json::Arr(xs) => xs.iter().collect(),
        other => vec![other],
    };
    anyhow::ensure!(!items.is_empty(), "sweep spec: axis {key} must be non-empty");
    items
        .into_iter()
        .map(|x| f(x).ok_or_else(|| anyhow!("sweep spec: bad value in axis {key}: {x:?}")))
        .collect()
}

/// `"none"`, `"s3"`, or a number of milliseconds — same values as the
/// `latency` key of the `key = value` config format.
fn parse_latency(v: &Json) -> Result<Option<LatencyConfig>> {
    match v {
        Json::Str(s) if s == "none" => Ok(None),
        Json::Str(s) if s == "s3" => Ok(Some(LatencyConfig::s3_like())),
        Json::Num(ms) => Ok(Some(LatencyConfig::from_ms(*ms))),
        _ => bail!("sweep spec: latency must be \"none\", \"s3\", or milliseconds"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let spec = SweepSpec::parse_json(
            r#"{
                "model": "mnist",
                "modes": ["sync", "async"],
                "strategies": ["fedavg", "fedavgm"],
                "skews": [0.0, 0.9],
                "n_nodes": [2, 5],
                "seeds": [42, 43],
                "epochs": 2,
                "steps_per_epoch": 25,
                "train_size": 2000,
                "test_size": 320,
                "store": "sharded:4",
                "jobs": 3
            }"#,
        )
        .unwrap();
        assert_eq!(spec.modes, vec![FederationMode::Sync, FederationMode::Async]);
        assert_eq!(spec.strategies, vec![StrategyKind::FedAvg, StrategyKind::FedAvgM]);
        assert_eq!(spec.skews, vec![0.0, 0.9]);
        assert_eq!(spec.node_counts, vec![2, 5]);
        assert_eq!(spec.seeds, vec![42, 43]);
        assert_eq!(spec.base.store, StoreKind::Sharded(4));
        assert_eq!(spec.jobs, 3);
        assert_eq!(spec.cells().len(), 8);
        assert_eq!(spec.n_trials(), 16);
    }

    #[test]
    fn defaults_are_singleton_axes() {
        let spec = SweepSpec::parse_json("{}").unwrap();
        assert_eq!(spec.n_trials(), 1);
        let d = ExperimentConfig::default();
        assert_eq!(spec.modes, vec![d.mode]);
        assert_eq!(spec.seeds, vec![d.seed]);
        assert_eq!(spec.jobs, 0);
    }

    #[test]
    fn scalar_axis_values_accepted() {
        let spec =
            SweepSpec::parse_json(r#"{"modes": "sync", "n_nodes": 3, "skews": 0.5}"#).unwrap();
        assert_eq!(spec.modes, vec![FederationMode::Sync]);
        assert_eq!(spec.node_counts, vec![3]);
        assert_eq!(spec.skews, vec![0.5]);
    }

    #[test]
    fn trials_shorthand_matches_run_trials_schedule() {
        let spec = SweepSpec::parse_json(r#"{"seed": 7, "trials": 3}"#).unwrap();
        assert_eq!(spec.seeds, vec![7, 1007, 2007]);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(SweepSpec::parse_json(r#"{"strategy": "fedavg"}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"modes": ["warp"]}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"seeds": [1], "trials": 2}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"modes": []}"#).is_err());
        assert!(SweepSpec::parse_json(r#"[1, 2]"#).is_err());
    }

    #[test]
    fn rejects_non_integral_and_negative_integers() {
        // no silent truncation/saturation of bad numeric values
        assert!(SweepSpec::parse_json(r#"{"seed": -1}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"epochs": 2.9}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"seeds": [1.5, 1.7]}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"n_nodes": [2.5]}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"jobs": -2}"#).is_err());
        // beyond f64's exact-integer range: reject, don't saturate
        assert!(SweepSpec::parse_json(r#"{"train_size": 1e300}"#).is_err());
    }

    #[test]
    fn expand_rejects_duplicate_seeds() {
        let spec = SweepSpec::parse_json(r#"{"seeds": [5, 5]}"#).unwrap();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn expand_resolves_every_cell_and_seed() {
        let spec = SweepSpec::parse_json(
            r#"{"modes": ["sync", "async"], "skews": [0.0, 0.9], "seeds": [1, 2]}"#,
        )
        .unwrap();
        let trials = spec.expand().unwrap();
        assert_eq!(trials.len(), 8);
        // trials are grouped by cell, seeds innermost
        assert_eq!(trials[0].cell_index, 0);
        assert_eq!(trials[1].cell_index, 0);
        assert_eq!(trials[2].cell_index, 1);
        assert_eq!(trials[0].cfg.seed, 1);
        assert_eq!(trials[1].cfg.seed, 2);
        assert_eq!(trials[3].cfg.mode, FederationMode::Sync);
        assert_eq!(trials[3].cfg.skew, 0.9);
        for (i, t) in trials.iter().enumerate() {
            assert_eq!(t.trial_index, i);
        }
    }

    #[test]
    fn fs_store_is_namespaced_per_trial() {
        let spec = SweepSpec::parse_json(
            r#"{"store": "fs:/tmp/sweep", "modes": ["sync", "async"], "seeds": [1, 2]}"#,
        )
        .unwrap();
        let trials = spec.expand().unwrap();
        let mut dirs: Vec<String> = trials
            .iter()
            .map(|t| match &t.cfg.store {
                StoreKind::Fs(p) => p.display().to_string(),
                other => panic!("expected fs store, got {other:?}"),
            })
            .collect();
        assert!(dirs[0].starts_with("/tmp/sweep/"));
        assert!(dirs[0].ends_with("seed1"));
        dirs.sort();
        dirs.dedup();
        assert_eq!(dirs.len(), trials.len(), "every trial needs its own namespace");
    }

    #[test]
    fn expand_rejects_invalid_cells() {
        // train_size smaller than a cell's node count violates
        // ExperimentConfig::validate
        let spec =
            SweepSpec::parse_json(r#"{"train_size": 3, "n_nodes": [2, 5]}"#).unwrap();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn gossip_mode_axis_parses_with_fanout() {
        let spec = SweepSpec::parse_json(
            r#"{"modes": ["local", "sync", "async", "gossip:3"], "n_nodes": 3}"#,
        )
        .unwrap();
        assert_eq!(spec.modes.len(), 4);
        assert_eq!(spec.modes[3], FederationMode::Gossip { fanout: 3 });
        // all four protocol families expand into one grid
        let trials = spec.expand().unwrap();
        assert_eq!(trials.len(), 4);
        assert!(trials[3].cfg.validate().is_ok());
    }

    #[test]
    fn gossip_fanouts_get_distinct_cells_and_labels() {
        let spec =
            SweepSpec::parse_json(r#"{"modes": ["gossip:1", "gossip:2"]}"#).unwrap();
        let cells = spec.cells();
        assert_eq!(cells.len(), 2);
        assert_ne!(cells[0], cells[1]);
        assert!(cells[0].label().starts_with("gossip1_"));
        assert!(cells[1].label().starts_with("gossip2_"));
    }

    #[test]
    fn compress_axis_expands_into_distinct_cells() {
        let spec = SweepSpec::parse_json(
            r#"{"modes": "async", "compress": ["none", "q8", "topk:0.1", "delta-q8"]}"#,
        )
        .unwrap();
        assert_eq!(
            spec.compressions,
            vec![
                CodecKind::None,
                CodecKind::Q8,
                CodecKind::TopK { frac: 0.1 },
                CodecKind::DeltaQ8
            ]
        );
        let cells = spec.cells();
        assert_eq!(cells.len(), 4);
        // the uncompressed cell keeps the legacy label; codec cells are
        // suffixed, so no two cells share a store namespace
        assert_eq!(cells[0].label(), "async_fedavg_s0_n2");
        assert_eq!(cells[1].label(), "async_fedavg_s0_n2_q8");
        assert_eq!(cells[2].label(), "async_fedavg_s0_n2_topk0.1");
        assert_eq!(cells[3].label(), "async_fedavg_s0_n2_delta-q8");
        let trials = spec.expand().unwrap();
        assert_eq!(trials.len(), 4);
        assert_eq!(trials[1].cfg.compress, CodecKind::Q8);
        // scalar value and default also work
        let spec = SweepSpec::parse_json(r#"{"compress": "q8"}"#).unwrap();
        assert_eq!(spec.compressions, vec![CodecKind::Q8]);
        let spec = SweepSpec::parse_json("{}").unwrap();
        assert_eq!(spec.compressions, vec![CodecKind::None]);
        // bad values are rejected
        assert!(SweepSpec::parse_json(r#"{"compress": "zip"}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"compress": ["topk:0"]}"#).is_err());
    }

    #[test]
    fn threads_axis_expands_with_auto_and_distinct_labels() {
        let spec =
            SweepSpec::parse_json(r#"{"threads": [1, 8, "auto"]}"#).unwrap();
        assert_eq!(spec.threads, vec![1, 8, 0]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 3);
        // the single-threaded cell keeps the legacy label; others are
        // suffixed so no two cells share a store namespace
        assert_eq!(cells[0].label(), "async_fedavg_s0_n2");
        assert_eq!(cells[1].label(), "async_fedavg_s0_n2_t8");
        assert_eq!(cells[2].label(), "async_fedavg_s0_n2_tauto");
        let trials = spec.expand().unwrap();
        assert_eq!(trials.len(), 3);
        assert_eq!(trials[1].cfg.threads, 8);
        assert_eq!(trials[2].cfg.threads, 0);
        // scalar value and default also work
        let spec = SweepSpec::parse_json(r#"{"threads": "auto"}"#).unwrap();
        assert_eq!(spec.threads, vec![0]);
        let spec = SweepSpec::parse_json("{}").unwrap();
        assert_eq!(spec.threads, vec![1]);
        // bad values are rejected: 0 must be spelled auto
        assert!(SweepSpec::parse_json(r#"{"threads": 0}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"threads": ["lots"]}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"threads": [2.5]}"#).is_err());
    }

    #[test]
    fn adversary_axis_expands_with_clean_sibling_first() {
        let spec = SweepSpec::parse_json(
            r#"{"modes": "sync", "adversary": ["none", "byzantine:1", "scale:10"], "n_nodes": 4}"#,
        )
        .unwrap();
        assert_eq!(spec.adversaries.len(), 3);
        assert_eq!(spec.adversaries[0], None);
        let cells = spec.cells();
        assert_eq!(cells.len(), 3);
        // the clean cell keeps the legacy label; attacked cells are
        // suffixed, and the adversary axis is innermost so the clean
        // sibling leads its group
        assert_eq!(cells[0].label(), "sync_fedavg_s0_n4");
        assert_eq!(cells[1].label(), "sync_fedavg_s0_n4_byz1");
        assert_eq!(cells[2].label(), "sync_fedavg_s0_n4_scale10");
        let trials = spec.expand().unwrap();
        assert!(trials[0].cfg.adversary.is_none());
        assert_eq!(trials[1].cfg.adversary, AdversarySpec::parse("byzantine:1"));
        // default is the honest singleton
        let spec = SweepSpec::parse_json("{}").unwrap();
        assert_eq!(spec.adversaries, vec![None]);
        // bad values are rejected
        assert!(SweepSpec::parse_json(r#"{"adversary": "gremlin"}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"adversary": [3]}"#).is_err());
    }

    #[test]
    fn participation_axis_expands_with_distinct_cells() {
        let spec = SweepSpec::parse_json(
            r#"{"modes": "async", "participation": [1.0, 0.5, 0.1], "n_nodes": 10}"#,
        )
        .unwrap();
        assert_eq!(spec.participations, vec![1.0, 0.5, 0.1]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 3);
        // the full-participation cell keeps the legacy label; sampled
        // cells are suffixed so no two cells share a store namespace
        assert_eq!(cells[0].label(), "async_fedavg_s0_n10");
        assert_eq!(cells[1].label(), "async_fedavg_s0_n10_p0.5");
        assert_eq!(cells[2].label(), "async_fedavg_s0_n10_p0.1");
        let trials = spec.expand().unwrap();
        assert_eq!(trials.len(), 3);
        assert_eq!(trials[1].cfg.participation, 0.5);
        // out-of-range fractions die at expand via config validation
        let spec = SweepSpec::parse_json(r#"{"participation": [0.0]}"#).unwrap();
        assert!(spec.expand().is_err());
        // scalar value and default also work
        let spec = SweepSpec::parse_json(r#"{"participation": 0.25}"#).unwrap();
        assert_eq!(spec.participations, vec![0.25]);
        let spec = SweepSpec::parse_json("{}").unwrap();
        assert_eq!(spec.participations, vec![1.0]);
    }

    #[test]
    fn fault_axis_expands_with_distinct_cells() {
        let spec = SweepSpec::parse_json(
            r#"{"modes": "async", "fault": [0.0, 0.05], "outage": "2:1", "sync_quorum": 0.75}"#,
        )
        .unwrap();
        assert_eq!(spec.faults, vec![0.0, 0.05]);
        assert_eq!(spec.base.sync_quorum, 0.75);
        assert_eq!(spec.base.fault.outages.len(), 1);
        let cells = spec.cells();
        assert_eq!(cells.len(), 2);
        // the clean cell keeps the legacy label; faulty cells are
        // suffixed so no two cells share a store namespace
        assert_eq!(cells[0].label(), "async_fedavg_s0_n2");
        assert_eq!(cells[1].label(), "async_fedavg_s0_n2_f0.05");
        let trials = spec.expand().unwrap();
        assert_eq!(trials.len(), 2);
        assert_eq!(trials[1].cfg.fault.p_fail, 0.05);
        // the shared outage windows and quorum reach every trial
        assert_eq!(trials[0].cfg.fault.outages, spec.base.fault.outages);
        assert_eq!(trials[1].cfg.sync_quorum, 0.75);
        // scalar value and default also work
        let spec = SweepSpec::parse_json(r#"{"fault": 0.1}"#).unwrap();
        assert_eq!(spec.faults, vec![0.1]);
        let spec = SweepSpec::parse_json("{}").unwrap();
        assert_eq!(spec.faults, vec![0.0]);
        // bad values are rejected
        assert!(SweepSpec::parse_json(r#"{"fault": 1.5}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"fault": "often"}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"outage": "backwards"}"#).is_err());
    }

    #[test]
    fn scheduler_and_availability_are_base_scalars() {
        use crate::sched::{AvailabilitySpec, SchedulerKind};
        let spec = SweepSpec::parse_json(
            r#"{"scheduler": "events", "clock": "virtual", "availability": "churn:0.2"}"#,
        )
        .unwrap();
        assert_eq!(spec.base.scheduler, SchedulerKind::Events);
        assert_eq!(spec.base.availability, AvailabilitySpec::Churn { p: 0.2 });
        spec.expand().unwrap();
        assert!(SweepSpec::parse_json(r#"{"scheduler": "fibers"}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"availability": "weekly"}"#).is_err());
    }

    #[test]
    fn robust_key_appends_robust_strategies() {
        let spec = SweepSpec::parse_json(
            r#"{"strategies": "fedavg", "robust": ["median", "krum:2", "trimmed-mean:0.25"]}"#,
        )
        .unwrap();
        assert_eq!(spec.strategies.len(), 4);
        assert_eq!(spec.strategies[0], StrategyKind::FedAvg);
        assert!(spec.strategies[1..].iter().all(|k| k.is_robust()));
        // duplicates collapse; parameterized strategies get distinct labels
        let spec = SweepSpec::parse_json(
            r#"{"strategies": ["median"], "robust": ["median", "krum:1"]}"#,
        )
        .unwrap();
        assert_eq!(spec.strategies.len(), 2);
        let cells = spec.cells();
        assert_eq!(cells[1].label(), "async_krum1_s0_n2");
        // non-robust strategies are rejected under `robust`
        assert!(SweepSpec::parse_json(r#"{"robust": ["fedavg"]}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"robust": ["gremlin"]}"#).is_err());
    }

    #[test]
    fn clock_values() {
        use crate::time::ClockKind;
        let spec = SweepSpec::parse_json(r#"{"clock": "virtual"}"#).unwrap();
        assert_eq!(spec.base.clock, ClockKind::Virtual);
        let spec = SweepSpec::parse_json("{}").unwrap();
        assert_eq!(spec.base.clock, ClockKind::Real);
        assert!(SweepSpec::parse_json(r#"{"clock": "sundial"}"#).is_err());
        assert!(SweepSpec::parse_json(r#"{"clock": 3}"#).is_err());
    }

    #[test]
    fn divergence_key_enables_tracing_on_the_base_config() {
        let spec = SweepSpec::parse_json(r#"{"divergence": true}"#).unwrap();
        assert!(spec.base.trace);
        spec.expand().unwrap().iter().for_each(|t| assert!(t.cfg.trace));
        let spec = SweepSpec::parse_json("{}").unwrap();
        assert!(!spec.base.trace, "tracing stays opt-in for sweeps");
        assert!(SweepSpec::parse_json(r#"{"divergence": "yes"}"#).is_err());
    }

    #[test]
    fn latency_values() {
        let spec = SweepSpec::parse_json(r#"{"latency": "s3"}"#).unwrap();
        assert!(spec.base.latency.is_some());
        let spec = SweepSpec::parse_json(r#"{"latency": 50}"#).unwrap();
        assert_eq!(spec.base.latency.unwrap().base, Duration::from_millis(50));
        let spec = SweepSpec::parse_json(r#"{"latency": "none"}"#).unwrap();
        assert!(spec.base.latency.is_none());
        assert!(SweepSpec::parse_json(r#"{"latency": true}"#).is_err());
    }
}
