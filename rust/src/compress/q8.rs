//! [`Q8`] — per-chunk affine int8 quantization (codec id 1).

use anyhow::{bail, Result};

use crate::par::ChunkPool;
use crate::tensor::FlatParams;

use super::{Codec, CodecKind};

/// Elements per quantization chunk: small enough that one outlier only
/// coarsens 256 neighbours, large enough that the 8-byte per-chunk
/// header (min + scale) stays ~3% overhead.
pub const Q8_CHUNK: usize = 256;

/// Quantization chunks per parallel work item (64 × 256 elements =
/// 64 KiB of f32 input, the kernel layer's standard granularity). A
/// constant of the wire-independent *work split* only — payload bytes
/// are a pure function of the input either way.
const PAR_GROUP: usize = 64;

/// Affine int8 quantizer: each [`Q8_CHUNK`]-element chunk stores
/// `(min: f32, scale: f32)` followed by one byte per element, with
/// `x ≈ min + scale * q`, `q ∈ [0, 255]`, `scale = (max - min) / 255`.
///
/// Wire cost: `n + 8 * ceil(n / 256)` bytes — ~3.88× smaller than raw
/// f32. Error bound (per element): half a quantization step,
/// `(chunk_max - chunk_min) / 255 / 2`, plus f32 rounding slop (see
/// [`Codec::error_bound`]).
///
/// Every 256-element chunk encodes and decodes independently, so both
/// directions run chunk-parallel on a [`ChunkPool`] with byte-identical
/// payloads for any thread count.
pub struct Q8;

/// Encode one chunk into its `8 + chunk.len()` output slot. Quantizer
/// arithmetic runs in f64 so a chunk spanning huge magnitudes (where
/// `max - min` overflows f32 to inf) still yields a finite scale and
/// finite reconstructions — a silent-NaN here would poison every peer's
/// aggregation.
fn encode_chunk(chunk: &[f32], out: &mut [u8]) {
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in chunk {
        min = min.min(x);
        max = max.max(x);
    }
    if !min.is_finite() || !max.is_finite() {
        // Degenerate chunk (empty or non-finite): store a zero range so
        // decode reproduces the min for every slot.
        min = if min.is_finite() { min } else { 0.0 };
        max = min;
    }
    // f64 range never overflows for finite f32 inputs; the f32 scale is
    // finite (<= f32::MAX / 255 * 2).
    let scale = ((max as f64 - min as f64) / 255.0) as f32;
    out[0..4].copy_from_slice(&min.to_le_bytes());
    out[4..8].copy_from_slice(&scale.to_le_bytes());
    for (slot, &x) in out[8..].iter_mut().zip(chunk) {
        *slot = if scale > 0.0 {
            ((x as f64 - min as f64) / scale as f64).round().clamp(0.0, 255.0) as u8
        } else {
            0
        };
    }
}

/// Quantize a full vector (shared with [`super::DeltaQ8`], which runs
/// the same quantizer over a delta vector): each [`PAR_GROUP`]-chunk
/// work item writes its own pre-sized output slot, so the payload is
/// byte-identical for any thread count (a sequential pool runs it
/// inline).
pub(crate) fn q8_encode_pooled(xs: &[f32], pool: ChunkPool) -> Vec<u8> {
    let chunks = xs.len().div_ceil(Q8_CHUNK);
    let mut out = vec![0u8; xs.len() + 8 * chunks];
    // Work-item boundaries fall on Q8_CHUNK multiples, so input and
    // output groups stay aligned (a full group is PAR_GROUP chunks of
    // exactly 8 + 256 bytes each; only the final group is ragged).
    let in_stride = PAR_GROUP * Q8_CHUNK;
    let out_stride = PAR_GROUP * (Q8_CHUNK + 8);
    let items: Vec<(&[f32], &mut [u8])> =
        xs.chunks(in_stride).zip(out.chunks_mut(out_stride)).collect();
    pool.for_each(items, |_, (src, dst)| {
        let mut at = 0;
        for chunk in src.chunks(Q8_CHUNK) {
            encode_chunk(chunk, &mut dst[at..at + 8 + chunk.len()]);
            at += 8 + chunk.len();
        }
    });
    out
}

/// Decode one work item's worth of chunks (validating each chunk header).
fn decode_group(dst: &mut [f32], src: &[u8]) -> Result<()> {
    let mut at = 0usize;
    for chunk in dst.chunks_mut(Q8_CHUNK) {
        let take = chunk.len();
        let min = f32::from_le_bytes(src[at..at + 4].try_into().unwrap());
        let scale = f32::from_le_bytes(src[at + 4..at + 8].try_into().unwrap());
        if !min.is_finite() || !scale.is_finite() || scale < 0.0 {
            bail!("q8 chunk header is not a finite (min, scale >= 0) pair");
        }
        at += 8;
        for (d, &q) in chunk.iter_mut().zip(&src[at..at + take]) {
            // f64 keeps min + scale * 255 finite even for chunks spanning
            // the full f32 range (mirrors the encoder's arithmetic)
            *d = (min as f64 + scale as f64 * q as f64) as f32;
        }
        at += take;
    }
    Ok(())
}

/// Dequantize `n` elements from a [`q8_encode_pooled`] payload; chunk
/// boundaries are fixed by the wire layout, so the reconstruction is
/// bit-identical for any thread count.
pub(crate) fn q8_decode_pooled(payload: &[u8], n: usize, pool: ChunkPool) -> Result<Vec<f32>> {
    let chunks = n.div_ceil(Q8_CHUNK);
    let want = n
        .checked_add(chunks.checked_mul(8).ok_or_else(|| anyhow::anyhow!("q8 size overflow"))?)
        .ok_or_else(|| anyhow::anyhow!("q8 size overflow"))?;
    if payload.len() != want {
        bail!("q8 payload is {} bytes, want {} for {} elements", payload.len(), want, n);
    }
    let mut out = vec![0.0f32; n];
    let in_stride = PAR_GROUP * Q8_CHUNK;
    let pay_stride = PAR_GROUP * (Q8_CHUNK + 8);
    // Equal group counts on both sides: a full group of PAR_GROUP chunks
    // consumes exactly in_stride elements and pay_stride bytes, and the
    // validated total sizes make the ragged tails line up too.
    let items: Vec<(&mut [f32], &[u8])> =
        out.chunks_mut(in_stride).zip(payload.chunks(pay_stride)).collect();
    let results = pool.map(items, |_, (dst, src)| decode_group(dst, src));
    for r in results {
        r?;
    }
    Ok(out)
}

/// Documented per-element bound for [`q8_encode_pooled`]: half a quantization
/// step on the widest chunk, with slop for the f32 arithmetic of the
/// quantizer itself (a few ulps of the chunk magnitude, covered by the
/// relative term, plus an absolute floor for near-zero ranges).
pub(crate) fn q8_error_bound(xs: &[f32]) -> f32 {
    let mut worst = 0.0f32;
    let mut mag = 0.0f32;
    for chunk in xs.chunks(Q8_CHUNK) {
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        for &x in chunk {
            min = min.min(x);
            max = max.max(x);
        }
        if min.is_finite() && max.is_finite() {
            worst = worst.max(((max as f64 - min as f64) / 255.0 * 0.5) as f32);
            mag = mag.max(min.abs().max(max.abs()));
        }
    }
    worst * (1.0 + 1e-3) + mag * 8.0 * f32::EPSILON + f32::EPSILON
}

impl Codec for Q8 {
    fn kind(&self) -> CodecKind {
        CodecKind::Q8
    }

    fn encode_pooled(
        &self,
        params: &FlatParams,
        _base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Vec<u8> {
        q8_encode_pooled(params.as_slice(), pool)
    }

    fn decode_pooled(
        &self,
        payload: &[u8],
        n: usize,
        _base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Result<FlatParams> {
        Ok(FlatParams(q8_decode_pooled(payload, n, pool)?))
    }

    fn error_bound(&self, params: &FlatParams, _base: Option<&FlatParams>) -> f32 {
        q8_error_bound(params.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_size_is_about_a_quarter_of_raw() {
        let p = FlatParams((0..10_000).map(|i| (i as f32).sin()).collect());
        let enc = Q8.encode(&p, None);
        assert_eq!(enc.len(), 10_000 + 8 * 40);
        assert!((p.len() * 4) as f64 / enc.len() as f64 > 3.8);
    }

    #[test]
    fn uniform_chunk_is_lossless() {
        let p = FlatParams(vec![3.25; 600]);
        let dec = Q8.decode(&Q8.encode(&p, None), 600, None).unwrap();
        assert_eq!(dec.0, p.0, "zero-range chunks reproduce exactly");
    }

    #[test]
    fn respects_error_bound_on_varied_data() {
        let p = FlatParams(
            (0..5_000)
                .map(|i| ((i as f32) * 0.37).sin() * (1.0 + (i % 7) as f32))
                .collect(),
        );
        let bound = Q8.error_bound(&p, None);
        let dec = Q8.decode(&Q8.encode(&p, None), p.len(), None).unwrap();
        assert!(bound > 0.0);
        assert!(
            p.max_abs_diff(&dec) <= bound,
            "max err {} > bound {}",
            p.max_abs_diff(&dec),
            bound
        );
    }

    #[test]
    fn pooled_encode_decode_matches_sequential_bitwise() {
        // spans several PAR_GROUP work items plus ragged chunk and group
        // tails
        for n in [0, 1, 255, 256, 257, PAR_GROUP * Q8_CHUNK, 2 * PAR_GROUP * Q8_CHUNK + 300] {
            let p: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.071).sin()).collect();
            let seq = ChunkPool::sequential();
            let enc_seq = q8_encode_pooled(&p, seq);
            for threads in [2, 8] {
                let pool = ChunkPool::new(threads);
                assert_eq!(q8_encode_pooled(&p, pool), enc_seq, "n={n} threads={threads}");
                let dec_seq = q8_decode_pooled(&enc_seq, n, seq).unwrap();
                let dec_par = q8_decode_pooled(&enc_seq, n, pool).unwrap();
                assert_eq!(
                    dec_seq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    dec_par.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn full_f32_range_chunk_stays_finite() {
        // max - min overflows f32 to inf here; the f64 quantizer path
        // must still produce a finite scale and finite reconstructions
        // (a silent NaN would poison every peer's aggregation).
        let mut xs = vec![0.0f32; 300];
        xs[0] = 3.0e38;
        xs[1] = -3.0e38;
        let p = FlatParams(xs);
        let enc = Q8.encode(&p, None);
        let dec = Q8.decode(&enc, 300, None).unwrap();
        assert!(dec.all_finite(), "reconstruction must never contain NaN/inf");
        let bound = Q8.error_bound(&p, None);
        assert!(bound.is_finite());
        assert!(p.max_abs_diff(&dec) <= bound);
    }

    #[test]
    fn non_finite_chunk_header_is_an_error() {
        let p = FlatParams(vec![1.0; 10]);
        let mut enc = Q8.encode(&p, None);
        enc[4..8].copy_from_slice(&f32::NAN.to_le_bytes()); // scale slot
        assert!(Q8.decode(&enc, 10, None).is_err());
        // the parallel path reports the same corruption
        assert!(Q8.decode_pooled(&enc, 10, None, ChunkPool::new(4)).is_err());
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let p = FlatParams(vec![1.0; 300]);
        let enc = Q8.encode(&p, None);
        assert!(Q8.decode(&enc[..enc.len() - 1], 300, None).is_err());
        assert!(Q8.decode(&enc, 299, None).is_err());
    }

    #[test]
    fn empty_vector_round_trips() {
        let p = FlatParams(vec![]);
        let enc = Q8.encode(&p, None);
        assert!(enc.is_empty());
        assert!(Q8.decode(&enc, 0, None).unwrap().is_empty());
    }
}
