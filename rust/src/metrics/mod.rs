//! Metrics: summary statistics (mean ± 95% CI, as the paper's tables
//! report), run logging (CSV/JSONL — the W&B substitute), and per-node
//! timelines used to regenerate the Figure-1 straggler-idle picture.

pub mod logger;
pub mod stats;
pub mod timeline;

pub use logger::RunLogger;
pub use stats::Summary;
pub use timeline::{SpanKind, Timeline};
