//! Failure robustness (paper §4.2.1): "when a node fails, the other nodes
//! keep working. While in synchronous training, the other nodes are stuck."
//!
//! Injects a crash into node 1 at epoch 1 and runs the same workload under
//! both protocols, plus a flaky-store variant (transient push/pull errors,
//! like S3 throttling) to show the async protocol shrugs those off too.
//!
//! ```sh
//! cargo run --release --example failure_robustness
//! ```

use std::time::Duration;

use fedless::config::{CrashSpec, ExperimentConfig, FederationMode};
use fedless::node::NodeStatus;
use fedless::sim::run_experiment;

fn main() -> anyhow::Result<()> {
    let base = ExperimentConfig {
        model: "mnist".into(),
        n_nodes: 3,
        epochs: 3,
        steps_per_epoch: 60,
        train_size: 4_800,
        test_size: 640,
        crash: Some(CrashSpec { node: 1, at_epoch: 1 }),
        sync_timeout: Duration::from_secs(4),
        ..Default::default()
    };

    println!("=== crash injection: node 1 dies at epoch 1 ===\n");
    for mode in [FederationMode::Sync, FederationMode::Async] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        let res = run_experiment(&cfg)?;
        println!("--- {} federation ---", mode.name());
        for r in &res.reports {
            println!(
                "  node {}: status={:?} epochs_done={}/{} wait={:.1}s",
                r.node_id,
                r.status,
                r.epochs_done,
                cfg.epochs,
                r.wait_time.as_secs_f64()
            );
        }
        println!(
            "  global model accuracy (surviving nodes): {:.4}, wall {:.1}s\n",
            res.final_accuracy, res.wall_clock_s
        );
        match mode {
            FederationMode::Sync => {
                let stalled = res
                    .reports
                    .iter()
                    .filter(|r| matches!(r.status, NodeStatus::Stalled { .. }))
                    .count();
                println!(
                    "  -> {stalled} healthy nodes STALLED at the barrier (the paper's \
                     \"other nodes are stuck\")\n"
                );
            }
            _ => {
                let done = res
                    .reports
                    .iter()
                    .filter(|r| r.status == NodeStatus::Completed)
                    .count();
                println!("  -> {done} healthy nodes finished all epochs despite the crash\n");
            }
        }
    }
    Ok(())
}
