//! Aggregation through the lowered L1 Pallas kernel (`agg_k{K}.hlo.txt`).
//!
//! The kernel computes `out[c] = sum_k w[k] * stack[k, c]` over fixed-size
//! chunks (`manifest.chunk` wide), so one artifact serves every model: the
//! executor tiles the flat parameter vectors into chunks and pads the tail.
//!
//! The strategies use the pure-rust [`crate::tensor::flat::weighted_average`]
//! on the hot path (it is allocation-light and avoids PJRT dispatch for an
//! element-wise op); this executor exists to (a) validate the L1 kernel
//! end-to-end from rust (`rust/tests/artifact_parity.rs`) and (b) benchmark
//! the two paths against each other (`rust/benches/microbench.rs`).

use anyhow::{anyhow, Result};

use super::engine::Engine;
use super::manifest::Manifest;
use crate::tensor::FlatParams;

/// Chunked FedAvg aggregation via the compiled Pallas kernel.
pub struct AggExecutor {
    exe: xla::PjRtLoadedExecutable,
    /// Number of clients the loaded artifact aggregates.
    pub k: usize,
    /// Chunk width the artifact was lowered with.
    pub chunk: usize,
}

impl AggExecutor {
    /// Load the K-way aggregation artifact (K must be one of the built
    /// `--agg-k` values, default 2/3/5).
    pub fn load(engine: &Engine, manifest: &Manifest, k: usize) -> Result<AggExecutor> {
        let path = manifest
            .agg
            .get(&k)
            .ok_or_else(|| anyhow!("no agg artifact for k={k} (built: {:?})", manifest.agg.keys()))?;
        Ok(AggExecutor {
            exe: engine.compile_hlo_file(path)?,
            k,
            chunk: manifest.chunk,
        })
    }

    /// `sum_k weights[k] * params[k]` through the kernel artifact.
    pub fn aggregate(&self, params: &[&FlatParams], weights: &[f32]) -> Result<FlatParams> {
        anyhow::ensure!(params.len() == self.k, "expected {} clients, got {}", self.k, params.len());
        anyhow::ensure!(weights.len() == self.k, "weights arity");
        let p = params[0].len();
        for x in params {
            anyhow::ensure!(x.len() == p, "client param length mismatch");
        }
        // Hoisted out of the chunk loop: the weights literal, the
        // reshape dims, and the reusable host-side stack buffer.
        let w_lit = xla::Literal::vec1(weights);
        let stack_dims = [self.k as i64, self.chunk as i64];

        let mut out = Vec::with_capacity(p);
        let mut stack = vec![0.0f32; self.k * self.chunk];
        let n_chunks = p.div_ceil(self.chunk);
        for ci in 0..n_chunks {
            let start = ci * self.chunk;
            let end = (start + self.chunk).min(p);
            let width = end - start;
            if width < self.chunk {
                // tail chunk: zero the whole stack once (full chunks
                // overwrite every row slot, so only the tail needs it —
                // and only here, not once per client row)
                stack.fill(0.0);
            }
            for (kk, x) in params.iter().enumerate() {
                stack[kk * self.chunk..kk * self.chunk + width]
                    .copy_from_slice(&x.as_slice()[start..end]);
            }
            let stack_lit = xla::Literal::vec1(&stack).reshape(&stack_dims)?;
            let res = self.exe.execute(&[&stack_lit, &w_lit])?[0][0]
                .to_literal_sync()?;
            let chunk_out = res.to_tuple1()?.to_vec::<f32>()?;
            out.extend_from_slice(&chunk_out[..width]);
        }
        Ok(FlatParams(out))
    }
}
