//! Quickstart: the 20-line version of serverless federated learning.
//!
//! Two nodes train the MNIST-like CNN asynchronously (paper Algorithm 1),
//! exchanging weights through an in-memory weight store, then the global
//! model is evaluated on the held-out test set.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fedless::prelude::*;
use fedless::strategy::StrategyKind;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig {
        model: "mnist".into(),
        n_nodes: 2,
        mode: FederationMode::Async, // the paper's FedAvgAsync (Algorithm 1)
        strategy: StrategyKind::FedAvg,
        skew: 0.9, // partial label skew, like the paper's "partial skew" split
        epochs: 3,
        steps_per_epoch: 100,
        train_size: 6_000,
        test_size: 960,
        ..Default::default()
    };

    println!("running {} ...", cfg.run_name());
    let result = run_experiment(&cfg)?;

    println!("test accuracy : {:.4}", result.final_accuracy);
    println!("test loss     : {:.4}", result.final_loss);
    println!("wall clock    : {:.2}s", result.wall_clock_s);
    println!("store pushes  : {}", result.store_pushes);
    for r in &result.reports {
        println!(
            "node {}: epochs={} aggregations={} train={:.2}s wait={:.2}s",
            r.node_id,
            r.epochs_done,
            r.aggregations,
            r.train_time.as_secs_f64(),
            r.wait_time.as_secs_f64(),
        );
    }
    println!("{}", result.render_timelines(72));
    Ok(())
}
