//! FedAvg (McMahan et al. 2016), applied client-side — Eq. (1):
//! `w <- sum_k (n_k / n) * ω[k]`. Stateless.

use super::{fedavg_of, Contribution, Strategy};
use crate::par::ChunkPool;
use crate::tensor::FlatParams;

/// Stateless example-weighted averaging — the paper's default strategy.
#[derive(Default)]
pub struct FedAvg;

impl FedAvg {
    /// FedAvg has no hyperparameters or state.
    pub fn new() -> Self {
        FedAvg
    }
}

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        if contribs.is_empty() {
            return None;
        }
        Some(fedavg_of(contribs, pool))
    }
}

#[cfg(test)]
mod tests {
    use super::super::strategy_tests::contrib;
    use super::*;

    #[test]
    fn weighted_mean() {
        let mut s = FedAvg::new();
        let out = s
            .aggregate(&[
                contrib(0, 100, true, &[1.0, 2.0]),
                contrib(1, 300, false, &[5.0, 6.0]),
            ])
            .unwrap();
        assert_eq!(out.0, vec![4.0, 5.0]);
    }

    #[test]
    fn single_self_contribution_is_identity() {
        let mut s = FedAvg::new();
        let out = s.aggregate(&[contrib(0, 10, true, &[3.0, -1.0])]).unwrap();
        assert_eq!(out.0, vec![3.0, -1.0]);
    }

    #[test]
    fn empty_returns_none() {
        let mut s = FedAvg::new();
        assert!(s.aggregate(&[]).is_none());
    }
}
