//! MNIST sync-vs-async comparison (the paper's §4.2.1 experiment, Table 1)
//! with a heterogeneous-speed twist: node 1 is an artificial straggler, so
//! this example shows *both* effects the paper reports — accuracy parity at
//! low skew, and async's wall-clock win when node speeds differ.
//!
//! ```sh
//! cargo run --release --example mnist_sync_vs_async [skew]
//! ```

use fedless::config::{ExperimentConfig, FederationMode};
use fedless::sim::run_experiment;

fn main() -> anyhow::Result<()> {
    let skew: f64 = std::env::args().nth(1).map(|s| s.parse().unwrap()).unwrap_or(0.9);

    let base = ExperimentConfig {
        model: "mnist".into(),
        n_nodes: 2,
        skew,
        epochs: 3,
        steps_per_epoch: 120,
        train_size: 6_000,
        test_size: 960,
        // node 1 is a straggler: +8ms per training step
        node_delays_ms: vec![0.0, 8.0],
        ..Default::default()
    };

    let mut summary = Vec::new();
    for mode in [FederationMode::Sync, FederationMode::Async] {
        let mut cfg = base.clone();
        cfg.mode = mode;
        println!("=== {} federation (skew={skew}) ===", mode.name());
        let res = run_experiment(&cfg)?;
        println!("accuracy  : {:.4}", res.final_accuracy);
        println!("wall clock: {:.2}s", res.wall_clock_s);
        println!("mean idle : {:.1}%", 100.0 * res.mean_idle_fraction);
        println!("{}", res.render_timelines(72));
        summary.push((mode, res.final_accuracy, res.wall_clock_s, res.mean_idle_fraction));
    }

    let (_, acc_s, wall_s, idle_s) = summary[0];
    let (_, acc_a, wall_a, idle_a) = summary[1];
    println!("=== summary ===");
    println!("accuracy  : sync {acc_s:.4} vs async {acc_a:.4} (paper: ~equal at moderate skew)");
    println!(
        "wall clock: sync {wall_s:.2}s vs async {wall_a:.2}s  -> async {:.1}% faster",
        100.0 * (wall_s - wall_a) / wall_s
    );
    println!(
        "idle time : sync {:.1}% vs async {:.1}% (async removes barrier waits)",
        100.0 * idle_s,
        100.0 * idle_a
    );
    Ok(())
}
