//! Fault-injecting store wrapper: seeded transient errors on push/pull,
//! used by the robustness experiments (§4.2.1: "real world model training
//! jobs can be fragile") and by failure-handling tests.
//!
//! Two fault mechanisms compose:
//!
//! * **per-op Bernoulli** — each data operation fails with probability
//!   `p_fail`, deterministically in the wrapper's seed (and, for a
//!   per-node wrapper, in that node's own operation order);
//! * **scheduled outage windows** — every data operation inside a
//!   configured `[start, start+duration)` interval of the experiment
//!   clock fails. The schedule is pure in `(config, simulated-time)`, so
//!   a retrying client that straddles an outage replays bit-identically
//!   under any scheduler or thread count — which is exactly what the
//!   chaos conformance tests exercise.
//!
//! Injected failures carry a [`StoreError`] of kind
//! [`crate::store::StoreErrorKind::Transient`], so the retry layer
//! ([`crate::store::RetryStore`]) knows they are worth retrying.
//!
//! The subscription path (`version`/`wait_for_change`) is never injected
//! — see the comments on those methods.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::{PushRequest, StoreError, WeightEntry, WeightStore};
use crate::time::Clock;
use crate::util::Rng;

/// One scheduled store outage: every data-plane operation with a clock
/// reading in `[start, start + duration)` fails (a fault *burst* in the
/// taxonomy of ISSUE terms — total unavailability for the window).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OutageWindow {
    /// Offset of the outage start from the experiment clock's origin.
    pub start: Duration,
    /// How long the outage lasts.
    pub duration: Duration,
}

impl OutageWindow {
    /// Whether clock offset `t` falls inside the outage.
    pub fn contains(&self, t: Duration) -> bool {
        t >= self.start && t < self.start + self.duration
    }

    /// Parse `"<start_s>:<dur_s>"` (seconds, fractional allowed); `None`
    /// on malformed input or a non-positive duration.
    pub fn parse(s: &str) -> Option<OutageWindow> {
        let (start, dur) = s.split_once(':')?;
        let start = start.trim().parse::<f64>().ok().filter(|v| v.is_finite() && *v >= 0.0)?;
        let dur = dur.trim().parse::<f64>().ok().filter(|v| v.is_finite() && *v > 0.0)?;
        Some(OutageWindow {
            start: Duration::from_secs_f64(start),
            duration: Duration::from_secs_f64(dur),
        })
    }
}

/// The runtime fault configuration: Bernoulli rate plus any scheduled
/// outage windows. Carried on
/// [`crate::config::ExperimentConfig`] and handed to
/// [`FaultStore::with_model`] when building a node's store stack.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultModel {
    /// Per-operation failure probability in `[0, 1]`.
    pub p_fail: f64,
    /// Scheduled outages on the experiment clock.
    pub outages: Vec<OutageWindow>,
}

impl FaultModel {
    /// Whether this model can ever inject a failure.
    pub fn is_active(&self) -> bool {
        self.p_fail > 0.0 || !self.outages.is_empty()
    }
}

/// Wraps an inner store; each operation fails with probability `p_fail`,
/// and unconditionally inside any scheduled [`OutageWindow`].
pub struct FaultStore<S> {
    inner: S,
    p_fail: f64,
    outages: Vec<OutageWindow>,
    /// Clock the outage schedule is evaluated on; `None` disables
    /// outages (the legacy Bernoulli-only construction).
    clock: Option<Arc<dyn Clock>>,
    rng: Mutex<Rng>,
    injected: std::sync::atomic::AtomicU64,
}

impl<S: WeightStore> FaultStore<S> {
    /// Wrap `inner`; each operation fails with probability `p_fail`,
    /// deterministically in `seed`. No outage schedule.
    pub fn new(inner: S, p_fail: f64, seed: u64) -> Self {
        FaultStore::build(inner, p_fail, Vec::new(), None, seed)
    }

    /// Wrap `inner` with a full [`FaultModel`]: Bernoulli failures plus
    /// outage windows evaluated on `clock` (pass the experiment clock so
    /// the schedule lives in simulated time).
    pub fn with_model(inner: S, model: &FaultModel, clock: Arc<dyn Clock>, seed: u64) -> Self {
        FaultStore::build(inner, model.p_fail, model.outages.clone(), Some(clock), seed)
    }

    fn build(
        inner: S,
        p_fail: f64,
        outages: Vec<OutageWindow>,
        clock: Option<Arc<dyn Clock>>,
        seed: u64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&p_fail));
        FaultStore {
            inner,
            p_fail,
            outages,
            clock,
            rng: Mutex::new(Rng::new(seed ^ 0xFA_17)),
            injected: Default::default(),
        }
    }

    /// Number of injected failures so far (outages included).
    pub fn injected(&self) -> u64 {
        self.injected.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn maybe_fail(&self, op: &'static str) -> Result<()> {
        if let Some(clock) = &self.clock {
            let t = clock.now();
            if let Some(w) = self.outages.iter().find(|w| w.contains(t)) {
                self.injected.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Err(StoreError::transient(
                    op,
                    format!(
                        "store outage window {:.3}s+{:.3}s (t={:.3}s)",
                        w.start.as_secs_f64(),
                        w.duration.as_secs_f64(),
                        t.as_secs_f64()
                    ),
                ));
            }
        }
        if self.p_fail > 0.0 && self.rng.lock().unwrap().chance(self.p_fail) {
            self.injected.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return Err(StoreError::transient(op, "injected store failure"));
        }
        Ok(())
    }
}

impl<S: WeightStore> WeightStore for FaultStore<S> {
    fn push(&self, req: PushRequest) -> Result<u64> {
        self.maybe_fail("push")?;
        self.inner.push(req)
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        self.maybe_fail("latest_per_node")?;
        self.inner.latest_per_node()
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        self.maybe_fail("entries_for_round")?;
        self.inner.entries_for_round(round)
    }

    fn state_hash(&self) -> Result<u64> {
        self.maybe_fail("state_hash")?;
        self.inner.state_hash()
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        self.maybe_fail("latest_for_node")?;
        self.inner.latest_for_node(node_id)
    }

    fn version(&self) -> Result<u64> {
        // Never fault-injected: `version`/`wait_for_change` are the
        // barrier notification path, and a poll that "fails" would
        // desert it — the sync barrier reads `version` for its wake-up
        // token every lap, so an injected error here aborted the whole
        // node instead of simulating a flaky *data* operation. Faults
        // belong on the data reads/writes around the subscription
        // (push/pull/state_hash), which the protocols handle.
        self.inner.version()
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        // The wait itself is a local blocking primitive, not a remote
        // round-trip: faults are injected on the reads around it, so a
        // flaky store still delivers wake-ups (see `version`).
        self.inner.wait_for_change(since, timeout)
    }

    fn push_count(&self) -> u64 {
        self.inner.push_count()
    }

    fn clear(&self) -> Result<()> {
        self.inner.clear()
    }

    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // a conditional put is a data write like any other: injectable,
        // then forwarded to the inner store's atomic CAS
        self.maybe_fail("push_if_version")?;
        self.inner.push_if_version(req, expected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::store_tests;
    use crate::store::{MemoryStore, StoreErrorKind};

    #[test]
    fn p_zero_is_transparent() {
        let s = FaultStore::new(MemoryStore::new(), 0.0, 1);
        store_tests::conformance(&s);
        assert_eq!(s.injected(), 0);
    }

    #[test]
    fn p_one_always_fails() {
        let s = FaultStore::new(MemoryStore::new(), 1.0, 1);
        assert!(s.push(store_tests::push_req(0, 0, 1.0)).is_err());
        assert!(s.latest_per_node().is_err());
        assert!(s.state_hash().is_err());
        assert_eq!(s.injected(), 3);
    }

    #[test]
    fn injected_errors_classify_as_transient() {
        let s = FaultStore::new(MemoryStore::new(), 1.0, 1);
        let err = s.push(store_tests::push_req(0, 0, 1.0)).unwrap_err();
        assert_eq!(StoreError::classify(&err), StoreErrorKind::Transient);
        // a context wrapper around it must still classify through the chain
        let wrapped = err.context("pushing epoch 0 weights");
        assert_eq!(StoreError::classify(&wrapped), StoreErrorKind::Transient);
    }

    /// Regression: the subscription path (`version`/`wait_for_change`)
    /// must never be fault-injected. A poll that "fails" deserts the
    /// barrier notification path — the sync barrier reads `version` for
    /// its wake-up token every lap, so an injected error there aborted
    /// the node instead of simulating a flaky data op.
    #[test]
    fn subscription_path_is_never_fault_injected() {
        use std::sync::Arc;
        use std::time::Instant;

        let inner: Arc<dyn WeightStore> = Arc::new(MemoryStore::new());
        let s = Arc::new(FaultStore::new(Arc::clone(&inner), 1.0, 1));

        // version succeeds even at p = 1 (everything else fails)
        let v0 = s.version().expect("version must never be injected");
        assert!(s.state_hash().is_err(), "data ops still fail at p = 1");

        // ...and a waiter parked through the faulty wrapper still gets
        // the wake-up when a peer's push lands on the shared inner store.
        let waiter = {
            let s = Arc::clone(&s);
            std::thread::spawn(move || {
                s.wait_for_change(v0, Duration::from_secs(20))
                    .expect("wait_for_change must never be injected")
            })
        };
        std::thread::sleep(Duration::from_millis(30));
        let t = Instant::now();
        inner.push(store_tests::push_req(1, 0, 2.0)).unwrap();
        let v = waiter.join().unwrap();
        assert!(v > v0, "waiter must observe the push through the faulty wrapper");
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "waiter must wake on the push, not ride out the timeout"
        );

        // a clean timeout is also not an error
        let v = s.wait_for_change(v, Duration::from_millis(20)).unwrap();
        assert_eq!(v, s.version().unwrap());
    }

    #[test]
    fn failure_rate_roughly_matches() {
        let s = FaultStore::new(MemoryStore::new(), 0.3, 7);
        let fails = (0..1000)
            .filter(|_| s.push(store_tests::push_req(0, 0, 1.0)).is_err())
            .count();
        assert!((200..400).contains(&fails), "fails={fails}");
    }

    #[test]
    fn outage_window_fails_inside_and_heals_outside() {
        use crate::time::{ParticipantGuard, VirtualClock};
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        clock.enter();
        let _guard = ParticipantGuard::adopt(Arc::clone(&clock));
        let model = FaultModel {
            p_fail: 0.0,
            outages: vec![OutageWindow {
                start: Duration::from_secs(2),
                duration: Duration::from_secs(3),
            }],
        };
        let s = FaultStore::with_model(
            MemoryStore::with_clock(Arc::clone(&clock)),
            &model,
            Arc::clone(&clock),
            1,
        );
        // before the outage: healthy
        s.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        // inside the window: every data op fails, typed transient
        clock.sleep(Duration::from_secs(2));
        let err = s.push(store_tests::push_req(0, 1, 2.0)).unwrap_err();
        assert_eq!(StoreError::classify(&err), StoreErrorKind::Transient);
        assert!(s.latest_per_node().is_err());
        // the subscription path still works mid-outage
        s.version().expect("version must survive an outage");
        // past the window: healed
        clock.sleep(Duration::from_secs(3));
        s.push(store_tests::push_req(0, 2, 3.0)).unwrap();
        assert_eq!(s.injected(), 2);
    }

    #[test]
    fn outage_parse_roundtrip() {
        let w = OutageWindow::parse("2.5:1").unwrap();
        assert_eq!(w.start, Duration::from_millis(2500));
        assert_eq!(w.duration, Duration::from_secs(1));
        assert!(w.contains(Duration::from_secs(3)));
        assert!(!w.contains(Duration::from_millis(2499)));
        assert!(!w.contains(Duration::from_millis(3500)));
        assert!(OutageWindow::parse("5").is_none());
        assert!(OutageWindow::parse("5:0").is_none());
        assert!(OutageWindow::parse("-1:2").is_none());
        assert!(OutageWindow::parse("a:b").is_none());
    }
}
