//! Trace and analytics exporters — and the `inspect`-side loader.
//!
//! Three files land in a traced run's directory:
//!
//! * `trace.jsonl` — one JSON object per [`TraceEvent`], in the
//!   canonical (node id, program order) merge order. Timestamps are
//!   integer microseconds on the experiment clock; digests are 16-hex
//!   strings (a `u64` exceeds exact `f64` range, so they are never
//!   emitted as JSON numbers).
//! * `trace_chrome.json` — the Chrome trace-event array format
//!   (load in Perfetto / `chrome://tracing`): every timeline span is a
//!   `ph: "X"` complete event and every push/pull/aggregate a `ph: "i"`
//!   instant, with `pid` 0 and `tid` = node id, sorted by
//!   `(tid, ts)` so each node track is monotone.
//! * `analysis.json` — the figure-ready [`RunSummary`] (per-node span
//!   shares, traffic, divergence tables). [`load_summary`] parses it
//!   back with [`crate::util::json`]; `fedbench inspect` renders the
//!   loaded summary through the same [`RunSummary::render`] that
//!   `fedbench run` printed.
//!
//! All floats are written with Rust's shortest-round-trip `{}` display
//! (re-parses to the same bits) and every value is guarded finite, so
//! exported files are always valid JSON.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::metrics::timeline::Timeline;
use crate::trace::{
    ClientDivergence, DivergenceReport, FaultTotals, NodeSpanSummary, RoundDivergence,
    RunSummary, TraceEvent, TraceEventKind, Tracer,
};
use crate::util::json::Json;

/// JSON-string-escape `s` (quotes, backslashes, and all control
/// characters).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A finite f64 as a JSON number (shortest round-trip); non-finite
/// values (which the analytics layer never produces) degrade to 0.
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "0".to_string()
    }
}

fn micros(d: Duration) -> u64 {
    d.as_micros() as u64
}

/// One `trace.jsonl` line (no trailing newline).
pub fn event_jsonl_line(ev: &TraceEvent) -> String {
    let mut line = format!(
        "{{\"node\":{},\"round\":{},\"kind\":\"{}\",\"start_us\":{},\"end_us\":{}",
        ev.node_id,
        ev.round,
        ev.kind.name(),
        micros(ev.start),
        micros(ev.end),
    );
    match ev.kind {
        TraceEventKind::Train | TraceEventKind::NodeFailed | TraceEventKind::Restart => {}
        TraceEventKind::Push { wire_bytes, digest } => {
            line.push_str(&format!(",\"wire_bytes\":{wire_bytes},\"digest\":\"{digest:016x}\""));
        }
        TraceEventKind::Pull { entries, wire_bytes } => {
            line.push_str(&format!(",\"entries\":{entries},\"wire_bytes\":{wire_bytes}"));
        }
        TraceEventKind::Aggregate { digest } => {
            line.push_str(&format!(",\"digest\":\"{digest:016x}\""));
        }
    }
    line.push('}');
    line
}

/// Render the Chrome trace-event array for a run: timeline spans as
/// complete (`"X"`) events, tracer push/pull/aggregate instants as
/// (`"i"`) events, sorted by `(tid, ts)` so every per-node track is
/// monotone non-decreasing.
pub fn chrome_trace_json(events: &[TraceEvent], timelines: &[&Timeline]) -> String {
    // (tid, ts_us, seq, rendered) — seq keeps the sort stable
    let mut rows: Vec<(usize, u64, usize, String)> = Vec::new();
    for t in timelines {
        for s in &t.spans {
            let name = match s.kind {
                crate::metrics::timeline::SpanKind::Train => "train",
                crate::metrics::timeline::SpanKind::Wait => "wait",
                crate::metrics::timeline::SpanKind::Aggregate => "aggregate",
                crate::metrics::timeline::SpanKind::Crashed => "crashed",
            };
            let ts = micros(s.start);
            rows.push((
                t.node_id,
                ts,
                rows.len(),
                format!(
                    "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{}}}",
                    name,
                    ts,
                    micros(s.end).saturating_sub(ts),
                    t.node_id,
                ),
            ));
        }
    }
    for ev in events {
        let args = match ev.kind {
            TraceEventKind::Train => continue, // already a timeline span
            // restart covers the crash→recovery window as a Crashed
            // timeline span; the failure mark carries no payload — both
            // export as bare instants at their event timestamp
            TraceEventKind::NodeFailed | TraceEventKind::Restart => {
                format!("{{\"round\":{}}}", ev.round)
            }
            TraceEventKind::Push { wire_bytes, digest } => {
                format!("{{\"round\":{},\"wire_bytes\":{},\"digest\":\"{:016x}\"}}", ev.round, wire_bytes, digest)
            }
            TraceEventKind::Pull { entries, wire_bytes } => {
                format!("{{\"round\":{},\"entries\":{},\"wire_bytes\":{}}}", ev.round, entries, wire_bytes)
            }
            TraceEventKind::Aggregate { digest } => {
                format!("{{\"round\":{},\"digest\":\"{:016x}\"}}", ev.round, digest)
            }
        };
        let ts = micros(ev.start);
        rows.push((
            ev.node_id,
            ts,
            rows.len(),
            format!(
                "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{},\"pid\":0,\"tid\":{},\"args\":{}}}",
                ev.kind.name(),
                ts,
                ev.node_id,
                args,
            ),
        ));
    }
    rows.sort_by_key(|(tid, ts, seq, _)| (*tid, *ts, *seq));
    let body: Vec<String> = rows.into_iter().map(|(_, _, _, r)| r).collect();
    format!("[\n{}\n]\n", body.join(",\n"))
}

fn node_json(n: &NodeSpanSummary) -> String {
    format!(
        "{{\"node_id\":{},\"train_s\":{},\"wait_s\":{},\"aggregate_s\":{},\"total_s\":{},\"rounds_trained\":{},\"bytes_pushed\":{},\"bytes_pulled\":{},\"pushes\":{},\"entries_pulled\":{},\"completed\":{}}}",
        n.node_id,
        jnum(n.train_s),
        jnum(n.wait_s),
        jnum(n.aggregate_s),
        jnum(n.total_s),
        n.rounds_trained,
        n.bytes_pushed,
        n.bytes_pulled,
        n.pushes,
        n.entries_pulled,
        n.completed,
    )
}

fn divergence_json(d: &DivergenceReport) -> String {
    let rounds: Vec<String> = d
        .rounds
        .iter()
        .map(|r| {
            let clients: Vec<String> = r
                .clients
                .iter()
                .map(|c| {
                    format!(
                        "{{\"node_id\":{},\"l2\":{},\"cosine\":{}}}",
                        c.node_id,
                        jnum(c.l2),
                        jnum(c.cosine)
                    )
                })
                .collect();
            format!(
                "{{\"round\":{},\"mean_l2\":{},\"mean_cosine\":{},\"clients\":[{}]}}",
                r.round,
                jnum(r.mean_l2),
                jnum(r.mean_cosine),
                clients.join(",")
            )
        })
        .collect();
    let pairwise = match &d.pairwise_cosine {
        None => "null".to_string(),
        Some(m) => {
            let rows: Vec<String> = m
                .iter()
                .map(|row| {
                    let cells: Vec<String> = row.iter().map(|v| jnum(*v)).collect();
                    format!("[{}]", cells.join(","))
                })
                .collect();
            format!("[{}]", rows.join(","))
        }
    };
    let nodes: Vec<String> = d.pairwise_nodes.iter().map(|n| n.to_string()).collect();
    let clusters: Vec<String> = d
        .clusters
        .iter()
        .map(|c| {
            let ids: Vec<String> = c.iter().map(|n| n.to_string()).collect();
            format!("[{}]", ids.join(","))
        })
        .collect();
    format!(
        "{{\"cluster_threshold\":{},\"rounds\":[{}],\"pairwise_nodes\":[{}],\"pairwise_cosine\":{},\"clusters\":[{}]}}",
        jnum(d.cluster_threshold),
        rounds.join(","),
        nodes.join(","),
        pairwise,
        clusters.join(",")
    )
}

/// Serialize a [`RunSummary`] as the `analysis.json` document.
pub fn summary_json(s: &RunSummary) -> String {
    let nodes: Vec<String> = s.nodes.iter().map(node_json).collect();
    let divergence = match &s.divergence {
        None => "null".to_string(),
        Some(d) => divergence_json(d),
    };
    let f = &s.faults;
    format!(
        "{{\n\"run_name\":\"{}\",\n\"n_nodes\":{},\n\"wall_clock_s\":{},\n\"global_digest\":\"{:016x}\",\n\"store_pushes\":{},\n\"mean_idle_fraction\":{},\n\"all_completed\":{},\n\"faults\":{{\"injected_faults\":{},\"store_retries\":{},\"store_give_ups\":{},\"degraded_rounds\":{},\"restarts\":{}}},\n\"nodes\":[{}],\n\"divergence\":{}\n}}\n",
        esc(&s.run_name),
        s.n_nodes,
        jnum(s.wall_clock_s),
        s.global_digest,
        s.store_pushes,
        jnum(s.mean_idle_fraction),
        s.all_completed,
        f.injected_faults,
        f.store_retries,
        f.store_give_ups,
        f.degraded_rounds,
        f.restarts,
        nodes.join(","),
        divergence,
    )
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("analysis.json: missing key `{key}`"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    req(j, key)?.as_f64().ok_or_else(|| anyhow!("analysis.json: `{key}` is not a number"))
}

fn req_u64(j: &Json, key: &str) -> Result<u64> {
    Ok(req_f64(j, key)? as u64)
}

fn req_bool(j: &Json, key: &str) -> Result<bool> {
    req(j, key)?.as_bool().ok_or_else(|| anyhow!("analysis.json: `{key}` is not a bool"))
}

fn parse_divergence(j: &Json) -> Result<DivergenceReport> {
    let rounds = req(j, "rounds")?
        .as_arr()
        .ok_or_else(|| anyhow!("analysis.json: `rounds` is not an array"))?
        .iter()
        .map(|r| {
            let clients = req(r, "clients")?
                .as_arr()
                .ok_or_else(|| anyhow!("analysis.json: `clients` is not an array"))?
                .iter()
                .map(|c| {
                    Ok(ClientDivergence {
                        node_id: req_u64(c, "node_id")? as usize,
                        l2: req_f64(c, "l2")?,
                        cosine: req_f64(c, "cosine")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(RoundDivergence {
                round: req_u64(r, "round")?,
                mean_l2: req_f64(r, "mean_l2")?,
                mean_cosine: req_f64(r, "mean_cosine")?,
                clients,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let pairwise_nodes = req(j, "pairwise_nodes")?
        .as_arr()
        .ok_or_else(|| anyhow!("analysis.json: `pairwise_nodes` is not an array"))?
        .iter()
        .map(|n| n.as_usize().ok_or_else(|| anyhow!("bad pairwise node id")))
        .collect::<Result<Vec<_>>>()?;
    let pairwise_cosine = match req(j, "pairwise_cosine")? {
        Json::Null => None,
        m => Some(
            m.as_arr()
                .ok_or_else(|| anyhow!("analysis.json: `pairwise_cosine` is not an array"))?
                .iter()
                .map(|row| {
                    row.as_arr()
                        .ok_or_else(|| anyhow!("bad pairwise row"))?
                        .iter()
                        .map(|v| v.as_f64().ok_or_else(|| anyhow!("bad pairwise cell")))
                        .collect::<Result<Vec<_>>>()
                })
                .collect::<Result<Vec<_>>>()?,
        ),
    };
    let clusters = req(j, "clusters")?
        .as_arr()
        .ok_or_else(|| anyhow!("analysis.json: `clusters` is not an array"))?
        .iter()
        .map(|c| {
            c.as_arr()
                .ok_or_else(|| anyhow!("bad cluster"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad cluster member")))
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(DivergenceReport {
        rounds,
        pairwise_nodes,
        pairwise_cosine,
        clusters,
        cluster_threshold: req_f64(j, "cluster_threshold")?,
    })
}

/// Parse an `analysis.json` document back into a [`RunSummary`].
pub fn parse_summary(src: &str) -> Result<RunSummary> {
    let j = Json::parse(src).map_err(|e| anyhow!("analysis.json: {e}"))?;
    let digest_hex = req(&j, "global_digest")?
        .as_str()
        .ok_or_else(|| anyhow!("analysis.json: `global_digest` is not a string"))?;
    let global_digest = u64::from_str_radix(digest_hex, 16)
        .with_context(|| format!("bad digest `{digest_hex}`"))?;
    let nodes = req(&j, "nodes")?
        .as_arr()
        .ok_or_else(|| anyhow!("analysis.json: `nodes` is not an array"))?
        .iter()
        .map(|n| {
            Ok(NodeSpanSummary {
                node_id: req_u64(n, "node_id")? as usize,
                train_s: req_f64(n, "train_s")?,
                wait_s: req_f64(n, "wait_s")?,
                aggregate_s: req_f64(n, "aggregate_s")?,
                total_s: req_f64(n, "total_s")?,
                rounds_trained: req_u64(n, "rounds_trained")?,
                bytes_pushed: req_u64(n, "bytes_pushed")?,
                bytes_pulled: req_u64(n, "bytes_pulled")?,
                pushes: req_u64(n, "pushes")?,
                entries_pulled: req_u64(n, "entries_pulled")?,
                completed: req_bool(n, "completed")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let divergence = match req(&j, "divergence")? {
        Json::Null => None,
        d => Some(parse_divergence(d)?),
    };
    // absent in analysis.json files written before the fault layer
    // existed — default to all-zero so old exports still load
    let faults = match j.get("faults") {
        None => FaultTotals::default(),
        Some(f) => FaultTotals {
            injected_faults: req_u64(f, "injected_faults")?,
            store_retries: req_u64(f, "store_retries")?,
            store_give_ups: req_u64(f, "store_give_ups")?,
            degraded_rounds: req_u64(f, "degraded_rounds")?,
            restarts: req_u64(f, "restarts")?,
        },
    };
    Ok(RunSummary {
        run_name: req(&j, "run_name")?
            .as_str()
            .ok_or_else(|| anyhow!("analysis.json: `run_name` is not a string"))?
            .to_string(),
        n_nodes: req_u64(&j, "n_nodes")? as usize,
        wall_clock_s: req_f64(&j, "wall_clock_s")?,
        global_digest,
        store_pushes: req_u64(&j, "store_pushes")?,
        mean_idle_fraction: req_f64(&j, "mean_idle_fraction")?,
        all_completed: req_bool(&j, "all_completed")?,
        faults,
        nodes,
        divergence,
    })
}

/// Load the [`RunSummary`] exported into `run_dir` (`analysis.json`) —
/// the `fedbench inspect` entry point.
pub fn load_summary(run_dir: &Path) -> Result<RunSummary> {
    let path = run_dir.join("analysis.json");
    let src = std::fs::read_to_string(&path)
        .with_context(|| format!("no analysis.json in {} (was the run traced?)", run_dir.display()))?;
    parse_summary(&src)
}

/// Write the full trace export set (`trace.jsonl`, `trace_chrome.json`,
/// `analysis.json`) into `dir`, creating it if needed. Returns the
/// directory back for `ExperimentResult::trace_dir` bookkeeping.
pub fn export_run(
    dir: &Path,
    tracer: &Tracer,
    timelines: &[&Timeline],
    summary: &RunSummary,
) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating trace dir {}", dir.display()))?;
    let events = tracer.events();
    let mut jsonl = String::new();
    for ev in &events {
        jsonl.push_str(&event_jsonl_line(ev));
        jsonl.push('\n');
    }
    std::fs::write(dir.join("trace.jsonl"), jsonl)?;
    std::fs::write(dir.join("trace_chrome.json"), chrome_trace_json(&events, timelines))?;
    std::fs::write(dir.join("analysis.json"), summary_json(summary))?;
    Ok(dir.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::timeline::SpanKind;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn jsonl_lines_are_valid_json() {
        let evs = [
            TraceEvent { node_id: 0, round: 1, start: ms(5), end: ms(5), kind: TraceEventKind::Push { wire_bytes: 52, digest: u64::MAX } },
            TraceEvent { node_id: 1, round: 2, start: ms(9), end: ms(9), kind: TraceEventKind::Pull { entries: 3, wire_bytes: 156 } },
            TraceEvent { node_id: 1, round: 2, start: ms(9), end: ms(9), kind: TraceEventKind::Aggregate { digest: 7 } },
            TraceEvent { node_id: 2, round: 0, start: ms(0), end: ms(4), kind: TraceEventKind::Train },
        ];
        for ev in &evs {
            let line = event_jsonl_line(ev);
            let j = Json::parse(&line).expect("line must parse");
            assert_eq!(j.get("node").unwrap().as_usize().unwrap(), ev.node_id);
            assert_eq!(j.get("kind").unwrap().as_str().unwrap(), ev.kind.name());
        }
        // u64::MAX survives as a hex string, not a lossy f64
        let line = event_jsonl_line(&evs[0]);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("digest").unwrap().as_str().unwrap(), "ffffffffffffffff");
    }

    #[test]
    fn chrome_trace_is_valid_and_monotone_per_track() {
        let tracer = Tracer::new(2);
        tracer.instant(1, 0, ms(7), TraceEventKind::Push { wire_bytes: 9, digest: 1 });
        tracer.instant(0, 0, ms(3), TraceEventKind::Pull { entries: 1, wire_bytes: 9 });
        let mut t0 = Timeline::new(0);
        t0.record(SpanKind::Train, ms(0), ms(3));
        t0.record(SpanKind::Wait, ms(3), ms(7));
        let mut t1 = Timeline::new(1);
        t1.record(SpanKind::Train, ms(0), ms(7));
        let src = chrome_trace_json(&tracer.events(), &[&t0, &t1]);
        let j = Json::parse(&src).expect("chrome trace must be valid JSON");
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 5);
        let mut last: Option<(usize, u64)> = None;
        for e in arr {
            let tid = e.get("tid").unwrap().as_usize().unwrap();
            let ts = e.get("ts").unwrap().as_f64().unwrap() as u64;
            if let Some((ltid, lts)) = last {
                if ltid == tid {
                    assert!(ts >= lts, "track {tid} must be monotone");
                }
            }
            last = Some((tid, ts));
        }
    }

    #[test]
    fn summary_round_trips_through_analysis_json() {
        let summary = RunSummary {
            run_name: "demo \"run\"\t1".into(),
            n_nodes: 2,
            wall_clock_s: 1.25,
            global_digest: 0xdead_beef_0000_0001,
            store_pushes: 8,
            mean_idle_fraction: 0.125,
            all_completed: true,
            faults: FaultTotals {
                injected_faults: 5,
                store_retries: 4,
                store_give_ups: 1,
                degraded_rounds: 2,
                restarts: 1,
            },
            nodes: vec![NodeSpanSummary {
                node_id: 0,
                train_s: 1.0,
                wait_s: 0.25,
                aggregate_s: 0.0,
                total_s: 1.25,
                rounds_trained: 4,
                bytes_pushed: 100,
                bytes_pulled: 300,
                pushes: 4,
                entries_pulled: 12,
                completed: true,
            }],
            divergence: Some(DivergenceReport {
                rounds: vec![RoundDivergence {
                    round: 0,
                    mean_l2: 2.0,
                    mean_cosine: 0.5,
                    clients: vec![
                        ClientDivergence { node_id: 0, l2: 2.0, cosine: 0.0 },
                        ClientDivergence { node_id: 1, l2: 2.0, cosine: 1.0 },
                    ],
                }],
                pairwise_nodes: vec![0, 1],
                pairwise_cosine: Some(vec![vec![1.0, 0.0], vec![0.0, 1.0]]),
                clusters: vec![vec![0], vec![1]],
                cluster_threshold: 0.9,
            }),
        };
        let parsed = parse_summary(&summary_json(&summary)).unwrap();
        assert_eq!(parsed, summary);
        assert_eq!(parsed.render(), summary.render());

        // pre-fault-layer analysis.json files have no "faults" key and
        // must still load, defaulting every counter to zero
        let legacy: String = summary_json(&summary)
            .lines()
            .filter(|l| !l.starts_with("\"faults\""))
            .collect::<Vec<_>>()
            .join("\n");
        let parsed = parse_summary(&legacy).unwrap();
        assert_eq!(parsed.faults, FaultTotals::default());
    }
}
