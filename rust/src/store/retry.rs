//! Retrying store client — the fault-tolerance layer between a node and a
//! flaky weight store.
//!
//! The paper's store is an S3 bucket; real object stores throw transient
//! errors (throttling, 5xx, network blips) that a production client
//! absorbs with retries rather than surfacing to the training loop. This
//! wrapper reproduces that client behaviour:
//!
//! * **exponential backoff with seeded jitter** — attempt n sleeps
//!   `base · 2^(n-1)` capped at `max_delay`, plus up to 50% deterministic
//!   jitter. Sleeps go through the experiment [`Clock`], so under a
//!   [`crate::time::VirtualClock`] a retry storm costs simulated time
//!   only, and the whole schedule replays bit-identically.
//! * **deterministic jitter** — the jitter draw is pure in
//!   `(seed, clock.now(), attempt)`, not in a shared mutable RNG, so it
//!   does not depend on how other nodes' operations interleave. Two
//!   replays (or the threads vs. events schedulers) that reach the same
//!   simulated instant draw the same jitter.
//! * **error taxonomy** — only failures classified
//!   [`StoreErrorKind::Transient`] (via [`StoreError::classify`]) are
//!   retried; permanent errors and unknown error types propagate
//!   immediately.
//! * **per-op deadline budget** — each operation gets at most
//!   `op_deadline` of clock time across all attempts; the budget also
//!   clips the final backoff sleep so a retrying op never overshoots it.
//!
//! The subscription path (`version`/`wait_for_change`) is forwarded
//! without retry: those are never fault-injected (see
//! [`super::FaultStore`]) and `wait_for_change` has its own timeout
//! discipline. A CAS conflict (`push_if_version` returning `Ok(None)`)
//! is a *successful* operation, not a failure — it is never retried.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::{PushRequest, StoreError, StoreErrorKind, WeightEntry, WeightStore};
use crate::time::Clock;
use crate::util::Rng;

/// Backoff/budget knobs for [`RetryStore`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Maximum attempts per operation (first try included).
    pub max_attempts: u32,
    /// Backoff before the second attempt; doubles per retry.
    pub base_delay: Duration,
    /// Cap on any single backoff sleep (pre-jitter).
    pub max_delay: Duration,
    /// Total clock-time budget per operation across all attempts.
    pub op_deadline: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 10,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_secs(2),
            op_deadline: Duration::from_secs(30),
        }
    }
}

/// Counters a [`RetryStore`] accumulates, for run reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RetryStats {
    /// Transient failures absorbed by a later successful attempt.
    pub retries: u64,
    /// Operations that exhausted attempts or deadline and failed.
    pub give_ups: u64,
}

/// Wraps an inner store with transparent retry of transient failures.
pub struct RetryStore<S> {
    inner: S,
    policy: RetryPolicy,
    clock: Arc<dyn Clock>,
    seed: u64,
    retries: AtomicU64,
    give_ups: AtomicU64,
}

impl<S: WeightStore> RetryStore<S> {
    /// Wrap `inner`; backoff sleeps run on `clock` and jitter is
    /// deterministic in `seed` and the clock reading.
    pub fn new(inner: S, policy: RetryPolicy, clock: Arc<dyn Clock>, seed: u64) -> Self {
        assert!(policy.max_attempts >= 1, "need at least one attempt");
        RetryStore {
            inner,
            policy,
            clock,
            seed,
            retries: Default::default(),
            give_ups: Default::default(),
        }
    }

    /// The wrapped store.
    pub fn inner(&self) -> &S {
        &self.inner
    }

    /// Counters so far.
    pub fn stats(&self) -> RetryStats {
        RetryStats {
            retries: self.retries.load(Ordering::Relaxed),
            give_ups: self.give_ups.load(Ordering::Relaxed),
        }
    }

    /// Jitter fraction in `[0, 0.5)`, pure in `(seed, now, attempt)` —
    /// no shared RNG state, so the draw is independent of how other
    /// nodes' store traffic interleaves with ours.
    fn jitter_frac(&self, now: Duration, attempt: u32) -> f64 {
        let mut rng = Rng::new(
            self.seed
                ^ (now.as_nanos() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        rng.f64() * 0.5
    }

    fn with_retry<T>(&self, op: &'static str, f: impl Fn(&S) -> Result<T>) -> Result<T> {
        let start = self.clock.now();
        let mut attempt = 1u32;
        loop {
            let err = match f(&self.inner) {
                Ok(out) => return Ok(out),
                Err(err) => err,
            };
            if StoreError::classify(&err) == StoreErrorKind::Permanent {
                return Err(err);
            }
            let elapsed = self.clock.now() - start;
            if attempt >= self.policy.max_attempts || elapsed >= self.policy.op_deadline {
                self.give_ups.fetch_add(1, Ordering::Relaxed);
                return Err(err.context(format!(
                    "gave up on {op} after {attempt} attempts ({:.3}s of {:.3}s budget)",
                    elapsed.as_secs_f64(),
                    self.policy.op_deadline.as_secs_f64()
                )));
            }
            self.retries.fetch_add(1, Ordering::Relaxed);
            let backoff = self
                .policy
                .base_delay
                .saturating_mul(1u32 << (attempt - 1).min(20))
                .min(self.policy.max_delay);
            let jittered = backoff.mul_f64(1.0 + self.jitter_frac(self.clock.now(), attempt));
            // never sleep past the deadline budget
            let budget = self.policy.op_deadline - elapsed;
            self.clock.sleep(jittered.min(budget));
            attempt += 1;
        }
    }
}

impl<S: WeightStore> WeightStore for RetryStore<S> {
    fn push(&self, req: PushRequest) -> Result<u64> {
        self.with_retry("push", |s| s.push(req.clone()))
    }

    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        self.with_retry("latest_per_node", |s| s.latest_per_node())
    }

    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        self.with_retry("entries_for_round", |s| s.entries_for_round(round))
    }

    fn state_hash(&self) -> Result<u64> {
        self.with_retry("state_hash", |s| s.state_hash())
    }

    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        self.with_retry("latest_for_node", |s| s.latest_for_node(node_id))
    }

    fn version(&self) -> Result<u64> {
        // subscription path: never injected, never retried (see module doc)
        self.inner.version()
    }

    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        self.inner.wait_for_change(since, timeout)
    }

    fn push_count(&self) -> u64 {
        self.inner.push_count()
    }

    fn clear(&self) -> Result<()> {
        self.with_retry("clear", |s| s.clear())
    }

    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // Ok(None) is a version conflict — a *successful* round-trip the
        // caller must react to (re-read, re-base), not a failure to retry.
        self.with_retry("push_if_version", |s| s.push_if_version(req.clone(), expected))
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex;

    use super::*;
    use crate::store::store_tests::{self, push_req};
    use crate::store::{FaultModel, FaultStore, MemoryStore, OutageWindow};
    use crate::time::{ParticipantGuard, RealClock, VirtualClock};

    /// Scripted flaky store: fails the first `fail_first` calls of every
    /// retried op with the given error kind, then heals.
    struct Flaky {
        inner: MemoryStore,
        fail_first: u64,
        kind: StoreErrorKind,
        calls: AtomicU64,
    }

    impl Flaky {
        fn new(fail_first: u64, kind: StoreErrorKind) -> Self {
            Flaky { inner: MemoryStore::new(), fail_first, kind, calls: AtomicU64::new(0) }
        }

        fn trip(&self, op: &'static str) -> Result<()> {
            if self.calls.fetch_add(1, Ordering::SeqCst) < self.fail_first {
                return Err(match self.kind {
                    StoreErrorKind::Transient => StoreError::transient(op, "scripted blip"),
                    StoreErrorKind::Permanent => StoreError::permanent(op, "scripted hard fail"),
                });
            }
            Ok(())
        }
    }

    impl WeightStore for Flaky {
        fn push(&self, req: PushRequest) -> Result<u64> {
            self.trip("push")?;
            self.inner.push(req)
        }
        fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
            self.trip("latest_per_node")?;
            self.inner.latest_per_node()
        }
        fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
            self.trip("entries_for_round")?;
            self.inner.entries_for_round(round)
        }
        fn state_hash(&self) -> Result<u64> {
            self.trip("state_hash")?;
            self.inner.state_hash()
        }
        fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
            self.trip("latest_for_node")?;
            self.inner.latest_for_node(node_id)
        }
        fn version(&self) -> Result<u64> {
            self.inner.version()
        }
        fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
            self.inner.wait_for_change(since, timeout)
        }
        fn push_count(&self) -> u64 {
            self.inner.push_count()
        }
        fn clear(&self) -> Result<()> {
            self.inner.clear()
        }
    }

    fn quick_policy() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 5,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(8),
            op_deadline: Duration::from_secs(5),
        }
    }

    #[test]
    fn conformance_over_healthy_store() {
        let s = RetryStore::new(MemoryStore::new(), quick_policy(), RealClock::shared(), 1);
        store_tests::conformance(&s);
        assert_eq!(s.stats(), RetryStats::default());
    }

    #[test]
    fn transient_blips_are_absorbed() {
        let s = RetryStore::new(
            Flaky::new(2, StoreErrorKind::Transient),
            quick_policy(),
            RealClock::shared(),
            1,
        );
        s.push(push_req(0, 0, 1.0)).expect("two blips then success");
        let stats = s.stats();
        assert_eq!(stats.retries, 2);
        assert_eq!(stats.give_ups, 0);
        assert_eq!(s.inner().inner.push_count(), 1);
    }

    #[test]
    fn permanent_errors_propagate_immediately() {
        let s = RetryStore::new(
            Flaky::new(1, StoreErrorKind::Permanent),
            quick_policy(),
            RealClock::shared(),
            1,
        );
        assert!(s.push(push_req(0, 0, 1.0)).is_err());
        assert_eq!(s.stats(), RetryStats::default(), "no retry, no give-up counter");
        // the store healed after one failure, but we must not have retried
        s.push(push_req(0, 0, 1.0)).unwrap();
    }

    #[test]
    fn unknown_errors_are_not_retried() {
        struct Hostile(MemoryStore);
        impl WeightStore for Hostile {
            fn push(&self, _: PushRequest) -> Result<u64> {
                anyhow::bail!("some error with no StoreError in its chain")
            }
            fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
                self.0.latest_per_node()
            }
            fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
                self.0.entries_for_round(round)
            }
            fn state_hash(&self) -> Result<u64> {
                self.0.state_hash()
            }
            fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
                self.0.latest_for_node(node_id)
            }
            fn version(&self) -> Result<u64> {
                self.0.version()
            }
            fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
                self.0.wait_for_change(since, timeout)
            }
            fn push_count(&self) -> u64 {
                self.0.push_count()
            }
            fn clear(&self) -> Result<()> {
                self.0.clear()
            }
        }
        let s =
            RetryStore::new(Hostile(MemoryStore::new()), quick_policy(), RealClock::shared(), 1);
        assert!(s.push(push_req(0, 0, 1.0)).is_err());
        assert_eq!(s.stats().retries, 0, "unclassified errors default to permanent");
    }

    #[test]
    fn gives_up_after_max_attempts() {
        let s = RetryStore::new(
            Flaky::new(u64::MAX, StoreErrorKind::Transient),
            quick_policy(),
            RealClock::shared(),
            1,
        );
        let err = s.push(push_req(0, 0, 1.0)).unwrap_err();
        assert!(err.to_string().contains("gave up on push after 5 attempts"), "{err:#}");
        let stats = s.stats();
        assert_eq!(stats.retries, 4, "5 attempts = 4 retries");
        assert_eq!(stats.give_ups, 1);
        // the give-up error still classifies transient through the context chain
        assert_eq!(StoreError::classify(&err), StoreErrorKind::Transient);
    }

    #[test]
    fn deadline_budget_bounds_total_wall_time() {
        // On a virtual clock: huge backoffs, tiny deadline — the op must
        // stop at the deadline, not ride out max_attempts.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        clock.enter();
        let _guard = ParticipantGuard::adopt(Arc::clone(&clock));
        let policy = RetryPolicy {
            max_attempts: 100,
            base_delay: Duration::from_secs(1),
            max_delay: Duration::from_secs(64),
            op_deadline: Duration::from_secs(10),
        };
        let s = RetryStore::new(
            Flaky::new(u64::MAX, StoreErrorKind::Transient),
            policy,
            Arc::clone(&clock),
            1,
        );
        let t0 = clock.now();
        assert!(s.push(push_req(0, 0, 1.0)).is_err());
        let spent = clock.now() - t0;
        assert!(spent <= Duration::from_secs(10), "budget overshot: {spent:?}");
        assert_eq!(s.stats().give_ups, 1);
        assert!(s.stats().retries < 99, "deadline must cut the attempt loop short");
    }

    #[test]
    fn retry_rides_out_an_outage_window_in_simulated_time() {
        // The acceptance-path integration: FaultStore outage under
        // RetryStore on a virtual clock. The op starts mid-outage, backs
        // off past the window's end, then lands.
        let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
        clock.enter();
        let _guard = ParticipantGuard::adopt(Arc::clone(&clock));
        let model = FaultModel {
            p_fail: 0.0,
            outages: vec![OutageWindow {
                start: Duration::ZERO,
                duration: Duration::from_millis(500),
            }],
        };
        let faulty = FaultStore::with_model(
            MemoryStore::with_clock(Arc::clone(&clock)),
            &model,
            Arc::clone(&clock),
            7,
        );
        let s = RetryStore::new(
            faulty,
            RetryPolicy {
                max_attempts: 20,
                base_delay: Duration::from_millis(50),
                max_delay: Duration::from_secs(1),
                op_deadline: Duration::from_secs(30),
            },
            Arc::clone(&clock),
            7,
        );
        s.push(push_req(0, 0, 1.0)).expect("retry must outlast the outage");
        assert!(s.stats().retries >= 1);
        assert_eq!(s.stats().give_ups, 0);
        assert!(clock.now() >= Duration::from_millis(500), "must have slept past the window");
        assert!(s.inner().injected() >= 1);
    }

    #[test]
    fn backoff_schedule_is_deterministic_in_seed_and_clock() {
        // Two identical replays must sleep identical schedules; a
        // different seed must diverge (jitter is live, not constant).
        let run = |seed: u64| -> Vec<Duration> {
            let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
            clock.enter();
            let _guard = ParticipantGuard::adopt(Arc::clone(&clock));
            let s = RetryStore::new(
                Flaky::new(4, StoreErrorKind::Transient),
                quick_policy(),
                Arc::clone(&clock),
                seed,
            );
            let sleeps = Mutex::new(Vec::new());
            let mut last = clock.now();
            for _ in 0..4 {
                // each push trips once less as the flaky store drains
                let _ = s.push(push_req(0, 0, 1.0));
                let now = clock.now();
                sleeps.lock().unwrap().push(now - last);
                last = now;
            }
            sleeps.into_inner().unwrap()
        };
        assert_eq!(run(1), run(1), "same seed, same simulated schedule");
        assert_ne!(run(1), run(2), "different seed must draw different jitter");
    }

    #[test]
    fn cas_conflict_is_not_retried() {
        let s = RetryStore::new(MemoryStore::new(), quick_policy(), RealClock::shared(), 1);
        s.push(push_req(0, 0, 1.0)).unwrap();
        let stale = 0u64; // version before the push
        let out = s.push_if_version(push_req(1, 0, 2.0), stale).unwrap();
        assert!(out.is_none(), "conflict reported, not retried into success");
        assert_eq!(s.stats().retries, 0);
    }

    #[test]
    fn cas_conformance_through_retry() {
        let s = RetryStore::new(MemoryStore::new(), quick_policy(), RealClock::shared(), 1);
        store_tests::cas_conformance(&s);
    }
}
