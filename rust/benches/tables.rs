//! Per-table end-to-end benches: one representative cell of every paper
//! table (1–7) plus the Figure-1 sync-vs-async wall-clock comparison, run
//! at smoke scale and timed. These measure the *system* cost of each
//! experiment family (full federated run: data synthesis, node threads,
//! PJRT training, store traffic, aggregation, evaluation); accuracy
//! regeneration at real scale is `fedbench`'s job.
//!
//! Run: `cargo bench --offline --bench tables`

mod common;

use common::bench;
use fedless::config::{ExperimentConfig, FederationMode};
use fedless::sim::run_experiment;
use fedless::strategy::StrategyKind;

fn smoke(model: &str) -> ExperimentConfig {
    let (steps, train) = match model {
        "cifar" => (8, 800),
        m if m.starts_with("lm") => (10, 400),
        _ => (12, 1200),
    };
    ExperimentConfig {
        model: model.into(),
        epochs: 2,
        steps_per_epoch: steps,
        train_size: train,
        test_size: 160,
        seed: 42,
        ..Default::default()
    }
}

fn run(cfg: &ExperimentConfig) -> f64 {
    run_experiment(cfg).expect("experiment").final_accuracy
}

fn main() {
    println!("fedless table benches — one representative cell per paper table\n");
    let mut accs: Vec<(String, f64)> = Vec::new();
    let mut acc = |name: &str, cfg: ExperimentConfig| {
        let mut last = 0.0;
        bench(name, 0, 3, || last = run(&cfg));
        accs.push((name.to_string(), last));
    };

    // Table 1: mnist sync vs async at skew 0.9 (2 nodes)
    let mut c = smoke("mnist");
    c.mode = FederationMode::Sync;
    c.skew = 0.9;
    acc("table1/mnist-sync-skew0.9-n2", c);
    let mut c = smoke("mnist");
    c.mode = FederationMode::Async;
    c.skew = 0.9;
    acc("table1/mnist-async-skew0.9-n2", c);

    // Table 2: mnist FedAvgM async, 3 nodes, skew 0.9
    let mut c = smoke("mnist");
    c.mode = FederationMode::Async;
    c.strategy = StrategyKind::FedAvgM;
    c.n_nodes = 3;
    c.skew = 0.9;
    acc("table2/mnist-fedavgm-async-n3", c);

    // Table 3: mnist FedAdam sync, 5 nodes, skew 0.99
    let mut c = smoke("mnist");
    c.mode = FederationMode::Sync;
    c.strategy = StrategyKind::FedAdam;
    c.n_nodes = 5;
    c.skew = 0.99;
    acc("table3/mnist-fedadam-sync-n5", c);

    // Table 4: cifar async at skew 1 (2 nodes)
    let mut c = smoke("cifar");
    c.mode = FederationMode::Async;
    c.skew = 1.0;
    acc("table4/cifar-async-skew1-n2", c);

    // Table 5: cifar FedAvg sync, 3 nodes, skew 0.9
    let mut c = smoke("cifar");
    c.mode = FederationMode::Sync;
    c.n_nodes = 3;
    c.skew = 0.9;
    acc("table5/cifar-fedavg-sync-n3", c);

    // Table 6: cifar FedAvgM async, 2 nodes, skew 0.99
    let mut c = smoke("cifar");
    c.mode = FederationMode::Async;
    c.strategy = StrategyKind::FedAvgM;
    c.skew = 0.99;
    acc("table6/cifar-fedavgm-async-n2", c);

    // Table 7: lm sync vs async (2 nodes)
    let mut c = smoke("lm");
    c.mode = FederationMode::Sync;
    acc("table7/lm-sync-n2", c);
    let mut c = smoke("lm");
    c.mode = FederationMode::Async;
    acc("table7/lm-async-n2", c);

    // Figure 1: straggler wall-clock, sync vs async
    println!("\n--- fig1: straggler wall-clock (node 2 delayed 15ms/step) ---");
    for mode in [FederationMode::Sync, FederationMode::Async] {
        let mut c = smoke("mnist");
        c.mode = mode;
        c.n_nodes = 3;
        c.node_delays_ms = vec![0.0, 0.0, 15.0];
        bench(&format!("fig1/{}-straggler-n3", mode.name()), 0, 3, || {
            run(&c);
        });
    }

    println!("\naccuracies at smoke scale (sanity only):");
    for (name, a) in accs {
        println!("  {name:40} {a:.3}");
    }
}
