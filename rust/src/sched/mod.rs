//! Event-driven node scheduling — the 10k-client regime.
//!
//! The thread-per-node worker ([`crate::node::spawn_node`]) is faithful
//! but caps out at tens of nodes: every simulated client costs an OS
//! thread, a PJRT engine, and a VirtualClock participant slot. The
//! paper's cross-device claims ("millions of users") need trials three
//! orders of magnitude larger. This module supplies that regime:
//!
//! * [`TaskClock`] — a single-threaded clock whose time is *set* by the
//!   executor between task steps instead of negotiated between blocked
//!   threads. Same [`crate::time::Clock`] interface, so stores,
//!   protocols, and timelines are reused unchanged.
//! * [`EventExecutor`] — a discrete-event loop over resumable
//!   [`Task`]s: a binary heap of `(deadline, task)` events, one step per
//!   event, [`StepOutcome::Wait`] parking a task until the weight-store
//!   version moves or its timeout deadline arrives.
//! * [`ParticipationPlan`] — seeded per-round cohort sampling
//!   (`participation = <frac>`) and per-node availability traces
//!   (`availability = churn:<p> | diurnal:<period> |
//!   stragglers:<frac>:<mult>`), the FedLess/syft-flwr-style partial
//!   participation that only makes sense at this scale.
//! * [`run_events_trial`] — an artifact-free trial harness (synthetic
//!   params, no PJRT) used by the conformance and scale tests.
//!
//! Select with the `scheduler = threads | events` config key (or
//! `fedbench run --scheduler events`). The threaded path remains the
//! conformance baseline: on the existing 4–10 node timing/determinism
//! suites both schedulers produce bit-identical simulated timelines and
//! model digests (`rust/tests/timing.rs`, `rust/tests/determinism.rs`).
//!
//! # Caveat
//!
//! Under a [`crate::store::LatencyStore`], store operations *inside* one
//! task step happen at interpolated instants on the threaded path but at
//! the step's start instant here; scenarios that depend on sub-step
//! interleaving of store latency can diverge between schedulers. All
//! shipped goldens use latency-free stores, where the schedules are
//! provably identical (see ARCHITECTURE.md §12).

mod clock;
mod executor;
mod harness;
mod participation;

pub use clock::TaskClock;
pub use executor::{EventExecutor, StepOutcome, Task};
pub use harness::{run_events_trial, run_events_trial_captured, SimNodeResult, TrialSpec};
pub use participation::{AvailabilitySpec, ParticipationPlan};

/// Which node scheduler drives an experiment — the config-level selector
/// (`scheduler = threads | events`), parallel to `ClockKind` for clocks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedulerKind {
    /// One OS thread per node on a shared [`crate::time::Clock`]; the
    /// default, and the conformance baseline for the event path.
    #[default]
    Threads,
    /// Resumable node tasks on a single-threaded [`EventExecutor`] —
    /// requires `clock = virtual` semantics (enforced at config
    /// validation) and scales to tens of thousands of clients.
    Events,
}

impl SchedulerKind {
    /// Parse a config/CLI value: `threads` or `events`.
    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s.to_ascii_lowercase().as_str() {
            "threads" => Some(SchedulerKind::Threads),
            "events" => Some(SchedulerKind::Events),
            _ => None,
        }
    }

    /// Canonical lowercase name (inverse of [`SchedulerKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Threads => "threads",
            SchedulerKind::Events => "events",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_kind_parse_and_name() {
        assert_eq!(SchedulerKind::parse("threads"), Some(SchedulerKind::Threads));
        assert_eq!(SchedulerKind::parse("EVENTS"), Some(SchedulerKind::Events));
        assert_eq!(SchedulerKind::parse("fibers"), None);
        assert_eq!(SchedulerKind::default(), SchedulerKind::Threads);
        for kind in [SchedulerKind::Threads, SchedulerKind::Events] {
            assert_eq!(SchedulerKind::parse(kind.name()), Some(kind));
        }
    }
}
