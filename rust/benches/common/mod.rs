//! Minimal bench harness (the image vendors no criterion): warmup + N
//! timed iterations, reporting mean / p50 / p95 and derived throughput.

use std::time::{Duration, Instant};

use fedless::metrics::stats::percentile;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:44} {:>5} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        )
    }
}

/// Time `f` with `warmup` throwaway calls and `iters` measured calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    // shared nearest-rank percentile (errors on an empty sample instead
    // of panicking; a zero-iteration bench is a harness misconfiguration)
    let secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
    let p50 = Duration::from_secs_f64(
        percentile(&secs, 50.0).unwrap_or_else(|e| panic!("bench {name}: {e}")),
    );
    let p95 = Duration::from_secs_f64(
        percentile(&secs, 95.0).unwrap_or_else(|e| panic!("bench {name}: {e}")),
    );
    let r = BenchResult { name: name.to_string(), iters, mean, p50, p95 };
    println!("{}", r.row());
    r
}

/// GB/s for an operation that touches `bytes` per call.
#[allow(dead_code)] // used by microbench, not tables
pub fn gbps(bytes: usize, d: Duration) -> f64 {
    bytes as f64 / d.as_secs_f64() / 1e9
}
