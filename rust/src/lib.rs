//! # fedless — serverless federated learning
//!
//! A reproduction of *"Serverless Federated Learning with flwr-serverless"*
//! (Namjoshi et al., 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: federated
//!   nodes that train locally and aggregate weights **client-side** from a
//!   shared [`store::WeightStore`], through a pluggable
//!   [`protocol::FederationProtocol`]: the synchronous barrier protocol,
//!   the asynchronous `FedAvgAsync` protocol (paper Algorithm 1), a
//!   gossip protocol (`mode = gossip[:m]`), and the no-federation
//!   baseline. No central server exists anywhere in the system.
//! * **L2 (JAX, build time)** — model fwd/bwd + Adam as flat-parameter
//!   train/eval steps, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L1 (Pallas, build time)** — weighted-aggregation, fused-Adam and
//!   MXU-tiled matmul kernels inside those artifacts.
//!
//! The [`runtime`] module loads the artifacts through the PJRT C API (`xla`
//! crate) — Python never runs on the training path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use fedless::prelude::*;
//!
//! let exp = ExperimentConfig {
//!     model: "mnist".into(),
//!     n_nodes: 2,
//!     mode: FederationMode::Async,
//!     strategy: StrategyKind::FedAvg,
//!     skew: 0.9,
//!     epochs: 3,
//!     steps_per_epoch: 120,
//!     ..Default::default()
//! };
//! let result = run_experiment(&exp).unwrap();
//! println!("test accuracy = {:.3}", result.final_accuracy);
//! ```
//!
//! To reproduce a whole paper table (a *grid* of experiments) in one
//! call, see the [`sweep`] module and the `fedbench sweep` subcommand.

#![warn(missing_docs)]

pub mod compress;
pub mod config;
pub mod data;
pub mod metrics;
pub mod node;
pub mod par;
pub mod protocol;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod store;
pub mod strategy;
pub mod sweep;
pub mod tensor;
pub mod time;
pub mod trace;
pub mod util;

/// Convenient re-exports for examples and binaries.
pub mod prelude {
    pub use crate::compress::{Codec, CodecKind};
    pub use crate::config::{ExperimentConfig, FederationMode, Scale};
    pub use crate::data::{DatasetKind, Partitioner};
    pub use crate::metrics::stats::Summary;
    pub use crate::node::{NodeHandle, NodeReport};
    pub use crate::par::ChunkPool;
    pub use crate::protocol::{FederationProtocol, ProtocolKind};
    pub use crate::runtime::{Engine, ModelBundle};
    pub use crate::sched::{AvailabilitySpec, ParticipationPlan, SchedulerKind};
    pub use crate::sim::{run_experiment, run_trials, ExperimentResult};
    pub use crate::store::{FsStore, LatencyStore, MemoryStore, ShardedStore, WeightStore};
    pub use crate::strategy::StrategyKind;
    pub use crate::sweep::{run_sweep, SweepReport, SweepSpec};
    pub use crate::tensor::FlatParams;
    pub use crate::time::{Clock, ClockKind, RealClock, VirtualClock};
    pub use crate::trace::{DivergenceReport, RunSummary, Tracer};
}
