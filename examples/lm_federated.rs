//! End-to-end driver (DESIGN.md §Experiment-index): federated training of a
//! Pythia-14M-scale transformer (paper §4.4) across serverless async nodes,
//! on the synthetic byte-level corpus, logging the full loss curve.
//!
//! This is the repo's full-stack proof: L1 Pallas kernels (tiled matmul +
//! fused AdamW) inside the L2 JAX train step, AOT-compiled to HLO, executed
//! by the L3 rust coordinator across federated node threads with
//! client-side aggregation through the weight store — Python nowhere at
//! runtime.
//!
//! ```sh
//! cargo run --release --example lm_federated [model] [nodes] [steps_per_epoch]
//! # model defaults to lm14m (≈ Pythia-14M parameter budget);
//! # use lm_medium / lm for faster runs.
//! ```

use std::path::PathBuf;

use fedless::config::{ExperimentConfig, FederationMode};
use fedless::runtime::Manifest;
use fedless::sim::run_experiment;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "lm14m".to_string());
    let n_nodes: usize = std::env::args().nth(2).map(|s| s.parse().unwrap()).unwrap_or(2);
    let steps: usize = std::env::args().nth(3).map(|s| s.parse().unwrap()).unwrap_or(60);

    let manifest = Manifest::discover()?;
    let info = manifest.model(&model)?;
    println!(
        "model {model}: {:.1}M params, batch {}, seq {}",
        info.param_count as f64 / 1e6,
        info.batch_size,
        info.input_shape[0] - 1
    );

    let cfg = ExperimentConfig {
        model: model.clone(),
        n_nodes,
        mode: FederationMode::Async,
        epochs: 3,
        steps_per_epoch: steps,
        train_size: 6_000,
        test_size: 300,
        log_dir: Some(PathBuf::from("runs")),
        verbose: true,
        ..Default::default()
    };

    println!(
        "federated AdamW training: {n_nodes} async nodes x {} epochs x {steps} steps\n",
        cfg.epochs
    );
    let res = run_experiment(&cfg)?;

    println!("\n=== results ===");
    println!("next-token accuracy: {:.4} (paper Table 7 band: .22-.26)", res.final_accuracy);
    println!("test loss          : {:.4}", res.final_loss);
    println!("wall clock         : {:.1}s", res.wall_clock_s);
    println!("\nper-node loss curves (mean loss per epoch):");
    for r in &res.reports {
        let curve: Vec<String> = r.epoch_losses.iter().map(|l| format!("{l:.3}")).collect();
        println!("  node {}: {}", r.node_id, curve.join(" -> "));
    }
    let run_dir = format!("runs/{}", cfg.run_name());
    println!("\nfull step-level metrics: {run_dir}/metrics.csv");
    println!("events log           : {run_dir}/events.jsonl");

    // the loss must actually decrease over training
    for r in &res.reports {
        anyhow::ensure!(
            r.epoch_losses.last().unwrap() < r.epoch_losses.first().unwrap(),
            "node {} loss did not improve: {:?}",
            r.node_id,
            r.epoch_losses
        );
    }
    println!("\nloss decreased on every node — end-to-end stack verified.");
    Ok(())
}
