//! Per-node activity timelines — the data behind the paper's Figure 1
//! (sync stragglers force idle waiting; async nodes keep training).
//!
//! Each node records `(kind, start, end)` spans as offsets from the
//! experiment clock's origin; `render_ascii` draws the figure in the
//! terminal and `idle_fraction` quantifies the efficiency loss that
//! asynchronous federation removes. Timelines are clock-agnostic:
//! callers stamp spans with [`crate::time::Clock::now`] offsets, so
//! under a [`crate::time::VirtualClock`] the recorded spans are
//! *simulated* time — deterministic, and faithful to the configured
//! delays rather than to host scheduling noise.

use std::time::Duration;

/// What a node was doing during a span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Local training steps.
    Train,
    /// Blocked polling the sync barrier for peers.
    Wait,
    /// Pushing/pulling/aggregating through the weight store.
    Aggregate,
    /// Injected crash (the node stops here).
    Crashed,
}

impl SpanKind {
    /// One-character glyph used by [`render_ascii`].
    pub fn glyph(self) -> char {
        match self {
            SpanKind::Train => '#',
            SpanKind::Wait => '.',
            SpanKind::Aggregate => 'A',
            SpanKind::Crashed => 'x',
        }
    }
}

/// One recorded activity interval.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    /// What the node was doing.
    pub kind: SpanKind,
    /// Start offset from the experiment clock's origin.
    pub start: Duration,
    /// End offset from the experiment clock's origin.
    pub end: Duration,
}

/// Spans for one node, as offsets from the experiment clock's origin.
#[derive(Debug)]
pub struct Timeline {
    /// The node these spans belong to.
    pub node_id: usize,
    /// Recorded spans, in recording order.
    pub spans: Vec<Span>,
    /// Wire-byte accounting for this node's pushes and pulls (recorded
    /// by the protocol layer alongside the Aggregate/Wait spans).
    pub traffic: crate::metrics::TrafficMeter,
}

impl Timeline {
    /// Empty timeline for `node_id`.
    pub fn new(node_id: usize) -> Self {
        Timeline { node_id, spans: Vec::new(), traffic: Default::default() }
    }

    /// Record a span over `[start, end]` clock offsets (both from
    /// [`crate::time::Clock::now`] of the experiment's clock).
    pub fn record(&mut self, kind: SpanKind, start: Duration, end: Duration) {
        self.spans.push(Span { kind, start, end });
    }

    /// Total time recorded under `kind` across all spans.
    pub fn total(&self, kind: SpanKind) -> Duration {
        self.spans
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.end.saturating_sub(s.start))
            .sum()
    }

    /// Fraction of wall-clock spent waiting (the Figure-1 quantity).
    pub fn idle_fraction(&self) -> f64 {
        let end = self
            .spans
            .iter()
            .map(|s| s.end)
            .max()
            .unwrap_or(Duration::ZERO);
        if end.is_zero() {
            return 0.0;
        }
        self.total(SpanKind::Wait).as_secs_f64() / end.as_secs_f64()
    }
}

/// ASCII rendering of a set of node timelines (Figure-1 style). The common
/// setup prefix (engine construction + artifact compilation, before any
/// span starts) is trimmed so the picture shows the federation dynamics.
///
/// Takes timelines by reference so callers holding them inside other
/// structures (e.g. [`crate::node::NodeReport`]) can render without
/// cloning any span data.
pub fn render_ascii(timelines: &[&Timeline], width: usize) -> String {
    let t0 = timelines
        .iter()
        .flat_map(|t| t.spans.iter().map(|s| s.start))
        .min()
        .unwrap_or(Duration::ZERO);
    let end = timelines
        .iter()
        .flat_map(|t| t.spans.iter().map(|s| s.end))
        .max()
        .unwrap_or(Duration::ZERO)
        .saturating_sub(t0);
    if end.is_zero() {
        return String::new();
    }
    let scale = width as f64 / end.as_secs_f64();
    let mut out = String::new();
    out.push_str(&format!(
        "time ->  total {:.2}s   ('#'=train '.'=wait 'A'=aggregate 'x'=crashed)\n",
        end.as_secs_f64()
    ));
    for t in timelines {
        let mut row = vec![' '; width];
        for s in &t.spans {
            let a = (s.start.saturating_sub(t0).as_secs_f64() * scale) as usize;
            let b = ((s.end.saturating_sub(t0).as_secs_f64() * scale) as usize).min(width);
            for cell in row.iter_mut().take(b).skip(a) {
                *cell = s.kind.glyph();
            }
        }
        out.push_str(&format!("node {:>2} |{}|\n", t.node_id, row.iter().collect::<String>()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn records_and_totals() {
        let mut t = Timeline::new(0);
        t.record(SpanKind::Train, ms(0), ms(5));
        t.record(SpanKind::Train, ms(7), ms(10));
        assert_eq!(t.total(SpanKind::Train), ms(8));
        assert_eq!(t.total(SpanKind::Wait), Duration::ZERO);
    }

    #[test]
    fn idle_fraction_zero_without_waits() {
        let mut t = Timeline::new(0);
        t.record(SpanKind::Train, ms(0), ms(2));
        assert_eq!(t.idle_fraction(), 0.0);
    }

    #[test]
    fn idle_fraction_of_empty_or_zero_span_timeline_is_zero_not_nan() {
        // empty timeline (a node that never recorded a span — e.g. a
        // crash at epoch 0 or a fully off-cohort client)
        let t = Timeline::new(0);
        assert_eq!(t.idle_fraction(), 0.0);
        // all spans end at offset zero (instant crash marker)
        let mut t = Timeline::new(1);
        t.record(SpanKind::Crashed, ms(0), ms(0));
        assert_eq!(t.idle_fraction(), 0.0);
    }

    #[test]
    fn idle_fraction_counts_wait_spans() {
        let mut t = Timeline::new(0);
        t.record(SpanKind::Train, ms(0), ms(6));
        t.record(SpanKind::Wait, ms(6), ms(8));
        assert!((t.idle_fraction() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn ascii_render_has_one_row_per_node() {
        let mut a = Timeline::new(0);
        let mut b = Timeline::new(1);
        a.record(SpanKind::Train, ms(0), ms(2));
        b.record(SpanKind::Wait, ms(0), ms(2));
        let art = render_ascii(&[&a, &b], 40);
        assert_eq!(art.lines().count(), 3); // header + 2 rows
        assert!(art.contains("node  0"));
        assert!(art.contains('#'));
        assert!(art.contains('.'));
    }
}
