//! Hot-path kernel microbench — the perf trajectory of the [`fedless::par`]
//! kernel layer. Measures GB/s for:
//!
//! * **aggregation** — the old K-sweep axpy loop vs the fused one-pass
//!   `weighted_average` (sequential and pooled at 1/2/8 threads)
//! * **codec** — q8 encode/decode: scalar-forced (`*_scalar`, SIMD
//!   dispatch off) vs the default runtime-dispatched kernels, sequential
//!   and chunk-parallel
//! * **hash** — byte-at-a-time FNV (bench-local reference for the
//!   original implementation), the library's word-folding FNV
//!   (`hash_f32s`), and the lane-parallel chunked hash
//! * **allocation** — allocations per blob pull (raw v1 and q8 v2),
//!   counted by a thread-local counting allocator; the zero-copy decode
//!   contract in numbers
//!
//! at mnist-/lm-/14M-sized parameter vectors. Results land in
//! `BENCH_kernels.json` (re-run after kernel changes and compare; CI
//! runs `--check` mode — tiny size, few iters, same artifact shape — and
//! uploads the file, then the bench-guard compares headline rows against
//! the committed baseline). All variants compute bit-identical results;
//! only the GB/s may move. Needs no artifacts or PJRT runtime.
//!
//! Run: `cargo bench --offline --bench kernels [-- --check]`

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::fmt::Write as _;
use std::fs;
use std::time::Instant;

use fedless::compress::{Codec, CodecKind, CodecState, Q8};
use fedless::par::ChunkPool;
use fedless::tensor::codec::{decode_blob, encode_blob, encode_blob_v2, read_blob, BlobMeta};
use fedless::tensor::flat::{weighted_average_pooled, FlatParams};
use fedless::util::hash::{chunked_hash_f32s_pooled, hash_f32s};
use fedless::util::simd::set_simd_enabled;
use fedless::util::Rng;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates to `System`; the thread-local Cell<u64> update never
// allocates (no Drop, so no TLS destructor registration).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations performed by `f` on this thread.
fn allocs_in<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = ALLOCS.with(|c| c.get());
    let r = f();
    (ALLOCS.with(|c| c.get()) - before, r)
}

/// The pre-rewrite byte-at-a-time FNV-1a over f32 bytes, kept bench-local
/// so the `hash_fnv_bytewise` trajectory row keeps meaning the same
/// computation forever (the library's `hash_f32s` now folds words).
fn fnv1a64_bytewise_f32s(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325_u64;
    for x in xs {
        for b in x.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

const K: usize = 5; // clients per aggregation (a paper-sized fan-in)

struct Row {
    kernel: &'static str,
    params: usize,
    threads: usize,
    gbps: f64,
}

fn time<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() / iters as f64
}

fn gbps(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs / 1e9
}

fn random_params(rng: &mut Rng, n: usize) -> FlatParams {
    FlatParams((0..n).map(|_| rng.normal_f32()).collect())
}

/// The replaced aggregation: K full memory sweeps over the output.
fn axpy_sweeps(xs: &[&FlatParams], weights: &[f32]) -> FlatParams {
    let mut out = FlatParams::zeros(xs[0].len());
    for (x, &w) in xs.iter().zip(weights) {
        out.axpy(w, x);
    }
    out
}

fn bench_size(n: usize, iters: usize, threads: &[usize], rows: &mut Vec<Row>) {
    let mut rng = Rng::new(n as u64 ^ 0xBEEF);
    let clients: Vec<FlatParams> = (0..K).map(|_| random_params(&mut rng, n)).collect();
    let refs: Vec<&FlatParams> = clients.iter().collect();
    let w = vec![1.0 / K as f32; K];
    let agg_bytes = n * 4 * K; // bytes read per aggregation

    println!("\n--- {n} params ---");
    let mut push = |kernel: &'static str, threads: usize, bytes: usize, secs: f64| {
        let r = Row { kernel, params: n, threads, gbps: gbps(bytes, secs) };
        println!("{:>24}  t={:<2}  {:>8.2} GB/s", r.kernel, r.threads, r.gbps);
        rows.push(r);
    };

    // aggregation: K-sweep axpy baseline, then fused at each thread count
    let s = time(iters, || {
        std::hint::black_box(axpy_sweeps(&refs, &w));
    });
    push("agg_axpy_ksweep", 1, agg_bytes, s);
    for &t in threads {
        let pool = ChunkPool::new(t);
        let s = time(iters, || {
            std::hint::black_box(weighted_average_pooled(&refs, &w, pool));
        });
        push("agg_fused", t, agg_bytes, s);
    }

    // codec: q8 with SIMD dispatch forced off (the scalar denominator of
    // the SIMD speedup), then the default dispatched kernels at each
    // thread count (bytes = raw f32 moved)
    let p = &clients[0];
    let seq = ChunkPool::new(1);
    set_simd_enabled(false);
    let s = time(iters, || {
        std::hint::black_box(Q8.encode_pooled(p, None, seq));
    });
    push("q8_encode_scalar", 1, n * 4, s);
    let enc = Q8.encode_pooled(p, None, seq);
    let s = time(iters, || {
        std::hint::black_box(Q8.decode_pooled(&enc, n, None, seq).unwrap());
    });
    push("q8_decode_scalar", 1, n * 4, s);
    set_simd_enabled(true); // dispatched: AVX2 where the CPU has it
    for &t in threads {
        let pool = ChunkPool::new(t);
        let s = time(iters, || {
            std::hint::black_box(Q8.encode_pooled(p, None, pool));
        });
        push("q8_encode", t, n * 4, s);
        let enc = Q8.encode_pooled(p, None, pool);
        let s = time(iters, || {
            std::hint::black_box(Q8.decode_pooled(&enc, n, None, pool).unwrap());
        });
        push("q8_decode", t, n * 4, s);
    }

    // hash: byte-at-a-time FNV reference, the library's word-folding
    // FNV, then the lane-parallel chunked hash
    let s = time(iters, || {
        std::hint::black_box(fnv1a64_bytewise_f32s(p.as_slice()));
    });
    push("hash_fnv_bytewise", 1, n * 4, s);
    let s = time(iters, || {
        std::hint::black_box(hash_f32s(p.as_slice()));
    });
    push("hash_fnv_word", 1, n * 4, s);
    for &t in threads {
        let pool = ChunkPool::new(t);
        let s = time(iters, || {
            std::hint::black_box(chunked_hash_f32s_pooled(p.as_slice(), pool));
        });
        push("hash_chunked", t, n * 4, s);
    }
}

/// Allocations per blob pull: `(raw v1 decode, q8 v2 decode_wire)`. The
/// raw pull is the zero-copy contract's headline (≤1; also pinned by
/// `rust/tests/wire.rs`); the q8 number tracks the lossy path's overhead.
fn decode_alloc_counts() -> (u64, u64) {
    let p = FlatParams((0..4096).map(|i| (i as f32) * 0.01 - 20.0).collect());
    let meta = BlobMeta { node_id: 0, round: 0, epoch: 0, n_examples: 1 };
    let pool = ChunkPool::new(1);

    let v1 = encode_blob(&meta, &p);
    let _ = decode_blob(&v1).unwrap(); // warm one-time TLS/anyhow costs
    let (raw_pull, _) = allocs_in(|| decode_blob(&v1).unwrap());

    let state = CodecState::new(CodecKind::Q8);
    let payload = Q8.encode(&p, None);
    let v2 = encode_blob_v2(&meta, CodecKind::Q8.id(), 0, p.len(), &payload);
    let _ = state.decode_wire(&read_blob(&v2).unwrap(), pool).unwrap();
    let (q8_pull, _) = allocs_in(|| {
        let wire = read_blob(&v2).unwrap();
        state.decode_wire(&wire, pool).unwrap()
    });
    (raw_pull, q8_pull)
}

/// GB/s of `kernel` at (`params`, `threads`), if measured.
fn lookup(rows: &[Row], kernel: &str, params: usize, threads: usize) -> Option<f64> {
    rows.iter()
        .find(|r| r.kernel == kernel && r.params == params && r.threads == threads)
        .map(|r| r.gbps)
}

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    // check mode: one small size and few iters — validates the bench
    // end-to-end and keeps the artifact shape without burning minutes
    let (sizes, iters): (Vec<usize>, usize) = if check {
        (vec![20_490], 5)
    } else {
        (vec![20_490, 470_528, 14_000_000], 8)
    };
    let threads = [1usize, 2, 8];
    println!(
        "fedless kernel microbench ({} mode): fused agg vs axpy, parallel q8, chunked hash",
        if check { "check" } else { "full" }
    );

    let mut rows = Vec::new();
    for &n in &sizes {
        let it = if n > 1_000_000 { 3 } else { iters };
        bench_size(n, it, &threads, &mut rows);
    }

    // headline speedups at the largest size (the acceptance ratios)
    let big = *sizes.last().unwrap();
    let ratio = |a: Option<f64>, b: Option<f64>| -> f64 {
        match (a, b) {
            (Some(a), Some(b)) if b > 0.0 => a / b,
            _ => 0.0,
        }
    };
    let agg_speedup =
        ratio(lookup(&rows, "agg_fused", big, 8), lookup(&rows, "agg_axpy_ksweep", big, 1));
    let q8_speedup = ratio(lookup(&rows, "q8_encode", big, 8), lookup(&rows, "q8_encode", big, 1));
    let hash_speedup =
        ratio(lookup(&rows, "hash_chunked", big, 8), lookup(&rows, "hash_fnv_bytewise", big, 1));
    let simd_speedup =
        ratio(lookup(&rows, "q8_encode", big, 1), lookup(&rows, "q8_encode_scalar", big, 1));
    let word_speedup =
        ratio(lookup(&rows, "hash_fnv_word", big, 1), lookup(&rows, "hash_fnv_bytewise", big, 1));
    let (raw_pull_allocs, q8_pull_allocs) = decode_alloc_counts();
    println!("\nheadline at {big} params:");
    println!("  fused agg (8t) vs axpy K-sweep : {agg_speedup:.2}x");
    println!("  parallel q8 encode (8t) vs 1t  : {q8_speedup:.2}x");
    println!("  chunked hash (8t) vs FNV       : {hash_speedup:.2}x");
    println!("  SIMD q8 encode (1t) vs scalar  : {simd_speedup:.2}x");
    println!("  word FNV (1t) vs bytewise      : {word_speedup:.2}x");
    println!("  allocations per pull           : raw {raw_pull_allocs}, q8 {q8_pull_allocs}");

    let mut json = String::from("{\n  \"bench\": \"hot_path_kernels\",\n");
    let _ = writeln!(json, "  \"clients_per_agg\": {K},");
    let _ = writeln!(json, "  \"check_mode\": {check},");
    let _ = writeln!(json, "  \"provenance\": \"measured\",");
    let _ = writeln!(
        json,
        "  \"headline\": {{\"params\": {big}, \"fused_agg_8t_vs_axpy\": {agg_speedup:.3}, \
         \"q8_encode_8t_vs_1t\": {q8_speedup:.3}, \"chunked_hash_8t_vs_fnv\": {hash_speedup:.3}, \
         \"q8_encode_simd_vs_scalar_1t\": {simd_speedup:.3}, \
         \"hash_word_vs_bytewise_1t\": {word_speedup:.3}}},"
    );
    let _ = writeln!(
        json,
        "  \"decode_allocs\": {{\"raw_pull\": {raw_pull_allocs}, \"q8_pull\": {q8_pull_allocs}}},"
    );
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = writeln!(
            json,
            "    {{\"kernel\": \"{}\", \"params\": {}, \"threads\": {}, \"gbps\": {:.3}}}{}",
            r.kernel,
            r.params,
            r.threads,
            r.gbps,
            if i + 1 < rows.len() { "," } else { "" }
        );
    }
    json.push_str("  ]\n}\n");
    fs::write("BENCH_kernels.json", &json).expect("write BENCH_kernels.json");
    println!("\nwrote BENCH_kernels.json");
}
