//! [`Q8`] — per-chunk affine int8 quantization (codec id 1).

use anyhow::{bail, Result};

use crate::par::ChunkPool;
use crate::tensor::FlatParams;

use super::{Codec, CodecKind};

/// Elements per quantization chunk: small enough that one outlier only
/// coarsens 256 neighbours, large enough that the 8-byte per-chunk
/// header (min + scale) stays ~3% overhead.
pub const Q8_CHUNK: usize = 256;

/// Quantization chunks per parallel work item (64 × 256 elements =
/// 64 KiB of f32 input, the kernel layer's standard granularity). A
/// constant of the wire-independent *work split* only — payload bytes
/// are a pure function of the input either way.
const PAR_GROUP: usize = 64;

/// Affine int8 quantizer: each [`Q8_CHUNK`]-element chunk stores
/// `(min: f32, scale: f32)` followed by one byte per element, with
/// `x ≈ min + scale * q`, `q ∈ [0, 255]`, `scale = (max - min) / 255`.
///
/// Wire cost: `n + 8 * ceil(n / 256)` bytes — ~3.88× smaller than raw
/// f32. Error bound (per element): half a quantization step,
/// `(chunk_max - chunk_min) / 255 / 2`, plus f32 rounding slop (see
/// [`Codec::error_bound`]).
///
/// Every 256-element chunk encodes and decodes independently, so both
/// directions run chunk-parallel on a [`ChunkPool`] with byte-identical
/// payloads for any thread count.
///
/// Both directions dispatch to AVX2 bodies at runtime
/// ([`crate::util::simd`]); the scalar expressions remain the
/// specification and the SIMD bodies are pinned bit-identical to them,
/// so neither the CPU generation nor `FEDLESS_NO_SIMD` can change a
/// payload byte.
pub struct Q8;

/// Quantize a slice against a chunk header — the scalar body. This
/// expression is the *specification* of the quantizer; the AVX2 body in
/// [`quantize_avx2`] is a bit-identical re-evaluation of it (pinned by
/// this module's `simd_matches_scalar_*` tests), and dispatch happens in
/// [`quantize_slice`]. Arithmetic runs in f64 so `x - min` spanning the
/// full f32 range stays finite; NaN inputs quantize to 0 (`NaN as u8`).
fn quantize_scalar(chunk: &[f32], min: f32, scale: f32, out: &mut [u8]) {
    let (minf, sf) = (min as f64, scale as f64);
    for (slot, &x) in out.iter_mut().zip(chunk) {
        *slot = ((x as f64 - minf) / sf).round().clamp(0.0, 255.0) as u8;
    }
}

/// AVX2 body of [`quantize_scalar`] — same f64 arithmetic, 16 elements
/// per iteration, byte-identical output. The correspondence argument,
/// term by term:
///
/// * `v = (x - min) / scale` is the same two correctly-rounded f64 ops.
/// * Scalar `v.round()` is round-half-away-from-zero. Here `v >= 0`
///   (x >= chunk min) and `v < 2^52`, so `trunc(v) + (v - trunc(v) >=
///   0.5)` computes it exactly: the subtraction is exact (Sterbenz for
///   `v >= 1`, trivially for `v < 1`), and a NaN `v` fails the `>=`
///   compare (ordered, quiet) just as it fails scalar rounding.
/// * `_mm256_cvtpd_epi32` on the integral result is exact; NaN maps to
///   i32::MIN. The packus i32→u16→u8 double saturation then reproduces
///   `clamp(0.0, 255.0) as u8` (values are in [0, ~383]; i32::MIN
///   saturates to 0, matching `f64::NAN as u8 == 0`).
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 (see
/// [`crate::util::simd::simd_enabled`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn quantize_avx2(chunk: &[f32], min: f32, scale: f32, out: &mut [u8]) {
    use std::arch::x86_64::*;

    /// Round-and-convert 4 f32s at `p` to quantized i32 lanes.
    ///
    /// # Safety
    /// AVX2 must be available and `p` must point at 4 readable f32s.
    #[inline(always)]
    #[target_feature(enable = "avx2")]
    unsafe fn quad(p: *const f32, minv: __m256d, scalev: __m256d) -> __m128i {
        let half = _mm256_set1_pd(0.5);
        let one = _mm256_set1_pd(1.0);
        let x = _mm_loadu_ps(p);
        let v = _mm256_div_pd(_mm256_sub_pd(_mm256_cvtps_pd(x), minv), scalev);
        let t = _mm256_round_pd::<{ _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC }>(v);
        let away = _mm256_cmp_pd::<_CMP_GE_OQ>(_mm256_sub_pd(v, t), half);
        _mm256_cvtpd_epi32(_mm256_add_pd(t, _mm256_and_pd(away, one)))
    }

    let minv = _mm256_set1_pd(min as f64);
    let scalev = _mm256_set1_pd(scale as f64);
    let n = chunk.len().min(out.len());
    let mut i = 0;
    while i + 16 <= n {
        let p = chunk.as_ptr().add(i);
        let a = quad(p, minv, scalev);
        let b = quad(p.add(4), minv, scalev);
        let c = quad(p.add(8), minv, scalev);
        let d = quad(p.add(12), minv, scalev);
        let bytes = _mm_packus_epi16(_mm_packus_epi32(a, b), _mm_packus_epi32(c, d));
        _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, bytes);
        i += 16;
    }
    quantize_scalar(&chunk[i..], min, scale, &mut out[i..]);
}

/// Quantize with the fastest available bit-identical body (the one
/// SIMD dispatch point of the encoder).
fn quantize_slice(chunk: &[f32], min: f32, scale: f32, out: &mut [u8]) {
    #[cfg(target_arch = "x86_64")]
    if crate::util::simd::simd_enabled() {
        // SAFETY: simd_enabled() implies AVX2 was detected at runtime.
        unsafe { quantize_avx2(chunk, min, scale, out) };
        return;
    }
    quantize_scalar(chunk, min, scale, out);
}

/// Encode one chunk into its `8 + chunk.len()` output slot. Quantizer
/// arithmetic runs in f64 so a chunk spanning huge magnitudes (where
/// `max - min` overflows f32 to inf) still yields a finite scale and
/// finite reconstructions — a silent-NaN here would poison every peer's
/// aggregation.
fn encode_chunk(chunk: &[f32], out: &mut [u8]) {
    let mut min = f32::INFINITY;
    let mut max = f32::NEG_INFINITY;
    for &x in chunk {
        min = min.min(x);
        max = max.max(x);
    }
    if !min.is_finite() || !max.is_finite() {
        // Degenerate chunk (empty or non-finite): store a zero range so
        // decode reproduces the min for every slot.
        min = if min.is_finite() { min } else { 0.0 };
        max = min;
    }
    // f64 range never overflows for finite f32 inputs; the f32 scale is
    // finite (<= f32::MAX / 255 * 2).
    let scale = ((max as f64 - min as f64) / 255.0) as f32;
    out[0..4].copy_from_slice(&min.to_le_bytes());
    out[4..8].copy_from_slice(&scale.to_le_bytes());
    if scale > 0.0 {
        quantize_slice(chunk, min, scale, &mut out[8..]);
    } else {
        out[8..].fill(0);
    }
}

/// Quantize a full vector (shared with [`super::DeltaQ8`], which runs
/// the same quantizer over a delta vector): each [`PAR_GROUP`]-chunk
/// work item writes its own pre-sized output slot, so the payload is
/// byte-identical for any thread count (a sequential pool runs it
/// inline).
pub(crate) fn q8_encode_pooled(xs: &[f32], pool: ChunkPool) -> Vec<u8> {
    let chunks = xs.len().div_ceil(Q8_CHUNK);
    let mut out = vec![0u8; xs.len() + 8 * chunks];
    // Work-item boundaries fall on Q8_CHUNK multiples, so input and
    // output groups stay aligned (a full group is PAR_GROUP chunks of
    // exactly 8 + 256 bytes each; only the final group is ragged).
    let in_stride = PAR_GROUP * Q8_CHUNK;
    let out_stride = PAR_GROUP * (Q8_CHUNK + 8);
    let items: Vec<(&[f32], &mut [u8])> =
        xs.chunks(in_stride).zip(out.chunks_mut(out_stride)).collect();
    pool.for_each(items, |_, (src, dst)| {
        let mut at = 0;
        for chunk in src.chunks(Q8_CHUNK) {
            encode_chunk(chunk, &mut dst[at..at + 8 + chunk.len()]);
            at += 8 + chunk.len();
        }
    });
    out
}

/// Dequantize a slice against a chunk header — the scalar body and,
/// like [`quantize_scalar`], the specification the AVX2 body must match
/// bit-for-bit. f64 keeps `min + scale * 255` finite even for chunks
/// spanning the full f32 range (mirrors the encoder's arithmetic).
fn dequantize_scalar(qs: &[u8], min: f32, scale: f32, out: &mut [f32]) {
    let (minf, sf) = (min as f64, scale as f64);
    for (d, &q) in out.iter_mut().zip(qs) {
        *d = (minf + sf * q as f64) as f32;
    }
}

/// AVX2 body of [`dequantize_scalar`]: widen 8 bytes to f64 lanes, then
/// the same multiply and add as two separate correctly-rounded f64 ops
/// (deliberately *not* an FMA — a fused multiply-add rounds once where
/// the scalar spec rounds twice), then `_mm256_cvtpd_ps`, which is the
/// same round-to-nearest-ties-even (overflow to ±inf included) as the
/// scalar `as f32` cast.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 (see
/// [`crate::util::simd::simd_enabled`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dequantize_avx2(qs: &[u8], min: f32, scale: f32, out: &mut [f32]) {
    use std::arch::x86_64::*;
    let minv = _mm256_set1_pd(min as f64);
    let scalev = _mm256_set1_pd(scale as f64);
    let n = qs.len().min(out.len());
    let mut i = 0;
    while i + 8 <= n {
        let b = _mm_loadl_epi64(qs.as_ptr().add(i) as *const __m128i);
        let lo = _mm256_cvtepi32_pd(_mm_cvtepu8_epi32(b));
        let hi = _mm256_cvtepi32_pd(_mm_cvtepu8_epi32(_mm_srli_si128::<4>(b)));
        let rlo = _mm256_cvtpd_ps(_mm256_add_pd(minv, _mm256_mul_pd(scalev, lo)));
        let rhi = _mm256_cvtpd_ps(_mm256_add_pd(minv, _mm256_mul_pd(scalev, hi)));
        _mm_storeu_ps(out.as_mut_ptr().add(i), rlo);
        _mm_storeu_ps(out.as_mut_ptr().add(i + 4), rhi);
        i += 8;
    }
    dequantize_scalar(&qs[i..], min, scale, &mut out[i..]);
}

/// Dequantize with the fastest available bit-identical body (the one
/// SIMD dispatch point of the decoder).
fn dequantize_slice(qs: &[u8], min: f32, scale: f32, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if crate::util::simd::simd_enabled() {
        // SAFETY: simd_enabled() implies AVX2 was detected at runtime.
        unsafe { dequantize_avx2(qs, min, scale, out) };
        return;
    }
    dequantize_scalar(qs, min, scale, out);
}

/// Decode one work item's worth of chunks (validating each chunk header).
fn decode_group(dst: &mut [f32], src: &[u8]) -> Result<()> {
    let mut at = 0usize;
    for chunk in dst.chunks_mut(Q8_CHUNK) {
        let take = chunk.len();
        let min = f32::from_le_bytes(src[at..at + 4].try_into().unwrap());
        let scale = f32::from_le_bytes(src[at + 4..at + 8].try_into().unwrap());
        if !min.is_finite() || !scale.is_finite() || scale < 0.0 {
            bail!("q8 chunk header is not a finite (min, scale >= 0) pair");
        }
        at += 8;
        dequantize_slice(&src[at..at + take], min, scale, chunk);
        at += take;
    }
    Ok(())
}

/// Dequantize `n` elements from a [`q8_encode_pooled`] payload; chunk
/// boundaries are fixed by the wire layout, so the reconstruction is
/// bit-identical for any thread count.
pub(crate) fn q8_decode_pooled(payload: &[u8], n: usize, pool: ChunkPool) -> Result<Vec<f32>> {
    let chunks = n.div_ceil(Q8_CHUNK);
    let want = n
        .checked_add(chunks.checked_mul(8).ok_or_else(|| anyhow::anyhow!("q8 size overflow"))?)
        .ok_or_else(|| anyhow::anyhow!("q8 size overflow"))?;
    if payload.len() != want {
        bail!("q8 payload is {} bytes, want {} for {} elements", payload.len(), want, n);
    }
    let mut out = vec![0.0f32; n];
    let in_stride = PAR_GROUP * Q8_CHUNK;
    let pay_stride = PAR_GROUP * (Q8_CHUNK + 8);
    // Equal group counts on both sides: a full group of PAR_GROUP chunks
    // consumes exactly in_stride elements and pay_stride bytes, and the
    // validated total sizes make the ragged tails line up too.
    let items: Vec<(&mut [f32], &[u8])> =
        out.chunks_mut(in_stride).zip(payload.chunks(pay_stride)).collect();
    let results = pool.map(items, |_, (dst, src)| decode_group(dst, src));
    for r in results {
        r?;
    }
    Ok(out)
}

/// Documented per-element bound for [`q8_encode_pooled`]: half a quantization
/// step on the widest chunk, with slop for the f32 arithmetic of the
/// quantizer itself (a few ulps of the chunk magnitude, covered by the
/// relative term, plus an absolute floor for near-zero ranges).
pub(crate) fn q8_error_bound(xs: &[f32]) -> f32 {
    let mut worst = 0.0f32;
    let mut mag = 0.0f32;
    for chunk in xs.chunks(Q8_CHUNK) {
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        for &x in chunk {
            min = min.min(x);
            max = max.max(x);
        }
        if min.is_finite() && max.is_finite() {
            worst = worst.max(((max as f64 - min as f64) / 255.0 * 0.5) as f32);
            mag = mag.max(min.abs().max(max.abs()));
        }
    }
    worst * (1.0 + 1e-3) + mag * 8.0 * f32::EPSILON + f32::EPSILON
}

impl Codec for Q8 {
    fn kind(&self) -> CodecKind {
        CodecKind::Q8
    }

    fn encode_pooled(
        &self,
        params: &FlatParams,
        _base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Vec<u8> {
        q8_encode_pooled(params.as_slice(), pool)
    }

    fn decode_pooled(
        &self,
        payload: &[u8],
        n: usize,
        _base: Option<&FlatParams>,
        pool: ChunkPool,
    ) -> Result<FlatParams> {
        Ok(FlatParams(q8_decode_pooled(payload, n, pool)?))
    }

    fn error_bound(&self, params: &FlatParams, _base: Option<&FlatParams>) -> f32 {
        q8_error_bound(params.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_size_is_about_a_quarter_of_raw() {
        let p = FlatParams((0..10_000).map(|i| (i as f32).sin()).collect());
        let enc = Q8.encode(&p, None);
        assert_eq!(enc.len(), 10_000 + 8 * 40);
        assert!((p.len() * 4) as f64 / enc.len() as f64 > 3.8);
    }

    #[test]
    fn uniform_chunk_is_lossless() {
        let p = FlatParams(vec![3.25; 600]);
        let dec = Q8.decode(&Q8.encode(&p, None), 600, None).unwrap();
        assert_eq!(dec.0, p.0, "zero-range chunks reproduce exactly");
    }

    #[test]
    fn respects_error_bound_on_varied_data() {
        let p = FlatParams(
            (0..5_000)
                .map(|i| ((i as f32) * 0.37).sin() * (1.0 + (i % 7) as f32))
                .collect(),
        );
        let bound = Q8.error_bound(&p, None);
        let dec = Q8.decode(&Q8.encode(&p, None), p.len(), None).unwrap();
        assert!(bound > 0.0);
        assert!(
            p.max_abs_diff(&dec) <= bound,
            "max err {} > bound {}",
            p.max_abs_diff(&dec),
            bound
        );
    }

    #[test]
    fn pooled_encode_decode_matches_sequential_bitwise() {
        // spans several PAR_GROUP work items plus ragged chunk and group
        // tails
        for n in [0, 1, 255, 256, 257, PAR_GROUP * Q8_CHUNK, 2 * PAR_GROUP * Q8_CHUNK + 300] {
            let p: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.071).sin()).collect();
            let seq = ChunkPool::sequential();
            let enc_seq = q8_encode_pooled(&p, seq);
            for threads in [2, 8] {
                let pool = ChunkPool::new(threads);
                assert_eq!(q8_encode_pooled(&p, pool), enc_seq, "n={n} threads={threads}");
                let dec_seq = q8_decode_pooled(&enc_seq, n, seq).unwrap();
                let dec_par = q8_decode_pooled(&enc_seq, n, pool).unwrap();
                assert_eq!(
                    dec_seq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    dec_par.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn full_f32_range_chunk_stays_finite() {
        // max - min overflows f32 to inf here; the f64 quantizer path
        // must still produce a finite scale and finite reconstructions
        // (a silent NaN would poison every peer's aggregation).
        let mut xs = vec![0.0f32; 300];
        xs[0] = 3.0e38;
        xs[1] = -3.0e38;
        let p = FlatParams(xs);
        let enc = Q8.encode(&p, None);
        let dec = Q8.decode(&enc, 300, None).unwrap();
        assert!(dec.all_finite(), "reconstruction must never contain NaN/inf");
        let bound = Q8.error_bound(&p, None);
        assert!(bound.is_finite());
        assert!(p.max_abs_diff(&dec) <= bound);
    }

    #[test]
    fn non_finite_chunk_header_is_an_error() {
        let p = FlatParams(vec![1.0; 10]);
        let mut enc = Q8.encode(&p, None);
        enc[4..8].copy_from_slice(&f32::NAN.to_le_bytes()); // scale slot
        assert!(Q8.decode(&enc, 10, None).is_err());
        // the parallel path reports the same corruption
        assert!(Q8.decode_pooled(&enc, 10, None, ChunkPool::new(4)).is_err());
    }

    #[test]
    fn truncated_payload_is_an_error() {
        let p = FlatParams(vec![1.0; 300]);
        let enc = Q8.encode(&p, None);
        assert!(Q8.decode(&enc[..enc.len() - 1], 300, None).is_err());
        assert!(Q8.decode(&enc, 299, None).is_err());
    }

    #[test]
    fn empty_vector_round_trips() {
        let p = FlatParams(vec![]);
        let enc = Q8.encode(&p, None);
        assert!(enc.is_empty());
        assert!(Q8.decode(&enc, 0, None).unwrap().is_empty());
    }

    /// Run scalar and (when the CPU has it) AVX2 quantize over the same
    /// slice and demand byte equality; then dequantize both ways and
    /// demand bit equality. Returns false when AVX2 is unavailable so
    /// callers know the check was vacuous (CI runners have AVX2, so the
    /// real check always runs there).
    fn assert_simd_matches_scalar(xs: &[f32], min: f32, scale: f32) -> bool {
        let mut q_scalar = vec![0u8; xs.len()];
        quantize_scalar(xs, min, scale, &mut q_scalar);
        let mut d_scalar = vec![0.0f32; xs.len()];
        dequantize_scalar(&q_scalar, min, scale, &mut d_scalar);
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") {
            let mut q_simd = vec![0u8; xs.len()];
            // SAFETY: AVX2 availability checked just above.
            unsafe { quantize_avx2(xs, min, scale, &mut q_simd) };
            assert_eq!(q_simd, q_scalar, "quantize min={min} scale={scale}");
            let mut d_simd = vec![0.0f32; xs.len()];
            // SAFETY: as above.
            unsafe { dequantize_avx2(&q_scalar, min, scale, &mut d_simd) };
            assert_eq!(
                d_simd.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                d_scalar.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "dequantize min={min} scale={scale}"
            );
            return true;
        }
        false
    }

    #[test]
    fn simd_matches_scalar_on_adversarial_values() {
        // Exact halfway points (min=0, scale=1 ⇒ v = x): scalar rounds
        // half away from zero; the SIMD trunc+compare must agree.
        let halfway: Vec<f32> = (0..300).map(|i| i as f32 + 0.5).collect();
        assert_simd_matches_scalar(&halfway, 0.0, 1.0);
        // NaN elements must quantize to 0 in both bodies.
        let mut with_nan: Vec<f32> = (0..257).map(|i| (i as f32) * 0.01).collect();
        with_nan[0] = f32::NAN;
        with_nan[100] = f32::NAN;
        with_nan[256] = f32::NAN;
        assert_simd_matches_scalar(&with_nan, 0.0, 0.01);
        // Denormal scale (a chunk whose range underflows): v can reach
        // ~383, exercising the upper saturation band.
        let tiny: Vec<f32> = (0..64).map(|i| f32::from_bits(i)).collect();
        assert_simd_matches_scalar(&tiny, 0.0, f32::from_bits(1));
        // Full-range magnitudes (f64 arithmetic, overflow-to-inf on the
        // dequantize f32 narrowing).
        let huge = vec![3.0e38f32, -3.0e38, 0.0, 1.0, -1.0, f32::MIN_POSITIVE];
        assert_simd_matches_scalar(&huge, -3.0e38, ((3.0e38f64 - -3.0e38f64) / 255.0) as f32);
    }

    #[test]
    fn simd_matches_scalar_on_random_and_ragged() {
        let mut rng = crate::util::Rng::new(0x51D0_CAFE);
        for n in [0usize, 1, 7, 8, 15, 16, 17, 255, 256, 1000] {
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let (mut min, mut max) = (f32::INFINITY, f32::NEG_INFINITY);
            for &x in &xs {
                min = min.min(x);
                max = max.max(x);
            }
            if !min.is_finite() {
                continue;
            }
            let scale = ((max as f64 - min as f64) / 255.0) as f32;
            if scale > 0.0 {
                assert_simd_matches_scalar(&xs, min, scale);
            }
        }
    }

    #[test]
    fn whole_encode_path_is_identical_with_simd_forced_off() {
        // End-to-end: the dispatched encode/decode vs the forced-scalar
        // kernels, across chunk and group boundaries. (We compare the
        // public path against a reference built from the scalar bodies
        // rather than toggling the global switch — unit tests run
        // concurrently.)
        let xs: Vec<f32> = (0..(Q8_CHUNK * 3 + 77))
            .map(|i| ((i as f32) * 0.137).sin() * (1.0 + (i % 5) as f32))
            .collect();
        let enc = q8_encode_pooled(&xs, ChunkPool::sequential());
        let mut at = 0;
        for chunk in xs.chunks(Q8_CHUNK) {
            let min = f32::from_le_bytes(enc[at..at + 4].try_into().unwrap());
            let scale = f32::from_le_bytes(enc[at + 4..at + 8].try_into().unwrap());
            let mut want = vec![0u8; chunk.len()];
            if scale > 0.0 {
                quantize_scalar(chunk, min, scale, &mut want);
            }
            assert_eq!(&enc[at + 8..at + 8 + chunk.len()], want, "chunk at {at}");
            at += 8 + chunk.len();
        }
        // and the decode of that payload matches the scalar dequantizer
        let dec = q8_decode_pooled(&enc, xs.len(), ChunkPool::sequential()).unwrap();
        let mut at = 0;
        let mut want = vec![0.0f32; xs.len()];
        for chunk in want.chunks_mut(Q8_CHUNK) {
            let min = f32::from_le_bytes(enc[at..at + 4].try_into().unwrap());
            let scale = f32::from_le_bytes(enc[at + 4..at + 8].try_into().unwrap());
            at += 8;
            dequantize_scalar(&enc[at..at + chunk.len()], min, scale, chunk);
            at += chunk.len();
        }
        assert_eq!(
            dec.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }
}
