//! Binary blob codec for weight-store entries (the wire/disk format).
//!
//! Layout (little-endian):
//! ```text
//!   magic   u32   0x464C_5752  ("FLWR")
//!   version u16   1
//!   flags   u16   reserved, 0
//!   node_id u32
//!   round   u64   (sync round; async entries use the node's epoch counter)
//!   epoch   u64
//!   n_examples u64
//!   len     u64   number of f32 elements
//!   hash    u64   fnv1a64 of the payload bytes
//!   payload len * 4 bytes of f32 LE
//! ```
//! The hash field makes torn/corrupt writes detectable — important for the
//! `FsStore`, where concurrent readers may observe partially-written files
//! (the same failure mode an S3 multipart PUT protects against).

use anyhow::{bail, Result};

use super::FlatParams;
use crate::util::fnv1a64;

/// Blob magic number ("FLWR" little-endian).
pub const MAGIC: u32 = 0x464C_5752;
/// Current blob format version.
pub const VERSION: u16 = 1;
/// Fixed header size in bytes (everything before the payload).
pub const HEADER_LEN: usize = 4 + 2 + 2 + 4 + 8 + 8 + 8 + 8 + 8;

/// Metadata attached to a serialized weight entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlobMeta {
    /// Id of the node that produced the weights.
    pub node_id: u32,
    /// Sync round (async entries use the node's epoch counter).
    pub round: u64,
    /// The producing node's local epoch counter.
    pub epoch: u64,
    /// Examples the node trained on (FedAvg numerator n_k).
    pub n_examples: u64,
}

/// Serialize params + metadata into a self-validating blob.
pub fn encode_blob(meta: &BlobMeta, params: &FlatParams) -> Vec<u8> {
    let payload_len = params.len() * 4;
    let mut out = Vec::with_capacity(HEADER_LEN + payload_len);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&meta.node_id.to_le_bytes());
    out.extend_from_slice(&meta.round.to_le_bytes());
    out.extend_from_slice(&meta.epoch.to_le_bytes());
    out.extend_from_slice(&meta.n_examples.to_le_bytes());
    out.extend_from_slice(&(params.len() as u64).to_le_bytes());
    // hash goes after len; fill payload first, then patch
    let hash_pos = out.len();
    out.extend_from_slice(&0u64.to_le_bytes());
    for x in params.as_slice() {
        out.extend_from_slice(&x.to_le_bytes());
    }
    let h = fnv1a64(&out[HEADER_LEN..]);
    out[hash_pos..hash_pos + 8].copy_from_slice(&h.to_le_bytes());
    out
}

fn read_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes(b[at..at + 2].try_into().unwrap())
}
fn read_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().unwrap())
}
fn read_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().unwrap())
}

/// Decode and validate a blob produced by [`encode_blob`].
pub fn decode_blob(bytes: &[u8]) -> Result<(BlobMeta, FlatParams)> {
    if bytes.len() < HEADER_LEN {
        bail!("blob too short: {} bytes", bytes.len());
    }
    if read_u32(bytes, 0) != MAGIC {
        bail!("bad magic");
    }
    let version = read_u16(bytes, 4);
    if version != VERSION {
        bail!("unsupported blob version {version}");
    }
    let meta = BlobMeta {
        node_id: read_u32(bytes, 8),
        round: read_u64(bytes, 12),
        epoch: read_u64(bytes, 20),
        n_examples: read_u64(bytes, 28),
    };
    let len = read_u64(bytes, 36) as usize;
    let hash = read_u64(bytes, 44);
    let payload = &bytes[HEADER_LEN..];
    if payload.len() != len * 4 {
        bail!("payload length {} != {} * 4 (torn write?)", payload.len(), len);
    }
    if fnv1a64(payload) != hash {
        bail!("payload hash mismatch (corrupt or torn write)");
    }
    let mut xs = Vec::with_capacity(len);
    for chunk in payload.chunks_exact(4) {
        xs.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok((meta, FlatParams(xs)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> BlobMeta {
        BlobMeta { node_id: 3, round: 7, epoch: 2, n_examples: 38400 }
    }

    #[test]
    fn round_trip() {
        let p = FlatParams(vec![1.0, -2.5, f32::MIN_POSITIVE, 1e30]);
        let blob = encode_blob(&meta(), &p);
        let (m2, p2) = decode_blob(&blob).unwrap();
        assert_eq!(m2, meta());
        assert_eq!(p2, p);
    }

    #[test]
    fn empty_params_round_trip() {
        let p = FlatParams(vec![]);
        let (m2, p2) = decode_blob(&encode_blob(&meta(), &p)).unwrap();
        assert_eq!(m2, meta());
        assert!(p2.is_empty());
    }

    #[test]
    fn detects_truncation() {
        let blob = encode_blob(&meta(), &FlatParams(vec![1.0; 100]));
        assert!(decode_blob(&blob[..blob.len() - 4]).is_err());
        assert!(decode_blob(&blob[..10]).is_err());
    }

    #[test]
    fn detects_corruption() {
        let mut blob = encode_blob(&meta(), &FlatParams(vec![1.0; 100]));
        let n = blob.len();
        blob[n - 1] ^= 0xFF;
        assert!(decode_blob(&blob).is_err());
    }

    #[test]
    fn detects_bad_magic_and_version() {
        let mut blob = encode_blob(&meta(), &FlatParams(vec![1.0]));
        blob[0] = 0;
        assert!(decode_blob(&blob).is_err());
        let mut blob2 = encode_blob(&meta(), &FlatParams(vec![1.0]));
        blob2[4] = 99;
        assert!(decode_blob(&blob2).is_err());
    }
}
