//! Hot-path kernel microbench — the perf trajectory of the [`fedless::par`]
//! kernel layer. Measures GB/s for:
//!
//! * **aggregation** — the old K-sweep axpy loop vs the fused one-pass
//!   `weighted_average` (sequential and pooled at 1/2/8 threads)
//! * **codec** — q8 encode/decode, scalar vs chunk-parallel
//! * **hash** — byte-at-a-time FNV (`hash_f32s`) vs the word-at-a-time
//!   chunked hash (sequential and pooled)
//!
//! at mnist-/lm-/14M-sized parameter vectors. Results land in
//! `BENCH_kernels.json` (re-run after kernel changes and compare; CI
//! runs `--check` mode — tiny size, few iters, same artifact shape — and
//! uploads the file). All variants compute bit-identical results; only
//! the GB/s may move. Needs no artifacts or PJRT runtime.
//!
//! Run: `cargo bench --offline --bench kernels [-- --check]`

use std::fmt::Write as _;
use std::fs;
use std::time::Instant;

use fedless::compress::{Codec, Q8};
use fedless::par::ChunkPool;
use fedless::tensor::flat::{weighted_average_pooled, FlatParams};
use fedless::util::hash::{chunked_hash_f32s_pooled, hash_f32s};
use fedless::util::Rng;

const K: usize = 5; // clients per aggregation (a paper-sized fan-in)

struct Row {
    kernel: &'static str,
    params: usize,
    threads: usize,
    gbps: f64,
}

fn time<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() / iters as f64
}

fn gbps(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs / 1e9
}

fn random_params(rng: &mut Rng, n: usize) -> FlatParams {
    FlatParams((0..n).map(|_| rng.normal_f32()).collect())
}

/// The replaced aggregation: K full memory sweeps over the output.
fn axpy_sweeps(xs: &[&FlatParams], weights: &[f32]) -> FlatParams {
    let mut out = FlatParams::zeros(xs[0].len());
    for (x, &w) in xs.iter().zip(weights) {
        out.axpy(w, x);
    }
    out
}

fn bench_size(n: usize, iters: usize, threads: &[usize], rows: &mut Vec<Row>) {
    let mut rng = Rng::new(n as u64 ^ 0xBEEF);
    let clients: Vec<FlatParams> = (0..K).map(|_| random_params(&mut rng, n)).collect();
    let refs: Vec<&FlatParams> = clients.iter().collect();
    let w = vec![1.0 / K as f32; K];
    let agg_bytes = n * 4 * K; // bytes read per aggregation

    println!("\n--- {n} params ---");
    let mut push = |kernel: &'static str, threads: usize, bytes: usize, secs: f64| {
        let r = Row { kernel, params: n, threads, gbps: gbps(bytes, secs) };
        println!("{:>24}  t={:<2}  {:>8.2} GB/s", r.kernel, r.threads, r.gbps);
        rows.push(r);
    };

    // aggregation: K-sweep axpy baseline, then fused at each thread count
    let s = time(iters, || {
        std::hint::black_box(axpy_sweeps(&refs, &w));
    });
    push("agg_axpy_ksweep", 1, agg_bytes, s);
    for &t in threads {
        let pool = ChunkPool::new(t);
        let s = time(iters, || {
            std::hint::black_box(weighted_average_pooled(&refs, &w, pool));
        });
        push("agg_fused", t, agg_bytes, s);
    }

    // codec: q8 encode/decode, scalar vs pooled (bytes = raw f32 moved)
    let p = &clients[0];
    for &t in threads {
        let pool = ChunkPool::new(t);
        let s = time(iters, || {
            std::hint::black_box(Q8.encode_pooled(p, None, pool));
        });
        push("q8_encode", t, n * 4, s);
        let enc = Q8.encode_pooled(p, None, pool);
        let s = time(iters, || {
            std::hint::black_box(Q8.decode_pooled(&enc, n, None, pool).unwrap());
        });
        push("q8_decode", t, n * 4, s);
    }

    // hash: byte-at-a-time FNV baseline vs chunked word-at-a-time
    let s = time(iters, || {
        std::hint::black_box(hash_f32s(p.as_slice()));
    });
    push("hash_fnv_bytewise", 1, n * 4, s);
    for &t in threads {
        let pool = ChunkPool::new(t);
        let s = time(iters, || {
            std::hint::black_box(chunked_hash_f32s_pooled(p.as_slice(), pool));
        });
        push("hash_chunked", t, n * 4, s);
    }
}

/// GB/s of `kernel` at (`params`, `threads`), if measured.
fn lookup(rows: &[Row], kernel: &str, params: usize, threads: usize) -> Option<f64> {
    rows.iter()
        .find(|r| r.kernel == kernel && r.params == params && r.threads == threads)
        .map(|r| r.gbps)
}

fn main() {
    let check = std::env::args().any(|a| a == "--check");
    // check mode: one small size and few iters — validates the bench
    // end-to-end and keeps the artifact shape without burning minutes
    let (sizes, iters): (Vec<usize>, usize) = if check {
        (vec![20_490], 5)
    } else {
        (vec![20_490, 470_528, 14_000_000], 8)
    };
    let threads = [1usize, 2, 8];
    println!(
        "fedless kernel microbench ({} mode): fused agg vs axpy, parallel q8, chunked hash",
        if check { "check" } else { "full" }
    );

    let mut rows = Vec::new();
    for &n in &sizes {
        let it = if n > 1_000_000 { 3 } else { iters };
        bench_size(n, it, &threads, &mut rows);
    }

    // headline speedups at the largest size (the acceptance ratios)
    let big = *sizes.last().unwrap();
    let ratio = |a: Option<f64>, b: Option<f64>| -> f64 {
        match (a, b) {
            (Some(a), Some(b)) if b > 0.0 => a / b,
            _ => 0.0,
        }
    };
    let agg_speedup =
        ratio(lookup(&rows, "agg_fused", big, 8), lookup(&rows, "agg_axpy_ksweep", big, 1));
    let q8_speedup = ratio(lookup(&rows, "q8_encode", big, 8), lookup(&rows, "q8_encode", big, 1));
    let hash_speedup =
        ratio(lookup(&rows, "hash_chunked", big, 8), lookup(&rows, "hash_fnv_bytewise", big, 1));
    println!("\nheadline at {big} params:");
    println!("  fused agg (8t) vs axpy K-sweep : {agg_speedup:.2}x");
    println!("  parallel q8 encode (8t) vs 1t  : {q8_speedup:.2}x");
    println!("  chunked hash (8t) vs FNV       : {hash_speedup:.2}x");

    let mut json = String::from("{\n  \"bench\": \"hot_path_kernels\",\n");
    let _ = writeln!(json, "  \"clients_per_agg\": {K},");
    let _ = writeln!(json, "  \"check_mode\": {check},");
    let _ = writeln!(json, "  \"provenance\": \"measured\",");
    let _ = writeln!(
        json,
        "  \"headline\": {{\"params\": {big}, \"fused_agg_8t_vs_axpy\": {agg_speedup:.3}, \
         \"q8_encode_8t_vs_1t\": {q8_speedup:.3}, \"chunked_hash_8t_vs_fnv\": {hash_speedup:.3}}},"
    );
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = writeln!(
            json,
            "    {{\"kernel\": \"{}\", \"params\": {}, \"threads\": {}, \"gbps\": {:.3}}}{}",
            r.kernel,
            r.params,
            r.threads,
            r.gbps,
            if i + 1 < rows.len() { "," } else { "" }
        );
    }
    json.push_str("  ]\n}\n");
    fs::write("BENCH_kernels.json", &json).expect("write BENCH_kernels.json");
    println!("\nwrote BENCH_kernels.json");
}
